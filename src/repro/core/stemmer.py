"""Pure-Python English Snowball stemmer (Porter2).

The paper optionally integrates "a C-based implementation of the Snowball
stemmer" (PyStemmer). That C dependency is unavailable offline, so this is a
faithful pure-Python implementation of the Snowball *english* algorithm
(Porter2, https://snowballstem.org/algorithms/english/stemmer.html).

Stemming is applied to the *vocabulary*, not to every token occurrence
(exactly the trick the paper describes): the tokenizer stems each unique word
once and looks occurrences up through the vocab dict, so stemmer speed is
never on the hot path.
"""

from __future__ import annotations

from functools import lru_cache

_VOWELS = frozenset("aeiouy")
_DOUBLES = ("bb", "dd", "ff", "gg", "mm", "nn", "pp", "rr", "tt")
_LI_ENDING = frozenset("cdeghkmnrt")

_EXCEPTIONS1 = {
    "skis": "ski", "skies": "sky", "dying": "die", "lying": "lie",
    "tying": "tie", "idly": "idl", "gently": "gentl", "ugly": "ugli",
    "early": "earli", "only": "onli", "singly": "singl",
    # invariants
    "sky": "sky", "news": "news", "howe": "howe", "atlas": "atlas",
    "cosmos": "cosmos", "bias": "bias", "andes": "andes",
}

_EXCEPTIONS2 = frozenset(
    {"inning", "outing", "canning", "herring", "earring", "proceed",
     "exceed", "succeed"}
)

_STEP2_SUFFIXES = (
    ("ization", "ize"), ("ational", "ate"), ("ousness", "ous"),
    ("iveness", "ive"), ("fulness", "ful"), ("biliti", "ble"),
    ("tional", "tion"), ("lessli", "less"), ("entli", "ent"),
    ("ation", "ate"), ("alism", "al"), ("aliti", "al"),
    ("fulli", "ful"), ("ousli", "ous"), ("iviti", "ive"),
    ("enci", "ence"), ("anci", "ance"), ("abli", "able"),
    ("izer", "ize"), ("ator", "ate"), ("alli", "al"),
    ("bli", "ble"),
)

_STEP3_SUFFIXES = (
    ("ational", "ate"), ("tional", "tion"), ("alize", "al"),
    ("icate", "ic"), ("iciti", "ic"), ("ical", "ic"),
    ("ful", ""), ("ness", ""),
)

_STEP4_SUFFIXES = (
    "ement", "ance", "ence", "able", "ible", "ment",
    "ant", "ent", "ism", "ate", "iti", "ous", "ive", "ize",
    "al", "er", "ic",
)


def _is_vowel(word: str, i: int) -> bool:
    return word[i] in _VOWELS


def _regions(word: str) -> tuple[int, int]:
    """Compute R1 and R2 start offsets per the Snowball definition."""
    n = len(word)
    # special prefixes
    r1 = n
    for prefix in ("gener", "commun", "arsen"):
        if word.startswith(prefix):
            r1 = len(prefix)
            break
    else:
        for i in range(1, n):
            if not _is_vowel(word, i) and _is_vowel(word, i - 1):
                r1 = i + 1
                break
    r2 = n
    for i in range(r1 + 1, n):
        if not _is_vowel(word, i) and _is_vowel(word, i - 1):
            r2 = i + 1
            break
    return r1, r2


def _ends_short_syllable(word: str) -> bool:
    n = len(word)
    if n == 2:
        return _is_vowel(word, 0) and not _is_vowel(word, 1)
    if n >= 3:
        c1, v, c2 = word[-3], word[-2], word[-1]
        return (
            c1 not in _VOWELS
            and v in _VOWELS
            and c2 not in _VOWELS
            and c2 not in "wxY"
        )
    return False


def _is_short(word: str, r1: int) -> bool:
    return r1 >= len(word) and _ends_short_syllable(word)


def _preprocess(word: str) -> str:
    if word.startswith("'"):
        word = word[1:]
    if word.startswith("y"):
        word = "Y" + word[1:]
    chars = list(word)
    for i in range(1, len(chars)):
        if chars[i] == "y" and chars[i - 1] in _VOWELS:
            chars[i] = "Y"
    return "".join(chars)


def _step0(word: str) -> str:
    for suf in ("'s'", "'s", "'"):
        if word.endswith(suf):
            return word[: -len(suf)]
    return word


def _step1a(word: str) -> str:
    if word.endswith("sses"):
        return word[:-2]
    if word.endswith("ied") or word.endswith("ies"):
        return word[:-2] if len(word) > 4 else word[:-1]
    if word.endswith("us") or word.endswith("ss"):
        return word
    if word.endswith("s"):
        # delete if the preceding word part contains a vowel not
        # immediately before the s
        if any(ch in _VOWELS for ch in word[:-2].lower()):
            return word[:-1]
    return word


def _step1b(word: str, r1: int) -> str:
    for suf in ("eedly", "eed"):
        if word.endswith(suf):
            if len(word) - len(suf) >= r1:
                return word[: -len(suf)] + "ee"
            return word
    for suf in ("ingly", "edly", "ing", "ed"):
        if word.endswith(suf):
            stem = word[: -len(suf)]
            if any(ch in _VOWELS for ch in stem.lower()):
                if stem.endswith(("at", "bl", "iz")):
                    return stem + "e"
                if stem.endswith(_DOUBLES):
                    return stem[:-1]
                if _is_short(stem, _regions(stem)[0]):
                    return stem + "e"
                return stem
            return word
    return word


def _step1c(word: str) -> str:
    if (
        len(word) > 2
        and word[-1] in "yY"
        and word[-2] not in _VOWELS
    ):
        return word[:-1] + "i"
    return word


def _step2(word: str, r1: int) -> str:
    for suf, repl in _STEP2_SUFFIXES:
        if word.endswith(suf):
            if len(word) - len(suf) >= r1:
                if suf == "bli":  # ogi / li special handling below
                    return word[:-3] + "ble"
                return word[: -len(suf)] + repl
            return word
    if word.endswith("ogi") and len(word) - 3 >= r1 and len(word) >= 4 and word[-4] == "l":
        return word[:-1]
    if word.endswith("li") and len(word) - 2 >= r1 and len(word) >= 3 and word[-3] in _LI_ENDING:
        return word[:-2]
    return word


def _step3(word: str, r1: int, r2: int) -> str:
    for suf, repl in _STEP3_SUFFIXES:
        if word.endswith(suf):
            if len(word) - len(suf) >= r1:
                return word[: -len(suf)] + repl
            return word
    if word.endswith("ative") and len(word) - 5 >= r2:
        return word[:-5]
    return word


def _step4(word: str, r2: int) -> str:
    if word.endswith("ion"):
        if len(word) - 3 >= r2 and len(word) >= 4 and word[-4] in "st":
            return word[:-3]
        return word
    for suf in _STEP4_SUFFIXES:
        if word.endswith(suf):
            if len(word) - len(suf) >= r2:
                return word[: -len(suf)]
            return word
    return word


def _step5(word: str, r1: int, r2: int) -> str:
    if word.endswith("e"):
        if len(word) - 1 >= r2:
            return word[:-1]
        if len(word) - 1 >= r1 and not _ends_short_syllable(word[:-1]):
            return word[:-1]
        return word
    if word.endswith("l") and len(word) - 1 >= r2 and len(word) >= 2 and word[-2] == "l":
        return word[:-1]
    return word


@lru_cache(maxsize=1 << 18)
def snowball_stem(word: str) -> str:
    """Stem one lowercase English word with the Snowball (Porter2) algorithm."""
    if len(word) <= 2:
        return word
    if word in _EXCEPTIONS1:
        return _EXCEPTIONS1[word]
    word = _preprocess(word)
    word = _step0(word)
    word = _step1a(word)
    if word.lower() in _EXCEPTIONS2:
        return word.lower()
    r1, r2 = _regions(word.lower())
    word = _step1b(word, r1)
    word = _step1c(word)
    r1, r2 = _regions(word.lower())
    word = _step2(word, r1)
    word = _step3(word, r1, r2)
    word = _step4(word, r2)
    word = _step5(word, r1, r2)
    return word.lower()


class SnowballStemmer:
    """Object façade matching PyStemmer's ``Stemmer('english')`` interface."""

    def __init__(self, language: str = "english") -> None:
        if language not in ("english", "en", "porter2", "snowball"):
            raise ValueError(f"only English is bundled, got {language!r}")

    def stemWord(self, word: str) -> str:  # noqa: N802 - PyStemmer API
        return snowball_stem(word)

    def stemWords(self, words: list[str]) -> list[str]:  # noqa: N802
        return [snowball_stem(w) for w in words]
