"""Benchmark harness — one section per paper table.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Emits CSV-ish lines ``table,key=value,...`` and writes
benchmarks/out/results.json plus BENCH_1.json (fused pipeline + vectorized
indexing — the PR-1 perf trajectory numbers), BENCH_2.json (gathered vs
full-scan retrieval regimes — the PR-2 numbers) and BENCH_3.json (cost-model
planner vs forced regimes + residency transfer audit — the PR-3 numbers) at
the repo root.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller corpora (CI-sized)")
    ap.add_argument("--force", action="store_true",
                    help="allow a --fast run to overwrite full-scale "
                         "BENCH_* artifacts")
    args = ap.parse_args()

    from . import fused, gathered, kernels_bench, planner, throughput, \
        tokenization, variants

    # every BENCH_* write goes through the clobber guard: a --fast run
    # refuses to replace a committed full-scale artifact (the PR-4
    # incident) unless --force
    def _write(path, payload):
        planner._guarded_write(path, payload, fast=args.fast,
                               force=args.force)

    results = {}
    t0 = time.time()

    results["bench1_fused"] = fused.run(fast=args.fast)
    for section, r in results["bench1_fused"].items():
        print(f"bench1_{section}," + ",".join(
            f"{k}={v}" for k, v in r.items()), flush=True)
    _write("BENCH_1.json", results["bench1_fused"])

    results["bench2_gathered"] = gathered.run(fast=args.fast)
    for r in results["bench2_gathered"]["cells"]:
        print("bench2_gathered," + ",".join(
            f"{k}={v}" for k, v in r.items()), flush=True)
    _write("BENCH_2.json", results["bench2_gathered"])

    results["bench3_planner"] = planner.run(fast=args.fast)
    for r in results["bench3_planner"]["cells"]:
        print("bench3_planner," + ",".join(
            f"{k}={v}" for k, v in r.items()), flush=True)
    _write("BENCH_3.json", results["bench3_planner"])
    _write("BENCH_4.json", results["bench3_planner"]["pruned"])

    sizes = ((1000, 3000), (5000, 10000)) if args.fast else \
        ((2000, 5000), (10000, 20000), (50000, 50000))
    results["table1_throughput"] = throughput.run(sizes=sizes)
    for r in results["table1_throughput"]:
        print("table1," + ",".join(f"{k}={v}" for k, v in r.items()),
              flush=True)

    n_docs = 300 if args.fast else 800
    results["table2_tokenization"] = tokenization.run(n_docs=n_docs)
    for r in results["table2_tokenization"]:
        print("table2," + ",".join(f"{k}={v}" for k, v in r.items()),
              flush=True)

    results["tokenize_throughput"] = tokenization.run_throughput(
        n_docs=1000 if args.fast else 3000)
    print("tokenize_throughput," + ",".join(
        f"{k}={v}" for k, v in results["tokenize_throughput"].items()),
        flush=True)

    results["table3_variants"] = variants.run(n_docs=n_docs)
    for r in results["table3_variants"]:
        print("table3," + ",".join(f"{k}={v}" for k, v in r.items()),
              flush=True)

    results["kernels"] = kernels_bench.run(
        n_docs=2048 if args.fast else 8192,
        n_vocab=2000 if args.fast else 8000)
    for r in results["kernels"]:
        print("kernels," + ",".join(f"{k}={v}" for k, v in r.items()),
              flush=True)

    os.makedirs("benchmarks/out", exist_ok=True)
    with open("benchmarks/out/results.json", "w") as f:
        json.dump(results, f, indent=1)
    print(f"done in {time.time() - t0:.1f}s -> benchmarks/out/results.json")


if __name__ == "__main__":
    main()
