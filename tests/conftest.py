"""Shared fixtures. NOTE: device count stays 1 here (the 512-device flag is
set ONLY inside launch/dryrun.py); multi-device tests spawn subprocesses or
use mesh-of-one."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_corpus(rng, n_docs=60, n_vocab=50, max_len=30):
    return [rng.integers(0, n_vocab, size=rng.integers(1, max_len)
                         ).astype(np.int32) for _ in range(n_docs)]
