"""Fault tolerance: typed errors, the exact degradation ladder, injection.

Pins the PR-6 contract:

* **errors** — one taxonomy (`RetrievalError` base) covers every serving
  failure; each subclass also inherits the builtin it replaced, so
  pre-taxonomy ``except ValueError`` callers keep working.
* **ladder** — for every injected fault class × five BM25 variants, the
  degraded answer carries each returned document's EXACT oracle score
  (the repo-wide exactness idiom: float32 reassociation tolerance) and
  ``last_plan.degradations`` names the hop taken; pruned→resident
  recovery is bit-identical (same machinery minus the skip).
* **strict mode** — ``on_fault="raise"`` surfaces the typed error instead
  of degrading; forced-regime calls are strict implicitly.
* **sanitizer** — one ``validate_query_batch`` behind every entry point,
  with per-engine counters for dropped/recast tokens.
* **caps** — ``sharded_retrieve_adaptive`` raises ``PlanOverflowError``
  (with the attempted bucket trail) instead of looping or silently
  returning when overflow persists at the Σdf bucket.
"""

import time

import numpy as np
import pytest

from conftest import make_corpus
from repro.core import (BM25Params, ScipyBM25, build_index,
                        build_sharded_indexes, topk_numpy,
                        validate_query_batch)
from repro.serve import (DeviceRetriever, InvalidQueryError,
                         PlanOverflowError, ResidencyError, RetrievalEngine,
                         RetrievalError, ScoreIntegrityError,
                         TruncationWarning)
from repro.serve.errors import RetrievalConfigError
from repro.serve.faults import SITES, FaultSpec, inject_faults

ALL_VARIANTS = ["robertson", "atire", "lucene", "bm25l", "bm25+"]

SMALL = dict(block_size=16, tile=16, acc_block=16, frag=8, q_max=8)

pytestmark = pytest.mark.no_chaos      # this module ARMS faults itself


def _mk(rng, method, n_vocab=64, n_docs=90):
    corpus = make_corpus(rng, n_docs=n_docs, n_vocab=n_vocab, max_len=20)
    return build_index(corpus, n_vocab, params=BM25Params(method=method))


def _queries(rng, n_vocab, n=3):
    return [rng.integers(0, n_vocab, size=rng.integers(1, 6)
                         ).astype(np.int32) for _ in range(n)]


def _assert_exact(dr, ids, vals, k, oracle=None):
    """The repo's exactness idiom: every returned id carries its exact
    oracle score, and the top-k score vector equals the oracle's."""
    sc = oracle or ScipyBM25(dr.index)
    for i, q in enumerate(dr.last_queries):
        ref = sc.score(q)
        _, ref_v = topk_numpy(ref[None], k)
        np.testing.assert_allclose(vals[i], ref_v[0], atol=1e-4)
        np.testing.assert_allclose(ref[ids[i]], vals[i], atol=1e-4)


# -- taxonomy ----------------------------------------------------------------

def test_taxonomy_one_base_class():
    for exc in (InvalidQueryError, PlanOverflowError, ResidencyError,
                ScoreIntegrityError, RetrievalConfigError):
        assert issubclass(exc, RetrievalError)
    # back-compat: the classes that replaced bare ValueErrors still ARE one
    for exc in (InvalidQueryError, ResidencyError, RetrievalConfigError):
        assert issubclass(exc, ValueError)
    assert issubclass(TruncationWarning, RuntimeWarning)


def test_config_errors_are_typed(rng):
    idx = _mk(rng, "lucene")
    with pytest.raises(RetrievalConfigError):
        DeviceRetriever(idx, regime="wand", **SMALL)
    with pytest.raises(RetrievalConfigError):
        DeviceRetriever(idx, on_fault="panic", **SMALL)
    with pytest.raises(RetrievalConfigError):
        DeviceRetriever(idx, regime="pruned", gather="host", **SMALL)


def test_fault_spec_rejects_unknown_site():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec(site="nope", kind="residency")
    with pytest.raises(ValueError, match="no kind"):
        FaultSpec(site="residency.put_posting_arrays", kind="nan_board")
    assert set(SITES) == {"residency.put_posting_arrays",
                          "plan.fragments_device", "kernel.resident_pruned",
                          "query.batch", "snapshot.write",
                          "snapshot.manifest", "snapshot.array",
                          "kernel.stall", "frontend.former", "queue.flood"}
    with pytest.raises(ValueError, match="no kind"):
        FaultSpec(site="snapshot.array", kind="torn_write")


# -- ladder recovery, every fault class × five variants ----------------------

@pytest.mark.parametrize("method", ALL_VARIANTS)
def test_residency_fault_recovers_exact(method, rng):
    """Upload failure in the host-gather hop degrades (here: to the
    oracle rung — the gathered-only build has no blocked layout) with the
    exact answer."""
    idx = _mk(rng, method)
    dr = DeviceRetriever(idx, regime="gathered", gather="host", **SMALL)
    qs = _queries(rng, 64)
    with inject_faults({"site": "residency.put_posting_arrays",
                        "kind": "residency", "times": 1, "seed": 1}) as sp:
        ids, vals = dr.retrieve_batch(qs, 7)
    assert sp[0].fired == 1
    trail = dr.last_plan.degradations
    assert [t["from"] for t in trail] == ["host"]
    assert trail[0]["to"] == "oracle" and trail[0]["error"] == "ResidencyError"
    _assert_exact(dr, ids, vals, 7)
    assert dr.health()["degradations"] == {"host->oracle": 1}


@pytest.mark.parametrize("method", ALL_VARIANTS)
def test_residency_fault_recovers_via_blocked(method, rng):
    """An auto build holds the blocked layout, so the ladder lands there
    (never reaching the oracle) when the host gather's upload fails."""
    idx = _mk(rng, method)
    dr = DeviceRetriever(idx, regime="auto", gather="host", **SMALL)
    qs = _queries(rng, 64)
    # the auto cost model must route this batch to the host gather;
    # force the work ratio by querying a thin token slice
    with inject_faults({"site": "residency.put_posting_arrays",
                        "kind": "residency", "times": 1, "seed": 1}):
        ids, vals = dr.retrieve_batch(qs, 7)
    trail = dr.last_plan.degradations
    if trail:                       # planner picked the gathered entry
        assert trail[0]["from"] == "host" and trail[0]["to"] == "blocked"
    _assert_exact(dr, ids, vals, 7)


@pytest.mark.parametrize("method", ALL_VARIANTS)
def test_overflow_fault_recovers_exact(method, rng):
    """nf-bucket exhaustion in the device fragment planner hops
    resident → host with the exact answer."""
    idx = _mk(rng, method)
    dr = DeviceRetriever(idx, regime="gathered", gather="resident",
                         plan="device", **SMALL)
    qs = _queries(rng, 64)
    ids0, vals0 = dr.retrieve_batch(qs, 7)
    with inject_faults({"site": "plan.fragments_device",
                        "kind": "overflow", "times": 1, "seed": 2}) as sp:
        ids, vals = dr.retrieve_batch(qs, 7)
    assert sp[0].fired == 1
    trail = dr.last_plan.degradations
    assert trail[0]["from"] == "resident" and trail[0]["to"] == "host"
    assert trail[0]["error"] == "PlanOverflowError"
    np.testing.assert_allclose(vals, vals0, atol=1e-5)
    _assert_exact(dr, ids, vals, 7)


@pytest.mark.parametrize("method", ALL_VARIANTS)
@pytest.mark.parametrize("kind", ["nan_board", "inf_board"])
def test_score_integrity_fault_recovers_bit_identical(method, kind, rng):
    """A poisoned [B, k] board from the pruned kernel is caught by the
    finite-check and re-served by the unpruned resident hop —
    bit-identical, because pruning only removes provably-losing work."""
    idx = _mk(rng, method)
    dr = DeviceRetriever(idx, regime="pruned", gather="resident",
                         plan="host", **SMALL)
    qs = _queries(rng, 64)
    ids0, vals0 = dr.retrieve_batch(qs, 7)
    with inject_faults({"site": "kernel.resident_pruned", "kind": kind,
                        "times": 1, "seed": 3}) as sp:
        ids, vals = dr.retrieve_batch(qs, 7)
    assert sp[0].fired == 1
    trail = dr.last_plan.degradations
    assert trail[0]["from"] == "pruned" and trail[0]["to"] == "resident"
    assert trail[0]["error"] == "ScoreIntegrityError"
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(vals0))
    _assert_exact(dr, ids, vals, 7)


@pytest.mark.parametrize("method", ALL_VARIANTS)
@pytest.mark.parametrize("kind", ["query.range", "query.negative",
                                  "query.dtype", "query.ragged"])
def test_malformed_query_fault_sanitized_exact(method, kind, rng):
    """Corrupted client batches are repaired by the shared sanitizer; the
    answer is exact for the sanitized batch (dropping an unscorable token
    is the only behavior-preserving repair)."""
    idx = _mk(rng, method)
    dr = DeviceRetriever(idx, regime="gathered", gather="host", **SMALL)
    qs = _queries(rng, 64, n=4)
    with inject_faults({"site": "query.batch", "kind": kind,
                        "times": 1, "seed": 4}) as sp:
        ids, vals = dr.retrieve_batch(qs, 7)
    assert sp[0].fired == 1
    assert not dr.last_plan.degradations        # sanitizer, not the ladder
    if kind in ("query.range", "query.negative"):
        assert dr.query_counters.get("dropped_tokens", 0) >= 1
    if kind == "query.dtype":
        assert dr.query_counters.get("recast_queries", 0) >= 1
    if kind == "query.ragged":
        assert dr.query_counters.get("null_queries", 0) >= 1
    _assert_exact(dr, ids, vals, 7)


def test_fault_injection_is_deterministic(rng):
    idx = _mk(rng, "lucene")
    dr = DeviceRetriever(idx, regime="gathered", gather="host", **SMALL)
    qs = _queries(rng, 64, n=4)
    runs = []
    for _ in range(2):
        dr.query_counters.clear()
        with inject_faults({"site": "query.batch", "kind": "query.range",
                            "times": 1, "seed": 11}):
            dr.retrieve_batch(qs, 5)
        runs.append([q.tolist() for q in dr.last_queries])
    assert runs[0] == runs[1]          # same seed -> same corruption


# -- strict mode -------------------------------------------------------------

def test_strict_mode_surfaces_typed_errors(rng):
    idx = _mk(rng, "lucene")
    dr = DeviceRetriever(idx, regime="gathered", gather="host",
                         on_fault="raise", **SMALL)
    qs = _queries(rng, 64)
    # strict calls never enter the ladder guard (no recovery path there),
    # so surfacing an injected fault needs an UNguarded spec
    with inject_faults({"site": "residency.put_posting_arrays",
                        "kind": "residency", "times": 1,
                        "guarded": False}):
        with pytest.raises(ResidencyError, match="injected"):
            dr.retrieve_batch(qs, 5)
    # malformed input raises the typed query error instead of repairing
    with pytest.raises(InvalidQueryError, match="token ids"):
        dr.retrieve_batch([np.array([999999], np.int64)], 5)
    # ... and the base class catches everything
    with inject_faults({"site": "residency.put_posting_arrays",
                        "kind": "residency", "times": 1,
                        "guarded": False}):
        with pytest.raises(RetrievalError):
            dr.retrieve_batch(qs, 5)
    # a GUARDED spec is a no-op against a strict retriever: chaos mode
    # cannot crash an on_fault="raise" deployment
    with inject_faults({"site": "residency.put_posting_arrays",
                        "kind": "residency", "times": 1}) as sp:
        dr.retrieve_batch(qs, 5)
    assert sp[0].fired == 0


def test_forced_regime_is_strict(rng):
    """A per-call regime override is operator intent — no silent ladder."""
    idx = _mk(rng, "lucene")
    dr = DeviceRetriever(idx, regime="gathered", gather="resident",
                         plan="host", **SMALL)
    with pytest.raises(ValueError, match="gathered-only"):
        dr.retrieve_batch([np.array([1], np.int32)], 2, regime="blocked")
    with pytest.raises(RetrievalError):
        dr.retrieve_batch([np.array([1], np.int32)], 2, regime="blocked")


# -- the sanitizer, directly -------------------------------------------------

def test_validate_query_batch_repairs_and_counts():
    c = {}
    out = validate_query_batch(
        [np.array([1, 2, 70, -3]),                  # out-of-range + negative
         None,                                      # null entry
         np.array([[1, 2]]),                        # 2-D drift
         np.array([1.0, 2.0]),                      # integral float drift
         np.array([1.5, 2.0]),                      # non-integral: drop
         np.array([np.nan, 3.0])],                  # NaN: drop
        64, counters=c)
    assert [q.tolist() for q in out] == [[1, 2], [], [1, 2], [1, 2],
                                         [2], [3]]
    assert all(q.dtype == np.int32 for q in out)
    assert c["dropped_tokens"] == 4 and c["null_queries"] == 1
    assert c["raveled_queries"] == 1 and c["recast_queries"] >= 3


def test_validate_query_batch_strict_raises():
    with pytest.raises(InvalidQueryError):
        validate_query_batch([np.array([99])], 64, on_invalid="raise")
    with pytest.raises(InvalidQueryError):
        validate_query_batch([None], 64, on_invalid="raise")
    with pytest.raises(InvalidQueryError):
        validate_query_batch([np.array([1.5])], 64, on_invalid="raise")
    # integral float drift is lossless — allowed even in strict mode
    out = validate_query_batch([np.array([3.0])], 64, on_invalid="raise")
    assert out[0].tolist() == [3]


# -- engine-level health -----------------------------------------------------

def test_engine_health_reports_ladder_and_sanitizer(rng):
    corpus = make_corpus(rng, n_docs=80, n_vocab=64)
    shards = build_sharded_indexes(corpus, 64, 2, params=BM25Params())
    eng = RetrievalEngine(shards, k=5, deadline_s=5.0, scorer="gathered",
                          scorer_opts=dict(gather="host", **SMALL))
    h0 = eng.health()
    assert h0["responses"] == 0 and len(h0["shards"]) == 2
    with inject_faults({"site": "residency.put_posting_arrays",
                        "kind": "residency", "times": 1, "seed": 6}):
        r = eng.retrieve_batch([np.array([1, 2, 60], np.int32),
                                np.array([5], np.int32)])
    assert not r.degraded               # shard answered (via its ladder)
    h = eng.health()
    assert h["responses"] == 1 and h["degraded_responses"] == 0
    assert sum(s["batches_degraded"] for s in h["shards"]) == 1
    hops = {}
    for s in h["shards"]:
        for key, n in s["degradations"].items():
            hops[key] = hops.get(key, 0) + n
    assert sum(hops.values()) == 1      # exactly one shard took one hop
    # engine-boundary sanitizer counters live on the engine itself
    eng.retrieve(np.array([1, 99999], np.int64))
    assert eng.health()["queries"]["dropped_tokens"] == 1


# -- satellite: adaptive sharded retry is capped -----------------------------

def test_sharded_adaptive_cap_raises_plan_overflow(monkeypatch):
    """Persistent overflow at the Σdf bucket raises the typed error with
    the attempted bucket trail instead of looping or silently returning."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.core import retrieval as rmod

    calls = []

    def fake_make(mesh, shard_axes, *, p_max, k, n_docs_per_shard,
                  return_overflow, gathered):
        def fn(idx_arrays, q_tokens, q_weights):
            calls.append(p_max)
            b = q_tokens.shape[0]
            return (jnp.zeros((b, k), jnp.int32),
                    jnp.zeros((b, k), jnp.float32),
                    jnp.ones((b,), bool))          # overflow NEVER clears
        return fn

    monkeypatch.setattr(rmod, "make_sharded_retrieve", fake_make)
    mesh = Mesh(np.array(jax.devices()[:1]), ("shards",))
    retrieve = rmod.sharded_retrieve_adaptive(
        mesh, ("shards",), k=3, n_docs_per_shard=8, p_floor=8)
    idx_arrays = (None, np.zeros((1, 64)), None, None, None, None)
    q = jnp.zeros((2, 4), jnp.int32)
    w = jnp.zeros((2, 4), jnp.float32)
    with pytest.raises(PlanOverflowError, match="attempted") as ei:
        retrieve(idx_arrays, q, w)
    assert calls == [8, 16, 32, 64]                # pow2 regrowth to cap
    assert ei.value.attempted == calls and ei.value.cap == 64


def test_sharded_adaptive_success_path_unchanged(monkeypatch):
    """Overflow that clears mid-trail still returns (ids, vals, p)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.core import retrieval as rmod

    def fake_make(mesh, shard_axes, *, p_max, k, n_docs_per_shard,
                  return_overflow, gathered):
        def fn(idx_arrays, q_tokens, q_weights):
            b = q_tokens.shape[0]
            over = jnp.full((b,), p_max < 32)
            return (jnp.zeros((b, k), jnp.int32),
                    jnp.zeros((b, k), jnp.float32), over)
        return fn

    monkeypatch.setattr(rmod, "make_sharded_retrieve", fake_make)
    mesh = Mesh(np.array(jax.devices()[:1]), ("shards",))
    retrieve = rmod.sharded_retrieve_adaptive(
        mesh, ("shards",), k=3, n_docs_per_shard=8, p_floor=8)
    idx_arrays = (None, np.zeros((1, 64)), None, None, None, None)
    ids, vals, p = retrieve(idx_arrays, jnp.zeros((2, 4), jnp.int32),
                            jnp.zeros((2, 4), jnp.float32))
    assert p == 32


# -- satellite: taxonomy migrations ------------------------------------------

def test_corpus_coo_raises_invalid_query_error():
    from repro.core.index import _corpus_coo
    corpus = [np.array([1, 25], dtype=np.int32)]
    with pytest.raises(InvalidQueryError, match="token ids"):
        _corpus_coo(corpus, 20)
    with pytest.raises(ValueError, match="token ids"):   # back-compat
        _corpus_coo(corpus, 20)


def test_bm25_retriever_truncation_warning():
    from repro.core import BM25Retriever
    texts = [f"apple banana cherry token{i} filler words here extra"
             for i in range(50)]
    r = BM25Retriever(method="lucene", stopwords=None, stemmer=None)
    r.index(texts)
    with pytest.warns(TruncationWarning):
        r.retrieve(["apple banana cherry filler words extra"], k=5,
                   p_max=2)
    with pytest.warns(RuntimeWarning):                   # back-compat
        r.retrieve(["apple banana cherry filler words extra"], k=5,
                   p_max=2)


# -- the snapshot I/O fault lane ---------------------------------------------
#
# The three snapshot.* sites mutate REAL on-disk files (the load-side
# guard() scope makes them chaos-armable: every corruption they can inject
# is one the recovery ladder undoes exactly, except torn_write — a save-
# time crash — and stale_version — a typed refusal by design).

def _snap(tmp_path, rng, method="lucene"):
    idx = _mk(rng, method)
    from repro.sparse import snapshot
    path = str(tmp_path / "snap")
    snapshot.save_index(idx, path, block_size=16, tile=16, frag=8)
    return idx, path


@pytest.mark.parametrize("kind", ["bit_flip", "truncate"])
@pytest.mark.parametrize("guarded", [True, False])
def test_snapshot_array_fault_recovers_exact(kind, guarded, tmp_path, rng):
    """Array corruption injected during a verified load is healed by the
    dup/layout recovery ladder — the loaded index is bit-identical."""
    from repro.sparse import snapshot
    idx, path = _snap(tmp_path, rng)
    with inject_faults({"site": "snapshot.array", "kind": kind,
                        "times": 1, "seed": 7, "guarded": guarded}) as sp:
        ld = snapshot.load_index(path)
    assert sp[0].fired == 1            # load's guard scope admits the fault
    assert ld.snapshot_report["hops"]  # ... and the ladder healed it
    np.testing.assert_array_equal(ld.indptr, idx.indptr)
    np.testing.assert_array_equal(ld.doc_ids, idx.doc_ids)
    np.testing.assert_array_equal(ld.scores, idx.scores)
    np.testing.assert_array_equal(ld.nonoccurrence, idx.nonoccurrence)
    np.testing.assert_array_equal(ld.doc_lens, idx.doc_lens)


@pytest.mark.parametrize("guarded", [True, False])
def test_snapshot_manifest_corrupt_recovers_via_dup(guarded, tmp_path, rng):
    from repro.sparse import snapshot
    idx, path = _snap(tmp_path, rng)
    with inject_faults({"site": "snapshot.manifest",
                        "kind": "manifest_corrupt", "times": 1, "seed": 3,
                        "guarded": guarded}) as sp:
        ld = snapshot.load_index(path)
    assert sp[0].fired == 1
    assert "manifest<-dup" in ld.snapshot_report["hops"]
    np.testing.assert_array_equal(ld.doc_ids, idx.doc_ids)


@pytest.mark.parametrize("guarded", [True, False])
def test_snapshot_stale_version_is_typed(guarded, tmp_path, rng):
    """Version skew is a refusal, not a recovery — the dup holds the same
    future version, so no ladder hop can apply."""
    from repro.serve import SnapshotVersionError
    from repro.sparse import snapshot
    idx, path = _snap(tmp_path, rng)
    with inject_faults({"site": "snapshot.manifest",
                        "kind": "stale_version", "times": 1, "seed": 3,
                        "guarded": guarded}) as sp:
        with pytest.raises(SnapshotVersionError):
            snapshot.load_index(path)
    assert sp[0].fired == 1


def test_snapshot_torn_write_guarded_vs_unguarded(tmp_path, rng):
    """Saves run OUTSIDE any guard scope: a guarded torn_write can never
    fire there (chaos safety), an unguarded one is the kill-mid-save
    drill — and the previous snapshot survives it."""
    from repro.sparse import snapshot
    idx, path = _snap(tmp_path, rng)
    with inject_faults({"site": "snapshot.write", "kind": "torn_write",
                        "times": 1, "seed": 0}) as sp:
        snapshot.save_index(idx, path, block_size=16, tile=16, frag=8)
    assert sp[0].fired == 0            # guarded: the save was untouched
    with inject_faults({"site": "snapshot.write", "kind": "torn_write",
                        "times": 1, "seed": 0, "guarded": False}) as sp:
        with pytest.raises(OSError, match="injected"):
            snapshot.save_index(idx, path, block_size=16, tile=16, frag=8)
    assert sp[0].fired == 1
    ld = snapshot.load_index(path)     # previous generation, intact
    assert not ld.snapshot_report["hops"]
    np.testing.assert_array_equal(ld.doc_ids, idx.doc_ids)


def test_snapshot_fault_is_deterministic(tmp_path, rng):
    """Same seed -> same victim file and same corruption -> same report."""
    from repro.sparse import snapshot
    idx, _ = _snap(tmp_path, rng)
    reports = []
    for run in range(2):
        path = str(tmp_path / f"det-{run}")
        snapshot.save_index(idx, path, block_size=16, tile=16, frag=8)
        with inject_faults({"site": "snapshot.array", "kind": "bit_flip",
                            "times": 1, "seed": 42}):
            ld = snapshot.load_index(path)
        reports.append((sorted(ld.snapshot_report["corrupt"]),
                        sorted(ld.snapshot_report["hops"])))
    assert reports[0] == reports[1]


# -- no-fault behavior: the harness costs nothing when disarmed --------------

def test_healthy_path_records_no_degradations(rng):
    idx = _mk(rng, "lucene")
    dr = DeviceRetriever(idx, regime="auto", gather="resident",
                         plan="host", **SMALL)
    qs = _queries(rng, 64)
    ids, vals = dr.retrieve_batch(qs, 7)
    assert dr.last_plan.degradations == []
    assert dr.batches_degraded == 0 and dr.fault_counters == {}
    _assert_exact(dr, ids, vals, 7)


def test_guarded_fault_does_not_fire_outside_ladder(rng):
    """A guarded (default) spec cannot break index construction — the
    chaos-mode safety property."""
    from repro.sparse.block_csr import put_posting_arrays
    with inject_faults({"site": "residency.put_posting_arrays",
                        "kind": "residency", "times": 5}) as sp:
        put_posting_arrays(np.zeros(4, np.int32))        # outside guard()
    assert sp[0].fired == 0
    with inject_faults({"site": "residency.put_posting_arrays",
                        "kind": "residency", "times": 1,
                        "guarded": False}) as sp:
        with pytest.raises(ResidencyError):
            put_posting_arrays(np.zeros(4, np.int32))
    assert sp[0].fired == 1


# -- the perm rung: doc-id reordering under the I/O fault lane ----------------
#
# A reordered snapshot's ``perm`` array is one more manifest primary, so
# the ``snapshot.array`` chaos pool corrupts it like any other array. Its
# ladder has an extra rung the others don't: the permutation is a pure
# function of the (client-order) postings, so with BOTH on-disk copies
# gone it is recomputed from signatures and verified against the manifest
# checksum; only a checksum mismatch (signature-scheme drift) falls to
# identity — which drops the permuted layouts and rebuilds them from the
# client CSC, trading the skip-rate win for exactness, never correctness.

def _reordered_snap(tmp_path, rng, method="lucene"):
    idx = _mk(rng, method)
    r = DeviceRetriever(idx, regime="pruned", reorder="signature",
                        **{k: v for k, v in SMALL.items()
                           if k != "acc_block"})
    assert r.dindex.perm is not None
    path = str(tmp_path / "snap")
    r.save(path)
    return idx, r, path


def _gen_file(path, name):
    import json as _json
    import os
    with open(os.path.join(path, "CURRENT")) as fh:
        gen = _json.load(fh)["generation"]
    return os.path.join(path, gen, name)


def _corrupt(fname, offset=8):
    with open(fname, "r+b") as fh:
        fh.seek(offset)
        b = fh.read(1)
        fh.seek(offset)
        fh.write(bytes([b[0] ^ 0xFF]))


def _assert_adopted_identical(r, path, want_hop):
    from repro.sparse.block_csr import DeviceIndex
    di = DeviceIndex.load(path)
    assert want_hop in di.snapshot_report["hops"]
    r2 = DeviceRetriever(None, regime="pruned", device_index=di,
                         **{k: v for k, v in SMALL.items()
                            if k != "acc_block"})
    rng_q = np.random.default_rng(5)
    qs = _queries(rng_q, 64) + [np.zeros(0, np.int32)]
    i0, v0 = r.retrieve_batch(qs, 7)
    i1, v1 = r2.retrieve_batch(qs, 7)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    return di


def test_perm_bitflip_recovers_via_dup(tmp_path, rng):
    idx, r, path = _reordered_snap(tmp_path, rng)
    _corrupt(_gen_file(path, "perm.bin"))
    di = _assert_adopted_identical(r, path, "perm<-dup")
    np.testing.assert_array_equal(di.perm, r.dindex.perm)


def test_perm_and_dup_recover_via_signature_recompute(tmp_path, rng):
    """Both perm replicas gone: the loader re-derives the permutation
    from the client-order postings and proves it against the manifest
    checksum — serving is identical, not merely equivalent."""
    idx, r, path = _reordered_snap(tmp_path, rng)
    _corrupt(_gen_file(path, "perm.bin"))
    _corrupt(_gen_file(path, "perm.dup.bin"))
    di = _assert_adopted_identical(r, path, "perm<-signatures")
    np.testing.assert_array_equal(di.perm, r.dindex.perm)
    assert di.reorder == "signature"


def test_perm_checksum_mismatch_falls_to_identity(tmp_path, rng,
                                                  monkeypatch):
    """Signature-scheme drift (recompute no longer matches the stored
    checksum) forfeits the reorder but NEVER correctness: the loader
    drops to identity order and rebuilds the permuted layouts from the
    client CSC."""
    import repro.sparse.reorder as reorder_mod
    idx, r, path = _reordered_snap(tmp_path, rng)
    _corrupt(_gen_file(path, "perm.bin"))
    _corrupt(_gen_file(path, "perm.dup.bin"))
    real = reorder_mod.signature_permutation

    def drifted(index, *, mode="signature"):
        p = real(index, mode=mode)
        if p is None:
            return None
        return p[::-1].copy()                       # a DIFFERENT valid perm

    monkeypatch.setattr(reorder_mod, "signature_permutation", drifted)
    from repro.sparse.block_csr import DeviceIndex
    di = DeviceIndex.load(path)
    assert "perm<-identity" in di.snapshot_report["hops"]
    assert di.perm is None
    r2 = DeviceRetriever(None, regime="pruned", device_index=di,
                         **{k: v for k, v in SMALL.items()
                            if k != "acc_block"})
    rng_q = np.random.default_rng(5)
    qs = _queries(rng_q, 64)
    ids, vals = r2.retrieve_batch(qs, 7)
    sc = ScipyBM25(idx)
    for i, q in enumerate(qs):
        ref = sc.score(q)
        _, ref_v = topk_numpy(ref[None], 7)
        np.testing.assert_allclose(vals[i], ref_v[0], atol=1e-4)
        np.testing.assert_allclose(ref[np.asarray(ids)[i]],
                                   np.asarray(vals)[i], atol=1e-4)


# -- the overload fault lane (PR 10): stalls, breakers, floods ----------------
#
# kernel.stall is exact BY CONSTRUCTION both ways: without a watchdog the
# injected sleep is pure latency (the hop still returns its exact board);
# with one, the stall becomes a typed ExecutionStalledError the ladder
# absorbs. frontend.former fires at the top of a former iteration —
# nothing in flight — so supervisor recovery is exact. queue.flood is a
# typed shed at the door (caller-visible), so it is unguarded-only, like
# torn_write.

def _settle(dr, qs, k, tries=6):
    """Drive the retriever until its jit caches are warm enough that a
    call completes without spurious watchdog stalls (a cold compile can
    outlast a serving-sized deadline; the abandoned worker still
    finishes and caches it)."""
    for _ in range(tries):
        dr.retrieve_batch(qs, k)
        if not dr.last_plan.degradations:
            return
        time.sleep(0.2)       # let abandoned workers finish their compiles
    raise AssertionError("retriever never settled under its watchdog")


@pytest.mark.parametrize("method", ALL_VARIANTS)
def test_watchdog_stall_recovers_exact(method, rng):
    """A stalled pruned-kernel launch trips the watchdog, surfaces as a
    typed ExecutionStalledError, and the ladder re-serves the batch on
    the unpruned resident rung — bit-identical to the no-fault answer."""
    idx = _mk(rng, method)
    # breakers off: a cold compile can spuriously stall a few times while
    # settling, and this test pins the watchdog/ladder story in isolation
    dr = DeviceRetriever(idx, regime="pruned", gather="resident",
                         plan="host", watchdog_s=0.12,
                         breaker_threshold=None, **SMALL)
    qs = _queries(rng, 64)
    _settle(dr, qs, 7)
    ids0, vals0 = dr.retrieve_batch(qs, 7)
    stalls0 = dr.health()["watchdog"]["stalls"]
    with inject_faults({"site": "kernel.stall", "kind": "stall",
                        "times": 1, "seed": 5}) as sp:
        ids, vals = dr.retrieve_batch(qs, 7)
    assert sp[0].fired == 1
    trail = dr.last_plan.degradations
    assert trail[0]["from"] == "pruned" and trail[0]["to"] == "resident"
    assert trail[0]["error"] == "ExecutionStalledError"
    assert dr.health()["watchdog"]["stalls"] == stalls0 + 1
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(vals0))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids0))
    _assert_exact(dr, ids, vals, 7)


def test_stall_without_watchdog_is_latency_only(rng):
    """No watchdog armed: the injected stall is pure latency — the hop
    still returns its exact board and nothing degrades."""
    idx = _mk(rng, "lucene")
    dr = DeviceRetriever(idx, regime="gathered", gather="host", **SMALL)
    qs = _queries(rng, 64)
    ids0, vals0 = dr.retrieve_batch(qs, 7)
    with inject_faults({"site": "kernel.stall", "kind": "stall",
                        "times": 1, "seed": 5}) as sp:
        t0 = time.monotonic()
        ids, vals = dr.retrieve_batch(qs, 7)
        dt = time.monotonic() - t0
    assert sp[0].fired == 1
    assert dt >= 0.15                     # the sleep really happened
    assert dr.last_plan.degradations == []
    assert dr.health()["watchdog"] == {}
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(vals0))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids0))


def test_stall_is_guard_scoped(rng):
    """A guarded stall spec cannot fire on a strict retriever (strict
    calls never enter the ladder guard) — chaos safety for
    on_fault="raise" deployments."""
    idx = _mk(rng, "lucene")
    dr = DeviceRetriever(idx, regime="gathered", gather="host",
                         on_fault="raise", **SMALL)
    qs = _queries(rng, 64)
    with inject_faults({"site": "kernel.stall", "kind": "stall",
                        "times": 1, "seed": 5}) as sp:
        dr.retrieve_batch(qs, 7)
    assert sp[0].fired == 0


@pytest.mark.parametrize("method", ALL_VARIANTS)
def test_breaker_opens_after_threshold_and_recloses(method, rng):
    """The per-rung breaker state machine end to end: K faults open it,
    the ladder then skips the rung WITHOUT execution (trail says
    BreakerOpen), the cooldown's half-open probe re-closes it — and
    every answer along the way is exact."""
    idx = _mk(rng, method)
    dr = DeviceRetriever(idx, regime="gathered", gather="host",
                         breaker_threshold=2, breaker_cooldown_s=0.3,
                         **SMALL)
    qs = _queries(rng, 64)
    # two faulted calls: host faults, ladder hops to oracle, breaker
    # accumulates
    for _ in range(2):
        with inject_faults({"site": "residency.put_posting_arrays",
                            "kind": "residency", "times": 1, "seed": 1}):
            ids, vals = dr.retrieve_batch(qs, 7)
        _assert_exact(dr, ids, vals, 7)
    h = dr.health()
    assert h["breakers"]["host"]["state"] == "open"
    assert h["breakers"]["host"]["opened"] == 1
    # breaker open: the host rung is skipped without execution (no fault
    # armed — it WOULD succeed, but the breaker remembers), still exact
    ids, vals = dr.retrieve_batch(qs, 7)
    trail = dr.last_plan.degradations
    assert trail[0]["from"] == "host" and trail[0]["error"] == "BreakerOpen"
    assert trail[0]["to"] == "oracle"
    assert dr.health()["breakers"]["host"]["skips"] >= 1
    _assert_exact(dr, ids, vals, 7)
    # cooldown elapses -> half-open -> the probe succeeds -> closed
    time.sleep(0.35)
    ids, vals = dr.retrieve_batch(qs, 7)
    assert dr.last_plan.degradations == []
    assert dr.health()["breakers"]["host"]["state"] == "closed"
    _assert_exact(dr, ids, vals, 7)


def test_breaker_probe_failure_reopens(rng):
    """A fault during the half-open probe re-opens the breaker for
    another cooldown instead of closing it."""
    idx = _mk(rng, "lucene")
    dr = DeviceRetriever(idx, regime="gathered", gather="host",
                         breaker_threshold=1, breaker_cooldown_s=0.2,
                         **SMALL)
    qs = _queries(rng, 64)
    with inject_faults({"site": "residency.put_posting_arrays",
                        "kind": "residency", "times": 1, "seed": 1}):
        dr.retrieve_batch(qs, 7)
    assert dr.health()["breakers"]["host"]["state"] == "open"
    time.sleep(0.25)                       # half-open: probe slot free
    with inject_faults({"site": "residency.put_posting_arrays",
                        "kind": "residency", "times": 1, "seed": 1}):
        ids, vals = dr.retrieve_batch(qs, 7)
    h = dr.health()["breakers"]["host"]
    assert h["state"] == "open" and h["opened"] == 2
    _assert_exact(dr, ids, vals, 7)


def test_trip_breaker_forced_open_serves_exact(rng):
    """Operator override: with the entry rung's breaker forced open,
    serving continues exactly on the remaining rungs and health says so."""
    idx = _mk(rng, "lucene")
    dr = DeviceRetriever(idx, regime="gathered", gather="host", **SMALL)
    qs = _queries(rng, 64)
    dr.trip_breaker("host", cooldown_s=60.0)
    ids, vals = dr.retrieve_batch(qs, 7)
    trail = dr.last_plan.degradations
    assert trail[0] == {"from": "host", "to": "oracle",
                        "error": "BreakerOpen", "detail": trail[0]["detail"]}
    h = dr.health()
    assert h["breakers"]["host"]["state"] == "open"
    assert h["degradations"] == {"host->oracle": 1}
    _assert_exact(dr, ids, vals, 7)
    with pytest.raises(RetrievalConfigError, match="unknown ladder rung"):
        dr.trip_breaker("nope")
    dr_off = DeviceRetriever(idx, regime="gathered", gather="host",
                             breaker_threshold=None, **SMALL)
    assert dr_off.health()["breakers"] == {}
    with pytest.raises(RetrievalConfigError, match="disabled"):
        dr_off.trip_breaker("host")


@pytest.mark.parametrize("method", ALL_VARIANTS)
def test_retry_budget_absorbs_transient_residency_fault(method, rng):
    """With a retry budget, a transient ResidencyError is retried on the
    SAME rung (seeded backoff) instead of burning a ladder hop."""
    idx = _mk(rng, method)
    dr = DeviceRetriever(idx, regime="gathered", gather="host",
                         retry_budget=2, retry_backoff_s=0.001, **SMALL)
    qs = _queries(rng, 64)
    with inject_faults({"site": "residency.put_posting_arrays",
                        "kind": "residency", "times": 1, "seed": 1}) as sp:
        ids, vals = dr.retrieve_batch(qs, 7)
    assert sp[0].fired == 1
    assert dr.last_plan.degradations == []          # no hop burned
    h = dr.health()
    assert h["retries"] == 1
    assert h["faults"]["ResidencyError"] == 1       # still counted typed
    _assert_exact(dr, ids, vals, 7)


def test_frontend_former_death_recovers(rng):
    """Injected former-thread death is absorbed by the stage supervisor:
    the stage restarts, queued requests ride the next iteration, and the
    answers stay bit-identical to direct retrieval."""
    from repro.serve import ServingFrontend
    idx = _mk(rng, "lucene")
    dr = DeviceRetriever(idx, regime="gathered", gather="host", **SMALL)
    qs = _queries(rng, 64, n=4)
    direct = dr.retrieve_batch(qs, 5)
    with inject_faults({"site": "frontend.former", "kind": "thread_death",
                        "times": 1, "seed": 1}) as sp:
        fe = ServingFrontend(dr, k=5, max_batch=4,
                             batch_deadline_s=0.005)
        futs = [fe.submit(q) for q in qs]
        rows = [f.result(timeout=10.0) for f in futs]
        fe.close()
    assert sp[0].fired == 1
    assert fe.health()["restarts"] == 1
    for i, row in enumerate(rows):
        np.testing.assert_array_equal(np.asarray(row.ids),
                                      np.asarray(direct.ids[i]))
        np.testing.assert_array_equal(np.asarray(row.scores),
                                      np.asarray(direct.scores[i]))


def test_queue_flood_guarded_vs_unguarded(rng):
    """submit() has no guard scope, so a guarded flood spec can never
    fire (chaos safety: the shed is caller-visible); an unguarded one
    inflates the depth the gate sees and the submission is REJECTED
    typed at the door — the real queue is untouched."""
    from repro.serve import QueueOverflowError, ServingFrontend
    idx = _mk(rng, "lucene")
    dr = DeviceRetriever(idx, regime="gathered", gather="host", **SMALL)
    fe = ServingFrontend(dr, k=5, max_batch=4, batch_deadline_s=0.005,
                         max_queue=64)
    q = np.array([1, 2], np.int32)
    with inject_faults({"site": "queue.flood", "kind": "flood",
                        "times": 1, "seed": 1}) as sp:
        fe.submit(q).result(timeout=10.0)
    assert sp[0].fired == 0                # guarded: submit untouched
    with inject_faults({"site": "queue.flood", "kind": "flood",
                        "times": 1, "seed": 1, "guarded": False}) as sp:
        with pytest.raises(QueueOverflowError, match="queue full"):
            fe.submit(q)
    assert sp[0].fired == 1
    h = fe.health()
    assert h["pending"] == 0               # the flood never queued anything
    fe.submit(q).result(timeout=10.0)      # ... and serving continues
    fe.close()


@pytest.mark.parametrize("kind", ["bit_flip", "truncate"])
def test_reordered_snapshot_array_fault_recovers_exact(kind, tmp_path, rng):
    """The io chaos pool's array faults hit reordered snapshots too
    (perm.bin is a manifest primary) — the ladder heals whatever array
    the injector picked and serving stays identical."""
    idx, r, path = _reordered_snap(tmp_path, rng)
    from repro.sparse.block_csr import DeviceIndex
    with inject_faults({"site": "snapshot.array", "kind": kind,
                        "times": 1, "seed": 11}) as sp:
        di = DeviceIndex.load(path)
    assert sp[0].fired == 1
    assert di.snapshot_report["hops"]
    r2 = DeviceRetriever(None, regime="pruned", device_index=di,
                         **{k: v for k, v in SMALL.items()
                            if k != "acc_block"})
    rng_q = np.random.default_rng(5)
    qs = _queries(rng_q, 64)
    i0, v0 = r.retrieve_batch(qs, 7)
    i1, v1 = r2.retrieve_batch(qs, 7)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
