"""Production serving launcher (the paper's workload).

    PYTHONPATH=src python -m repro.launch.serve --docs 20000 --shards 4 \\
        --queries 100 --k 10 [--variant bm25+] [--deadline-ms 200]

Builds the sharded eager index (distributed build: global-stats pass +
per-shard scoring), starts the hedged retrieval engine, serves a query
stream and prints QPS / tail latency / degradation stats. ``--straggle``
injects a slow shard to demonstrate deadline hedging.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20_000)
    ap.add_argument("--vocab", type=int, default=20_000)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--queries", type=int, default=100)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--variant", default="lucene")
    ap.add_argument("--k1", type=float, default=1.5)
    ap.add_argument("--b", type=float, default=0.75)
    ap.add_argument("--deadline-ms", type=float, default=500.0)
    ap.add_argument("--quorum", type=float, default=0.75)
    ap.add_argument("--straggle", action="store_true",
                    help="make shard 0 sleep 1s (hedging demo)")
    ap.add_argument("--rescale", type=int, default=None,
                    help="elastically re-shard to N after half the stream")
    args = ap.parse_args()

    import numpy as np

    from ..core import BM25Params, build_sharded_indexes
    from ..data.corpus import zipf_corpus, zipf_queries
    from ..serve import RetrievalEngine

    print(f"[serve] indexing {args.docs} docs "
          f"({args.variant}, k1={args.k1}, b={args.b}) "
          f"into {args.shards} shards...")
    t0 = time.time()
    corpus = zipf_corpus(args.docs, args.vocab, avg_len=80)
    params = BM25Params(method=args.variant, k1=args.k1, b=args.b)
    shards = build_sharded_indexes(corpus, args.vocab, args.shards,
                                   params=params)
    print(f"[serve] indexed in {time.time() - t0:.1f}s "
          f"({sum(s.nnz for s in shards) / 1e6:.2f}M postings)")

    delay = (lambda i: (lambda: 1.0) if i == 0 else None) \
        if args.straggle else None
    engine = RetrievalEngine(shards, k=args.k,
                             deadline_s=args.deadline_ms / 1e3,
                             quorum=args.quorum, delay=delay)

    queries = zipf_queries(args.queries, args.vocab, q_len=5)
    lat, degraded = [], 0
    t0 = time.time()
    for i, q in enumerate(queries):
        if args.rescale and i == len(queries) // 2:
            print(f"[serve] elastic re-shard -> {args.rescale}")
            engine.rescale(args.rescale)
        r = engine.retrieve(q)
        lat.append(r.latency_s)
        degraded += int(r.degraded)
    dt = time.time() - t0
    lat = np.asarray(lat)
    print(f"[serve] {len(queries)} queries  {len(queries) / dt:.1f} QPS  "
          f"p50 {1e3 * np.percentile(lat, 50):.1f}ms  "
          f"p99 {1e3 * np.percentile(lat, 99):.1f}ms  "
          f"degraded {degraded}/{len(queries)}")


if __name__ == "__main__":
    main()
