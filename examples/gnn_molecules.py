"""EGNN on batched molecule graphs: train the equivariant model and verify
that predictions are invariant to rotating the inputs.

    PYTHONPATH=src python examples/gnn_molecules.py
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.graphs import batched_molecules
from repro.models import egnn
from repro.train import AdamW, init_train_state, make_train_step

cfg = egnn.EGNNConfig(name="egnn-mol", n_layers=4, d_hidden=64, d_feat=11,
                      n_out=1, readout="graph")
params = egnn.init_params(jax.random.PRNGKey(0), cfg)

N_GRAPHS = 64
base = make_train_step(functools.partial(egnn.loss_fn, cfg), AdamW(lr=1e-3))
step = jax.jit(lambda p, s, b: base(p, s, dict(b, n_graphs=N_GRAPHS)))
opt = AdamW(lr=1e-3)
state = init_train_state(params, opt)

batch = batched_molecules(N_GRAPHS, n_nodes=30, n_edges=64)
batch.pop("n_graphs")
batch = {k: jnp.asarray(v) for k, v in batch.items()}

for i in range(100):
    params, state, m = step(params, state, batch)
    if i % 20 == 0 or i == 99:
        print(f"step {i:3d}  mse {float(m['loss']):.4f}")

# E(3) invariance of the trained model
theta = 0.9
rot = jnp.asarray([[np.cos(theta), -np.sin(theta), 0],
                   [np.sin(theta), np.cos(theta), 0],
                   [0, 0, 1]], jnp.float32)
b2 = dict(batch, n_graphs=N_GRAPHS)
pred1, _ = egnn.forward(cfg, params, b2)
b3 = dict(b2, coords=b2["coords"] @ rot.T + 5.0)
pred2, _ = egnn.forward(cfg, params, b3)
print("max |pred(x) - pred(Rx+t)| =",
      float(jnp.abs(pred1 - pred2).max()), "(E(3)-invariant)")
