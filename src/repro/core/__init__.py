"""BM25S core: eager sparse scoring (Lù, 2024) as a composable JAX module."""

from .index import BM25Index, CorpusStats, build_index, build_sharded_indexes, reshard_index
from .reference import RankBM25Baseline, ScipyBM25, dense_oracle_scores
from .retrieval import (RetrievalPlan, blockwise_topk, default_doc_ids,
                        merge_topk, merge_topk_batch, plan_retrieval,
                        sharded_retrieve_adaptive, topk_jax, topk_numpy,
                        validate_query_batch)
from .scoring import (DeviceIndex, batch_posting_budget, bucket_pow2,
                      pad_queries, score_batch, suggest_p_max)
from .tokenizer import Tokenizer, Vocabulary
from .variants import BM25Params, VARIANTS, get_variant

__all__ = [
    "BM25Index", "BM25Params", "BM25Retriever", "CorpusStats", "DeviceIndex",
    "RankBM25Baseline", "ScipyBM25", "Tokenizer", "VARIANTS", "Vocabulary",
    "RetrievalPlan", "batch_posting_budget", "blockwise_topk",
    "bucket_pow2", "build_index", "build_sharded_indexes",
    "default_doc_ids", "dense_oracle_scores", "get_variant", "merge_topk",
    "merge_topk_batch", "pad_queries", "plan_retrieval", "reshard_index",
    "score_batch", "sharded_retrieve_adaptive", "suggest_p_max", "topk_jax",
    "topk_numpy", "validate_query_batch",
]


class BM25Retriever:
    """End-to-end convenience API: texts in, ranked documents out.

    >>> r = BM25Retriever(method="lucene").index(corpus_texts)
    >>> ids, scores = r.retrieve(["sparse lexical search"], k=10)
    """

    def __init__(self, *, method: str = "lucene", k1: float = 1.5,
                 b: float = 0.75, delta: float = 0.5,
                 stopwords: str | None = "english",
                 stemmer: str | None = "snowball"):
        self.params = BM25Params(k1=k1, b=b, delta=delta, method=method)
        self.tokenizer = Tokenizer(stopwords=stopwords, stemmer=stemmer)
        self.bm25_index: BM25Index | None = None
        self._device_index: DeviceIndex | None = None

    def index(self, corpus: list[str]) -> "BM25Retriever":
        tokens = self.tokenizer.tokenize_corpus(corpus)
        self.bm25_index = build_index(
            tokens, self.tokenizer.vocab_size, params=self.params)
        self._device_index = DeviceIndex.from_host(self.bm25_index)
        return self

    def retrieve(self, queries: list[str], k: int = 10, *,
                 q_max: int = 32, p_max: int | None = None):
        assert self._device_index is not None, "call .index() first"
        self.query_counters: dict = getattr(self, "query_counters", {})
        q_tokens = validate_query_batch(
            self.tokenizer.tokenize_queries(queries),
            self.bm25_index.n_vocab, counters=self.query_counters)
        toks, wts = pad_queries(q_tokens, q_max)
        if p_max is None:
            p_max = suggest_p_max(self.bm25_index, q_max)
        scores, overflow = score_batch(self._device_index, toks, wts,
                                       p_max=p_max, return_overflow=True)
        import numpy as _np
        n_over = int(_np.asarray(overflow).sum())
        if n_over:
            import warnings

            # TruncationWarning subclasses RuntimeWarning: pre-taxonomy
            # filters keep matching, new callers can catch one base class
            from repro.serve.errors import TruncationWarning
            warnings.warn(
                f"{n_over}/{len(queries)} queries overflowed the posting "
                f"budget p_max={p_max}; their scores miss postings — "
                f"retry with a larger p_max", TruncationWarning,
                stacklevel=2)
        idx, vals = topk_jax(scores, min(k, self.bm25_index.doc_lens.size))
        return idx, vals
