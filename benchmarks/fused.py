"""BENCH_1 — the fused score→top-k pipeline and the vectorized index build.

Three sections, written to ``BENCH_1.json`` by ``benchmarks/run.py``:

* ``indexing``  — documents/second through ``build_index`` with the
  vectorized single-pass ``_corpus_coo`` vs the seed's per-document
  ``np.unique`` loop (re-implemented here as the baseline), on a ≥50k-doc
  Zipf corpus. The acceptance bar is ≥5x.
* ``retrieval`` — per-batch latency of the fused blocked pipeline
  (``bm25_retrieve_blocked``: per-block top-k out of the accumulator, tiny
  merge) vs the unfused two-pass path (dense ``bm25_score_blocked`` +
  global top-k) and the paper's host/scipy + device/gather paths. CPU
  numbers (kernels run in interpret mode) — relative, not TPU-projected.
* ``intermediate_bytes`` — peak HBM bytes of the score intermediate:
  dense ``[nb, block_size, B]·4`` vs fused ``[nb, k, B]·8`` (ids+values),
  the bandwidth argument for the fusion.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import BM25Params, build_index
from repro.core.index import CorpusStats
from repro.data.corpus import zipf_corpus, zipf_queries
from repro.core import pad_queries
from repro.sparse.block_csr import (block_postings_from_index,
                                    pack_query_batch,
                                    query_nonoccurrence_shift)


# -- seed baseline: the per-document loop the vectorized path replaced ------

def _corpus_coo_loop(doc_tokens):
    tok_c, doc_c, tf_c = [], [], []
    doc_lens = np.zeros(len(doc_tokens), dtype=np.int32)
    for d, toks in enumerate(doc_tokens):
        doc_lens[d] = toks.size
        if toks.size == 0:
            continue
        uniq, counts = np.unique(toks, return_counts=True)
        tok_c.append(uniq.astype(np.int64))
        doc_c.append(np.full(uniq.size, d, dtype=np.int64))
        tf_c.append(counts.astype(np.float64))
    return (np.concatenate(tok_c), np.concatenate(doc_c),
            np.concatenate(tf_c), doc_lens)


def _stats_loop(doc_tokens, n_vocab):
    df = np.zeros(n_vocab, dtype=np.int64)
    total = 0
    for toks in doc_tokens:
        total += int(toks.size)
        if toks.size:
            df[np.unique(toks)] += 1
    return df, total / max(len(doc_tokens), 1)


def bench_indexing(n_docs: int = 50_000, n_vocab: int = 30_000,
                   avg_len: int = 60) -> dict:
    from repro.core.index import _corpus_coo
    corpus = zipf_corpus(n_docs, n_vocab, avg_len=avg_len)

    # seed pipeline: a df/length loop (CorpusStats) + a per-doc COO loop
    t0 = time.perf_counter()
    _stats_loop(corpus, n_vocab)
    _corpus_coo_loop(corpus)
    t_loop = time.perf_counter() - t0

    # vectorized pipeline: ONE flattened np.unique pass feeds both
    t0 = time.perf_counter()
    tok, _doc, _tf, doc_lens = _corpus_coo(corpus, n_vocab)
    CorpusStats.from_coo(tok, doc_lens, n_docs, n_vocab)
    t_vec = time.perf_counter() - t0

    # and the full eager build end-to-end (vectorized path only)
    t0 = time.perf_counter()
    build_index(corpus, n_vocab, params=BM25Params())
    t_build = time.perf_counter() - t0

    return {
        "n_docs": n_docs, "n_vocab": n_vocab, "avg_len": avg_len,
        "coo_loop_s": round(t_loop, 4),
        "coo_vectorized_s": round(t_vec, 4),
        "coo_speedup": round(t_loop / t_vec, 2),
        "docs_per_s_loop": round(n_docs / t_loop, 1),
        "docs_per_s_vectorized": round(n_docs / t_vec, 1),
        "full_build_s": round(t_build, 4),
        "full_build_docs_per_s": round(n_docs / t_build, 1),
    }


def bench_retrieval(n_docs: int = 2048, n_vocab: int = 2000,
                    batch: int = 8, k: int = 10, block_size: int = 256,
                    repeats: int = 3) -> dict:
    import jax.numpy as jnp

    from repro.core import (DeviceIndex, ScipyBM25, score_batch,
                            suggest_p_max, topk_jax)
    from repro.kernels import ops

    corpus = zipf_corpus(n_docs, n_vocab, avg_len=60)
    idx = build_index(corpus, n_vocab, params=BM25Params())
    bp = block_postings_from_index(idx, block_size=block_size,
                                   tile=block_size)
    queries = zipf_queries(batch, n_vocab, q_len=5)
    toks, wts = pad_queries(queries, 8)
    uniq, weights = pack_query_batch(toks, wts, u_max=256)
    shift = query_nonoccurrence_shift(idx.nonoccurrence, toks, wts)
    args = (jnp.asarray(bp.token_ids), jnp.asarray(bp.local_doc),
            jnp.asarray(bp.scores), jnp.asarray(uniq),
            jnp.asarray(weights), jnp.asarray(shift))
    kw = dict(block_size=bp.block_size, n_docs=n_docs,
              tile_p=min(block_size, bp.nnz_pad))

    def timed(fn):
        fn()                                     # compile/warmup
        t0 = time.perf_counter()
        for _ in range(repeats):
            fn()
        return (time.perf_counter() - t0) / repeats

    t_fused = timed(lambda: ops.bm25_retrieve_blocked(*args, k=k, **kw)[
        0].block_until_ready())
    t_unfused = timed(lambda: ops.topk(
        ops.bm25_score_blocked(*args, **kw), k)[0].block_until_ready())

    di = DeviceIndex.from_host(idx)
    jt, jw = jnp.asarray(toks), jnp.asarray(wts)
    p_max = suggest_p_max(idx, 8)
    t_gather = timed(lambda: topk_jax(
        score_batch(di, jt, jw, p_max=p_max), k)[0].block_until_ready())

    sc = ScipyBM25(idx)
    t_scipy = timed(lambda: [sc.retrieve(q, k) for q in queries])

    nb = bp.n_blocks
    dense_bytes = nb * bp.block_size * batch * 4
    fused_bytes = nb * k * batch * (4 + 4)
    return {
        "n_docs": n_docs, "n_vocab": n_vocab, "batch": batch, "k": k,
        "block_size": bp.block_size, "n_blocks": nb,
        "fused_batch_s": round(t_fused, 4),
        "unfused_dense_batch_s": round(t_unfused, 4),
        "gather_segment_sum_batch_s": round(t_gather, 4),
        "scipy_batch_s": round(t_scipy, 4),
        "dense_intermediate_bytes": dense_bytes,
        "fused_intermediate_bytes": fused_bytes,
        "intermediate_bytes_ratio": round(dense_bytes / fused_bytes, 1),
        "note": "CPU wall times; Pallas kernels run in interpret mode — "
                "compare paths relatively, bytes are the TPU argument",
    }


def run(*, fast: bool = False) -> dict:
    return {
        # the acceptance corpus stays >= 50k docs even in --fast
        "indexing": bench_indexing(n_docs=50_000,
                                   n_vocab=10_000 if fast else 30_000),
        "retrieval": bench_retrieval(n_docs=1024 if fast else 2048,
                                     n_vocab=1000 if fast else 2000),
    }
