"""Overload-protection primitives for the serving path.

BM25S's eager-scoring speed only matters if the serving path stays up
when traffic exceeds capacity or a regime starts failing repeatedly.
This module holds the four mechanisms the front-end and retriever thread
through their hot paths — all of them trade latency and availability,
NEVER scores (every ladder rung stays exact):

* :class:`AdmissionController` — a token-bucket rate gate plus a
  CoDel-style controller on measured queue delay. The bucket sheds load
  above a configured sustainable rate; the CoDel half watches the
  *standing* queue delay (the windowed minimum of ``queue_s``, the same
  number ``health()`` reports per request) and, when it stays above
  ``codel_target_s`` for a full ``codel_interval_s``, starts shedding at
  the classic ``interval / sqrt(drop_count)`` cadence until the standing
  delay drops back under target. Sheds surface as
  :class:`~repro.serve.errors.AdmissionRejectedError` carrying
  ``retry_after_s`` — typed backpressure at the door, so sustained
  overload converges to bounded p99 instead of an ever-growing queue.
  Deterministic: no RNG — the shed decision is a pure function of the
  observed clock/queue-delay sequence.
* :class:`CircuitBreaker` — the per-rung memory the degradation ladder
  lacked: ``threshold`` typed faults on a rung within ``window_s`` open
  the breaker, the ladder skips the rung for ``cooldown_s`` (no
  fault-then-hop tax per batch), then ONE half-open probe batch is let
  through — success closes the breaker, another fault re-opens it.
* :class:`WatchdogExecutor` — runs device execution on a supervised
  single worker thread under a deadline. A deadline miss abandons the
  (presumed hung) worker, replaces the thread so the next rung has a
  live stage, and raises
  :class:`~repro.serve.errors.ExecutionStalledError` — typed, so the
  existing exact ladder absorbs a stall like any other rung fault.
* :class:`RetryPolicy` — seeded exponential backoff with a bounded
  budget for transient faults (the retriever retries a rung on
  :class:`~repro.serve.errors.ResidencyError` before hopping). The
  jitter sequence is a pure function of ``seed`` — replayable, like
  every other piece of the fault story.

Knobs live on the ``ServingFrontend`` / ``DeviceRetriever``
constructors; every shed / open / trip / restart event is a schema-2
``health()`` counter (see the ``repro.serve`` package docstring).
"""

from __future__ import annotations

import math
import threading
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutTimeout

import numpy as np

from .errors import ExecutionStalledError


class AdmissionController:
    """Token-bucket + CoDel-style admission gate (see module docstring).

    Not internally locked: the front-end calls :meth:`admit` /
    :meth:`observe` under its own condition lock, which also orders the
    controller's state transitions with the queue counters they gate.

    Parameters
    ----------
    rate_qps:
        Sustainable admission rate for the token bucket (None disables
        the bucket — CoDel alone then gates).
    burst:
        Bucket capacity: how many back-to-back arrivals are admitted
        from a full bucket before the rate limit bites (default
        ``max(2 * rate_qps // 10, 8)`` — a ~200ms burst allowance).
    codel_target_s:
        Standing queue-delay target (None disables the CoDel half).
        When the windowed minimum of observed ``queue_s`` stays above
        this for ``codel_interval_s``, the controller sheds.
    codel_interval_s:
        CoDel control interval: the patience window before shedding
        starts, and the base of the ``interval / sqrt(n)`` shed cadence.
    """

    def __init__(self, *, rate_qps: float | None = None,
                 burst: int | None = None,
                 codel_target_s: float | None = None,
                 codel_interval_s: float = 0.1):
        if rate_qps is not None and rate_qps <= 0:
            raise ValueError("rate_qps must be positive (or None)")
        if codel_target_s is not None and codel_target_s <= 0:
            raise ValueError("codel_target_s must be positive (or None)")
        self.rate_qps = rate_qps
        self.burst = int(burst if burst is not None
                         else max((rate_qps or 0) // 5, 8))
        self.codel_target_s = codel_target_s
        self.codel_interval_s = float(codel_interval_s)
        self._tokens = float(self.burst)
        self._t_refill: float | None = None
        # CoDel state: when did queue_s first sit above target, are we
        # shedding, when is the next shed due, how many sheds this episode
        self._first_above: float | None = None
        self._min_delay: float | None = None
        self._dropping = False
        self._drop_next = 0.0
        self._drop_count = 0
        # counters (reported through the owner's health())
        self.shed_bucket = 0
        self.shed_codel = 0
        self.admitted = 0

    # -- CoDel input -----------------------------------------------------

    def observe(self, queue_s: float, now: float) -> None:
        """Feed one measured queue delay (called as each batch forms)."""
        if self.codel_target_s is None:
            return
        if queue_s < self.codel_target_s:
            # standing delay back under target: leave the episode
            self._first_above = None
            self._dropping = False
            self._drop_count = 0
        elif self._first_above is None:
            self._first_above = now

    # -- the gate --------------------------------------------------------

    def admit(self, now: float, pending: int) -> float | None:
        """None = admitted; otherwise the ``retry_after_s`` of the shed."""
        if self.rate_qps is not None:
            if self._t_refill is None:
                self._t_refill = now
            self._tokens = min(
                float(self.burst),
                self._tokens + (now - self._t_refill) * self.rate_qps)
            self._t_refill = now
            if self._tokens < 1.0:
                self.shed_bucket += 1
                return (1.0 - self._tokens) / self.rate_qps
        if self.codel_target_s is not None:
            if (not self._dropping and self._first_above is not None
                    and now - self._first_above >= self.codel_interval_s):
                # delay stood above target a whole interval: start shedding
                self._dropping = True
                self._drop_count = 0
            if self._dropping:
                if self._drop_count == 0 or now >= self._drop_next:
                    self._drop_count += 1
                    gap = (self.codel_interval_s
                           / math.sqrt(self._drop_count))
                    self._drop_next = now + gap
                    self.shed_codel += 1
                    return gap
        if self.rate_qps is not None:
            self._tokens -= 1.0
        self.admitted += 1
        return None

    def snapshot(self) -> dict:
        """Health-report view of the gate's state and counters."""
        out = {"admitted": self.admitted, "shed_bucket": self.shed_bucket,
               "shed_codel": self.shed_codel}
        if self.rate_qps is not None:
            out.update(rate_qps=self.rate_qps, burst=self.burst,
                       tokens=round(self._tokens, 3))
        if self.codel_target_s is not None:
            out.update(codel_target_s=self.codel_target_s,
                       codel_interval_s=self.codel_interval_s,
                       codel_dropping=self._dropping)
        return out


class CircuitBreaker:
    """Per-rung breaker: closed → open → half-open → closed (or re-open).

    ``threshold`` faults within ``window_s`` open the breaker;
    :meth:`allow` then refuses the rung until ``cooldown_s`` elapses, at
    which point exactly ONE probe is allowed (half-open). A recorded
    success closes the breaker; a recorded fault re-opens it for another
    cooldown. Not internally locked — the retriever serializes calls
    under its health lock.
    """

    def __init__(self, *, threshold: int = 3, window_s: float = 30.0,
                 cooldown_s: float = 5.0):
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.threshold = int(threshold)
        self.window_s = float(window_s)
        self.cooldown_s = float(cooldown_s)
        self._faults: list[float] = []       # timestamps inside the window
        self._open_until: float | None = None
        self._probing = False
        self.opened = 0                      # open transitions (health)
        self.skips = 0                       # batches the open breaker shed

    def state(self, now: float) -> str:
        if self._open_until is None:
            return "closed"
        return "open" if now < self._open_until else "half-open"

    def allow(self, now: float) -> bool:
        """May the ladder run this rung now? (May claim the probe slot.)"""
        st = self.state(now)
        if st == "closed":
            return True
        if st == "open" or self._probing:
            self.skips += 1
            return False
        self._probing = True                 # the one half-open probe
        return True

    def record_success(self, now: float) -> None:
        if self._open_until is not None and self._probing:
            # probe succeeded: close
            self._open_until = None
            self._probing = False
            self._faults.clear()

    def record_fault(self, now: float) -> None:
        if self._open_until is not None:
            if self._probing:
                # probe failed: re-open for another cooldown
                self._probing = False
                self._open_until = now + self.cooldown_s
                self.opened += 1
            return
        self._faults.append(now)
        self._faults = [t for t in self._faults if now - t <= self.window_s]
        if len(self._faults) >= self.threshold:
            self._open_until = now + self.cooldown_s
            self._probing = False
            self._faults.clear()
            self.opened += 1

    def force_open(self, now: float, *, cooldown_s: float | None = None
                   ) -> None:
        """Operator override: open the breaker without waiting for faults."""
        self._open_until = now + (cooldown_s if cooldown_s is not None
                                  else self.cooldown_s)
        self._probing = False
        self.opened += 1

    def snapshot(self, now: float) -> dict:
        return {"state": self.state(now), "opened": self.opened,
                "skips": self.skips,
                "faults_in_window": len(self._faults)}


class WatchdogExecutor:
    """Deadline-guarded execution on a supervised single worker thread.

    ``run(fn, *args)`` executes on the worker and waits ``timeout_s``; a
    miss abandons the stalled worker (its eventual result is discarded),
    REPLACES the thread so the next call has a live stage, and raises
    :class:`ExecutionStalledError`. The worker's death-by-exception is
    already safe — the future carries the exception — so the supervisor
    half here is the replacement-on-stall; stage supervision for the
    front-end's former thread lives in ``frontend.py``.
    """

    def __init__(self, timeout_s: float, *, name: str = "watchdog"):
        if timeout_s <= 0:
            raise ValueError("watchdog timeout_s must be positive")
        self.timeout_s = float(timeout_s)
        self.name = name
        self.stalls = 0
        self._lock = threading.Lock()
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix=name)

    def run(self, fn, *args, ctx=None, timeout_s: float | None = None):
        """Run ``fn(*args)`` under the deadline; ``ctx`` (a context-manager
        factory, e.g. ``faults.guard``) is entered ON the worker thread so
        thread-local guard scopes survive the thread hop."""
        def _call():
            if ctx is None:
                return fn(*args)
            with ctx():
                return fn(*args)

        budget = self.timeout_s if timeout_s is None else float(timeout_s)
        with self._lock:
            fut = self._pool.submit(_call)
        try:
            return fut.result(timeout=budget)
        except _FutTimeout:
            with self._lock:
                self.stalls += 1
                # abandon the stalled worker; a fresh thread takes the stage
                self._pool.shutdown(wait=False)
                self._pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix=self.name)
            raise ExecutionStalledError(
                f"device execution stalled past the {budget * 1e3:.0f} ms "
                f"watchdog deadline ({self.name}); the launch was "
                f"abandoned and its worker thread replaced",
                waited_s=budget) from None

    def close(self) -> None:
        with self._lock:
            self._pool.shutdown(wait=False)


class RetryPolicy:
    """Seeded exponential backoff with a bounded budget.

    ``delays()`` yields ``budget`` sleep durations:
    ``base_s * factor**i * (1 + jitter * u_i)`` with ``u_i`` drawn from
    ``default_rng(seed)`` — the whole sequence is a pure function of the
    constructor arguments, so a retried fault replays byte-for-byte.
    """

    def __init__(self, *, budget: int = 0, base_s: float = 0.005,
                 factor: float = 2.0, jitter: float = 0.5, seed: int = 0):
        if budget < 0:
            raise ValueError("retry budget must be >= 0")
        self.budget = int(budget)
        self.base_s = float(base_s)
        self.factor = float(factor)
        self.jitter = float(jitter)
        self.seed = int(seed)

    def delays(self) -> list[float]:
        rng = np.random.default_rng(self.seed)
        return [self.base_s * self.factor ** i
                * (1.0 + self.jitter * float(rng.random()))
                for i in range(self.budget)]


__all__ = ["AdmissionController", "CircuitBreaker", "WatchdogExecutor",
           "RetryPolicy"]
