"""Batched retrieval serving with shard hedging, deadlines and elasticity.

The paper's §2 "Multi-threading" uses pooled executors for retrieval
speedup; at pod scale the same executor pattern becomes the scatter-gather
layer over document shards, and the operational concerns become:

* stragglers — the global merge proceeds once a QUORUM of shard top-k lists
  has arrived by the deadline; late shards are dropped from that response
  (recorded as ``degraded``) instead of stalling the tail latency. Because
  per-shard top-k is a superset property, a missed shard can only remove
  candidates it owns — results from responsive shards stay exact.
* elasticity — ``rescale(n_shards)`` re-buckets the postings (pure host
  re-slicing, ``core.index.reshard_index``) when the pool grows/shrinks.

* device offload — each ``ShardRuntime`` scores either host-side
  (``scorer="scipy"``, the paper's CSC slice+sum) or on device through one
  of the two fused Pallas regimes: ``scorer="blocked"``
  (:class:`BlockedRetriever`, full-scan — streams every posting tile, wins
  when Σ df approaches nnz) or ``scorer="gathered"``
  (:class:`GatheredRetriever`, query-driven — gathers only the query
  tokens' posting runs, O(Σ df) work independent of corpus size, wins
  everywhere else). Both re-block/gather without ever materializing the
  dense score vector.

* batching — ``retrieve_batch`` runs B queries through ONE kernel launch
  per shard (the batch dimension is free on the MXU), amortizing launch
  and membership-table cost across the batch; per-query ``retrieve``
  stays for latency-sensitive single queries.

``ShardRuntime`` is process-local here (threads simulate shard servers; a
``delay`` hook lets tests inject stragglers), but the engine logic —
quorum, deadline, merge, re-shard — is exactly the production control
plane.
"""

from __future__ import annotations

import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from ..core.index import BM25Index, reshard_index
from ..core.reference import ScipyBM25
from ..core.retrieval import merge_topk


def _empty_batch(n_queries: int):
    ids = np.zeros((n_queries, 0), dtype=np.int64)
    scores = np.zeros((n_queries, 0), dtype=np.float32)
    return ids, scores


class _DeviceRetrieverBase:
    """Shared host half of the device scorers (query packing + warmup).

    Subclasses set ``index``, ``n_docs``, ``q_max`` in ``__init__`` and
    implement ``retrieve_batch``; the packing helper and the single-query /
    warmup conveniences live here so the bucketing and no-truncation
    invariants have exactly ONE implementation.
    """

    def _pack_batch(self, query_tokens):
        """Batch -> padded query tables, every device dim pow2-bucketed.

        Three shape dimensions are bucketed so jit recompiles stay
        O(log demand) each, none silently truncating:

        * batch ``B`` — padded with empty queries (a ragged client batch
          must not trigger a fresh multi-second compile per distinct size);
        * per-query width — bucketed from the longest query (width ≥ query
          length ≥ its unique count, so ``pad_queries`` never truncates,
          unlike a fixed q_max that would quietly keep only the
          highest-count tokens of a long query);
        * unique-token table ``u_max`` — bucketed from the batch's actual
          distinct-token count.

        The token stream is sorted ONCE (``pad_queries``'s lexsort); the
        batch-unique table comes from its run set (``return_uniq``) and is
        reused for the pack table and the posting-run gather.

        Returns ``(b_true, uniq_batch, uniq_tab [u], weights [u, B],
        shift [B])`` — callers slice device outputs back to ``b_true``.
        """
        from ..core.scoring import bucket_pow2, pad_queries
        from ..sparse.block_csr import (pack_query_batch,
                                        query_nonoccurrence_shift)
        qs = [np.asarray(q).ravel() for q in query_tokens]
        b_true = len(qs)
        b_pad = bucket_pow2(max(b_true, 1), floor=8)
        qs += [np.zeros(0, np.int32)] * (b_pad - b_true)
        width = bucket_pow2(max((q.size for q in qs), default=1) or 1,
                            floor=self.q_max)
        toks, wts, uniq_batch = pad_queries(qs, width, return_uniq=True)
        u_max = bucket_pow2(max(uniq_batch.size, 1), floor=self.q_max)
        uniq_tab, weights = pack_query_batch(toks, wts, u_max=u_max,
                                             uniq=uniq_batch)
        shift = query_nonoccurrence_shift(self.index.nonoccurrence, toks,
                                          wts)
        return b_true, uniq_batch, uniq_tab, weights, shift

    def warmup(self, *, k: int) -> None:
        """Compile the floor-bucket retrieve path at engine build.

        The compiled-fn cache per (bucket..., k) is jax.jit's own
        static-arg/shape cache — the power-of-two bucketing in
        ``_pack_batch`` is what keys it to O(log demand) entries; this call
        pre-populates the floor buckets (B ≤ 8, width/u_max ≤ q_max floor)
        so typical first live queries never pay tracing+compilation; bigger
        batches pay one compile per pow2 bucket, then never again.
        """
        if self.n_docs == 0 or k <= 0:
            return
        q = np.zeros(1, dtype=np.int32)
        self.retrieve_batch([q], min(k, self.n_docs))

    def retrieve(self, query_tokens: np.ndarray, k: int
                 ) -> tuple[np.ndarray, np.ndarray]:
        ids, vals = self.retrieve_batch([np.asarray(query_tokens)], k)
        return ids[0], vals[0]


class BlockedRetriever(_DeviceRetrieverBase):
    """Full-scan fused-kernel scorer (drop-in for :class:`ScipyBM25`).

    Blocks the shard's postings once (``sparse.block_csr``) and serves
    ``retrieve``/``retrieve_batch`` via ``kernels.ops.bm25_retrieve_blocked``:
    the dense per-document score vector never exists anywhere — scores
    stream from the posting tiles into a VMEM accumulator and leave as
    ``[k]`` winners. Work is O(nnz) per batch regardless of the query —
    prefer :class:`GatheredRetriever` unless batches are dense enough that
    Σ df ≈ nnz (see the module docstring's regime notes).
    """

    def __init__(self, index: BM25Index, *, block_size: int = 512,
                 tile: int = 512, q_max: int = 32):
        import jax.numpy as jnp

        from ..sparse.block_csr import block_postings_from_index
        self.index = index
        self.q_max = q_max                       # bucket floor, not a cap
        self.n_docs = int(index.doc_lens.size)
        bp = block_postings_from_index(index, block_size=block_size,
                                       tile=tile)
        self.block_size = bp.block_size
        self.tile_p = min(tile, bp.nnz_pad)
        self._tok = jnp.asarray(bp.token_ids)
        self._loc = jnp.asarray(bp.local_doc)
        self._sc = jnp.asarray(bp.scores)

    def retrieve_batch(self, query_tokens: Sequence[np.ndarray], k: int
                       ) -> tuple[np.ndarray, np.ndarray]:
        """B queries -> (ids [B, k], scores [B, k]) in ONE kernel launch."""
        import jax.numpy as jnp

        from ..kernels import ops
        if self.n_docs == 0 or k <= 0:           # empty shard post-rescale
            return _empty_batch(len(query_tokens))
        b, _, uniq, weights, shift = self._pack_batch(query_tokens)
        ids, vals = ops.bm25_retrieve_blocked(
            self._tok, self._loc, self._sc, jnp.asarray(uniq),
            jnp.asarray(weights), jnp.asarray(shift),
            block_size=self.block_size, n_docs=self.n_docs,
            k=min(k, self.n_docs), tile_p=self.tile_p)
        return (np.asarray(ids[:b]).astype(np.int64) + self.index.doc_offset,
                np.asarray(vals[:b]))


class GatheredRetriever(_DeviceRetrieverBase):
    """Query-driven gather→score→top-k scorer — the O(Σ df) device regime.

    The inverted-index asymptotics of the paper, restored on device: from
    the CSC ``indptr`` compute the batch's posting-run descriptors, gather
    ONLY those runs into candidate-compacted tiles
    (``sparse.block_csr.gather_posting_runs``) and push them through
    ``kernels.ops.bm25_retrieve_gathered`` — work O(Σ df(q)·B), independent
    of corpus size and nnz, vs the full-scan :class:`BlockedRetriever`'s
    O(nnz·B).

    Budgets are **adaptive**: posting tiles and the candidate chunk count
    are sized from the batch's ACTUAL Σ df / candidate count, rounded up to
    power-of-two buckets (``core.scoring.bucket_pow2``) so recompiles stay
    O(log max-demand). Because shapes are sized from actuals, the host path
    cannot overflow — there is nothing to truncate silently; a demand
    spike just lands in a larger bucket (one extra compile, exact scores).

    ``acc_block`` (the per-chunk accumulator height) stays SMALL and fixed:
    the kernel's one-hot scatter costs ``acc_block`` MACs per posting, so
    large candidate sets are handled by MORE chunks, keeping total work
    linear in Σ df (see ``sparse.block_csr.GatheredPostings``).
    """

    def __init__(self, index: BM25Index, *, tile: int = 512,
                 acc_block: int = 512, q_max: int = 32):
        self.index = index
        self.tile = tile
        self.q_max = q_max                       # unique-table bucket floor
        self.acc_block = acc_block               # candidate chunk height
        self.n_docs = int(index.doc_lens.size)

    def retrieve_batch(self, query_tokens: Sequence[np.ndarray], k: int
                       ) -> tuple[np.ndarray, np.ndarray]:
        """B queries -> (ids [B, k], scores [B, k]), one gathered launch."""
        import jax.numpy as jnp

        from ..core.scoring import bucket_pow2
        from ..kernels import ops
        from ..sparse.block_csr import gather_posting_runs
        if self.n_docs == 0 or k <= 0:           # empty shard post-rescale
            return _empty_batch(len(query_tokens))
        b, uniq_batch, uniq_tab, weights, shift = \
            self._pack_batch(query_tokens)
        kk = min(k, self.n_docs)
        # chunk height grows only if k outruns it (kernel needs k ≤
        # acc_block); posting/chunk dims bucket inside the gather
        acc_block = bucket_pow2(kk, floor=self.acc_block)
        gp = gather_posting_runs(self.index, uniq_batch,
                                 acc_block=acc_block, tile=self.tile)
        ids, vals = ops.bm25_retrieve_gathered(
            jnp.asarray(gp.token_ids), jnp.asarray(gp.slot_ids),
            jnp.asarray(gp.scores), jnp.asarray(uniq_tab),
            jnp.asarray(weights), jnp.asarray(gp.candidates),
            jnp.asarray(shift), acc_block=gp.acc_block, k=kk,
            n_docs=self.n_docs, tile_p=min(self.tile, gp.p_pad))
        return (np.asarray(ids[:b]).astype(np.int64) + self.index.doc_offset,
                np.asarray(vals[:b]))


_SCORERS = {"scipy": ScipyBM25, "blocked": BlockedRetriever,
            "gathered": GatheredRetriever}


@dataclass
class ShardRuntime:
    """One shard's scorer (thread-simulated shard server)."""

    index: BM25Index
    delay: Callable[[], float] | None = None     # test hook: seconds to sleep
    scorer: str = "scipy"                        # "scipy"|"blocked"|"gathered"

    def __post_init__(self):
        if self.scorer not in _SCORERS:
            raise ValueError(f"unknown scorer {self.scorer!r}; "
                             f"available: {sorted(_SCORERS)}")
        self._scorer = _SCORERS[self.scorer](self.index)

    def warmup(self, k: int) -> None:
        """Pre-compile the device scorer so query #1 skips compilation."""
        fn = getattr(self._scorer, "warmup", None)
        if fn is not None:
            fn(k=k)

    def topk(self, query_tokens: np.ndarray, k: int
             ) -> tuple[np.ndarray, np.ndarray]:
        if self.delay is not None:
            time.sleep(self.delay())
        return self._scorer.retrieve(query_tokens, k)

    def topk_batch(self, query_batch: Sequence[np.ndarray], k: int
                   ) -> tuple[np.ndarray, np.ndarray]:
        """[B queries] -> (ids [B, k'], scores [B, k']) for this shard."""
        if self.delay is not None:
            time.sleep(self.delay())
        fn = getattr(self._scorer, "retrieve_batch", None)
        if fn is not None:                       # one kernel launch for B
            return fn(query_batch, k)
        parts = [self._scorer.retrieve(q, k) for q in query_batch]
        kk = min((p[0].size for p in parts), default=0)
        ids = np.stack([p[0][:kk] for p in parts]) if parts else \
            np.zeros((0, 0), np.int64)
        sc = np.stack([p[1][:kk] for p in parts]) if parts else \
            np.zeros((0, 0), np.float32)
        return ids.astype(np.int64), sc.astype(np.float32)


@dataclass
class RetrievalResult:
    ids: np.ndarray
    scores: np.ndarray
    degraded: bool
    shards_answered: int
    latency_s: float


class RetrievalEngine:
    def __init__(self, shards: Sequence[BM25Index], *, k: int = 10,
                 deadline_s: float = 0.5, quorum: float = 0.75,
                 max_workers: int = 8,
                 delay: Callable[[int], Callable[[], float] | None] = None,
                 scorer: str = "scipy", warmup: bool = True):
        self.k = k
        self.deadline_s = deadline_s
        self.quorum = quorum
        self.scorer = scorer
        self.warmup = warmup
        self._delay_factory = delay
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._build_runtimes(list(shards))

    def _build_runtimes(self, shards: list[BM25Index]) -> None:
        self.shards = shards
        self.runtimes = [
            ShardRuntime(s, delay=self._delay_factory(i)
                         if self._delay_factory else None,
                         scorer=self.scorer)
            for i, s in enumerate(shards)
        ]
        if self.warmup:
            # compile the device scorers at BUILD time (and after every
            # rescale) so the first live query never pays jit compilation —
            # on the floor buckets, which absorb typical traffic.
            for rt in self.runtimes:
                rt.warmup(self.k)

    # -- control plane ------------------------------------------------------
    def rescale(self, n_shards: int) -> None:
        """Elastic re-shard (device pool grew or shrank)."""
        self._build_runtimes(reshard_index(self.shards, n_shards))

    # -- data plane ----------------------------------------------------------
    def _scatter_gather(self, submit, merge, k: int):
        """Shared hedged scatter-gather: quorum + deadline + merge."""
        t0 = time.time()
        futures = {submit(rt): i for i, rt in enumerate(self.runtimes)}
        need = max(1, int(np.ceil(self.quorum * len(self.runtimes))))
        done: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        pending = set(futures)
        deadline = t0 + self.deadline_s
        while pending:
            timeout = deadline - time.time()
            if timeout <= 0 and len(done) >= need:
                break                     # quorum met, deadline passed
            finished, pending = wait(
                pending, timeout=max(timeout, 0.005),
                return_when=FIRST_COMPLETED)
            for f in finished:
                done[futures[f]] = f.result()
            if not finished and len(done) >= need:
                break
        for f in pending:                 # backfill continues off-path
            f.cancel()
        ids, scores = merge(done.values(), k)
        return RetrievalResult(
            ids=ids, scores=scores,
            degraded=len(done) < len(self.runtimes),
            shards_answered=len(done), latency_s=time.time() - t0)

    def retrieve(self, query_tokens: np.ndarray, *, k: int | None = None
                 ) -> RetrievalResult:
        k = k or self.k
        return self._scatter_gather(
            lambda rt: self._pool.submit(rt.topk, query_tokens, k),
            self._merge, k)

    def retrieve_batch(self, query_batch: Sequence[np.ndarray], *,
                       k: int | None = None) -> RetrievalResult:
        """B queries in one hedged scatter-gather round.

        Each shard serves the whole batch in ONE device launch
        (``ShardRuntime.topk_batch``), so kernel-launch and query-table
        costs amortize over B; the merge is the batched stage-2
        (``core.retrieval.merge_topk_batch``). Returns a single
        :class:`RetrievalResult` with ``ids``/``scores`` of shape [B, k].
        """
        k = k or self.k
        query_batch = [np.asarray(q) for q in query_batch]
        return self._scatter_gather(
            lambda rt: self._pool.submit(rt.topk_batch, query_batch, k),
            self._merge_batch, k)

    @staticmethod
    def _merge(parts, k: int) -> tuple[np.ndarray, np.ndarray]:
        # stage-2 of the paper's two-stage top-k, vectorized in
        # core.retrieval.merge_topk (concatenate + argpartition).
        return merge_topk(parts, k)

    @staticmethod
    def _merge_batch(parts, k: int) -> tuple[np.ndarray, np.ndarray]:
        from ..core.retrieval import merge_topk_batch
        return merge_topk_batch(parts, k)
