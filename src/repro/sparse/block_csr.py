"""Block-bucketed CSR — the TPU-native layout for eager sparse scores.

DESIGN.md §3.1: documents (or GNN destination nodes) are grouped into fixed
blocks of ``block_size``; each block's postings (or edges) live in flat
arrays padded to a static per-block budget that is a multiple of the kernel
tile. Every shape is static under ``jit``; padding waste is the block-size
quantization cost and is reported by ``padding_stats``.

The same layout backs three workloads:
  * BM25S scoring   — (token_id, local_doc, score) per posting
  * GNN aggregation — (src_node, local_dst, edge_weight/message id)
  * EmbeddingBag    — (row_id, local_bag, sample_weight)
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np


# -- host→device transfer accounting -----------------------------------------
#
# The BM25S claim is that eager scoring moves ALL per-query work off the hot
# path; per-batch posting uploads would quietly re-add an O(Σ df) host→device
# copy to every call. Every posting-array upload in the repo goes through
# :func:`put_posting_arrays` so tests can ASSERT the steady-state serving
# path performs zero of them (and benchmarks can report bytes-per-batch
# before/after index residency). Descriptor uploads (O(U) run metadata) are
# counted separately — they are the per-batch cost the resident design is
# allowed to pay.

@dataclass
class TransferStats:
    """Counters for host→device uploads, split by payload class."""

    posting_uploads: int = 0    # device_put calls carrying posting arrays
    posting_bytes: int = 0      # bytes of postings shipped
    descriptor_uploads: int = 0  # run/fragment descriptor tables
    descriptor_bytes: int = 0

    def reset(self) -> None:
        self.posting_uploads = 0
        self.posting_bytes = 0
        self.descriptor_uploads = 0
        self.descriptor_bytes = 0


TRANSFERS = TransferStats()


def reset_transfer_stats() -> TransferStats:
    TRANSFERS.reset()
    return TRANSFERS


def put_posting_arrays(*arrays):
    """Upload posting arrays to device, counting the transfer.

    The ONLY sanctioned way to move posting data host→device: index builds
    and rescales call it once per (re)built shard; the host-gather fallback
    calls it per batch (which is exactly what the counters expose). Returns
    the device arrays in input order.

    Fault-injection site ``residency.put_posting_arrays`` (see
    ``repro.serve.faults``): an armed residency fault makes the upload
    raise ``ResidencyError`` — the peek costs nothing unless the harness
    module is already imported AND a fault is armed.
    """
    import sys
    _f = sys.modules.get("repro.serve.faults")
    if _f is not None and _f.ACTIVE:
        _f.fire("residency.put_posting_arrays")
    import jax.numpy as jnp
    out = []
    for a in arrays:
        a = np.asarray(a)
        TRANSFERS.posting_uploads += 1
        TRANSFERS.posting_bytes += a.nbytes
        out.append(jnp.asarray(a))
    return out[0] if len(out) == 1 else tuple(out)


def put_descriptor_array(arr):
    """Upload a run/fragment descriptor table (O(U) metadata, not postings)."""
    import jax.numpy as jnp
    arr = np.asarray(arr)
    TRANSFERS.descriptor_uploads += 1
    TRANSFERS.descriptor_bytes += arr.nbytes
    return jnp.asarray(arr)


@dataclass
class BlockedPostings:
    """Postings bucketed by destination block (static-shape sparse layout).

    ``token_ids[i, p]`` is -1 for padding slots; padding slots carry
    ``scores == 0`` and ``local_doc == 0`` so any consumer that forgets the
    mask still computes correct sums.
    """

    token_ids: np.ndarray   # [n_blocks, nnz_pad] int32, -1 = pad
    local_doc: np.ndarray   # [n_blocks, nnz_pad] int32 in [0, block_size)
    scores: np.ndarray      # [n_blocks, nnz_pad] float32
    block_size: int
    n_docs: int             # true (unpadded) number of documents
    n_vocab: int

    @property
    def n_blocks(self) -> int:
        return int(self.token_ids.shape[0])

    @property
    def nnz_pad(self) -> int:
        return int(self.token_ids.shape[1])

    def padding_stats(self) -> dict:
        real = int((self.token_ids >= 0).sum())
        total = self.token_ids.size
        return {
            "nnz": real,
            "padded_nnz": total,
            "pad_fraction": 1.0 - real / max(total, 1),
            "n_blocks": self.n_blocks,
            "nnz_pad_per_block": self.nnz_pad,
        }


def _round_up(x: int, tile: int) -> int:
    return max(tile, ((x + tile - 1) // tile) * tile)


def bucket_pow2(n: int, *, floor: int = 512, cap: int | None = None) -> int:
    """Round ``n`` up to a power-of-two bucket (≥ ``floor``).

    Adaptive budgets size device shapes from the batch's ACTUAL demand
    (Σ df, candidate count), but a fresh shape per batch would recompile
    every call — power-of-two buckets bound the distinct compiled shapes to
    O(log max-demand). ``cap`` (if given) clamps the bucket; callers must
    then treat ``n > cap`` as overflow and retry or fall back, never
    truncate silently. (Canonical definition — ``core.scoring`` re-exports
    it; keep ONE power-of-two bucketing implementation in the repo.)
    """
    b = max(int(floor), 1)
    while b < n:
        b <<= 1
    return min(b, cap) if cap is not None else b


def block_postings_from_coo(
    token_ids: np.ndarray,
    doc_ids: np.ndarray,
    scores: np.ndarray,
    *,
    n_docs: int,
    n_vocab: int,
    block_size: int = 512,
    tile: int = 512,
    sort_tokens: bool = True,
) -> BlockedPostings:
    """Bucket COO postings by ``doc_id // block_size`` and pad per block.

    ``nnz_pad`` is the max per-block count rounded up to ``tile`` (one budget
    shared by all blocks so the arrays are rectangular). Within a block
    postings are sorted by token id (the membership-lookup kernel exploits
    locality, and determinism helps tests).

    Fully vectorized: one ``lexsort`` by (block, token) makes each block a
    contiguous run, the within-block column of every posting is
    ``rank - block_start``, and a single fancy-indexed scatter fills the
    rectangular arrays — no per-block Python loop.
    """
    n_blocks = max(1, -(-n_docs // block_size))
    blk = doc_ids // block_size
    counts = np.bincount(blk, minlength=n_blocks)
    nnz_pad = _round_up(int(counts.max()) if counts.size else 0, tile)

    tok = np.full((n_blocks, nnz_pad), -1, dtype=np.int32)
    loc = np.zeros((n_blocks, nnz_pad), dtype=np.int32)
    sc = np.zeros((n_blocks, nnz_pad), dtype=np.float32)

    order = (np.lexsort((token_ids, blk)) if sort_tokens
             else np.argsort(blk, kind="stable"))
    token_ids, doc_ids, scores, blk = (
        token_ids[order], doc_ids[order], scores[order], blk[order])
    starts = np.zeros(n_blocks + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    col = np.arange(blk.size, dtype=np.int64) - starts[blk]
    tok[blk, col] = token_ids
    loc[blk, col] = doc_ids - blk * block_size
    sc[blk, col] = scores
    return BlockedPostings(tok, loc, sc, block_size=block_size,
                           n_docs=n_docs, n_vocab=n_vocab)


def block_postings_from_index(index, *, block_size: int = 512,
                              tile: int = 512) -> BlockedPostings:
    """Re-block a :class:`repro.core.index.BM25Index` (CSC-by-token) shard."""
    df = np.diff(index.indptr)
    tok = np.repeat(np.arange(index.n_vocab, dtype=np.int32), df)
    return block_postings_from_coo(
        tok, index.doc_ids.astype(np.int64), index.scores,
        n_docs=int(index.doc_lens.size), n_vocab=index.n_vocab,
        block_size=block_size, tile=tile)


def block_edges(src: np.ndarray, dst: np.ndarray, weight: np.ndarray | None,
                *, n_nodes: int, block_size: int = 512,
                tile: int = 512) -> BlockedPostings:
    """GNN edge list -> destination-blocked layout (same container).

    ``token_ids`` carries the *source node id*, ``local_doc`` the destination
    offset within its block, ``scores`` the edge weight (1.0 if None).
    """
    w = np.ones(src.shape[0], np.float32) if weight is None else weight
    return block_postings_from_coo(
        src.astype(np.int32), dst.astype(np.int64), w.astype(np.float32),
        n_docs=n_nodes, n_vocab=n_nodes, block_size=block_size, tile=tile,
        sort_tokens=False)


@dataclass
class GatheredPostings:
    """Query-driven posting gather in the candidate-compacted layout.

    Only the query tokens' posting runs are materialized — total work is
    O(Σ df(qᵢ)) over the *batch's unique tokens*, never O(nnz). Candidate
    documents (the union of gathered doc ids, sorted ascending) are mapped
    to compact slots ``0..n_candidates-1``; slots are chunked by
    ``slot // acc_block`` so chunk ``c``'s postings only touch accumulator
    rows ``[0, acc_block)`` — the static shape the gather kernel's
    VMEM accumulator needs. ``candidates[c, r]`` recovers the global doc id
    of chunk ``c``'s slot ``r`` (-1 = padding slot, masked to -inf before
    top-k selection).

    ``acc_block`` should stay SMALL (the blocked layout's block_size, 512):
    the kernel's scatter is a one-hot matmul whose cost is
    ``acc_block × tile_p × B`` per posting tile, so total MXU work is
    ``Σ df × acc_block × B`` — chunking a large candidate set over many
    short accumulators keeps that linear in Σ df, while one tall
    accumulator would multiply every posting by its full height and hand
    the advantage back to the full scan.
    """

    token_ids: np.ndarray    # [n_chunks, p_pad] int32, -1 = pad
    slot_ids: np.ndarray     # [n_chunks, p_pad] int32 in [0, acc_block)
    scores: np.ndarray       # [n_chunks, p_pad] float32
    candidates: np.ndarray   # [n_chunks, acc_block] int32 global ids, -1 pad
    acc_block: int           # accumulator height (candidate slots per chunk)
    n_candidates: int        # true (unpadded) candidate-document count
    sum_df: int              # Σ df over the batch's unique query tokens

    @property
    def n_chunks(self) -> int:
        return int(self.token_ids.shape[0])

    @property
    def p_pad(self) -> int:
        return int(self.token_ids.shape[1])

    def work_ratio(self, nnz: int) -> float:
        """Full-scan postings / gathered postings — the asymptotic win."""
        return nnz / max(self.sum_df, 1)


def _flatten_run_positions(starts: np.ndarray, lens: np.ndarray
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized run flatten: flat slot ``j`` of run ``i`` reads posting
    position ``starts[i] + (j - run_start_i)``.

    Returns ``(pos [Σ lens], run_of [Σ lens])``. The ONE implementation
    every traversal shares — the cached/uncached gathers and the fragment
    compiler must produce byte-identical streams, so they must not each
    carry a copy of this bookkeeping.
    """
    total = int(lens.sum())
    run_of = np.repeat(np.arange(lens.size, dtype=np.int64), lens)
    run_start = np.repeat(np.cumsum(lens) - lens, lens)
    pos = starts[run_of] + np.arange(total, dtype=np.int64) - run_start
    return pos, run_of


def posting_runs(indptr: np.ndarray, uniq_tokens: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Per-token posting-run descriptors ``(start, len)`` from CSC indptr.

    The inverted-index traversal plan: one ``(start, len)`` pair per unique
    query token, O(U) to compute. ``Σ len`` is the exact posting budget the
    gather needs — the adaptive-bucket logic sizes from it.
    """
    starts = indptr[uniq_tokens]
    lens = indptr[uniq_tokens + 1] - starts
    return starts.astype(np.int64), lens.astype(np.int64)


def _gather_runs_cached(index, uniq_tokens: np.ndarray, starts: np.ndarray,
                        lens: np.ndarray, cache: PostingRunCache
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Per-token run gather through the LRU: hot tokens skip the re-gather.

    Cache misses are still gathered in ONE vectorized pass over the missing
    subset (then split per token to populate the cache); the assembled
    ``(doc_ids, scores)`` stream is byte-identical to the uncached path.
    """
    u = uniq_tokens.size
    runs: list[tuple[np.ndarray, np.ndarray] | None] = [None] * u
    miss = []
    for i in range(u):
        if lens[i] == 0:
            runs[i] = (np.zeros(0, np.int64), np.zeros(0, np.float32))
            continue
        hit = cache.get(int(uniq_tokens[i]))
        if hit is None:
            miss.append(i)
        else:
            runs[i] = hit
    if miss:
        m = np.asarray(miss, dtype=np.int64)
        m_lens = lens[m]
        pos, _ = _flatten_run_positions(starts[m], m_lens)
        md = index.doc_ids[pos].astype(np.int64)
        ms = index.scores[pos].astype(np.float32)
        cuts = np.cumsum(m_lens)[:-1]
        for i, d, s in zip(miss, np.split(md, cuts), np.split(ms, cuts)):
            runs[i] = (d, s)
            # copies, not np.split views: a view would pin the WHOLE miss
            # batch's arrays in memory for as long as this run stays in
            # the LRU (capacity bounds entries, not bytes)
            cache.put(int(uniq_tokens[i]), d.copy(), s.copy())
    g_doc = np.concatenate([r[0] for r in runs]) if u else \
        np.zeros(0, np.int64)
    g_sc = np.concatenate([r[1] for r in runs]) if u else \
        np.zeros(0, np.float32)
    return g_doc, g_sc


def gather_posting_runs(index, uniq_tokens: np.ndarray, *,
                        acc_block: int = 512, tile: int = 512,
                        p_bucket: int | None = None,
                        cache: PostingRunCache | None = None,
                        descriptors_only: bool = False):
    """Gather ONLY the query tokens' posting runs (host, fully vectorized).

    One ``np.repeat``-based run flattening replaces per-token slicing: flat
    position ``j`` of run ``i`` reads ``doc_ids[start_i + j]``. Candidate
    compaction is one ``np.unique`` over the gathered doc ids; chunking by
    ``slot // acc_block`` reuses :func:`block_postings_from_coo` (postings
    within a chunk stay token-sorted for the kernel's membership locality).

    Both static dimensions are power-of-two bucketed so the kernel
    recompiles O(log Σdf) times, not once per batch: the per-chunk posting
    dimension rounds up to a power-of-two multiple of ``tile`` (``p_bucket``
    overrides with an explicit floor), and the chunk count pads with empty
    chunks (all -1). The gather itself can never overflow: shapes are sized
    *from* the batch's actual Σ df.

    ``descriptors_only=True`` stops after the O(U) descriptor computation
    and returns :class:`RunDescriptors` — the ``(start, len)`` traversal
    plan with NO posting copy (the resident device path's input; see
    :func:`fragment_plan` for the kernel-ready form). ``cache`` routes the
    copy through a :class:`PostingRunCache` so hot tokens are gathered
    once across batches.
    """
    uniq_tokens = np.asarray(uniq_tokens, dtype=np.int64)
    starts, lens = posting_runs(index.indptr, uniq_tokens)
    total = int(lens.sum())
    if descriptors_only:
        return RunDescriptors(starts=starts, lens=lens, sum_df=total)
    if total == 0:
        p_pad = max(tile, p_bucket or tile)
        return GatheredPostings(
            token_ids=np.full((1, p_pad), -1, np.int32),
            slot_ids=np.zeros((1, p_pad), np.int32),
            scores=np.zeros((1, p_pad), np.float32),
            candidates=np.full((1, acc_block), -1, np.int32),
            acc_block=acc_block, n_candidates=0, sum_df=0)
    g_tok = np.repeat(uniq_tokens, lens).astype(np.int32)
    if cache is not None:
        g_doc, g_sc = _gather_runs_cached(index, uniq_tokens, starts, lens,
                                          cache)
    else:
        pos, _ = _flatten_run_positions(starts, lens)
        g_doc = index.doc_ids[pos].astype(np.int64)
        g_sc = index.scores[pos].astype(np.float32)

    candidates = np.unique(g_doc)                 # sorted ascending
    slot = np.searchsorted(candidates, g_doc)
    n_cand = int(candidates.size)

    bp = block_postings_from_coo(g_tok, slot, g_sc, n_docs=n_cand,
                                 n_vocab=int(index.n_vocab),
                                 block_size=acc_block, tile=tile)
    tok, loc, sc = bp.token_ids, bp.local_doc, bp.scores
    p_pad = max(bucket_pow2(bp.nnz_pad, floor=tile), p_bucket or 0)
    if p_pad > bp.nnz_pad:
        pad = p_pad - bp.nnz_pad
        tok = np.pad(tok, ((0, 0), (0, pad)), constant_values=-1)
        loc = np.pad(loc, ((0, 0), (0, pad)))
        sc = np.pad(sc, ((0, 0), (0, pad)))
    nc = bucket_pow2(bp.n_blocks, floor=1)        # bucket the chunk count
    if nc > bp.n_blocks:
        pad = nc - bp.n_blocks
        tok = np.pad(tok, ((0, pad), (0, 0)), constant_values=-1)
        loc = np.pad(loc, ((0, pad), (0, 0)))
        sc = np.pad(sc, ((0, pad), (0, 0)))
    cand = np.full((nc, acc_block), -1, np.int32)
    flat = cand.reshape(-1)
    flat[:n_cand] = candidates
    return GatheredPostings(token_ids=tok, slot_ids=loc, scores=sc,
                            candidates=cand, acc_block=acc_block,
                            n_candidates=n_cand, sum_df=total)


@dataclass
class RunDescriptors:
    """Descriptor-only posting gather: ``(start, len)`` per unique token.

    What :func:`gather_posting_runs` emits in ``descriptors_only`` mode —
    the traversal plan WITHOUT the O(Σ df) posting copy. O(U) to compute
    and O(U) to ship; the device-resident kernel path turns these into
    fragment DMAs against the HBM-resident index (:class:`DeviceIndex`),
    so postings never cross the host→device boundary per batch.
    """

    starts: np.ndarray      # [U] int64 — posting-run start in the CSC arrays
    lens: np.ndarray        # [U] int64 — run length (= df of the token)
    sum_df: int             # Σ lens — the batch's total posting work

    def work_ratio(self, nnz: int) -> float:
        return nnz / max(self.sum_df, 1)


@dataclass
class FragmentPlan:
    """SMEM descriptor table driving the resident scalar-prefetch kernel.

    The batch's posting runs, split at document-block boundaries into
    *segments* (one (token, block) pair each, grouped by block) and then
    into fixed-``frag``-sized *fragments* — the unit one DMA moves out of
    the HBM-resident CSC arrays. ``desc`` rows (all int32):

      0  start  — fragment's first posting position in the resident arrays
      1  valid  — number of real postings (≤ frag; 0 marks a padding slot)
      2  uniq   — owning row of the ``[U, B]`` query-weight table
      3  block  — global document-block id (accumulator window)
      4  first  — 1 iff first fragment of its block (kernel zeroes the acc)
      5  last   — 1 iff last fragment of its block (kernel reduces top-k)

    Total per-batch upload is ``24 · nf_pad`` bytes of descriptors — O(Σ df
    / frag + #segments), never the postings themselves.
    """

    desc: np.ndarray        # [6, nf_pad] int32
    vis_blocks: np.ndarray  # [nv] int64 — sorted blocks the batch touches
    n_frags: int            # true fragment count (before pow2 padding)
    sum_df: int
    block_size: int
    frag: int

    @property
    def nf_pad(self) -> int:
        return int(self.desc.shape[1])


def fragment_plan(index, uniq_tokens: np.ndarray, *, block_size: int,
                  frag: int = 512, nf_bucket: int | None = None
                  ) -> FragmentPlan:
    """Compile a query batch into the resident kernel's fragment table.

    Reads ONLY host metadata (``indptr`` + one pass over the runs'
    ``doc_ids`` to find block boundaries) — no posting scores are touched
    and nothing O(Σ df) is uploaded. Segments are ordered by block so each
    block's fragments are contiguous in the grid (the kernel's accumulator
    lives across exactly that span); the fragment count is pow2-bucketed so
    recompiles stay O(log demand).
    """
    uniq_tokens = np.asarray(uniq_tokens, dtype=np.int64)
    starts, lens = posting_runs(index.indptr, uniq_tokens)
    total = int(lens.sum())
    if total == 0:
        nf_pad = max(nf_bucket or 8, 8)
        return FragmentPlan(np.zeros((6, nf_pad), np.int32),
                            np.zeros(0, np.int64), 0, 0, block_size, frag)
    assert int(index.indptr[-1]) < 2 ** 31, "int32 fragment starts"
    # flatten runs (positions only — doc ids drive the block split)
    pos, run_of = _flatten_run_positions(starts, lens)
    blk = index.doc_ids[pos].astype(np.int64) // block_size
    # segments: maximal (run, block)-constant spans of the flat stream
    new = np.empty(total, dtype=bool)
    new[0] = True
    new[1:] = (run_of[1:] != run_of[:-1]) | (blk[1:] != blk[:-1])
    seg_at = np.flatnonzero(new)
    seg_len = np.diff(np.append(seg_at, total))
    seg_start = pos[seg_at]
    seg_uniq = run_of[seg_at]
    seg_blk = blk[seg_at]
    order = np.argsort(seg_blk, kind="stable")      # group by block
    seg_start, seg_uniq, seg_blk, seg_len = (
        seg_start[order], seg_uniq[order], seg_blk[order], seg_len[order])
    # fragments: split each segment into ≤frag-sized DMA units
    nf_seg = -(-seg_len // frag)
    nf = int(nf_seg.sum())
    fseg = np.repeat(np.arange(nf_seg.size, dtype=np.int64), nf_seg)
    fm = np.arange(nf, dtype=np.int64) - np.repeat(
        np.cumsum(nf_seg) - nf_seg, nf_seg)
    f_start = seg_start[fseg] + fm * frag
    f_valid = np.minimum(frag, seg_len[fseg] - fm * frag)
    f_uniq = seg_uniq[fseg]
    f_blk = seg_blk[fseg]
    f_first = np.empty(nf, dtype=np.int64)
    f_first[0] = 1
    f_first[1:] = f_blk[1:] != f_blk[:-1]
    f_last = np.empty(nf, dtype=np.int64)
    f_last[-1] = 1
    f_last[:-1] = f_blk[1:] != f_blk[:-1]
    nf_pad = max(bucket_pow2(nf, floor=8), nf_bucket or 0)
    desc = np.zeros((6, nf_pad), np.int32)
    desc[0, :nf] = f_start
    desc[1, :nf] = f_valid
    desc[2, :nf] = f_uniq
    desc[3, :nf] = f_blk
    desc[4, :nf] = f_first
    desc[5, :nf] = f_last
    return FragmentPlan(desc, np.unique(seg_blk), nf, total, block_size,
                        frag)


# -- block-max tables (the pruned regime's bound metadata) --------------------
#
# Eager scoring makes block-max pruning FREE at build time: every posting's
# final contribution is already known, so the per-(token, doc-block) maximum
# is one ``np.maximum.reduceat`` over the CSC run boundaries. The table is
# clamped at zero (a document MISSING a posting contributes exactly 0, so a
# negative block max — robertson's negative-IDF differentials — never bounds
# anything below zero), which is what makes the bound valid on all five
# variants:
#
#     score(d in block b, q) = Σ_t w_t · s(t, d)  ≤  Σ_t w_t · bmax[t, b]
#
# for any nonnegative query weights w. The pruned retrieval regime compares
# that upper bound against a per-query threshold (a REAL document's full
# score, so a certified lower bound on the final k-th score) and skips every
# fragment whose block provably cannot alter the scoreboard.

_BOUND_SLACK = 1e-3   # relative inflation covering f32 kernel accumulation
_BOUND_ABS = 1e-6     # absolute floor so equal-to-zero bounds stay strict


@dataclass
class BlockMaxTable:
    """Dense per-(token, doc-block) score upper bounds, host + HBM-resident.

    ``host[t, b]`` bounds the stored (shifted) score any document of block
    ``b`` can receive from token ``t`` — clamped at 0 so the bound also
    covers documents without the posting (and negative-IDF postings). The
    column dimension is pow2-bucketed (``nb_pad``) so jit shapes stay
    stable across rescales; columns ≥ ``n_blocks`` are zero.

    ``quantized=True`` stores u8 codes with a PER-TOKEN scale (one f32 per
    vocabulary row — a global scale would inflate every low-IDF token's
    bounds to the corpus-wide maximum's granularity and kill pruning on
    exactly the Zipf-head tokens that matter), CEIL-quantized (``dequant ≥
    true max``) so the bound stays conservative; the auto builder picks u8
    whenever the f32 table would exceed a quarter of the posting bytes —
    the HBM budget the resident index is allowed to spend on pruning
    metadata. ``device``/``scale_dev`` mirror the table in HBM (uploaded
    once per (re)build, descriptor-class traffic).
    """

    host: np.ndarray        # [V, nb_pad] float32, or uint8 codes
    scale: np.ndarray       # [V] f32 per-token dequant scale (1s for f32)
    quantized: bool
    block_size: int
    n_blocks: int           # true block count (before pow2 padding)
    nb_pad: int
    over_budget: bool       # even u8 exceeded the ≤1/4-posting-bytes target
    device: object = None   # same table, HBM-resident (jax array)
    scale_dev: object = None

    @property
    def nbytes(self) -> int:
        return int(self.host.nbytes
                   + (self.scale.nbytes if self.quantized else 0))

    def rows(self, tokens: np.ndarray) -> np.ndarray:
        """Dequantized f32 bound rows for ``tokens`` (clipped to range)."""
        safe = np.clip(np.asarray(tokens, dtype=np.int64), 0,
                       self.host.shape[0] - 1)
        r = self.host[safe].astype(np.float32)
        return r * self.scale[safe][:, None] if self.quantized else r


def build_block_max(index, *, block_size: int, dtype: str = "auto"
                    ) -> BlockMaxTable:
    """One vectorized pass COO → block-max table (build-time byproduct).

    The CSC invariant (postings sorted by token, then doc id) makes every
    (token, doc-block) pair a contiguous run of the posting stream, so the
    per-run maxima are a single ``np.maximum.reduceat`` over the run
    boundaries — O(nnz), no per-token loop, shared with nothing (the
    fragment planners find the same boundaries per *batch*; this runs once
    per build over ALL tokens).

    ``dtype``: ``"f32"`` / ``"u8"`` force the storage; ``"auto"`` picks f32
    when it fits the ≤1/4-posting-bytes budget, else the u8 ceil-quantized
    form (u8 is kept even when it too overflows the budget — recorded in
    ``over_budget`` — because the pruned regime is opt-in via the planner).
    """
    if dtype not in ("auto", "f32", "u8"):
        raise ValueError(f"unknown block-max dtype {dtype!r}")
    v = int(index.n_vocab)
    n_docs = int(index.doc_lens.size)
    n_blocks = max(1, -(-n_docs // block_size))
    nb_pad = bucket_pow2(n_blocks, floor=8)
    table = np.zeros((v, nb_pad), dtype=np.float32)
    nnz = int(index.doc_ids.size)
    if nnz:
        df = np.diff(index.indptr)
        tok = np.repeat(np.arange(v, dtype=np.int64), df)
        blk = index.doc_ids.astype(np.int64) // block_size
        new = np.empty(nnz, dtype=bool)
        new[0] = True
        new[1:] = (tok[1:] != tok[:-1]) | (blk[1:] != blk[:-1])
        run_at = np.flatnonzero(new)
        run_max = np.maximum.reduceat(index.scores, run_at)
        # clamp: docs without the posting contribute 0, so the bound is
        # max(0, run max) — also neutralizes negative-IDF differentials
        table[tok[run_at], blk[run_at]] = np.maximum(run_max, 0.0)
    posting_budget = nnz * 8 // 4            # doc_ids i32 + scores f32
    if dtype == "auto":
        dtype = "f32" if table.nbytes <= posting_budget else "u8"
    if dtype == "u8":
        # PER-TOKEN scales: each row quantizes against its own maximum, so
        # a tiny-IDF token's bounds keep 1/255 relative resolution instead
        # of the corpus-max granularity
        mx = table.max(axis=1)
        scale = np.where(mx > 0, mx / 255.0, 1.0).astype(np.float32)
        codes = np.ceil(table / scale[:, None]).astype(np.int64)
        host = np.clip(codes, 0, 255).astype(np.uint8)  # dequant ≥ true
        quantized = True
    else:
        host, scale, quantized = table, np.ones(v, np.float32), False
    bm = BlockMaxTable(host=host, scale=scale, quantized=quantized,
                       block_size=block_size, n_blocks=n_blocks,
                       nb_pad=nb_pad,
                       over_budget=host.nbytes > max(posting_budget, 1))
    bm.device = put_descriptor_array(host)
    bm.scale_dev = put_descriptor_array(scale)   # ones when unquantized
    return bm


def block_upper_bounds(bmax: BlockMaxTable, uniq_tab: np.ndarray,
                       weights: np.ndarray) -> np.ndarray:
    """Per-(block, query) score upper bounds for one packed batch.

    ``uniq_tab``/``weights`` are the kernel's own query operands
    (``pack_query_batch`` layout: sentinel rows carry zero weight, so
    clipping their token id is harmless). Computed in f64 and inflated by
    ``_BOUND_SLACK`` so the f32 kernel's accumulation rounding can never
    push a real score past its bound — inflation only ever makes pruning
    MORE conservative, never wrong. Returns ``[nb_pad, B]`` float32.
    """
    rows = bmax.rows(uniq_tab).astype(np.float64)        # [U, nb_pad]
    ub = rows.T @ weights.astype(np.float64)             # [nb_pad, B]
    return (ub * (1.0 + _BOUND_SLACK) + _BOUND_ABS).astype(np.float32)


def prune_fragment_plan(fp: FragmentPlan, keep_blocks: np.ndarray
                        ) -> FragmentPlan:
    """Compact a fragment table to the fragments of surviving blocks.

    ``keep_blocks`` is a boolean mask over block ids (``[nb]``, nb ≥ max
    block id + 1). Pruning is BLOCK-granular, so the surviving fragments
    keep their relative order and their first/last accumulator flags stay
    consistent (whole blocks leave, never a block's interior). The
    returned plan's ``vis_blocks`` is preserved UNPRUNED — the
    default-document splice must keep treating pruned blocks as visited
    (their documents score below the threshold, not zero) — while
    ``sum_df`` reflects the surviving posting work and ``nf_pad``
    re-buckets so the kernel grid shrinks with the pruned work.
    """
    n = fp.n_frags
    d = fp.desc[:, :n]
    keep = keep_blocks[d[3]] if n else np.zeros(0, dtype=bool)
    sel = d[:, keep]
    nf = int(sel.shape[1])
    nf_pad = bucket_pow2(max(nf, 1), floor=8)
    desc = np.zeros((6, nf_pad), np.int32)
    desc[:, :nf] = sel
    return FragmentPlan(desc, fp.vis_blocks, nf, int(sel[1].sum()),
                        fp.block_size, fp.frag)


def estimate_prune_survivors(bmax: BlockMaxTable, uniq_tab: np.ndarray,
                             weights: np.ndarray, *, k: int,
                             b_true: int | None = None
                             ) -> tuple[float, np.ndarray]:
    """Host estimate of the pruning win, BEFORE any device work.

    The planner needs the surviving-work fraction to decide whether the
    pruned regime is worth its overhead, but the certified threshold only
    exists after the seed pass. This estimate stands in: each block's best
    single-term score ``max_t w_t · bmax[t, b]`` is (approximately) a
    score some document of the block achieves, so the k-th largest of
    those across blocks approximates the final k-th score from below —
    conservative on the variants with nonnegative contributions, a
    heuristic on robertson (execution stays exact either way; only the
    regime CHOICE consumes this number). Survivors are the blocks whose
    full upper bound reaches the estimated threshold for any query;
    the fraction is over visited blocks (a block-count proxy for the df
    share — per-block df is not free host metadata).

    ``b_true`` marks the real batch width: columns past it are pow2
    padding whose results are sliced off, so they are EXCLUDED here and
    their bound columns returned as -inf — a padding column's trivial
    0-threshold would otherwise veto every prune (a REAL empty query
    keeps that veto on purpose: its all-tied output must reproduce the
    oracle's fold order exactly, so nothing may be pruned for it).

    Returns ``(survivor_frac, ub [nb_pad, B])`` — under HOST planning the
    execution path reuses the bounds so the matmul is paid once per batch
    (device planning recomputes them on device and callers skip this
    estimate unless the auto cost model needs it).

    Under doc-id reordering (``DeviceIndex.build(reorder=...)``) the
    caller MUST pass the block-max table built on the PERMUTED order —
    the retriever hands over ``self.dindex.bmax``, which is exactly that
    table, and reuses the returned ``ub`` for fragment plans drawn from
    the permuted host copy, so estimate, bounds and plans share one id
    space (a client-order table here would mis-bound every block).
    """
    ub = block_upper_bounds(bmax, uniq_tab, weights)
    b = weights.shape[1]
    if b_true is not None and b_true < b:
        ub[:, b_true:] = -np.inf
    else:
        b_true = b
    if b_true == 0:
        return 1.0, ub
    visited = ub[:, :b_true].max(axis=1) > 2.0 * _BOUND_ABS
    nv = int(visited.sum())
    if nv == 0:
        return 1.0, ub
    rows = bmax.rows(uniq_tab)                           # [U, nb_pad]
    kb = min(k, nv)
    tau_hat = np.empty(b_true, dtype=np.float32)
    for q in range(b_true):                              # B is small
        lb = (rows * weights[:, q:q + 1]).max(axis=0)    # [nb_pad]
        lb = lb[visited]
        tau_hat[q] = np.partition(lb, lb.size - kb)[lb.size - kb]
    surv = visited & (ub[:, :b_true] >= tau_hat[None, :]).any(axis=1)
    return float(surv.sum() / nv), ub


def seed_block_budget(k: int) -> int:
    """How many highest-bound blocks the threshold-seeding pass scores.

    The k winners can sit in up to k distinct blocks, so a tight seed
    threshold wants ~k blocks; the cap bounds the re-scored seed work for
    large k (the in-kernel skip refines whatever the seed pass missed).
    """
    return max(2, min(16, k))


def select_seed_blocks(ub: np.ndarray, vis_blocks: np.ndarray, *,
                       k: int, block_size: int) -> np.ndarray:
    """Threshold-seeding block choice: PER QUERY, the visited blocks with
    the highest upper bounds — the likeliest homes of that query's top-k
    documents, so scoring them first yields a tight per-query threshold
    (:func:`seed_block_budget` blocks each, unioned across the batch; a
    single shared pick would let one query's hot blocks crowd out the
    rest, leaving their thresholds loose and the pre-launch compaction
    toothless). Returns a boolean keep-mask over block ids, shaped like
    ``ub``'s block axis."""
    keep = np.zeros(ub.shape[0], dtype=bool)
    if vis_blocks.size == 0:
        return keep
    n_seed = min(int(vis_blocks.size), seed_block_budget(k))
    score = ub[vis_blocks]                               # [nv, B]
    for q in range(score.shape[1]):                      # B is small
        if not np.isfinite(score[:, q]).any():
            continue                                     # padding column
        top = vis_blocks[np.argsort(-score[:, q],
                                    kind="stable")[:n_seed]]
        keep[top] = True
    return keep


class PostingRunCache:
    """LRU cache of per-token gathered posting runs (host-gather fallback).

    Zipf-head query tokens recur across batches; without a cache the host
    fallback re-gathers their (large) posting runs from the CSC arrays on
    every batch. Keyed by token id; values are the ``(doc_ids, scores)``
    run copies. Bounded by ``capacity`` entries, least-recently-used out
    first. The resident device path never needs this — its index never
    leaves HBM.

    get/put are lock-guarded: the serving engine's thread pool may run the
    SAME shard's scorer for concurrent requests, and an unguarded
    ``move_to_end``/``popitem`` race corrupts the OrderedDict. Entries for
    a given token are immutable snapshots of the index, so cross-request
    interleaving is otherwise harmless (a double put stores equal arrays).
    """

    def __init__(self, capacity: int = 256):
        import threading
        self.capacity = int(capacity)
        self._runs: OrderedDict[int, tuple[np.ndarray, np.ndarray]] = \
            OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._runs)

    def get(self, token: int):
        with self._lock:
            run = self._runs.get(token)
            if run is None:
                self.misses += 1
                return None
            self._runs.move_to_end(token)
            self.hits += 1
            return run

    def put(self, token: int, doc_ids: np.ndarray, scores: np.ndarray
            ) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            self._runs[token] = (doc_ids, scores)
            self._runs.move_to_end(token)
            while len(self._runs) > self.capacity:
                self._runs.popitem(last=False)


@dataclass
class DeviceIndex:
    """HBM-resident eager index: posting arrays uploaded ONCE per (re)build.

    The device-side half of the BM25S residency story: the shifted CSC
    posting arrays live in HBM across calls (``csc_doc_ids``/``csc_scores``,
    shaped ``[1, nnz_pad]`` so fragment DMAs can slice them at dynamic
    offsets), alongside the block-bucketed full-scan layout — so BOTH
    retrieval regimes read resident arrays and the steady-state serving
    path ships only O(U) query tables and fragment descriptors per batch.
    Host-side it keeps the run-descriptor metadata (``indptr``/``df``) the
    planner and fragment compiler need, which is why plan costs are free.

    Holding both layouts costs ≤2× posting memory; pass ``with_blocked`` /
    ``with_csc`` False to drop the regime you will never force. With
    DEVICE-side fragment planning (``sparse.fragment_device``) nothing on
    the serving path reads the host CSC copy either — ``host_arrays=
    "drop"`` then releases it (``host`` becomes None; only the O(V)
    ``indptr``/``df`` metadata stays, which the planner and bucket sizing
    need). The host-gather fallback and ``PostingRunCache`` keep their
    copy — drop only what device planning made dead weight.
    """

    host: object            # BM25Index — descriptor metadata + fallbacks
    indptr: np.ndarray      # [V+1] host — the run-descriptor table
    df: np.ndarray          # [V] host — per-token run lengths (Σ df is free)
    nnz: int
    n_docs: int
    n_vocab: int
    doc_offset: int
    block_size: int
    tile_p: int
    frag: int
    csc_doc_ids: object = None   # [1, nnz_pad] int32 device (or None)
    csc_scores: object = None    # [1, nnz_pad] f32 device (or None)
    csc_indptr: object = None    # [V+1] int32 device (device plan builder)
    blk_tok: object = None       # [nb, p_pad] int32 device (or None)
    blk_loc: object = None
    blk_sc: object = None
    bmax: object = None          # BlockMaxTable (pruned regime) or None
    reused: dict = None          # which layouts a rescale build recycled
    snapshot_report: dict = None  # set by sparse.snapshot loads (health())
    # build-time doc-id reordering (sparse.reorder): ``perm[new] = old``
    # client id, or None when the layouts keep the client order. ``host``
    # and every resident layout live in the PERMUTED id space; retrievers
    # gather ``perm`` over the winner board at the merge.
    perm: np.ndarray = None      # [n_docs] int32 new_id -> old_id, or None
    reorder: str = "none"        # the scheme that produced ``perm``

    @staticmethod
    def _postings_identical(a, b) -> bool:
        """Byte-identical posting payload (layouts depend on nothing else
        except the doc count, checked separately where it matters)."""
        return (a is not None and b is not None
                and np.array_equal(a.indptr, b.indptr)
                and np.array_equal(a.doc_ids, b.doc_ids)
                and np.array_equal(a.scores, b.scores))

    @staticmethod
    def build(index, *, block_size: int = 512, tile: int = 512,
              frag: int = 512, with_blocked: bool = True,
              with_csc: bool = True, with_bmax: bool | None = None,
              bmax_dtype: str = "auto",
              host_arrays: str = "keep",
              reorder: str = "none",
              reuse_from: "DeviceIndex | None" = None) -> "DeviceIndex":
        """Upload a shard's resident layouts, recycling ``reuse_from``'s.

        ``reorder`` (``"none"`` | ``"signature"`` | ``"minhash"``) runs the
        build-time doc-id clustering pass (``sparse.reorder``): documents
        are re-numbered so similar posting signatures share doc blocks,
        which tightens the block-max bounds and raises pruned-regime skip
        rates. Every layout below — CSC, blocked, block-max — is then
        built on the PERMUTED order in the same one-lexsort pass the
        builder already uses; ``di.perm`` carries the ``new -> old`` map
        retrievers gather over the winner board at the merge. Exactness
        is untouched: scores travel with their postings bit-for-bit.

        ``reuse_from`` is the incremental re-blocking path for elastic
        rescales: when the new shard's posting bytes are identical to the
        old DeviceIndex's (boundaries moved through posting-less documents,
        or didn't move at all) the already-resident CSC arrays are adopted
        as-is, and the blocked layout + block-max table are adopted too
        whenever the block grid still matches (same ``block_size`` and
        block count) — no host-side re-blocking, no re-upload, zero
        posting bytes shipped. ``di.reused`` records which layouts were
        recycled (the engine surfaces it as ``blockmax_reused``). A
        donor whose PERMUTATION differs (reordered vs. unordered, or a
        different clustering) is never adopted — its layouts index a
        different doc space.
        """
        from .reorder import (permutations_equal, permute_index,
                              signature_permutation)
        if host_arrays not in ("keep", "drop"):
            raise ValueError(f"unknown host_arrays mode {host_arrays!r}")
        if with_bmax is None:
            with_bmax = with_csc
        perm = signature_permutation(index, mode=reorder)
        if perm is not None:
            index = permute_index(index, perm)
        nnz = int(index.doc_ids.size)
        n_docs = int(index.doc_lens.size)
        di = DeviceIndex(
            host=index, indptr=index.indptr, df=np.diff(index.indptr),
            nnz=nnz, n_docs=n_docs,
            n_vocab=int(index.n_vocab), doc_offset=int(index.doc_offset),
            block_size=block_size, tile_p=tile, frag=frag,
            reused={"csc": False, "blocked": False, "bmax": False},
            perm=perm, reorder=reorder)
        old = reuse_from
        same_postings = (
            old is not None and old.host is not None
            and old.block_size == block_size and old.frag == frag
            and permutations_equal(perm, old.perm)
            and DeviceIndex._postings_identical(index, old.host))
        # the blocked layout and the block-max table additionally depend on
        # the block GRID — a doc-count change through trailing empty docs
        # only invalidates them when it moves the block count
        same_grid = (same_postings
                     and -(-n_docs // block_size)
                     == -(-old.n_docs // block_size))
        if with_csc:
            if same_postings and old.csc_doc_ids is not None:
                di.csc_doc_ids = old.csc_doc_ids
                di.csc_scores = old.csc_scores
                di.csc_indptr = old.csc_indptr
                di.reused["csc"] = True
            else:
                # pad so any fragment DMA [start, start+frag) stays in
                # bounds (starts are < nnz; padding postings carry score 0
                # / doc 0 and are masked by the fragment's valid length)
                assert nnz < 2 ** 31, "int32 resident CSC positions"
                nnz_pad = _round_up(max(nnz, 1), frag) + frag
                doc = np.zeros((1, nnz_pad), np.int32)
                sc = np.zeros((1, nnz_pad), np.float32)
                doc[0, :nnz] = index.doc_ids
                sc[0, :nnz] = index.scores
                di.csc_doc_ids, di.csc_scores = put_posting_arrays(doc, sc)
                # one-time O(V) upload so fragment tables can be built on
                # device (counted as the descriptor traffic it replaces)
                di.csc_indptr = put_descriptor_array(
                    index.indptr.astype(np.int32))
        if with_blocked:
            if same_grid and old.blk_tok is not None \
                    and old.tile_p == min(tile, old.blk_tok.shape[1]):
                di.tile_p = old.tile_p
                di.blk_tok, di.blk_loc, di.blk_sc = (old.blk_tok,
                                                     old.blk_loc, old.blk_sc)
                di.reused["blocked"] = True
            else:
                bp = block_postings_from_index(index, block_size=block_size,
                                               tile=tile)
                di.tile_p = min(tile, bp.nnz_pad)
                di.blk_tok, di.blk_loc, di.blk_sc = put_posting_arrays(
                    bp.token_ids, bp.local_doc, bp.scores)
        if with_bmax and with_csc:
            if same_grid and old.bmax is not None \
                    and (bmax_dtype == "auto"
                         or old.bmax.quantized == (bmax_dtype == "u8")):
                di.bmax = old.bmax
                di.reused["bmax"] = True
            else:
                di.bmax = build_block_max(index, block_size=block_size,
                                          dtype=bmax_dtype)
        if host_arrays == "drop":
            if perm is not None:
                # keep a posting-free PERMUTED metadata copy: retrievers
                # and snapshot saves need doc_lens in the layouts' id
                # space (the O(nnz) arrays are still released)
                from dataclasses import replace as _replace
                di.host = _replace(
                    index, doc_ids=np.zeros(0, np.int32),
                    scores=np.zeros(0, np.float32))
            else:
                di.host = None           # serving must never read it again
        return di

    def sum_df(self, uniq_tokens: np.ndarray) -> int:
        """Batch posting work Σ df — free, from the host descriptor table."""
        u = np.asarray(uniq_tokens)
        return int(self.df[u].sum()) if u.size else 0

    # -- crash-safe persistence (sparse.snapshot) ---------------------------
    def save(self, path: str, *, index=None, algo: str | None = None) -> dict:
        """Atomic checksummed snapshot of the resident layouts (see
        ``sparse.snapshot``). ``index=`` supplies host metadata when this
        DeviceIndex was built with ``host_arrays='drop'``."""
        from . import snapshot
        return snapshot.save_device_index(self, path, index=index, algo=algo)

    @staticmethod
    def load(path: str, *, mmap: bool = False, host_arrays: str = "keep",
             verify: bool = True, corpus=None) -> "DeviceIndex":
        """Cold-start from a snapshot: verified (checksummed) read, then
        upload straight from the (mem)mapped padded layouts through
        ``put_posting_arrays`` — no host re-blocking, and the
        zero-steady-state-bytes invariant holds for every batch after."""
        from . import snapshot
        return snapshot.load_device_index(path, mmap=mmap,
                                          host_arrays=host_arrays,
                                          verify=verify, corpus=corpus)


def query_nonoccurrence_shift(nonoccurrence: np.ndarray,
                              q_tokens: np.ndarray,
                              q_weights: np.ndarray) -> np.ndarray:
    """Per-query §2.1 constant ``Σᵢ wᵢ·S⁰(qᵢ)`` for a padded query batch.

    ``[B]`` float32, zero for sparse variants. The single definition of the
    host-side shift the fused retrieval path adds after its merge
    (``ops.bm25_retrieve_blocked``'s ``nonocc_shift`` operand).
    """
    safe = np.where(q_tokens >= 0, q_tokens, 0)
    return ((q_weights * nonoccurrence[safe] * (q_tokens >= 0))
            .sum(-1).astype(np.float32))


def pack_query_batch(q_tokens: np.ndarray, q_weights: np.ndarray,
                     u_max: int, *, uniq: np.ndarray | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Batch of padded queries -> (sorted unique tokens [U], weights [U, B]).

    The batched kernel scores *all* queries in one pass over the postings
    (DESIGN.md §3.3); its query-side operand is the batch's unique-token
    table plus a per-query weight column. Pad token = 2^31 - 1 (sorts last,
    matches nothing since posting pads are -1). ``uniq`` lets hot-path
    callers that already computed the batch's sorted unique tokens (for
    bucket sizing / run gathering) skip the redundant sort here.
    """
    b = q_tokens.shape[0]
    if uniq is None:
        uniq = np.unique(q_tokens[q_tokens >= 0])
    if uniq.size > u_max:
        raise ValueError(f"query batch has {uniq.size} unique tokens "
                         f"> u_max={u_max}")
    table = np.full(u_max, np.iinfo(np.int32).max, dtype=np.int32)
    table[: uniq.size] = uniq
    weights = np.zeros((u_max, b), dtype=np.float32)
    # tokens are unique within a query (pad_queries), so one scatter works
    qi, slot = np.nonzero(q_tokens >= 0)
    pos = np.searchsorted(uniq, q_tokens[qi, slot])
    weights[pos, qi] = q_weights[qi, slot]
    return table, weights
