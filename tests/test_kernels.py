"""Every Pallas kernel vs its ref.py oracle: shape/dtype sweeps,
interpret=True on CPU (the kernels target TPU)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.blockwise_topk import blockwise_topk_kernel
from repro.kernels.bm25_block_score import bm25_block_score
from repro.kernels.block_segment_sum import block_segment_sum
from repro.kernels.embedding_bag import embedding_bag_kernel
from repro.sparse.block_csr import (block_postings_from_index,
                                    pack_query_batch)
from repro.core import BM25Params, build_index, pad_queries


@pytest.mark.parametrize("nb,p,tile,bs,u,b", [
    (2, 128, 64, 32, 16, 4),
    (3, 256, 128, 64, 32, 8),
    (1, 512, 512, 128, 64, 16),
])
def test_bm25_block_score_shapes(nb, p, tile, bs, u, b, rng):
    vocab = max(40, 2 * u)
    tok = rng.integers(-1, vocab, size=(nb, p)).astype(np.int32)
    loc = rng.integers(0, bs, size=(nb, p)).astype(np.int32)
    sc = rng.normal(size=(nb, p)).astype(np.float32)
    sc[tok < 0] = 0.0
    uniq = np.sort(rng.choice(vocab, size=u, replace=False)).astype(np.int32)
    w = rng.normal(size=(u, b)).astype(np.float32)
    out = bm25_block_score(jnp.asarray(tok), jnp.asarray(loc),
                           jnp.asarray(sc), jnp.asarray(uniq),
                           jnp.asarray(w), block_size=bs, tile_p=tile)
    expect = ref.bm25_block_score_ref(jnp.asarray(tok), jnp.asarray(loc),
                                      jnp.asarray(sc), jnp.asarray(uniq),
                                      jnp.asarray(w), block_size=bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("method", ["lucene", "bm25l"])
def test_bm25_kernel_end_to_end_exact(method, rng):
    """Blocked kernel path == dense oracle on a real index."""
    from repro.core import dense_oracle_scores
    corpus = [rng.integers(0, 64, size=rng.integers(1, 20)).astype(np.int32)
              for _ in range(90)]
    p = BM25Params(method=method)
    idx = build_index(corpus, 64, params=p)
    bp = block_postings_from_index(idx, block_size=32, tile=64)
    queries = [rng.integers(0, 64, size=rng.integers(1, 6)).astype(np.int32)
               for _ in range(4)]
    toks, wts = pad_queries(queries, 8)
    uniq, weights = pack_query_batch(toks, wts, u_max=32)
    safe = np.where(toks >= 0, toks, 0)
    shift = (wts * idx.nonoccurrence[safe] * (toks >= 0)).sum(-1)
    out = ops.bm25_score_blocked(
        jnp.asarray(bp.token_ids), jnp.asarray(bp.local_doc),
        jnp.asarray(bp.scores), jnp.asarray(uniq), jnp.asarray(weights),
        nonocc_shift=jnp.asarray(shift), block_size=bp.block_size,
        n_docs=90, tile_p=64)
    for i, q in enumerate(queries):
        np.testing.assert_allclose(
            np.asarray(out)[i], dense_oracle_scores(corpus, 64, q, p),
            atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize("nb,p,d,s", [(2, 128, 8, 16), (4, 256, 32, 64)])
def test_block_segment_sum_sweep(nb, p, d, s, dtype, rng):
    vals = rng.normal(size=(nb, p, d)).astype(dtype)
    ids = rng.integers(0, s, size=(nb, p)).astype(np.int32)
    out = block_segment_sum(jnp.asarray(vals), jnp.asarray(ids),
                            num_segments=s, tile_p=p // 2)
    expect = ref.block_segment_sum_ref(jnp.asarray(vals), jnp.asarray(ids),
                                       num_segments=s)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("v,d,b,f,tile_b", [
    (100, 16, 32, 4, 16), (500, 64, 64, 9, 32),
])
def test_embedding_bag_kernel_sweep(v, d, b, f, tile_b, rng):
    table = rng.normal(size=(v, d)).astype(np.float32)
    idx = rng.integers(-1, v, size=(b, f)).astype(np.int32)
    w = rng.normal(size=(b, f)).astype(np.float32)
    out = embedding_bag_kernel(jnp.asarray(table), jnp.asarray(idx),
                               jnp.asarray(w), tile_b=tile_b)
    expect = ref.embedding_bag_ref(jnp.asarray(table), jnp.asarray(idx),
                                   jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_embedding_bag_pads_batch(rng):
    table = rng.normal(size=(50, 8)).astype(np.float32)
    idx = rng.integers(0, 50, size=(13, 3)).astype(np.int32)   # 13 % tile != 0
    out = ops.embedding_bag(jnp.asarray(table), jnp.asarray(idx), tile_b=8)
    expect = ref.embedding_bag_ref(
        jnp.asarray(table), jnp.asarray(idx), jnp.ones((13, 3), jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,block,k", [(4096, 512, 7), (8192, 1024, 50)])
def test_blockwise_topk_vs_full_sort(n, block, k, rng):
    x = rng.normal(size=(2, n)).astype(np.float32)
    vals, idx = ops.topk(jnp.asarray(x), k, block=block)
    rv, ri = jax.lax.top_k(jnp.asarray(x), k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), atol=1e-6)
    np.testing.assert_allclose(
        np.take_along_axis(x, np.asarray(idx), 1), np.asarray(rv), atol=1e-6)


def test_blockwise_topk_kernel_matches_ref(rng):
    x = rng.normal(size=(6, 256)).astype(np.float32)
    vals, idx = blockwise_topk_kernel(jnp.asarray(x), k=5)
    rvals, ridx = ref.blockwise_topk_ref(jnp.asarray(x).reshape(-1), k=5,
                                         block=256)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), atol=1e-6)


def test_topk_with_duplicates():
    x = jnp.zeros((1, 4096))
    vals, idx = ops.topk(x, 5, block=1024)
    np.testing.assert_allclose(np.asarray(vals), 0.0)
    assert len(set(np.asarray(idx)[0].tolist())) == 5   # distinct positions
