"""Sharded checkpointing with atomic manifests and corruption fallback.

Layout (one directory per step):

    ckpt_dir/
      step_000120/
        host_000.npz          (this host's shard of every leaf)
        MANIFEST.json         (written LAST, atomically — marks complete)

``latest_complete_step`` only considers steps whose manifest exists and
whose files pass a size check, so a preempted or corrupted write falls
back to the previous step — tested by truncating files mid-"failure".

On a real multi-host pod each host writes its addressable shards
(``host_{process_index}.npz``); in this single-process environment that is
host 0 holding everything, but the format and recovery path are identical.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, state) -> str:
    """Write state for ``step``; manifest written last + atomic rename."""
    d = os.path.join(ckpt_dir, f"step_{step:06d}")
    tmp = d + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    host = jax.process_index()
    fname = os.path.join(tmp, f"host_{host:03d}.npz")
    np.savez(fname, **flat)
    manifest = {
        "step": step,
        "n_hosts": jax.process_count(),
        "files": {f"host_{host:03d}.npz": os.path.getsize(fname)},
        "keys": sorted(flat),
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(d):
        shutil.rmtree(d)
    os.replace(tmp, d)
    return d


def _is_complete(d: str) -> bool:
    man = os.path.join(d, "MANIFEST.json")
    if not os.path.exists(man):
        return False
    try:
        with open(man) as f:
            m = json.load(f)
        for fname, size in m["files"].items():
            p = os.path.join(d, fname)
            if not os.path.exists(p) or os.path.getsize(p) != size:
                return False
        return True
    except (json.JSONDecodeError, KeyError, OSError):
        return False


def latest_complete_step(ckpt_dir: str) -> int | None:
    """Newest step with a complete, size-verified manifest (else older)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(
        (int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
         if n.startswith("step_") and not n.endswith(".tmp")),
        reverse=True)
    for s in steps:
        if _is_complete(os.path.join(ckpt_dir, f"step_{s:06d}")):
            return s
    return None


def load_checkpoint(ckpt_dir: str, step: int, state_like):
    """Restore into the structure of ``state_like`` (values replaced)."""
    d = os.path.join(ckpt_dir, f"step_{step:06d}")
    host = jax.process_index()
    arrs = np.load(os.path.join(d, f"host_{host:03d}.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(state_like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaves.append(arrs[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)
