"""BENCH_5 — cold-start: rebuild vs snapshot load vs mmap snapshot load.

The PR-7 persistence story: a server that restarts should NOT pay the
eager-scoring index build again. ``sparse.snapshot`` persists every
layout (padded CSC, blocked, block-max) as raw little-endian files that
``np.memmap`` can view directly, so a cold start is: read manifest,
verify checksums, memmap the arrays, and upload straight through
``put_posting_arrays`` — no tokenization, no scoring, no re-blocking.

Each cell times three ways to reach a ready resident retriever from the
same corpus, then proves the loaded replicas are bit-identical to the
built one and still ship zero posting bytes per steady-state batch:

- ``build_s``      tokenized corpus -> ``build_index`` -> resident upload
- ``load_s``       snapshot -> eager ``np.fromfile`` read -> upload
- ``load_mmap_s``  snapshot -> ``np.memmap`` -> upload (pages fault in
                   lazily; checksum verification still reads each file
                   once, which is the honest floor for a VERIFIED load)

Acceptance (full run): ``load_mmap_s`` at least 5x faster than
``build_s`` on the 50k-doc cell, with the transfer audit zero.

Standalone::

    PYTHONPATH=src python -m benchmarks.coldstart [--fast]

CI cold-start smoke (two PROCESSES, so the load side shares nothing
with the save side but the snapshot directory)::

    python -m benchmarks.coldstart --fast --save  /tmp/snap
    python -m benchmarks.coldstart --fast --serve /tmp/snap

``--save`` builds one cell, snapshots it, and records the expected
retrieval results; ``--serve`` cold-starts from the snapshot in a fresh
interpreter, replays the recorded queries, and exits nonzero unless the
scores are bit-identical AND the steady-state batch shipped zero posting
bytes.
"""

from __future__ import annotations

import argparse
import gc
import json
import os
import time

import numpy as np

from repro.core import BM25Params, build_index
from repro.data.corpus import zipf_corpus

from .planner import _guarded_write, _profile_queries

GEOM = dict(block_size=64, frag=512, tile=2048)


def _resident(idx=None, *, device_index=None):
    from repro.serve import DeviceRetriever
    return DeviceRetriever(idx, regime="gathered", gather="resident",
                           plan="device", device_index=device_index,
                           **GEOM)


def _timed(fn, repeats: int):
    """min-of-N wall time; returns (best_s, last result)."""
    best, out = np.inf, None
    for _ in range(repeats):
        gc.collect()
        gc.disable()
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
        gc.enable()
    return best, out


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _, files in os.walk(path):
        total += sum(os.path.getsize(os.path.join(root, f)) for f in files)
    return total


def bench_cell(n_docs: int, n_vocab: int, workdir: str, *, batch: int = 8,
               k: int = 10, avg_len: int = 60, repeats: int = 3) -> dict:
    from repro.sparse import snapshot
    from repro.sparse.block_csr import TRANSFERS, reset_transfer_stats

    corpus = zipf_corpus(n_docs, n_vocab, avg_len=avg_len)
    rng = np.random.default_rng(3)
    queries = _profile_queries(rng, "head", n_vocab, batch, q_len=5)

    # rebuild path: what a restart costs WITHOUT persistence — tokenized
    # corpus back through eager scoring and the resident upload
    def _build():
        idx = build_index(corpus, n_vocab, params=BM25Params())
        return _resident(idx)
    build_s, dr_built = _timed(_build, repeats)
    ref_ids, ref_scores = dr_built.retrieve_batch(queries, k)

    snap = os.path.join(workdir, f"cell-{n_docs}x{n_vocab}")
    save_s, _ = _timed(lambda: dr_built.save(snap), 1)

    def _load(mmap: bool):
        ld = snapshot.load_device_index(snap, mmap=mmap)
        return _resident(device_index=ld)
    load_s, _ = _timed(lambda: _load(False), repeats)
    load_mmap_s, dr_mmap = _timed(lambda: _load(True), repeats)

    # the loaded replica must be indistinguishable from the built one:
    # bit-identical results AND the same zero-posting-bytes steady state
    exact = True
    for dr in (_load(False), dr_mmap):
        ids, scores = dr.retrieve_batch(queries, k)
        exact &= (np.array_equal(ids, ref_ids)
                  and np.array_equal(scores, ref_scores))
    reset_transfer_stats()
    dr_mmap.retrieve_batch(queries, k)
    post, desc = TRANSFERS.posting_bytes, TRANSFERS.descriptor_bytes

    return {
        "n_docs": n_docs, "n_vocab": n_vocab, "batch": batch, "k": k,
        "nnz": int(dr_built.index.nnz),
        "snapshot_bytes": _dir_bytes(snap),
        "build_s": round(build_s, 4),
        "save_s": round(save_s, 4),
        "load_s": round(load_s, 4),
        "load_mmap_s": round(load_mmap_s, 4),
        "speedup_load_vs_build": round(build_s / max(load_s, 1e-9), 2),
        "speedup_mmap_vs_build": round(build_s / max(load_mmap_s, 1e-9), 2),
        "loaded_results_bit_identical": bool(exact),
        "posting_bytes_per_batch_loaded": int(post),
        "descriptor_bytes_per_batch_loaded": int(desc),
    }


def run(*, fast: bool = False, workdir: str) -> dict:
    grid = ([(1_000, 2_000), (3_000, 5_000)] if fast else
            [(5_000, 5_000), (20_000, 10_000), (50_000, 10_000)])
    cells = [bench_cell(n, v, workdir, repeats=2 if n >= 20_000 else 3)
             for n, v in grid]
    largest = cells[-1]
    return {
        "cells": cells,
        "summary": {
            "largest_cell_docs": largest["n_docs"],
            "mmap_speedup_at_largest_cell":
                largest["speedup_mmap_vs_build"],
            "mmap_speedup_ge_5x_at_largest":
                largest["speedup_mmap_vs_build"] >= 5.0,
            "all_loaded_results_bit_identical": all(
                c["loaded_results_bit_identical"] for c in cells),
            "loaded_posting_bytes_all_zero": all(
                c["posting_bytes_per_batch_loaded"] == 0
                and c["descriptor_bytes_per_batch_loaded"] == 0
                for c in cells),
            "note": "loads run verify=True (checksums read every byte "
                    "once) — the honest cold-start floor. CPU wall "
                    "times; kernels in interpret mode.",
        },
    }


# --- two-process CI smoke -------------------------------------------------

_SMOKE = dict(n_docs=2_000, n_vocab=2_000, batch=8, k=10, avg_len=60)


def save_mode(path: str) -> None:
    """Process 1: build, snapshot, record the expected answers."""
    cfg = _SMOKE
    corpus = zipf_corpus(cfg["n_docs"], cfg["n_vocab"],
                         avg_len=cfg["avg_len"])
    idx = build_index(corpus, cfg["n_vocab"], params=BM25Params())
    dr = _resident(idx)
    rng = np.random.default_rng(3)
    queries = _profile_queries(rng, "head", cfg["n_vocab"], cfg["batch"],
                               q_len=5)
    ids, scores = dr.retrieve_batch(queries, cfg["k"])
    t0 = time.perf_counter()
    dr.save(path)
    print(f"coldstart_save,snapshot={path},"
          f"save_s={time.perf_counter() - t0:.4f},"
          f"bytes={_dir_bytes(path)}")
    with open(os.path.join(path, "expected.json"), "w") as f:
        json.dump({"k": cfg["k"],
                   "queries": [q.tolist() for q in queries],
                   "ids": ids.tolist(), "scores": scores.tolist()}, f)


def serve_mode(path: str) -> None:
    """Process 2: cold-start from the snapshot alone, prove exactness and
    the zero-byte steady state. Raises SystemExit on any mismatch."""
    from repro.sparse import snapshot
    from repro.sparse.block_csr import TRANSFERS, reset_transfer_stats

    with open(os.path.join(path, "expected.json")) as f:
        exp = json.load(f)
    queries = [np.asarray(q, dtype=np.int32) for q in exp["queries"]]

    t0 = time.perf_counter()
    ld = snapshot.load_device_index(path, mmap=True)
    dr = _resident(device_index=ld)
    load_s = time.perf_counter() - t0
    ids, scores = dr.retrieve_batch(queries, exp["k"])     # warm/compile
    reset_transfer_stats()
    ids, scores = dr.retrieve_batch(queries, exp["k"])
    post, desc = TRANSFERS.posting_bytes, TRANSFERS.descriptor_bytes
    print(f"coldstart_serve,load_mmap_s={load_s:.4f},"
          f"posting_bytes={post},descriptor_bytes={desc},"
          f"report={dr.health()['snapshot']}")
    if not np.array_equal(ids, np.asarray(exp["ids"])):
        raise SystemExit("cold-start ids differ from the saving process")
    if not np.array_equal(
            scores, np.asarray(exp["scores"], dtype=np.float32)):
        raise SystemExit("cold-start scores differ from the saving process")
    if post or desc:
        raise SystemExit(
            f"steady-state batch shipped bytes after cold start "
            f"(posting={post}, descriptor={desc}); residency is broken")
    print("coldstart_serve,ok=1 (bit-identical, zero posting bytes)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="tiny corpora (CI bench-smoke sized)")
    ap.add_argument("--force", action="store_true",
                    help="allow a --fast run to overwrite a full-scale "
                         "artifact")
    ap.add_argument("--out", default="BENCH_5.json")
    ap.add_argument("--save", metavar="DIR",
                    help="build the smoke cell and snapshot it to DIR")
    ap.add_argument("--serve", metavar="DIR",
                    help="cold-start from DIR in THIS process and verify")
    ap.add_argument("--workdir", default=None,
                    help="where sweep snapshots live (default: tempdir)")
    args = ap.parse_args()
    if args.save:
        save_mode(args.save)
        return
    if args.serve:
        serve_mode(args.serve)
        return

    import tempfile
    t0 = time.time()
    with tempfile.TemporaryDirectory() as tmp:
        result = run(fast=args.fast, workdir=args.workdir or tmp)
    for c in result["cells"]:
        print("bench5_coldstart," + ",".join(f"{k}={v}"
                                             for k, v in c.items()),
              flush=True)
    print("bench5_summary," + ",".join(
        f"{k}={v}" for k, v in result["summary"].items()))
    _guarded_write(args.out, result, fast=args.fast, force=args.force)
    print(f"done in {time.time() - t0:.1f}s -> {args.out}")


if __name__ == "__main__":
    main()
