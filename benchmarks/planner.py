"""BENCH_3 — cost-model planner vs forced regimes + residency transfer audit.

The PR-3 perf story has two claims:

1. **Planner**: ``scorer="auto"`` (``core.retrieval.plan_retrieval``) picks
   the winning regime per batch from the free work ratio ``nnz / Σ df``, so
   one retriever serves head-heavy tiny-vocab traffic (full-scan territory)
   and tail traffic on big corpora (gathered territory) without the
   operator hand-picking. Acceptance: auto within 10% of the best forced
   regime on EVERY cell, ≥2x better than the worst forced regime on at
   least one.
2. **Residency**: with the index HBM-resident (``DeviceIndex``), the
   steady-state batch ships ZERO posting bytes host→device — only O(U)
   fragment descriptors + query tables. The audit column reports measured
   bytes per batch before (host-gather) vs after (resident) from the
   ``sparse.block_csr.TRANSFERS`` instrumentation.

The sweep crosses corpus size × vocabulary size × query df profile; the
tiny-vocabulary head cells are the full-scan regime's home turf (work
ratio → 1), the big-vocab tail cells the gather's (work ratio ≫ 1). Each
cell also reports the implied break-even evidence; the summary emits a
``suggested_crossover`` (geometric mean of the boundary cells' work
ratios) — copy it into ``core.retrieval.DEFAULT_CROSSOVER`` after running
on TPU to re-calibrate (CPU wall times run the Pallas kernels in interpret
mode; compare paths relatively).

Written to ``BENCH_3.json`` by ``benchmarks/run.py`` or standalone:

    PYTHONPATH=src python -m benchmarks.planner [--fast]
"""

from __future__ import annotations

import argparse
import gc
import json
import time

import numpy as np

from repro.core import BM25Params, build_index
from repro.data.corpus import zipf_corpus


def _profile_queries(rng: np.random.Generator, profile: str, n_vocab: int,
                     batch: int, q_len: int) -> list[np.ndarray]:
    """head: top-df ranks (Zipf rank order = df order); tail: low-df ranks;
    dense: long queries over the WHOLE vocabulary — the batch's unique
    tokens approach |V| and Σ df approaches nnz (work ratio → 1), which is
    the full-scan regime's home turf."""
    if profile == "head":
        pool = np.arange(0, max(8, n_vocab // 100))
    elif profile == "dense":
        pool = np.arange(n_vocab)
        q_len = max(q_len, 4 * n_vocab // batch)
    else:
        pool = np.arange(n_vocab // 2, n_vocab)
    return [rng.choice(pool, size=q_len).astype(np.int32)
            for _ in range(batch)]


def bench_cell(n_docs: int, n_vocab: int, profile: str, *, batch: int = 8,
               k: int = 10, avg_len: int = 60, tile: int = 2048,
               repeats: int = 2) -> dict:
    from repro.serve import DeviceRetriever
    from repro.sparse.block_csr import TRANSFERS, reset_transfer_stats

    corpus = zipf_corpus(n_docs, n_vocab, avg_len=avg_len)
    idx = build_index(corpus, n_vocab, params=BM25Params())
    rng = np.random.default_rng(3)
    queries = _profile_queries(rng, profile, n_vocab, batch, q_len=5)

    # serving-default device scorer (host gather off-TPU, resident on TPU)
    dr = DeviceRetriever(idx, regime="auto", tile=tile)

    paths = {
        "auto": lambda: dr.retrieve_batch(queries, k),
        "blocked": lambda: dr.retrieve_batch(queries, k, regime="blocked"),
        "gathered": lambda: dr.retrieve_batch(queries, k,
                                              regime="gathered"),
    }
    for fn in paths.values():                    # compile/warm every path
        fn()
    paths["auto"]()                              # refresh auto's decision
    plan = dr.last_plan
    times = {name: np.inf for name in paths}
    for _ in range(repeats):                     # interleaved min-of-N:
        for name, fn in paths.items():           # robust to noise AND to
            gc.collect()                         # drift across the run;
            gc.disable()                         # GC pauses land between
            t0 = time.perf_counter()             # measurements, not inside
            fn()                                 # whichever path runs first
            times[name] = min(times[name], time.perf_counter() - t0)
            gc.enable()
    t_auto, t_blocked, t_gathered = (times["auto"], times["blocked"],
                                     times["gathered"])
    best, worst = min(t_blocked, t_gathered), max(t_blocked, t_gathered)

    # auto executes EXACTLY the planned regime's code path plus the
    # planning step, so its honest latency decomposes as
    # times[planned] + plan overhead; measure that overhead directly. The
    # raw auto re-measurement is reported alongside — any gap between the
    # two is scheduler noise on an identical computation, not planning
    # cost.
    from repro.core import plan_retrieval
    uniq = np.unique(np.concatenate(queries))
    t0 = time.perf_counter()
    for _ in range(100):
        plan_retrieval(dr.dindex.sum_df(uniq), dr.dindex.nnz)
    plan_s = (time.perf_counter() - t0) / 100
    t_auto_eff = times[plan.regime] + plan_s

    # transfer audit: posting bytes shipped per batch, before vs after
    # residency (small frag so the audit stays fast in interpret mode)
    host = DeviceRetriever(idx, regime="gathered", gather="host",
                           tile=tile, run_cache=0)
    host.retrieve_batch(queries, k)
    reset_transfer_stats()
    host.retrieve_batch(queries, k)
    bytes_host = TRANSFERS.posting_bytes
    res = DeviceRetriever(idx, regime="gathered", gather="resident",
                          plan="host", tile=tile)
    res.retrieve_batch(queries, k)
    reset_transfer_stats()
    res.retrieve_batch(queries, k)
    bytes_res, bytes_desc = (TRANSFERS.posting_bytes,
                             TRANSFERS.descriptor_bytes)
    # device-side planning: the fragment table is born on device, so the
    # steady-state batch ships NEITHER postings NOR descriptors — the
    # perf-trend gate (benchmarks.perf_gate) fails on any nonzero byte
    dev = DeviceRetriever(idx, regime="gathered", gather="resident",
                          plan="device", tile=tile)
    dev.retrieve_batch(queries, k)                # settle the nf bucket
    reset_transfer_stats()
    dev.retrieve_batch(queries, k)
    bytes_res_dev, bytes_desc_dev = (TRANSFERS.posting_bytes,
                                     TRANSFERS.descriptor_bytes)

    return {
        "n_docs": n_docs, "n_vocab": n_vocab, "batch": batch, "k": k,
        "profile": profile, "nnz": int(idx.nnz),
        "sum_df": int(plan.sum_df),
        "work_ratio_nnz_over_sum_df": round(plan.work_ratio, 2),
        "planned_regime": plan.regime,
        "planner_picked_winner": plan.regime == (
            "blocked" if t_blocked <= t_gathered else "gathered"),
        "auto_batch_s": round(t_auto_eff, 4),
        "auto_batch_s_remeasured": round(t_auto, 4),
        "plan_overhead_s": round(plan_s, 6),
        "blocked_batch_s": round(t_blocked, 4),
        "gathered_batch_s": round(t_gathered, 4),
        "auto_vs_best": round(t_auto_eff / max(best, 1e-9), 3),
        "auto_minus_best_s": round(t_auto_eff - best, 4),
        "worst_vs_auto": round(worst / max(t_auto_eff, 1e-9), 2),
        "posting_bytes_per_batch_host_gather": int(bytes_host),
        "posting_bytes_per_batch_resident": int(bytes_res),
        "descriptor_bytes_per_batch_resident": int(bytes_desc),
        "posting_bytes_per_batch_device_plan": int(bytes_res_dev),
        "descriptor_bytes_per_batch_device_plan": int(bytes_desc_dev),
    }


def run(*, fast: bool = False) -> dict:
    from repro.core.retrieval import DEFAULT_CROSSOVER
    if fast:
        grid = [(1_000, 50), (1_000, 2_000), (3_000, 5_000)]
    else:
        grid = [(2_000, 50), (5_000, 5_000), (20_000, 10_000),
                (50_000, 10_000)]
    cells = [bench_cell(n, v, profile,
                        repeats=4 if n >= 20_000 else 8)
             for n, v in grid
             for profile in (("head", "tail", "dense") if v <= 2_000
                             else ("head", "tail"))]

    # implied crossover: the boundary between cells the full scan wins and
    # cells the gather wins, in work-ratio space
    blocked_win = [c["work_ratio_nnz_over_sum_df"] for c in cells
                   if c["blocked_batch_s"] < c["gathered_batch_s"]]
    gathered_win = [c["work_ratio_nnz_over_sum_df"] for c in cells
                    if c["gathered_batch_s"] <= c["blocked_batch_s"]]
    if blocked_win and gathered_win:
        suggested = float(np.sqrt(max(blocked_win) * min(gathered_win)))
    elif gathered_win:
        suggested = 1.0                           # gather always won
    else:
        suggested = float(max(blocked_win)) * 2
    return {
        "cells": cells,
        "summary": {
            "crossover_used": DEFAULT_CROSSOVER,
            "suggested_crossover": round(suggested, 2),
            # auto_batch_s = planned regime's measured latency + measured
            # planning overhead (auto RUNS that exact code path; the raw
            # re-measurement is auto_batch_s_remeasured). The 2ms floor
            # absorbs residual host noise on single-digit-ms cells.
            "auto_within_10pct_of_best_everywhere": all(
                c["auto_vs_best"] <= 1.10 or c["auto_minus_best_s"] <= 0.002
                for c in cells),
            "planner_picked_winner_everywhere": all(
                c["planner_picked_winner"] for c in cells),
            "auto_beats_worst_regime_2x_somewhere": any(
                c["worst_vs_auto"] >= 2.0 for c in cells),
            "resident_posting_bytes_all_zero": all(
                c["posting_bytes_per_batch_resident"] == 0 for c in cells),
            # plan="device": zero posting AND zero descriptor bytes — the
            # fully-device-resident steady state the perf gate enforces
            "device_plan_bytes_all_zero": all(
                c["posting_bytes_per_batch_device_plan"] == 0
                and c["descriptor_bytes_per_batch_device_plan"] == 0
                for c in cells),
            "note": "CPU wall times; Pallas kernels run in interpret mode "
                    "— compare paths relatively. Re-run on TPU and copy "
                    "suggested_crossover into "
                    "core.retrieval.DEFAULT_CROSSOVER to re-calibrate.",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="tiny corpora (CI bench-smoke sized)")
    ap.add_argument("--out", default="BENCH_3.json")
    args = ap.parse_args()
    t0 = time.time()
    result = run(fast=args.fast)
    for c in result["cells"]:
        print("bench3_planner," + ",".join(f"{k}={v}"
                                           for k, v in c.items()),
              flush=True)
    print("bench3_summary," + ",".join(
        f"{k}={v}" for k, v in result["summary"].items()))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"done in {time.time() - t0:.1f}s -> {args.out}")


if __name__ == "__main__":
    main()
