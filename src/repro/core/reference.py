"""Reference retrievers.

1. :class:`ScipyBM25` — a faithful port of the BM25S retrieval path exactly
   as the paper describes it: eager scores in a ``scipy.sparse.csc_matrix``
   of shape ``|C| × |V|`` (docs × tokens, CSC ⇒ token columns contiguous);
   query = slice the query-token columns + sum across the token dimension;
   top-k via ``np.argpartition`` (average O(n) selection, Quickselect-style).

2. :class:`RankBM25Baseline` — a faithful reimplementation of the
   ``rank_bm25.BM25Okapi`` scoring loop the paper benchmarks against:
   *lazy* scoring with a per-document Python dict of term frequencies and a
   per-query-token Python-loop gather. This is the baseline column of
   Table 1 and deliberately keeps rank_bm25's per-token
   ``[doc.get(q, 0) for doc in corpus]`` list comprehension — that loop *is*
   what BM25S's eager scoring removes.

Both are host-side and used by tests (exactness) and benchmarks (Table 1).
"""

from __future__ import annotations

from typing import Sequence

import numpy as np
import scipy.sparse as sp

from .index import BM25Index
from .variants import BM25Params, get_variant


class ScipyBM25:
    """Paper-faithful scipy CSC retrieval over an eager :class:`BM25Index`."""

    def __init__(self, index: BM25Index):
        self.index = index
        df = np.diff(index.indptr)
        tok = np.repeat(np.arange(index.n_vocab, dtype=np.int64), df)
        # docs × tokens so that CSC stores each token's postings contiguously
        self.matrix = sp.csc_matrix(
            (index.scores, (index.doc_ids, tok)),
            shape=(index.doc_lens.size, index.n_vocab),
        )
        self.nonoccurrence = index.nonoccurrence

    def score(self, query_tokens: np.ndarray) -> np.ndarray:
        """Exact BM25 scores for every document ("slice rows ... and sum")."""
        q = query_tokens[query_tokens >= 0]
        if q.size == 0:
            return np.zeros(self.matrix.shape[0], dtype=np.float32)
        sliced = self.matrix[:, q]                      # |C| × |Q|
        scores = np.asarray(sliced.sum(axis=1)).ravel()  # sum token dimension
        # §2.1: add the query-constant nonoccurrence shift back (exactness)
        scores += float(self.nonoccurrence[q].sum())
        return scores.astype(np.float32)

    def retrieve(self, query_tokens: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
        scores = self.score(query_tokens)
        k = min(k, scores.size)
        # average-O(n) selection, then O(k log k) ordering — §2 "Top-k selection"
        part = np.argpartition(scores, -k)[-k:]
        order = np.argsort(-scores[part], kind="stable")
        idx = part[order]
        return idx + self.index.doc_offset, scores[idx]


class RankBM25Baseline:
    """rank_bm25.BM25Okapi-equivalent lazy scorer (the Table 1 baseline)."""

    def __init__(self, corpus_tokens: Sequence[np.ndarray],
                 params: BM25Params | None = None):
        self.params = params or BM25Params(method="robertson")
        self.variant = get_variant(self.params.method)
        self.corpus_size = len(corpus_tokens)
        self.doc_freqs: list[dict[int, int]] = []
        self.doc_len = np.array([t.size for t in corpus_tokens], dtype=np.float64)
        self.avgdl = float(self.doc_len.mean()) if self.corpus_size else 0.0
        df: dict[int, int] = {}
        for toks in corpus_tokens:
            freqs: dict[int, int] = {}
            for t in toks.tolist():
                freqs[t] = freqs.get(t, 0) + 1
            self.doc_freqs.append(freqs)
            for t in freqs:
                df[t] = df.get(t, 0) + 1
        self.idf = {
            t: float(self.variant.idf(np.asarray([d], dtype=np.float64),
                                      self.corpus_size)[0])
            for t, d in df.items()
        }

    def get_scores(self, query_tokens: np.ndarray) -> np.ndarray:
        """Lazy per-query scoring — rank_bm25's exact control flow."""
        p = self.params
        score = np.zeros(self.corpus_size)
        for q in query_tokens.tolist():
            if q not in self.idf:
                continue
            # the O(|C|) Python loop BM25S eliminates:
            q_freq = np.array([doc.get(q, 0) for doc in self.doc_freqs],
                              dtype=np.float64)
            denom = q_freq + p.k1 * (1.0 - p.b + p.b * self.doc_len / self.avgdl)
            num = q_freq * (p.k1 + 1.0) if self.variant.name in ("atire",) \
                else q_freq
            score += self.idf[q] * num / denom
        return score

    def retrieve(self, query_tokens: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
        scores = self.get_scores(query_tokens)
        k = min(k, scores.size)
        idx = np.argsort(-scores, kind="stable")[:k]   # rank_bm25 sorts fully
        return idx, scores[idx]


def dense_oracle_scores(corpus_tokens: Sequence[np.ndarray], n_vocab: int,
                        query_tokens: np.ndarray,
                        params: BM25Params) -> np.ndarray:
    """Brute-force lazy scorer straight from the formulas (tests only)."""
    variant = get_variant(params.method)
    n_docs = len(corpus_tokens)
    dl = np.array([t.size for t in corpus_tokens], dtype=np.float64)
    l_avg = float(dl.mean())
    df = np.zeros(n_vocab, dtype=np.float64)
    for toks in corpus_tokens:
        if toks.size:
            df[np.unique(toks)] += 1
    scores = np.zeros(n_docs, dtype=np.float64)
    for q in query_tokens.tolist():
        if q < 0 or df[q] == 0:
            continue
        for d, toks in enumerate(corpus_tokens):
            tf = float((toks == q).sum())
            if tf > 0:
                scores[d] += float(variant.score(
                    np.asarray([tf]), np.asarray([df[q]]), n_docs,
                    np.asarray([dl[d]]), l_avg, params)[0])
            else:
                scores[d] += float(variant.nonoccurrence(
                    np.asarray([df[q]]), n_docs, params)[0])
    return scores
