"""Synthetic data pipelines: corpora with planted relevance, LM batches,
procedural graphs + neighbor sampling, recsys click logs."""

from .corpus import SyntheticCorpus, zipf_corpus
from .lm import lm_batches
from .graphs import (batched_molecules, neighbor_sample, random_graph)
from .clicklogs import ctr_batches, seq_rec_batches

__all__ = ["SyntheticCorpus", "zipf_corpus", "lm_batches", "random_graph",
           "neighbor_sample", "batched_molecules", "ctr_batches",
           "seq_rec_batches"]
