"""CI perf gate over BENCH_3 (planner), BENCH_6 (reorder), BENCH_7 (serving).

Compares a candidate bench JSON (PR head) against a baseline run of the
SAME bench (the PR's base ref re-run on the same runner, or the committed
``BENCH_baseline.json`` when no base checkout is available) and FAILS the
job when either:

* any planner-cell latency column regresses by more than ``--max-ratio``
  (default 1.25 = +25%) AND by more than ``--abs-floor-s`` absolute
  seconds (wall-clock noise floor — single-digit-ms cells jitter far more
  than 25% on shared CI runners), or
* any candidate cell ships nonzero steady-state bytes on a resident
  channel: posting bytes on the resident path, or posting/descriptor
  bytes under ``plan="device"`` — the residency invariants must hold at
  EVERY scale the sweep touches, not just in tier-1's toy cells, or
* the candidate's fault-free degraded-mode cell reports
  ``degradations_per_batch_healthy > 0`` — a healthy baseline that walks
  the fallback ladder is a planner/capability bug being silently
  absorbed, not fault tolerance working, or
* (BENCH_6 cells) the doc-id-reordering ``skip_rate_gain`` at a fixed
  cell drops by more than 50% relative to the baseline's gain — the
  clustering stopped tightening block-max bounds — or a reordered cell
  ships MORE steady-state transfer bytes than the random-order cell
  (posting bytes must be equal; descriptor bytes may legitimately shrink
  under clustering but never grow — the id remap must stay a host gather
  on the winner board, not a device transfer). Both checks are
  schema-tolerant: baselines predating BENCH_6 simply have no such
  columns and are not penalized, or
* (BENCH_7 serving cells) the micro-batching front-end's request-latency
  p99 regresses by more than ``--max-ratio`` at a fixed (arrival rate,
  batch deadline) load point, a serving cell stops asserting
  bit-identity against direct ``retrieve_batch`` calls, or the frontend
  zero-copy audit reports any steady-state posting/descriptor bytes.
  Schema-tolerant like BENCH_6: baselines without serving cells are not
  penalized, but a baseline WITH serving cells whose grid no longer
  intersects the candidate's fails (vacuous-gate protection), or
* (BENCH_8 overload cells) the protected front-end's ``goodput_ratio``
  (goodput / measured capacity, machine-independent) at a fixed
  ``rate_factor`` drops by more than 25% relative to the baseline's
  ratio — the admission gate stopped protecting throughput. Candidate-
  side hard gates, baseline or not: a nonzero ``shed_leak`` (a shed
  request that still consumed device work) is a LEAK; ``dominates``
  false past saturation, a dropped ``bit_identical`` assertion, or
  ``p99_bounded`` false (admitted-p99 growing with offered load — the
  gate stopped bounding the queue) are BROKEN. Schema-tolerant:
  baselines without an ``overload`` section are not penalized, but a
  baseline WITH overload cells whose ``rate_factor`` grid no longer
  intersects the candidate's fails (vacuous-gate protection).

Cells are matched on ``(n_docs, n_vocab, profile, batch, k)``; cells or
columns present on only one side are reported as ``new``/``dropped`` but
do not regress-fail (schema drift across refs is expected — the
comparison covers the intersection). An EMPTY intersection, however, is
itself a failure: with zero comparable latency cells the gate would pass
vacuously, which is exactly how a sweep-grid change would otherwise
silently disable it (``--allow-empty-intersection`` is the explicit
escape hatch for an intentional grid migration — use it in the PR that
changes the grid and refreshes the baseline, then drop it). The full
comparison lands as a markdown table, appended to ``--summary`` (pass
``"$GITHUB_STEP_SUMMARY"`` in CI) and echoed to stdout.

``--inject-slowdown F`` multiplies every candidate latency by ``F``
before comparing — the dry-run switch that DEMONSTRATES the gate trips on
a synthetic >25% regression without committing one (the baseline compared
against its own slowed-down copy; any pair with matching grids works):

    PYTHONPATH=src python -m benchmarks.perf_gate \
        --baseline BENCH_baseline.json --candidate BENCH_baseline.json \
        --inject-slowdown 1.5   # must exit 1

To refresh the committed baseline after an INTENTIONAL perf change:
``PYTHONPATH=src python -m benchmarks.planner --fast --out
BENCH_baseline.json`` and commit the result with the PR that changes the
performance.
"""

from __future__ import annotations

import argparse
import json
import sys

CELL_KEY = ("n_docs", "n_vocab", "profile", "batch", "k")

LATENCY_COLS = ("auto_batch_s", "blocked_batch_s", "gathered_batch_s",
                "resident_batch_s", "pruned_batch_s",
                # BENCH_6 (doc-id reordering) cells
                "pruned_batch_s_none", "pruned_batch_s_signature")

# (column, human label) pairs that must be exactly zero on the candidate
RESIDENCY_COLS = (
    ("posting_bytes_per_batch_resident", "resident posting bytes"),
    ("posting_bytes_per_batch_device_plan", "device-plan posting bytes"),
    ("descriptor_bytes_per_batch_device_plan",
     "device-plan descriptor bytes"),
    ("posting_bytes_per_batch_pruned", "pruned posting bytes"),
    ("posting_bytes_per_batch_pruned_device_plan",
     "pruned device-plan posting bytes"),
    ("descriptor_bytes_per_batch_pruned_device_plan",
     "pruned device-plan descriptor bytes"),
)

# deterministic-for-fixed-seed counters that must not COLLAPSE: unlike wall
# clock they carry no runner noise, so a big drop means the pruning logic
# stopped cutting work (e.g. bounds silently loosened), even if latency
# hides it in noise. Fails when candidate < (1 - max drop) × baseline.
SKIP_RATE_COL = "pruned_skip_rate"
SKIP_RATE_MAX_DROP = 0.5

# BENCH_6 (doc-id reordering): the skip-rate GAIN over random order is the
# whole point of the reorder pass — a candidate keeping >50% of the
# baseline's gain at a fixed cell passes; losing more (or going negative)
# means the clustering stopped tightening bounds. Same no-noise rationale
# as the skip-rate gate: the counter is deterministic for a fixed seed.
GAIN_COL = "skip_rate_gain"
GAIN_MAX_DROP = 0.5

# BENCH_6 transfer-byte direction: reordered serving must never move MORE
# bytes than random-order serving (the id remap is a host gather, so any
# extra device traffic is a leak). Posting bytes must be exactly equal;
# descriptor bytes may be LOWER under reordering — clustering concentrates
# each token's postings into fewer blocks, shrinking the fragment table
# (a legitimate win, e.g. the 50k-doc/batch-4 full cell halves it) — and
# they are legitimately nonzero under host planning, so the invariant is
# reordered <= none, not zero
BYTE_PAIRS = (
    ("posting_bytes_per_batch_none", "posting_bytes_per_batch_reordered",
     "posting bytes", "eq"),
    ("descriptor_bytes_per_batch_none",
     "descriptor_bytes_per_batch_reordered", "descriptor bytes", "le"),
)

# healthy-baseline ladder activity (PR-6): the planner sweep runs with no
# fault injected, so ANY nonzero degradation rate means the entry regime
# is failing in production-shaped traffic and the fallback ladder is
# silently absorbing a real bug. Candidate-side only: old baselines
# predate the column (schema drift tolerated, like every other column).
DEGRADED_COL = "degradations_per_batch_healthy"

# BENCH_7 (serving front-end): micro-batching cells are matched on the
# load point — (arrival rate, batch deadline) — and the gated column is
# the frontend's request-latency p99: the SLO number the deadline knob
# exists to protect. Same ratio threshold as the planner latency cells,
# with a millisecond floor (p99 of a finite request sample jitters).
# Candidate-side hard gates, baseline or not: a serving cell that stops
# asserting bit-identity, and steady-state bytes on the zero-copy audit.
SERVING_KEY = ("rate_qps", "deadline_ms")
SERVING_P99_COL = "frontend_p99_ms"
SERVING_ABS_FLOOR_MS = 2.0

# BENCH_8 (overload sweep): cells are matched on rate_factor — offered
# load as a multiple of MEASURED capacity — so the comparison survives
# runner-speed differences between refs (absolute qps does not). The
# gated column is goodput_ratio = goodput / capacity, also
# machine-independent. shed_leak / dominates / bit_identical /
# p99_bounded are candidate-side hard gates: they encode the overload
# contract itself (a shed request must cost zero device work; protection
# must strictly beat no-protection past saturation; admitted requests
# stay bit-identical; admitted p99 must not grow with offered load), so
# a candidate violating them fails even against an old baseline.
OVERLOAD_KEY = ("rate_factor",)
GOODPUT_COL = "goodput_ratio"
GOODPUT_MAX_DROP = 0.25


def cell_key(cell: dict) -> tuple:
    return tuple(cell.get(k) for k in CELL_KEY)


def compare(baseline: dict, candidate: dict, *, max_ratio: float = 1.25,
            abs_floor_s: float = 0.002,
            allow_empty_intersection: bool = False
            ) -> tuple[list[dict], list[str]]:
    """Diff two planner-sweep results -> (table rows, failure messages)."""
    base_cells = {cell_key(c): c for c in baseline.get("cells", [])}
    had_base = bool(base_cells)
    rows, failures, matched = [], [], 0
    if baseline.get("fast") and not candidate.get("fast"):
        # one-directional: smoke-vs-smoke (the CI bench job) and
        # full-vs-full are both legitimate; judging a full-scale candidate
        # against CI-smoke-sized numbers is how a clobbered BENCH_* file
        # would silently poison every later comparison
        failures.append(
            "baseline artifact is marked \"fast\": true (a --fast CI-smoke "
            "run) but the candidate is full-scale — refresh the baseline "
            "with a full-scale run before gating against it")
    for cand in candidate.get("cells", []):
        key = cell_key(cand)
        base = base_cells.pop(key, None)
        for col in LATENCY_COLS:
            if col not in cand:
                continue
            row = {"cell": key, "metric": col, "candidate_s": cand[col]}
            if base is None or col not in base:
                row.update(baseline_s=None, ratio=None, status="new")
            else:
                matched += 1
                ratio = cand[col] / max(base[col], 1e-9)
                regressed = (ratio > max_ratio
                             and cand[col] - base[col] > abs_floor_s)
                row.update(baseline_s=base[col], ratio=round(ratio, 3),
                           status="REGRESSED" if regressed else "ok")
                if regressed:
                    failures.append(
                        f"{key} {col}: {base[col]:.4f}s -> "
                        f"{cand[col]:.4f}s ({ratio:.2f}x > "
                        f"{max_ratio:.2f}x)")
            rows.append(row)
        if SKIP_RATE_COL in cand or SKIP_RATE_COL in (base or {}):
            # a candidate that silently STOPS reporting the counter is the
            # most total skip-rate collapse — treat the missing column as
            # rate 0 so it trips, instead of vacuously passing
            rate = cand.get(SKIP_RATE_COL, 0.0)
            base_rate = (base or {}).get(SKIP_RATE_COL)
            row = {"cell": key, "metric": SKIP_RATE_COL,
                   "candidate_s": rate}
            if base_rate is None:
                row.update(baseline_s=None, ratio=None, status="new")
            else:
                collapsed = (base_rate > 0
                             and rate < (1.0 - SKIP_RATE_MAX_DROP)
                             * base_rate)
                row.update(baseline_s=base_rate,
                           ratio=round(rate / max(base_rate, 1e-9), 3),
                           status="COLLAPSED" if collapsed else "ok")
                if collapsed:
                    failures.append(
                        f"{key} {SKIP_RATE_COL}: {base_rate:.4f} -> "
                        f"{rate:.4f} (skip-rate collapse: >"
                        f"{SKIP_RATE_MAX_DROP:.0%} drop — the pruning "
                        f"logic stopped cutting work)")
            rows.append(row)
        if GAIN_COL in cand or GAIN_COL in (base or {}):
            # like the skip-rate gate: a candidate that stops reporting
            # the gain counts as gain 0 and trips, never passes vacuously
            gain = cand.get(GAIN_COL, 0.0)
            base_gain = (base or {}).get(GAIN_COL)
            row = {"cell": key, "metric": GAIN_COL, "candidate_s": gain}
            if base_gain is None:
                row.update(baseline_s=None, ratio=None, status="new")
            else:
                collapsed = (base_gain > 0
                             and gain < (1.0 - GAIN_MAX_DROP) * base_gain)
                row.update(baseline_s=base_gain,
                           ratio=round(gain / max(base_gain, 1e-9), 3),
                           status="COLLAPSED" if collapsed else "ok")
                if collapsed:
                    failures.append(
                        f"{key} {GAIN_COL}: {base_gain:.4f} -> "
                        f"{gain:.4f} (reorder gain collapse: >"
                        f"{GAIN_MAX_DROP:.0%} relative drop — doc-id "
                        f"clustering stopped tightening the block-max "
                        f"bounds)")
            rows.append(row)
        for none_col, reord_col, label, rel in BYTE_PAIRS:
            if none_col not in cand and reord_col not in cand:
                continue
            b_none = cand.get(none_col, 0)
            b_reord = cand.get(reord_col, 0)
            ok = b_reord == b_none if rel == "eq" else b_reord <= b_none
            rows.append({"cell": key, "metric": reord_col,
                         "candidate_s": b_reord, "baseline_s": b_none,
                         "ratio": None,
                         "status": "ok" if ok else "LEAK"})
            if not ok:
                failures.append(
                    f"{key}: reordered {label} ({b_reord}) "
                    f"{'!=' if rel == 'eq' else '>'} random-order "
                    f"{label} ({b_none}) per steady-state batch — the id "
                    f"remap must stay a host gather, not a device "
                    f"transfer")
        for col, label in RESIDENCY_COLS:
            bytes_shipped = cand.get(col, 0)
            rows.append({"cell": key, "metric": col,
                         "candidate_s": bytes_shipped, "baseline_s": 0,
                         "ratio": None,
                         "status": "LEAK" if bytes_shipped else "ok"})
            if bytes_shipped:
                failures.append(
                    f"{key}: {bytes_shipped} {label} per steady-state "
                    f"batch (must be 0)")
    for key, cell in base_cells.items():
        rows.append({"cell": key, "metric": "-", "candidate_s": None,
                     "baseline_s": None, "ratio": None, "status": "dropped"})
        if SKIP_RATE_COL in cell or GAIN_COL in cell:
            # plain latency cells may drift across refs (schema evolution);
            # a PRUNED/REORDER cell disappearing wholesale is the
            # silent-disable path of the skip-rate/gain gates, so it fails
            # like a collapse
            failures.append(
                f"{key}: pruned/reorder cell present in the baseline is "
                f"missing from the candidate — the skip-rate/gain gate "
                f"would be vacuous (keep the sweep cells, or refresh the "
                f"baseline in the PR that intentionally changes them)")
    degraded = candidate.get("degraded") or {}
    if DEGRADED_COL in degraded or DEGRADED_COL in candidate.get(
            "summary", {}):
        rate = float(degraded.get(DEGRADED_COL,
                     candidate.get("summary", {}).get(DEGRADED_COL, 0.0)))
        dkey = tuple(degraded.get(k) for k in CELL_KEY)
        rows.append({"cell": dkey, "metric": DEGRADED_COL,
                     "candidate_s": rate, "baseline_s": 0, "ratio": None,
                     "status": "DEGRADED" if rate > 0 else "ok"})
        if rate > 0:
            failures.append(
                f"{dkey}: {DEGRADED_COL}={rate} in a fault-free baseline "
                f"run (must be 0) — the entry regime is failing and the "
                f"fallback ladder is absorbing it (trail sample: "
                f"{degraded.get('degraded_trail')})")
    # -- BENCH_7 serving cells (frontend p99 at fixed load points) -------
    base_serv = {tuple(c.get(k) for k in SERVING_KEY): c
                 for c in (baseline.get("serving") or {}).get("cells", [])}
    had_serv_base = bool(base_serv)
    serv_matched = 0
    for cand in (candidate.get("serving") or {}).get("cells", []):
        key = tuple(cand.get(k) for k in SERVING_KEY)
        base = base_serv.pop(key, None)
        p99 = cand.get(SERVING_P99_COL)
        row = {"cell": key, "metric": SERVING_P99_COL, "candidate_s": p99}
        if base is None or SERVING_P99_COL not in base:
            row.update(baseline_s=None, ratio=None, status="new")
        else:
            serv_matched += 1
            base_p99 = base[SERVING_P99_COL]
            ratio = p99 / max(base_p99, 1e-9)
            regressed = (ratio > max_ratio
                         and p99 - base_p99 > SERVING_ABS_FLOOR_MS)
            row.update(baseline_s=base_p99, ratio=round(ratio, 3),
                       status="REGRESSED" if regressed else "ok")
            if regressed:
                failures.append(
                    f"serving {key} {SERVING_P99_COL}: {base_p99:.2f}ms "
                    f"-> {p99:.2f}ms ({ratio:.2f}x > {max_ratio:.2f}x) "
                    f"at a fixed (rate, deadline) load point")
        rows.append(row)
        if not cand.get("bit_identical", False):
            rows.append({"cell": key, "metric": "bit_identical",
                         "candidate_s": False, "baseline_s": True,
                         "ratio": None, "status": "BROKEN"})
            failures.append(
                f"serving {key}: bit_identical is not asserted — "
                f"frontend batches must replay bit-for-bit against "
                f"direct retrieve_batch calls")
    zero_copy = candidate.get("zero_copy")
    if zero_copy is not None:
        for col in ("posting_bytes", "descriptor_bytes"):
            shipped = zero_copy.get(col, 0)
            rows.append({"cell": ("frontend-zero-copy",), "metric": col,
                         "candidate_s": shipped, "baseline_s": 0,
                         "ratio": None,
                         "status": "LEAK" if shipped else "ok"})
            if shipped:
                failures.append(
                    f"frontend zero-copy audit: {shipped} {col} per "
                    f"steady-state batch (must be 0)")
    if (had_serv_base and serv_matched == 0
            and not allow_empty_intersection):
        failures.append(
            "no serving cell matched between baseline and candidate — "
            "the frontend p99 gate would be vacuous. Keep the "
            "(rate, deadline) grid stable or pass "
            "--allow-empty-intersection in the grid-migration PR.")
    # -- BENCH_8 overload cells (goodput under admission control) --------
    base_over = {tuple(c.get(k) for k in OVERLOAD_KEY): c
                 for c in (baseline.get("overload") or {}).get("cells", [])}
    had_over_base = bool(base_over)
    over_matched = 0
    cand_over = candidate.get("overload") or {}
    for cand in cand_over.get("cells", []):
        key = tuple(cand.get(k) for k in OVERLOAD_KEY)
        base = base_over.pop(key, None)
        ratio_val = cand.get(GOODPUT_COL)
        row = {"cell": ("overload",) + key, "metric": GOODPUT_COL,
               "candidate_s": ratio_val}
        if base is None or GOODPUT_COL not in base:
            row.update(baseline_s=None, ratio=None, status="new")
        else:
            over_matched += 1
            base_ratio = base[GOODPUT_COL]
            rel = (ratio_val or 0.0) / max(base_ratio, 1e-9)
            dropped = (base_ratio > 0
                       and (ratio_val or 0.0)
                       < (1.0 - GOODPUT_MAX_DROP) * base_ratio)
            row.update(baseline_s=base_ratio, ratio=round(rel, 3),
                       status="COLLAPSED" if dropped else "ok")
            if dropped:
                failures.append(
                    f"overload {key} {GOODPUT_COL}: {base_ratio:.3f} -> "
                    f"{ratio_val:.3f} (>{GOODPUT_MAX_DROP:.0%} goodput "
                    f"drop at a fixed rate_factor — the admission gate "
                    f"stopped protecting throughput under overload)")
        rows.append(row)
        leak = cand.get("shed_leak", 0)
        rows.append({"cell": ("overload",) + key, "metric": "shed_leak",
                     "candidate_s": leak, "baseline_s": 0, "ratio": None,
                     "status": "LEAK" if leak else "ok"})
        if leak:
            failures.append(
                f"overload {key}: shed_leak={leak} — a shed request "
                f"consumed device work (admission must reject BEFORE "
                f"the request reaches the batch former)")
        if cand.get("dominates") is False:
            rows.append({"cell": ("overload",) + key,
                         "metric": "dominates", "candidate_s": False,
                         "baseline_s": True, "ratio": None,
                         "status": "BROKEN"})
            failures.append(
                f"overload {key}: protected p99 does not dominate the "
                f"unprotected frontend past saturation — shedding is "
                f"not buying the latency it exists to buy")
        if not cand.get("bit_identical", False):
            rows.append({"cell": ("overload",) + key,
                         "metric": "bit_identical", "candidate_s": False,
                         "baseline_s": True, "ratio": None,
                         "status": "BROKEN"})
            failures.append(
                f"overload {key}: bit_identical is not asserted — "
                f"admitted requests must replay bit-for-bit against "
                f"direct retrieve_batch calls")
    if cand_over.get("cells") and cand_over.get("p99_bounded") is False:
        rows.append({"cell": ("overload",), "metric": "p99_bounded",
                     "candidate_s": False, "baseline_s": True,
                     "ratio": None, "status": "BROKEN"})
        failures.append(
            "overload: admitted-request p99 grows with offered load — "
            "the admission gate is not bounding the queue (CoDel target "
            "or bucket rate mistuned)")
    if (had_over_base and over_matched == 0
            and not allow_empty_intersection):
        failures.append(
            "no overload cell matched between baseline and candidate — "
            "the goodput gate would be vacuous. Keep the rate_factor "
            "grid stable or pass --allow-empty-intersection in the "
            "grid-migration PR.")
    if matched == 0 and had_base and not allow_empty_intersection:
        # zero comparable cells would make the latency gate pass
        # VACUOUSLY — the silent-disable path a sweep-grid change opens
        failures.append(
            "no latency cell matched between baseline and candidate — "
            "the latency gate would be vacuous. Keep the sweep grid "
            "stable, refresh BENCH_baseline.json, or pass "
            "--allow-empty-intersection in the grid-migration PR.")
    return rows, failures


def to_markdown(rows: list[dict], failures: list[str], *,
                max_ratio: float) -> str:
    lines = [
        "## Planner perf-trend gate",
        "",
        f"Threshold: fail above {max_ratio:.2f}x per latency cell; any "
        "nonzero resident posting/descriptor bytes fails; a "
        f">{SKIP_RATE_MAX_DROP:.0%} pruned-skip-rate drop at a fixed "
        f"cell fails; a >{GAIN_MAX_DROP:.0%} relative drop of the "
        "reorder skip-rate gain fails; reordered transfer bytes must "
        "not exceed random-order bytes (postings exactly equal); any "
        "healthy-baseline ladder degradation fails; a serving-cell "
        f"frontend p99 regression above {max_ratio:.2f}x at a fixed "
        "(rate, deadline) load point fails, as does a dropped "
        "bit-identity assertion or any frontend zero-copy byte leak; "
        f"an overload-cell goodput_ratio drop above "
        f"{GOODPUT_MAX_DROP:.0%} at a fixed rate_factor fails, as does "
        "any shed_leak (shed request consuming device work), a lost "
        "p99-dominance past saturation, or unbounded admitted p99.",
        "",
        "| cell (docs, vocab, profile, B, k) | metric | baseline | "
        "candidate | ratio | status |",
        "|---|---|---|---|---|---|",
    ]
    for r in rows:
        fmt = (lambda v: "-" if v is None
               else (f"{v:.4f}" if isinstance(v, float) else str(v)))
        status = r["status"]
        if status in ("REGRESSED", "LEAK", "COLLAPSED", "DEGRADED",
                      "BROKEN"):
            status = f"**{status}**"
        lines.append(
            f"| {r['cell']} | {r['metric']} | {fmt(r['baseline_s'])} | "
            f"{fmt(r['candidate_s'])} | {fmt(r['ratio'])} | {status} |")
    lines.append("")
    if failures:
        lines.append(f"### ❌ {len(failures)} gate failure(s)")
        lines.extend(f"- {f}" for f in failures)
    else:
        lines.append("### ✅ no regressions, residency invariants hold")
    return "\n".join(lines) + "\n"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="baseline BENCH_3-format JSON")
    ap.add_argument("--candidate", required=True,
                    help="candidate BENCH_3-format JSON (PR head)")
    ap.add_argument("--max-ratio", type=float, default=1.25,
                    help="fail when candidate/baseline exceeds this "
                         "(default 1.25 = +25%%)")
    ap.add_argument("--abs-floor-s", type=float, default=0.002,
                    help="ignore regressions smaller than this many "
                         "absolute seconds (CI noise floor)")
    ap.add_argument("--summary", default=None,
                    help="append the markdown table to this file "
                         "(e.g. \"$GITHUB_STEP_SUMMARY\")")
    ap.add_argument("--inject-slowdown", type=float, default=None,
                    help="dry run: multiply candidate latencies by this "
                         "factor to DEMONSTRATE the gate trips")
    ap.add_argument("--allow-empty-intersection", action="store_true",
                    help="do not fail when zero cells match (ONLY for an "
                         "intentional sweep-grid migration PR)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.candidate) as f:
        candidate = json.load(f)
    if args.inject_slowdown is not None:
        for c in candidate.get("cells", []):
            for col in LATENCY_COLS:
                if col in c:
                    c[col] = c[col] * args.inject_slowdown
        for c in (candidate.get("serving") or {}).get("cells", []):
            if SERVING_P99_COL in c:
                c[SERVING_P99_COL] = (c[SERVING_P99_COL]
                                      * args.inject_slowdown)
        for c in (candidate.get("overload") or {}).get("cells", []):
            # a slower stack admits the same load but completes less of
            # it — model the slowdown as a proportional goodput loss so
            # the dry run demonstrates the goodput gate too
            if GOODPUT_COL in c:
                c[GOODPUT_COL] = c[GOODPUT_COL] / args.inject_slowdown

    rows, failures = compare(
        baseline, candidate, max_ratio=args.max_ratio,
        abs_floor_s=args.abs_floor_s,
        allow_empty_intersection=args.allow_empty_intersection)
    md = to_markdown(rows, failures, max_ratio=args.max_ratio)
    print(md)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(md)
    if failures:
        print(f"perf gate FAILED ({len(failures)} finding(s))",
              file=sys.stderr)
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
