"""Kernel-path microbenchmarks (CPU; kernels run in interpret mode).

Times the blocked batched-scoring formulation (DESIGN.md §3.3, pure-jnp
lowering of the kernel contraction) against the paper-faithful per-query
gather path, as batch size grows — the arithmetic-intensity argument for
the beyond-paper path. Wall times here are CPU-indicative only; the TPU
projection lives in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (BM25Params, DeviceIndex, build_index, pad_queries,
                        score_batch, suggest_p_max)
from repro.data.corpus import zipf_corpus, zipf_queries
from repro.kernels.ref import bm25_block_score_ref
from repro.sparse.block_csr import block_postings_from_index, \
    pack_query_batch


def run(n_docs: int = 8192, n_vocab: int = 8000) -> list[dict]:
    corpus = zipf_corpus(n_docs, n_vocab, avg_len=60)
    p = BM25Params()
    idx = build_index(corpus, n_vocab, params=p)
    di = DeviceIndex.from_host(idx)
    bp = block_postings_from_index(idx, block_size=512, tile=512)
    tok_d = jnp.asarray(bp.token_ids)
    loc_d = jnp.asarray(bp.local_doc)
    sc_d = jnp.asarray(bp.scores)

    blocked = jax.jit(lambda u, w: bm25_block_score_ref(
        tok_d, loc_d, sc_d, u, w, block_size=bp.block_size))

    rows = []
    for batch in (8, 32, 128):
        queries = zipf_queries(batch, n_vocab, q_len=5, seed=batch)
        toks, wts = pad_queries(queries, 8)
        uniq, weights = pack_query_batch(toks, wts, u_max=1024)
        u_d, w_d = jnp.asarray(uniq), jnp.asarray(weights)
        p_max = suggest_p_max(idx, 8)
        jt, jw = jnp.asarray(toks), jnp.asarray(wts)

        score_batch(di, jt, jw, p_max=p_max).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            score_batch(di, jt, jw, p_max=p_max).block_until_ready()
        t_gather = (time.perf_counter() - t0) / 3

        blocked(u_d, w_d).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            blocked(u_d, w_d).block_until_ready()
        t_blocked = (time.perf_counter() - t0) / 3

        rows.append({
            "batch": batch,
            "gather_us_per_q": round(1e6 * t_gather / batch, 1),
            "blocked_us_per_q": round(1e6 * t_blocked / batch, 1),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
