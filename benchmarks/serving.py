"""BENCH_7: micro-batching front-end vs one-query-at-a-time serving.

Drives a seeded Poisson arrival stream at each (arrival rate × batch
deadline) cell through TWO servers over the same retriever:

* **frontend** — :class:`repro.serve.ServingFrontend`: arrivals group by
  jit-cache shape bucket, flush on size-or-deadline, pack of batch i+1
  overlaps execution of batch i;
* **direct**   — the naive bridge: one ``retrieve_batch([q], k)`` launch
  per arrival, FIFO (the strongest honest baseline: same scorer, same
  compiled kernels, no batching).

Per cell it reports request-latency p50/p99 and completed-requests/s for
both paths, the formed-batch stats, and the throughput gain — the
latency/throughput Pareto the batching deadline knob trades along. Two
invariants are asserted on the way (and stamped into the artifact):

* **bit-identity** — every batch the frontend formed is replayed through
  a direct ``retrieve_batch`` call and must match bit-for-bit
  (micro-batching changes cost, never results);
* **zero steady-state bytes** — a resident/device-plan retriever served
  through the frontend ships ZERO posting and descriptor bytes per
  steady-state batch (the PR-4 residency invariant survives the new
  serving path).

``--overload`` runs the OVERLOAD sweep instead (BENCH_8): it measures
the retriever's batch-32 capacity, then drives seeded arrival streams at
1–5x that capacity through a PROTECTED front-end (token-bucket admission
at 0.9x capacity + CoDel queue-delay backstop) and an UNPROTECTED one,
and asserts the overload contract on the way:

* protected goodput stays within a band of measured capacity
  (``goodput_floor``) at every factor — shedding converges instead of
  collapsing;
* admitted-request p99 is BOUNDED across overload factors (no monotone
  queue growth), and strictly dominates the unprotected p99 past
  saturation;
* sheds cost no device work (``shed_leak == 0``: formed-batch rows sum
  exactly to admitted requests) and every admitted request is
  bit-identical to a direct ``retrieve_batch`` of its formed batch.

Cells are keyed by ``rate_factor`` (rate / measured capacity) and stamp
``goodput_ratio`` (goodput / capacity), so cross-ref comparison in the
perf gate is machine-independent.

Conventions follow ``benchmarks.planner``: ``--fast`` runs the CI-smoke
grid and stamps ``"fast": true``; ``_guarded_write`` refuses to clobber
a committed full-scale BENCH_7.json / BENCH_8.json with smoke numbers.
The perf gate (``benchmarks.perf_gate``) compares the ``serving.cells``
p99 columns at fixed (rate, deadline) and the ``overload.cells`` goodput
ratios at fixed rate_factor across refs and fails >25% regressions.

    PYTHONPATH=src python -m benchmarks.serving --fast --force
    PYTHONPATH=src python -m benchmarks.serving --overload --fast --force
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.planner import _guarded_write
from repro.core import BM25Params, build_index
from repro.data.corpus import zipf_corpus
from repro.serve import (AdmissionRejectedError, DeviceRetriever,
                         ServingFrontend)

FAST = dict(n_docs=400, n_vocab=300, avg_len=40, n_requests=48,
            rates=(100.0, 2000.0), deadlines_ms=(2.0, 10.0))
# FULL is sized to the CPU interpret-mode proxy this repo benches on:
# one warm launch costs ~4.4ms at 2000x1000 (~227 qps direct capacity,
# batch-16 ~1071 qps effective), so the low rate sits under direct
# capacity (a sane Pareto baseline) and the high rates saturate it —
# which is the regime micro-batching exists for. On real hardware a
# batch costs ~one launch, so the gain only grows; re-size rates to the
# measured single-launch capacity when re-running there (the TPU
# recalibration item in ROADMAP.md).
FULL = dict(n_docs=2_000, n_vocab=1_000, avg_len=60, n_requests=300,
            rates=(150.0, 1000.0, 3000.0), deadlines_ms=(1.0, 5.0, 20.0))

K = 10
MAX_BATCH = 32

# overload sweep (BENCH_8): duration-based sizing — n_requests per cell =
# rate x duration_s, so queue dynamics are comparable across machines of
# very different capacity (everything else is keyed on rate_factor)
OVERLOAD_FAST = dict(n_docs=400, n_vocab=300, avg_len=40, duration_s=0.35,
                     factors=(1.0, 3.0), goodput_floor=0.5)
OVERLOAD_FULL = dict(n_docs=2_000, n_vocab=1_000, avg_len=60,
                     duration_s=1.5, factors=(1.0, 3.0, 5.0),
                     goodput_floor=0.8)
ADMIT_FRACTION = 0.9          # token-bucket rate as a fraction of capacity
MAX_OVERLOAD_REQUESTS = 30_000


def _poisson_arrivals(n: int, rate_qps: float, seed: int) -> np.ndarray:
    """Seeded arrival offsets (s): identical stream for both servers."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_qps, size=n))


def _queries(n: int, n_vocab: int, seed: int) -> list[np.ndarray]:
    from repro.data.corpus import zipf_queries
    return zipf_queries(n, n_vocab, q_len=5, seed=seed)


def _warm(dr: DeviceRetriever, queries: list[np.ndarray],
          seed: int = 3) -> None:
    """Pre-compile every jit bucket the sweep can plausibly form.

    Every device dim is pow2-bucketed (batch B, query width, u_max,
    posting budget), so the bucket space is O(log demand) — but a bucket
    first hit mid-measurement charges a multi-hundred-ms compile to some
    unlucky request's latency. Real query batches (not a synthetic
    token) are required: the u_max and posting-budget buckets depend on
    the batch's actual distinct tokens and Σ df. The pow2 size ladder
    plus random compositions cover the reachable bucket set; steady
    state is then compile-free, which is what the sweep measures.
    """
    rng = np.random.default_rng(seed)
    for b in (1, 2, 3, 4, 6, 8, 12, 16, 24, 32):
        if b <= len(queries):
            dr.retrieve_batch(queries[:b], K)
    # low-rate cells form small batches of CONTIGUOUS arrivals — walk
    # those compositions directly (their Σ df buckets are what the size
    # ladder above can miss)
    for b in (1, 2, 3, 4):
        for i in range(0, len(queries) - b + 1, b):
            dr.retrieve_batch(queries[i:i + b], K)
    for _ in range(40):
        b = int(rng.integers(1, MAX_BATCH + 1))
        pick = rng.choice(len(queries), size=min(b, len(queries)),
                          replace=False)
        dr.retrieve_batch([queries[i] for i in pick], K)


def _pcts(lat_s: list[float]) -> dict:
    a = np.asarray(lat_s) * 1e3
    return {"p50_ms": round(float(np.percentile(a, 50)), 3),
            "p99_ms": round(float(np.percentile(a, 99)), 3)}


def _run_frontend(dr, queries, arrivals, deadline_s, *, record=False):
    """Replay the arrival stream through the micro-batching front-end."""
    fe = ServingFrontend(dr, k=K, max_batch=MAX_BATCH,
                         batch_deadline_s=deadline_s,
                         max_queue=len(queries) + 1,
                         record_batches=record)
    t0 = time.monotonic()
    futs = []
    for q, t_arr in zip(queries, arrivals):
        dt = t_arr - (time.monotonic() - t0)
        if dt > 0:
            time.sleep(dt)
        futs.append((fe.submit(q), time.monotonic() - t0))
    rows = [(f.result(), t_sub) for f, t_sub in futs]
    fe.close()
    h = fe.health()
    lat = [r.latency_s for r, _ in rows]
    done = max(t_sub + r.latency_s for r, t_sub in rows)
    span = max(done - float(arrivals[0]), 1e-9)
    return {**_pcts(lat), "qps": round(len(rows) / span, 1),
            "batches": h["batches"],
            "mean_batch": round(h["mean_batch"], 2)}, fe


def _run_direct(dr, queries, arrivals):
    """Same stream, one launch per arrival, FIFO single server."""
    t0 = time.monotonic()
    lat, done = [], 0.0
    for q, t_arr in zip(queries, arrivals):
        dt = t_arr - (time.monotonic() - t0)
        if dt > 0:
            time.sleep(dt)
        dr.retrieve_batch([q], K)
        done = time.monotonic() - t0
        lat.append(done - float(t_arr))
    span = max(done - float(arrivals[0]), 1e-9)
    return {**_pcts(lat), "qps": round(len(lat) / span, 1)}


def _assert_bit_identity(dr, fe: ServingFrontend) -> int:
    """Replay every formed batch directly; raise on any mismatch."""
    replayed = 0
    for batch_qs, kk, res in fe.recorded:
        replay = dr.retrieve_batch(batch_qs, kk)
        if not (np.array_equal(np.asarray(res.ids), np.asarray(replay.ids))
                and np.array_equal(np.asarray(res.scores),
                                   np.asarray(replay.scores))):
            raise AssertionError(
                f"frontend batch (B={len(batch_qs)}, k={kk}) is not "
                f"bit-identical to the direct retrieve_batch call")
        replayed += 1
    return replayed


def bench_sweep(cfg: dict, *, seed: int = 7) -> dict:
    corpus = zipf_corpus(cfg["n_docs"], cfg["n_vocab"],
                         avg_len=cfg["avg_len"])
    idx = build_index(corpus, cfg["n_vocab"], params=BM25Params())
    dr = DeviceRetriever(idx)
    n = cfg["n_requests"]
    queries = _queries(n, cfg["n_vocab"], seed)
    _warm(dr, queries)
    # throwaway overload run: any u-bucket the pow2 warm ladder missed
    # compiles here, not inside a measured cell
    _run_frontend(dr, queries, _poisson_arrivals(n, max(cfg["rates"]),
                                                 seed),
                  min(cfg["deadlines_ms"]) / 1e3)
    cells, replayed_total = [], 0
    for rate in cfg["rates"]:
        arrivals = _poisson_arrivals(n, rate, seed)
        for dl_ms in cfg["deadlines_ms"]:
            fe_stats, fe = _run_frontend(dr, queries, arrivals,
                                         dl_ms / 1e3, record=True)
            replayed_total += _assert_bit_identity(dr, fe)
            di_stats = _run_direct(dr, queries, arrivals)
            cells.append({
                "rate_qps": rate, "deadline_ms": dl_ms, "k": K,
                "n_requests": n, "max_batch": MAX_BATCH,
                "frontend": fe_stats, "direct": di_stats,
                "frontend_p99_ms": fe_stats["p99_ms"],
                "direct_p99_ms": di_stats["p99_ms"],
                "throughput_gain": round(
                    fe_stats["qps"] / max(di_stats["qps"], 1e-9), 2),
                "bit_identical": True,
            })
    return {"n_docs": cfg["n_docs"], "n_vocab": cfg["n_vocab"],
            "cells": cells, "batches_replayed": replayed_total}


def bench_zero_copy(*, seed: int = 11) -> dict:
    """Residency audit: frontend traffic on a resident/device-plan
    retriever ships zero steady-state posting AND descriptor bytes."""
    from repro.sparse.block_csr import TRANSFERS, reset_transfer_stats

    n_docs, n_vocab, n_req = 120, 80, 8
    corpus = zipf_corpus(n_docs, n_vocab, avg_len=20)
    idx = build_index(corpus, n_vocab, params=BM25Params())
    dr = DeviceRetriever(idx, regime="gathered", gather="resident",
                         plan="device", tile=64, block_size=32, q_max=8)
    queries = _queries(n_req, n_vocab, seed)
    dr.retrieve_batch(queries, K)                 # warm the bucket
    reset_transfer_stats()
    with ServingFrontend(dr, k=K, max_batch=n_req,
                         batch_deadline_s=0.05) as fe:
        futs = [fe.submit(q) for q in queries]
        for f in futs:
            f.result(timeout=120)
    out = {"requests": n_req,
           "posting_bytes": int(TRANSFERS.posting_bytes),
           "descriptor_bytes": int(TRANSFERS.descriptor_bytes)}
    if out["posting_bytes"] or out["descriptor_bytes"]:
        raise AssertionError(
            f"frontend path shipped steady-state bytes on the resident "
            f"device-plan channel: {out}")
    return out


def _measure_capacity(dr, cfg, seed) -> float:
    """Sustainable rate of the SERVING PATH: a closed-loop flood through
    an unprotected frontend, served / span.

    Bare batch-32 launch timing overstates it — per-request futures,
    result-row construction and stage handoffs are part of serving — and
    a gate sized off the optimistic number admits more than the path can
    drain, which the sweep would misread as a goodput collapse.
    """
    t0 = time.perf_counter()
    dr.retrieve_batch(_queries(MAX_BATCH, cfg["n_vocab"], seed), K)
    est = MAX_BATCH / max(time.perf_counter() - t0, 1e-9)
    n = int(min(max(est * 0.5, 256), MAX_OVERLOAD_REQUESTS // 2))
    queries = _queries(n, cfg["n_vocab"], seed)
    fe = ServingFrontend(dr, k=K, max_batch=MAX_BATCH,
                         batch_deadline_s=0.05, max_queue=n + 1)
    t0 = time.monotonic()
    futs = [fe.submit(q) for q in queries]
    for f in futs:
        f.result()
    span = max(time.monotonic() - t0, 1e-9)
    fe.close()
    return n / span


def _run_overload(dr, queries, rate_qps, capacity, *, protect, seed):
    """Replay one seeded arrival stream; protect=True arms the gate.

    Pacing sleep-spins in ~0.2ms GIL-releasing ticks: plain sleep()
    granularity would cap the offered rate below a fast machine's
    capacity multiple, while a busy-wait would hold the GIL and starve
    the very pipeline being measured (arrivals land in sub-ms clumps;
    the mean rate — all that matters here — is preserved). The batching
    deadline is the time a FULL batch takes to accumulate at the
    admitted rate, so sustained overload converges to full-batch
    launches — the regime the capacity number was measured in.
    """
    n = len(queries)
    arrivals = _poisson_arrivals(n, rate_qps, seed)
    admit_qps = ADMIT_FRACTION * capacity
    kwargs = {}
    if protect:
        kwargs = dict(admission_rate_qps=admit_qps,
                      admission_burst=2 * MAX_BATCH,
                      codel_target_s=3 * MAX_BATCH / capacity,
                      codel_interval_s=0.05)
    # 1.5x the full-batch accumulation time: size flushes dominate
    # (Poisson variance would otherwise trigger the deadline at 28-31
    # requests and pay near-full launch cost for partial batches)
    fe = ServingFrontend(dr, k=K, max_batch=MAX_BATCH,
                         batch_deadline_s=1.5 * MAX_BATCH / admit_qps,
                         max_queue=n + 1, record_batches=protect, **kwargs)
    t0 = time.monotonic()
    futs, shed = [], 0
    for q, t_arr in zip(queries, arrivals):
        while True:
            dt = t_arr - (time.monotonic() - t0)
            if dt <= 0:
                break
            time.sleep(min(dt, 2e-4))
        try:
            futs.append(fe.submit(q))
        except AdmissionRejectedError:
            shed += 1
    rows = [f.result() for f in futs]
    t_done = time.monotonic() - t0
    fe.close()
    span = max(t_done - float(arrivals[0]), 1e-9)
    stats = {**_pcts([r.latency_s for r in rows]),
             "offered_qps": round(n / max(float(arrivals[-1]), 1e-9), 1),
             "goodput_qps": round(len(rows) / span, 1),
             "admitted": len(rows), "shed": shed}
    return stats, fe


def bench_overload(cfg: dict, *, seed: int = 13) -> dict:
    """The protected-vs-unprotected capacity sweep (see module docstring).

    Raises AssertionError on any overload-contract violation — a BENCH_8
    artifact only exists if the contract held when it was generated.
    """
    corpus = zipf_corpus(cfg["n_docs"], cfg["n_vocab"],
                         avg_len=cfg["avg_len"])
    idx = build_index(corpus, cfg["n_vocab"], params=BM25Params())
    dr = DeviceRetriever(idx)
    pool = _queries(256, cfg["n_vocab"], seed)
    _warm(dr, pool)
    capacity = _measure_capacity(dr, cfg, seed)
    floor = cfg["goodput_floor"]
    cells = []
    for f in cfg["factors"]:
        rate = f * capacity
        n = min(int(rate * cfg["duration_s"]), MAX_OVERLOAD_REQUESTS)
        queries = _queries(n, cfg["n_vocab"], seed + int(10 * f))
        prot, fe = _run_overload(dr, queries, rate, capacity,
                                 protect=True, seed=seed + int(f))
        formed = sum(len(b) for b, _, _ in fe.recorded)
        shed_leak = formed - prot["admitted"]
        if shed_leak:
            raise AssertionError(
                f"shed leak at factor {f}: {formed} formed-batch rows != "
                f"{prot['admitted']} admitted requests — a shed request "
                f"consumed device work")
        replayed = _assert_bit_identity(dr, fe)
        unprot, _ = _run_overload(dr, queries, rate, capacity,
                                  protect=False, seed=seed + int(f))
        goodput_ratio = prot["goodput_qps"] / capacity
        if goodput_ratio < floor:
            raise AssertionError(
                f"protected goodput collapsed at factor {f}: "
                f"{prot['goodput_qps']:.0f} qps < {floor} x capacity "
                f"({capacity:.0f} qps)")
        dominates = (prot["p99_ms"] < unprot["p99_ms"]) if f > 1 else None
        if dominates is False:
            raise AssertionError(
                f"protected p99 ({prot['p99_ms']} ms) does not dominate "
                f"unprotected ({unprot['p99_ms']} ms) at factor {f}")
        cells.append({
            "rate_factor": f, "rate_qps": round(rate, 1),
            "n_requests": n, "k": K, "max_batch": MAX_BATCH,
            "protected": prot, "unprotected": unprot,
            "goodput_ratio": round(goodput_ratio, 3),
            "protected_p99_ms": prot["p99_ms"],
            "unprotected_p99_ms": unprot["p99_ms"],
            "dominates": dominates, "shed_leak": shed_leak,
            "batches_replayed": replayed, "bit_identical": True,
        })
    over = [c for c in cells if c["rate_factor"] > 1]
    p99_bounded = (over[-1]["protected_p99_ms"]
                   <= 1.6 * over[0]["protected_p99_ms"] + 2.0
                   if len(over) >= 2 else True)
    if not p99_bounded:
        raise AssertionError(
            f"admitted p99 grows with overload factor: "
            f"{[c['protected_p99_ms'] for c in over]} ms — the gate is "
            f"not bounding the standing queue")
    return {"n_docs": cfg["n_docs"], "n_vocab": cfg["n_vocab"],
            "capacity_qps": round(capacity, 1),
            "admit_rate_qps": round(ADMIT_FRACTION * capacity, 1),
            "goodput_floor": floor, "p99_bounded": p99_bounded,
            "cells": cells}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-smoke grid (stamps \"fast\": true)")
    ap.add_argument("--force", action="store_true",
                    help="allow --fast to overwrite a full-scale artifact")
    ap.add_argument("--overload", action="store_true",
                    help="run the overload sweep (BENCH_8) instead")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.overload:
        cfg = OVERLOAD_FAST if args.fast else OVERLOAD_FULL
        overload = bench_overload(cfg)
        result = {
            "bench": "serving_overload",
            "config": {k: (list(v) if isinstance(v, tuple) else v)
                       for k, v in cfg.items()},
            "overload": overload,
        }
        _guarded_write(args.out or "BENCH_8.json", result,
                       fast=args.fast, force=args.force)
        print(json.dumps({"capacity_qps": overload["capacity_qps"],
                          "p99_bounded": overload["p99_bounded"],
                          "cells": [{k: c[k] for k in
                                     ("rate_factor", "goodput_ratio",
                                      "protected_p99_ms",
                                      "unprotected_p99_ms")}
                                    for c in overload["cells"]]}, indent=1))
        return

    cfg = FAST if args.fast else FULL
    serving = bench_sweep(cfg)
    zero_copy = bench_zero_copy()
    best = max(serving["cells"], key=lambda c: c["throughput_gain"])
    result = {
        "bench": "serving",
        "config": {k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in cfg.items()},
        "serving": serving,
        "zero_copy": zero_copy,
        "best_cell": {"rate_qps": best["rate_qps"],
                      "deadline_ms": best["deadline_ms"],
                      "throughput_gain": best["throughput_gain"],
                      "frontend_p99_ms": best["frontend_p99_ms"],
                      "direct_p99_ms": best["direct_p99_ms"]},
    }
    _guarded_write(args.out or "BENCH_7.json", result, fast=args.fast,
                   force=args.force)
    print(json.dumps(result["best_cell"], indent=1))


if __name__ == "__main__":
    main()
