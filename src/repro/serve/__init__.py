"""Serving stack: sharded retrieval engine with hedging, LM decode engine."""

from .retrieval_engine import RetrievalEngine, ShardRuntime
from .decode_engine import DecodeEngine

__all__ = ["RetrievalEngine", "ShardRuntime", "DecodeEngine"]
