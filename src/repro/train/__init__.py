"""Training stack: optimizer, step builder, checkpointing, fault tolerance."""

from .optimizer import AdamW, cosine_schedule, global_norm
from .step import init_train_state, make_train_step

__all__ = ["AdamW", "cosine_schedule", "global_norm",
           "init_train_state", "make_train_step"]
