import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

DOC = """§Perf hillclimb driver: named variants of the three chosen cells.

Each variant encodes one hypothesis from the EXPERIMENTS.md §Perf log
(sharding scheme, microbatch count, dtype, top-k structure). Results append
to benchmarks/out/hillclimb.json next to the baselines in dryrun.json.

    python -m repro.launch.hillclimb --cell qwen3-8b/train_4k --variant dp64tp4
    python -m repro.launch.hillclimb --all
"""

import argparse


def _variants():
    import jax.numpy as jnp

    from ..configs import bm25s as bm25s_cfg
    from ..configs import mixtral_8x22b, qwen3_8b
    from ..configs.common import lm_train_cell, remesh_dp_tp

    v = {}

    # ---- bonus: qwen3-8b/decode_32k (memory-bound) — int8 KV cache -------
    from dataclasses import replace as _rep
    from ..configs.common import lm_decode_cell
    v["qwen3-8b/decode_32k"] = {
        "kv_int8": lm_decode_cell(
            "qwen3-8b", _rep(qwen3_8b.CONFIG, kv_quant=True),
            batch=128, seq_len=32768, shape_name="decode_32k",
            note="int8 KV cache, per-(pos, head) scales"),
    }

    # ---- qwen3-8b/train_4k: dense-LM TP collectives dominate -------------
    q = qwen3_8b.CONFIG
    v["qwen3-8b/train_4k"] = {
        "mb2": lm_train_cell("qwen3-8b", q, global_batch=256, seq_len=4096,
                             n_microbatches=2, note="mb 4->2"),
        "dp64tp4": lm_train_cell(
            "qwen3-8b", q, global_batch=256, seq_len=4096, n_microbatches=4,
            remesh=remesh_dp_tp(64, 4), note="remesh dp64 tp4"),
        "dp256tp1": lm_train_cell(
            "qwen3-8b", q, global_batch=256, seq_len=4096, n_microbatches=4,
            remesh=remesh_dp_tp(256, 1), note="remesh dp256 tp1 (pure FSDP)"),
        "dp256tp1_mb1": lm_train_cell(
            "qwen3-8b", q, global_batch=256, seq_len=4096, n_microbatches=1,
            remesh=remesh_dp_tp(256, 1),
            note="pure FSDP + single microbatch (gathers once)"),
    }

    # ---- mixtral-8x22b/train_4k: most collective-bound cell --------------
    m = mixtral_8x22b.CONFIG
    v["mixtral-8x22b/train_4k"] = {
        "mb4": lm_train_cell("mixtral-8x22b", m, global_batch=256,
                             seq_len=4096, n_microbatches=4,
                             note="mb 8->4 (halve FSDP re-gathers)"),
        "dp64tp4_mb4": lm_train_cell(
            "mixtral-8x22b", m, global_batch=256, seq_len=4096,
            n_microbatches=4, remesh=remesh_dp_tp(64, 4),
            note="remesh dp64 tp4 + mb4"),
        "dp32tp8_mb4": lm_train_cell(
            "mixtral-8x22b", m, global_batch=256, seq_len=4096,
            n_microbatches=4, remesh=remesh_dp_tp(32, 8),
            note="remesh dp32 tp8 + mb4"),
        "dp32tp8_mb2": lm_train_cell(
            "mixtral-8x22b", m, global_batch=256, seq_len=4096,
            n_microbatches=2, remesh=remesh_dp_tp(32, 8),
            note="remesh dp32 tp8 + mb2 (halve weight re-gathers again)"),
        "dp64tp4_mb2": lm_train_cell(
            "mixtral-8x22b", m, global_batch=256, seq_len=4096,
            n_microbatches=2, remesh=remesh_dp_tp(64, 4),
            note="remesh dp64 tp4 + mb2"),
    }

    # ---- bm25s/score_blocked_2m: the paper's technique, batched ----------
    v["bm25s/score_blocked_2m"] = {
        "topk2stage": bm25s_cfg._score_blocked_cell(
            sharded_topk=True, note="shard-aligned 2-stage top-k"),
        "topk2stage_bf16": bm25s_cfg._score_blocked_cell(
            sharded_topk=True, score_dtype=jnp.bfloat16,
            note="2-stage top-k + bf16 scores/weights"),
        "topk2stage_bf16_b1024": bm25s_cfg._score_blocked_cell(
            sharded_topk=True, score_dtype=jnp.bfloat16, batch=1024,
            u_max=4096, note="+ 4x query batch (amortize posting reads)"),
    }
    return v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/out/hillclimb.json")
    args = ap.parse_args()

    from .dryrun import load_results, run_cell, save_result
    from .mesh import make_production_mesh

    variants = _variants()
    todo = []
    if args.all:
        for cell_key, vs in variants.items():
            todo += [(cell_key, name, c) for name, c in vs.items()]
    else:
        vs = variants[args.cell]
        names = [args.variant] if args.variant else list(vs)
        todo = [(args.cell, n, vs[n]) for n in names]

    mesh = make_production_mesh(multi_pod=False)
    done = load_results(args.out)
    for cell_key, name, cell in todo:
        key = f"{cell_key}#{name}@16x16"
        if key in done and done[key].get("ok"):
            print(f"[hillclimb] skip {key}")
            continue
        try:
            rec = run_cell(cell, mesh)
            rec["variant"] = name
        except Exception as e:
            import traceback
            rec = {"ok": False, "variant": name, "error": repr(e),
                   "traceback": traceback.format_exc()[-1500:]}
            print(f"[hillclimb] FAIL {key}: {e!r}")
        save_result(args.out, key, rec)


if __name__ == "__main__":
    main()
