"""The CI perf-trend gate's comparison logic (benchmarks.perf_gate).

The gate is CODE, so its failure modes are tier-1-testable without a CI
run: a synthetic >25% cell regression must trip it, noise under the
absolute floor must not, nonzero resident posting/descriptor bytes must
trip it, and schema drift (cells/columns on one side only) must degrade
to reporting, never crash. ``main`` is exercised end-to-end including the
``--inject-slowdown`` dry-run switch the PR uses to demonstrate the gate.
"""

import copy
import json

import pytest

from benchmarks.perf_gate import CELL_KEY, compare, main, to_markdown


def _cell(n_docs=1000, n_vocab=50, profile="head", batch=8, k=10,
          auto=0.10, blocked=0.20, gathered=0.05, **extra):
    c = {"n_docs": n_docs, "n_vocab": n_vocab, "profile": profile,
         "batch": batch, "k": k, "auto_batch_s": auto,
         "blocked_batch_s": blocked, "gathered_batch_s": gathered,
         "posting_bytes_per_batch_resident": 0,
         "posting_bytes_per_batch_device_plan": 0,
         "descriptor_bytes_per_batch_device_plan": 0}
    c.update(extra)
    return c


def _bench(*cells):
    return {"cells": list(cells), "summary": {}}


def test_gate_passes_identical_runs():
    base = _bench(_cell(), _cell(profile="tail"))
    rows, failures = compare(base, copy.deepcopy(base))
    assert failures == []
    assert all(r["status"] == "ok" for r in rows)


def test_gate_trips_on_25pct_regression():
    base = _bench(_cell(), _cell(profile="tail"))
    cand = copy.deepcopy(base)
    cand["cells"][1]["gathered_batch_s"] *= 1.5      # one cell, one column
    rows, failures = compare(base, cand, max_ratio=1.25)
    assert len(failures) == 1
    assert "gathered_batch_s" in failures[0] and "tail" in failures[0]
    assert sum(r["status"] == "REGRESSED" for r in rows) == 1


def test_gate_ignores_noise_under_absolute_floor():
    """3x on a 1ms cell is scheduler jitter, not a regression — the
    absolute floor keeps tiny cells from flapping the gate."""
    base = _bench(_cell(auto=0.001, blocked=0.001, gathered=0.001))
    cand = _bench(_cell(auto=0.003, blocked=0.001, gathered=0.001))
    _, failures = compare(base, cand, max_ratio=1.25, abs_floor_s=0.005)
    assert failures == []
    _, failures = compare(base, cand, max_ratio=1.25, abs_floor_s=0.0)
    assert len(failures) == 1                        # floor off: it trips


def test_gate_trips_on_residency_leak():
    base = _bench(_cell())
    for col in ("posting_bytes_per_batch_resident",
                "posting_bytes_per_batch_device_plan",
                "descriptor_bytes_per_batch_device_plan"):
        cand = _bench(_cell(**{col: 4096}))
        rows, failures = compare(base, cand)
        assert len(failures) == 1 and "4096" in failures[0], col
        assert any(r["status"] == "LEAK" for r in rows)


def test_gate_tolerates_schema_drift():
    """Cells/columns on only one side report as new/dropped, never fail —
    the baseline ref may predate the current bench schema."""
    old_cell = {k: v for k, v in _cell().items()
                if not k.endswith("device_plan")}
    del old_cell["auto_batch_s"]                     # column drift too
    base = _bench(old_cell, _cell(profile="dropped-only"))
    cand = _bench(_cell(), _cell(profile="brand-new"))
    rows, failures = compare(base, cand)
    assert failures == []
    statuses = {r["status"] for r in rows}
    assert "new" in statuses and "dropped" in statuses


def test_gate_fails_on_empty_intersection():
    """Zero comparable cells = vacuous gate: a sweep-grid change must not
    silently disable the latency comparison. The escape hatch is explicit
    opt-in, and an empty baseline (first run ever) stays permitted."""
    base = _bench(_cell(n_docs=1000))
    cand = _bench(_cell(n_docs=9999))             # disjoint grids
    _, failures = compare(base, cand)
    assert len(failures) == 1 and "vacuous" in failures[0]
    _, failures = compare(base, cand, allow_empty_intersection=True)
    assert failures == []
    _, failures = compare({"cells": []}, cand)    # no baseline at all
    assert failures == []


def test_main_empty_intersection_exit_codes(tmp_path):
    b, c = tmp_path / "b.json", tmp_path / "c.json"
    b.write_text(json.dumps(_bench(_cell(n_docs=1))))
    c.write_text(json.dumps(_bench(_cell(n_docs=2))))
    argv = ["--baseline", str(b), "--candidate", str(c)]
    assert main(argv) == 1
    assert main(argv + ["--allow-empty-intersection"]) == 0


def test_markdown_lists_failures_and_cells():
    base = _bench(_cell())
    cand = _bench(_cell(gathered=0.5))
    rows, failures = compare(base, cand)
    md = to_markdown(rows, failures, max_ratio=1.25)
    assert "REGRESSED" in md and "gate failure" in md
    assert str(_cell()["n_docs"]) in md
    md_ok = to_markdown(*compare(base, base), max_ratio=1.25)
    assert "no regressions" in md_ok


def test_main_inject_slowdown_dry_run(tmp_path, capsys):
    """The PR's demonstration path: identical runs pass, the injected
    1.5x slowdown makes the gate exit nonzero, and the summary file gets
    the table either way."""
    bench = _bench(_cell(), _cell(profile="tail"))
    b = tmp_path / "base.json"
    c = tmp_path / "cand.json"
    s = tmp_path / "summary.md"
    b.write_text(json.dumps(bench))
    c.write_text(json.dumps(bench))
    argv = ["--baseline", str(b), "--candidate", str(c),
            "--summary", str(s)]
    assert main(argv) == 0
    assert "no regressions" in s.read_text()
    assert main(argv + ["--inject-slowdown", "1.5"]) == 1
    out = capsys.readouterr()
    assert "REGRESSED" in out.out
    assert "REGRESSED" in s.read_text()              # appended


def test_cell_key_covers_sweep_axes():
    # the sweep is keyed by corpus/vocab/profile/batch/k — a reminder that
    # adding a sweep axis must extend the key or cells will collide
    assert set(CELL_KEY) == {"n_docs", "n_vocab", "profile", "batch", "k"}


@pytest.mark.parametrize("ratio,expect", [(1.2, 0), (1.3, 1)])
def test_threshold_boundary(ratio, expect):
    base = _bench(_cell(gathered=0.1))
    cand = _bench(_cell(gathered=0.1 * ratio))
    _, failures = compare(base, cand, max_ratio=1.25)
    assert len(failures) == expect


def test_gate_trips_on_skip_rate_collapse():
    """The pruned cells' skip rate is deterministic for a fixed seed — a
    >50% drop means the pruning logic stopped cutting work, and must fail
    even when every latency column looks fine."""
    base = _bench(_cell(profile="head_mixed", batch=2,
                        pruned_batch_s=0.02, resident_batch_s=0.06,
                        pruned_skip_rate=0.70,
                        posting_bytes_per_batch_pruned=0))
    cand = copy.deepcopy(base)
    cand["cells"][0]["pruned_skip_rate"] = 0.30      # 57% drop
    rows, failures = compare(base, cand)
    assert len(failures) == 1 and "skip-rate collapse" in failures[0]
    assert any(r["status"] == "COLLAPSED" for r in rows)
    # a drop within the tolerance passes
    cand["cells"][0]["pruned_skip_rate"] = 0.40      # 43% drop
    _, failures = compare(base, cand)
    assert failures == []
    # pruned latency columns are gated like the others
    cand = copy.deepcopy(base)
    cand["cells"][0]["pruned_skip_rate"] = 0.70
    cand["cells"][0]["pruned_batch_s"] = 0.06        # 3x
    _, failures = compare(base, cand)
    assert len(failures) == 1 and "pruned_batch_s" in failures[0]
    # nonzero pruned-path bytes are a LEAK
    cand = copy.deepcopy(base)
    cand["cells"][0]["posting_bytes_per_batch_pruned"] = 128
    _, failures = compare(base, cand)
    assert len(failures) == 1 and "pruned posting bytes" in failures[0]


def test_gate_trips_when_pruned_cells_or_counter_vanish():
    """The silent-disable paths: a candidate that stops reporting the
    skip-rate column (counter renamed) or drops the pruned cells wholesale
    must fail — both are total collapses the per-cell check can't see."""
    base = _bench(_cell(profile="head_mixed", batch=2,
                        pruned_batch_s=0.02, pruned_skip_rate=0.70))
    cand = copy.deepcopy(base)
    del cand["cells"][0]["pruned_skip_rate"]         # counter vanished
    _, failures = compare(base, cand)
    assert len(failures) == 1 and "skip-rate collapse" in failures[0]
    cand = copy.deepcopy(base)
    cand["cells"][0]["profile"] = "head"             # pruned cell replaced
    _, failures = compare(base, cand, allow_empty_intersection=True)
    assert any("missing from the candidate" in f for f in failures)
    # a plain latency cell disappearing still only reports, never fails
    base2 = _bench(_cell(), _cell(profile="tail"))
    cand2 = _bench(_cell())
    _, failures = compare(base2, cand2)
    assert failures == []


def test_gate_trips_on_healthy_baseline_degradation():
    """A fault-free sweep that walked the fallback ladder is a planner or
    capability bug the degradation machinery is silently absorbing — the
    gate must surface it even though every latency cell looks fine."""
    base = _bench(_cell())
    cand = copy.deepcopy(base)
    cand["degraded"] = {"n_docs": 50_000, "n_vocab": 10_000, "batch": 4,
                        "k": 10, "profile": "head_mixed",
                        "degradations_per_batch_healthy": 0.0,
                        "degraded_trail": ["host->oracle"]}
    rows, failures = compare(base, cand)
    assert failures == []
    assert any(r["metric"] == "degradations_per_batch_healthy"
               and r["status"] == "ok" for r in rows)
    cand["degraded"]["degradations_per_batch_healthy"] = 0.05
    rows, failures = compare(base, cand)
    assert len(failures) == 1
    assert "fault-free baseline" in failures[0]
    assert any(r["status"] == "DEGRADED" for r in rows)
    # old-schema candidates (no degraded section) stay quietly ungated
    _, failures = compare(base, base)
    assert failures == []


def test_gate_rejects_fast_baseline_for_full_candidate():
    """A --fast (CI-smoke) artifact can never gate a full-scale run: the
    marker rejection is how a clobbered committed BENCH_* file surfaces
    as a loud failure instead of silently blessing smoke-sized numbers
    as the trend baseline."""
    base = _bench(_cell())
    cand = copy.deepcopy(base)
    base["fast"] = True
    _, failures = compare(base, cand)
    assert len(failures) == 1 and '"fast": true' in failures[0]
    # smoke-vs-smoke (the CI bench job) and full-vs-full both stay clean,
    # and a fast CANDIDATE against a full baseline is fine too
    cand["fast"] = True
    assert compare(base, cand)[1] == []
    base["fast"] = False
    assert compare(base, copy.deepcopy(base))[1] == []
    assert compare(base, cand)[1] == []


def test_planner_guarded_write_refuses_fast_clobber(tmp_path):
    """planner._guarded_write stamps every payload "fast" and refuses to
    let a --fast run replace an unstamped (full-scale) artifact unless
    forced — the regression guard for the PR-4 BENCH clobber."""
    from benchmarks.planner import _guarded_write

    out = tmp_path / "BENCH.json"
    _guarded_write(str(out), {"cells": [1]}, fast=False, force=False)
    assert json.loads(out.read_text())["fast"] is False
    with pytest.raises(SystemExit, match="refusing to overwrite"):
        _guarded_write(str(out), {"cells": [2]}, fast=True, force=False)
    assert json.loads(out.read_text())["cells"] == [1]
    # --force overrides; fast-over-fast never needs it
    _guarded_write(str(out), {"cells": [3]}, fast=True, force=True)
    assert json.loads(out.read_text()) == {"fast": True, "cells": [3]}
    _guarded_write(str(out), {"cells": [4]}, fast=True, force=False)
    assert json.loads(out.read_text())["cells"] == [4]
    # a fast artifact never blocks a full-scale refresh
    _guarded_write(str(out), {"cells": [5]}, fast=False, force=False)
    assert json.loads(out.read_text()) == {"fast": False, "cells": [5]}


def test_gate_trips_on_reorder_gain_collapse():
    """BENCH_6: the skip-rate GAIN over random order is deterministic for
    a fixed seed — losing >50% of it means the doc-id clustering stopped
    tightening bounds, even when absolute rates still look healthy."""
    base = _bench(_cell(profile="head_mixed", batch=2,
                        pruned_batch_s_none=0.02,
                        pruned_batch_s_signature=0.018,
                        pruned_skip_rate_none=0.70,
                        pruned_skip_rate_signature=0.80,
                        skip_rate_gain=0.10,
                        posting_bytes_per_batch_none=0,
                        posting_bytes_per_batch_reordered=0,
                        descriptor_bytes_per_batch_none=4096,
                        descriptor_bytes_per_batch_reordered=4096))
    cand = copy.deepcopy(base)
    rows, failures = compare(base, cand)
    assert failures == []
    cand["cells"][0]["skip_rate_gain"] = 0.04          # 60% relative drop
    rows, failures = compare(base, cand)
    assert len(failures) == 1 and "reorder gain collapse" in failures[0]
    assert any(r["metric"] == "skip_rate_gain"
               and r["status"] == "COLLAPSED" for r in rows)
    # within tolerance passes
    cand["cells"][0]["skip_rate_gain"] = 0.06          # 40% drop
    _, failures = compare(base, cand)
    assert failures == []
    # a candidate that silently stops reporting the gain trips too
    del cand["cells"][0]["skip_rate_gain"]
    _, failures = compare(base, cand)
    assert len(failures) == 1 and "reorder gain collapse" in failures[0]
    # reordered latency columns are gated like the others
    cand = copy.deepcopy(base)
    cand["cells"][0]["pruned_batch_s_signature"] = 0.09    # 5x
    _, failures = compare(base, cand)
    assert len(failures) == 1 and "pruned_batch_s_signature" in failures[0]


def test_gate_trips_on_reorder_byte_inequality():
    """Reordered serving must never move MORE bytes than random-order
    serving — the remap is a host gather. Posting bytes are exactly
    equal; descriptor bytes may shrink under clustering (fewer fragments)
    but never grow (schema-tolerant: cells without the columns are
    ignored)."""
    base = _bench(_cell(profile="head_mixed", batch=2,
                        pruned_batch_s_none=0.02,
                        pruned_batch_s_signature=0.018,
                        skip_rate_gain=0.10,
                        posting_bytes_per_batch_none=0,
                        posting_bytes_per_batch_reordered=0,
                        descriptor_bytes_per_batch_none=4096,
                        descriptor_bytes_per_batch_reordered=4096))
    cand = copy.deepcopy(base)
    cand["cells"][0]["descriptor_bytes_per_batch_reordered"] = 8192
    rows, failures = compare(base, cand)
    assert len(failures) == 1 and "host gather" in failures[0]
    assert any(r["metric"] == "descriptor_bytes_per_batch_reordered"
               and r["status"] == "LEAK" for r in rows)
    # a SMALLER reordered descriptor table is the clustering win the
    # full-scale BENCH_6 cells actually show — it must pass
    cand = copy.deepcopy(base)
    cand["cells"][0]["descriptor_bytes_per_batch_reordered"] = 2048
    _, failures = compare(base, cand)
    assert failures == []
    cand = copy.deepcopy(base)
    cand["cells"][0]["posting_bytes_per_batch_reordered"] = 64
    _, failures = compare(base, cand)
    assert len(failures) == 1 and "posting bytes" in failures[0]
    # posting bytes stay an exact-equality check: fewer posting bytes
    # than the random-order cell is just as anomalous as more
    cand = copy.deepcopy(base)
    cand["cells"][0]["posting_bytes_per_batch_none"] = 64
    _, failures = compare(base, cand)
    assert len(failures) == 1 and "posting bytes" in failures[0]
    # old-schema baselines (no BENCH_6 columns) gate nothing
    legacy = _bench(_cell())
    _, failures = compare(legacy, copy.deepcopy(legacy))
    assert failures == []


# -- BENCH_7 serving cells (PR-9 micro-batching front-end) ---------------

def _serving_cell(rate=800.0, deadline=2.0, p99=12.0, direct_p99=70.0,
                  bit_identical=True):
    return {"rate_qps": rate, "deadline_ms": deadline, "k": 10,
            "frontend_p99_ms": p99, "direct_p99_ms": direct_p99,
            "throughput_gain": round(direct_p99 / p99, 2),
            "bit_identical": bit_identical}


def _serving_bench(*cells, zero_copy=None):
    return {"serving": {"cells": list(cells)},
            "zero_copy": zero_copy or {"posting_bytes": 0,
                                       "descriptor_bytes": 0}}


def test_serving_gate_passes_identical_runs():
    base = _serving_bench(_serving_cell(), _serving_cell(rate=100.0))
    rows, failures = compare(base, copy.deepcopy(base))
    assert failures == []
    assert any(r["metric"] == "frontend_p99_ms" for r in rows)


def test_serving_gate_trips_on_p99_regression():
    """>25% frontend p99 at a fixed (rate, deadline) cell fails."""
    base = _serving_bench(_serving_cell(p99=12.0),
                          _serving_cell(rate=100.0, p99=6.0))
    cand = copy.deepcopy(base)
    cand["serving"]["cells"][0]["frontend_p99_ms"] = 20.0   # 1.67x, +8ms
    rows, failures = compare(base, cand, max_ratio=1.25)
    assert len(failures) == 1
    assert "frontend_p99_ms" in failures[0]
    assert sum(r["status"] == "REGRESSED" for r in rows) == 1


def test_serving_gate_millisecond_floor():
    """p99 jitter under the ms floor must not flap the gate."""
    base = _serving_bench(_serving_cell(p99=1.0))
    cand = _serving_bench(_serving_cell(p99=2.5))     # 2.5x but +1.5ms
    _, failures = compare(base, cand, max_ratio=1.25)
    assert failures == []


def test_serving_gate_trips_on_dropped_bit_identity():
    base = _serving_bench(_serving_cell())
    cand = _serving_bench(_serving_cell(bit_identical=False))
    rows, failures = compare(base, cand)
    assert any("bit_identical" in f for f in failures)
    assert any(r["status"] == "BROKEN" for r in rows)


def test_serving_gate_trips_on_zero_copy_leak():
    base = _serving_bench(_serving_cell())
    cand = _serving_bench(_serving_cell(),
                          zero_copy={"posting_bytes": 4096,
                                     "descriptor_bytes": 0})
    _, failures = compare(base, cand)
    assert any("zero-copy" in f and "posting_bytes" in f
               for f in failures)


def test_serving_gate_fails_on_empty_serving_intersection():
    """A (rate, deadline) grid change silently disabling the p99 gate
    fails, mirroring the planner-cell vacuous-gate protection."""
    base = _serving_bench(_serving_cell(rate=800.0))
    cand = _serving_bench(_serving_cell(rate=999.0))
    _, failures = compare(base, cand)
    assert any("serving cell matched" in f for f in failures)
    _, failures = compare(base, cand, allow_empty_intersection=True)
    assert not any("serving cell matched" in f for f in failures)


def test_serving_gate_tolerates_pre_serving_baseline():
    """Baselines predating BENCH_7 have no serving section — candidate
    serving cells report as new, never regress-fail."""
    base = _bench(_cell())
    cand = _bench(_cell())
    cand.update(_serving_bench(_serving_cell()))
    rows, failures = compare(base, cand)
    assert failures == []
    serv = [r for r in rows if r["metric"] == "frontend_p99_ms"]
    assert serv and all(r["status"] == "new" for r in serv)


def test_main_inject_slowdown_trips_serving_gate(tmp_path):
    base = _serving_bench(_serving_cell(p99=20.0))
    b = tmp_path / "base.json"
    c = tmp_path / "cand.json"
    b.write_text(json.dumps(base))
    c.write_text(json.dumps(base))
    assert main(["--baseline", str(b), "--candidate", str(c)]) == 0
    assert main(["--baseline", str(b), "--candidate", str(c),
                 "--inject-slowdown", "1.5"]) == 1


# -- BENCH_8 overload cells (PR-10 admission control) --------------------

def _overload_cell(factor=3.0, goodput_ratio=0.9, p99=50.0,
                   unprot_p99=400.0, shed_leak=0, dominates=True,
                   bit_identical=True):
    return {"rate_factor": factor, "rate_qps": factor * 400.0, "k": 10,
            "goodput_ratio": goodput_ratio, "protected_p99_ms": p99,
            "unprotected_p99_ms": unprot_p99, "shed_leak": shed_leak,
            "dominates": dominates, "bit_identical": bit_identical}


def _overload_bench(*cells, p99_bounded=True):
    return {"overload": {"capacity_qps": 400.0, "p99_bounded": p99_bounded,
                         "cells": list(cells)}}


def test_overload_gate_passes_identical_runs():
    base = _overload_bench(_overload_cell(factor=1.0, dominates=None),
                           _overload_cell(factor=3.0))
    rows, failures = compare(base, copy.deepcopy(base))
    assert failures == []
    assert any(r["metric"] == "goodput_ratio" for r in rows)


def test_overload_gate_trips_on_goodput_drop():
    """>25% relative goodput_ratio drop at a fixed rate_factor fails —
    the admission gate stopped protecting throughput."""
    base = _overload_bench(_overload_cell(goodput_ratio=0.90))
    cand = _overload_bench(_overload_cell(goodput_ratio=0.60))  # -33%
    rows, failures = compare(base, cand)
    assert len(failures) == 1 and "goodput" in failures[0]
    assert any(r["status"] == "COLLAPSED" for r in rows)
    # a drop within the tolerance passes
    cand = _overload_bench(_overload_cell(goodput_ratio=0.70))  # -22%
    _, failures = compare(base, cand)
    assert failures == []


def test_overload_gate_trips_on_shed_leak():
    """A shed request that still consumed device work is a LEAK — the
    whole point of admission control is rejecting BEFORE the former."""
    base = _overload_bench(_overload_cell())
    cand = _overload_bench(_overload_cell(shed_leak=3))
    rows, failures = compare(base, cand)
    assert any("shed_leak=3" in f for f in failures)
    assert any(r["metric"] == "shed_leak" and r["status"] == "LEAK"
               for r in rows)


def test_overload_gate_trips_on_lost_dominance_and_bit_identity():
    base = _overload_bench(_overload_cell())
    cand = _overload_bench(_overload_cell(dominates=False))
    rows, failures = compare(base, cand)
    assert any("dominate" in f for f in failures)
    assert any(r["metric"] == "dominates" and r["status"] == "BROKEN"
               for r in rows)
    # the factor-1.0 cell legitimately reports dominates=None (at
    # capacity there is nothing to dominate) — that must NOT fail
    cand = _overload_bench(_overload_cell(dominates=None))
    _, failures = compare(base, cand)
    assert not any("dominate" in f for f in failures)
    cand = _overload_bench(_overload_cell(bit_identical=False))
    _, failures = compare(base, cand)
    assert any("bit_identical" in f for f in failures)


def test_overload_gate_trips_on_unbounded_p99():
    base = _overload_bench(_overload_cell())
    cand = _overload_bench(_overload_cell(), p99_bounded=False)
    rows, failures = compare(base, cand)
    assert any("p99" in f and "bounding" in f for f in failures)
    assert any(r["metric"] == "p99_bounded" and r["status"] == "BROKEN"
               for r in rows)


def test_overload_gate_tolerates_pre_overload_baseline():
    """Baselines predating BENCH_8 have no overload section — candidate
    overload cells report as new, never regress-fail — and a candidate
    with no overload section gates nothing new either."""
    base = _bench(_cell())
    cand = _bench(_cell())
    cand.update(_overload_bench(_overload_cell()))
    rows, failures = compare(base, cand)
    assert failures == []
    over = [r for r in rows if r["metric"] == "goodput_ratio"]
    assert over and all(r["status"] == "new" for r in over)
    _, failures = compare(_bench(_cell()), _bench(_cell()))
    assert failures == []


def test_overload_gate_fails_on_empty_overload_intersection():
    """A rate_factor grid change silently disabling the goodput gate
    fails, mirroring the serving-cell vacuous-gate protection."""
    base = _overload_bench(_overload_cell(factor=3.0))
    cand = _overload_bench(_overload_cell(factor=7.0))
    _, failures = compare(base, cand)
    assert any("overload cell matched" in f for f in failures)
    _, failures = compare(base, cand, allow_empty_intersection=True)
    assert not any("overload cell matched" in f for f in failures)
    # a candidate that DROPS the overload section entirely is the same
    # silent-disable path and fails identically
    _, failures = compare(base, _bench(_cell()))
    assert any("overload cell matched" in f for f in failures)


def test_main_inject_slowdown_trips_overload_gate(tmp_path):
    """The dry run models a slowdown as proportional goodput loss, so
    --inject-slowdown demonstrates the goodput gate trips too."""
    base = _overload_bench(_overload_cell(goodput_ratio=0.9))
    b = tmp_path / "base.json"
    c = tmp_path / "cand.json"
    b.write_text(json.dumps(base))
    c.write_text(json.dumps(base))
    assert main(["--baseline", str(b), "--candidate", str(c)]) == 0
    assert main(["--baseline", str(b), "--candidate", str(c),
                 "--inject-slowdown", "1.5"]) == 1
