"""BENCH_6 — build-time doc-id reordering: skip rate, latency, exactness.

The block-max pruning evidence in BENCH_4 left 24–37% of planned
fragments DMA'd at the head_mixed cells: with random doc order a block's
per-token bound is set by its single hottest document, so the summed
bound ``Σ_t w_t · bmax[t, b]`` stays loose. ``sparse.reorder`` clusters
documents by posting signature at build time so similar docs share
blocks; this bench proves the three claims that ship with it:

1. **Skip rate** — at the BENCH_4 pruned cells (same ``block_size=64``,
   same head_mixed query distribution), the reordered index's
   ``pruned_skip_rate`` — averaged over 16 seeded query batches, since a
   single small batch is seed noise — is strictly above the random-order
   rate. ``bound_tightness`` (mean bound / true block max, see
   ``benchmarks.planner``) is reported per cell as a diagnostic; the
   skip win is threshold-driven, so the MEAN ratio need not move even
   when far more blocks fall under the per-query threshold.
2. **Exactness** — top-k vs the ``ScipyBM25`` oracle for ALL FIVE paper
   variants: client-id boards identical wherever scores are uniquely
   ordered, and inside bit-equal score ties the returned id provably
   achieves the tied score (the id CHOICE within an exact tie is
   unspecified on every path, reordered or not — the device kernels and
   numpy's argpartition already break ties by internal layout). Scores
   match the oracle to the same 1e-4 tolerance tier-1 asserts for the
   unordered device paths (f32 matmul accumulation order differs
   per-layout; bit-equality holds within a layout, and the permuted
   board is asserted bit-identical to its OWN resident oracle in
   tier-1's property tests).
3. **Build overhead** — the signature pass (sort-free signature
   extraction + posting permutation) costs a fraction of ``build_index``
   itself and a small fraction of end-to-end indexing
   (``build_index`` + ``DeviceIndex.build``; BENCH_1 indexes ~115k
   docs/s — the pass must not dent that).

A microbench block justifies the default scheme: ``"signature"``
(top-weight tokens) vs ``"minhash"`` (weight-blind Jaccard clustering)
at one cell — minhash groups docs sharing ANY token, signature groups
docs sharing HOT tokens, which is exactly what the bounds sum over.

Written to ``BENCH_6.json`` (``benchmarks.perf_gate`` fails on a >50%
relative drop of the skip-rate GAIN at a fixed cell):

    PYTHONPATH=src python -m benchmarks.reorder [--fast] [--force]
"""

from __future__ import annotations

import argparse
import gc
import json
import time

import numpy as np

from benchmarks.planner import _guarded_write, _profile_queries, \
    bound_tightness
from repro.core import BM25Params, build_index
from repro.serve import DeviceRetriever
from repro.data.corpus import zipf_corpus

FIVE_VARIANTS = ("robertson", "lucene", "atire", "bm25l", "bm25+")


def _check_topk_vs_oracle(idx, ids, vals, queries, k) -> bool:
    """Tie-aware exactness vs ScipyBM25: every id identical to the
    oracle's, EXCEPT where the returned id provably achieves the oracle's
    score at that rank (a tie — possibly straddling the k boundary, where
    the tie partner sits just outside the returned window). The id CHOICE
    within a tie is unspecified on every path, reordered or not."""
    from repro.core.reference import ScipyBM25
    oracle = ScipyBM25(idx)
    ids, vals = np.asarray(ids), np.asarray(vals)
    for b, q in enumerate(queries):
        oi, ov = oracle.retrieve(q, k)
        if not np.allclose(ov.astype(np.float32), vals[b], atol=1e-4):
            return False
        full = None
        for j in range(min(k, oi.size)):
            if int(ids[b, j]) == int(oi[j]):
                continue
            if full is None:
                full = oracle.score(q)
            if abs(float(full[int(ids[b, j])]) - float(ov[j])) > 2e-4:
                return False
    return True


def _timed(fn, repeats: int) -> float:
    fn()                                         # compile/warm
    t = np.inf
    for _ in range(repeats):
        gc.collect()
        gc.disable()
        t0 = time.perf_counter()
        fn()
        t = min(t, time.perf_counter() - t0)
        gc.enable()
    return t


# skip rates are averaged over this many seeded query batches per cell:
# a single 2-4 query batch is seed noise (observed +-0.1 swings at small
# corpora), 16 batches give a stable mean at every grid scale
N_SKIP_BATCHES = 16


def _avg_skip_rate(r, rng_seeds, n_vocab: int, batch: int, k: int) -> float:
    rates = []
    for seed in rng_seeds:
        rng = np.random.default_rng(seed)
        q = _profile_queries(rng, "head_mixed", n_vocab, batch, q_len=5)
        r.retrieve_batch(q, k)
        p = r.last_plan
        dmad = p.frags_planned - p.frags_pruned - p.frags_skipped
        rates.append((p.frags_planned - dmad) / p.frags_planned
                     if p.frags_planned else 0.0)
    return float(np.mean(rates))


def bench_reorder_cell(n_docs: int, n_vocab: int, *, batch: int = 2,
                       k: int = 10, block_size: int = 64,
                       avg_len: int = 60, tile: int = 2048,
                       repeats: int = 3) -> dict:
    """One BENCH_4-shaped cell, served random-order vs signature-reordered.

    Both retrievers run the SAME head_mixed query distribution through
    the pruned regime at the same block size; skip rates are means over
    ``N_SKIP_BATCHES`` seeded batches. The cell reports both skip rates,
    the gain, both bound-tightness ratios, pruned latency, per-batch
    transfer bytes for BOTH orders (the zero-extra-bytes claim: the id
    remap is one host gather on the ``[B, k]`` board, inside the
    reordered latency, so posting bytes are byte-equal and descriptor
    bytes never grow — they SHRINK where clustering drops the fragment
    count's pow2 bucket), exactness vs the scipy oracle, and the reorder
    pass overhead relative to ``build_index`` alone and to end-to-end
    indexing (``build_index`` + ``DeviceIndex.build``).
    """
    from repro.sparse.block_csr import TRANSFERS, reset_transfer_stats
    from repro.sparse.reorder import permute_index, signature_permutation

    corpus = zipf_corpus(n_docs, n_vocab, avg_len=avg_len)
    t0 = time.perf_counter()
    idx = build_index(corpus, n_vocab, params=BM25Params())
    t_index = time.perf_counter() - t0

    t0 = time.perf_counter()
    perm = signature_permutation(idx, mode="signature")
    idx_p = permute_index(idx, perm) if perm is not None else idx
    t_reorder = time.perf_counter() - t0

    rng = np.random.default_rng(3)
    queries = _profile_queries(rng, "head_mixed", n_vocab, batch, q_len=5)

    t0 = time.perf_counter()
    plain = DeviceRetriever(idx, regime="pruned", block_size=block_size, frag=512, tile=tile)
    t_device = time.perf_counter() - t0
    reord = DeviceRetriever(idx, regime="pruned", block_size=block_size, frag=512, tile=tile,
                            reorder="signature")
    t_plain = _timed(lambda: plain.retrieve_batch(queries, k), repeats)
    t_reord = _timed(lambda: reord.retrieve_batch(queries, k), repeats)

    seeds = range(N_SKIP_BATCHES)
    sr_plain = _avg_skip_rate(plain, seeds, n_vocab, batch, k)
    sr_reord = _avg_skip_rate(reord, seeds, n_vocab, batch, k)

    def batch_bytes(r):
        reset_transfer_stats()
        r.retrieve_batch(queries, k)
        return int(TRANSFERS.posting_bytes), int(TRANSFERS.descriptor_bytes)

    post_none, desc_none = batch_bytes(plain)
    post_reord, desc_reord = batch_bytes(reord)

    ids, vals = reord.retrieve_batch(queries, k)
    exact = _check_topk_vs_oracle(idx, ids, vals, queries, k)

    return {
        "n_docs": n_docs, "n_vocab": n_vocab, "batch": batch, "k": k,
        "profile": "head_mixed", "block_size": block_size,
        "nnz": int(idx.nnz),
        "skip_rate_batches": N_SKIP_BATCHES,
        "pruned_skip_rate_none": round(float(sr_plain), 4),
        "pruned_skip_rate_signature": round(float(sr_reord), 4),
        "skip_rate_gain": round(float(sr_reord - sr_plain), 4),
        "bound_tightness_none": round(
            bound_tightness(idx, plain.dindex.bmax, queries), 3),
        "bound_tightness_signature": round(
            bound_tightness(idx_p, reord.dindex.bmax, queries), 3),
        "pruned_batch_s_none": round(t_plain, 4),
        "pruned_batch_s_signature": round(t_reord, 4),
        "index_build_s": round(t_index, 4),
        "reorder_pass_s": round(t_reorder, 4),
        "reorder_overhead_frac": round(t_reorder / max(t_index, 1e-9), 4),
        "reorder_overhead_frac_e2e": round(
            t_reorder / max(t_index + t_device, 1e-9), 4),
        "topk_exact_vs_oracle": bool(exact),
        "posting_bytes_per_batch_none": post_none,
        "posting_bytes_per_batch_reordered": post_reord,
        "descriptor_bytes_per_batch_none": desc_none,
        "descriptor_bytes_per_batch_reordered": desc_reord,
    }


def bench_variants(n_docs: int, n_vocab: int, *, batch: int = 4,
                   k: int = 10, block_size: int = 64,
                   avg_len: int = 60, tile: int = 2048) -> dict:
    """Exactness sweep: reordered pruned top-k vs the oracle, per variant."""

    corpus = zipf_corpus(n_docs, n_vocab, avg_len=avg_len)
    rng = np.random.default_rng(7)
    queries = _profile_queries(rng, "head_mixed", n_vocab, batch, q_len=5)
    queries.append(np.zeros(0, np.int32))        # empty query edge case
    out = {}
    for variant in FIVE_VARIANTS:
        idx = build_index(corpus, n_vocab,
                          params=BM25Params(method=variant))
        r = DeviceRetriever(idx, regime="pruned", block_size=block_size, frag=512,
                            tile=tile, reorder="signature")
        ids, vals = r.retrieve_batch(queries, k)
        out[variant] = _check_topk_vs_oracle(idx, ids, vals, queries, k)
    return out


def bench_schemes(n_docs: int, n_vocab: int, *, batch: int = 2,
                  k: int = 10, block_size: int = 64, avg_len: int = 60,
                  tile: int = 2048) -> dict:
    """Microbench: signature vs minhash — skip rate and pass cost.

    Justifies the ``"signature"`` default: the top-weight sort clusters
    on exactly the per-token maxima the bounds sum over, minhash on raw
    token-set overlap.
    """
    from repro.sparse.reorder import signature_permutation

    corpus = zipf_corpus(n_docs, n_vocab, avg_len=avg_len)
    idx = build_index(corpus, n_vocab, params=BM25Params())
    out = {}
    for mode in ("none", "signature", "minhash"):
        t0 = time.perf_counter()
        signature_permutation(idx, mode=mode)
        t_pass = time.perf_counter() - t0
        r = DeviceRetriever(idx, regime="pruned", block_size=block_size, frag=512,
                            tile=tile, reorder=mode)
        sr = _avg_skip_rate(r, range(N_SKIP_BATCHES), n_vocab, batch, k)
        out[mode] = {
            "pruned_skip_rate": round(sr, 4),
            "perm_pass_s": round(t_pass, 4),
        }
    return out


def snapshot_roundtrip(n_docs: int = 2_000, n_vocab: int = 3_000, *,
                       block_size: int = 64, tile: int = 2048) -> dict:
    """Save → corrupt perm (+ its replica) → load recovers EXACTLY.

    The acceptance demo for the perm recovery rung: with both perm
    copies gone the loader recomputes the signature permutation from the
    client-order postings, verifies it against the manifest checksum,
    and serves identical results.
    """
    import os
    import shutil
    import tempfile

    from repro.sparse import snapshot
    from repro.sparse.block_csr import DeviceIndex

    corpus = zipf_corpus(n_docs, n_vocab, avg_len=40)
    idx = build_index(corpus, n_vocab, params=BM25Params())
    rng = np.random.default_rng(11)
    queries = _profile_queries(rng, "head_mixed", n_vocab, 4, q_len=5)
    r = DeviceRetriever(idx, regime="pruned", block_size=block_size, frag=512, tile=tile,
                        reorder="signature")
    want_ids, want_vals = r.retrieve_batch(queries, 10)

    path = tempfile.mkdtemp(prefix="bench6-snap-")
    try:
        r.save(path)
        with open(os.path.join(path, "CURRENT")) as fh:
            gen = json.load(fh)["generation"]
        for name in ("perm.bin", "perm.dup.bin"):
            f = os.path.join(path, gen, name)
            with open(f, "r+b") as fh:
                fh.seek(8)
                b = fh.read(1)
                fh.seek(8)
                fh.write(bytes([b[0] ^ 0xFF]))
        di = DeviceIndex.load(path)
        hops = list(di.snapshot_report["hops"])
        r2 = DeviceRetriever(None, regime="pruned", block_size=block_size, frag=512,
                             tile=tile, device_index=di)
        got_ids, got_vals = r2.retrieve_batch(queries, 10)
        exact = (np.array_equal(np.asarray(want_ids), np.asarray(got_ids))
                 and np.array_equal(np.asarray(want_vals),
                                    np.asarray(got_vals)))
        return {"recovery_hops": hops, "recovered_exactly": bool(exact),
                "loads_counted": int(snapshot.COUNTERS["loads"] > 0)}
    finally:
        shutil.rmtree(path, ignore_errors=True)


def run(*, fast: bool = False) -> dict:
    if fast:
        # 8k docs is the smallest corpus where the averaged gain is
        # reliably positive (at 3k docs / 47 blocks even the 16-batch
        # mean is seed noise); still CI-smoke cheap
        grid = [(8_000, 8_000, 2, 10), (8_000, 8_000, 4, 10)]
        scheme_cell = (8_000, 8_000)
        variant_cell = (2_000, 3_000)
    else:
        grid = [(20_000, 10_000, 2, 10), (50_000, 10_000, 2, 10),
                (50_000, 10_000, 4, 10), (50_000, 10_000, 2, 4)]
        scheme_cell = (20_000, 10_000)
        variant_cell = (10_000, 8_000)
    cells = [bench_reorder_cell(n, v, batch=b, k=k,
                                repeats=3 if n >= 20_000 else 6)
             for n, v, b, k in grid]
    schemes = bench_schemes(*scheme_cell)
    variants = bench_variants(*variant_cell)
    roundtrip = snapshot_roundtrip()
    return {
        "cells": cells,
        "schemes": schemes,
        "variants_topk_exact": variants,
        "snapshot_roundtrip": roundtrip,
        "summary": {
            "skip_rate_gains": [c["skip_rate_gain"] for c in cells],
            "reordered_above_random_everywhere": all(
                c["pruned_skip_rate_signature"]
                > c["pruned_skip_rate_none"] for c in cells),
            "topk_exact_all_cells": all(
                c["topk_exact_vs_oracle"] for c in cells),
            "topk_exact_all_variants": all(variants.values()),
            "max_reorder_overhead_frac": max(
                c["reorder_overhead_frac"] for c in cells),
            "max_reorder_overhead_frac_e2e": max(
                c["reorder_overhead_frac_e2e"] for c in cells),
            # the remap is a host gather: reordered serving never ships
            # MORE bytes than random order — postings are byte-equal
            # (zero resident), and the descriptor table can only shrink
            # (clustering concentrates each token's postings into fewer
            # blocks, so the fragment count — and its pow2 bucket — drops
            # at some cells; e.g. 50k docs / batch 4 halves it)
            "reordered_bytes_le_none": all(
                c["posting_bytes_per_batch_reordered"]
                == c["posting_bytes_per_batch_none"]
                and c["descriptor_bytes_per_batch_reordered"]
                <= c["descriptor_bytes_per_batch_none"]
                for c in cells),
            "snapshot_roundtrip_exact":
                roundtrip["recovered_exactly"],
            "note": "CPU wall times (Pallas kernels in interpret mode) — "
                    "compare skip rates and relative latency, not "
                    "absolute seconds. Exactness contract: ids identical "
                    "to the scipy oracle except inside bit-equal score "
                    "ties, where the returned id provably achieves the "
                    "tied score; scores within 1e-4 (the tier-1 device "
                    "tolerance).",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="tiny corpora (CI bench-smoke sized)")
    ap.add_argument("--force", action="store_true",
                    help="allow a --fast run to overwrite a full-scale "
                         "artifact")
    ap.add_argument("--out", default="BENCH_6.json")
    args = ap.parse_args()
    t0 = time.time()
    result = run(fast=args.fast)
    for c in result["cells"]:
        print("bench6_reorder," + ",".join(f"{k}={v}"
                                           for k, v in c.items()),
              flush=True)
    print("bench6_schemes," + json.dumps(result["schemes"]))
    print("bench6_summary," + ",".join(
        f"{k}={v}" for k, v in result["summary"].items()))
    _guarded_write(args.out, result, fast=args.fast, force=args.force)
    print(f"done in {time.time() - t0:.1f}s -> {args.out}")


if __name__ == "__main__":
    main()
