"""SASRec [arXiv:1808.09781]: self-attentive sequential recommendation.

embed_dim=50 (paper's MovieLens setting — deliberately NOT padded to an
MXU-friendly 64; the alignment waste shows up in the roofline table),
2 blocks, 1 head, seq_len=50. Item catalog sized 2^20 so the
``retrieval_cand`` cell scores the full catalog.
"""

from ..models.recsys import RecsysConfig, reduced
from .common import recsys_cells

CONFIG = RecsysConfig(
    name="sasrec", model="sasrec",
    vocab_sizes=(1_048_576,), embed_dim=50,
    n_blocks=2, n_heads=1, seq_len=50,
)

SMOKE = reduced(CONFIG)

FAMILY = "recsys"


def cells():
    return recsys_cells("sasrec", CONFIG)
