"""EGNN — E(n)-equivariant graph network (Satorras et al., arXiv:2102.09844).

Edge-list message passing on the shared sparse substrate (DESIGN.md §2):
message construction is a gather over ``(src, dst)`` index arrays, and
aggregation is the same segment-sum primitive as BM25 scoring — the kernel
regime the assignment calls "cheap equivariant" (scalar-distance MLP, no
spherical harmonics).

Per layer l (m_ij over directed edges):
    m_ij      = φ_e(h_i, h_j, ‖x_i − x_j‖², a_ij)
    x_i'      = x_i + mean_j (x_i − x_j) · φ_x(m_ij)        (equivariant)
    h_i'      = φ_h(h_i, Σ_j m_ij)                           (invariant)

Graphs are static-shape: ``edges [E, 2]`` int32 with -1 padding; batched
small graphs are flattened with a ``graph_ids`` vector for the readout.

Distribution: edges sharded over the mesh, node tensors replicated; the
per-layer psum of the aggregated messages is the collective-bound roofline
cell (EXPERIMENTS.md).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .common import normal_init, split_keys
from ..sparse.segment_ops import segment_mean, segment_sum


@dataclass(frozen=True)
class EGNNConfig:
    name: str
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 64            # input node-feature dim
    d_edge: int = 0             # input edge-attribute dim (0 = none)
    n_out: int = 1              # classes (nodes) or regression dims (graph)
    readout: str = "node"       # "node" | "graph"
    coord_dim: int = 3
    dtype: Any = jnp.float32


def _mlp_init(key, dims):
    ks = split_keys(key, len(dims) - 1)
    return [{"w": normal_init(k, (a, b), 1.0 / np.sqrt(a)),
             "b": jnp.zeros((b,))}
            for k, (a, b) in zip(ks, zip(dims[:-1], dims[1:]))]


def _mlp(params, x, act=jax.nn.silu, last_act=False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or last_act:
            x = act(x)
    return x


def init_params(key, cfg: EGNNConfig) -> dict:
    d = cfg.d_hidden
    ks = iter(split_keys(key, 3 + 4 * cfg.n_layers))
    params = {
        "proj_in": {"w": normal_init(next(ks), (cfg.d_feat, d),
                                     1.0 / np.sqrt(cfg.d_feat)),
                    "b": jnp.zeros((d,))},
        "layers": [],
        "head": _mlp_init(next(ks), (d, d, cfg.n_out)),
    }
    edge_in = 2 * d + 1 + cfg.d_edge
    for _ in range(cfg.n_layers):
        params["layers"].append({
            "phi_e": _mlp_init(next(ks), (edge_in, d, d)),
            "phi_x": _mlp_init(next(ks), (d, d, 1)),
            "phi_h": _mlp_init(next(ks), (2 * d, d, d)),
        })
    return params


def _layer(cfg: EGNNConfig, lp: dict, h, x, src, dst, edge_attr, valid,
           n_nodes: int):
    """One EGNN layer over the (padded) directed edge list."""
    hi, hj = h[dst], h[src]                       # messages flow src -> dst
    xi, xj = x[dst], x[src]
    diff = xi - xj                                # [E, 3]
    dist2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
    feats = [hi, hj, dist2]
    if edge_attr is not None:
        feats.append(edge_attr)
    m = _mlp(lp["phi_e"], jnp.concatenate(feats, axis=-1), last_act=True)
    m = m * valid[:, None]

    # equivariant coordinate update (mean over incoming edges)
    coef = _mlp(lp["phi_x"], m)                   # [E, 1]
    upd = diff * coef * valid[:, None]
    seg = jnp.where(valid > 0, dst, n_nodes)      # padding -> sentinel
    x = x + segment_mean(upd, seg, n_nodes)

    # invariant feature update (sum aggregation)
    agg = segment_sum(m, seg, n_nodes)
    h = h + _mlp(lp["phi_h"], jnp.concatenate([h, agg], axis=-1))
    return h, x


def forward(cfg: EGNNConfig, params: dict, batch: dict
            ) -> tuple[jax.Array, jax.Array]:
    """batch: node_feat [N,F], coords [N,3], edges [E,2] (-1 pad),
    optional edge_attr [E,De], optional graph_ids [N] (graph readout).
    Returns (predictions, final coords)."""
    nf = batch["node_feat"].astype(cfg.dtype)
    x = batch["coords"].astype(cfg.dtype)
    edges = batch["edges"]
    valid = (edges[:, 0] >= 0).astype(cfg.dtype)
    src = jnp.maximum(edges[:, 0], 0)
    dst = jnp.maximum(edges[:, 1], 0)
    n_nodes = nf.shape[0]
    edge_attr = batch.get("edge_attr")

    h = nf @ params["proj_in"]["w"] + params["proj_in"]["b"]
    layer = jax.checkpoint(
        lambda lp, h, x: _layer(cfg, lp, h, x, src, dst, edge_attr, valid,
                                n_nodes),
        policy=jax.checkpoint_policies.nothing_saveable)
    for lp in params["layers"]:
        h, x = layer(lp, h, x)   # remat: messages recomputed in backward

    if cfg.readout == "graph":
        gid = batch["graph_ids"]
        n_graphs = int(batch["n_graphs"])
        pooled = segment_sum(h, gid, n_graphs)
        return _mlp(params["head"], pooled), x
    return _mlp(params["head"], h), x


def loss_fn(cfg: EGNNConfig, params: dict, batch: dict
            ) -> tuple[jax.Array, dict]:
    pred, _ = forward(cfg, params, batch)
    if cfg.readout == "graph":
        target = batch["targets"]                          # [G, n_out]
        loss = jnp.mean((pred - target) ** 2)
        return loss, {"loss": loss, "mse": loss}
    labels = batch["labels"]                               # [N] (-1 = unlabeled)
    mask = (labels >= 0).astype(jnp.float32)
    logits = pred.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[:, None], axis=-1)[:, 0]
    ce = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    acc = (((jnp.argmax(logits, -1) == labels) * mask).sum()
           / jnp.maximum(mask.sum(), 1.0))
    return ce, {"loss": ce, "acc": acc}


def reduced(cfg: EGNNConfig, **overrides) -> EGNNConfig:
    small = dict(n_layers=2, d_hidden=16)
    small.update(overrides)
    return replace(cfg, **small)
