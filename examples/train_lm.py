"""Train a small LM (~13M params) for a few hundred steps on CPU with the
full production stack: microbatched AdamW, cosine schedule, checkpointing,
auto-resume, int8 gradient compression (optional).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--compress]
"""

import argparse
import functools

import jax
import jax.numpy as jnp

from repro.data.lm import lm_batches
from repro.models import transformer
from repro.train import AdamW, cosine_schedule, init_train_state, \
    make_train_step
from repro.train.loop import LoopConfig, run_training

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--compress", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
args = ap.parse_args()

cfg = transformer.LMConfig(
    name="tiny-lm", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
    head_dim=32, d_ff=1024, vocab_size=4096, sliding_window=64,
    seq_chunk=64, loss_chunk=64, dtype=jnp.float32)

params = transformer.init_params(jax.random.PRNGKey(0), cfg)
n_params = sum(x.size for x in jax.tree.leaves(params))
print(f"model: {n_params / 1e6:.1f}M params")

opt = AdamW(lr=cosine_schedule(peak_lr=3e-3, warmup_steps=30,
                               total_steps=args.steps))
step = jax.jit(make_train_step(
    functools.partial(transformer.loss_fn, cfg), opt,
    n_microbatches=2, compress=args.compress))
state = init_train_state(params, opt, compress=args.compress)

gen = lm_batches(vocab_size=cfg.vocab_size, batch=8, seq_len=128)
batches = (jax.tree.map(jnp.asarray, b) for b in gen)


def log(s, m):
    print(f"step {s:4d}  loss {m['loss']:.4f}  lr {m['lr']:.2e}  "
          f"gnorm {m['grad_norm']:.2f}", flush=True)


loop_cfg = LoopConfig(total_steps=args.steps, ckpt_every=100,
                      ckpt_dir=args.ckpt_dir, metrics_cb=log, log_every=20)
params, state = run_training(step, (params, state), batches, loop_cfg)
print("done; checkpoints in", args.ckpt_dir,
      "(rerun to see auto-resume skip finished steps)")
