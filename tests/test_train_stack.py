"""Training stack: optimizer, schedules, microbatching, compression,
checkpointing, fault tolerance, convergence."""

import functools
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.train import AdamW, cosine_schedule, init_train_state, \
    make_train_step
from repro.train.checkpoint import (latest_complete_step, load_checkpoint,
                                    save_checkpoint)
from repro.train.loop import LoopConfig, run_training


def _quadratic_loss(params, batch):
    err = params["w"] - batch["target"]
    return jnp.sum(err * err), {}


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.ones((8,)) * 5.0}
    opt = AdamW(lr=0.2, weight_decay=0.0)
    step = jax.jit(make_train_step(_quadratic_loss, opt))
    state = init_train_state(params, opt)
    batch = {"target": jnp.zeros((8,))}
    for _ in range(100):
        params, state, m = step(params, state, batch)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_cosine_schedule_shape():
    lr = cosine_schedule(peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr(jnp.asarray(0))) < 0.2
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=0.1)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, abs=0.02)


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    opt = AdamW(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
    step = jax.jit(make_train_step(_quadratic_loss, opt))
    state = init_train_state(params, opt)
    _, _, m = step(params, state, {"target": jnp.ones((4,)) * 1e6})
    assert float(m["grad_norm"]) > 1.0   # pre-clip norm reported


def test_microbatch_equals_full_batch():
    """Grad accumulation over M microbatches == one big batch (linear loss)."""
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
    batch = {"x": jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32)),
             "y": jnp.asarray(rng.normal(size=(16,)).astype(np.float32))}
    opt = AdamW(lr=0.01, weight_decay=0.0, clip_norm=None)
    outs = {}
    for m in (1, 4):
        step = jax.jit(make_train_step(loss_fn, opt, n_microbatches=m))
        state = init_train_state(params, opt)
        p, _, _ = step(params, state, batch)
        outs[m] = np.asarray(p["w"])
    # microbatch mean-of-means == full mean for equal-size microbatches
    np.testing.assert_allclose(outs[1], outs[4], rtol=1e-5, atol=1e-6)


def test_int8_compression_tracks_fp32():
    """Compressed training converges to the same loss region on a tiny LM."""
    from repro.data.lm import lm_batches
    from repro.models import transformer
    cfg = transformer.LMConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                               n_kv_heads=2, d_ff=64, vocab_size=64,
                               head_dim=8, seq_chunk=16, loss_chunk=16,
                               dtype=jnp.float32)
    params0 = transformer.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=1e-2)
    gen = lm_batches(vocab_size=64, batch=8, seq_len=16, seed=0)
    batches = [next(gen) for _ in range(25)]
    finals = {}
    for compress in (False, True):
        step = jax.jit(make_train_step(
            functools.partial(transformer.loss_fn, cfg), opt,
            compress=compress))
        params = jax.tree.map(jnp.copy, params0)
        state = init_train_state(params, opt, compress=compress)
        losses = []
        for b in batches:
            params, state, m = step(params, state,
                                    jax.tree.map(jnp.asarray, b))
            losses.append(float(m["loss"]))
        finals[compress] = losses
    assert finals[True][-1] < 0.8 * finals[True][0]          # it learns
    assert abs(finals[True][-1] - finals[False][-1]) < \
        0.15 * finals[False][-1]                             # tracks fp32


def test_checkpoint_roundtrip_and_corruption_fallback(tmp_path):
    state = ({"w": jnp.arange(4.0)}, {"m": jnp.ones((2, 2))})
    d = str(tmp_path)
    save_checkpoint(d, 10, state)
    save_checkpoint(d, 20, state)
    assert latest_complete_step(d) == 20
    # corrupt newest: truncate the data file
    f = os.path.join(d, "step_000020", "host_000.npz")
    with open(f, "r+b") as fh:
        fh.truncate(10)
    assert latest_complete_step(d) == 10                     # falls back
    restored = load_checkpoint(d, 10, state)
    np.testing.assert_array_equal(np.asarray(restored[0]["w"]),
                                  np.arange(4.0))


def test_loop_auto_resume_and_fault_retry(tmp_path):
    params = {"w": jnp.ones((4,)) * 3.0}
    opt = AdamW(lr=0.1, weight_decay=0.0)
    step = jax.jit(make_train_step(_quadratic_loss, opt))
    batches = iter(lambda: {"target": jnp.zeros((4,))}, None)

    faults = {"n": 0}

    def fault_hook(s):
        if s == 7 and faults["n"] < 1:       # one transient failure at step 7
            faults["n"] += 1
            raise RuntimeError("injected preemption")

    seen = []
    cfg = LoopConfig(total_steps=10, ckpt_every=4, ckpt_dir=str(tmp_path),
                     metrics_cb=lambda s, m: seen.append(s),
                     fault_hook=fault_hook, log_every=1)
    state = init_train_state(params, opt)
    p1, s1 = run_training(step, (params, state), batches, cfg)
    assert faults["n"] == 1                  # fault happened and was retried
    assert latest_complete_step(str(tmp_path)) == 10
    # resume: raising total_steps continues from step 10, not 0
    cfg2 = LoopConfig(total_steps=12, ckpt_every=4, ckpt_dir=str(tmp_path),
                      metrics_cb=lambda s, m: seen.append(s), log_every=1)
    run_training(step, (params, state), batches, cfg2)
    assert min(s for s in seen if s > 10) == 11   # continued, didn't restart
