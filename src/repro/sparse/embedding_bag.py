"""EmbeddingBag in JAX: ``jnp.take`` + segment reduction.

JAX has no native ``nn.EmbeddingBag``; per the brief this IS part of the
system. Bags are fixed-fanout ``(B, F)`` index arrays (recsys multi-hot
fields, GNN sampled neighborhoods) with optional per-sample weights and a
``-1`` padding convention.

The gather is a plain ``jnp.take`` so XLA can turn it into a fused dynamic
gather; with row-sharded tables under ``jit`` the gather lowers to the
cross-device collectives counted in the roofline table. A Pallas
DMA-pipelined version lives in ``kernels/embedding_bag.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table: jax.Array, indices: jax.Array,
                  weights: jax.Array | None = None, *,
                  combiner: str = "sum") -> jax.Array:
    """Gather-and-reduce: table [V, D], indices [..., F] -> [..., D].

    ``indices == -1`` are padding (contribute zero; excluded from "mean").
    """
    valid = indices >= 0
    safe = jnp.where(valid, indices, 0)
    rows = jnp.take(table, safe, axis=0)                 # [..., F, D]
    w = valid.astype(table.dtype)
    if weights is not None:
        w = w * weights
    rows = rows * w[..., None]
    if combiner == "sum":
        return rows.sum(axis=-2)
    if combiner == "mean":
        denom = jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-9)
        return rows.sum(axis=-2) / denom
    if combiner == "max":
        neg = jnp.where(valid[..., None], rows,
                        jnp.finfo(table.dtype).min)
        out = neg.max(axis=-2)
        any_valid = valid.any(axis=-1, keepdims=True)
        return jnp.where(any_valid, out, 0.0)
    raise ValueError(f"unknown combiner {combiner!r}")


def multi_table_lookup(tables: list[jax.Array], indices: jax.Array
                       ) -> jax.Array:
    """Per-field single-hot lookup: indices [B, n_fields] -> [B, n_fields, D].

    Recsys convention: one embedding table per categorical field, all with
    the same dim. Fields with huge vocabs may be row-sharded; the stacked
    form (`stacked_table_lookup`) is preferred under jit for those.
    """
    cols = [jnp.take(t, indices[:, i], axis=0) for i, t in enumerate(tables)]
    return jnp.stack(cols, axis=1)


def stacked_table_lookup(table: jax.Array, offsets: jax.Array,
                         indices: jax.Array) -> jax.Array:
    """Lookup into one concatenated [Σ vocab_f, D] table.

    ``offsets[f]`` is the row offset of field ``f``; concatenating tables
    gives a single shardable array (row-sharded over "model") and a single
    gather — the layout used by the production configs.
    """
    flat = indices + offsets[None, :]
    return jnp.take(table, flat, axis=0)
