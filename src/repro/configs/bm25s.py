"""The paper's own architecture: BM25S eager-sparse retrieval at pod scale.

Corpus: the paper's footnote-13 example — 2M documents, 200K vocabulary
(the dense score matrix would be 1.6 TB; eager-sparse is ~250M postings).
Queries arrive in batches of 256, ≤32 unique tokens each.

Two device cells (extra, beyond the 40 assigned cells):

  score_2m          — paper-faithful path: documents sharded over every mesh
                      axis, per-shard gather+segment_sum scoring (shard_map),
                      per-shard top-k, all-gather k·shards candidates, global
                      merge. Collective volume O(shards·k·8B).
  score_blocked_2m  — beyond-paper batched path (DESIGN.md §3.2/3.3): the
                      block-bucketed layout streamed once for the whole query
                      batch; scatter lowered as one-hot matmul on the MXU.
                      Lowered from the pure-jnp kernel oracle so the HLO is
                      shardable; the Pallas kernel is the TPU codegen of the
                      same contraction.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.variants import BM25Params
from .common import Cell, sds

N_DOCS = 2_097_152            # 2M docs (paper footnote 13 example)
N_VOCAB = 200_000
AVG_UNIQUE_TOKENS = 120       # postings per doc
QUERY_BATCH = 256
Q_MAX = 32
P_MAX = 16_384                # per-shard posting budget per query
TOP_K = 100
DOC_BLOCK = 512
U_MAX = 2048                  # unique tokens across the query batch

PARAMS = BM25Params(method="lucene", k1=1.5, b=0.75)

FAMILY = "bm25s"
CONFIG = dict(n_docs=N_DOCS, n_vocab=N_VOCAB, params=PARAMS)
SMOKE = dict(n_docs=512, n_vocab=256, params=PARAMS)


def _score_2m_cell() -> Cell:
    def build(mesh):
        from ..core.retrieval import make_sharded_retrieve
        axes = tuple(mesh.shape.keys())
        n_shards = int(np.prod(list(mesh.shape.values())))
        docs_per_shard = N_DOCS // n_shards
        nnz_per_shard = N_DOCS * AVG_UNIQUE_TOKENS // n_shards
        nnz_pad = int(-(-nnz_per_shard // 1024) * 1024)
        fn = make_sharded_retrieve(mesh, axes, p_max=P_MAX, k=TOP_K,
                                   n_docs_per_shard=docs_per_shard)
        idx_arrays = (
            sds((n_shards, N_VOCAB + 1), jnp.int32),   # indptr
            sds((n_shards, nnz_pad), jnp.int32),       # doc_ids
            sds((n_shards, nnz_pad), jnp.float32),     # scores
            sds((n_shards, N_VOCAB), jnp.float32),     # nonoccurrence
            sds((n_shards, 1), jnp.int32),             # offsets
            sds((n_shards, 1), jnp.int32),             # true doc counts
        )
        return fn, (idx_arrays,
                    sds((QUERY_BATCH, Q_MAX), jnp.int32),
                    sds((QUERY_BATCH, Q_MAX), jnp.float32))

    def shardings(mesh, args):
        idx_arrays, qt, qw = args
        axes = tuple(mesh.shape.keys())
        sh = tuple(NamedSharding(mesh, P(axes)) for _ in idx_arrays)
        return (sh, NamedSharding(mesh, P()), NamedSharding(mesh, P()))

    # useful work: gather+add of each query's postings on every shard
    flops = 2.0 * QUERY_BATCH * P_MAX * 1.0
    return Cell("bm25s", "score_2m", "retrieval", build, shardings, flops,
                note="paper-faithful gather+segment_sum (extra cell)")


def _score_blocked_cell(*, doc_block: int = DOC_BLOCK,
                        batch: int = QUERY_BATCH, u_max: int = U_MAX,
                        score_dtype=jnp.float32,
                        sharded_topk: bool = False,
                        note: str = "beyond-paper batched MXU path "
                                    "(extra cell)") -> Cell:
    n_blocks = N_DOCS // doc_block
    nnz_pad = int(-(-AVG_UNIQUE_TOKENS * doc_block // 512) * 512)

    def build(mesh):
        from jax.experimental.shard_map import shard_map
        from ..kernels.ref import bm25_block_score_ref
        from ..core.retrieval import blockwise_topk
        axes = tuple(mesh.shape.keys())
        ax_sizes = [mesh.shape[a] for a in axes]
        n_shards = int(np.prod(ax_sizes))

        if sharded_topk:
            # GSPMD replicates the batched scatter-add output (it cannot
            # prove block-locality), gathering the full [C, B] scores to
            # every chip. shard_map makes the block-locality explicit:
            # per-shard scoring + per-shard top-k, merge only [S, B, K].
            per = n_blocks // n_shards
            docs_local = per * doc_block

            def local_fn(tok, loc, sc, uniq, weights):
                out = bm25_block_score_ref(tok, loc, sc, uniq, weights,
                                           block_size=doc_block)
                flat = jnp.transpose(out, (2, 0, 1)).reshape(
                    batch, docs_local)
                lv, li = jax.lax.top_k(flat, TOP_K)       # [B, K] local
                sid = jnp.zeros((), jnp.int32)
                for a in axes:
                    sid = sid * mesh.shape[a] + jax.lax.axis_index(a)
                gi = li + sid * docs_local
                return lv[None], gi[None]                 # keep shard dim

            smapped = shard_map(
                local_fn, mesh=mesh,
                in_specs=(P(axes, None), P(axes, None), P(axes, None),
                          P(), P()),
                out_specs=(P(axes, None, None), P(axes, None, None)))

            def fn(token_ids, local_doc, scores, uniq, weights):
                lv, gi = smapped(token_ids, local_doc, scores, uniq, weights)
                allv = jnp.transpose(lv, (1, 0, 2)).reshape(batch, -1)
                alli = jnp.transpose(gi, (1, 0, 2)).reshape(batch, -1)
                mv, mi = jax.lax.top_k(allv, TOP_K)
                return jnp.take_along_axis(alli, mi, axis=-1), mv
        else:
            def fn(token_ids, local_doc, scores, uniq, weights):
                out = bm25_block_score_ref(token_ids, local_doc, scores,
                                           uniq, weights,
                                           block_size=doc_block)
                flat = jnp.transpose(out, (2, 0, 1)).reshape(
                    batch, n_blocks * doc_block)
                idx, vals = blockwise_topk(flat, TOP_K, block=4096)
                return idx, vals

        return fn, (sds((n_blocks, nnz_pad), jnp.int32),
                    sds((n_blocks, nnz_pad), jnp.int32),
                    sds((n_blocks, nnz_pad), score_dtype),
                    sds((u_max,), jnp.int32),
                    sds((u_max, batch), score_dtype))

    def shardings(mesh, args):
        axes = tuple(mesh.shape.keys())
        blk = NamedSharding(mesh, P(axes, None))
        return (blk, blk, blk, NamedSharding(mesh, P()),
                NamedSharding(mesh, P()))

    # useful work: one multiply-add per (posting, query) with avg df hit rate
    flops = 2.0 * batch * N_DOCS * AVG_UNIQUE_TOKENS * (Q_MAX / N_VOCAB)
    return Cell("bm25s", "score_blocked_2m", "retrieval", build, shardings,
                flops, note=note)


def cells() -> list[Cell]:
    return [_score_2m_cell(), _score_blocked_cell()]
