"""EGNN equivariance/invariance properties (the paper's defining test)."""

import numpy as np
from conftest import given, settings, st

import jax
import jax.numpy as jnp

from repro.models import egnn


def _setup(seed, n_out=3, readout="node"):
    cfg = egnn.EGNNConfig(name="e", n_layers=2, d_hidden=16, d_feat=8,
                          n_out=n_out, readout=readout)
    params = egnn.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    n, e = 20, 50
    batch = {
        "node_feat": jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32)),
        "coords": jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32)),
        "edges": jnp.asarray(rng.integers(0, n, size=(e, 2)).astype(np.int32)),
    }
    return cfg, params, batch, rng


def _rotation(rng):
    a = rng.normal(size=(3, 3))
    q, _ = np.linalg.qr(a)
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return jnp.asarray(q.astype(np.float32))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_en_equivariance(seed):
    """h invariant, x equivariant under any rotation + translation."""
    cfg, params, batch, rng = _setup(seed % 7)
    rng = np.random.default_rng(seed)
    r = _rotation(rng)
    t = jnp.asarray(rng.normal(size=3).astype(np.float32))
    pred1, x1 = egnn.forward(cfg, params, batch)
    b2 = dict(batch)
    b2["coords"] = batch["coords"] @ r.T + t
    pred2, x2 = egnn.forward(cfg, params, b2)
    np.testing.assert_allclose(np.asarray(pred1), np.asarray(pred2),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(x1 @ r.T + t), np.asarray(x2),
                               rtol=2e-3, atol=2e-3)


def test_permutation_equivariance():
    """Relabeling nodes permutes outputs correspondingly."""
    cfg, params, batch, rng = _setup(3)
    n = batch["node_feat"].shape[0]
    perm = np.asarray(rng.permutation(n))
    inv = np.argsort(perm)
    pred1, _ = egnn.forward(cfg, params, batch)
    b2 = {
        "node_feat": batch["node_feat"][perm],
        "coords": batch["coords"][perm],
        "edges": jnp.asarray(inv.astype(np.int32))[
            jnp.maximum(batch["edges"], 0)],
    }
    pred2, _ = egnn.forward(cfg, params, b2)
    np.testing.assert_allclose(np.asarray(pred1[perm]), np.asarray(pred2),
                               rtol=1e-3, atol=1e-3)


def test_padding_edges_are_inert():
    cfg, params, batch, _ = _setup(5)
    pred1, x1 = egnn.forward(cfg, params, batch)
    pad = jnp.full((10, 2), -1, jnp.int32)
    b2 = dict(batch)
    b2["edges"] = jnp.concatenate([batch["edges"], pad])
    pred2, x2 = egnn.forward(cfg, params, b2)
    np.testing.assert_allclose(np.asarray(pred1), np.asarray(pred2),
                               rtol=1e-5, atol=1e-5)


def test_neighbor_sampler_shapes_static():
    from repro.data.graphs import neighbor_sample, random_graph
    g = random_graph(200, 6, d_feat=8, n_classes=3, seed=0)
    rng = np.random.default_rng(0)
    # seeds with nonzero in-degree so the subgraph is non-trivial
    seeds = np.unique(g.edges[:, 1])[:16]
    b1 = neighbor_sample(g, seeds[:8], (4, 3), rng=rng)
    b2 = neighbor_sample(g, seeds[8:16], (4, 3), rng=rng)
    for k in ("node_feat", "coords", "edges", "labels"):
        assert b1[k].shape == b2[k].shape       # jit-stable shapes
    assert (b1["labels"][:8] >= 0).all() and (b1["labels"][8:] == -1).all()
    # every edge's endpoints are within the sampled node set
    e = b1["edges"][b1["edges"][:, 0] >= 0]
    assert e.size > 0 and e.max() < b1["node_feat"].shape[0]


def test_egnn_molecule_training_reduces_loss():
    from repro.data.graphs import batched_molecules
    from repro.train import AdamW, init_train_state, make_train_step
    import functools
    cfg = egnn.EGNNConfig(name="m", n_layers=2, d_hidden=16, d_feat=11,
                          n_out=1, readout="graph")
    params = egnn.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=3e-3)
    base = make_train_step(functools.partial(egnn.loss_fn, cfg), opt)
    n_graphs = 16
    step = jax.jit(lambda p, s, b: base(p, s, dict(b, n_graphs=n_graphs)))
    state = init_train_state(params, opt)
    batch = batched_molecules(n_graphs, n_nodes=10, n_edges=16)
    batch.pop("n_graphs")
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    losses = []
    for _ in range(30):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.5 * losses[0], losses[::10]
