"""Exact reproduction of the five Kamphuis et al. (2020) BM25 variants +
the §2.1 score-shifting identity, pinned against the brute-force oracle."""

import numpy as np
import pytest

from conftest import make_corpus
from repro.core import (BM25Params, ScipyBM25, build_index,
                        dense_oracle_scores, get_variant)
from repro.core.variants import VARIANTS, dense_score_matrix

METHODS = ["robertson", "atire", "lucene", "bm25l", "bm25+", "tfldp"]


@pytest.mark.parametrize("method", METHODS)
def test_eager_index_matches_lazy_oracle(method, rng):
    corpus = make_corpus(rng)
    n_vocab = 50
    p = BM25Params(method=method)
    idx = build_index(corpus, n_vocab, params=p)
    scorer = ScipyBM25(idx)
    for _ in range(5):
        q = rng.integers(0, n_vocab, size=rng.integers(1, 6)).astype(np.int32)
        oracle = dense_oracle_scores(corpus, n_vocab, q, p)
        np.testing.assert_allclose(scorer.score(q), oracle, atol=1e-4)


@pytest.mark.parametrize("method", ["bm25l", "bm25+", "tfldp"])
def test_shifted_variants_store_differential(method, rng):
    """Shifted variants: stored matrix is SΔ = S − S⁰ (sparse), and the
    nonoccurrence vector is nonzero (the whole point of §2.1)."""
    corpus = make_corpus(rng)
    p = BM25Params(method=method)
    idx = build_index(corpus, 50, params=p)
    assert idx.is_shifted
    assert (idx.nonoccurrence != 0).any()


@pytest.mark.parametrize("method", ["robertson", "atire", "lucene"])
def test_sparse_variants_have_zero_shift(method, rng):
    corpus = make_corpus(rng)
    idx = build_index(corpus, 50, params=BM25Params(method=method))
    assert not idx.is_shifted
    np.testing.assert_array_equal(idx.nonoccurrence, 0.0)


@pytest.mark.parametrize("method", METHODS)
def test_score_shift_identity_vs_dense_matrix(method, rng):
    """S(t,D) == SΔ(t,D) + S⁰(t) for every (t, D), via the dense oracle."""
    corpus = make_corpus(rng, n_docs=25, n_vocab=30, max_len=15)
    n_vocab = 30
    p = BM25Params(method=method)
    variant = get_variant(method)
    tf = np.zeros((n_vocab, len(corpus)))
    for d, toks in enumerate(corpus):
        np.add.at(tf[:, d], toks, 1)
    dl = np.array([t.size for t in corpus], dtype=np.float64)
    dense = dense_score_matrix(tf, len(corpus), dl, variant, p)

    idx = build_index(corpus, n_vocab, params=p)
    recon = np.zeros_like(dense)
    df = np.diff(idx.indptr)
    tok_of = np.repeat(np.arange(n_vocab), df)
    recon[tok_of, idx.doc_ids] = idx.scores            # SΔ
    recon += np.where(df[:, None] > 0, idx.nonoccurrence[:, None], 0.0)
    np.testing.assert_allclose(recon, dense, atol=1e-4)


def test_atire_bm25plus_equal_ranks(rng):
    """Table 3: ATIRE and BM25+ produce near-identical rankings at k1=1.2."""
    corpus = make_corpus(rng, n_docs=100)
    q = rng.integers(0, 50, size=5).astype(np.int32)
    outs = {}
    for m in ("atire", "bm25+"):
        p = BM25Params(method=m, k1=1.2, b=0.75, delta=1.0)
        outs[m] = dense_oracle_scores(corpus, 50, q, p)
    ra = np.argsort(-outs["atire"], kind="stable")[:10]
    rb = np.argsort(-outs["bm25+"], kind="stable")[:10]
    assert len(set(ra[:5]) & set(rb[:5])) >= 4


def test_unknown_variant_raises():
    with pytest.raises(ValueError):
        get_variant("bm42")


def test_all_variants_registered():
    assert {"robertson", "atire", "lucene", "bm25l", "bm25+",
            "tfldp"} <= set(VARIANTS)
