"""Crash-safe index persistence (PR-7 contract).

Pins the ``sparse.snapshot`` format and its operational guarantees:

* **round-trip** — ``save_device_index`` → ``load_device_index`` is
  bit-identical for every on-disk array across all five BM25 variants ×
  {f32, u8} block-max × {mmap, eager}, and a retriever adopting the
  loaded index serves the exact ScipyBM25 oracle answer.
* **atomicity** — a kill mid-save (injected ``torn_write``) leaves the
  PREVIOUS generation committed and loadable; a torn FIRST save yields a
  typed :class:`SnapshotIntegrityError`, never garbage.
* **recovery ladder** — each corrupted section is rebuilt exactly from
  its duplicate replica or the surviving sibling layout; double
  corruption falls back to the provided corpus; with nothing left the
  typed error names the corrupt files. Every hop lands in
  ``snapshot_report`` and the module counters.
* **cold-start invariants** — ``mmap=True`` loads hand ``np.memmap``
  views to the uploader; steady-state batches after any load ship ZERO
  posting bytes; ``host_arrays="drop"`` composes with loads.
* **engine** — ``RetrievalEngine.save``/``load`` round-trips per-shard
  runtimes (device and scipy) without rebuilding a layout.
"""

import json
import os

import numpy as np
import pytest

from conftest import make_corpus
from repro.core import BM25Params, ScipyBM25, build_index, topk_numpy
from repro.serve import (DeviceRetriever, RetrievalEngine,
                         RetrievalError, SnapshotIntegrityError,
                         SnapshotVersionError)
from repro.serve.faults import inject_faults
from repro.sparse import snapshot
from repro.sparse.block_csr import (DeviceIndex, TRANSFERS,
                                    reset_transfer_stats)

ALL_VARIANTS = ["robertson", "atire", "lucene", "bm25l", "bm25+"]

SMALL = dict(block_size=16, tile=16, frag=8)
RSMALL = dict(block_size=16, tile=16, acc_block=16, frag=8, q_max=8,
              gather="resident", plan="device")

pytestmark = pytest.mark.no_chaos      # this module ARMS faults itself


def _mk(rng, method, n_vocab=64, n_docs=90):
    corpus = make_corpus(rng, n_docs=n_docs, n_vocab=n_vocab, max_len=20)
    return corpus, build_index(corpus, n_vocab,
                               params=BM25Params(method=method))


def _queries(rng, n_vocab, n=3):
    return [rng.integers(0, n_vocab, size=rng.integers(1, 6)
                         ).astype(np.int32) for _ in range(n)]


def _di(idx, bmax_dtype="f32"):
    return DeviceIndex.build(idx, with_blocked=True, with_csc=True,
                             with_bmax=True, bmax_dtype=bmax_dtype,
                             **SMALL)


def _assert_oracle_exact(idx, qs, ids, vals, k):
    sc = ScipyBM25(idx)
    for i, q in enumerate(qs):
        ref = sc.score(q)
        _, ref_v = topk_numpy(ref[None], k)
        np.testing.assert_allclose(vals[i], ref_v[0], atol=1e-4)
        np.testing.assert_allclose(ref[ids[i] - idx.doc_offset], vals[i],
                                   atol=1e-4)


def _gen_dir(path):
    with open(os.path.join(path, "CURRENT"), encoding="utf-8") as fh:
        return os.path.join(path, json.load(fh)["generation"])


def _flip_byte(fname, offset=5):
    with open(fname, "r+b") as fh:
        fh.seek(offset)
        b = fh.read(1)
        fh.seek(offset)
        fh.write(bytes([b[0] ^ 0x10]))


# -- round-trip: 5 variants x {f32,u8} bmax x {mmap,eager} -------------------

@pytest.mark.parametrize("method", ALL_VARIANTS)
@pytest.mark.parametrize("bmax_dtype", ["f32", "u8"])
@pytest.mark.parametrize("mmap", [False, True])
def test_roundtrip_bit_identical(method, bmax_dtype, mmap, tmp_path, rng):
    corpus, idx = _mk(rng, method)
    di = _di(idx, bmax_dtype)
    path = str(tmp_path / "snap")
    di.save(path)
    ld = DeviceIndex.load(path, mmap=mmap)
    assert ld.snapshot_report["verified"] and not ld.snapshot_report["hops"]
    # every persisted array comes back bit-identical
    np.testing.assert_array_equal(ld.host.indptr, idx.indptr)
    np.testing.assert_array_equal(ld.host.doc_ids, idx.doc_ids)
    np.testing.assert_array_equal(ld.host.scores, idx.scores)
    np.testing.assert_array_equal(ld.host.nonoccurrence, idx.nonoccurrence)
    np.testing.assert_array_equal(ld.host.doc_lens, idx.doc_lens)
    for a, b in ((di.csc_doc_ids, ld.csc_doc_ids),
                 (di.csc_scores, ld.csc_scores),
                 (di.blk_tok, ld.blk_tok), (di.blk_loc, ld.blk_loc),
                 (di.blk_sc, ld.blk_sc),
                 (di.bmax.host, ld.bmax.host),
                 (di.bmax.scale, ld.bmax.scale)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ld.bmax.quantized == (bmax_dtype == "u8")
    if mmap:   # cold-start reads postings lazily through the page cache
        assert isinstance(ld.host.doc_ids.base, np.memmap) \
            or isinstance(ld.host.doc_ids, np.memmap)
    # and the adopted retriever serves the exact oracle answer
    dr = DeviceRetriever(ld.host, regime="auto", device_index=ld,
                         acc_block=16, q_max=8, gather="resident",
                         plan="device")
    qs = _queries(rng, 64)
    ids, vals = dr.retrieve_batch(qs, 7)
    _assert_oracle_exact(idx, qs, ids, vals, 7)


def test_adopted_retriever_skips_rebuild_and_matches_built(tmp_path, rng):
    """Loaded runtime == built runtime, bit for bit, with no re-upload."""
    corpus, idx = _mk(rng, "lucene")
    dr0 = DeviceRetriever(idx, regime="auto", **RSMALL)
    qs = _queries(rng, 64)
    ids0, vals0 = dr0.retrieve_batch(qs, 7)
    path = str(tmp_path / "snap")
    dr0.save(path)
    reset_transfer_stats()
    ld = DeviceIndex.load(path, mmap=True)
    uploads_after_load = TRANSFERS.posting_uploads
    assert uploads_after_load > 0          # the one cold-start upload set
    dr1 = DeviceRetriever(ld.host, regime="auto", device_index=ld, **RSMALL)
    assert dr1.dindex is ld                # adopted, not rebuilt
    assert TRANSFERS.posting_uploads == uploads_after_load
    ids1, vals1 = dr1.retrieve_batch(qs, 7)
    np.testing.assert_array_equal(np.asarray(ids0), np.asarray(ids1))
    np.testing.assert_array_equal(np.asarray(vals0), np.asarray(vals1))


def test_steady_state_posting_bytes_zero_after_load(tmp_path, rng):
    corpus, idx = _mk(rng, "lucene")
    di = _di(idx)
    path = str(tmp_path / "snap")
    di.save(path)
    ld = DeviceIndex.load(path, mmap=True)
    dr = DeviceRetriever(ld.host, regime="gathered", device_index=ld,
                         **RSMALL)
    qs = _queries(rng, 64)
    dr.retrieve_batch(qs, 7)               # compile + any lazy residency
    reset_transfer_stats()
    for _ in range(3):
        dr.retrieve_batch(qs, 7)
    assert TRANSFERS.posting_bytes == 0    # the paper-path invariant holds
    assert TRANSFERS.descriptor_bytes == 0  # device planner: nothing ships


def test_load_drop_composes(tmp_path, rng):
    corpus, idx = _mk(rng, "lucene")
    path = str(tmp_path / "snap")
    _di(idx).save(path)
    ld = DeviceIndex.load(path, mmap=True, host_arrays="drop")
    assert ld.host.doc_ids.size == 0 and ld.host.scores.size == 0
    np.testing.assert_array_equal(ld.host.indptr, idx.indptr)
    dr = DeviceRetriever(ld.host, regime="gathered", device_index=ld,
                         acc_block=16, q_max=8)
    assert dr.plan_mode == "device"        # host paths force-resolved away
    qs = _queries(rng, 64)
    ids, vals = dr.retrieve_batch(qs, 7)
    _assert_oracle_exact(idx, qs, ids, vals, 7)


def test_empty_shard_roundtrip(tmp_path, rng):
    idx = build_index([], 64)
    path = str(tmp_path / "snap")
    snapshot.save_index(idx, path, block_size=16, tile=16, frag=8)
    ld = snapshot.load_index(path, mmap=True)
    assert ld.doc_lens.size == 0 and int(ld.indptr[-1]) == 0
    np.testing.assert_array_equal(ld.indptr, idx.indptr)
    np.testing.assert_array_equal(ld.nonoccurrence, idx.nonoccurrence)


def test_host_only_roundtrip_scipy_oracle(tmp_path, rng):
    corpus, idx = _mk(rng, "bm25+")
    path = str(tmp_path / "snap")
    snapshot.save_index(idx, path, block_size=16, tile=16, frag=8)
    ld = snapshot.load_index(path, mmap=True)
    q = np.array([3, 9, 40], np.int32)
    np.testing.assert_array_equal(ScipyBM25(ld).score(q),
                                  ScipyBM25(idx).score(q))


# -- atomicity ----------------------------------------------------------------

def test_torn_write_preserves_previous_generation(tmp_path, rng):
    corpus, idx = _mk(rng, "lucene")
    path = str(tmp_path / "snap")
    di = _di(idx)
    di.save(path)
    # second save killed mid-write: every written file is a candidate
    # victim; the OSError is the simulated kill
    for seed in range(4):
        with inject_faults({"site": "snapshot.write", "kind": "torn_write",
                            "times": 1, "seed": seed,
                            "guarded": False}) as sp:
            with pytest.raises(OSError, match="injected"):
                di.save(path)
        assert sp[0].fired == 1
        ld = snapshot.load_index(path)     # previous snapshot, intact
        assert not ld.snapshot_report["hops"]
        np.testing.assert_array_equal(ld.doc_ids, idx.doc_ids)
    # ... and the next clean save commits over the debris
    di.save(path)
    assert snapshot.load_index(path).snapshot_report["generation"] \
        != "gen-000001"


def test_torn_first_save_is_typed_not_garbage(tmp_path, rng):
    corpus, idx = _mk(rng, "lucene")
    path = str(tmp_path / "fresh")
    with inject_faults({"site": "snapshot.write", "kind": "torn_write",
                        "times": 1, "seed": 0, "guarded": False}):
        with pytest.raises(OSError):
            snapshot.save_index(idx, path, **SMALL)
    with pytest.raises(SnapshotIntegrityError):
        snapshot.load_index(path)
    with pytest.raises(RetrievalError):    # one base class catches it
        snapshot.load_index(path)


def test_resave_gcs_old_generations(tmp_path, rng):
    corpus, idx = _mk(rng, "lucene")
    path = str(tmp_path / "snap")
    for _ in range(3):
        snapshot.save_index(idx, path, **SMALL)
    gens = [d for d in os.listdir(path) if d.startswith("gen-")]
    assert gens == ["gen-000003"]          # exactly one survivor


# -- the recovery ladder, hop by hop -----------------------------------------

def test_recover_small_array_from_dup(tmp_path, rng):
    corpus, idx = _mk(rng, "lucene")
    path = str(tmp_path / "snap")
    snapshot.save_index(idx, path, **SMALL)
    for name in ("index.indptr", "index.nonoccurrence", "index.doc_lens"):
        _flip_byte(os.path.join(_gen_dir(path), f"{name}.bin"))
        ld = snapshot.load_index(path)
        assert f"{name}<-dup" in ld.snapshot_report["hops"]
        np.testing.assert_array_equal(ld.indptr, idx.indptr)
        np.testing.assert_array_equal(ld.doc_lens, idx.doc_lens)
        snapshot.save_index(idx, path, **SMALL)      # fresh generation


def test_recover_csc_from_blocked_and_back(tmp_path, rng):
    corpus, idx = _mk(rng, "atire")
    path = str(tmp_path / "snap")
    snapshot.save_index(idx, path, **SMALL)
    gen = _gen_dir(path)
    _flip_byte(os.path.join(gen, "csc.doc_ids.bin"), offset=64)
    ld = snapshot.load_index(path)
    assert "csc<-blocked" in ld.snapshot_report["hops"]
    np.testing.assert_array_equal(ld.doc_ids, idx.doc_ids)
    np.testing.assert_array_equal(ld.scores, idx.scores)
    snapshot.save_index(idx, path, **SMALL)
    gen = _gen_dir(path)
    _flip_byte(os.path.join(gen, "blocked.sc.bin"), offset=64)
    ld2 = DeviceIndex.load(path)
    assert "blocked<-csc" in ld2.snapshot_report["hops"]
    np.testing.assert_array_equal(ld2.host.doc_ids, idx.doc_ids)


def test_recover_bmax_rebuild(tmp_path, rng):
    corpus, idx = _mk(rng, "lucene")
    path = str(tmp_path / "snap")
    _di(idx, "u8").save(path)
    _flip_byte(os.path.join(_gen_dir(path), "bmax.host.bin"))
    ld = DeviceIndex.load(path)
    assert "bmax<-csc" in ld.snapshot_report["hops"]
    fresh = _di(idx, "u8")
    np.testing.assert_array_equal(np.asarray(ld.bmax.host),
                                  np.asarray(fresh.bmax.host))
    np.testing.assert_array_equal(np.asarray(ld.bmax.scale),
                                  np.asarray(fresh.bmax.scale))


def test_recover_manifest_from_dup(tmp_path, rng):
    corpus, idx = _mk(rng, "lucene")
    path = str(tmp_path / "snap")
    snapshot.save_index(idx, path, **SMALL)
    _flip_byte(os.path.join(_gen_dir(path), "manifest.json"), offset=40)
    ld = snapshot.load_index(path)
    assert "manifest<-dup" in ld.snapshot_report["hops"]
    np.testing.assert_array_equal(ld.doc_ids, idx.doc_ids)


def test_double_corruption_falls_back_to_corpus(tmp_path, rng):
    """csc AND blocked both gone -> exact rebuild from the corpus."""
    corpus, idx = _mk(rng, "bm25l")
    path = str(tmp_path / "snap")
    snapshot.save_index(idx, path, **SMALL)
    gen = _gen_dir(path)
    _flip_byte(os.path.join(gen, "csc.scores.bin"), offset=64)
    _flip_byte(os.path.join(gen, "blocked.sc.bin"), offset=64)
    ld = snapshot.load_index(path, corpus=corpus)
    assert ld.snapshot_report["full_rebuild"]
    np.testing.assert_array_equal(ld.doc_ids, idx.doc_ids)
    np.testing.assert_array_equal(ld.scores, idx.scores)
    np.testing.assert_array_equal(ld.nonoccurrence, idx.nonoccurrence)


def test_ladder_dry_raises_typed_with_corrupt_list(tmp_path, rng):
    corpus, idx = _mk(rng, "lucene")
    path = str(tmp_path / "snap")
    snapshot.save_index(idx, path, **SMALL)
    gen = _gen_dir(path)
    _flip_byte(os.path.join(gen, "csc.scores.bin"), offset=64)
    _flip_byte(os.path.join(gen, "blocked.sc.bin"), offset=64)
    with pytest.raises(SnapshotIntegrityError) as ei:
        snapshot.load_index(path)          # no corpus -> nothing left
    assert any("csc" in c or "blocked" in c for c in ei.value.corrupt)


def test_stale_version_is_authoritative(tmp_path, rng):
    corpus, idx = _mk(rng, "lucene")
    path = str(tmp_path / "snap")
    snapshot.save_index(idx, path, **SMALL)
    mpath = os.path.join(_gen_dir(path), "manifest.json")
    with open(mpath, encoding="utf-8") as fh:
        m = json.load(fh)
    m["version"] = snapshot.VERSION + 1
    del m["manifest_checksum"]
    m["manifest_checksum"] = snapshot.manifest_checksum(m)
    with open(mpath, "w", encoding="utf-8") as fh:
        json.dump(m, fh)
    # a future version is a typed refusal — the dup (same bytes would be
    # rewritten by a future writer) must NOT be consulted
    with pytest.raises(SnapshotVersionError, match="version"):
        snapshot.load_index(path, corpus=corpus)


def test_counters_track_every_hop(tmp_path, rng):
    corpus, idx = _mk(rng, "lucene")
    path = str(tmp_path / "snap")
    snapshot.reset_counters()
    snapshot.save_index(idx, path, **SMALL)
    snapshot.load_index(path)
    _flip_byte(os.path.join(_gen_dir(path), "index.indptr.bin"))
    snapshot.load_index(path)
    assert snapshot.COUNTERS["saves"] == 1
    assert snapshot.COUNTERS["loads"] == 2
    assert snapshot.COUNTERS["dup_recoveries"] == 1


# -- engine save/load ---------------------------------------------------------

@pytest.mark.parametrize("scorer", ["scipy", "auto"])
def test_engine_roundtrip(scorer, tmp_path, rng):
    from repro.core import build_sharded_indexes
    corpus = make_corpus(rng, n_docs=80, n_vocab=64)
    shards = build_sharded_indexes(corpus, 64, 2, params=BM25Params())
    opts = dict(RSMALL) if scorer == "auto" else {}
    eng = RetrievalEngine(shards, k=5, deadline_s=5.0, scorer=scorer,
                          warmup=False, scorer_opts=opts)
    qs = _queries(rng, 64, n=4)
    r0 = eng.retrieve_batch(qs)
    path = str(tmp_path / "engine")
    cfg = eng.save(path)
    assert cfg["n_shards"] == 2
    eng2 = RetrievalEngine.load(path, mmap=True, warmup=False,
                                deadline_s=5.0, scorer_opts=opts)
    assert eng2.k == 5 and eng2.scorer == scorer
    r1 = eng2.retrieve_batch(qs)
    np.testing.assert_array_equal(r0.ids, r1.ids)
    np.testing.assert_array_equal(r0.scores, r1.scores)
    h = eng2.health()["shards"][0]["snapshot"]
    if scorer == "auto":
        assert h["verified"] and h["generation"] == "gen-000001"
    # a loaded engine still rescales (adoption is first-build-only);
    # scores stay exact (ids may reorder within tied scores across the
    # new shard boundaries)
    eng2.rescale(3)
    r2 = eng2.retrieve_batch(qs)
    np.testing.assert_array_equal(r0.scores, r2.scores)


def test_engine_load_recovers_shard_from_corpus_slice(tmp_path, rng):
    from repro.core import build_sharded_indexes
    corpus = make_corpus(rng, n_docs=80, n_vocab=64)
    shards = build_sharded_indexes(corpus, 64, 2, params=BM25Params())
    eng = RetrievalEngine(shards, k=5, deadline_s=5.0, scorer="scipy")
    qs = _queries(rng, 64, n=4)
    r0 = eng.retrieve_batch(qs)
    path = str(tmp_path / "engine")
    eng.save(path)
    sdir = os.path.join(path, "shard-0001")
    gen = _gen_dir(sdir)
    _flip_byte(os.path.join(gen, "csc.scores.bin"), offset=64)
    _flip_byte(os.path.join(gen, "blocked.sc.bin"), offset=64)
    eng2 = RetrievalEngine.load(path, corpus=corpus, deadline_s=5.0)
    assert eng2.shards[1].snapshot_report["full_rebuild"]
    r1 = eng2.retrieve_batch(qs)
    np.testing.assert_array_equal(r0.ids, r1.ids)
    np.testing.assert_array_equal(r0.scores, r1.scores)


def test_engine_store_version_guard(tmp_path, rng):
    corpus, idx = _mk(rng, "lucene")
    eng = RetrievalEngine([idx], k=3, scorer="scipy")
    path = str(tmp_path / "engine")
    eng.save(path)
    epath = os.path.join(path, "engine.json")
    with open(epath, encoding="utf-8") as fh:
        cfg = json.load(fh)
    cfg["version"] = 999
    with open(epath, "w", encoding="utf-8") as fh:
        json.dump(cfg, fh)
    with pytest.raises(SnapshotVersionError):
        RetrievalEngine.load(path)
