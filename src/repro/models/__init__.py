"""Model zoo: LM transformer family, EGNN, and recsys architectures."""

from . import egnn, recsys, transformer
from .transformer import LMConfig
from .egnn import EGNNConfig
from .recsys import RecsysConfig

__all__ = ["egnn", "recsys", "transformer",
           "LMConfig", "EGNNConfig", "RecsysConfig"]
