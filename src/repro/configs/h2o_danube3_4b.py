"""h2o-danube3-4b [arXiv:2401.16818]: llama+mistral mix with SWA.

24L, d_model=3840, 32 heads (GQA kv=8), d_ff=10240, vocab=32000.
head_dim = 3840/32 = 120 — NOT MXU-aligned (kernels pad to 128; the waste is
noted in the roofline table). All layers sliding-window (mistral-style 4096)
⇒ the long_500k decode cell runs with a window-capped KV cache.
"""

import jax.numpy as jnp

from ..models.transformer import LMConfig, reduced
from .common import lm_cells

CONFIG = LMConfig(
    name="h2o-danube3-4b",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab_size=32000,
    sliding_window=4096, rope_theta=10_000.0,
    dtype=jnp.bfloat16,
)

SMOKE = reduced(CONFIG)

FAMILY = "lm"
N_MICROBATCHES = 4


def cells():
    return lm_cells("h2o-danube3-4b", CONFIG, n_microbatches=N_MICROBATCHES)
