"""Serving stack: sharded retrieval engine with hedging, LM decode engine."""

from .retrieval_engine import (BlockedRetriever, DeviceRetriever,
                               GatheredRetriever, RetrievalEngine,
                               ShardRuntime)
from .decode_engine import DecodeEngine

__all__ = ["BlockedRetriever", "DeviceRetriever", "GatheredRetriever",
           "RetrievalEngine", "ShardRuntime", "DecodeEngine"]
