"""Block-bucketed CSR — the TPU-native layout for eager sparse scores.

DESIGN.md §3.1: documents (or GNN destination nodes) are grouped into fixed
blocks of ``block_size``; each block's postings (or edges) live in flat
arrays padded to a static per-block budget that is a multiple of the kernel
tile. Every shape is static under ``jit``; padding waste is the block-size
quantization cost and is reported by ``padding_stats``.

The same layout backs three workloads:
  * BM25S scoring   — (token_id, local_doc, score) per posting
  * GNN aggregation — (src_node, local_dst, edge_weight/message id)
  * EmbeddingBag    — (row_id, local_bag, sample_weight)
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class BlockedPostings:
    """Postings bucketed by destination block (static-shape sparse layout).

    ``token_ids[i, p]`` is -1 for padding slots; padding slots carry
    ``scores == 0`` and ``local_doc == 0`` so any consumer that forgets the
    mask still computes correct sums.
    """

    token_ids: np.ndarray   # [n_blocks, nnz_pad] int32, -1 = pad
    local_doc: np.ndarray   # [n_blocks, nnz_pad] int32 in [0, block_size)
    scores: np.ndarray      # [n_blocks, nnz_pad] float32
    block_size: int
    n_docs: int             # true (unpadded) number of documents
    n_vocab: int

    @property
    def n_blocks(self) -> int:
        return int(self.token_ids.shape[0])

    @property
    def nnz_pad(self) -> int:
        return int(self.token_ids.shape[1])

    def padding_stats(self) -> dict:
        real = int((self.token_ids >= 0).sum())
        total = self.token_ids.size
        return {
            "nnz": real,
            "padded_nnz": total,
            "pad_fraction": 1.0 - real / max(total, 1),
            "n_blocks": self.n_blocks,
            "nnz_pad_per_block": self.nnz_pad,
        }


def _round_up(x: int, tile: int) -> int:
    return max(tile, ((x + tile - 1) // tile) * tile)


def block_postings_from_coo(
    token_ids: np.ndarray,
    doc_ids: np.ndarray,
    scores: np.ndarray,
    *,
    n_docs: int,
    n_vocab: int,
    block_size: int = 512,
    tile: int = 512,
    sort_tokens: bool = True,
) -> BlockedPostings:
    """Bucket COO postings by ``doc_id // block_size`` and pad per block.

    ``nnz_pad`` is the max per-block count rounded up to ``tile`` (one budget
    shared by all blocks so the arrays are rectangular). Within a block
    postings are sorted by token id (the membership-lookup kernel exploits
    locality, and determinism helps tests).

    Fully vectorized: one ``lexsort`` by (block, token) makes each block a
    contiguous run, the within-block column of every posting is
    ``rank - block_start``, and a single fancy-indexed scatter fills the
    rectangular arrays — no per-block Python loop.
    """
    n_blocks = max(1, -(-n_docs // block_size))
    blk = doc_ids // block_size
    counts = np.bincount(blk, minlength=n_blocks)
    nnz_pad = _round_up(int(counts.max()) if counts.size else 0, tile)

    tok = np.full((n_blocks, nnz_pad), -1, dtype=np.int32)
    loc = np.zeros((n_blocks, nnz_pad), dtype=np.int32)
    sc = np.zeros((n_blocks, nnz_pad), dtype=np.float32)

    order = (np.lexsort((token_ids, blk)) if sort_tokens
             else np.argsort(blk, kind="stable"))
    token_ids, doc_ids, scores, blk = (
        token_ids[order], doc_ids[order], scores[order], blk[order])
    starts = np.zeros(n_blocks + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    col = np.arange(blk.size, dtype=np.int64) - starts[blk]
    tok[blk, col] = token_ids
    loc[blk, col] = doc_ids - blk * block_size
    sc[blk, col] = scores
    return BlockedPostings(tok, loc, sc, block_size=block_size,
                           n_docs=n_docs, n_vocab=n_vocab)


def block_postings_from_index(index, *, block_size: int = 512,
                              tile: int = 512) -> BlockedPostings:
    """Re-block a :class:`repro.core.index.BM25Index` (CSC-by-token) shard."""
    df = np.diff(index.indptr)
    tok = np.repeat(np.arange(index.n_vocab, dtype=np.int32), df)
    return block_postings_from_coo(
        tok, index.doc_ids.astype(np.int64), index.scores,
        n_docs=int(index.doc_lens.size), n_vocab=index.n_vocab,
        block_size=block_size, tile=tile)


def block_edges(src: np.ndarray, dst: np.ndarray, weight: np.ndarray | None,
                *, n_nodes: int, block_size: int = 512,
                tile: int = 512) -> BlockedPostings:
    """GNN edge list -> destination-blocked layout (same container).

    ``token_ids`` carries the *source node id*, ``local_doc`` the destination
    offset within its block, ``scores`` the edge weight (1.0 if None).
    """
    w = np.ones(src.shape[0], np.float32) if weight is None else weight
    return block_postings_from_coo(
        src.astype(np.int32), dst.astype(np.int64), w.astype(np.float32),
        n_docs=n_nodes, n_vocab=n_nodes, block_size=block_size, tile=tile,
        sort_tokens=False)


def query_nonoccurrence_shift(nonoccurrence: np.ndarray,
                              q_tokens: np.ndarray,
                              q_weights: np.ndarray) -> np.ndarray:
    """Per-query §2.1 constant ``Σᵢ wᵢ·S⁰(qᵢ)`` for a padded query batch.

    ``[B]`` float32, zero for sparse variants. The single definition of the
    host-side shift the fused retrieval path adds after its merge
    (``ops.bm25_retrieve_blocked``'s ``nonocc_shift`` operand).
    """
    safe = np.where(q_tokens >= 0, q_tokens, 0)
    return ((q_weights * nonoccurrence[safe] * (q_tokens >= 0))
            .sum(-1).astype(np.float32))


def pack_query_batch(q_tokens: np.ndarray, q_weights: np.ndarray,
                     u_max: int) -> tuple[np.ndarray, np.ndarray]:
    """Batch of padded queries -> (sorted unique tokens [U], weights [U, B]).

    The batched kernel scores *all* queries in one pass over the postings
    (DESIGN.md §3.3); its query-side operand is the batch's unique-token
    table plus a per-query weight column. Pad token = 2^31 - 1 (sorts last,
    matches nothing since posting pads are -1).
    """
    b = q_tokens.shape[0]
    uniq = np.unique(q_tokens[q_tokens >= 0])
    if uniq.size > u_max:
        raise ValueError(f"query batch has {uniq.size} unique tokens "
                         f"> u_max={u_max}")
    table = np.full(u_max, np.iinfo(np.int32).max, dtype=np.int32)
    table[: uniq.size] = uniq
    weights = np.zeros((u_max, b), dtype=np.float32)
    # tokens are unique within a query (pad_queries), so one scatter works
    qi, slot = np.nonzero(q_tokens >= 0)
    pos = np.searchsorted(uniq, q_tokens[qi, slot])
    weights[pos, qi] = q_weights[qi, slot]
    return table, weights
