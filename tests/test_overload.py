"""Overload protection: admission control, breakers, watchdog, supervision.

Pins the PR-10 contract:

* **admission** — the token-bucket + CoDel gate sheds at the DOOR with a
  typed :class:`AdmissionRejectedError` (carrying ``retry_after_s``)
  before any device work is consumed; the decision sequence is a pure
  function of the observed clock, no RNG.
* **breakers** — the per-rung circuit breaker walks
  closed -> open -> half-open -> closed (or re-open) exactly as specified
  (fault-injection integration lives in ``test_faults.py``).
* **watchdog** — a deadline miss abandons the stalled worker, REPLACES
  the thread, and surfaces a typed :class:`ExecutionStalledError`.
* **supervision** — a dead batch-former never hangs a client: in-flight
  and stranded requests fail typed (:class:`StageFailedError`), the
  stage restarts within ``max_stage_restarts``, and ``close()`` resolves
  every future under both drain and abort semantics.
* **thread-safe health** — concurrent submits + direct retriever calls
  leave counters that SUM EXACTLY (the hammer test).
"""

import threading
import time

import numpy as np
import pytest

from repro.core import BM25Params, build_index
from repro.data.corpus import zipf_corpus, zipf_queries
from repro.serve import (AdmissionController, AdmissionRejectedError,
                         CircuitBreaker, DeviceRetriever,
                         ExecutionStalledError, RetrievalConfigError,
                         RetrievalResult, RetryPolicy, ServingFrontend,
                         StageFailedError, WatchdogExecutor)

pytestmark = pytest.mark.no_chaos    # asserts exact counter values

N_VOCAB = 120
SMALL = dict(block_size=32, tile=64, q_max=8, frag=64)


class _StubRetriever:
    """Device-free retrieve_batch target with a tunable service time."""

    def __init__(self, delay_s=0.0):
        self.q_max = 8
        self.query_counters = {}
        self.delay_s = delay_s
        self.calls = 0
        self.rows = 0
        self._lock = threading.Lock()

    def retrieve_batch(self, batch, k=5, **kw):
        if self.delay_s:
            time.sleep(self.delay_s)
        with self._lock:
            self.calls += 1
            self.rows += len(batch)
        b = len(batch)
        return RetrievalResult(ids=np.tile(np.arange(k), (b, 1)),
                               scores=np.zeros((b, k), np.float32))


# -- AdmissionController (unit, fake clock) ------------------------------

def test_bucket_sheds_above_rate_and_refills():
    ac = AdmissionController(rate_qps=10.0, burst=2)
    assert ac.admit(0.0, 0) is None
    assert ac.admit(0.0, 0) is None              # burst of 2 admitted
    ra = ac.admit(0.0, 0)
    assert ra is not None and ra == pytest.approx(0.1)   # 1 token / 10 qps
    assert ac.admit(0.05, 0) is not None         # half a token accrued
    assert ac.admit(0.1001, 0) is None           # a full token accrued
    assert ac.admitted == 3
    assert ac.shed_bucket == 2 and ac.shed_codel == 0


def test_bucket_is_deterministic():
    """Same clock sequence -> same decision sequence (no RNG anywhere)."""
    seq = [0.0, 0.01, 0.02, 0.3, 0.31, 0.32, 0.9]
    runs = []
    for _ in range(2):
        ac = AdmissionController(rate_qps=5.0, burst=1)
        runs.append([ac.admit(t, 0) for t in seq])
    assert runs[0] == runs[1]


def test_codel_sheds_after_interval_and_recovers():
    ac = AdmissionController(codel_target_s=0.01, codel_interval_s=0.1)
    ac.observe(0.05, 0.0)                        # above target at t=0
    assert ac.admit(0.05, 0) is None             # patience: < one interval
    ra = ac.admit(0.11, 0)                       # interval elapsed: shed
    assert ra == pytest.approx(0.1)              # interval / sqrt(1)
    assert ac.admit(0.12, 0) is None             # next shed not yet due
    ra = ac.admit(0.22, 0)                       # past _drop_next
    assert ra == pytest.approx(0.1 / np.sqrt(2))
    ac.observe(0.001, 0.3)                       # delay back under target
    assert ac.admit(0.31, 0) is None             # episode over: admit again
    assert ac.shed_codel == 2
    snap = ac.snapshot()
    assert snap["admitted"] == 3 and snap["codel_dropping"] is False


def test_admission_validation_and_defaults():
    with pytest.raises(ValueError, match="rate_qps"):
        AdmissionController(rate_qps=-1.0)
    with pytest.raises(ValueError, match="codel_target_s"):
        AdmissionController(codel_target_s=0.0)
    assert AdmissionController(rate_qps=1000.0).burst == 200
    assert AdmissionController(rate_qps=10.0).burst == 8  # floor


# -- CircuitBreaker (unit, fake clock) -----------------------------------

def test_breaker_state_machine():
    br = CircuitBreaker(threshold=3, window_s=10.0, cooldown_s=5.0)
    assert br.state(0.0) == "closed" and br.allow(0.0)
    br.record_fault(0.0)
    br.record_fault(1.0)
    assert br.state(1.0) == "closed"             # under threshold
    br.record_fault(2.0)
    assert br.state(2.0) == "open" and br.opened == 1
    assert not br.allow(3.0) and br.skips == 1
    assert br.state(7.0) == "half-open"
    assert br.allow(7.0)                          # claims THE probe slot
    assert not br.allow(7.1)                      # second caller: no slot
    br.record_success(7.2)
    assert br.state(7.2) == "closed"
    assert br.snapshot(7.2)["faults_in_window"] == 0


def test_breaker_window_prunes_old_faults():
    br = CircuitBreaker(threshold=2, window_s=1.0, cooldown_s=5.0)
    br.record_fault(0.0)
    br.record_fault(5.0)                          # first fault aged out
    assert br.state(5.0) == "closed"
    br.record_fault(5.5)
    assert br.state(5.5) == "open"


def test_breaker_probe_failure_reopens():
    br = CircuitBreaker(threshold=1, cooldown_s=2.0)
    br.record_fault(0.0)
    assert br.allow(3.0)                          # half-open probe
    br.record_fault(3.1)                          # probe failed
    assert br.state(3.2) == "open" and br.opened == 2
    assert br.state(5.2) == "half-open"           # another cooldown later


def test_breaker_force_open_and_validation():
    br = CircuitBreaker()
    br.force_open(0.0, cooldown_s=100.0)
    assert br.state(50.0) == "open" and br.opened == 1
    with pytest.raises(ValueError, match="threshold"):
        CircuitBreaker(threshold=0)


# -- WatchdogExecutor ----------------------------------------------------

def test_watchdog_converts_stall_and_replaces_worker():
    wd = WatchdogExecutor(0.05, name="t-wd")
    with pytest.raises(ExecutionStalledError) as ei:
        wd.run(time.sleep, 0.5)
    assert ei.value.waited_s == pytest.approx(0.05)
    assert isinstance(ei.value, TimeoutError)     # builtin-compat base
    assert wd.stalls == 1
    assert wd.run(lambda: 42) == 42               # fresh worker is live
    wd.close()


def test_watchdog_enters_ctx_on_worker_thread():
    """Thread-local guard scopes must be re-entered ON the worker."""
    import contextlib

    entered_on = []

    @contextlib.contextmanager
    def ctx():
        entered_on.append(threading.current_thread().name)
        yield

    wd = WatchdogExecutor(5.0, name="ctx-wd")
    ran_on = wd.run(lambda: threading.current_thread().name, ctx=ctx)
    assert entered_on == [ran_on]                 # same (worker) thread
    assert ran_on != threading.current_thread().name
    wd.close()
    with pytest.raises(ValueError, match="positive"):
        WatchdogExecutor(0.0)


def test_watchdog_propagates_worker_exceptions():
    wd = WatchdogExecutor(5.0)

    def boom():
        raise KeyError("from the worker")

    with pytest.raises(KeyError, match="from the worker"):
        wd.run(boom)
    assert wd.stalls == 0
    wd.close()


# -- RetryPolicy ---------------------------------------------------------

def test_retry_policy_is_seeded_and_bounded():
    rp = RetryPolicy(budget=3, base_s=0.01, factor=2.0, seed=7)
    d1, d2 = rp.delays(), rp.delays()
    assert d1 == d2 and len(d1) == 3              # pure function of seed
    assert 0.01 <= d1[0] <= 0.015                 # base * (1 + 0.5*u)
    assert d1[1] >= 2 * 0.01 and d1[2] >= 4 * 0.01
    assert RetryPolicy().delays() == []           # budget 0: no retries
    assert RetryPolicy(budget=3, seed=8).delays() != d1
    with pytest.raises(ValueError, match="budget"):
        RetryPolicy(budget=-1)


def test_retriever_overload_knob_validation(rng_index):
    idx = rng_index
    with pytest.raises(RetrievalConfigError, match="watchdog_s"):
        DeviceRetriever(idx, watchdog_s=0.0, **SMALL)
    with pytest.raises(RetrievalConfigError, match="retry_budget"):
        DeviceRetriever(idx, retry_budget=-1, **SMALL)
    with pytest.raises(RetrievalConfigError, match="breaker_threshold"):
        DeviceRetriever(idx, breaker_threshold=0, **SMALL)


@pytest.fixture(scope="module")
def rng_index():
    return build_index(zipf_corpus(150, N_VOCAB, avg_len=25), N_VOCAB,
                       params=BM25Params())


# -- frontend: admission gate --------------------------------------------

def test_admission_gate_sheds_typed_before_device_work():
    stub = _StubRetriever()
    fe = ServingFrontend(stub, k=5, max_batch=4, batch_deadline_s=0.001,
                         admission_rate_qps=0.001, admission_burst=2)
    q = np.array([1, 2], np.int32)
    futs = [fe.submit(q), fe.submit(q)]           # the whole burst
    with pytest.raises(AdmissionRejectedError) as ei:
        fe.submit(q)
    assert ei.value.retry_after_s is not None and ei.value.retry_after_s > 0
    assert ei.value.pending is not None
    assert isinstance(ei.value, RuntimeError)     # builtin-compat base
    for f in futs:
        f.result(timeout=10.0)
    fe.close()
    h = fe.health()
    assert h["shed"] == 1 and h["rejected"] == 1
    assert h["faults"]["AdmissionRejectedError"] == 1
    assert h["served"] == 2 and h["submitted"] == 2
    assert h["admission"]["shed_bucket"] == 1
    assert stub.rows == 2                         # the shed cost NO work


def test_codel_gate_converges_under_sustained_overload():
    """A slow backend + sustained arrivals: the CoDel half starts
    shedding once the standing delay exceeds target, and every ADMITTED
    request still resolves."""
    stub = _StubRetriever(delay_s=0.03)
    fe = ServingFrontend(stub, k=5, max_batch=1, batch_deadline_s=0.0002,
                         codel_target_s=0.005, codel_interval_s=0.02)
    q = np.array([1, 2], np.int32)
    futs, shed = [], 0
    for _ in range(40):
        try:
            futs.append(fe.submit(q))
        except AdmissionRejectedError:
            shed += 1
        time.sleep(0.002)
    for f in futs:
        f.result(timeout=30.0)
    fe.close()
    h = fe.health()
    assert shed > 0 and h["admission"]["shed_codel"] == shed
    assert h["served"] == len(futs) == stub.rows  # admitted => served
    assert h["served"] + shed == 40


# -- frontend: close semantics + stage supervision ------------------------

def test_close_abort_fails_queued_typed():
    stub = _StubRetriever()
    fe = ServingFrontend(stub, k=5, max_batch=64,
                         batch_deadline_s=30.0)   # deadline never fires
    q = np.array([1, 2], np.int32)
    futs = [fe.submit(q) for _ in range(5)]
    fe.close(drain=False)
    for f in futs:
        with pytest.raises(StageFailedError) as ei:
            f.result(timeout=5.0)
        assert ei.value.stage == "close"
    h = fe.health()
    assert h["aborted"] == 5 and h["pending"] == 0
    assert h["faults"]["StageFailedError"] == 5
    assert stub.rows == 0                         # nothing reached the device


def test_supervisor_restarts_former_within_budget():
    """A crashing former step fails nothing queued (nothing was in
    flight), restarts in place, and keeps serving."""
    stub = _StubRetriever()
    fe = ServingFrontend(stub, k=5, max_batch=4, batch_deadline_s=0.001,
                         autostart=False, max_stage_restarts=3)
    real_step, crashes = fe._former_step, []

    def flaky_step():
        if not crashes:
            crashes.append(1)
            raise RuntimeError("injected former crash")
        return real_step()

    fe._former_step = flaky_step
    fe.start()
    q = np.array([1, 2], np.int32)
    row = fe.submit(q).result(timeout=10.0)
    assert row.ids.shape == (5,)
    fe.close()
    assert fe.health()["restarts"] == 1


def test_supervisor_budget_exhaustion_fails_pending_typed():
    """Beyond max_stage_restarts the frontend STOPS: queued requests fail
    typed instead of crash-looping, and new submits are refused."""
    stub = _StubRetriever()
    fe = ServingFrontend(stub, k=5, max_batch=64, batch_deadline_s=30.0,
                         autostart=False, max_stage_restarts=2)
    fe._started = True                  # queue without threads (test idiom)
    q = np.array([1, 2], np.int32)
    futs = [fe.submit(q) for _ in range(3)]
    fe._started = False

    def always_boom():
        raise RuntimeError("unrecoverable former crash")

    fe._former_step = always_boom
    fe.start()
    for f in futs:
        with pytest.raises(StageFailedError) as ei:
            f.result(timeout=5.0)
        assert ei.value.stage == "former"
    with pytest.raises(RuntimeError, match="not running"):
        fe.submit(q)
    h = fe.health()
    assert h["restarts"] == 2 and h["pending"] == 0


def test_dead_former_detected_and_revived_at_submit():
    """A former found dead at submit time is restarted (budget
    permitting) after failing what it stranded — submits never queue
    onto a dead stage."""
    stub = _StubRetriever()
    fe = ServingFrontend(stub, k=5, max_batch=4, batch_deadline_s=0.001)
    with fe._cond:                                # kill the former cleanly
        fe._stopping = True
        fe._cond.notify_all()
    fe._former.join(timeout=5.0)
    assert not fe._former.is_alive()
    fe._stopping = False                          # simulate silent death
    q = np.array([1, 2], np.int32)
    row = fe.submit(q).result(timeout=10.0)       # revived + served
    assert row.ids.shape == (5,)
    assert fe.health()["restarts"] == 1
    fe.close()


def test_frontend_knob_validation():
    with pytest.raises(ValueError, match="max_stage_restarts"):
        ServingFrontend(_StubRetriever(), max_stage_restarts=-1,
                        autostart=False)


# -- the hammer: thread-safe health counters ------------------------------

def test_concurrent_submit_counters_sum_exactly(rng_index):
    """Satellite (b): submits racing across threads WITH direct
    retriever calls leave health counters that sum exactly — no lost
    updates anywhere in the two-level report."""
    dr = DeviceRetriever(rng_index, **SMALL)
    dr.retrieve_batch(zipf_queries(4, N_VOCAB), 5)        # warm compiles
    base_batches = dr.health()["served"]
    fe = ServingFrontend(dr, k=5, max_batch=8, batch_deadline_s=0.002)
    qs = zipf_queries(8, N_VOCAB)
    n_threads, per_thread, n_direct = 8, 10, 6
    errs = []

    def submitter():
        try:
            futs = [fe.submit(qs[i % len(qs)]) for i in range(per_thread)]
            for f in futs:
                f.result(timeout=60.0)
        except BaseException as e:               # noqa: BLE001
            errs.append(e)

    def direct_caller():
        try:
            for _ in range(n_direct // 2):
                dr.retrieve_batch(qs[:4], 5)
        except BaseException as e:               # noqa: BLE001
            errs.append(e)

    threads = ([threading.Thread(target=submitter) for _ in range(n_threads)]
               + [threading.Thread(target=direct_caller) for _ in range(2)])
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120.0)
    fe.close()
    assert not errs
    h = fe.health()
    total = n_threads * per_thread
    assert h["submitted"] == total
    assert h["served"] == total                   # nothing lost, nothing shed
    assert h["pending"] == 0 and h["rejected"] == 0
    assert h["faults"] == {}
    hr = dr.health()
    # retriever-level: frontend batches + direct calls, counted exactly
    assert hr["served"] == base_batches + h["batches"] + n_direct
    assert sum(h["flushes"].values()) == h["batches"]
