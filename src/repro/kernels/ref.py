"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` takes exactly the operands of its kernel counterpart and is
written with the most obvious jnp formulation — no blocking, no MXU tricks —
so kernel tests can ``assert_allclose`` against unambiguous semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def bm25_block_score_ref(token_ids: jax.Array, local_doc: jax.Array,
                         scores: jax.Array, uniq_tokens: jax.Array,
                         weights: jax.Array, *, block_size: int,
                         spmd_axes=None) -> jax.Array:
    """[nb, P] postings x [U, B] query weights -> [nb, block_size, B] scores.

    For each posting p in block i: binary-search its token in the sorted
    unique-token table (exact match; padding postings have token -1 and
    match nothing), gather the per-query weight row, multiply by the eager
    score, scatter-add into its local document row.
    """
    nb, p = token_ids.shape

    def one_block(tok, loc, sc):
        idx = jnp.searchsorted(uniq_tokens, tok).astype(jnp.int32)
        idx = jnp.minimum(idx, uniq_tokens.shape[0] - 1)
        hit = (jnp.take(uniq_tokens, idx) == tok)[:, None]
        w = jnp.where(hit, jnp.take(weights, idx, axis=0), 0.0)           # [P,B]
        contrib = sc[:, None] * w                                         # [P,B]
        return jax.ops.segment_sum(contrib, loc, num_segments=block_size)

    # spmd_axes pins the block dim's mesh axes so the per-block scatter
    # stays shard-local under pjit (see DESIGN.md §5)
    return jax.vmap(one_block, spmd_axis_name=spmd_axes)(
        token_ids, local_doc, scores)


def bm25_block_topk_ref(token_ids: jax.Array, local_doc: jax.Array,
                        scores: jax.Array, uniq_tokens: jax.Array,
                        weights: jax.Array, *, block_size: int, k: int,
                        n_docs: int) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused kernel: dense block scores, then per-block top-k.

    Documents past ``n_docs`` (tail-of-last-block padding) are masked to
    -inf before selection, matching the fused kernel's contract.
    """
    dense = bm25_block_score_ref(token_ids, local_doc, scores, uniq_tokens,
                                 weights, block_size=block_size)
    nb = dense.shape[0]
    gdoc = (jnp.arange(nb)[:, None] * block_size
            + jnp.arange(block_size)[None, :])
    masked = jnp.where((gdoc < n_docs)[:, :, None], dense,
                       jnp.finfo(dense.dtype).min)
    vals, idx = jax.lax.top_k(jnp.swapaxes(masked, 1, 2), k)   # [nb, B, k]
    return (jnp.swapaxes(vals, 1, 2),
            jnp.swapaxes(idx, 1, 2).astype(jnp.int32))         # [nb, k, B]


def bm25_gather_topk_ref(token_ids: jax.Array, slot_ids: jax.Array,
                         scores: jax.Array, uniq_tokens: jax.Array,
                         weights: jax.Array, candidates: jax.Array, *,
                         acc_block: int, k: int
                         ) -> tuple[jax.Array, jax.Array]:
    """Oracle for the gathered fused kernel (``bm25_gather_score_topk``).

    Dense per-chunk candidate-slot scores, mask padding slots (candidate id
    -1) to -inf, per-chunk top-k, then translate winning slots to global doc
    ids through the chunk's candidate table.
    """
    dense = bm25_block_score_ref(token_ids, slot_ids, scores, uniq_tokens,
                                 weights, block_size=acc_block)
    masked = jnp.where((candidates >= 0)[:, :, None], dense,
                       jnp.finfo(dense.dtype).min)
    vals, slots = jax.lax.top_k(jnp.swapaxes(masked, 1, 2), k)  # [nc, B, k]
    gids = jnp.take_along_axis(candidates[:, None, :]
                               .repeat(vals.shape[1], axis=1), slots, axis=2)
    return (jnp.swapaxes(vals, 1, 2),
            jnp.swapaxes(gids, 1, 2).astype(jnp.int32))         # [nc, k, B]


def block_segment_sum_ref(values: jax.Array, segment_ids: jax.Array,
                          *, num_segments: int) -> jax.Array:
    """[nb, P, D] values + [nb, P] local ids -> [nb, num_segments, D].

    Padding rows must carry zero values (the blocked layouts guarantee it).
    """
    def one_block(v, s):
        return jax.ops.segment_sum(v, s, num_segments=num_segments)

    return jax.vmap(one_block)(values, segment_ids)


def embedding_bag_ref(table: jax.Array, indices: jax.Array,
                      weights: jax.Array) -> jax.Array:
    """[V, D] table + [B, F] indices (-1 pad) + [B, F] weights -> [B, D]."""
    valid = indices >= 0
    safe = jnp.where(valid, indices, 0)
    rows = jnp.take(table, safe, axis=0)                  # [B, F, D]
    w = weights * valid.astype(table.dtype)
    return (rows * w[..., None]).sum(axis=1)


def blockwise_topk_ref(x: jax.Array, *, k: int, block: int
                       ) -> tuple[jax.Array, jax.Array]:
    """[n] -> per-block (values [nb, k], global indices [nb, k]), descending."""
    n = x.shape[0]
    nb = n // block
    blocks = x.reshape(nb, block)
    vals, idx = jax.lax.top_k(blocks, k)
    gidx = idx + (jnp.arange(nb, dtype=idx.dtype) * block)[:, None]
    return vals, gidx
