"""Shared model components: norms, RoPE, initializers, masking.

Pure-functional style: params are pytrees of jnp arrays, every module is a
``(params, x) -> y`` function. Compute runs in ``cfg.dtype`` (bf16 on TPU);
parameters are stored fp32 and cast at use (the train stack keeps fp32
masters + optimizer state; serving casts once at load).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    """RMSNorm; ``plus_one`` selects the Gemma ``(1 + w)`` convention."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    x32 = x32 * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    w = 1.0 + w if plus_one else w
    return (x32 * w).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float
               ) -> jax.Array:
    """Rotary embedding. x: [..., S, n_heads, head_dim]; positions: [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs       # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                             # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def causal_window_mask(q_pos: jax.Array, k_pos: jax.Array,
                       window: jax.Array | int) -> jax.Array:
    """True where key j may attend query i: causal ∧ (window==0 ∨ i-j<window).

    ``window`` may be a traced scalar (per-layer value carried through scan),
    0 meaning full (dense causal) attention.
    """
    causal = k_pos[None, :] <= q_pos[:, None]
    dist_ok = (q_pos[:, None] - k_pos[None, :]) < jnp.where(
        jnp.asarray(window) > 0, jnp.asarray(window), jnp.iinfo(jnp.int32).max)
    return causal & dist_ok


def uniform_init(key, shape, scale: float, dtype=jnp.float32) -> jax.Array:
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def normal_init(key, shape, stddev: float, dtype=jnp.float32) -> jax.Array:
    return jax.random.normal(key, shape, dtype) * stddev


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def count_params(params) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))


def cast_tree(params, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, params)
