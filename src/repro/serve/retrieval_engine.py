"""Batched retrieval serving with shard hedging, deadlines and elasticity.

The paper's §2 "Multi-threading" uses pooled executors for retrieval
speedup; at pod scale the same executor pattern becomes the scatter-gather
layer over document shards, and the operational concerns become:

* stragglers — the global merge proceeds once a QUORUM of shard top-k lists
  has arrived by the deadline; late shards are dropped from that response
  (recorded as ``degraded``) instead of stalling the tail latency. Because
  per-shard top-k is a superset property, a missed shard can only remove
  candidates it owns — results from responsive shards stay exact.
* elasticity — ``rescale(n_shards)`` re-buckets the postings (pure host
  re-slicing, ``core.index.reshard_index``) when the pool grows/shrinks.

``ShardRuntime`` is process-local here (threads simulate shard servers; a
``delay`` hook lets tests inject stragglers), but the engine logic —
quorum, deadline, merge, re-shard — is exactly the production control
plane.
"""

from __future__ import annotations

import heapq
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from ..core.index import BM25Index, reshard_index
from ..core.reference import ScipyBM25


@dataclass
class ShardRuntime:
    """One shard's scorer (thread-simulated shard server)."""

    index: BM25Index
    delay: Callable[[], float] | None = None     # test hook: seconds to sleep

    def __post_init__(self):
        self._scorer = ScipyBM25(self.index)

    def topk(self, query_tokens: np.ndarray, k: int
             ) -> tuple[np.ndarray, np.ndarray]:
        if self.delay is not None:
            time.sleep(self.delay())
        return self._scorer.retrieve(query_tokens, k)


@dataclass
class RetrievalResult:
    ids: np.ndarray
    scores: np.ndarray
    degraded: bool
    shards_answered: int
    latency_s: float


class RetrievalEngine:
    def __init__(self, shards: Sequence[BM25Index], *, k: int = 10,
                 deadline_s: float = 0.5, quorum: float = 0.75,
                 max_workers: int = 8,
                 delay: Callable[[int], Callable[[], float] | None] = None):
        self.k = k
        self.deadline_s = deadline_s
        self.quorum = quorum
        self._delay_factory = delay
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._build_runtimes(list(shards))

    def _build_runtimes(self, shards: list[BM25Index]) -> None:
        self.shards = shards
        self.runtimes = [
            ShardRuntime(s, delay=self._delay_factory(i)
                         if self._delay_factory else None)
            for i, s in enumerate(shards)
        ]

    # -- control plane ------------------------------------------------------
    def rescale(self, n_shards: int) -> None:
        """Elastic re-shard (device pool grew or shrank)."""
        self._build_runtimes(reshard_index(self.shards, n_shards))

    # -- data plane ----------------------------------------------------------
    def retrieve(self, query_tokens: np.ndarray, *, k: int | None = None
                 ) -> RetrievalResult:
        k = k or self.k
        t0 = time.time()
        futures = {
            self._pool.submit(rt.topk, query_tokens, k): i
            for i, rt in enumerate(self.runtimes)
        }
        need = max(1, int(np.ceil(self.quorum * len(self.runtimes))))
        done: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        pending = set(futures)
        deadline = t0 + self.deadline_s
        while pending:
            timeout = deadline - time.time()
            if timeout <= 0 and len(done) >= need:
                break                     # quorum met, deadline passed
            finished, pending = wait(
                pending, timeout=max(timeout, 0.005),
                return_when=FIRST_COMPLETED)
            for f in finished:
                done[futures[f]] = f.result()
            if not finished and len(done) >= need:
                break
        for f in pending:                 # backfill continues off-path
            f.cancel()
        ids, scores = self._merge(done.values(), k)
        return RetrievalResult(
            ids=ids, scores=scores,
            degraded=len(done) < len(self.runtimes),
            shards_answered=len(done), latency_s=time.time() - t0)

    @staticmethod
    def _merge(parts, k: int) -> tuple[np.ndarray, np.ndarray]:
        heap: list[tuple[float, int]] = []
        for ids, scores in parts:
            for i, s in zip(ids.tolist(), scores.tolist()):
                if len(heap) < k:
                    heapq.heappush(heap, (s, i))
                elif s > heap[0][0]:
                    heapq.heapreplace(heap, (s, i))
        heap.sort(reverse=True)
        return (np.asarray([i for _, i in heap], dtype=np.int64),
                np.asarray([s for s, _ in heap], dtype=np.float32))
