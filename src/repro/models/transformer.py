"""Decoder-only LM transformer family.

One configurable implementation covers the five assigned LM architectures:

* GQA (``n_kv_heads < n_heads``), explicit ``head_dim`` (Gemma3's 256,
  danube3's non-MXU-aligned 120);
* sliding-window attention (Mistral/danube3) and Gemma3's N:1
  local:global layer pattern with per-layer RoPE theta;
* optional qk-norm (Qwen3);
* SwiGLU dense MLP or Mixtral-style top-2 MoE (token-dispatch formulation —
  DESIGN.md explains why weight-gathered MoE beats all-to-all for E=8 on
  this mesh);
* scan-over-layers + remat for training/prefill (bounded HLO + memory),
  unrolled layers with per-layer window-capped ring KV caches for decode.

Attention never materializes the full ``[S, S]`` score matrix: queries are
processed in ``seq_chunk`` blocks (``lax.map``), each computing an exact
softmax over all keys — peak live memory is one chunk's scores.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .common import (causal_window_mask, normal_init, rms_norm,
                     split_keys)
from ..dist.sharding import constrain, dp_spmd_axes


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    rope_theta: float = 10_000.0
    rope_theta_global: float | None = None   # gemma3: global layers use 1e6
    qk_norm: bool = False
    sliding_window: int | None = None        # None = full attention
    global_every: int | None = None          # every Nth layer is global
    n_experts: int | None = None             # None = dense MLP
    top_k: int = 2
    capacity_factor: float = 1.25
    embed_scale: bool = False                # gemma: h *= sqrt(d_model)
    rmsnorm_plus_one: bool = False           # gemma (1 + w) convention
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    seq_chunk: int = 512                     # attention query-chunk
    loss_chunk: int = 512                    # logits/CE sequence-chunk
    moe_group_seq: int = 4096                # MoE dispatch group (tokens)
    kv_quant: bool = False                   # int8 KV cache (decode only)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts is not None

    def layer_windows(self) -> np.ndarray:
        """Per-layer attention window; 0 = full (global) attention."""
        w = np.zeros(self.n_layers, dtype=np.int32)
        if self.sliding_window is not None:
            w[:] = self.sliding_window
            if self.global_every is not None:
                w[self.global_every - 1:: self.global_every] = 0
        return w

    def layer_thetas(self) -> np.ndarray:
        t = np.full(self.n_layers, self.rope_theta, dtype=np.float32)
        if self.rope_theta_global is not None and self.global_every:
            t[self.global_every - 1:: self.global_every] = self.rope_theta_global
        return t


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(key, cfg: LMConfig) -> dict:
    l, d, f, v = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = iter(split_keys(key, 16))
    s_in = 1.0 / np.sqrt(d)
    layers = {
        "attn_norm": jnp.zeros((l, d)) if cfg.rmsnorm_plus_one
        else jnp.ones((l, d)),
        "mlp_norm": jnp.zeros((l, d)) if cfg.rmsnorm_plus_one
        else jnp.ones((l, d)),
        "wq": normal_init(next(ks), (l, d, h * hd), s_in),
        "wk": normal_init(next(ks), (l, d, kv * hd), s_in),
        "wv": normal_init(next(ks), (l, d, kv * hd), s_in),
        "wo": normal_init(next(ks), (l, h * hd, d), 1.0 / np.sqrt(h * hd)),
    }
    if cfg.qk_norm:
        layers["q_norm"] = jnp.ones((l, hd))
        layers["k_norm"] = jnp.ones((l, hd))
    if cfg.is_moe:
        e = cfg.n_experts
        layers["router"] = normal_init(next(ks), (l, d, e), s_in)
        layers["w_gate"] = normal_init(next(ks), (l, e, d, f), s_in)
        layers["w_up"] = normal_init(next(ks), (l, e, d, f), s_in)
        layers["w_down"] = normal_init(next(ks), (l, e, f, d), 1.0 / np.sqrt(f))
    else:
        layers["w_gate"] = normal_init(next(ks), (l, d, f), s_in)
        layers["w_up"] = normal_init(next(ks), (l, d, f), s_in)
        layers["w_down"] = normal_init(next(ks), (l, f, d), 1.0 / np.sqrt(f))
    params = {
        "embed": normal_init(next(ks), (v, d), 1.0),
        "layers": layers,
        "final_norm": jnp.zeros((d,)) if cfg.rmsnorm_plus_one
        else jnp.ones((d,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(next(ks), (d, v), s_in)
    return params


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def _heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array,
                      window: jax.Array, *, seq_chunk: int) -> jax.Array:
    """Exact causal/windowed attention, one query chunk at a time.

    q: [B, Sq, H, hd]; k, v: [B, Sk, KV, hd]; positions are absolute.
    Returns [B, Sq, H, hd]. Peak memory: one chunk's [B, H, Cq, Sk] scores.
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    cq = min(seq_chunk, sq)
    while sq % cq:
        cq //= 2
    nc = sq // cq
    scale = hd ** -0.5

    qg = q.reshape(b, nc, cq, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    posc = q_pos.reshape(nc, cq)

    def one_chunk(args):
        qc, pc = args                                       # [B,Cq,KV,G,hd], [Cq]
        s = jnp.einsum("bqkgh,bskh->bkgqs", qc.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale       # [B,KV,G,Cq,Sk]
        mask = causal_window_mask(pc, k_pos, window)        # [Cq, Sk]
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkgqs,bskh->bqkgh", p,
                          v.astype(jnp.float32)).astype(q.dtype)

    out = jax.lax.map(one_chunk, (qg, posc))                # [nc,B,Cq,KV,G,hd]
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hd)


def attention_block(cfg: LMConfig, lp: dict, x: jax.Array,
                    positions: jax.Array, window: jax.Array,
                    theta: jax.Array) -> jax.Array:
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.dtype
    q = _heads(x @ lp["wq"].astype(dt), h, hd)
    k = _heads(x @ lp["wk"].astype(dt), kv, hd)
    v = _heads(x @ lp["wv"].astype(dt), kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], eps=cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], eps=cfg.norm_eps)
    q = _rope_dyn(q, positions, theta)
    k = _rope_dyn(k, positions, theta)
    # Megatron-style TP: query heads over "model" (replicated if H % model
    # != 0, e.g. Gemma3's 4 heads), K/V replicated across the model axis
    # (GQA standard when TP > n_kv_heads).
    q = constrain(q, "dp", None, "model", None)
    k = constrain(k, "dp", None, None, None)
    v = constrain(v, "dp", None, None, None)
    out = chunked_attention(q, k, v, positions, positions, window,
                            seq_chunk=cfg.seq_chunk)
    out = out.reshape(b, s, h * hd) @ lp["wo"].astype(dt)
    return constrain(out, "dp", None, None)


def _rope_dyn(x, positions, theta):
    """RoPE with a (possibly traced, per-layer) theta scalar."""
    hd = x.shape[-1]
    exponent = jnp.arange(0, hd, 2, dtype=jnp.float32) / hd
    freqs = jnp.asarray(theta, jnp.float32) ** -exponent
    ang = positions[..., None].astype(jnp.float32) * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP / MoE
# --------------------------------------------------------------------------

def mlp_block(cfg: LMConfig, lp: dict, x: jax.Array) -> jax.Array:
    dt = cfg.dtype
    gate = jax.nn.silu(x @ lp["w_gate"].astype(dt))
    gate = constrain(gate, "dp", None, "model")
    up = constrain(x @ lp["w_up"].astype(dt), "dp", None, "model")
    out = (gate * up) @ lp["w_down"].astype(dt)
    return constrain(out, "dp", None, None)


def moe_block(cfg: LMConfig, lp: dict, x: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """Top-k token-dispatch MoE (scatter/gather, static capacity).

    Returns (output, aux_load_balance_loss). Tokens beyond an expert's
    capacity are dropped (contribute zero), standard GShard behaviour.
    Dispatch runs per GROUP (GShard's G dimension): groups are
    (batch × seq-chunks of ``moe_group_seq``), so the scatter/gather stays
    local to the data shard and the ``[G, E, C, d_ff]`` expert activations
    stay bounded for long-sequence prefill.
    """
    b, s, d = x.shape
    g_seq = min(cfg.moe_group_seq, s)
    while s % g_seq:
        g_seq //= 2
    groups = b * (s // g_seq)
    xg = constrain(x.reshape(groups, g_seq, d), "dp", None, None)
    # spmd_axis_name pins the group dim to the data axes so the partitioner
    # keeps dispatch/expert-GEMMs group-local (all-gathering the FSDP-
    # sharded expert weights) instead of partial-contracting + all-reducing
    # activations across shards.
    yg, aux = jax.vmap(lambda xr: _moe_tokens(cfg, lp, xr),
                       spmd_axis_name=dp_spmd_axes())(xg)
    yg = constrain(yg, "dp", None, None)
    return yg.reshape(b, s, d), aux.mean()


def _moe_tokens(cfg: LMConfig, lp: dict, xf: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """MoE over a flat token block xf [T, D] -> ([T, D], aux)."""
    dt = cfg.dtype
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(np.ceil(cfg.capacity_factor * t * k / e))

    logits = (xf @ lp["router"].astype(dt)).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)                             # [T, K]
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    # GShard aux loss: E * Σ_e f_e · p_e
    f_e = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)

    flat_e = idx.reshape(-1)                                     # [T*K]
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)              # [T*K, E]
    pos = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1              # rank in expert
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)          # sentinel last

    x_rep = jnp.repeat(xf, k, axis=0)                            # [T*K, D]
    buf = jnp.zeros((e * cap + 1, d), dt).at[slot].add(
        x_rep * keep[:, None].astype(dt))
    buf = constrain(buf, None, None)             # group-local (+dp via vmap)
    xin = buf[: e * cap].reshape(e, cap, d)

    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, lp["w_gate"].astype(dt)))
    gate = constrain(gate, None, None, "model")
    up = constrain(jnp.einsum("ecd,edf->ecf", xin, lp["w_up"].astype(dt)),
                   None, None, "model")
    h = jnp.einsum("ecf,efd->ecd", gate * up, lp["w_down"].astype(dt))

    hflat = jnp.concatenate([h.reshape(e * cap, d),
                             jnp.zeros((1, d), dt)], axis=0)
    hflat = constrain(hflat, None, None)
    y = hflat[slot].reshape(t, k, d)
    y = (y * (w * keep.reshape(t, k)).astype(dt)[..., None]).sum(axis=1)
    return y, aux


# --------------------------------------------------------------------------
# full forward (scan over layers, remat)
# --------------------------------------------------------------------------

def _layer_fwd(cfg: LMConfig, lp: dict, x: jax.Array, positions: jax.Array,
               window: jax.Array, theta: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    h = rms_norm(x, lp["attn_norm"], eps=cfg.norm_eps,
                 plus_one=cfg.rmsnorm_plus_one)
    x = x + attention_block(cfg, lp, h, positions, window, theta)
    h = rms_norm(x, lp["mlp_norm"], eps=cfg.norm_eps,
                 plus_one=cfg.rmsnorm_plus_one)
    if cfg.is_moe:
        y, aux = moe_block(cfg, lp, h)
    else:
        y, aux = mlp_block(cfg, lp, h), jnp.zeros((), jnp.float32)
    return x + y, aux


def forward(cfg: LMConfig, params: dict, tokens: jax.Array,
            positions: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Embed + all layers. Returns (hidden [B,S,D] in cfg.dtype, aux loss)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    x = params["embed"].astype(cfg.dtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    x = constrain(x, "dp", None, None)

    windows = jnp.asarray(cfg.layer_windows())
    thetas = jnp.asarray(cfg.layer_thetas())

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(x, scanned):
        lp, win, th = scanned
        x, aux = _layer_fwd(cfg, lp, x, positions, win, th)
        return x, aux

    x, auxes = jax.lax.scan(body, x, (params["layers"], windows, thetas))
    x = rms_norm(x, params["final_norm"], eps=cfg.norm_eps,
                 plus_one=cfg.rmsnorm_plus_one)
    return x, auxes.mean()


def _unembed(cfg: LMConfig, params: dict) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T.astype(cfg.dtype)
    return params["lm_head"].astype(cfg.dtype)


def loss_fn(cfg: LMConfig, params: dict, batch: dict) -> tuple[jax.Array, dict]:
    """Next-token CE, computed in sequence chunks (logits never [B,S,V]).

    batch: tokens [B, S] int32, labels [B, S] int32 (-1 = ignore).
    """
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    hidden, aux = forward(cfg, params, tokens)
    head = _unembed(cfg, params)

    cs = min(cfg.loss_chunk, s)
    while s % cs:
        cs //= 2
    nc = s // cs
    hs = hidden.reshape(b, nc, cs, cfg.d_model).transpose(1, 0, 2, 3)
    hs = constrain(hs, None, "dp", None, None)
    ls = labels.reshape(b, nc, cs).transpose(1, 0, 2)
    ls = constrain(ls, None, "dp", None)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_ce(args):
        # checkpointed so the [B, cs, V] logits are recomputed in the
        # backward instead of being stacked across all chunks
        h, lab = args
        logits = (h @ head).astype(jnp.float32)             # [B, cs, V]
        logits = constrain(logits, "dp", None, "model")     # vocab-sharded CE
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.maximum(lab, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        valid = (lab >= 0).astype(jnp.float32)
        return ((lse - gold) * valid).sum(), valid.sum()

    ces, cnts = jax.lax.map(chunk_ce, (hs, ls))
    n_tok = jnp.maximum(cnts.sum(), 1.0)
    ce = ces.sum() / n_tok
    loss = ce + 0.01 * aux
    return loss, {"loss": loss, "ce": ce, "aux": aux, "n_tokens": n_tok}


# --------------------------------------------------------------------------
# prefill + decode (serving)
# --------------------------------------------------------------------------

def prefill(cfg: LMConfig, params: dict, tokens: jax.Array
            ) -> tuple[jax.Array, dict]:
    """Full-sequence forward producing last-position logits + KV cache.

    The cache is uniform [L, B, S, KV, hd] (scan-stacked); decode uses
    per-layer window-capped caches — ``cache_from_prefill`` converts.
    """
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x = params["embed"].astype(cfg.dtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    x = constrain(x, "dp", None, None)
    windows = jnp.asarray(cfg.layer_windows())
    thetas = jnp.asarray(cfg.layer_thetas())
    kv, hd = cfg.n_kv_heads, cfg.hd

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(x, scanned):
        lp, win, th = scanned
        h = rms_norm(x, lp["attn_norm"], eps=cfg.norm_eps,
                     plus_one=cfg.rmsnorm_plus_one)
        q = _heads(h @ lp["wq"].astype(cfg.dtype), cfg.n_heads, hd)
        k = _heads(h @ lp["wk"].astype(cfg.dtype), kv, hd)
        v = _heads(h @ lp["wv"].astype(cfg.dtype), kv, hd)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"], eps=cfg.norm_eps)
            k = rms_norm(k, lp["k_norm"], eps=cfg.norm_eps)
        q = _rope_dyn(q, positions, th)
        k = _rope_dyn(k, positions, th)
        q = constrain(q, "dp", None, "model", None)
        k = constrain(k, "dp", None, None, None)
        v = constrain(v, "dp", None, None, None)
        att = chunked_attention(q, k, v, positions, positions, win,
                                seq_chunk=cfg.seq_chunk)
        att = att.reshape(b, s, cfg.n_heads * hd) @ lp["wo"].astype(cfg.dtype)
        x = x + constrain(att, "dp", None, None)
        h = rms_norm(x, lp["mlp_norm"], eps=cfg.norm_eps,
                     plus_one=cfg.rmsnorm_plus_one)
        if cfg.is_moe:
            y, _ = moe_block(cfg, lp, h)
        else:
            y = mlp_block(cfg, lp, h)
        return x + y, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], windows, thetas))
    x = rms_norm(x, params["final_norm"], eps=cfg.norm_eps,
                 plus_one=cfg.rmsnorm_plus_one)
    logits = (x[:, -1, :] @ _unembed(cfg, params)).astype(jnp.float32)
    return logits, {"k": ks, "v": vs, "pos": jnp.asarray(s, jnp.int32)}


def decode_cache_shapes(cfg: LMConfig, batch: int, seq_len: int
                        ) -> list[tuple[int, int, int, int]]:
    """Per-layer decode cache shapes: [B, min(S, window_i or S), KV, hd]."""
    out = []
    for w in cfg.layer_windows():
        s_i = seq_len if w == 0 else min(seq_len, int(w))
        out.append((batch, s_i, cfg.n_kv_heads, cfg.hd))
    return out


def init_decode_cache(cfg: LMConfig, batch: int, seq_len: int,
                      dtype=None) -> dict:
    """KV cache; with ``cfg.kv_quant`` entries are int8 + per-(pos, head)
    scales (KIVI-style per-token quantization — halves both the cache
    footprint and the decode HBM traffic, the dominant roofline term)."""
    dtype = dtype or cfg.dtype
    shapes = decode_cache_shapes(cfg, batch, seq_len)
    cache = {
        "pos": jnp.asarray(seq_len, jnp.int32),   # decode continues at S
    }
    if cfg.kv_quant:
        cache["k"] = [jnp.zeros(s, jnp.int8) for s in shapes]
        cache["v"] = [jnp.zeros(s, jnp.int8) for s in shapes]
        cache["k_scale"] = [jnp.ones(s[:3], jnp.float32) for s in shapes]
        cache["v_scale"] = [jnp.ones(s[:3], jnp.float32) for s in shapes]
    else:
        cache["k"] = [jnp.zeros(s, dtype) for s in shapes]
        cache["v"] = [jnp.zeros(s, dtype) for s in shapes]
    return cache


def _kv_quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[B, 1, KV, hd] -> int8 values + per-(B, 1, KV) scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def decode_step(cfg: LMConfig, params: dict, cache: dict, tokens: jax.Array
                ) -> tuple[jax.Array, dict]:
    """One decode step for the whole batch (lockstep position).

    tokens: [B] int32. Layers are unrolled so each layer keeps its own
    window-capped ring cache (a production decode graph, not a scan).
    """
    b = tokens.shape[0]
    h_heads, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h_heads // kv
    pos = cache["pos"]
    x = params["embed"].astype(cfg.dtype)[tokens][:, None, :]   # [B,1,D]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    thetas = cfg.layer_thetas()
    new_k, new_v = [], []
    new_ks, new_vs = [], []
    scale = hd ** -0.5
    posv = pos[None]

    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda p: p[i], params["layers"])
        ck, cv = cache["k"][i], cache["v"][i]
        s_i = ck.shape[1]
        h = rms_norm(x, lp["attn_norm"], eps=cfg.norm_eps,
                     plus_one=cfg.rmsnorm_plus_one)
        q = _heads(h @ lp["wq"].astype(cfg.dtype), h_heads, hd)
        k = _heads(h @ lp["wk"].astype(cfg.dtype), kv, hd)
        v = _heads(h @ lp["wv"].astype(cfg.dtype), kv, hd)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"], eps=cfg.norm_eps)
            k = rms_norm(k, lp["k_norm"], eps=cfg.norm_eps)
        th = jnp.asarray(thetas[i])
        q = _rope_dyn(q, posv, th)
        k = _rope_dyn(k, posv, th)
        slot = pos % s_i                                        # ring index
        if cfg.kv_quant:
            kq, ks_ = _kv_quantize(k)
            vq, vs_ = _kv_quantize(v)
            ck = jax.lax.dynamic_update_slice(ck, kq, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, vq, (0, slot, 0, 0))
            cks = jax.lax.dynamic_update_slice(
                cache["k_scale"][i], ks_, (0, slot, 0))
            cvs = jax.lax.dynamic_update_slice(
                cache["v_scale"][i], vs_, (0, slot, 0))
            new_ks.append(cks)
            new_vs.append(cvs)
            k_full = _kv_dequant(ck, cks)
            v_full = _kv_dequant(cv, cvs)
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, slot, 0, 0))
            k_full = ck.astype(jnp.float32)
            v_full = cv.astype(jnp.float32)
        new_k.append(ck)
        new_v.append(cv)
        n_valid = jnp.minimum(pos + 1, s_i)
        qh = q.reshape(b, kv, g, hd).astype(jnp.float32)
        s_ = jnp.einsum("bkgh,bskh->bkgs", qh, k_full) * scale   # [B,KV,G,S]
        valid = jnp.arange(s_i)[None, None, None, :] < n_valid
        s_ = jnp.where(valid, s_, -1e30)
        p = jax.nn.softmax(s_, axis=-1)
        att = jnp.einsum("bkgs,bskh->bkgh", p, v_full)
        att = att.reshape(b, 1, h_heads * hd).astype(cfg.dtype)
        x = x + att @ lp["wo"].astype(cfg.dtype)
        h = rms_norm(x, lp["mlp_norm"], eps=cfg.norm_eps,
                     plus_one=cfg.rmsnorm_plus_one)
        if cfg.is_moe:
            y, _ = moe_block(cfg, lp, h)
        else:
            y = mlp_block(cfg, lp, h)
        x = x + y

    x = rms_norm(x, params["final_norm"], eps=cfg.norm_eps,
                 plus_one=cfg.rmsnorm_plus_one)
    logits = (x[:, 0, :] @ _unembed(cfg, params)).astype(jnp.float32)
    out_cache = {"k": new_k, "v": new_v, "pos": pos + 1}
    if cfg.kv_quant:
        out_cache["k_scale"] = new_ks
        out_cache["v_scale"] = new_vs
    return logits, out_cache


def reduced(cfg: LMConfig, **overrides) -> LMConfig:
    """Smoke-test-sized variant of a config (same family/features)."""
    small = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.global_every is None
                     else cfg.global_every + 1),
        d_model=64, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2), head_dim=16, d_ff=128,
        vocab_size=256,
        sliding_window=None if cfg.sliding_window is None else 16,
        n_experts=None if cfg.n_experts is None else 4,
        seq_chunk=16, loss_chunk=16,
        dtype=jnp.float32,
    )
    small.update(overrides)
    return replace(cfg, **small)
