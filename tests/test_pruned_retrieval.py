"""Block-max pruned retrieval — the third planner regime (exact top-k).

Pins the pruning contract at every layer:

* **sparse** — the block-max table is a true per-(token, block) upper
  bound on stored scores (clamped at 0, so negative-IDF robertson
  differentials and missing postings are covered), its u8 form is
  CEIL-quantized (dequant ≥ true) with per-token scales, and
  ``prune_fragment_plan`` compacts whole blocks without disturbing
  fragment order or accumulator flags.
* **kernel + serve** — the pruned regime's output is BIT-identical (exact
  float equality, not allclose) to the single-buffer resident oracle on
  all five BM25 variants, under both planners and both bound dtypes,
  including empty queries, k ≥ n_docs and batches where everything
  outside the seed blocks is pruned; pruning provably fires on skewed
  corpora (both the pre-launch compaction and the in-kernel skip).
* **core** — ``plan_retrieval`` prices the pruned regime as gathered-cost
  × survivor_frac / PRUNE_DISCOUNT, never picks it without an estimate,
  and keeps the blocked/gathered decision bitwise-compatible with the
  two-regime planner.
* **engine** — ``scorer="pruned"`` serves exactly; a rescale whose
  boundaries move through posting-less documents reuses the block-max
  table and blocked layout (``blockmax_reused``) with zero posting
  re-uploads.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import make_corpus
from repro.core import (BM25Params, ScipyBM25, build_index,
                        build_sharded_indexes, dense_oracle_scores,
                        plan_retrieval, topk_numpy)
from repro.core.retrieval import PRUNE_DISCOUNT
from repro.serve import DeviceRetriever, RetrievalEngine
from repro.sparse.block_csr import (TRANSFERS, DeviceIndex,
                                    block_upper_bounds, build_block_max,
                                    fragment_plan, prune_fragment_plan,
                                    reset_transfer_stats)

# transfer/plan counters asserted here change legitimately when a
# chaos fault forces a ladder hop (e.g. an extra host-gather upload)
pytestmark = pytest.mark.no_chaos

ALL_VARIANTS = ["robertson", "atire", "lucene", "bm25l", "bm25+"]

SMALL = dict(block_size=16, tile=16, frag=8, q_max=8)


def _oracle(idx):
    """The exactness comparator: unpruned single-buffer resident path."""
    return DeviceRetriever(idx, regime="gathered", gather="resident",
                           double_buffer=False, acc_block=16, **SMALL)


def make_skewed_corpus(rng, n_docs=300, n_vocab=60):
    """Query token 0 has healthy IDF and a few spiky-tf documents — the
    score distribution block-max pruning exists for."""
    corpus = []
    for d in range(n_docs):
        base = rng.integers(1, n_vocab, size=10).astype(np.int32)
        if d % 3 == 0:
            tf0 = 20 if d % 90 == 0 else 1
            base = np.concatenate([np.zeros(tf0, np.int32), base])
        corpus.append(base)
    return corpus


# -- tentpole: bit-identical to the single-buffer oracle ----------------------

@pytest.mark.parametrize("method", ALL_VARIANTS)
@pytest.mark.parametrize("bmax_dtype", ["f32", "u8"])
def test_pruned_bit_identical_all_variants(method, bmax_dtype, rng):
    corpus = make_skewed_corpus(rng)
    idx = build_index(corpus, 60, params=BM25Params(method=method))
    oracle = _oracle(idx)
    pruned = DeviceRetriever(idx, regime="pruned", bmax_dtype=bmax_dtype, **SMALL)
    queries = [np.array([0], np.int32),
               rng.integers(0, 60, size=4).astype(np.int32),
               np.zeros(0, np.int32)]               # empty query in-batch
    for k in (1, 3, 9):
        i0, v0 = oracle.retrieve_batch(queries, k)
        i1, v1 = pruned.retrieve_batch(queries, k)
        np.testing.assert_array_equal(v0, v1)
        np.testing.assert_array_equal(i0, i1)
    # scores are also the true BM25 scores (not just self-consistent)
    sc = ScipyBM25(idx)
    for i, q in enumerate(queries):
        oracle_scores = sc.score(q)
        np.testing.assert_allclose(oracle_scores[i1[i]], v1[i], atol=1e-4)


@pytest.mark.parametrize("method", ALL_VARIANTS)
def test_pruned_device_plan_bit_identical(method, rng):
    corpus = make_skewed_corpus(rng)
    idx = build_index(corpus, 60, params=BM25Params(method=method))
    oracle = _oracle(idx)
    pruned = DeviceRetriever(idx, regime="pruned", plan="device", bmax_dtype="u8", **SMALL)
    queries = [np.array([0], np.int32),
               rng.integers(0, 60, size=5).astype(np.int32)]
    for k in (1, 4):
        i0, v0 = oracle.retrieve_batch(queries, k)
        i1, v1 = pruned.retrieve_batch(queries, k)
        np.testing.assert_array_equal(v0, v1)
        np.testing.assert_array_equal(i0, i1)


def test_prelaunch_compaction_fires_and_auto_picks_pruned(rng):
    """The regime must PRUNE, not just match: at k=1 the seed threshold
    beats most blocks before launch, and the cost model routes the batch
    to the pruned regime on its own."""
    corpus = make_skewed_corpus(rng)
    idx = build_index(corpus, 60, params=BM25Params())
    oracle = _oracle(idx)
    pruned = DeviceRetriever(idx, regime="pruned", **SMALL)
    q = [np.array([0], np.int32)]
    i0, v0 = oracle.retrieve_batch(q, 1)
    i1, v1 = pruned.retrieve_batch(q, 1)
    np.testing.assert_array_equal(v0, v1)
    np.testing.assert_array_equal(i0, i1)
    p1 = pruned.last_plan
    assert p1.regime == "pruned" and p1.frags_planned > 0
    assert p1.frags_pruned > p1.frags_planned // 2   # pre-launch compaction
    auto = DeviceRetriever(idx, regime="auto", gather="resident",
                           acc_block=16, **SMALL)
    auto.retrieve_batch(q, 1)
    assert auto.last_plan.regime == "pruned"
    assert auto.last_plan.survivor_frac < PRUNE_DISCOUNT


def test_inkernel_skip_fires_on_late_saturating_threshold(rng):
    """The in-kernel scoreboard test must cut DMAs the seed pass could
    not: two LOOSE decoy blocks (each query token's champion is a
    different document, so the block bound doubles what any one document
    scores) win the seeding and leave a weak threshold; the TIGHT winner
    (one document holding both tokens) folds early in block order, the
    board jumps past every later block's bound, and the victims' DMAs
    are skipped mid-launch."""
    def filler():
        return rng.integers(5, 40, size=8).astype(np.int32)

    docs = [filler() for _ in range(23 * 16)]

    def setdoc(i, tf0=0, tf1=0):
        docs[i] = np.concatenate([np.zeros(tf0, np.int32),
                                  np.ones(tf1, np.int32), filler()])

    for b in (0, 1):                                 # loose decoy blocks
        setdoc(b * 16, tf0=25)
        setdoc(b * 16 + 1, tf1=25)
    setdoc(2 * 16, tf0=15, tf1=15)                   # tight winner, block 2
    for b in range(3, 23):                           # victim blocks
        setdoc(b * 16, tf0=4)
        setdoc(b * 16 + 1, tf1=4)
    idx = build_index(docs, 40, params=BM25Params())
    oracle = _oracle(idx)
    q = [np.array([0, 1], np.int32)]
    i0, v0 = oracle.retrieve_batch(q, 1)
    for plan in ("host", "device"):
        pruned = DeviceRetriever(idx, regime="pruned", plan=plan, **SMALL)
        i1, v1 = pruned.retrieve_batch(q, 1)
        np.testing.assert_array_equal(v0, v1)
        np.testing.assert_array_equal(i0, i1)
        p = pruned.last_plan
        assert p.frags_skipped > p.frags_planned // 2, vars(p)
        assert i1[0, 0] == 2 * 16                    # the tight winner won


def test_pruned_edge_cases_exact(rng):
    """Empty batch entries, df-0 tail tokens, k ≥ n_docs, and k past the
    block size (pruning degenerates to the plain resident path)."""
    corpus = make_corpus(rng, n_docs=30, n_vocab=50)
    for method in ("lucene", "robertson"):
        idx = build_index(corpus, 50, params=BM25Params(method=method))
        oracle = _oracle(idx)
        pruned = DeviceRetriever(idx, regime="pruned", **SMALL)
        for qs in ([np.zeros(0, np.int32)],
                   [np.array([48, 49], np.int32)],
                   [np.zeros(0, np.int32), np.array([1, 2], np.int32)]):
            for k in (3, 30, 64):                    # 30 = n_docs, 64 > BS
                i0, v0 = oracle.retrieve_batch(qs, k)
                i1, v1 = pruned.retrieve_batch(qs, k)
                np.testing.assert_array_equal(v0, v1)
                np.testing.assert_array_equal(i0, i1)


def test_all_nonseed_fragments_pruned(rng):
    """One block owns every winner: everything outside the seed blocks is
    compacted away and the answer still matches the oracle exactly."""
    rng_ = np.random.default_rng(5)
    corpus = []
    for d in range(200):
        base = rng_.integers(1, 40, size=8).astype(np.int32)
        if d < 4:                                    # all spikes in block 0
            base = np.concatenate([np.zeros(25, np.int32), base])
        elif d % 5 == 0:
            base = np.concatenate([np.zeros(1, np.int32), base])
        corpus.append(base)
    idx = build_index(corpus, 40, params=BM25Params())
    oracle = _oracle(idx)
    pruned = DeviceRetriever(idx, regime="pruned", **SMALL)
    q = [np.array([0], np.int32)]
    i0, v0 = oracle.retrieve_batch(q, 1)
    i1, v1 = pruned.retrieve_batch(q, 1)
    np.testing.assert_array_equal(v0, v1)
    np.testing.assert_array_equal(i0, i1)
    p = pruned.last_plan
    n_seed = max(1, -(-1 // 16)) + 1                 # seed block budget
    surv = p.frags_planned - p.frags_pruned
    assert surv > 0
    # survivors are (at most) the seed blocks' fragments
    fp = fragment_plan(idx, np.array([0], np.int64), block_size=16, frag=8)
    per_block = np.bincount(fp.desc[3, :fp.n_frags])
    assert surv <= int(np.sort(per_block)[-n_seed:].sum())


def test_pruned_steady_state_zero_posting_bytes(rng):
    corpus = make_skewed_corpus(rng)
    idx = build_index(corpus, 60, params=BM25Params())
    qs = [np.array([0], np.int32), np.array([3, 7], np.int32)]
    host = DeviceRetriever(idx, regime="pruned", **SMALL)
    host.retrieve_batch(qs, 3)
    reset_transfer_stats()
    host.retrieve_batch(qs, 3)
    assert TRANSFERS.posting_bytes == 0              # bounds ship as
    assert TRANSFERS.descriptor_bytes > 0            # descriptors only
    dev = DeviceRetriever(idx, regime="pruned", plan="device", **SMALL)
    dev.retrieve_batch(qs, 3)
    reset_transfer_stats()
    dev.retrieve_batch(qs, 3)
    assert TRANSFERS.posting_bytes == 0              # device plan: nothing
    assert TRANSFERS.descriptor_bytes == 0


# -- sparse: bound validity and compaction invariants -------------------------

@pytest.mark.parametrize("method", ["robertson", "bm25l"])
@pytest.mark.parametrize("dtype", ["f32", "u8"])
def test_block_max_bounds_dominate_scores(method, dtype, rng):
    """Σ_t w_t·bmax[t, b] really bounds every doc's raw score in b."""
    corpus = make_corpus(rng, n_docs=80, n_vocab=30, max_len=25)
    idx = build_index(corpus, 30, params=BM25Params(method=method))
    bm = build_block_max(idx, block_size=16, dtype=dtype)
    uniq_tab = np.arange(30, dtype=np.int64)
    weights = rng.random((30, 4)).astype(np.float32)
    ub = block_upper_bounds(bm, uniq_tab, weights)
    for q in range(4):
        scores = np.zeros(idx.doc_lens.size, np.float64)
        for t in range(30):
            lo, hi = idx.indptr[t], idx.indptr[t + 1]
            scores[idx.doc_ids[lo:hi]] += weights[t, q] * idx.scores[lo:hi]
        for b in range(bm.n_blocks):
            blk_scores = scores[b * 16:(b + 1) * 16]
            if blk_scores.size:
                assert blk_scores.max() <= ub[b, q] + 1e-6


def test_u8_quantization_conservative_per_token(rng):
    corpus = make_corpus(rng, n_docs=100, n_vocab=40, max_len=20)
    idx = build_index(corpus, 40, params=BM25Params())
    f32 = build_block_max(idx, block_size=16, dtype="f32")
    u8 = build_block_max(idx, block_size=16, dtype="u8")
    assert u8.quantized and not f32.quantized
    assert u8.scale.shape == (40,)                   # per-token scales
    r32, r8 = f32.rows(np.arange(40)), u8.rows(np.arange(40))
    assert (r8 >= r32 - 1e-7).all()                  # never under-bounds
    # and stays tight: within one quantization step of the true max
    step = np.where(u8.scale > 0, u8.scale, 1.0)[:, None]
    assert (r8 <= r32 + step + 1e-7).all()
    assert u8.host.nbytes * 4 <= f32.host.nbytes + 4


def test_prune_fragment_plan_preserves_structure(rng):
    corpus = make_corpus(rng, n_docs=120, n_vocab=40, max_len=25)
    idx = build_index(corpus, 40, params=BM25Params())
    uniq = np.unique(rng.integers(0, 40, size=8)).astype(np.int64)
    fp = fragment_plan(idx, uniq, block_size=16, frag=8)
    blocks = np.unique(fp.desc[3, :fp.n_frags])
    keep = np.zeros(int(blocks.max()) + 1, dtype=bool)
    keep[blocks[::2]] = True                         # drop every other block
    pf = prune_fragment_plan(fp, keep)
    d = pf.desc[:, :pf.n_frags]
    assert set(np.unique(d[3])) == set(blocks[::2])
    # survivors keep order and flags: equal to re-planning by block subset
    ref = fp.desc[:, :fp.n_frags]
    ref = ref[:, keep[ref[3]]]
    np.testing.assert_array_equal(d, ref)
    first = np.flatnonzero(d[4] == 1)
    expect = np.flatnonzero(np.r_[True, d[3][1:] != d[3][:-1]])
    np.testing.assert_array_equal(first, expect)
    np.testing.assert_array_equal(pf.vis_blocks, fp.vis_blocks)  # UNPRUNED
    # keep-none compacts to all-padding
    none = prune_fragment_plan(fp, np.zeros_like(keep))
    assert none.n_frags == 0 and (none.desc == 0).all()


def test_compact_fragment_table_device_matches_host(rng):
    from repro.sparse.fragment_device import compact_fragment_table
    corpus = make_corpus(rng, n_docs=100, n_vocab=30, max_len=20)
    idx = build_index(corpus, 30, params=BM25Params())
    uniq = np.unique(rng.integers(0, 30, size=6)).astype(np.int64)
    fp = fragment_plan(idx, uniq, block_size=16, frag=8)
    blocks = np.unique(fp.desc[3, :fp.n_frags])
    keep_blocks = np.zeros(int(blocks.max()) + 1, dtype=bool)
    keep_blocks[blocks[1::2]] = True
    host = prune_fragment_plan(fp, keep_blocks)
    mask = np.zeros(fp.nf_pad, dtype=bool)
    mask[:fp.n_frags] = keep_blocks[fp.desc[3, :fp.n_frags]]
    dev, n = compact_fragment_table(jnp.asarray(fp.desc), jnp.asarray(mask))
    assert int(n) == host.n_frags
    np.testing.assert_array_equal(np.asarray(dev)[:, :host.n_frags],
                                  host.desc[:, :host.n_frags])
    assert (np.asarray(dev)[:, host.n_frags:] == 0).all()


# -- core: the three-regime cost model ---------------------------------------

def test_planner_prices_pruned_regime():
    # without an estimate the two-regime decision is unchanged
    assert plan_retrieval(100, 1000).regime == "gathered"
    assert plan_retrieval(100, 150).regime == "blocked"
    # a strong estimate wins over both
    p = plan_retrieval(100, 1000, survivor_frac=0.1)
    assert p.regime == "pruned" and p.survivor_frac == 0.1
    # survivor_frac == PRUNE_DISCOUNT prices pruned == gathered: the
    # existing regime wins ties
    assert plan_retrieval(100, 1000,
                          survivor_frac=PRUNE_DISCOUNT).regime == "gathered"
    # pruned must also beat the full scan
    assert plan_retrieval(100, 20, survivor_frac=0.5).regime == "blocked"
    assert plan_retrieval(100, 20, survivor_frac=0.01).regime == "pruned"
    # forced regime is recorded as such
    p = plan_retrieval(100, 1000, regime="pruned")
    assert p.regime == "pruned" and p.forced
    with pytest.raises(ValueError):
        plan_retrieval(1, 1, regime="wand")


# -- engine: serving + incremental re-blocking on rescale ---------------------

def test_engine_pruned_scorer_exact(rng):
    corpus = make_skewed_corpus(rng, n_docs=120, n_vocab=40)
    p = BM25Params(method="bm25+")
    shards = build_sharded_indexes(corpus, 40, 3, params=p)
    eng = RetrievalEngine(shards, k=5, deadline_s=30.0, scorer="pruned",
                          scorer_opts=dict(**SMALL))
    qs = [np.array([0], np.int32),
          rng.integers(0, 40, size=4).astype(np.int32)]
    rb = eng.retrieve_batch(qs)
    assert rb.ids.shape == (2, 5) and not rb.degraded
    for i, q in enumerate(qs):
        oracle = dense_oracle_scores(corpus, 40, q, p)
        _, ref_v = topk_numpy(oracle[None], 5)
        np.testing.assert_allclose(rb.scores[i], ref_v[0], atol=1e-3)
        np.testing.assert_allclose(oracle[rb.ids[i]], rb.scores[i],
                                   atol=1e-3)


def test_rescale_reuses_blockmax_through_empty_doc_boundary(rng):
    """Boundary moves through posting-less docs: postings byte-identical,
    doc range shifted — the runtime rebuilds but recycles the resident
    layouts + block-max table with ZERO posting re-uploads."""
    corpus = [rng.integers(0, 12, size=5).astype(np.int32)
              for _ in range(12)]
    corpus[4] = np.zeros(0, np.int32)
    corpus[5] = np.zeros(0, np.int32)
    shards = build_sharded_indexes(corpus, 12, 2, params=BM25Params())
    eng = RetrievalEngine(shards, k=3, deadline_s=30.0, scorer="pruned",
                          scorer_opts=dict(**SMALL))
    # 2 shards of 6 -> 3 shards of 4: shard 0 keeps docs 0-3 and exactly
    # its old postings (4, 5 were empty), so its rebuild is incremental
    reset_transfer_stats()
    eng.rescale(3)
    assert eng.last_build_stats["blockmax_reused"] >= 1
    reused_rt = eng.runtimes[0]._scorer.dindex.reused
    assert reused_rt["bmax"] and reused_rt["csc"]
    q = rng.integers(0, 12, size=3).astype(np.int32)
    r = eng.retrieve(q)
    oracle = dense_oracle_scores(corpus, 12, q, BM25Params())
    _, ref_v = topk_numpy(oracle[None], 3)
    np.testing.assert_allclose(np.sort(r.scores), np.sort(ref_v[0]),
                               atol=1e-4)
    np.testing.assert_allclose(oracle[r.ids], r.scores, atol=1e-4)


def test_device_index_reuse_requires_identical_postings(rng):
    corpus = make_corpus(rng, n_docs=40, n_vocab=20)
    idx = build_index(corpus, 20, params=BM25Params())
    di = DeviceIndex.build(idx, block_size=16, tile=16, frag=8)
    di2 = DeviceIndex.build(idx, block_size=16, tile=16, frag=8,
                            reuse_from=di)
    assert di2.reused == {"csc": True, "blocked": True, "bmax": True}
    assert di2.csc_doc_ids is di.csc_doc_ids
    assert di2.bmax is di.bmax
    other = build_index(corpus[:-1], 20, params=BM25Params())
    di3 = DeviceIndex.build(other, block_size=16, tile=16, frag=8,
                            reuse_from=di)
    assert di3.reused == {"csc": False, "blocked": False, "bmax": False}
    # mismatched grid parameters also rebuild
    di4 = DeviceIndex.build(idx, block_size=32, tile=16, frag=8,
                            reuse_from=di)
    assert not di4.reused["bmax"]
