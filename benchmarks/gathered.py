"""BENCH_2 — query-driven gathered retrieval vs the full-scan fused path.

The PR-2 perf story: the fused full-scan pipeline walks every posting tile
per query batch (O(nnz)), so BENCH_1 showed scipy's slice-and-sum beating
it on a 1k-doc corpus and the gap grows linearly with corpus size. The
gathered path does O(Σ df over the batch's unique tokens) — the paper's
eager-sparsity asymptotics, restored on device.

Sweep: corpus size × query df profile:

* ``head`` — query tokens sampled from the highest-df vocabulary ranks
  (worst case for the gather: Σ df is as large as it gets);
* ``tail`` — tokens from the Zipf tail (best case: tiny Σ df).

Per cell we report gathered / full-scan / scipy per-batch latency and the
**work ratio** ``nnz / Σ df`` — the posting-count advantage the gathered
layout has before either kernel runs. CPU wall times (Pallas in interpret
mode): compare paths relatively; the work ratio is the TPU argument.

Written to ``BENCH_2.json`` by ``benchmarks/run.py``.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import BM25Params, build_index, pad_queries
from repro.data.corpus import zipf_corpus


def _profile_queries(rng: np.random.Generator, profile: str, n_vocab: int,
                     batch: int, q_len: int) -> list[np.ndarray]:
    """head: top-df ranks (Zipf rank order = df order); tail: low-df ranks."""
    if profile == "head":
        pool = np.arange(0, max(32, n_vocab // 100))
    else:
        pool = np.arange(n_vocab // 2, n_vocab)
    return [rng.choice(pool, size=q_len).astype(np.int32)
            for _ in range(batch)]


def bench_cell(n_docs: int, profile: str, *, n_vocab: int = 10_000,
               batch: int = 8, k: int = 10, avg_len: int = 60,
               tile: int = 2048, repeats: int = 2) -> dict:
    from repro.serve import DeviceRetriever
    from repro.core import ScipyBM25, batch_posting_budget

    corpus = zipf_corpus(n_docs, n_vocab, avg_len=avg_len)
    idx = build_index(corpus, n_vocab, params=BM25Params())
    rng = np.random.default_rng(3)
    queries = _profile_queries(rng, profile, n_vocab, batch, q_len=5)
    toks, _ = pad_queries(queries, 8)
    sum_df = batch_posting_budget(idx, toks.reshape(1, -1))
    nnz = idx.nnz

    gathered = DeviceRetriever(idx, regime="gathered", tile=tile)
    blocked = DeviceRetriever(idx, regime="blocked", block_size=512, tile=tile)
    scipy_r = ScipyBM25(idx)

    def timed(fn):
        fn()                                     # compile/warmup
        t0 = time.perf_counter()
        for _ in range(repeats):
            fn()
        return (time.perf_counter() - t0) / repeats

    t_gath = timed(lambda: gathered.retrieve_batch(queries, k))
    t_full = timed(lambda: blocked.retrieve_batch(queries, k))
    t_scipy = timed(lambda: [scipy_r.retrieve(q, k) for q in queries])

    return {
        "n_docs": n_docs, "n_vocab": n_vocab, "batch": batch, "k": k,
        "profile": profile, "nnz": int(nnz), "sum_df": int(sum_df),
        "work_ratio_nnz_over_sum_df": round(nnz / max(sum_df, 1), 1),
        "gathered_batch_s": round(t_gath, 4),
        "full_scan_batch_s": round(t_full, 4),
        "scipy_batch_s": round(t_scipy, 4),
        "gathered_vs_full_scan_speedup": round(t_full / max(t_gath, 1e-9),
                                               1),
        "gathered_vs_scipy_speedup": round(t_scipy / max(t_gath, 1e-9), 2),
    }


def run(*, fast: bool = False) -> dict:
    # the acceptance corpus stays >= 50k docs even in --fast
    sizes = (5_000, 50_000) if fast else (5_000, 20_000, 50_000)
    cells = [bench_cell(n, profile,
                        n_vocab=5_000 if fast else 10_000,
                        repeats=1 if n >= 20_000 else 2)
             for n in sizes for profile in ("head", "tail")]
    biggest = [c for c in cells if c["n_docs"] == max(sizes)]
    return {
        "cells": cells,
        "summary": {
            "acceptance_50k_gathered_beats_full_scan": all(
                c["gathered_batch_s"] < c["full_scan_batch_s"]
                for c in biggest),
            "note": "CPU wall times; Pallas kernels run in interpret "
                    "mode — compare paths relatively, the work ratio "
                    "(nnz/Σdf) is the device argument",
        },
    }
