"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel file carries the ``pl.pallas_call`` + BlockSpec implementation;
``ops.py`` exposes the jit'd wrappers; ``ref.py`` holds the pure-jnp oracles
the tests pin every kernel against (interpret mode on CPU).

Kernels:
  bm25_block_score  — the paper's hot loop as membership-GEMM + scatter-GEMM
                      (full-scan regime: O(nnz) per query batch)
  bm25_gather_score — query-driven gather→score→top-k (inverted-index
                      regime: O(Σ df(qᵢ)) per query batch)
  block_segment_sum — shared scatter-add substrate (GNN / bags / scoring)
  embedding_bag     — HBM row-DMA gather + in-register weighted reduce
  blockwise_topk    — per-block iterative-max selection (2-stage top-k)
"""

from .ops import (bm25_retrieve_blocked, bm25_retrieve_gathered,
                  bm25_score_blocked, embedding_bag, segment_sum_blocked,
                  topk)
from . import ref

__all__ = ["bm25_retrieve_blocked", "bm25_retrieve_gathered",
           "bm25_score_blocked", "embedding_bag", "segment_sum_blocked",
           "topk", "ref"]
