"""Shared fixtures. NOTE: device count stays 1 here (the 512-device flag is
set ONLY inside launch/dryrun.py); multi-device tests spawn subprocesses or
use mesh-of-one."""

import numpy as np
import pytest

# ``hypothesis`` is an optional dev dependency (declared in pyproject.toml's
# ``test`` extra). When absent, property tests skip instead of breaking
# collection: import ``given``/``settings``/``st`` from here, not hypothesis.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (pip install '.[test]')")(f)

    def settings(*a, **k):
        return lambda f: f


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_corpus(rng, n_docs=60, n_vocab=50, max_len=30):
    return [rng.integers(0, n_vocab, size=rng.integers(1, max_len)
                         ).astype(np.int32) for _ in range(n_docs)]
