"""Table 2 — NDCG@10 under the tokenizer ablation (stopwords × stemmer).

The paper's finding: the Snowball stemmer modestly improves NDCG on
average, stopwords have a small effect. The synthetic corpus plants
relevance by topic (data/corpus.py) and inflects topical words so that
stemming actually matters (queries use different surface forms than
documents).
"""

from __future__ import annotations

import numpy as np

from repro.core import BM25Retriever
from repro.data.corpus import SyntheticCorpus, ndcg_at_k

_SUFFIXES = ["", "s", "ed", "ing", "ly"]


def _inflect(text: str, rng: np.random.Generator) -> str:
    return " ".join(w + rng.choice(_SUFFIXES) for w in text.split())


def run(n_docs: int = 800, n_queries: int = 60, k: int = 10) -> list[dict]:
    base = SyntheticCorpus(n_docs=n_docs, n_topics=16, vocab_size=900,
                           seed=3)
    rng = np.random.default_rng(7)
    docs = [_inflect(d, rng) for d in base.documents]
    queries, qrels = base.queries_with_qrels(n_queries)
    queries = [_inflect(q, rng) for q in queries]
    # mix stopwords into queries so the stopword axis is exercised
    queries = [f"the {q} of a" for q in queries]

    rows = []
    for stop in ("english", None):
        for stem in ("snowball", None):
            r = BM25Retriever(method="lucene", k1=1.5, b=0.75,
                              stopwords=stop, stemmer=stem).index(docs)
            ids, _ = r.retrieve(queries, k=k)
            ids = np.asarray(ids)
            ndcg = float(np.mean([
                ndcg_at_k(ids[i], qrels[i], k) for i in range(len(queries))
            ]))
            rows.append({"stopwords": stop or "none",
                         "stemmer": stem or "none",
                         "ndcg@10": round(ndcg, 4)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
