"""jit'd public wrappers around the Pallas kernels.

These are the entry points the rest of the framework calls; each picks the
kernel path on TPU and interpret mode elsewhere, and composes the kernel
with the surrounding host/JAX logic (layout reshapes, nonoccurrence shift,
global top-k merge).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .blockwise_topk import blockwise_topk_kernel
from .bm25_block_score import bm25_block_score
from .block_segment_sum import block_segment_sum
from .embedding_bag import embedding_bag_kernel


def bm25_score_blocked(token_ids: jax.Array, local_doc: jax.Array,
                       scores: jax.Array, uniq_tokens: jax.Array,
                       weights: jax.Array, nonocc_shift: jax.Array, *,
                       block_size: int, n_docs: int,
                       tile_p: int = 512) -> jax.Array:
    """Batched BM25 scores [B, n_docs] from block-bucketed postings.

    ``nonocc_shift`` is the per-query ``Σᵢ wᵢ·S⁰(qᵢ)`` constant ([B]) — zero
    for the sparse variants, the §2.1 shift for BM25L/BM25+/TFldp.
    """
    out = bm25_block_score(token_ids, local_doc, scores, uniq_tokens,
                           weights, block_size=block_size, tile_p=tile_p)
    nb, bs, b = out.shape
    flat = jnp.transpose(out, (2, 0, 1)).reshape(b, nb * bs)[:, :n_docs]
    return flat + nonocc_shift[:, None]


def segment_sum_blocked(values: jax.Array, segment_ids: jax.Array, *,
                        num_segments: int, tile_p: int = 512) -> jax.Array:
    """Blocked scatter-add: [nb, P, D] + [nb, P] -> [nb, num_segments, D]."""
    return block_segment_sum(values, segment_ids,
                             num_segments=num_segments, tile_p=tile_p)


def embedding_bag(table: jax.Array, indices: jax.Array,
                  weights: jax.Array | None = None, *,
                  tile_b: int = 128) -> jax.Array:
    """Kernel-backed EmbeddingBag; pads B up to a tile multiple if needed."""
    b, f = indices.shape
    if weights is None:
        weights = jnp.ones((b, f), table.dtype)
    pad = (-b) % tile_b
    if pad:
        indices = jnp.concatenate(
            [indices, jnp.full((pad, f), -1, indices.dtype)])
        weights = jnp.concatenate([weights, jnp.zeros((pad, f), weights.dtype)])
    out = embedding_bag_kernel(table, indices, weights, tile_b=tile_b)
    return out[:b]


def topk(x: jax.Array, k: int, *, block: int = 4096
         ) -> tuple[jax.Array, jax.Array]:
    """Two-stage top-k over the last axis: per-block kernel + global merge.

    Accepts [n] or [B, n]; returns (values, indices) sorted descending.
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    bsz, n = x.shape
    if n % block or n <= block:
        vals, idx = jax.lax.top_k(x, k)                 # fallback: tiny inputs
    else:
        nb = n // block
        kb = min(k, block)
        xb = x.reshape(bsz * nb, block)
        bvals, bidx = blockwise_topk_kernel(xb, k=kb)
        bvals = bvals.reshape(bsz, nb * kb)
        gidx = (bidx.reshape(bsz, nb, kb)
                + (jnp.arange(nb, dtype=jnp.int32) * block)[None, :, None]
                ).reshape(bsz, nb * kb)
        vals, merge_idx = jax.lax.top_k(bvals, k)       # tiny global merge
        idx = jnp.take_along_axis(gidx, merge_idx, axis=-1)
    if squeeze:
        return vals[0], idx[0]
    return vals, idx
