"""Distribution layer: logical-axis sharding helpers (see ``sharding.py``)."""

from .sharding import (activation_sharding, batch_pspec, constrain, data_axes,
                       dp_spmd_axes, param_pspecs)

__all__ = ["activation_sharding", "batch_pspec", "constrain", "data_axes",
           "dp_spmd_axes", "param_pspecs"]
