"""Table 1 — queries per second: eager sparse scoring vs lazy baseline.

The paper benchmarks BM25S against Rank-BM25 (lazy Python scoring),
BM25-PT and Elasticsearch on BEIR. Offline here, the corpora are Zipfian
synthetic at several sizes; the columns are:

  bm25s_scipy — the paper's exact retrieval path (CSC slice + sum,
                np.argpartition top-k)
  bm25s_jax   — this framework's device path (gather + segment_sum,
                XLA top_k), single CPU device
  rank_lazy   — faithful Rank-BM25 reimplementation (lazy per-query
                scoring; the Table-1 baseline)

The reported ratio bm25s_scipy / rank_lazy reproduces the paper's claim
(orders of magnitude; grows with corpus size since lazy scoring is
O(|C| · |Q|) Python-loop work per query).
"""

from __future__ import annotations

import time


from repro.core import (BM25Params, DeviceIndex, RankBM25Baseline, ScipyBM25,
                        build_index, pad_queries, score_batch, suggest_p_max,
                        topk_jax)
from repro.data.corpus import zipf_corpus, zipf_queries


def _time_qps(fn, queries, *, repeats: int = 1) -> float:
    t0 = time.perf_counter()
    for _ in range(repeats):
        for q in queries:
            fn(q)
    dt = time.perf_counter() - t0
    return len(queries) * repeats / dt


def run(sizes=((2000, 5000), (10000, 20000), (50000, 50000)),
        n_queries: int = 40, k: int = 10) -> list[dict]:
    rows = []
    for n_docs, n_vocab in sizes:
        corpus = zipf_corpus(n_docs, n_vocab, avg_len=80)
        queries = zipf_queries(n_queries, n_vocab, q_len=5)
        p = BM25Params(method="lucene")
        idx = build_index(corpus, n_vocab, params=p)

        scipy_scorer = ScipyBM25(idx)
        qps_scipy = _time_qps(lambda q: scipy_scorer.retrieve(q, k), queries)

        di = DeviceIndex.from_host(idx)
        toks, wts = pad_queries(queries, 8)
        p_max = suggest_p_max(idx, 8)
        import jax.numpy as jnp
        jt, jw = jnp.asarray(toks), jnp.asarray(wts)

        def jax_batch():
            s = score_batch(di, jt, jw, p_max=p_max)
            idxs, vals = topk_jax(s, k)
            vals.block_until_ready()

        jax_batch()                                  # compile
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            jax_batch()
        qps_jax = n_queries * reps / (time.perf_counter() - t0)

        lazy = RankBM25Baseline(corpus, params=BM25Params(method="robertson"))
        lazy_queries = queries[: max(4, n_queries // 10)]
        qps_lazy = _time_qps(lambda q: lazy.retrieve(q, k), lazy_queries)

        rows.append({
            "n_docs": n_docs, "n_vocab": n_vocab,
            "bm25s_scipy_qps": round(qps_scipy, 2),
            "bm25s_jax_qps": round(qps_jax, 2),
            "rank_lazy_qps": round(qps_lazy, 2),
            "speedup_scipy_vs_lazy": round(qps_scipy / qps_lazy, 1),
            "speedup_jax_vs_lazy": round(qps_jax / qps_lazy, 1),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
