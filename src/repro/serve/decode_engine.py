"""LM decode engine: slot-based continuous batching over ragged positions.

The dry-run decode cells use the lockstep ``decode_step`` (whole batch at
one position — the shape that matters for the roofline). Serving needs
per-request positions; this engine keeps a fixed batch of SLOTS, each with
its own position and ring cache row, and advances all active slots in one
jitted step per token (``decode_step_ragged``). Finished slots are refilled
from the queue — requests of different lengths never force a recompile
because every shape is static.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from ..models.common import rms_norm
from ..models.transformer import (LMConfig, _heads, _rope_dyn, _unembed,
                                  mlp_block, moe_block)


def decode_step_ragged(cfg: LMConfig, params: dict, cache: dict,
                       tokens: jax.Array, pos: jax.Array, active: jax.Array
                       ) -> tuple[jax.Array, dict]:
    """One token for every ACTIVE slot; slots carry independent positions.

    tokens, pos, active: [B]. Inactive slots compute but do not write cache.
    """
    b = tokens.shape[0]
    h_heads, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h_heads // kv
    x = params["embed"].astype(cfg.dtype)[tokens][:, None, :]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    thetas = cfg.layer_thetas()
    scale = hd ** -0.5
    new_k, new_v = [], []
    posv = pos[:, None]                                  # [B, 1]

    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda p: p[i], params["layers"])
        ck, cv = cache["k"][i], cache["v"][i]
        s_i = ck.shape[1]
        h = rms_norm(x, lp["attn_norm"], eps=cfg.norm_eps,
                     plus_one=cfg.rmsnorm_plus_one)
        q = _heads(h @ lp["wq"].astype(cfg.dtype), h_heads, hd)
        k = _heads(h @ lp["wk"].astype(cfg.dtype), kv, hd)
        v = _heads(h @ lp["wv"].astype(cfg.dtype), kv, hd)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"], eps=cfg.norm_eps)
            k = rms_norm(k, lp["k_norm"], eps=cfg.norm_eps)
        th = jnp.asarray(thetas[i])
        q = _rope_dyn(q, posv, th)
        k = _rope_dyn(k, posv, th)
        slot = (pos % s_i).astype(jnp.int32)             # [B] per-row ring
        rows = jnp.arange(b)
        upd_k = jnp.where(active[:, None, None],
                          k[:, 0].astype(ck.dtype), ck[rows, slot])
        upd_v = jnp.where(active[:, None, None],
                          v[:, 0].astype(cv.dtype), cv[rows, slot])
        ck = ck.at[rows, slot].set(upd_k)
        cv = cv.at[rows, slot].set(upd_v)
        new_k.append(ck)
        new_v.append(cv)
        n_valid = jnp.minimum(pos + 1, s_i)[:, None]     # [B, 1]
        qh = q.reshape(b, kv, g, hd).astype(jnp.float32)
        s_ = jnp.einsum("bkgh,bskh->bkgs", qh,
                        ck.astype(jnp.float32)) * scale
        valid = jnp.arange(s_i)[None, :] < n_valid       # [B, S]
        s_ = jnp.where(valid[:, None, None, :], s_, -1e30)
        p = jax.nn.softmax(s_, axis=-1)
        att = jnp.einsum("bkgs,bskh->bkgh", p, cv.astype(jnp.float32))
        att = att.reshape(b, 1, h_heads * hd).astype(cfg.dtype)
        x = x + att @ lp["wo"].astype(cfg.dtype)
        h = rms_norm(x, lp["mlp_norm"], eps=cfg.norm_eps,
                     plus_one=cfg.rmsnorm_plus_one)
        y = moe_block(cfg, lp, h)[0] if cfg.is_moe else mlp_block(cfg, lp, h)
        x = x + y

    x = rms_norm(x, params["final_norm"], eps=cfg.norm_eps,
                 plus_one=cfg.rmsnorm_plus_one)
    logits = (x[:, 0, :] @ _unembed(cfg, params)).astype(jnp.float32)
    return logits, {"k": new_k, "v": new_v, "pos": cache["pos"]}


@dataclass
class _Slot:
    request_id: int | None = None
    prompt: list[int] = field(default_factory=list)
    fed: int = 0                  # prompt tokens consumed
    generated: list[int] = field(default_factory=list)
    max_new: int = 16


class DecodeEngine:
    """Fixed-slot continuous batching around ``decode_step_ragged``."""

    def __init__(self, cfg: LMConfig, params, *, n_slots: int = 4,
                 max_seq: int = 256, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = transformer.init_decode_cache(cfg, n_slots, max_seq)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: deque = deque()
        self.finished: dict[int, list[int]] = {}
        self._next_id = 0
        self._step = jax.jit(
            lambda p, c, t, pos, act: decode_step_ragged(
                cfg, p, c, t, pos, act))

    def submit(self, prompt_ids: list[int], *, max_new: int = 16) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, list(prompt_ids), max_new))
        return rid

    def _fill_slots(self) -> None:
        for i, s in enumerate(self.slots):
            if s.request_id is None and self.queue:
                rid, prompt, max_new = self.queue.popleft()
                self.slots[i] = _Slot(request_id=rid, prompt=prompt,
                                      max_new=max_new)
                self.pos = self.pos.at[i].set(0)

    def step(self) -> None:
        """Advance every active slot by one token (prefill or generate)."""
        self._fill_slots()
        tokens = np.zeros(self.n_slots, np.int32)
        active = np.zeros(self.n_slots, bool)
        for i, s in enumerate(self.slots):
            if s.request_id is None:
                continue
            active[i] = True
            if s.fed < len(s.prompt):
                tokens[i] = s.prompt[s.fed]
            else:
                tokens[i] = s.generated[-1]
        if not active.any():
            return
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens), self.pos,
            jnp.asarray(active))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self.pos = self.pos + jnp.asarray(active, jnp.int32)
        for i, s in enumerate(self.slots):
            if s.request_id is None:
                continue
            if s.fed < len(s.prompt):
                s.fed += 1
                if s.fed == len(s.prompt):
                    s.generated.append(int(nxt[i]))
            else:
                s.generated.append(int(nxt[i]))
            if len(s.generated) >= s.max_new:
                self.finished[s.request_id] = s.generated
                self.slots[i] = _Slot()

    def run_until_done(self, max_steps: int = 10_000) -> dict[int, list[int]]:
        for _ in range(max_steps):
            if not self.queue and all(s.request_id is None
                                      for s in self.slots):
                break
            self.step()
        return self.finished
