"""Top-k selection and the sharded retrieval step.

The paper's §2 "Top-k selection": average-O(n) partition-based selection
(np.argpartition) or JAX/XLA ``top_k`` — it observes the JAX path is faster
in practice, so that is our device default.

At pod scale the corpus is document-sharded; top-k generalizes losslessly to
a two-stage merge: per-shard local top-k (each shard's winners are a superset
of its contribution to the global winners), all-gather the ``k`` candidates
per shard (tiny: ``shards × k × 8B``), then a global top-k over
``shards × k``. ``sharded_retrieve`` expresses this with ``shard_map`` so the
same code runs on 1 device (tests) and 512 chips (dry-run).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .scoring import DeviceIndex, score_query


def topk_numpy(scores: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Paper's np.argpartition path (introspective selection, O(n) average)."""
    k = min(k, scores.shape[-1])
    part = np.argpartition(scores, -k, axis=-1)[..., -k:]
    vals = np.take_along_axis(scores, part, axis=-1)
    order = np.argsort(-vals, axis=-1, kind="stable")
    idx = np.take_along_axis(part, order, axis=-1)
    return idx, np.take_along_axis(scores, idx, axis=-1)


def merge_topk(parts, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Host-side global merge of per-shard candidate lists (the paper's
    two-stage top-k, stage 2).

    ``parts`` is an iterable of ``(ids, scores)`` arrays — each a shard's
    local top-k. One concatenate + ``argpartition`` (average-O(n) selection)
    replaces the per-candidate Python heap: the candidate count is
    ``shards × k``, tiny, but the vectorized path keeps the serving engine's
    merge off the interpreter even at large fan-in.
    """
    pairs = [(np.asarray(i), np.asarray(s)) for i, s in parts]
    if k <= 0 or not pairs or sum(i.size for i, _ in pairs) == 0:
        return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.float32))
    ids = np.concatenate([i.astype(np.int64, copy=False) for i, _ in pairs])
    scores = np.concatenate([s for _, s in pairs]).astype(np.float64,
                                                          copy=False)
    k = min(k, ids.size)
    part = np.argpartition(scores, -k)[-k:]
    order = np.argsort(-scores[part], kind="stable")
    sel = part[order]
    return ids[sel], scores[sel].astype(np.float32)


@partial(jax.jit, static_argnames=("k",))
def topk_jax(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """XLA top_k (the paper's preferred backend). Returns (indices, values)."""
    vals, idx = jax.lax.top_k(scores, k)
    return idx, vals


def blockwise_topk(scores: jax.Array, k: int, block: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Two-stage single-device top-k: per-block top-k, then merge.

    Lossless: every global winner is a winner of its own block. Average work
    is O(n) + O((n/block)·k log ...) — the distributed merge in miniature,
    and the jnp oracle for ``kernels/blockwise_topk``.
    """
    n = scores.shape[-1]
    assert n % block == 0, (n, block)
    nb = n // block
    kb = min(k, block)
    blocks = scores.reshape(*scores.shape[:-1], nb, block)
    bvals, bidx = jax.lax.top_k(blocks, kb)            # [..., nb, kb]
    base = (jnp.arange(nb, dtype=jnp.int32) * block)[:, None]
    gidx = (bidx + base).reshape(*scores.shape[:-1], nb * kb)
    gvals = bvals.reshape(*scores.shape[:-1], nb * kb)
    mvals, midx = jax.lax.top_k(gvals, min(k, nb * kb))
    return jnp.take_along_axis(gidx, midx, axis=-1), mvals


def make_sharded_retrieve(mesh: Mesh, shard_axes: tuple[str, ...], *,
                          p_max: int, k: int, n_docs_per_shard: int,
                          return_overflow: bool = False):
    """Build the pod-scale retrieval step: shard-local score+topk, global merge.

    The device index arrays are sharded over ``shard_axes`` (leading dim =
    shard id); queries are replicated. Returns a jit-able
    ``retrieve(stacked_index, q_tokens[B,Q], q_weights[B,Q])``
    -> (global doc ids [B,k], scores [B,k]). With ``return_overflow=True``
    a third ``[B]`` bool output marks queries whose posting demand exceeded
    ``p_max`` on ANY shard (their scores are lower bounds — mirror of
    ``score_batch(..., return_overflow=True)``).
    """
    n_shards = int(np.prod([mesh.shape[a] for a in shard_axes]))

    def local_score_topk(idx_arrays, q_tokens, q_weights):
        # idx_arrays leaves have a leading shard dim of size 1 inside shard_map
        indptr, doc_ids, scores, nonocc, offsets = (x[0] for x in idx_arrays)
        dindex = DeviceIndex(indptr, doc_ids, scores, nonocc,
                             n_docs=n_docs_per_shard, doc_offset=0)
        s, over = jax.vmap(
            lambda t, w: score_query(dindex, t, w, p_max=p_max))(
            q_tokens, q_weights)                        # [B, n_local], [B]
        vals, local_idx = jax.lax.top_k(s, min(k, n_docs_per_shard))
        gidx = local_idx + offsets.astype(jnp.int32)
        return gidx[None], vals[None], over[None]       # keep shard dim

    spec_idx = tuple(P(shard_axes) for _ in range(5))

    @jax.jit
    def retrieve(idx_arrays, q_tokens, q_weights):
        gidx, gvals, gover = shard_map(
            local_score_topk, mesh=mesh,
            in_specs=(spec_idx, P(), P()),
            out_specs=(P(shard_axes), P(shard_axes), P(shard_axes)),
        )(idx_arrays, q_tokens, q_weights)
        # [n_shards, B, k] -> [B, n_shards*k] -> global top-k (the merge)
        b = q_tokens.shape[0]
        allv = jnp.swapaxes(gvals, 0, 1).reshape(b, -1)
        alli = jnp.swapaxes(gidx, 0, 1).reshape(b, -1)
        mvals, midx = jax.lax.top_k(allv, k)
        ids = jnp.take_along_axis(alli, midx, axis=-1)
        if return_overflow:
            return ids, mvals, jnp.any(gover, axis=0)
        return ids, mvals

    return retrieve


def stack_shard_arrays(shards, mesh: Mesh, shard_axes: tuple[str, ...]):
    """Host → device: stack per-shard index arrays padded to common sizes.

    Returns the 5-tuple consumed by ``make_sharded_retrieve`` with every
    leaf sharded over ``shard_axes`` on its leading (shard) dim, plus the
    static per-shard doc count.
    """
    n = len(shards)
    v = shards[0].n_vocab
    nnz_pad = max(s.doc_ids.size for s in shards)
    ndoc_pad = max(s.doc_lens.size for s in shards)
    indptr = np.zeros((n, v + 1), np.int32)
    doc_ids = np.zeros((n, nnz_pad), np.int32)
    scores = np.zeros((n, nnz_pad), np.float32)
    nonocc = np.zeros((n, v), np.float32)
    offsets = np.zeros((n, 1), np.int32)
    for i, s in enumerate(shards):
        indptr[i] = s.indptr
        doc_ids[i, : s.doc_ids.size] = s.doc_ids
        # padding postings point at doc 0 with score 0 — harmless
        scores[i, : s.scores.size] = s.scores
        nonocc[i] = s.nonoccurrence
        offsets[i, 0] = s.doc_offset
    sharding = NamedSharding(mesh, P(shard_axes))
    arrs = tuple(jax.device_put(a, sharding)
                 for a in (indptr, doc_ids, scores, nonocc, offsets))
    return arrs, ndoc_pad
