"""Tokenizer: sklearn regex split, Elastic stopwords, Snowball stemming."""

import pytest

from repro.core import Tokenizer
from repro.core.stemmer import snowball_stem
from repro.core.stopwords import ENGLISH_STOPWORDS


# Published Snowball english (Porter2) vocabulary samples
SNOWBALL_SAMPLES = {
    "consign": "consign", "consigned": "consign", "consigning": "consign",
    "consignment": "consign",
    "knack": "knack", "knackeries": "knackeri", "knavish": "knavish",
    "kneel": "kneel", "knots": "knot",
    "generate": "generat", "generates": "generat", "generating": "generat",
    "general": "general", "generally": "general",
    "skis": "ski", "skies": "sky", "dying": "die", "lying": "lie",
    "news": "news", "inning": "inning", "proceed": "proceed",
    "exceed": "exceed", "succeed": "succeed",
    "happy": "happi", "happiness": "happi",
    "relational": "relat", "conditional": "condit", "rational": "ration",
    "national": "nation",
}


@pytest.mark.parametrize("word,stem", sorted(SNOWBALL_SAMPLES.items()))
def test_snowball_published_samples(word, stem):
    assert snowball_stem(word) == stem


def test_regex_split_is_sklearn_pattern():
    t = Tokenizer(stopwords=None, stemmer=None)
    # \b\w\w+\b: single chars dropped, unicode words kept, punctuation split
    assert t.split("a bc def, ghi! x yz") == ["bc", "def", "ghi", "yz"]
    assert t.split("Café au lait") == ["café", "au", "lait"]


def test_stopword_removal():
    t = Tokenizer(stopwords="english", stemmer=None)
    words = t.tokenize_words("the cat and the hat will be there")
    assert "the" not in words and "and" not in words and "will" not in words
    assert "cat" in words and "hat" in words
    assert len(ENGLISH_STOPWORDS) == 33


def test_vocab_stability_and_oov():
    t = Tokenizer(stopwords=None, stemmer="snowball")
    corpus_ids = t.tokenize_corpus(["running runs runner", "jumping jumps"])
    v = t.vocab_size
    # queries must not grow the vocab; OOV words are dropped
    q = t.tokenize_queries(["running zzzzunknownzzzz"])[0]
    assert t.vocab_size == v
    assert q.size == 1   # "running" -> known stem; unknown dropped
    assert all(i < v for i in q)


def test_stemming_applied_to_vocabulary_not_occurrences():
    """'runs' and 'running' share one stem ⇒ one vocabulary id."""
    t = Tokenizer(stopwords=None, stemmer="snowball")
    ids = t.tokenize_ids("runs running run")
    assert len(set(ids.tolist())) == 1


def test_table2_ablation_axes():
    """The four Table-2 tokenizer configurations are constructible."""
    for stop in ("english", None):
        for stem in ("snowball", None):
            t = Tokenizer(stopwords=stop, stemmer=stem)
            ids = t.tokenize_ids("the quick brown foxes are jumping")
            assert ids.size > 0


def test_vectorized_corpus_pass_equals_per_token_loop():
    """The single-pass factorized tokenizer must reproduce the sequential
    per-token path EXACTLY — same id streams, same vocabulary, same id
    assignment order — across every (stopwords × stemmer) configuration,
    and for frozen-vocab query batches too."""
    import numpy as np
    rng = np.random.default_rng(9)
    words = ["cat", "cats", "running", "runs", "the", "and", "zebra",
             "zebras", "quickly", "quick", "hat"]
    docs = [" ".join(rng.choice(words, size=rng.integers(0, 12)))
            for _ in range(60)]
    docs[7] = ""                                     # empty document
    for stop in ("english", None):
        for stem in ("snowball", None):
            t_loop = Tokenizer(stopwords=stop, stemmer=stem)
            t_vec = Tokenizer(stopwords=stop, stemmer=stem)
            a = t_loop._tokenize_corpus_loop(docs)
            b = t_vec.tokenize_corpus(docs)
            for x, y in zip(a, b):
                np.testing.assert_array_equal(x, y)
            assert t_loop.vocab.word_to_id == t_vec.vocab.word_to_id
            qa = [t_loop.tokenize_ids(q, update_vocab=False)
                  for q in docs[:10] + ["unseen zzz words"]]
            qb = t_vec.tokenize_queries(docs[:10] + ["unseen zzz words"])
            for x, y in zip(qa, qb):
                np.testing.assert_array_equal(x, y)
            assert t_vec.vocab.word_to_id == t_loop.vocab.word_to_id
