"""qwen3-8b [hf:Qwen/Qwen3-8B]: dense GQA with qk-norm.

36L, d_model=4096, 32 heads (GQA kv=8), head_dim=128, d_ff=12288,
vocab=151936. Pure full attention — per the assignment rule, the
``long_500k`` cell is SKIPPED for this arch (no sub-quadratic attention);
recorded in DESIGN.md §Arch-applicability and EXPERIMENTS.md.
"""

import jax.numpy as jnp

from ..models.transformer import LMConfig, reduced
from .common import lm_cells

CONFIG = LMConfig(
    name="qwen3-8b",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab_size=151936,
    qk_norm=True, rope_theta=1_000_000.0,
    dtype=jnp.bfloat16,
)

SMOKE = reduced(CONFIG)

FAMILY = "lm"
N_MICROBATCHES = 4


def cells():
    return lm_cells("qwen3-8b", CONFIG, n_microbatches=N_MICROBATCHES,
                    skip_long=True)
