"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod: 2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is pure data parallelism (DCN-ish), "data"/"model" stay within a pod.

``make_mesh_from`` supports elastic scaling: given whatever devices survive,
it builds the largest valid (data, model) mesh — used by the serving engine
when the pool shrinks.
"""

from __future__ import annotations


import jax
from jax.sharding import Mesh

try:                       # jax >= 0.5: meshes carry explicit axis types
    from jax.sharding import AxisType

    def _axis_types(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:        # older jax: every axis is implicitly "auto"
    def _axis_types(n: int) -> dict:
        return {}


def _make(shape, axes) -> Mesh:
    return jax.make_mesh(shape, axes, **_axis_types(len(axes)))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh_from(devices=None, *, max_model: int = 16) -> Mesh:
    """Largest (data, model) mesh over the given (surviving) devices.

    model axis = largest power of two ≤ max_model dividing the device count;
    any leftover devices are dropped (elastic downsize never deadlocks).
    """
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    model = 1
    while model * 2 <= max_model and n % (model * 2) == 0:
        model *= 2
    data = n // model
    import numpy as np
    dev_array = np.asarray(devices[: data * model]).reshape(data, model)
    return Mesh(dev_array, ("data", "model"), **_axis_types(2))


def make_test_mesh(n_devices: int | None = None) -> Mesh:
    """Small mesh over however many (possibly fake) devices tests have."""
    return make_mesh_from(jax.devices()[:n_devices])
