"""EGNN [arXiv:2102.09844]: n_layers=4, d_hidden=64, E(n)-equivariant.

Four assigned shapes spanning the GNN kernel regimes:
  full_graph_sm — Cora (2,708 nodes / 10,556 edges / 1,433 features)
  minibatch_lg  — Reddit (232,965 nodes) with a real fanout-(15,10)
                  neighbor sampler (data/graphs.py); fixed-shape subgraph
  ogb_products  — 2,449,029 nodes / 61,859,140 edges, full-batch
  molecule      — 128 small graphs (30 nodes / 64 edges), graph regression

Cora/Reddit/products carry no native 3-D geometry; EGNN receives synthetic
coordinates (the arch is assigned to these shapes by the pool — the
equivariant path is exercised, geometry is procedural). Edge arrays are
sharded over the full mesh; nodes replicate (DESIGN.md §5).
"""

from dataclasses import replace

from ..models.egnn import EGNNConfig, reduced
from .common import Cell, gnn_train_cell

CONFIG = EGNNConfig(name="egnn", n_layers=4, d_hidden=64, d_feat=64, n_out=1)

SMOKE = reduced(CONFIG, d_feat=8, n_out=3)

FAMILY = "gnn"

# seeds=1024, fanout (15, 10): 1024 + 15,360 + 153,600 sampled nodes
_MINIBATCH_NODES = 1024 + 1024 * 15 + 1024 * 15 * 10
_MINIBATCH_EDGES = 1024 * 15 + 1024 * 15 * 10

SHAPE_DEFS = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_out=7),
    "minibatch_lg": dict(n_nodes=_MINIBATCH_NODES, n_edges=_MINIBATCH_EDGES,
                         d_feat=602, n_out=41),
    "ogb_products": dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                         n_out=47),
    "molecule": dict(n_nodes=128 * 30, n_edges=128 * 64, d_feat=11, n_out=1,
                     n_graphs=128),
}


def cells() -> list[Cell]:
    out = []
    for shape, d in SHAPE_DEFS.items():
        cfg = replace(CONFIG, d_feat=d["d_feat"], n_out=d["n_out"],
                      readout="graph" if shape == "molecule" else "node")
        out.append(gnn_train_cell(
            "egnn", cfg, shape, n_nodes=d["n_nodes"], n_edges=d["n_edges"],
            n_graphs=d.get("n_graphs"),
            note="neighbor-sampled" if shape == "minibatch_lg" else ""))
    return out
