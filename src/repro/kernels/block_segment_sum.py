"""Pallas TPU kernel: blocked segment-sum (scatter-add as one-hot matmul).

The shared sparse-substrate primitive (DESIGN.md §2): GNN message
aggregation, embedding-bag reduction and BM25 scoring all reduce to
``out[s] += values[p]`` for ``s = segment_ids[p]`` within a destination
block. TPU has no fast random scatter, so the tile-level scatter is lowered
to ``one_hot(segment_ids)ᵀ @ values`` on the MXU.

Grid ``(n_blocks, P // tile_p)``; the posting-tile dimension accumulates
into the block's output. Padding rows must carry zero values.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401


def _kernel(ids_ref, val_ref, out_ref, *, num_segments: int):
    pj = pl.program_id(1)

    @pl.when(pj == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[0, :]                                    # [PT] int32
    vals = val_ref[0, :, :]                                # [PT, D]
    s_iota = jax.lax.broadcasted_iota(
        jnp.int32, (num_segments, ids.shape[0]), 0)
    oneh = (s_iota == ids[None, :]).astype(vals.dtype)     # [S, PT]
    out_ref[0, :, :] += oneh @ vals                        # [S, D] MXU


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "tile_p", "interpret"))
def block_segment_sum(values: jax.Array, segment_ids: jax.Array, *,
                      num_segments: int, tile_p: int = 512,
                      interpret: bool | None = None) -> jax.Array:
    """[nb, P, D] values + [nb, P] local ids -> [nb, num_segments, D]."""
    nb, p, d = values.shape
    assert segment_ids.shape == (nb, p), (segment_ids.shape, values.shape)
    assert p % tile_p == 0, (p, tile_p)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    return pl.pallas_call(
        functools.partial(_kernel, num_segments=num_segments),
        grid=(nb, p // tile_p),
        in_specs=[
            pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),
            pl.BlockSpec((1, tile_p, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, num_segments, d), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, num_segments, d), values.dtype),
        interpret=interpret,
        name="block_segment_sum",
    )(segment_ids, values)
