"""Serving stack: sharded retrieval engine with hedging, an async
micro-batching front-end, and the LM decode engine.

The retrieval surface speaks ONE result dialect and ONE health dialect:

**Results.** Every retrieval entry point — ``DeviceRetriever.retrieve`` /
``retrieve_batch``, ``RetrievalEngine.retrieve`` / ``retrieve_batch``,
and the futures ``ServingFrontend.submit`` resolves — returns a
:class:`~repro.serve.results.RetrievalResult` carrying the winner boards
plus the evidence they were produced on (plan, degradation trail,
stage timings). It unpacks as the legacy ``(ids, scores)`` tuple, so
pre-unification call sites keep working unchanged.

**Health — the schema-2 contract.** Every level's ``health()`` —
``DeviceRetriever``, ``ShardRuntime``, ``RetrievalEngine``,
``ServingFrontend`` — returns one envelope
(:func:`~repro.serve.health.health_envelope`) whose COMMON keys mean the
same thing everywhere:

* ``schema``  — the schema version int
  (:data:`~repro.serve.health.HEALTH_SCHEMA`, currently ``2``);
* ``served``  — responses this level completed: batches for a retriever
  or shard, scatter-gather rounds for the engine, client requests for
  the front-end;
* ``degraded`` — how many of those were served degraded: exact-ladder
  hops (retriever/shard), missed shards under quorum+deadline hedging
  (engine), deadline-missed-but-answered requests (front-end). Degraded
  responses are still EXACT — degradation changes cost, never results;
* ``faults``  — typed-fault counts keyed by ``RetrievalError`` subclass
  name, aggregated upward (the engine sums its shards');
* ``queries`` — shared-sanitizer repair counters
  (``core.retrieval.validate_query_batch`` keys, e.g.
  ``clamped_tokens`` / ``dropped_tokens``).

Level-specific extras (legacy spellings like ``batches_served`` /
``responses``, per-shard breakdowns, the front-end's queue/batch stats)
ride alongside the common keys; tooling written against schema 2 reads
only the common ones.

**Overload protection — the contract.** When traffic exceeds capacity or
a ladder rung keeps faulting, the stack sheds and degrades in TYPED,
observable ways; it never queues unboundedly, never hangs a client
future, and never changes scores (every request it does answer is
bit-identical to a direct ``retrieve_batch`` of the same formed batch):

* load above the admission gate is shed at ``submit`` with
  :class:`AdmissionRejectedError` (``retry_after_s`` = backoff hint)
  BEFORE consuming device work, so admitted-request p99 stays bounded
  under sustained overload;
* a rung that faults repeatedly is skipped by a per-rung circuit
  breaker for a cooldown (one half-open probe re-closes it) — the
  ladder keeps serving exactly on the remaining rungs;
* device execution is watchdog-guarded: a stall becomes a typed
  :class:`ExecutionStalledError` feeding the same exact ladder, and
  transient :class:`ResidencyError` gets seeded bounded backoff;
* a dead pipeline stage fails its pending futures with
  :class:`StageFailedError` and restarts (bounded), so clients never
  block on a stage that no longer exists.

Every shed / breaker-open / stall / restart is a ``health()`` counter.
The knobs (all constructor arguments, all off by default except the
breakers):

====================== ========================= =======================
knob                   constructor               default
====================== ========================= =======================
admission_rate_qps     ``ServingFrontend``       None (bucket off)
admission_burst        ``ServingFrontend``       ``max(rate//5, 8)``
codel_target_s         ``ServingFrontend``       None (CoDel off)
codel_interval_s       ``ServingFrontend``       0.1
max_stage_restarts     ``ServingFrontend``       3
watchdog_s             ``DeviceRetriever``       None (watchdog off)
retry_budget           ``DeviceRetriever``       0 (no retries)
retry_backoff_s        ``DeviceRetriever``       0.005
breaker_threshold      ``DeviceRetriever``       3 (None disables)
breaker_window_s       ``DeviceRetriever``       30.0
breaker_cooldown_s     ``DeviceRetriever``       5.0
====================== ========================= =======================
"""

from .errors import (AdmissionRejectedError, DeadlineExceededError,
                     ExecutionStalledError, InvalidQueryError,
                     PlanOverflowError, QueueOverflowError, ResidencyError,
                     RetrievalConfigError, RetrievalError,
                     ScoreIntegrityError, SnapshotIntegrityError,
                     SnapshotVersionError, StageFailedError,
                     TruncationWarning)
from .overload import (AdmissionController, CircuitBreaker, RetryPolicy,
                       WatchdogExecutor)
from .health import HEALTH_SCHEMA, health_envelope
from .results import PackedBatch, RetrievalResult
from .retrieval_engine import (BlockedRetriever, DeviceRetriever,
                               GatheredRetriever, PrunedRetriever,
                               RetrievalEngine, ShardRuntime)
from .frontend import ServingFrontend
from .decode_engine import DecodeEngine

__all__ = ["BlockedRetriever", "DeviceRetriever", "GatheredRetriever",
           "PrunedRetriever", "RetrievalEngine", "ShardRuntime",
           "ServingFrontend", "RetrievalResult", "PackedBatch",
           "HEALTH_SCHEMA", "health_envelope",
           "DecodeEngine", "RetrievalError", "InvalidQueryError",
           "PlanOverflowError", "ResidencyError", "ScoreIntegrityError",
           "RetrievalConfigError", "SnapshotIntegrityError",
           "SnapshotVersionError", "DeadlineExceededError",
           "QueueOverflowError", "AdmissionRejectedError",
           "ExecutionStalledError", "StageFailedError",
           "AdmissionController", "CircuitBreaker", "RetryPolicy",
           "WatchdogExecutor", "TruncationWarning"]
