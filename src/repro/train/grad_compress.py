"""int8 gradient compression with error feedback (distributed-optimization).

At 1000-node scale the gradient all-reduce dominates the step for
communication-bound configs. This module quantizes each gradient tensor to
int8 with a per-tensor scale *before* the data-parallel reduction boundary
and keeps the quantization residual in an error-feedback buffer so the bias
vanishes over steps (1-bit-Adam / EF-SGD lineage).

In the SPMD formulation the reduction is inserted by XLA, so "compress the
all-reduce" is expressed as: quantize grads (what would travel the wire),
reduce, dequantize, and carry the residual. The convergence-tracking test
(`tests/test_grad_compress.py`) validates fp32-equivalence on a small LM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef_state):
    """Quantize grads+residual to int8; returns (dequantized, new residual)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return deq, x - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
