"""Pallas TPU kernel: query-driven gather→score→top-k (the O(Σ df) path).

The fused full-scan kernel (``bm25_block_score_topk``) walks EVERY posting
tile in the shard per query batch — O(nnz) compares and scatters, which
quietly re-introduced the corpus-size dependence the paper's eager scoring
removed. This kernel restores the inverted-index asymptotics on device:

* the host (or a device prologue) slices only the query tokens' posting
  runs out of the CSC layout — O(Σ df(qᵢ)) postings over the batch's
  unique tokens (``sparse.block_csr.gather_posting_runs``);
* gathered postings arrive candidate-compacted: doc ids are mapped to dense
  slots ``0..n_candidates-1`` (sorted-unique order), chunked so each chunk's
  slots fit a ``[acc_block, B]`` VMEM accumulator — the accumulator is sized
  to the *gathered candidate set*, not the shard's document count;
* scoring reuses ``_score_tile``'s membership/one-hot machinery unchanged;
  the final posting tile of each chunk masks padding slots (``candidates ==
  -1``) and runs ``select_topk`` column-wise, translating winning slots back
  to **global doc ids** in-register via the chunk's candidate table — the
  kernel emits ``[n_chunks, k, B]`` (values, global ids) per launch and the
  caller's merge needs no block-offset arithmetic.

Regime choice (see also ``bm25_block_score.py``): full-scan wins when the
query batch is so large/dense that Σ df approaches nnz (every tile would be
gathered anyway — then the streamed layout's perfect locality is free);
query-gathered wins everywhere else, and the gap grows linearly with corpus
size at fixed query df. ``serve.retrieval_engine`` picks via ``scorer=``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .blockwise_topk import select_topk
from .bm25_block_score import _score_tile


def _gather_kernel(tok_ref, loc_ref, sc_ref, uniq_ref, w_ref, cand_ref,
                   vals_ref, gid_ref, acc_ref, *, acc_block: int, k: int):
    """One (chunk, posting-tile) grid step of the gathered fused path."""
    pj = pl.program_id(1)

    @pl.when(pj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _score_tile(tok_ref, loc_ref, sc_ref, uniq_ref, w_ref,
                                block_size=acc_block)

    @pl.when(pj == pl.num_programs(1) - 1)
    def _reduce():
        acc = acc_ref[...]                                   # [acc_block, B]
        cand = cand_ref[0, :]                                # [acc_block]
        # padding slots (no candidate doc) must not outrank real negative
        # scores — same contract as the full-scan kernel's tail-doc mask,
        # but driven by the candidate table instead of a static n_docs.
        acc = jnp.where((cand >= 0)[:, None], acc,
                        jnp.finfo(acc.dtype).min)

        def emit(i, m, am):                                  # m, am: [B]
            b = m.shape[0]
            gid = jnp.take(cand, am)                         # slot -> doc id
            pl.store(vals_ref, (pl.ds(0, 1), pl.ds(i, 1), pl.ds(0, b)),
                     m[None, None, :])
            pl.store(gid_ref, (pl.ds(0, 1), pl.ds(i, 1), pl.ds(0, b)),
                     gid[None, None, :])

        select_topk(acc, k, axis=0, emit=emit)


@functools.partial(
    jax.jit,
    static_argnames=("acc_block", "k", "tile_p", "interpret"),
)
def bm25_gather_score_topk(token_ids: jax.Array, slot_ids: jax.Array,
                           scores: jax.Array, uniq_tokens: jax.Array,
                           weights: jax.Array, candidates: jax.Array, *,
                           acc_block: int, k: int, tile_p: int = 512,
                           interpret: bool | None = None
                           ) -> tuple[jax.Array, jax.Array]:
    """Gathered postings -> (values, GLOBAL doc ids) ``[n_chunks, k, B]``.

    Inputs are the :class:`~repro.sparse.block_csr.GatheredPostings` layout:
    ``[n_chunks, p_pad]`` posting tiles whose ``slot_ids`` index a
    ``[acc_block, B]`` VMEM accumulator, plus the ``[n_chunks, acc_block]``
    candidate table mapping slots back to global doc ids (-1 = pad). Work is
    O(Σ df · B) — independent of both corpus size and total nnz.
    """
    nc, p = token_ids.shape
    u, b = weights.shape
    assert p % tile_p == 0, (p, tile_p)
    assert k <= acc_block, (k, acc_block)
    assert candidates.shape == (nc, acc_block), (candidates.shape, nc,
                                                 acc_block)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid = (nc, p // tile_p)
    return pl.pallas_call(
        functools.partial(_gather_kernel, acc_block=acc_block, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),      # token_ids
            pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),      # slot_ids
            pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),      # scores
            pl.BlockSpec((u,), lambda i, j: (0,)),               # uniq table
            pl.BlockSpec((u, b), lambda i, j: (0, 0)),           # weights
            pl.BlockSpec((1, acc_block), lambda i, j: (i, 0)),   # candidates
        ],
        out_specs=(
            pl.BlockSpec((1, k, b), lambda i, j: (i, 0, 0)),     # values
            pl.BlockSpec((1, k, b), lambda i, j: (i, 0, 0)),     # global ids
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nc, k, b), weights.dtype),
            jax.ShapeDtypeStruct((nc, k, b), jnp.int32),
        ),
        scratch_shapes=[pltpu.VMEM((acc_block, b), weights.dtype)],
        interpret=interpret,
        name="bm25_gather_score_topk",
    )(token_ids, slot_ids, scores, uniq_tokens, weights, candidates)
