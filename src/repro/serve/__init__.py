"""Serving stack: sharded retrieval engine with hedging, LM decode engine."""

from .retrieval_engine import (BlockedRetriever, GatheredRetriever,
                               RetrievalEngine, ShardRuntime)
from .decode_engine import DecodeEngine

__all__ = ["BlockedRetriever", "GatheredRetriever", "RetrievalEngine",
           "ShardRuntime", "DecodeEngine"]
