"""Pallas TPU kernel: query-driven gather→score→top-k (the O(Σ df) path).

The fused full-scan kernel (``bm25_block_score_topk``) walks EVERY posting
tile in the shard per query batch — O(nnz) compares and scatters, which
quietly re-introduced the corpus-size dependence the paper's eager scoring
removed. This kernel restores the inverted-index asymptotics on device:

* the host (or a device prologue) slices only the query tokens' posting
  runs out of the CSC layout — O(Σ df(qᵢ)) postings over the batch's
  unique tokens (``sparse.block_csr.gather_posting_runs``);
* gathered postings arrive candidate-compacted: doc ids are mapped to dense
  slots ``0..n_candidates-1`` (sorted-unique order), chunked so each chunk's
  slots fit a ``[acc_block, B]`` VMEM accumulator — the accumulator is sized
  to the *gathered candidate set*, not the shard's document count;
* scoring reuses ``_score_tile``'s membership/one-hot machinery unchanged;
  the final posting tile of each chunk masks padding slots (``candidates ==
  -1``) and runs ``select_topk`` column-wise, translating winning slots back
  to **global doc ids** in-register via the chunk's candidate table — the
  kernel emits ``[n_chunks, k, B]`` (values, global ids) per launch and the
  caller's merge needs no block-offset arithmetic.

Regime choice (see also ``bm25_block_score.py``): full-scan wins when the
query batch is so large/dense that Σ df approaches nnz (every tile would be
gathered anyway — then the streamed layout's perfect locality is free);
query-gathered wins everywhere else, and the gap grows linearly with corpus
size at fixed query df. ``serve.retrieval_engine``'s planner picks per
batch (``core.retrieval.plan_retrieval``, ``scorer="auto"``).

Three gathered entry points:

* ``bm25_gather_score_topk``     — consumes HOST-gathered candidate-compacted
  tiles (the fallback that still ships O(Σ df) postings per batch). With
  ``two_level=True`` the per-chunk winners are reduced to SHARD winners
  inside the launch (running ``[k, B]`` scoreboard in VMEM), cutting the
  host merge from ``[nc·k, B]`` to ``[k, B]``.
* ``bm25_resident_score_topk``   — the zero-copy path: posting arrays are
  HBM-resident (``sparse.block_csr.DeviceIndex``), the host ships only a
  fragment-descriptor table (``fragment_plan``) which is scalar-prefetched
  into SMEM (the ``PrefetchScalarGridSpec`` pattern proven in
  ``kernels/embedding_bag.py``); each grid step DMAs one ≤``frag``-sized
  posting run fragment straight out of HBM, scatters it into a per-doc-block
  VMEM accumulator, and block winners fold into the same running ``[k, B]``
  shard scoreboard. No membership search is needed at all — the descriptor
  names the owning query-token row directly.
* ``bm25_resident_score_topk_pruned`` — the resident path with the
  block-max skip: an extra ``[nf, B]`` bound-row operand (per-fragment
  document-block score upper bounds from the resident
  ``sparse.block_csr.BlockMaxTable``) is tested against the live
  scoreboard's k-th value before each fragment's DMAs are issued, so
  fragments no posting can win are never copied at all — exact top-k
  pruning, bit-identical to the single-buffer kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .blockwise_topk import select_topk
from .bm25_block_score import _score_tile

# jax >= 0.5 renamed TPUMemorySpace -> MemorySpace
_ANY_SPACE = getattr(pltpu, "MemorySpace",
                     getattr(pltpu, "TPUMemorySpace", None)).ANY


def _fold_winners(ext_vals, ids_of_row, prev_ids, mv_ref, mi_ref, *,
                  n_rows: int, k: int):
    """k select-and-mask rounds over ``ext_vals = [acc ; prev_winners]``.

    ``ids_of_row(am)`` maps an accumulator-row argmax to its global doc id;
    rows ≥ ``n_rows`` are the previous winners, whose ids come from
    ``prev_ids`` via a one-hot sum (VPU-safe — no gather along a dynamic
    per-column index). Non-finite winners (padding) emit id -1. Results are
    staged in ``mv_ref``/``mi_ref`` so the caller can copy them into the
    live scoreboard AFTER the rounds stop reading it.
    """
    neg = jnp.finfo(ext_vals.dtype).min

    def emit(r, m, am):
        b = m.shape[0]
        safe_prev = jnp.clip(am - n_rows, 0, k - 1)
        oh = (jax.lax.broadcasted_iota(jnp.int32, (k, b), 0)
              == safe_prev[None, :])
        old = jnp.sum(jnp.where(oh, prev_ids, 0), axis=0)
        gid = jnp.where(am < n_rows, ids_of_row(am), old)
        gid = jnp.where(m > neg / 2, gid, -1)
        pl.store(mv_ref, (pl.ds(r, 1), pl.ds(0, b)), m[None, :])
        pl.store(mi_ref, (pl.ds(r, 1), pl.ds(0, b)), gid[None, :])

    select_topk(ext_vals, k, axis=0, emit=emit)


def _gather_kernel(tok_ref, loc_ref, sc_ref, uniq_ref, w_ref, cand_ref,
                   vals_ref, gid_ref, acc_ref, *, acc_block: int, k: int):
    """One (chunk, posting-tile) grid step of the gathered fused path."""
    pj = pl.program_id(1)

    @pl.when(pj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _score_tile(tok_ref, loc_ref, sc_ref, uniq_ref, w_ref,
                                block_size=acc_block)

    @pl.when(pj == pl.num_programs(1) - 1)
    def _reduce():
        acc = acc_ref[...]                                   # [acc_block, B]
        cand = cand_ref[0, :]                                # [acc_block]
        # padding slots (no candidate doc) must not outrank real negative
        # scores — same contract as the full-scan kernel's tail-doc mask,
        # but driven by the candidate table instead of a static n_docs.
        acc = jnp.where((cand >= 0)[:, None], acc,
                        jnp.finfo(acc.dtype).min)

        def emit(i, m, am):                                  # m, am: [B]
            b = m.shape[0]
            gid = jnp.take(cand, am)                         # slot -> doc id
            pl.store(vals_ref, (pl.ds(0, 1), pl.ds(i, 1), pl.ds(0, b)),
                     m[None, None, :])
            pl.store(gid_ref, (pl.ds(0, 1), pl.ds(i, 1), pl.ds(0, b)),
                     gid[None, None, :])

        select_topk(acc, k, axis=0, emit=emit)


def _gather_kernel_shard(tok_ref, loc_ref, sc_ref, uniq_ref, w_ref, cand_ref,
                         vals_ref, gid_ref, acc_ref, mv_ref, mi_ref, *,
                         acc_block: int, k: int):
    """Two-level variant: chunk winners fold into a shard ``[k, B]`` board.

    Same scoring as :func:`_gather_kernel`, but instead of emitting every
    chunk's ``[k, B]`` winners to HBM, each chunk's reduce extends its
    accumulator with the RUNNING shard winners and re-selects — top-k of a
    union equals top-k of (top-k ∪ top-k), so the single ``[k, B]`` output
    is exactly the merge of the per-chunk lists, computed without the
    ``[nc·k, B]`` round-trip.
    """
    pi = pl.program_id(0)
    pj = pl.program_id(1)
    neg = jnp.finfo(vals_ref.dtype).min

    @pl.when((pi == 0) & (pj == 0))
    def _init_out():
        vals_ref[...] = jnp.full_like(vals_ref, neg)
        gid_ref[...] = jnp.full_like(gid_ref, -1)

    @pl.when(pj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _score_tile(tok_ref, loc_ref, sc_ref, uniq_ref, w_ref,
                                block_size=acc_block)

    @pl.when(pj == pl.num_programs(1) - 1)
    def _reduce():
        acc = acc_ref[...]                                   # [acc_block, B]
        cand = cand_ref[0, :]                                # [acc_block]
        acc = jnp.where((cand >= 0)[:, None], acc, neg)
        prev_v, prev_i = vals_ref[...], gid_ref[...]
        ext = jnp.concatenate([acc, prev_v], axis=0)
        _fold_winners(
            ext, lambda am: jnp.take(cand, jnp.minimum(am, acc_block - 1)),
            prev_i, mv_ref, mi_ref, n_rows=acc_block, k=k)
        vals_ref[...] = mv_ref[...]
        gid_ref[...] = mi_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("acc_block", "k", "tile_p", "two_level", "interpret"),
)
def bm25_gather_score_topk(token_ids: jax.Array, slot_ids: jax.Array,
                           scores: jax.Array, uniq_tokens: jax.Array,
                           weights: jax.Array, candidates: jax.Array, *,
                           acc_block: int, k: int, tile_p: int = 512,
                           two_level: bool = False,
                           interpret: bool | None = None
                           ) -> tuple[jax.Array, jax.Array]:
    """Gathered postings -> (values, GLOBAL doc ids).

    Inputs are the :class:`~repro.sparse.block_csr.GatheredPostings` layout:
    ``[n_chunks, p_pad]`` posting tiles whose ``slot_ids`` index a
    ``[acc_block, B]`` VMEM accumulator, plus the ``[n_chunks, acc_block]``
    candidate table mapping slots back to global doc ids (-1 = pad). Work is
    O(Σ df · B) — independent of both corpus size and total nnz.

    ``two_level=False`` emits per-chunk winners ``[n_chunks, k, B]`` (the
    caller merges). ``two_level=True`` performs that merge INSIDE the
    launch — chunk winners fold into a running shard scoreboard and the
    output is ``[k, B]``, cutting HBM winner traffic and the host merge by
    ``n_chunks``×.
    """
    nc, p = token_ids.shape
    u, b = weights.shape
    assert p % tile_p == 0, (p, tile_p)
    assert k <= acc_block, (k, acc_block)
    assert candidates.shape == (nc, acc_block), (candidates.shape, nc,
                                                 acc_block)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid = (nc, p // tile_p)
    in_specs = [
        pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),      # token_ids
        pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),      # slot_ids
        pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),      # scores
        pl.BlockSpec((u,), lambda i, j: (0,)),               # uniq table
        pl.BlockSpec((u, b), lambda i, j: (0, 0)),           # weights
        pl.BlockSpec((1, acc_block), lambda i, j: (i, 0)),   # candidates
    ]
    if two_level:
        return pl.pallas_call(
            functools.partial(_gather_kernel_shard, acc_block=acc_block,
                              k=k),
            grid=grid,
            in_specs=in_specs,
            out_specs=(
                pl.BlockSpec((k, b), lambda i, j: (0, 0)),   # shard values
                pl.BlockSpec((k, b), lambda i, j: (0, 0)),   # shard ids
            ),
            out_shape=(
                jax.ShapeDtypeStruct((k, b), weights.dtype),
                jax.ShapeDtypeStruct((k, b), jnp.int32),
            ),
            scratch_shapes=[
                pltpu.VMEM((acc_block, b), weights.dtype),
                pltpu.VMEM((k, b), weights.dtype),
                pltpu.VMEM((k, b), jnp.int32),
            ],
            interpret=interpret,
            name="bm25_gather_score_topk_two_level",
        )(token_ids, slot_ids, scores, uniq_tokens, weights, candidates)
    return pl.pallas_call(
        functools.partial(_gather_kernel, acc_block=acc_block, k=k),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, k, b), lambda i, j: (i, 0, 0)),     # values
            pl.BlockSpec((1, k, b), lambda i, j: (i, 0, 0)),     # global ids
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nc, k, b), weights.dtype),
            jax.ShapeDtypeStruct((nc, k, b), jnp.int32),
        ),
        scratch_shapes=[pltpu.VMEM((acc_block, b), weights.dtype)],
        interpret=interpret,
        name="bm25_gather_score_topk",
    )(token_ids, slot_ids, scores, uniq_tokens, weights, candidates)


def _resident_scatter(acc_ref, w_ref, doc, sc, valid, uidx, blk, *,
                      block_size: int, frag: int):
    """Scatter one fragment's postings into the block accumulator.

    The ONE scoring definition shared by the single- and double-buffered
    resident kernels — identical operations in identical order, so the
    two paths are bit-identical (the double-buffer test asserts it).
    """
    ok = (jax.lax.broadcasted_iota(jnp.int32, (frag, 1), 0)
          < valid)                                       # [frag, 1]
    w_row = pl.load(w_ref, (pl.ds(uidx, 1), slice(None)))  # [1, B]
    contrib = jnp.where(ok, sc[:, None], 0.0) * w_row    # [frag, B]
    # over-read tail postings (ok == False) may carry arbitrary doc
    # ids, but their contrib rows are zero — a spurious one-hot match
    # adds exactly 0.
    loc = doc - blk * block_size
    d_iota = jax.lax.broadcasted_iota(jnp.int32, (block_size, frag), 0)
    oneh = (d_iota == loc[None, :]).astype(contrib.dtype)
    acc_ref[...] += oneh @ contrib                       # [BS, B] MXU


def _resident_fold(acc_ref, vals_ref, gid_ref, mv_ref, mi_ref, blk, *,
                   block_size: int, k: int, n_docs: int):
    """Fold a finished block accumulator into the shard scoreboard."""
    neg = jnp.finfo(vals_ref.dtype).min
    acc = acc_ref[...]                                   # [BS, B]
    row = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 0)
    acc = jnp.where(blk * block_size + row < n_docs, acc, neg)
    prev_v, prev_i = vals_ref[...], gid_ref[...]
    ext = jnp.concatenate([acc, prev_v], axis=0)
    _fold_winners(ext, lambda am: blk * block_size + am, prev_i,
                  mv_ref, mi_ref, n_rows=block_size, k=k)
    vals_ref[...] = mv_ref[...]
    gid_ref[...] = mi_ref[...]


def _resident_kernel(desc_ref, w_ref, doc_hbm, sc_hbm, vals_ref, gid_ref,
                     acc_ref, dbuf, sbuf, dsem, ssem, mv_ref, mi_ref, *,
                     block_size: int, frag: int, k: int, n_docs: int):
    """One fragment of the device-resident gather→score→top-k path.

    The grid walks the batch's fragment table (SMEM, scalar-prefetched;
    see ``sparse.block_csr.FragmentPlan`` for the row layout). Each step
    DMAs its ≤``frag`` postings (doc ids + eager scores) out of the
    HBM-resident CSC arrays at a descriptor-driven dynamic offset, scales
    by the owning token's ``[B]`` query-weight row (named by the
    descriptor — no membership search), and one-hot-scatters into the
    current document block's ``[block_size, B]`` accumulator. Block-final
    fragments mask tail-padding docs and fold the block into the running
    shard ``[k, B]`` scoreboard (two-level reduce).

    This SINGLE-BUFFER variant issues its two DMAs sequentially and waits
    before scoring — the exactness oracle for the double-buffered pipeline
    (:func:`_resident_kernel_db`), same role the two-step chunk merge
    plays for the two-level reduce.
    """
    i = pl.program_id(0)
    start = desc_ref[0, i]
    valid = desc_ref[1, i]
    uidx = desc_ref[2, i]
    blk = desc_ref[3, i]
    first = desc_ref[4, i]
    last = desc_ref[5, i]
    neg = jnp.finfo(vals_ref.dtype).min

    @pl.when(i == 0)
    def _init_out():
        vals_ref[...] = jnp.full_like(vals_ref, neg)
        gid_ref[...] = jnp.full_like(gid_ref, -1)

    @pl.when(first == 1)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid > 0)
    def _score():
        cp_d = pltpu.make_async_copy(
            doc_hbm.at[pl.ds(0, 1), pl.ds(start, frag)], dbuf, dsem)
        cp_s = pltpu.make_async_copy(
            sc_hbm.at[pl.ds(0, 1), pl.ds(start, frag)], sbuf, ssem)
        cp_d.start()
        cp_s.start()
        cp_d.wait()
        cp_s.wait()
        _resident_scatter(acc_ref, w_ref, dbuf[0, :], sbuf[0, :], valid,
                          uidx, blk, block_size=block_size, frag=frag)

    @pl.when(last == 1)
    def _reduce():
        _resident_fold(acc_ref, vals_ref, gid_ref, mv_ref, mi_ref, blk,
                       block_size=block_size, k=k, n_docs=n_docs)


def _resident_kernel_db(desc_ref, w_ref, doc_hbm, sc_hbm, vals_ref, gid_ref,
                        acc_ref, dbuf0, sbuf0, dbuf1, sbuf1, dsem0, ssem0,
                        dsem1, ssem1, mv_ref, mi_ref, *, block_size: int,
                        frag: int, k: int, n_docs: int):
    """Double-buffered variant: fragment f+1's DMAs fly during f's scatter.

    Same math as :func:`_resident_kernel` (both call
    :func:`_resident_scatter`/:func:`_resident_fold`, so outputs are
    bit-identical); only the copy schedule changes. Two (doc, score)
    scratch slots alternate by fragment parity — the two-slot + two-
    semaphore pattern proven in ``kernels/embedding_bag.py``: grid step
    ``f`` starts fragment ``f+1``'s copies into the idle slot BEFORE
    waiting on its own, so on real hardware the HBM reads of the next
    fragment overlap the one-hot scatter matmul of the current one
    (interpret mode executes the copies eagerly — what the CPU tests
    validate). Every fragment is copied, padding included (``start`` is 0
    there and the resident arrays over-allocate a full ``frag`` tail), so
    start/wait stay balanced with no cross-step control flow; padding
    still contributes nothing because the scatter is gated on
    ``valid > 0``.
    """
    i = pl.program_id(0)
    nf = pl.num_programs(0)
    start = desc_ref[0, i]
    valid = desc_ref[1, i]
    uidx = desc_ref[2, i]
    blk = desc_ref[3, i]
    first = desc_ref[4, i]
    last = desc_ref[5, i]
    even = i % 2 == 0
    neg = jnp.finfo(vals_ref.dtype).min

    def copies(s, dbuf, sbuf, dsem, ssem):
        return (pltpu.make_async_copy(
                    doc_hbm.at[pl.ds(0, 1), pl.ds(s, frag)], dbuf, dsem),
                pltpu.make_async_copy(
                    sc_hbm.at[pl.ds(0, 1), pl.ds(s, frag)], sbuf, ssem))

    @pl.when(i == 0)
    def _init_out():
        vals_ref[...] = jnp.full_like(vals_ref, neg)
        gid_ref[...] = jnp.full_like(gid_ref, -1)
        for cp in copies(start, dbuf0, sbuf0, dsem0, ssem0):
            cp.start()                            # warm-up: fragment 0

    @pl.when(first == 1)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # prefetch fragment i+1 into the slot this step does NOT consume
    @pl.when(i + 1 < nf)
    def _prefetch():
        nstart = desc_ref[0, i + 1]

        @pl.when(even)
        def _into_slot1():
            for cp in copies(nstart, dbuf1, sbuf1, dsem1, ssem1):
                cp.start()

        @pl.when(jnp.logical_not(even))
        def _into_slot0():
            for cp in copies(nstart, dbuf0, sbuf0, dsem0, ssem0):
                cp.start()

    # wait on THIS fragment's slot (unconditionally — semaphores must
    # balance even for padding fragments)
    @pl.when(even)
    def _wait_slot0():
        for cp in copies(start, dbuf0, sbuf0, dsem0, ssem0):
            cp.wait()

    @pl.when(jnp.logical_not(even))
    def _wait_slot1():
        for cp in copies(start, dbuf1, sbuf1, dsem1, ssem1):
            cp.wait()

    @pl.when(valid > 0)
    def _score():
        doc = jnp.where(even, dbuf0[0, :], dbuf1[0, :])   # [frag] int32
        sc = jnp.where(even, sbuf0[0, :], sbuf1[0, :])    # [frag] f32
        _resident_scatter(acc_ref, w_ref, doc, sc, valid, uidx, blk,
                          block_size=block_size, frag=frag)

    @pl.when(last == 1)
    def _reduce():
        _resident_fold(acc_ref, vals_ref, gid_ref, mv_ref, mi_ref, blk,
                       block_size=block_size, k=k, n_docs=n_docs)


def _resident_kernel_pruned(desc_ref, w_ref, bnd_ref, doc_hbm, sc_hbm,
                            vals_ref, gid_ref, skip_ref, acc_ref, dbuf, sbuf,
                            dsem, ssem, mv_ref, mi_ref, *, block_size: int,
                            frag: int, k: int, n_docs: int):
    """Threshold-skipping variant: DMAs gated on the live scoreboard.

    Same scatter/fold math as :func:`_resident_kernel` (bit-identical by
    construction — both call :func:`_resident_scatter` /
    :func:`_resident_fold`), plus the block-max skip: each fragment's row
    of ``bnd_ref`` carries its document block's per-query score UPPER
    bound (``sparse.block_csr.block_upper_bounds``), and the running
    scoreboard's k-th value (row ``k-1`` — folds emit ranks in descending
    order) is a certified LOWER bound on every query's final k-th score.
    When no query's bound reaches its threshold, the fragment's postings
    cannot alter the scoreboard for ANY query, so both posting DMAs and
    the one-hot scatter are skipped — this is how a threshold that
    saturates mid-launch still cuts DMA traffic the pre-launch compaction
    could not see. Skipping is exact:

    * the board holds full scores of real documents only (a block's
      fragments are contiguous, so its accumulator is complete when it
      folds), so row ``k-1`` never overestimates the final k-th score;
    * the board is constant across one block's fragments (folds happen at
      block boundaries), so a block skips or scores ATOMICALLY — a
      partially-scored block cannot leak a too-low score into the fold
      (and a fully-skipped block's zero accumulator folds harmlessly: the
      skip condition forces board-min > bound ≥ 0);
    * bounds are slack-inflated upstream, so f32 accumulation rounding
      cannot push a real score past its bound.

    ``skip_ref`` counts skipped real fragments — the kernel-level half of
    the pruned regime's observability (``last_plan.frags_skipped``).
    """
    i = pl.program_id(0)
    start = desc_ref[0, i]
    valid = desc_ref[1, i]
    uidx = desc_ref[2, i]
    blk = desc_ref[3, i]
    first = desc_ref[4, i]
    last = desc_ref[5, i]
    neg = jnp.finfo(vals_ref.dtype).min

    @pl.when(i == 0)
    def _init_out():
        vals_ref[...] = jnp.full_like(vals_ref, neg)
        gid_ref[...] = jnp.full_like(gid_ref, -1)
        skip_ref[...] = jnp.zeros_like(skip_ref)

    @pl.when(first == 1)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # live iff ANY query's threshold is still reachable by this block
    kth = pl.load(vals_ref, (pl.ds(k - 1, 1), slice(None)))[0, :]   # [B]
    live = jnp.any(bnd_ref[0, :] >= kth)

    @pl.when((valid > 0) & live)
    def _score():
        cp_d = pltpu.make_async_copy(
            doc_hbm.at[pl.ds(0, 1), pl.ds(start, frag)], dbuf, dsem)
        cp_s = pltpu.make_async_copy(
            sc_hbm.at[pl.ds(0, 1), pl.ds(start, frag)], sbuf, ssem)
        cp_d.start()
        cp_s.start()
        cp_d.wait()
        cp_s.wait()
        _resident_scatter(acc_ref, w_ref, dbuf[0, :], sbuf[0, :], valid,
                          uidx, blk, block_size=block_size, frag=frag)

    @pl.when((valid > 0) & jnp.logical_not(live))
    def _count_skip():
        skip_ref[...] += jnp.ones_like(skip_ref)

    @pl.when(last == 1)
    def _reduce():
        _resident_fold(acc_ref, vals_ref, gid_ref, mv_ref, mi_ref, blk,
                       block_size=block_size, k=k, n_docs=n_docs)


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "frag", "k", "n_docs", "interpret"),
)
def bm25_resident_score_topk_pruned(desc: jax.Array, weights: jax.Array,
                                    bounds: jax.Array,
                                    doc_ids_res: jax.Array,
                                    scores_res: jax.Array, *,
                                    block_size: int, frag: int, k: int,
                                    n_docs: int,
                                    interpret: bool | None = None
                                    ) -> tuple[jax.Array, jax.Array,
                                               jax.Array]:
    """Pruned-regime resident scorer: skip fragments no posting can win.

    Identical contract to :func:`bm25_resident_score_topk` (single-buffer
    schedule) with one extra operand and output: ``bounds`` is the
    ``[nf_pad, B]`` float32 per-fragment block upper-bound table (row f =
    the batch's score upper bound for fragment f's document block, already
    slack-inflated), and the third output is the ``[1, 1]`` int32 count of
    real fragments whose DMAs the in-kernel threshold test skipped.
    Outputs (values, ids) are BIT-identical to the single-buffer kernel on
    the same descriptor table — the skip removes only provably-losing
    work (see :func:`_resident_kernel_pruned` for the argument).
    """
    nf = desc.shape[1]
    u, b = weights.shape
    assert desc.shape[0] == 6, desc.shape
    assert bounds.shape == (nf, b), (bounds.shape, nf, b)
    assert k <= block_size, (k, block_size)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                    # desc table -> SMEM
        grid=(nf,),
        in_specs=[
            pl.BlockSpec((u, b), lambda i, d: (0, 0)),       # weights VMEM
            pl.BlockSpec((1, b), lambda i, d: (i, 0)),       # bound row
            pl.BlockSpec(memory_space=_ANY_SPACE),           # doc ids / HBM
            pl.BlockSpec(memory_space=_ANY_SPACE),           # scores / HBM
        ],
        out_specs=(
            pl.BlockSpec((k, b), lambda i, d: (0, 0)),       # shard values
            pl.BlockSpec((k, b), lambda i, d: (0, 0)),       # shard ids
            pl.BlockSpec((1, 1), lambda i, d: (0, 0)),       # skip count
        ),
        scratch_shapes=(
            [pltpu.VMEM((block_size, b), weights.dtype),     # block acc
             pltpu.VMEM((1, frag), jnp.int32),               # doc-id tile
             pltpu.VMEM((1, frag), jnp.float32),             # score tile
             pltpu.SemaphoreType.DMA,
             pltpu.SemaphoreType.DMA,
             pltpu.VMEM((k, b), weights.dtype),              # fold staging
             pltpu.VMEM((k, b), jnp.int32)]
        ),
    )
    return pl.pallas_call(
        functools.partial(_resident_kernel_pruned, block_size=block_size,
                          frag=frag, k=k, n_docs=n_docs),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((k, b), weights.dtype),
            jax.ShapeDtypeStruct((k, b), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ),
        interpret=interpret,
        name="bm25_resident_score_topk_pruned",
    )(desc, weights, bounds, doc_ids_res, scores_res)


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "frag", "k", "n_docs", "double_buffer",
                     "interpret"),
)
def bm25_resident_score_topk(desc: jax.Array, weights: jax.Array,
                             doc_ids_res: jax.Array, scores_res: jax.Array,
                             *, block_size: int, frag: int, k: int,
                             n_docs: int, double_buffer: bool = True,
                             interpret: bool | None = None
                             ) -> tuple[jax.Array, jax.Array]:
    """Fragment descriptors × resident index -> shard (values, ids) [k, B].

    ``desc`` is the ``[6, nf_pad]`` int32 table from
    ``sparse.block_csr.fragment_plan`` — or its device-built twin
    (``sparse.fragment_device.plan_fragments_device``), which never leaves
    HBM — scalar-prefetched to SMEM so it can drive DMA descriptors;
    ``doc_ids_res``/``scores_res`` are the ``[1, nnz_pad]`` HBM-resident
    CSC arrays of a ``sparse.block_csr.DeviceIndex`` — the ONLY posting
    data the kernel touches, and it never crosses the host→device boundary
    per batch. Winners carry global doc ids; blocks the batch never visits
    are absent (their docs score raw 0 — the caller splices default
    documents, same contract as the host-gathered path).

    ``double_buffer=True`` (default) overlaps fragment ``f+1``'s posting
    DMAs with fragment ``f``'s scatter (two scratch slots, embedding_bag's
    pattern); ``False`` keeps the sequential-copy kernel — the exactness
    oracle the bit-identity tests compare against.
    """
    nf = desc.shape[1]
    u, b = weights.shape
    assert desc.shape[0] == 6, desc.shape
    assert k <= block_size, (k, block_size)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    tile_scratch = [
        pltpu.VMEM((1, frag), jnp.int32),                # doc-id tile
        pltpu.VMEM((1, frag), jnp.float32),              # score tile
        pltpu.SemaphoreType.DMA,
        pltpu.SemaphoreType.DMA,
    ]
    if double_buffer:
        tile_scratch = [
            pltpu.VMEM((1, frag), jnp.int32),            # slot-0 doc tile
            pltpu.VMEM((1, frag), jnp.float32),          # slot-0 score tile
            pltpu.VMEM((1, frag), jnp.int32),            # slot-1 doc tile
            pltpu.VMEM((1, frag), jnp.float32),          # slot-1 score tile
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                    # desc table -> SMEM
        grid=(nf,),
        in_specs=[
            pl.BlockSpec((u, b), lambda i, d: (0, 0)),       # weights VMEM
            pl.BlockSpec(memory_space=_ANY_SPACE),           # doc ids / HBM
            pl.BlockSpec(memory_space=_ANY_SPACE),           # scores / HBM
        ],
        out_specs=(
            pl.BlockSpec((k, b), lambda i, d: (0, 0)),       # shard values
            pl.BlockSpec((k, b), lambda i, d: (0, 0)),       # shard ids
        ),
        scratch_shapes=(
            [pltpu.VMEM((block_size, b), weights.dtype)]     # block acc
            + tile_scratch
            + [pltpu.VMEM((k, b), weights.dtype),            # fold staging
               pltpu.VMEM((k, b), jnp.int32)]
        ),
    )
    kernel = _resident_kernel_db if double_buffer else _resident_kernel
    return pl.pallas_call(
        functools.partial(kernel, block_size=block_size,
                          frag=frag, k=k, n_docs=n_docs),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((k, b), weights.dtype),
            jax.ShapeDtypeStruct((k, b), jnp.int32),
        ),
        interpret=interpret,
        name="bm25_resident_score_topk_db" if double_buffer
        else "bm25_resident_score_topk",
    )(desc, weights, doc_ids_res, scores_res)
