"""Device-side scoring paths == oracles, incl. hypothesis property tests."""

import numpy as np
import pytest


from conftest import given, make_corpus, settings, st
from repro.core import (BM25Params, DeviceIndex, ScipyBM25, build_index,
                        build_sharded_indexes, dense_oracle_scores,
                        pad_queries, score_batch, suggest_p_max)


@pytest.mark.parametrize("method", ["lucene", "bm25+"])
def test_jax_gather_path_exact(method, rng):
    corpus = make_corpus(rng)
    p = BM25Params(method=method)
    idx = build_index(corpus, 50, params=p)
    di = DeviceIndex.from_host(idx)
    queries = [rng.integers(0, 50, size=rng.integers(1, 7)).astype(np.int32)
               for _ in range(6)]
    toks, wts = pad_queries(queries, 8)
    out = np.asarray(score_batch(di, toks, wts,
                                 p_max=suggest_p_max(idx, 8)))
    for i, q in enumerate(queries):
        np.testing.assert_allclose(
            out[i], dense_oracle_scores(corpus, 50, q, p), atol=1e-4)


def test_duplicate_query_tokens_weighted(rng):
    """A token occurring twice in the query contributes twice (weights)."""
    corpus = make_corpus(rng)
    idx = build_index(corpus, 50, params=BM25Params())
    di = DeviceIndex.from_host(idx)
    q1 = np.array([3, 3, 7], dtype=np.int32)
    q2 = np.array([3, 7], dtype=np.int32)
    toks, wts = pad_queries([q1, q2], 4)
    out = np.asarray(score_batch(di, toks, wts, p_max=1024))
    sc = ScipyBM25(idx)
    np.testing.assert_allclose(out[0], sc.score(q1), atol=1e-4)
    assert not np.allclose(out[0], out[1])


def test_sharded_build_matches_single(rng):
    corpus = make_corpus(rng, n_docs=80)
    p = BM25Params(method="bm25l")
    whole = build_index(corpus, 50, params=p)
    shards = build_sharded_indexes(corpus, 50, 5, params=p)
    # reassemble per-document scores from shards
    q = rng.integers(0, 50, size=4).astype(np.int32)
    ref = ScipyBM25(whole).score(q)
    got = np.zeros_like(ref)
    for sh in shards:
        got[sh.doc_offset: sh.doc_offset + sh.doc_lens.size] = \
            ScipyBM25(sh).score(q)
    np.testing.assert_allclose(got, ref, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_property_eager_equals_lazy(data):
    """Hypothesis: random corpora/queries/variants — eager == lazy oracle."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31)))
    n_vocab = data.draw(st.integers(5, 40))
    n_docs = data.draw(st.integers(2, 30))
    method = data.draw(st.sampled_from(
        ["robertson", "atire", "lucene", "bm25l", "bm25+", "tfldp"]))
    k1 = data.draw(st.floats(0.5, 2.0))
    b = data.draw(st.floats(0.0, 1.0))
    corpus = [rng.integers(0, n_vocab, size=rng.integers(1, 20)
                           ).astype(np.int32) for _ in range(n_docs)]
    p = BM25Params(method=method, k1=k1, b=b)
    idx = build_index(corpus, n_vocab, params=p)
    q = rng.integers(0, n_vocab, size=rng.integers(1, 5)).astype(np.int32)
    np.testing.assert_allclose(
        ScipyBM25(idx).score(q),
        dense_oracle_scores(corpus, n_vocab, q, p), atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 31), n_new=st.integers(1, 6))
def test_property_reshard_preserves_scores(seed, n_new):
    from repro.core import reshard_index
    rng = np.random.default_rng(seed)
    corpus = [rng.integers(0, 30, size=rng.integers(1, 15)).astype(np.int32)
              for _ in range(40)]
    p = BM25Params(method="lucene")
    shards = build_sharded_indexes(corpus, 30, 4, params=p)
    new = reshard_index(shards, n_new)
    q = rng.integers(0, 30, size=3).astype(np.int32)
    ref = dense_oracle_scores(corpus, 30, q, p)
    got = np.zeros_like(ref)
    for sh in new:
        got[sh.doc_offset: sh.doc_offset + sh.doc_lens.size] = \
            ScipyBM25(sh).score(q)
    np.testing.assert_allclose(got, ref, atol=1e-3)


def test_index_save_load_roundtrip(tmp_path, rng):
    corpus = make_corpus(rng)
    idx = build_index(corpus, 50, params=BM25Params(method="bm25+"))
    idx.save(str(tmp_path / "idx"))
    from repro.core import BM25Index
    idx2 = BM25Index.load(str(tmp_path / "idx"))
    np.testing.assert_array_equal(idx.indptr, idx2.indptr)
    np.testing.assert_array_equal(idx.scores, idx2.scores)
    assert idx2.variant == "bm25+" and idx2.params.method == "bm25+"
