"""Loop-aware cost accounting for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE, so a
scan-over-layers transformer under-reports FLOPs by ~n_layers ×
n_microbatches (verified in EXPERIMENTS.md §Dry-run methodology). Two
replacements:

* ``jaxpr_cost`` — walks the closed jaxpr multiplying through ``scan``
  lengths (exact trip counts by construction). FLOPs from dot_general
  contraction shapes; HBM-traffic estimate from a fusion-aware model:
  dot/gather/scatter/reduce operands+results are read/written from HBM,
  other elementwise ops contribute their OUTPUT bytes only (XLA fuses
  producer chains; each materialized tensor is written once). Documented
  as the traffic model in EXPERIMENTS.md.

* ``collective_bytes_multiplied`` — parses the post-SPMD optimized HLO,
  recovers each while loop's trip count from the largest integer constant
  in its condition computation, and multiplies the collective payloads in
  its body accordingly (recursively through call/fusion/conditional).
"""

from __future__ import annotations

import math
import re

import jax
import numpy as np

_DTYPE_BYTES = {
    "float64": 8, "float32": 4, "float16": 2, "bfloat16": 2,
    "int64": 8, "uint64": 8, "int32": 4, "uint32": 4,
    "int16": 2, "uint16": 2, "int8": 1, "uint8": 1, "bool": 1,
    "complex64": 8, "complex128": 16,
    "float8_e4m3fn": 1, "float8_e5m2": 1,
}


def _nbytes(aval) -> float:
    try:
        return float(np.prod(aval.shape)) * _DTYPE_BYTES.get(
            str(aval.dtype), 4)
    except Exception:
        return 0.0


def _nelems(aval) -> float:
    try:
        return float(np.prod(aval.shape))
    except Exception:
        return 0.0


_ELEMENTWISE_FREE = {
    "broadcast_in_dim", "reshape", "squeeze", "convert_element_type",
    "iota", "constant", "slice", "transpose", "rev", "bitcast_convert_type",
    "copy", "stop_gradient", "split",
}

_SUBJAXPR_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "closed_jaxpr")


def _sub_jaxprs(params: dict):
    for k in _SUBJAXPR_KEYS:
        if k in params and params[k] is not None:
            yield params[k]


def jaxpr_cost(closed, *, shard_map_factor: int = 1) -> dict:
    """Returns {"flops": .., "bytes": ..} for one closed jaxpr (global)."""
    acc = {"flops": 0.0, "bytes": 0.0}
    _walk(closed.jaxpr if hasattr(closed, "jaxpr") else closed, acc,
          shard_map_factor)
    return acc


def _walk(jaxpr, acc: dict, smf: int, scale: float = 1.0) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        params = eqn.params
        if name == "dot_general":
            lhs = eqn.invars[0].aval
            (lc, _rc), (lb, _rb) = params["dimension_numbers"]
            k = float(np.prod([lhs.shape[i] for i in lc])) if lc else 1.0
            out = eqn.outvars[0].aval
            acc["flops"] += scale * 2.0 * _nelems(out) * k
            acc["bytes"] += scale * (sum(_nbytes(v.aval) for v in eqn.invars)
                                     + _nbytes(out))
        elif name == "scan":
            length = float(params.get("length", 1))
            inner = {"flops": 0.0, "bytes": 0.0}
            _walk(params["jaxpr"].jaxpr, inner, smf)
            acc["flops"] += scale * length * inner["flops"]
            acc["bytes"] += scale * length * inner["bytes"]
        elif name == "while":
            # only Pallas-interpret / fori paths hit this; assume 1 trip and
            # flag via bytes of carry (rare in dry-run cells)
            for sub in _sub_jaxprs(params):
                inner = {"flops": 0.0, "bytes": 0.0}
                _walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub, inner, smf)
                acc["flops"] += scale * inner["flops"]
                acc["bytes"] += scale * inner["bytes"]
        elif name == "cond":
            costs = []
            for br in params.get("branches", ()):
                inner = {"flops": 0.0, "bytes": 0.0}
                _walk(br.jaxpr if hasattr(br, "jaxpr") else br, inner, smf)
                costs.append(inner)
            if costs:
                acc["flops"] += scale * max(c["flops"] for c in costs)
                acc["bytes"] += scale * max(c["bytes"] for c in costs)
        elif name == "shard_map":
            for sub in _sub_jaxprs(params):
                inner = {"flops": 0.0, "bytes": 0.0}
                _walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub, inner, smf)
                acc["flops"] += scale * smf * inner["flops"]
                acc["bytes"] += scale * smf * inner["bytes"]
        elif any(k in params for k in _SUBJAXPR_KEYS):
            for sub in _sub_jaxprs(params):
                _walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub, acc, smf,
                      scale)
        elif name in ("gather", "dynamic_slice", "take"):
            out = eqn.outvars[0].aval
            acc["bytes"] += scale * 2.0 * _nbytes(out)
        elif name.startswith("scatter") or name == "dynamic_update_slice":
            upd = eqn.invars[-1].aval
            acc["flops"] += scale * _nelems(upd)
            acc["bytes"] += scale * (2.0 * _nbytes(upd)
                                     + _nbytes(eqn.outvars[0].aval) * 0.0)
        elif name.startswith("reduce_") or name in ("argmax", "argmin"):
            inb = sum(_nbytes(v.aval) for v in eqn.invars)
            acc["flops"] += scale * sum(_nelems(v.aval) for v in eqn.invars)
            acc["bytes"] += scale * inb
        elif name in ("sort", "top_k", "approx_top_k"):
            inb = sum(_nbytes(v.aval) for v in eqn.invars)
            n = sum(_nelems(v.aval) for v in eqn.invars)
            acc["flops"] += scale * n * max(math.log2(max(n, 2)), 1.0)
            acc["bytes"] += scale * 2.0 * inb
        elif name in ("cumsum", "cumlogsumexp", "cummax", "cumprod"):
            acc["flops"] += scale * 2.0 * _nelems(eqn.outvars[0].aval)
            acc["bytes"] += scale * 2.0 * _nbytes(eqn.outvars[0].aval)
        elif name in _ELEMENTWISE_FREE:
            pass
        else:
            # generic elementwise: flops = outputs, traffic = outputs once
            outb = sum(_nbytes(v.aval) for v in eqn.outvars)
            acc["flops"] += scale * sum(_nelems(v.aval) for v in eqn.outvars)
            acc["bytes"] += scale * outb


def traced_cost(fn, args, *, n_shards: int = 1) -> dict:
    """Trace ``fn(*args)`` (abstract) and return global flops/bytes."""
    closed = jax.make_jaxpr(fn)(*args)
    return jaxpr_cost(closed, shard_map_factor=n_shards)


# --------------------------------------------------------------------------
# loop-aware collective accounting from optimized HLO text
# --------------------------------------------------------------------------

_HLO_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{")
_WHILE_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_WHILE_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLL_RE = re.compile(
    r"=\s+(.+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all"
    r"|collective-permute)(-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _HLO_DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _HLO_DTYPE_BYTES[dt]
    return total


def _split_computations(text: str) -> dict[str, list[str]]:
    """Map computation name -> body lines. Headers sit at column 0 and end
    with '{'; params may contain nested parens, so only the leading token
    (the computation name) is parsed."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        if line and not line.startswith(" "):
            s = line.strip()
            if s == "}":
                cur = None
                continue
            if s.endswith("{") and (s.startswith("%") or s.startswith("ENTRY")):
                tok = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
                name = tok.lstrip("%").split("(")[0]
                if name:
                    cur = name
                    comps[cur] = []
                continue
            cur = None          # module header / metadata sections
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def collective_bytes_multiplied(text: str) -> dict:
    """Collective wire bytes with while-loop trip counts multiplied in."""
    comps = _split_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None:
        entry = next(iter(comps), None)

    def trip_count(cond_name: str) -> int:
        consts = []
        for line in comps.get(cond_name, ()):
            consts += [int(c) for c in _CONST_RE.findall(line)]
        return max([c for c in consts if 1 <= c <= 10_000_000] or [1])

    memo: dict[str, dict] = {}

    def visit(name: str) -> dict:
        if name in memo:
            return memo[name]
        memo[name] = {"per_op": {}, "wire_bytes": 0.0}   # cycle guard
        agg: dict[str, dict] = {}
        wire = 0.0

        def add(op, nbytes, w, mult=1.0):
            d = agg.setdefault(op, {"count": 0, "bytes": 0.0,
                                    "wire_bytes": 0.0})
            d["count"] += mult
            d["bytes"] += nbytes * mult
            d["wire_bytes"] += w * mult

        for line in comps.get(name, ()):
            if " while(" in line:
                mc_, mb_ = (_WHILE_COND_RE.search(line),
                            _WHILE_BODY_RE.search(line))
                if mc_ and mb_:
                    t = trip_count(mc_.group(1))
                    sub = visit(mb_.group(1))
                    for op, d in sub["per_op"].items():
                        add(op, d["bytes"], d["wire_bytes"], t)
                    wire += t * sub["wire_bytes"]
                    continue
            mcnd = _COND_RE.search(line)
            if mcnd:
                branches = [b.strip().lstrip("%") for b in
                            mcnd.group(1).split(",")]
                subs = [visit(b) for b in branches if b in comps]
                if subs:
                    worst = max(subs, key=lambda s: s["wire_bytes"])
                    for op, d in worst["per_op"].items():
                        add(op, d["bytes"], d["wire_bytes"])
                    wire += worst["wire_bytes"]
                continue
            mc = _COLL_RE.search(line)
            if mc and mc.group(3) != "-done":
                nbytes = _shape_bytes(mc.group(1))
                w = 2 * nbytes if mc.group(2) == "all-reduce" else nbytes
                add(mc.group(2), nbytes, w)
                wire += w
                continue
            mcall = _CALL_RE.search(line)
            if mcall and "fusion" not in line:
                sub = visit(mcall.group(1))
                for op, d in sub["per_op"].items():
                    add(op, d["bytes"], d["wire_bytes"])
                wire += sub["wire_bytes"]
        memo[name] = {"per_op": agg, "wire_bytes": wire}
        return memo[name]

    out = visit(entry) if entry else {"per_op": {}, "wire_bytes": 0.0}
    # round counts for readability
    for d in out["per_op"].values():
        d["count"] = int(d["count"])
        d["bytes"] = int(d["bytes"])
        d["wire_bytes"] = int(d["wire_bytes"])
    out["wire_bytes"] = int(out["wire_bytes"])
    return out
