"""Eager index-time scoring — the core of BM25S (§2 of the paper).

``build_index`` turns a tokenized corpus into a :class:`BM25Index`: every
possible score any future query token can contribute to any document is
computed *now* and stored sparsely, CSC-style keyed by token id. For the
shifted variants (§2.1) the stored value is the differential
``SΔ(t,D) = S(t,D) − S⁰(t)`` and the per-token nonoccurrence vector ``S⁰``
is kept alongside (a |V| array — footnote 12 of the paper).

Query-time work is thereby reduced to: gather the postings of the query
tokens, sum per document, (+ the scalar ``Σ S⁰(qᵢ)`` for shifted variants),
then top-k. See ``scoring.py`` / ``retrieval.py`` for the device-side half.

Everything in this module is host-side NumPy; it is embarrassingly parallel
over document shards (each shard indexes its own documents given global
``df``/``L_avg`` statistics — see ``build_sharded_indexes``).
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, replace
from typing import Sequence

import numpy as np

from .variants import BM25Params, BM25Variant, get_variant


@dataclass
class CorpusStats:
    """Global statistics needed to eagerly score any document shard."""

    n_docs: int
    n_vocab: int
    df: np.ndarray        # [V] int64 document frequency
    l_avg: float          # mean document length (tokens)

    @staticmethod
    def from_corpus(doc_tokens: Sequence[np.ndarray], n_vocab: int) -> "CorpusStats":
        df = np.zeros(n_vocab, dtype=np.int64)
        total_len = 0
        for toks in doc_tokens:
            total_len += int(toks.size)
            if toks.size:
                df[np.unique(toks)] += 1
        n_docs = len(doc_tokens)
        l_avg = total_len / max(n_docs, 1)
        return CorpusStats(n_docs=n_docs, n_vocab=n_vocab, df=df, l_avg=l_avg)


@dataclass
class BM25Index:
    """Eager sparse score index in CSC-by-token layout.

    ``indptr[t] : indptr[t+1]`` delimits the postings of token ``t``;
    ``doc_ids`` are sorted ascending within each token's slice (the CSC
    invariant the distributed/blocked layouts rely on).
    """

    indptr: np.ndarray      # [V+1] int64
    doc_ids: np.ndarray     # [nnz] int32
    scores: np.ndarray      # [nnz] float32 — S or SΔ (differential)
    nonoccurrence: np.ndarray  # [V] float32 — S⁰; zeros for sparse variants
    doc_lens: np.ndarray    # [C] int32
    n_docs: int
    n_vocab: int
    l_avg: float
    variant: str
    params: BM25Params
    doc_offset: int = 0     # global id of local doc 0 (for shards)

    @property
    def nnz(self) -> int:
        return int(self.doc_ids.size)

    @property
    def is_shifted(self) -> bool:
        return bool(np.any(self.nonoccurrence != 0.0))

    def token_df(self) -> np.ndarray:
        return np.diff(self.indptr)

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        np.savez(
            os.path.join(path, "arrays.npz"),
            indptr=self.indptr, doc_ids=self.doc_ids, scores=self.scores,
            nonoccurrence=self.nonoccurrence, doc_lens=self.doc_lens,
        )
        meta = {
            "n_docs": self.n_docs, "n_vocab": self.n_vocab,
            "l_avg": self.l_avg, "variant": self.variant,
            "doc_offset": self.doc_offset,
            "params": {"k1": self.params.k1, "b": self.params.b,
                       "delta": self.params.delta, "method": self.params.method},
        }
        tmp = os.path.join(path, "meta.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(path, "meta.json"))

    @staticmethod
    def load(path: str) -> "BM25Index":
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        arrs = np.load(os.path.join(path, "arrays.npz"))
        return BM25Index(
            indptr=arrs["indptr"], doc_ids=arrs["doc_ids"],
            scores=arrs["scores"], nonoccurrence=arrs["nonoccurrence"],
            doc_lens=arrs["doc_lens"], n_docs=meta["n_docs"],
            n_vocab=meta["n_vocab"], l_avg=meta["l_avg"],
            variant=meta["variant"], doc_offset=meta.get("doc_offset", 0),
            params=BM25Params(**meta["params"]),
        )


def _corpus_coo(doc_tokens: Sequence[np.ndarray]) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(token_ids, doc_ids, tf) postings + doc lengths for a corpus shard."""
    tok_chunks, doc_chunks, tf_chunks = [], [], []
    doc_lens = np.zeros(len(doc_tokens), dtype=np.int32)
    for d, toks in enumerate(doc_tokens):
        doc_lens[d] = toks.size
        if toks.size == 0:
            continue
        uniq, counts = np.unique(toks, return_counts=True)
        tok_chunks.append(uniq.astype(np.int64))
        doc_chunks.append(np.full(uniq.size, d, dtype=np.int64))
        tf_chunks.append(counts.astype(np.float64))
    if not tok_chunks:
        z64, zf = np.zeros(0, np.int64), np.zeros(0, np.float64)
        return z64, z64.copy(), zf, doc_lens
    return (np.concatenate(tok_chunks), np.concatenate(doc_chunks),
            np.concatenate(tf_chunks), doc_lens)


def build_index(
    doc_tokens: Sequence[np.ndarray],
    n_vocab: int,
    *,
    params: BM25Params | None = None,
    stats: CorpusStats | None = None,
    doc_offset: int = 0,
) -> BM25Index:
    """Eagerly score a (shard of a) corpus into a :class:`BM25Index`.

    ``stats`` carries *global* corpus statistics; when ``None`` they are
    computed from ``doc_tokens`` itself (single-shard build). Passing global
    stats while giving only a document shard is exactly how the distributed
    index build works — scores depend on other shards only through
    ``(df, N, L_avg)``.
    """
    params = params or BM25Params()
    variant: BM25Variant = get_variant(params.method)
    if stats is None:
        stats = CorpusStats.from_corpus(doc_tokens, n_vocab)

    tok, doc, tf, doc_lens = _corpus_coo(doc_tokens)

    df_per_posting = stats.df[tok].astype(np.float64)
    dl_per_posting = doc_lens[doc].astype(np.float64)
    scores = variant.score(
        tf, df_per_posting, stats.n_docs, dl_per_posting, stats.l_avg, params
    )

    # §2.1 score shifting: store the differential score so the matrix stays
    # sparse. For sparse variants nonocc ≡ 0 and this is a no-op.
    df_all = stats.df.astype(np.float64)
    nonocc = np.where(
        df_all > 0,
        variant.nonoccurrence(np.maximum(df_all, 1.0), stats.n_docs, params),
        0.0,
    )
    scores = scores - nonocc[tok]

    # CSC-by-token: sort postings by (token, doc). np.lexsort is stable.
    order = np.lexsort((doc, tok))
    tok, doc, scores = tok[order], doc[order], scores[order]
    indptr = np.zeros(n_vocab + 1, dtype=np.int64)
    np.add.at(indptr, tok + 1, 1)
    np.cumsum(indptr, out=indptr)

    return BM25Index(
        indptr=indptr,
        doc_ids=doc.astype(np.int32),
        scores=scores.astype(np.float32),
        nonoccurrence=nonocc.astype(np.float32),
        doc_lens=doc_lens,
        n_docs=stats.n_docs if doc_offset == 0 and len(doc_tokens) == stats.n_docs
        else len(doc_tokens),
        n_vocab=n_vocab,
        l_avg=stats.l_avg,
        variant=variant.name,
        params=params,
        doc_offset=doc_offset,
    )


def build_sharded_indexes(
    doc_tokens: Sequence[np.ndarray],
    n_vocab: int,
    n_shards: int,
    *,
    params: BM25Params | None = None,
) -> list[BM25Index]:
    """Distributed index build: global stats pass + per-shard eager scoring.

    Shards are contiguous document ranges (balanced ±1). This mirrors the
    production flow where each host indexes its own documents after an
    all-reduce of ``(df, Σ len, N)``.
    """
    stats = CorpusStats.from_corpus(doc_tokens, n_vocab)
    bounds = np.linspace(0, len(doc_tokens), n_shards + 1).astype(int)
    shards = []
    for s in range(n_shards):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        shards.append(
            build_index(doc_tokens[lo:hi], n_vocab, params=params,
                        stats=stats, doc_offset=lo)
        )
    return shards


def reshard_index(shards: list[BM25Index], n_new: int) -> list[BM25Index]:
    """Elastically re-balance shards to a new shard count.

    Pure host-side re-slicing: postings are re-bucketed by global doc id.
    Used when the device pool shrinks/grows (see serve/engine.py).
    """
    if not shards:
        raise ValueError("no shards to reshard")
    # reconstruct global COO
    toks, docs, scs, lens_parts = [], [], [], []
    v = shards[0].n_vocab
    for sh in shards:
        tok = np.repeat(np.arange(v, dtype=np.int64), np.diff(sh.indptr))
        toks.append(tok)
        docs.append(sh.doc_ids.astype(np.int64) + sh.doc_offset)
        scs.append(sh.scores)
        lens_parts.append((sh.doc_offset, sh.doc_lens))
    tok = np.concatenate(toks)
    doc = np.concatenate(docs)
    sc = np.concatenate(scs)
    n_docs_total = max(off + dl.size for off, dl in lens_parts)
    doc_lens = np.zeros(n_docs_total, dtype=np.int32)
    for off, dl in lens_parts:
        doc_lens[off:off + dl.size] = dl

    proto = shards[0]
    bounds = np.linspace(0, n_docs_total, n_new + 1).astype(int)
    out = []
    for s in range(n_new):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        sel = (doc >= lo) & (doc < hi)
        t_s, d_s, s_s = tok[sel], doc[sel] - lo, sc[sel]
        order = np.lexsort((d_s, t_s))
        t_s, d_s, s_s = t_s[order], d_s[order], s_s[order]
        indptr = np.zeros(v + 1, dtype=np.int64)
        np.add.at(indptr, t_s + 1, 1)
        np.cumsum(indptr, out=indptr)
        out.append(replace(
            proto,
            indptr=indptr, doc_ids=d_s.astype(np.int32),
            scores=s_s.astype(np.float32), doc_lens=doc_lens[lo:hi],
            n_docs=hi - lo, doc_offset=lo,
        ))
    return out
