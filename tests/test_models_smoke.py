"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and finiteness. All 10 assigned archs."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_module, get_smoke
from repro.models import egnn, recsys, transformer

LM_ARCHS = [a for a in ASSIGNED_ARCHS
            if get_module(a).FAMILY == "lm"]
RECSYS_ARCHS = [a for a in ASSIGNED_ARCHS
                if get_module(a).FAMILY == "recsys"]


def _finite(tree) -> bool:
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch, rng):
    cfg = get_smoke(arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 16)),
                       jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: transformer.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert _finite(grads)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode(arch, rng):
    cfg = get_smoke(arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 8
    cache = transformer.init_decode_cache(cfg, b, s)
    cache["pos"] = jnp.asarray(0, jnp.int32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b,)), jnp.int32)
    logits, cache = transformer.decode_step(cfg, params, cache, toks)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(cache["pos"]) == 1


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_shapes(arch, rng):
    cfg = get_smoke(arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 16)),
                       jnp.int32)
    logits, cache = transformer.prefill(cfg, params, toks)
    assert logits.shape == (2, cfg.vocab_size)
    assert cache["k"].shape[0] == cfg.n_layers
    assert np.isfinite(np.asarray(logits)).all()


def test_egnn_smoke_all_shapes(rng):
    from repro.data.graphs import batched_molecules, random_graph
    cfg = get_smoke("egnn")
    # node classification
    g = random_graph(50, 4, d_feat=cfg.d_feat, n_classes=cfg.n_out)
    params = egnn.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"node_feat": jnp.asarray(g.node_feat),
             "coords": jnp.asarray(g.coords),
             "edges": jnp.asarray(g.edges.astype(np.int32)),
             "labels": jnp.asarray(g.labels.astype(np.int32))}
    loss, m = egnn.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    # graph regression (molecule)
    from dataclasses import replace
    cfgg = replace(cfg, readout="graph", n_out=1, d_feat=11)
    pg = egnn.init_params(jax.random.PRNGKey(1), cfgg)
    mb = batched_molecules(4, n_nodes=10, n_edges=12)
    mb = {k: (jnp.asarray(v) if not isinstance(v, int) else v)
          for k, v in mb.items()}
    loss, _ = egnn.loss_fn(cfgg, pg, mb)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train_and_retrieve(arch, rng):
    cfg = get_smoke(arch)
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    b = 8
    if cfg.model in ("dlrm", "autoint"):
        batch = {"sparse": jnp.asarray(
            np.stack([rng.integers(0, v, size=b) for v in cfg.vocab_sizes],
                     axis=1).astype(np.int32))}
        if cfg.n_dense:
            batch["dense"] = jnp.asarray(
                rng.normal(size=(b, cfg.n_dense)).astype(np.float32))
        batch["labels"] = jnp.asarray(rng.integers(0, 2, size=b), jnp.int32)
    else:
        v = cfg.vocab_sizes[0]
        hist = jnp.asarray(rng.integers(1, v, size=(b, cfg.seq_len)),
                           jnp.int32)
        if cfg.model == "sasrec":
            batch = {"history": hist,
                     "pos_items": jnp.asarray(
                         rng.integers(1, v, size=(b, cfg.seq_len)), jnp.int32),
                     "neg_items": jnp.asarray(
                         rng.integers(1, v, size=(b, cfg.seq_len)), jnp.int32)}
        else:
            batch = {"history": hist,
                     "pos_items": jnp.asarray(rng.integers(1, v, size=b),
                                              jnp.int32),
                     "neg_items": jnp.asarray(rng.integers(1, v, size=b),
                                              jnp.int32)}
    (loss, _), grads = jax.value_and_grad(
        lambda p: recsys.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert _finite(grads)
    # retrieval path
    cands = jnp.arange(1, 65, dtype=jnp.int32)
    one = {k: v[:1] for k, v in batch.items() if k != "labels"}
    scores = recsys.retrieval_scores(cfg, params, one, cands)
    assert scores.shape[-1] == 64
    assert np.isfinite(np.asarray(scores)).all()


def test_all_archs_have_configs():
    from repro.configs import list_archs
    archs = list_archs()
    assert len(archs) == 11          # 10 assigned + bm25s
    for a in archs:
        mod = get_module(a)
        assert hasattr(mod, "CONFIG") and hasattr(mod, "SMOKE")
        cells = mod.cells()
        assert cells, a
