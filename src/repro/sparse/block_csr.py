"""Block-bucketed CSR — the TPU-native layout for eager sparse scores.

DESIGN.md §3.1: documents (or GNN destination nodes) are grouped into fixed
blocks of ``block_size``; each block's postings (or edges) live in flat
arrays padded to a static per-block budget that is a multiple of the kernel
tile. Every shape is static under ``jit``; padding waste is the block-size
quantization cost and is reported by ``padding_stats``.

The same layout backs three workloads:
  * BM25S scoring   — (token_id, local_doc, score) per posting
  * GNN aggregation — (src_node, local_dst, edge_weight/message id)
  * EmbeddingBag    — (row_id, local_bag, sample_weight)
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class BlockedPostings:
    """Postings bucketed by destination block (static-shape sparse layout).

    ``token_ids[i, p]`` is -1 for padding slots; padding slots carry
    ``scores == 0`` and ``local_doc == 0`` so any consumer that forgets the
    mask still computes correct sums.
    """

    token_ids: np.ndarray   # [n_blocks, nnz_pad] int32, -1 = pad
    local_doc: np.ndarray   # [n_blocks, nnz_pad] int32 in [0, block_size)
    scores: np.ndarray      # [n_blocks, nnz_pad] float32
    block_size: int
    n_docs: int             # true (unpadded) number of documents
    n_vocab: int

    @property
    def n_blocks(self) -> int:
        return int(self.token_ids.shape[0])

    @property
    def nnz_pad(self) -> int:
        return int(self.token_ids.shape[1])

    def padding_stats(self) -> dict:
        real = int((self.token_ids >= 0).sum())
        total = self.token_ids.size
        return {
            "nnz": real,
            "padded_nnz": total,
            "pad_fraction": 1.0 - real / max(total, 1),
            "n_blocks": self.n_blocks,
            "nnz_pad_per_block": self.nnz_pad,
        }


def _round_up(x: int, tile: int) -> int:
    return max(tile, ((x + tile - 1) // tile) * tile)


def bucket_pow2(n: int, *, floor: int = 512, cap: int | None = None) -> int:
    """Round ``n`` up to a power-of-two bucket (≥ ``floor``).

    Adaptive budgets size device shapes from the batch's ACTUAL demand
    (Σ df, candidate count), but a fresh shape per batch would recompile
    every call — power-of-two buckets bound the distinct compiled shapes to
    O(log max-demand). ``cap`` (if given) clamps the bucket; callers must
    then treat ``n > cap`` as overflow and retry or fall back, never
    truncate silently. (Canonical definition — ``core.scoring`` re-exports
    it; keep ONE power-of-two bucketing implementation in the repo.)
    """
    b = max(int(floor), 1)
    while b < n:
        b <<= 1
    return min(b, cap) if cap is not None else b


def block_postings_from_coo(
    token_ids: np.ndarray,
    doc_ids: np.ndarray,
    scores: np.ndarray,
    *,
    n_docs: int,
    n_vocab: int,
    block_size: int = 512,
    tile: int = 512,
    sort_tokens: bool = True,
) -> BlockedPostings:
    """Bucket COO postings by ``doc_id // block_size`` and pad per block.

    ``nnz_pad`` is the max per-block count rounded up to ``tile`` (one budget
    shared by all blocks so the arrays are rectangular). Within a block
    postings are sorted by token id (the membership-lookup kernel exploits
    locality, and determinism helps tests).

    Fully vectorized: one ``lexsort`` by (block, token) makes each block a
    contiguous run, the within-block column of every posting is
    ``rank - block_start``, and a single fancy-indexed scatter fills the
    rectangular arrays — no per-block Python loop.
    """
    n_blocks = max(1, -(-n_docs // block_size))
    blk = doc_ids // block_size
    counts = np.bincount(blk, minlength=n_blocks)
    nnz_pad = _round_up(int(counts.max()) if counts.size else 0, tile)

    tok = np.full((n_blocks, nnz_pad), -1, dtype=np.int32)
    loc = np.zeros((n_blocks, nnz_pad), dtype=np.int32)
    sc = np.zeros((n_blocks, nnz_pad), dtype=np.float32)

    order = (np.lexsort((token_ids, blk)) if sort_tokens
             else np.argsort(blk, kind="stable"))
    token_ids, doc_ids, scores, blk = (
        token_ids[order], doc_ids[order], scores[order], blk[order])
    starts = np.zeros(n_blocks + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    col = np.arange(blk.size, dtype=np.int64) - starts[blk]
    tok[blk, col] = token_ids
    loc[blk, col] = doc_ids - blk * block_size
    sc[blk, col] = scores
    return BlockedPostings(tok, loc, sc, block_size=block_size,
                           n_docs=n_docs, n_vocab=n_vocab)


def block_postings_from_index(index, *, block_size: int = 512,
                              tile: int = 512) -> BlockedPostings:
    """Re-block a :class:`repro.core.index.BM25Index` (CSC-by-token) shard."""
    df = np.diff(index.indptr)
    tok = np.repeat(np.arange(index.n_vocab, dtype=np.int32), df)
    return block_postings_from_coo(
        tok, index.doc_ids.astype(np.int64), index.scores,
        n_docs=int(index.doc_lens.size), n_vocab=index.n_vocab,
        block_size=block_size, tile=tile)


def block_edges(src: np.ndarray, dst: np.ndarray, weight: np.ndarray | None,
                *, n_nodes: int, block_size: int = 512,
                tile: int = 512) -> BlockedPostings:
    """GNN edge list -> destination-blocked layout (same container).

    ``token_ids`` carries the *source node id*, ``local_doc`` the destination
    offset within its block, ``scores`` the edge weight (1.0 if None).
    """
    w = np.ones(src.shape[0], np.float32) if weight is None else weight
    return block_postings_from_coo(
        src.astype(np.int32), dst.astype(np.int64), w.astype(np.float32),
        n_docs=n_nodes, n_vocab=n_nodes, block_size=block_size, tile=tile,
        sort_tokens=False)


@dataclass
class GatheredPostings:
    """Query-driven posting gather in the candidate-compacted layout.

    Only the query tokens' posting runs are materialized — total work is
    O(Σ df(qᵢ)) over the *batch's unique tokens*, never O(nnz). Candidate
    documents (the union of gathered doc ids, sorted ascending) are mapped
    to compact slots ``0..n_candidates-1``; slots are chunked by
    ``slot // acc_block`` so chunk ``c``'s postings only touch accumulator
    rows ``[0, acc_block)`` — the static shape the gather kernel's
    VMEM accumulator needs. ``candidates[c, r]`` recovers the global doc id
    of chunk ``c``'s slot ``r`` (-1 = padding slot, masked to -inf before
    top-k selection).

    ``acc_block`` should stay SMALL (the blocked layout's block_size, 512):
    the kernel's scatter is a one-hot matmul whose cost is
    ``acc_block × tile_p × B`` per posting tile, so total MXU work is
    ``Σ df × acc_block × B`` — chunking a large candidate set over many
    short accumulators keeps that linear in Σ df, while one tall
    accumulator would multiply every posting by its full height and hand
    the advantage back to the full scan.
    """

    token_ids: np.ndarray    # [n_chunks, p_pad] int32, -1 = pad
    slot_ids: np.ndarray     # [n_chunks, p_pad] int32 in [0, acc_block)
    scores: np.ndarray       # [n_chunks, p_pad] float32
    candidates: np.ndarray   # [n_chunks, acc_block] int32 global ids, -1 pad
    acc_block: int           # accumulator height (candidate slots per chunk)
    n_candidates: int        # true (unpadded) candidate-document count
    sum_df: int              # Σ df over the batch's unique query tokens

    @property
    def n_chunks(self) -> int:
        return int(self.token_ids.shape[0])

    @property
    def p_pad(self) -> int:
        return int(self.token_ids.shape[1])

    def work_ratio(self, nnz: int) -> float:
        """Full-scan postings / gathered postings — the asymptotic win."""
        return nnz / max(self.sum_df, 1)


def posting_runs(indptr: np.ndarray, uniq_tokens: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Per-token posting-run descriptors ``(start, len)`` from CSC indptr.

    The inverted-index traversal plan: one ``(start, len)`` pair per unique
    query token, O(U) to compute. ``Σ len`` is the exact posting budget the
    gather needs — the adaptive-bucket logic sizes from it.
    """
    starts = indptr[uniq_tokens]
    lens = indptr[uniq_tokens + 1] - starts
    return starts.astype(np.int64), lens.astype(np.int64)


def gather_posting_runs(index, uniq_tokens: np.ndarray, *,
                        acc_block: int = 512, tile: int = 512,
                        p_bucket: int | None = None) -> GatheredPostings:
    """Gather ONLY the query tokens' posting runs (host, fully vectorized).

    One ``np.repeat``-based run flattening replaces per-token slicing: flat
    position ``j`` of run ``i`` reads ``doc_ids[start_i + j]``. Candidate
    compaction is one ``np.unique`` over the gathered doc ids; chunking by
    ``slot // acc_block`` reuses :func:`block_postings_from_coo` (postings
    within a chunk stay token-sorted for the kernel's membership locality).

    Both static dimensions are power-of-two bucketed so the kernel
    recompiles O(log Σdf) times, not once per batch: the per-chunk posting
    dimension rounds up to a power-of-two multiple of ``tile`` (``p_bucket``
    overrides with an explicit floor), and the chunk count pads with empty
    chunks (all -1). The gather itself can never overflow: shapes are sized
    *from* the batch's actual Σ df.
    """
    uniq_tokens = np.asarray(uniq_tokens, dtype=np.int64)
    starts, lens = posting_runs(index.indptr, uniq_tokens)
    total = int(lens.sum())
    if total == 0:
        p_pad = max(tile, p_bucket or tile)
        return GatheredPostings(
            token_ids=np.full((1, p_pad), -1, np.int32),
            slot_ids=np.zeros((1, p_pad), np.int32),
            scores=np.zeros((1, p_pad), np.float32),
            candidates=np.full((1, acc_block), -1, np.int32),
            acc_block=acc_block, n_candidates=0, sum_df=0)
    # vectorized run flatten: pos[j] = starts[run(j)] + (j - run_start(j))
    run_of = np.repeat(np.arange(lens.size, dtype=np.int64), lens)
    run_start = np.repeat(np.cumsum(lens) - lens, lens)
    pos = starts[run_of] + np.arange(total, dtype=np.int64) - run_start
    g_tok = uniq_tokens[run_of].astype(np.int32)
    g_doc = index.doc_ids[pos].astype(np.int64)
    g_sc = index.scores[pos].astype(np.float32)

    candidates = np.unique(g_doc)                 # sorted ascending
    slot = np.searchsorted(candidates, g_doc)
    n_cand = int(candidates.size)

    bp = block_postings_from_coo(g_tok, slot, g_sc, n_docs=n_cand,
                                 n_vocab=int(index.n_vocab),
                                 block_size=acc_block, tile=tile)
    tok, loc, sc = bp.token_ids, bp.local_doc, bp.scores
    p_pad = max(bucket_pow2(bp.nnz_pad, floor=tile), p_bucket or 0)
    if p_pad > bp.nnz_pad:
        pad = p_pad - bp.nnz_pad
        tok = np.pad(tok, ((0, 0), (0, pad)), constant_values=-1)
        loc = np.pad(loc, ((0, 0), (0, pad)))
        sc = np.pad(sc, ((0, 0), (0, pad)))
    nc = bucket_pow2(bp.n_blocks, floor=1)        # bucket the chunk count
    if nc > bp.n_blocks:
        pad = nc - bp.n_blocks
        tok = np.pad(tok, ((0, pad), (0, 0)), constant_values=-1)
        loc = np.pad(loc, ((0, pad), (0, 0)))
        sc = np.pad(sc, ((0, pad), (0, 0)))
    cand = np.full((nc, acc_block), -1, np.int32)
    flat = cand.reshape(-1)
    flat[:n_cand] = candidates
    return GatheredPostings(token_ids=tok, slot_ids=loc, scores=sc,
                            candidates=cand, acc_block=acc_block,
                            n_candidates=n_cand, sum_df=total)


def query_nonoccurrence_shift(nonoccurrence: np.ndarray,
                              q_tokens: np.ndarray,
                              q_weights: np.ndarray) -> np.ndarray:
    """Per-query §2.1 constant ``Σᵢ wᵢ·S⁰(qᵢ)`` for a padded query batch.

    ``[B]`` float32, zero for sparse variants. The single definition of the
    host-side shift the fused retrieval path adds after its merge
    (``ops.bm25_retrieve_blocked``'s ``nonocc_shift`` operand).
    """
    safe = np.where(q_tokens >= 0, q_tokens, 0)
    return ((q_weights * nonoccurrence[safe] * (q_tokens >= 0))
            .sum(-1).astype(np.float32))


def pack_query_batch(q_tokens: np.ndarray, q_weights: np.ndarray,
                     u_max: int, *, uniq: np.ndarray | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Batch of padded queries -> (sorted unique tokens [U], weights [U, B]).

    The batched kernel scores *all* queries in one pass over the postings
    (DESIGN.md §3.3); its query-side operand is the batch's unique-token
    table plus a per-query weight column. Pad token = 2^31 - 1 (sorts last,
    matches nothing since posting pads are -1). ``uniq`` lets hot-path
    callers that already computed the batch's sorted unique tokens (for
    bucket sizing / run gathering) skip the redundant sort here.
    """
    b = q_tokens.shape[0]
    if uniq is None:
        uniq = np.unique(q_tokens[q_tokens >= 0])
    if uniq.size > u_max:
        raise ValueError(f"query batch has {uniq.size} unique tokens "
                         f"> u_max={u_max}")
    table = np.full(u_max, np.iinfo(np.int32).max, dtype=np.int32)
    table[: uniq.size] = uniq
    weights = np.zeros((u_max, b), dtype=np.float32)
    # tokens are unique within a query (pad_queries), so one scatter works
    qi, slot = np.nonzero(q_tokens >= 0)
    pos = np.searchsorted(uniq, q_tokens[qi, slot])
    weights[pos, qi] = q_weights[qi, slot]
    return table, weights
