"""Serving stack: sharded retrieval engine with hedging, LM decode engine."""

from .errors import (InvalidQueryError, PlanOverflowError, ResidencyError,
                     RetrievalConfigError, RetrievalError,
                     ScoreIntegrityError, SnapshotIntegrityError,
                     SnapshotVersionError, TruncationWarning)
from .retrieval_engine import (BlockedRetriever, DeviceRetriever,
                               GatheredRetriever, PrunedRetriever,
                               RetrievalEngine, ShardRuntime)
from .decode_engine import DecodeEngine

__all__ = ["BlockedRetriever", "DeviceRetriever", "GatheredRetriever",
           "PrunedRetriever", "RetrievalEngine", "ShardRuntime",
           "DecodeEngine", "RetrievalError", "InvalidQueryError",
           "PlanOverflowError", "ResidencyError", "ScoreIntegrityError",
           "RetrievalConfigError", "SnapshotIntegrityError",
           "SnapshotVersionError", "TruncationWarning"]
