"""Async micro-batching front-end: single-query in, batched launches out.

The device scorers amortize kernel-launch and query-table cost over a
batch (``DeviceRetriever.retrieve_batch`` is ONE launch for B queries),
but real serving traffic arrives one query at a time. The naive bridge —
launch per arrival — pays the whole fixed cost per query; the naive
batcher — wait for B arrivals — blows the latency SLO at low rates.
:class:`ServingFrontend` is the standard middle path, specialized to this
stack's compilation model:

* **Admission** — :meth:`submit` enqueues one query and returns a
  ``concurrent.futures.Future`` resolving to a
  :class:`~repro.serve.results.RetrievalResult` (:meth:`asubmit` is the
  ``asyncio`` face of the same future). A full queue REJECTS at the door
  with :class:`~repro.serve.errors.QueueOverflowError` — backpressure,
  not an unbounded queue whose tail latency lies to every client.
* **Batch forming** — arrivals group into buckets keyed by their pow2
  width bucket (and requested k). These are exactly the shape keys
  ``DeviceRetriever._pack_batch`` buckets by — the jit-cache keys — so a
  formed batch NEVER triggers a compile the warmed retriever hasn't
  already paid: micro-batching is recompile-free in steady state. A
  bucket flushes when it reaches ``max_batch`` (size flush) or when its
  oldest request has waited ``batch_deadline_s`` (deadline flush),
  whichever comes first.
* **Pipelined execution** — each formed batch runs pack -> execute on two
  single-thread stages, so the host pack of batch i+1 OVERLAPS device
  execution of batch i (the double-buffer idiom one level above the
  kernel DMAs). The pack stage is the retriever's own
  :meth:`~DeviceRetriever.pack_batch` — the same fault hook + shared
  sanitizer + pow2 pack every direct call runs — and the execute stage
  resumes ``retrieve_batch(packed=...)``, so every frontend batch walks
  the same sanitizer and exact degradation ladder as a direct call and
  results are bit-identical by construction (tier-1 asserts this).
* **SLO accounting** — ``request_timeout_s`` arms a per-request serving
  deadline, checked when its batch forms: ``on_miss="raise"`` fails the
  future with :class:`~repro.serve.errors.DeadlineExceededError`
  (carrying the wait), ``on_miss="degrade"`` (default) still serves it —
  exactly — but counts it degraded in :meth:`health`, which speaks the
  schema-2 envelope like every other serving level (see the
  ``repro.serve`` package docstring).
* **Overload protection** — ``admission_rate_qps`` / ``codel_target_s``
  arm an :class:`~repro.serve.overload.AdmissionController` in front of
  :meth:`submit`: load above the sustainable rate (token bucket) or a
  standing queue delay above the CoDel target is shed at the door with
  :class:`~repro.serve.errors.AdmissionRejectedError` (carrying
  ``retry_after_s``) BEFORE it consumes any device work, so sustained
  overload converges to bounded p99 for admitted requests instead of an
  ever-growing queue. A stage supervisor absorbs batch-former crashes:
  in-flight requests fail typed (:class:`StageFailedError`), the stage
  restarts (bounded by ``max_stage_restarts``), and a former found dead
  at submit time is restarted after failing what it stranded —
  clients never hang on a dead stage.

The front-end wraps either a :class:`DeviceRetriever` (overlap path) or
any object with a ``retrieve_batch(batch, k)`` / ``retrieve_batch(batch,
k=...)`` surface, e.g. a :class:`RetrievalEngine` (single-stage path).
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from .errors import (AdmissionRejectedError, DeadlineExceededError,
                     QueueOverflowError, StageFailedError)
from .health import health_envelope
from .overload import AdmissionController
from .results import RetrievalResult


def _faults_module():
    """The fault harness, if (and only if) something already imported it."""
    import sys
    return sys.modules.get("repro.serve.faults")


@dataclass
class _Request:
    """One admitted query waiting in the batch former."""

    q: np.ndarray
    k: int
    t_submit: float                      # monotonic admission time
    future: Future = field(default_factory=Future)
    waited_s: float = 0.0                # set at flush time


class ServingFrontend:
    """Micro-batching serving front-end (see module docstring).

    Parameters
    ----------
    retriever:
        The scorer every batch routes through. A ``pack_batch``-capable
        retriever gets the two-stage pack/execute pipeline; anything
        else (e.g. ``RetrievalEngine``) is called in one stage.
    k:
        Default top-k per request (``submit(k=...)`` overrides per call).
    max_batch:
        Size flush threshold — a bucket launches as soon as it holds
        this many requests. Keep it at or under the batch sizes the
        retriever was warmed on to stay recompile-free.
    batch_deadline_s:
        Deadline flush threshold — the longest the OLDEST request in a
        bucket waits before its batch launches regardless of size. The
        latency/throughput knob: higher forms fuller batches.
    max_queue:
        Admission cap across all buckets; :meth:`submit` raises
        :class:`QueueOverflowError` beyond it.
    request_timeout_s / on_miss:
        Optional per-request SLO, checked when the batch forms.
        ``"raise"`` fails the future with
        :class:`DeadlineExceededError`; ``"degrade"`` serves the request
        and counts it degraded.
    autostart:
        Start the former/pipeline threads in the constructor. Tests that
        want deterministic queue states pass False and call
        :meth:`start` themselves.
    record_batches:
        Keep ``(queries, k, batch_result)`` per formed batch in
        ``self.recorded`` — the bit-identity tests and the serving
        benchmark replay these against direct ``retrieve_batch`` calls.
    admission_rate_qps / admission_burst:
        Token-bucket admission gate: sustained load above this rate is
        shed at :meth:`submit` with :class:`AdmissionRejectedError`
        (``retry_after_s`` = time until a token accrues). ``None``
        (default) disables the bucket. Size it just under measured
        capacity so admitted traffic never outruns the device.
    codel_target_s / codel_interval_s:
        CoDel-style queue-delay controller: when the standing queueing
        delay of admitted requests (each batch's oldest-request age at
        execution start) sits above ``codel_target_s`` for a full
        ``codel_interval_s``, submissions are shed at the classic
        ``interval/sqrt(n)`` cadence until the delay recovers — the
        backstop for a mis-estimated bucket rate. ``None`` disables.
    max_stage_restarts:
        Crash budget for the batch-former stage supervisor: a crash
        fails the in-flight batch typed and restarts the stage; beyond
        this many restarts the frontend stops and fails everything
        pending (:class:`StageFailedError`) instead of crash-looping.
    """

    def __init__(self, retriever, *, k: int = 10, max_batch: int = 32,
                 batch_deadline_s: float = 0.002, max_queue: int = 1024,
                 request_timeout_s: float | None = None,
                 on_miss: str = "degrade", autostart: bool = True,
                 record_batches: bool = False,
                 admission_rate_qps: float | None = None,
                 admission_burst: int | None = None,
                 codel_target_s: float | None = None,
                 codel_interval_s: float = 0.1,
                 max_stage_restarts: int = 3):
        if on_miss not in ("degrade", "raise"):
            raise ValueError(f"on_miss must be 'degrade' or 'raise', "
                             f"got {on_miss!r}")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_stage_restarts < 0:
            raise ValueError("max_stage_restarts must be >= 0")
        self.retriever = retriever
        self.k = int(k)
        self.max_batch = int(max_batch)
        self.batch_deadline_s = float(batch_deadline_s)
        self.max_queue = int(max_queue)
        self.request_timeout_s = request_timeout_s
        self.on_miss = on_miss
        self.record_batches = bool(record_batches)
        self.recorded: list[tuple[list, int, RetrievalResult]] = []
        # pow2 floor of the width bucket — mirror the retriever's, so the
        # frontend's grouping key equals _pack_batch's jit-cache key
        self._q_floor = int(getattr(retriever, "q_max", 32))
        self._two_stage = hasattr(retriever, "pack_batch")

        self.max_stage_restarts = int(max_stage_restarts)
        self._admission = (AdmissionController(
            rate_qps=admission_rate_qps, burst=admission_burst,
            codel_target_s=codel_target_s,
            codel_interval_s=codel_interval_s)
            if (admission_rate_qps is not None
                or codel_target_s is not None) else None)

        self._cond = threading.Condition()
        self._buckets: dict[tuple, list[_Request]] = {}
        self._pending = 0
        self._stopping = False
        self._started = False
        self._inflight: list[_Request] | None = None   # former mid-dispatch
        # counters (under self._cond's lock)
        self._submitted = 0
        self._served = 0
        self._degraded = 0
        self._rejected = 0
        self._shed = 0
        self._aborted = 0
        self._restarts = 0
        self._deadline_missed = 0
        self._batches = 0
        self._flushes = {"size": 0, "deadline": 0, "drain": 0}
        self._fault_counters: dict[str, int] = {}

        self._former: threading.Thread | None = None
        self._pack_pool: ThreadPoolExecutor | None = None
        self._exec_pool: ThreadPoolExecutor | None = None
        if autostart:
            self.start()

    # -- lifecycle --------------------------------------------------------

    def start(self) -> None:
        """Start the former thread + the two pipeline stages (idempotent)."""
        with self._cond:
            if self._started:
                return
            self._started = True
            self._stopping = False
        self._pack_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="frontend-pack")
        self._exec_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="frontend-exec")
        self._former = threading.Thread(target=self._former_loop,
                                        name="frontend-former", daemon=True)
        self._former.start()

    def close(self, *, drain: bool = True) -> None:
        """Stop the frontend. Drain-vs-abort semantics:

        ``drain=True`` (default) stops admission, SERVES everything
        already queued (the former's drain flushes), then stops the
        threads. ``drain=False`` aborts: queued requests that have not
        reached the pipeline fail immediately with a typed
        :class:`StageFailedError` (``stage="close"``); batches already
        dispatched still complete (their device work is sunk either way).

        Either way, close() never strands a caller in ``.result()``:
        after the stages stop, any future still unresolved (e.g. the
        former crashed beyond its restart budget with requests queued)
        is failed with the same typed error.
        """
        aborted: list[_Request] = []
        with self._cond:
            self._stopping = True
            if not drain:
                aborted = [r for reqs in self._buckets.values()
                           for r in reqs]
                self._buckets.clear()
                self._pending -= len(aborted)
                self._aborted += len(aborted)
                self._count_fault("StageFailedError", n=len(aborted))
            self._cond.notify_all()
        self._fail_typed(aborted, StageFailedError(
            "request aborted: ServingFrontend.close(drain=False) shut "
            "the frontend down before this request's batch formed",
            stage="close"))
        if self._former is not None:
            self._former.join()
            self._former = None
        # pack before exec: shutdown(wait=True) drains in pipeline order
        if self._pack_pool is not None:
            self._pack_pool.shutdown(wait=True)
            self._pack_pool = None
        if self._exec_pool is not None:
            self._exec_pool.shutdown(wait=True)
            self._exec_pool = None
        with self._cond:
            # sweep: whatever is STILL queued after the stages stopped
            # was stranded (a former crash past its restart budget) —
            # fail it typed rather than leave unresolved futures
            leftovers = [r for reqs in self._buckets.values()
                         for r in reqs]
            self._buckets.clear()
            self._pending -= len(leftovers)
            self._aborted += len(leftovers)
            if leftovers:
                self._count_fault("StageFailedError", n=len(leftovers))
            self._started = False
        self._fail_typed(leftovers, StageFailedError(
            "request stranded: the batch-former stage stopped before "
            "this request's batch formed", stage="close"))

    @staticmethod
    def _fail_typed(reqs: list[_Request], exc: BaseException) -> None:
        """Resolve still-pending futures with ``exc`` (counters already
        accounted; futures the pipeline already resolved are skipped)."""
        for r in reqs:
            if r.future.done():
                continue
            if r.future.set_running_or_notify_cancel():
                r.future.set_exception(exc)

    def __enter__(self) -> "ServingFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- admission --------------------------------------------------------

    def _bucket_key(self, q: np.ndarray, k: int) -> tuple:
        from ..core.scoring import bucket_pow2
        return (bucket_pow2(max(int(q.size), 1), floor=self._q_floor),
                int(k))

    def submit(self, query_tokens, k: int | None = None) -> Future:
        """Admit one query; the future resolves to its
        :class:`RetrievalResult` row (which unpacks as ``(ids, scores)``).

        Raises synchronously — the request was never admitted and
        consumed no device work — on :class:`QueueOverflowError` (queue
        full) or :class:`AdmissionRejectedError` (the overload gate
        shed it; ``.retry_after_s`` is the backoff hint).
        """
        q = np.asarray(query_tokens).ravel()
        kk = self.k if k is None else int(k)
        req = _Request(q=q, k=kk, t_submit=time.monotonic())
        revive = False
        with self._cond:
            if self._stopping or not self._started:
                raise RuntimeError("ServingFrontend is not running "
                                   "(start() it, or submit before close())")
            if self._former is not None and not self._former.is_alive():
                # former died between supervisor restarts (budget spent
                # mid-crash, or a non-restartable exit): don't queue onto
                # a dead stage — fail what it stranded and revive it if
                # the budget allows
                revive = True
            else:
                self._admit_locked(req)
        if not revive:
            return req.future
        self._revive_former()
        with self._cond:
            if self._stopping or not self._started:
                raise RuntimeError("ServingFrontend is not running "
                                   "(the batch former died beyond its "
                                   "restart budget)")
            self._admit_locked(req)
        return req.future

    def _admit_locked(self, req: _Request) -> None:
        """The admission gate proper (caller holds ``self._cond``)."""
        pending = self._pending
        _f = _faults_module()
        if _f is not None:
            # inject an apparent queue flood: the gate sees an inflated
            # depth and sheds (typed) — the real queue is untouched
            pending = int(_f.fire("queue.flood", pending))
        if self._admission is not None:
            ra = self._admission.admit(time.monotonic(), pending)
            if ra is not None:
                self._shed += 1
                self._rejected += 1
                self._count_fault("AdmissionRejectedError")
                raise AdmissionRejectedError(
                    f"admission gate shed this request ({pending} "
                    f"pending); retry after {ra * 1e3:.1f} ms",
                    retry_after_s=ra, pending=pending)
        if pending >= self.max_queue:
            self._rejected += 1
            raise QueueOverflowError(
                f"admission queue full ({pending} pending >= "
                f"max_queue={self.max_queue})", pending=pending)
        self._submitted += 1
        self._pending += 1
        self._buckets.setdefault(self._bucket_key(req.q, req.k),
                                 []).append(req)
        self._cond.notify_all()

    def _revive_former(self) -> None:
        """Replace a dead former thread found at submit time.

        Fails every request the dead stage stranded (typed), then either
        restarts the stage (budget permitting) or marks the frontend
        stopped so subsequent submits raise instead of hanging.
        """
        with self._cond:
            if self._former is not None and self._former.is_alive():
                return                       # raced with another reviver
            stranded = [r for reqs in self._buckets.values() for r in reqs]
            self._buckets.clear()
            self._pending -= len(stranded)
            if stranded:
                self._count_fault("StageFailedError", n=len(stranded))
            out_of_budget = self._restarts >= self.max_stage_restarts
            if out_of_budget:
                self._stopping = True
                self._started = False
            else:
                self._restarts += 1
        self._fail_typed(stranded, StageFailedError(
            "request stranded: the batch-former thread died before this "
            "request's batch formed", stage="former"))
        if out_of_budget:
            return
        former = threading.Thread(target=self._former_loop,
                                  name="frontend-former", daemon=True)
        with self._cond:
            self._former = former
        former.start()

    async def asubmit(self, query_tokens, k: int | None = None
                      ) -> RetrievalResult:
        """``await``-able :meth:`submit` (asyncio face of the same future)."""
        import asyncio
        return await asyncio.wrap_future(self.submit(query_tokens, k=k))

    # -- batch forming ----------------------------------------------------

    def _pick_flush(self, now: float):
        """(key, reason) of the ripest bucket, or None if nothing's ripe."""
        for key, reqs in self._buckets.items():
            if len(reqs) >= self.max_batch:
                return key, "size"
        for key, reqs in self._buckets.items():
            if reqs and now - reqs[0].t_submit >= self.batch_deadline_s:
                return key, "deadline"
        if self._stopping:
            for key, reqs in self._buckets.items():
                if reqs:
                    return key, "drain"
        return None

    def _next_wait(self, now: float) -> float | None:
        """Seconds until the earliest deadline flush (None: sleep forever)."""
        oldest = [reqs[0].t_submit for reqs in self._buckets.values()
                  if reqs]
        if not oldest:
            return None
        return max(min(oldest) + self.batch_deadline_s - now, 0.0)

    def _former_loop(self) -> None:
        """Supervised former stage: crashes fail the in-flight batch
        typed and restart the iteration, bounded by
        ``max_stage_restarts`` — a crash-looping former stops the
        frontend instead of spinning."""
        while True:
            try:
                if self._former_step():
                    return
            except BaseException as e:      # noqa: BLE001 — supervisor
                if self._supervise_former(e):
                    return

    def _former_step(self) -> bool:
        """One former iteration; True = clean exit (stopping + drained)."""
        _f = _faults_module()
        if _f is not None:
            with _f.guard():
                # thread-death injection point: nothing is in flight at
                # the top of the iteration, so supervisor recovery is
                # exact — queued requests just ride the next iteration
                _f.fire("frontend.former", None)
        with self._cond:
            while True:
                now = time.monotonic()
                pick = self._pick_flush(now)
                if pick is not None:
                    break
                if self._stopping:
                    return True
                self._cond.wait(timeout=self._next_wait(now))
            key, reason = pick
            whole = self._buckets.pop(key)
            reqs, tail = whole[:self.max_batch], whole[self.max_batch:]
            if tail:
                # burst admitted between flushes: the overflow stays
                # queued as the bucket's next generation
                self._buckets[key] = tail
            self._flushes[reason] += 1
            self._batches += 1
            self._inflight = reqs
        try:
            self._dispatch(reqs, key[1], now)
        finally:
            with self._cond:
                self._inflight = None
        return False

    def _supervise_former(self, exc: BaseException) -> bool:
        """Absorb one former crash; True = the loop should exit.

        The in-flight batch (if the crash hit mid-dispatch) fails typed;
        within budget the loop just continues (the stage logically
        restarts in place); beyond it everything pending fails typed and
        the frontend stops.
        """
        with self._cond:
            inflight = self._inflight or []
            self._inflight = None
            victims = [r for r in inflight if not r.future.done()]
            self._pending -= len(victims)
            if victims:
                self._count_fault("StageFailedError", n=len(victims))
            out_of_budget = self._restarts >= self.max_stage_restarts
            if out_of_budget:
                stranded = [r for reqs in self._buckets.values()
                            for r in reqs]
                self._buckets.clear()
                self._pending -= len(stranded)
                if stranded:
                    self._count_fault("StageFailedError", n=len(stranded))
                self._stopping = True
                self._started = False
            else:
                stranded = []
                self._restarts += 1
            self._cond.notify_all()
        self._fail_typed(victims, StageFailedError(
            f"batch was in flight when the former stage crashed "
            f"({type(exc).__name__}: {exc})", stage="former"))
        self._fail_typed(stranded, StageFailedError(
            f"request stranded: the former stage exhausted its restart "
            f"budget (max_stage_restarts={self.max_stage_restarts}) on "
            f"{type(exc).__name__}: {exc}", stage="former"))
        return out_of_budget

    def _dispatch(self, reqs: list[_Request], kk: int, t_flush: float
                  ) -> None:
        """SLO-check a formed batch, then hand it to the pipeline."""
        live = []
        for r in reqs:
            r.waited_s = t_flush - r.t_submit
            missed = (self.request_timeout_s is not None
                      and r.waited_s > self.request_timeout_s)
            if missed and self.on_miss == "raise":
                with self._cond:
                    self._deadline_missed += 1
                    self._pending -= 1
                    self._count_fault("DeadlineExceededError")
                if r.future.set_running_or_notify_cancel():
                    r.future.set_exception(DeadlineExceededError(
                        f"request waited {r.waited_s * 1e3:.2f} ms > "
                        f"timeout {self.request_timeout_s * 1e3:.2f} ms "
                        f"before its micro-batch launched",
                        waited_s=r.waited_s))
                continue
            if missed:
                with self._cond:
                    self._deadline_missed += 1
            live.append(r)
        if not live:
            return
        if self._two_stage:
            self._pack_pool.submit(self._pack_stage, live, kk)
        else:
            self._exec_pool.submit(self._exec_stage, live, kk, None)

    # -- pipeline stages --------------------------------------------------

    def _pack_stage(self, reqs: list[_Request], kk: int) -> None:
        """Host pack (stage 1) — overlaps the previous batch's execute."""
        try:
            packed = self.retriever.pack_batch([r.q for r in reqs])
        except BaseException as e:
            self._fail(reqs, e)
            return
        self._exec_pool.submit(self._exec_stage, reqs, kk, packed)

    def _exec_stage(self, reqs: list[_Request], kk: int, packed) -> None:
        """Device execute (stage 2) + per-request future resolution."""
        if self._admission is not None and reqs:
            # CoDel input: this batch's oldest-request age at execution
            # start IS the standing queueing delay (the exec-pool queue
            # is the real backlog under overload, not the former's)
            now = time.monotonic()
            with self._cond:
                self._admission.observe(
                    now - min(r.t_submit for r in reqs), now)
        try:
            if packed is not None:
                res = self.retriever.retrieve_batch(None, kk,
                                                    packed=packed)
            else:
                res = self.retriever.retrieve_batch([r.q for r in reqs],
                                                    k=kk)
        except BaseException as e:
            self._fail(reqs, e)
            return
        if self.record_batches:
            self.recorded.append(([r.q for r in reqs], kk, res))
        t_done = time.monotonic()
        batch_degraded = bool(getattr(res, "degraded", False))
        for i, r in enumerate(reqs):
            missed = (self.request_timeout_s is not None
                      and r.waited_s > self.request_timeout_s)
            row = RetrievalResult(
                ids=res.ids[i], scores=res.scores[i],
                plan=getattr(res, "plan", None),
                degradations=list(getattr(res, "degradations", [])),
                degraded=batch_degraded or missed,
                shards_answered=getattr(res, "shards_answered", None),
                latency_s=t_done - r.t_submit,
                timings={**getattr(res, "timings", {}),
                         "queue_s": r.waited_s,
                         "total_s": t_done - r.t_submit})
            with self._cond:
                self._pending -= 1
                self._served += 1
                if row.degraded:
                    self._degraded += 1
            if not r.future.set_running_or_notify_cancel():
                continue                 # client cancelled while queued
            r.future.set_result(row)

    def _fail(self, reqs: list[_Request], exc: BaseException) -> None:
        with self._cond:
            self._pending -= len(reqs)
            self._count_fault(type(exc).__name__, n=len(reqs))
        for r in reqs:
            if r.future.set_running_or_notify_cancel():
                r.future.set_exception(exc)

    def _count_fault(self, name: str, n: int = 1) -> None:
        self._fault_counters[name] = self._fault_counters.get(name, 0) + n

    # -- observability ----------------------------------------------------

    def health(self) -> dict:
        """Schema-2 health report (see ``repro.serve`` package docstring).

        ``served``/``degraded`` count client REQUESTS (a degraded request
        either rode a ladder-hopped batch or missed its SLO under
        ``on_miss="degrade"``; both are still exact). Frontend extras:
        ``pending``/``submitted``/``rejected``/``deadline_missed``,
        ``batches`` + per-reason ``flushes``, mean formed-batch size, the
        batching knobs, overload counters (``shed`` requests the
        admission gate refused — also counted in ``rejected`` —
        ``aborted`` futures failed by close/crash sweeps, ``restarts``
        of the former stage, and the gate's ``admission`` snapshot), and
        the wrapped retriever's own report under ``retriever``.
        """
        with self._cond:
            batches = self._batches
            stats = dict(
                pending=self._pending, submitted=self._submitted,
                rejected=self._rejected,
                deadline_missed=self._deadline_missed,
                batches=batches, flushes=dict(self._flushes),
                served=self._served, degraded=self._degraded,
                shed=self._shed, aborted=self._aborted,
                restarts=self._restarts,
                admission=(self._admission.snapshot()
                           if self._admission is not None else {}),
                faults=dict(self._fault_counters))
        sub = (self.retriever.health()
               if hasattr(self.retriever, "health") else {})
        return health_envelope(
            served=stats["served"], degraded=stats["degraded"],
            faults=stats["faults"],
            queries=dict(getattr(self.retriever, "query_counters", {})),
            pending=stats["pending"], submitted=stats["submitted"],
            rejected=stats["rejected"],
            deadline_missed=stats["deadline_missed"],
            batches=stats["batches"],
            flushes=stats["flushes"],
            mean_batch=(stats["served"] / batches if batches else 0.0),
            max_batch=self.max_batch,
            batch_deadline_s=self.batch_deadline_s,
            shed=stats["shed"], aborted=stats["aborted"],
            restarts=stats["restarts"], admission=stats["admission"],
            retriever=sub,
        )


__all__ = ["ServingFrontend"]
