"""End-to-end dry-run machinery on an 8-device mesh (subprocess): lower,
compile, memory/cost analysis, collective parsing, roofline record."""

import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    # pin the cpu backend BEFORE importing jax: the stripped subprocess env
    # drops the parent's JAX_PLATFORMS, and letting jax probe for TPU
    # hardware stalls startup by minutes on CPU-only hosts
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs import get_cells
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_mesh_from

    mesh = make_mesh_from(jax.devices())
    out = {}
    for arch, shape in [("egnn", "molecule"), ("sasrec", "serve_p99"),
                        ("mind", "retrieval_cand")]:
        cell = [c for c in get_cells(arch) if c.shape == shape][0]
        rec = run_cell(cell, mesh, verbose=False)
        out[f"{arch}/{shape}"] = {
            "ok": rec["ok"],
            "bottleneck": rec["bottleneck"],
            "has_terms": all(k in rec for k in
                             ("compute_s", "memory_s", "collective_s")),
            "flops_positive": rec["hlo_flops_per_device"] > 0,
        }
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def results():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=600, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


@pytest.mark.parametrize("key", ["egnn/molecule", "sasrec/serve_p99",
                                 "mind/retrieval_cand"])
def test_cell_compiles_and_produces_roofline(results, key):
    r = results[key]
    assert r["ok"] and r["has_terms"] and r["flops_positive"]
    assert r["bottleneck"] in ("compute", "memory", "collective")
