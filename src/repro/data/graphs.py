"""Procedural graphs + a real neighbor sampler (GNN data pipeline).

``neighbor_sample`` implements GraphSAGE-style layered fanout sampling over
a CSR adjacency — the ``minibatch_lg`` shape requires it. Output shapes are
STATIC (padded with -1 edges / repeated nodes) so the jitted train step
never recompiles across batches.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Graph:
    n_nodes: int
    edges: np.ndarray          # [E, 2] int64 (src, dst)
    node_feat: np.ndarray      # [N, F] float32
    coords: np.ndarray         # [N, 3] float32
    labels: np.ndarray         # [N] int64

    def csr(self) -> tuple[np.ndarray, np.ndarray]:
        """In-neighbor CSR: (indptr [N+1], src indices [E]) keyed by dst."""
        order = np.argsort(self.edges[:, 1], kind="stable")
        dst_sorted = self.edges[order, 1]
        src_sorted = self.edges[order, 0]
        indptr = np.zeros(self.n_nodes + 1, dtype=np.int64)
        np.add.at(indptr, dst_sorted + 1, 1)
        np.cumsum(indptr, out=indptr)
        return indptr, src_sorted


def random_graph(n_nodes: int, avg_degree: int, *, d_feat: int,
                 n_classes: int, seed: int = 0) -> Graph:
    """Power-lawish random graph with feature-correlated labels."""
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    # preferential-attachment-flavored endpoints (power-law in-degree)
    dst = (n_nodes * rng.power(3.0, n_edges)).astype(np.int64) % n_nodes
    src = rng.integers(0, n_nodes, size=n_edges)
    labels = rng.integers(0, n_classes, size=n_nodes)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feat = centers[labels] + rng.normal(
        scale=2.0, size=(n_nodes, d_feat)).astype(np.float32)
    coords = rng.normal(size=(n_nodes, 3)).astype(np.float32)
    return Graph(n_nodes, np.stack([src, dst], 1), feat, coords, labels)


def neighbor_sample(graph: Graph, seeds: np.ndarray, fanouts: tuple[int, ...],
                    *, rng: np.random.Generator) -> dict:
    """Layered fanout sampling -> fixed-shape padded subgraph batch.

    Returns arrays sized for the WORST case (seeds · Π fanouts) regardless
    of actual neighborhood sizes: node_feat/coords [n_max, F], edges
    [e_max, 2] (-1 padded), labels [n_max] with -1 for non-seed nodes.
    """
    indptr, src_idx = graph.csr()
    n_per_layer = [len(seeds)]
    for f in fanouts:
        n_per_layer.append(n_per_layer[-1] * f)
    n_max = sum(n_per_layer)
    e_max = sum(n_per_layer[1:])

    local_of = {int(n): i for i, n in enumerate(seeds)}
    nodes = list(seeds)
    edges = []
    frontier = list(seeds)
    for f in fanouts:
        nxt = []
        for u in frontier:
            lo, hi = int(indptr[u]), int(indptr[u + 1])
            if hi == lo:
                continue
            k = min(f, hi - lo)
            picks = rng.choice(src_idx[lo:hi], size=k, replace=False)
            for v in picks:
                v = int(v)
                if v not in local_of:
                    local_of[v] = len(nodes)
                    nodes.append(v)
                edges.append((local_of[v], local_of[int(u)]))   # src -> dst
            nxt.extend(int(p) for p in picks)
        frontier = nxt

    nodes = np.asarray(nodes, dtype=np.int64)
    feat = np.zeros((n_max, graph.node_feat.shape[1]), np.float32)
    coords = np.zeros((n_max, 3), np.float32)
    feat[: nodes.size] = graph.node_feat[nodes]
    coords[: nodes.size] = graph.coords[nodes]
    labels = np.full(n_max, -1, dtype=np.int32)
    labels[: len(seeds)] = graph.labels[seeds]
    e = np.full((e_max, 2), -1, dtype=np.int32)
    if edges:
        e[: len(edges)] = np.asarray(edges, dtype=np.int32)
    return {"node_feat": feat, "coords": coords, "edges": e,
            "labels": labels}


def batched_molecules(n_graphs: int, *, n_nodes: int = 30, n_edges: int = 64,
                      d_feat: int = 11, seed: int = 0) -> dict:
    """Flatten a batch of small molecule-like graphs + regression targets.

    Target = a smooth function of geometry (sum of pairwise 1/r over edges)
    so the EGNN objective is learnable and rotation-invariant.
    """
    rng = np.random.default_rng(seed)
    feat = rng.normal(size=(n_graphs * n_nodes, d_feat)).astype(np.float32)
    coords = rng.normal(size=(n_graphs * n_nodes, 3)).astype(np.float32)
    edges = []
    targets = np.zeros((n_graphs, 1), np.float32)
    for g in range(n_graphs):
        off = g * n_nodes
        src = rng.integers(0, n_nodes, size=n_edges)
        dst = (src + 1 + rng.integers(0, n_nodes - 1, size=n_edges)) % n_nodes
        edges.append(np.stack([src + off, dst + off], 1))
        d = np.linalg.norm(coords[src + off] - coords[dst + off], axis=1)
        targets[g, 0] = float((1.0 / (1.0 + d)).sum())
    graph_ids = np.repeat(np.arange(n_graphs, dtype=np.int32), n_nodes)
    return {"node_feat": feat, "coords": coords,
            "edges": np.concatenate(edges).astype(np.int32),
            "graph_ids": graph_ids, "n_graphs": n_graphs,
            "targets": targets}
