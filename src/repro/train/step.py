"""Train-step builder: microbatched grad accumulation + AdamW update.

``make_train_step`` turns a per-example ``loss_fn(params, batch)`` into the
jit-able production step:

    grads = (1/M) Σ_m grad(loss_fn)(params, microbatch_m)     (lax.scan)
    params, opt = adamw.update(clip(grads), opt, params)

Microbatch accumulation bounds activation memory (peak = one microbatch's
activations + a params-shaped fp32 accumulator); the scan keeps HLO size
independent of M. Optional int8 gradient compression with error feedback
sits between accumulation and the optimizer.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .grad_compress import compress_grads
from .optimizer import AdamW


def _split_microbatches(batch: dict, n: int) -> dict:
    return jax.tree.map(
        lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)


def make_train_step(loss_fn: Callable, optimizer: AdamW, *,
                    n_microbatches: int = 1,
                    compress: bool = False) -> Callable:
    """Returns ``train_step(params, opt_state, batch) -> (params, opt, metrics)``.

    ``opt_state`` carries {"m","v","step"} and, when ``compress``, an "ef"
    error-feedback pytree.
    """

    def train_step(params, opt_state, batch):
        if n_microbatches > 1:
            mbs = _split_microbatches(batch, n_microbatches)

            def body(acc, mb):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return acc, loss

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(body, zeros, mbs)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            loss = losses.mean()
        else:
            (loss, _metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        if compress:
            grads, ef = compress_grads(grads, opt_state["ef"])

        new_params, new_opt, om = optimizer.update(
            grads, {k: opt_state[k] for k in ("m", "v", "step")}, params)
        if compress:
            new_opt["ef"] = ef
        metrics = {"loss": loss, **om}
        return new_params, new_opt, metrics

    return train_step


def init_train_state(params, optimizer: AdamW, *, compress: bool = False):
    state = optimizer.init(params)
    if compress:
        from .grad_compress import init_error_feedback
        state["ef"] = init_error_feedback(params)
    return state
