"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \\
        --steps 50 --ckpt-dir /tmp/ckpt

Selects the architecture from the registry (``--arch``), builds the mesh
over whatever devices exist (elastic: ``make_mesh_from``), applies the
family's sharding rules, and runs the fault-tolerant loop with
checkpoint/auto-resume. ``--smoke`` swaps in the reduced config so the
same launcher runs on 1 CPU (CI) and a pod (TPU) unchanged.
"""

from __future__ import annotations

import argparse
import functools


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression w/ error feedback")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from ..configs import get_module, get_smoke, get_config
    from ..dist.sharding import activation_sharding
    from ..train import AdamW, cosine_schedule, init_train_state, \
        make_train_step
    from ..train.loop import LoopConfig, run_training
    from .mesh import make_mesh_from

    mod = get_module(args.arch)
    family = mod.FAMILY
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_mesh_from(jax.devices())
    print(f"[train] arch={args.arch} family={family} "
          f"mesh={dict(mesh.shape)} smoke={args.smoke}")

    opt = AdamW(lr=cosine_schedule(peak_lr=args.lr, warmup_steps=20,
                                   total_steps=args.steps))

    if family == "lm":
        from ..data.lm import lm_batches
        from ..models import transformer
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = functools.partial(transformer.loss_fn, cfg)
        gen = lm_batches(vocab_size=cfg.vocab_size, batch=args.batch,
                         seq_len=args.seq_len)
    elif family == "recsys":
        from ..data.clicklogs import ctr_batches, seq_rec_batches
        from ..models import recsys
        params = recsys.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = functools.partial(recsys.loss_fn, cfg)
        if cfg.model in ("dlrm", "autoint"):
            gen = ctr_batches(vocab_sizes=cfg.vocab_sizes,
                              n_dense=cfg.n_dense, batch=args.batch)
        else:
            gen = seq_rec_batches(n_items=cfg.vocab_sizes[0],
                                  seq_len=cfg.seq_len, batch=args.batch,
                                  per_position=cfg.model == "sasrec")
    elif family == "gnn":
        from ..data.graphs import random_graph
        from ..models import egnn
        params = egnn.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = functools.partial(egnn.loss_fn, cfg)
        g = random_graph(200, 6, d_feat=cfg.d_feat, n_classes=cfg.n_out)

        def _gen():
            batch = {"node_feat": g.node_feat, "coords": g.coords,
                     "edges": g.edges.astype("int32"),
                     "labels": g.labels.astype("int32")}
            while True:
                yield batch
        gen = _gen()
    else:
        raise SystemExit(f"--arch {args.arch} is not trainable "
                         f"(family={family}); use launch/serve.py")

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {n_params / 1e6:.2f}M params")
    step = make_train_step(loss_fn, opt, n_microbatches=args.microbatches,
                           compress=args.compress)
    state = init_train_state(params, opt, compress=args.compress)
    batches = (jax.tree.map(jnp.asarray, b) for b in gen)

    def log(s, m):
        print(f"[train] step {s:5d} loss {m['loss']:.4f} "
              f"lr {m.get('lr', 0):.2e}", flush=True)

    loop = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, metrics_cb=log, log_every=10)
    with mesh, activation_sharding(mesh):
        run_training(jax.jit(step), (params, state), batches, loop)
    print("[train] done")


if __name__ == "__main__":
    main()
