"""BM25S tokenizer: scikit-learn regex split + stopwords + Snowball stemming.

Faithful to §2 of the paper:

* splitting uses the exact scikit-learn ``CountVectorizer`` token pattern
  ``r"(?u)\\b\\w\\w+\\b"``;
* optional stopword removal (Elastic English list);
* optional Snowball stemming, applied to the *vocabulary* ("we can stem all
  words in the vocabulary, which can be used to look up the stemmed version
  of each word in the collection") — i.e. each unique surface form is stemmed
  once and occurrences are mapped through a dict;
* finally each (stemmed) unique word maps to an integer id, so documents and
  queries become ``int32`` arrays usable to index score matrices.

Everything here is host-side NumPy/Python — devices only ever see the ids.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

from .stemmer import snowball_stem
from .stopwords import get_stopwords

TOKEN_PATTERN = re.compile(r"(?u)\b\w\w+\b")


@dataclass
class Vocabulary:
    """Bidirectional word<->id mapping over (optionally stemmed) word forms."""

    word_to_id: dict[str, int] = field(default_factory=dict)
    frozen: bool = False

    def lookup(self, word: str) -> int:
        """Return id for ``word``, adding it if the vocab is not frozen."""
        wid = self.word_to_id.get(word, -1)
        if wid < 0 and not self.frozen:
            wid = len(self.word_to_id)
            self.word_to_id[word] = wid
        return wid

    def __len__(self) -> int:
        return len(self.word_to_id)

    @property
    def id_to_word(self) -> list[str]:
        out = [""] * len(self.word_to_id)
        for w, i in self.word_to_id.items():
            out[i] = w
        return out


@dataclass
class Tokenizer:
    """Configurable BM25S analyzer.

    Parameters mirror the paper's Table 2 ablation axes: ``stopwords`` in
    {"english", None} and ``stemmer`` in {"snowball", None}.
    """

    stopwords: str | None = "english"
    stemmer: str | None = "snowball"
    lower: bool = True

    def __post_init__(self) -> None:
        self._stop = get_stopwords(self.stopwords)
        self._stem_cache: dict[str, str] = {}
        self.vocab = Vocabulary()

    # -- single text ---------------------------------------------------------
    def split(self, text: str) -> list[str]:
        if self.lower:
            text = text.lower()
        return TOKEN_PATTERN.findall(text)

    def _stem(self, word: str) -> str:
        stemmed = self._stem_cache.get(word)
        if stemmed is None:
            stemmed = snowball_stem(word)
            self._stem_cache[word] = stemmed
        return stemmed

    def tokenize_words(self, text: str) -> list[str]:
        words = [w for w in self.split(text) if w not in self._stop]
        if self.stemmer is not None:
            words = [self._stem(w) for w in words]
        return words

    def tokenize_ids(self, text: str, *, update_vocab: bool = True) -> np.ndarray:
        """Tokenize to int32 ids. Unknown words map to -1 when vocab frozen."""
        was_frozen = self.vocab.frozen
        if not update_vocab:
            self.vocab.frozen = True
        try:
            ids = [self.vocab.lookup(w) for w in self.tokenize_words(text)]
        finally:
            self.vocab.frozen = was_frozen
        ids = [i for i in ids if i >= 0]
        return np.asarray(ids, dtype=np.int32)

    # -- corpus --------------------------------------------------------------
    def _tokenize_batch(self, texts: Sequence[str], *, update_vocab: bool
                        ) -> list[np.ndarray]:
        """One vectorized pass over a batch of texts.

        The hot loop of indexing. The per-TOKEN Python work of the
        sequential path (a stopword set probe, a stem-cache probe and a
        vocab dict probe per occurrence, plus per-token interpreter
        overhead) collapses to exactly ONE ``dict.setdefault`` per
        occurrence: the flattened word stream is factorized into distinct
        surface forms in first-occurrence order, the stopword / stemmer /
        vocabulary pipeline runs once per DISTINCT form into an id lookup
        array, and the whole batch's ids come back as one array gather
        ``lut[slots]`` — Zipf word distributions make distinct forms a
        small fraction of occurrences, which is where the speedup comes
        from (measured in ``benchmarks/tokenization.py``).

        Identical output to the sequential path, including vocabulary id
        ASSIGNMENT ORDER: distinct forms are processed in first-occurrence
        order, so a stem's id is assigned at the first occurrence of its
        earliest surface form — exactly when the per-token loop would
        have assigned it.
        """
        words_per_doc = [self.split(t) for t in texts]
        lens = np.fromiter((len(w) for w in words_per_doc), dtype=np.int64,
                           count=len(words_per_doc))
        total = int(lens.sum())
        if total == 0:
            return [np.zeros(0, dtype=np.int32) for _ in words_per_doc]
        slot_of: dict[str, int] = {}
        new_slot = slot_of.setdefault
        slots = np.fromiter(
            (new_slot(w, len(slot_of)) for ws in words_per_doc
             for w in ws),
            dtype=np.int64, count=total)
        lut = np.empty(len(slot_of), dtype=np.int32)
        was_frozen = self.vocab.frozen
        if not update_vocab:
            self.vocab.frozen = True
        try:
            for w, j in slot_of.items():          # first-occurrence order
                if w in self._stop:
                    lut[j] = -1
                    continue
                if self.stemmer is not None:
                    w = self._stem(w)
                lut[j] = self.vocab.lookup(w)
        finally:
            self.vocab.frozen = was_frozen
        ids = lut[slots]
        return [seg[seg >= 0].astype(np.int32)
                for seg in np.split(ids, np.cumsum(lens)[:-1])]

    def tokenize_corpus(self, texts: Iterable[str]) -> list[np.ndarray]:
        """Tokenize a corpus, growing the vocabulary (vectorized pass)."""
        return self._tokenize_batch(list(texts), update_vocab=True)

    def _tokenize_corpus_loop(self, texts: Iterable[str]
                              ) -> list[np.ndarray]:
        """The per-token sequential path — kept as the equivalence oracle
        for ``tokenize_corpus`` and the benchmark baseline."""
        return [self.tokenize_ids(t, update_vocab=True) for t in texts]

    def tokenize_queries(self, texts: Sequence[str]) -> list[np.ndarray]:
        """Tokenize queries against the frozen corpus vocabulary.

        Out-of-vocabulary query words are dropped: they cannot match any
        document, so their score contribution is exactly zero for the sparse
        variants, and they contribute only the query-constant ``S⁰`` shift
        for the shifted variants (handled by the retriever).
        """
        return self._tokenize_batch(list(texts), update_vocab=False)

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)
