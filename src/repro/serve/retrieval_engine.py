"""Batched retrieval serving with shard hedging, deadlines and elasticity.

The paper's §2 "Multi-threading" uses pooled executors for retrieval
speedup; at pod scale the same executor pattern becomes the scatter-gather
layer over document shards, and the operational concerns become:

* stragglers — the global merge proceeds once a QUORUM of shard top-k lists
  has arrived by the deadline; late shards are dropped from that response
  (recorded as ``degraded``) instead of stalling the tail latency. Because
  per-shard top-k is a superset property, a missed shard can only remove
  candidates it owns — results from responsive shards stay exact.
* elasticity — ``rescale(n_shards)`` re-buckets the postings (pure host
  re-slicing, ``core.index.reshard_index``) when the pool grows/shrinks.

* device offload — each ``ShardRuntime`` scores either host-side
  (``scorer="scipy"``, the paper's CSC slice+sum) or through the fused
  Pallas score→top-k pipeline (``scorer="blocked"``,
  :class:`BlockedRetriever`): postings are re-blocked once at runtime
  build, and every query runs gather→accumulate→per-block-top-k→merge on
  device without materializing the dense score vector.

``ShardRuntime`` is process-local here (threads simulate shard servers; a
``delay`` hook lets tests inject stragglers), but the engine logic —
quorum, deadline, merge, re-shard — is exactly the production control
plane.
"""

from __future__ import annotations

import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from ..core.index import BM25Index, reshard_index
from ..core.reference import ScipyBM25
from ..core.retrieval import merge_topk


class BlockedRetriever:
    """Fused-kernel scorer for one shard (drop-in for :class:`ScipyBM25`).

    Blocks the shard's postings once (``sparse.block_csr``) and serves
    ``retrieve`` via ``kernels.ops.bm25_retrieve_blocked``: the dense
    per-document score vector never exists anywhere — scores stream from
    the posting tiles into a VMEM accumulator and leave as ``[k]`` winners.
    """

    def __init__(self, index: BM25Index, *, block_size: int = 512,
                 tile: int = 512, q_max: int = 32):
        import jax.numpy as jnp

        from ..sparse.block_csr import block_postings_from_index
        self.index = index
        self.q_max = q_max                       # bucket floor, not a cap
        self.n_docs = int(index.doc_lens.size)
        bp = block_postings_from_index(index, block_size=block_size,
                                       tile=tile)
        self.block_size = bp.block_size
        self.tile_p = min(tile, bp.nnz_pad)
        self._tok = jnp.asarray(bp.token_ids)
        self._loc = jnp.asarray(bp.local_doc)
        self._sc = jnp.asarray(bp.scores)

    def retrieve(self, query_tokens: np.ndarray, k: int
                 ) -> tuple[np.ndarray, np.ndarray]:
        import jax.numpy as jnp

        from ..core.scoring import pad_queries
        from ..kernels import ops
        from ..sparse.block_csr import (pack_query_batch,
                                        query_nonoccurrence_shift)
        if self.n_docs == 0 or k <= 0:           # empty shard post-rescale
            return (np.zeros(0, dtype=np.int64), np.zeros(0, np.float32))
        query_tokens = np.asarray(query_tokens)
        # size the unique-token table to THIS query (bucketed to limit
        # recompiles) — a fixed q_max would silently truncate long queries
        # to their highest-count tokens, unlike the exact scipy scorer.
        n_uniq = np.unique(query_tokens[query_tokens >= 0]).size
        q_max = max(self.q_max, -(-max(n_uniq, 1) // 32) * 32)
        toks, wts = pad_queries([query_tokens], q_max)
        uniq, weights = pack_query_batch(toks, wts, u_max=q_max)
        shift = query_nonoccurrence_shift(self.index.nonoccurrence, toks,
                                          wts)
        ids, vals = ops.bm25_retrieve_blocked(
            self._tok, self._loc, self._sc, jnp.asarray(uniq),
            jnp.asarray(weights), jnp.asarray(shift),
            block_size=self.block_size, n_docs=self.n_docs,
            k=min(k, self.n_docs), tile_p=self.tile_p)
        return (np.asarray(ids[0]).astype(np.int64)
                + self.index.doc_offset, np.asarray(vals[0]))


_SCORERS = {"scipy": ScipyBM25, "blocked": BlockedRetriever}


@dataclass
class ShardRuntime:
    """One shard's scorer (thread-simulated shard server)."""

    index: BM25Index
    delay: Callable[[], float] | None = None     # test hook: seconds to sleep
    scorer: str = "scipy"                        # "scipy" | "blocked"

    def __post_init__(self):
        if self.scorer not in _SCORERS:
            raise ValueError(f"unknown scorer {self.scorer!r}; "
                             f"available: {sorted(_SCORERS)}")
        self._scorer = _SCORERS[self.scorer](self.index)

    def topk(self, query_tokens: np.ndarray, k: int
             ) -> tuple[np.ndarray, np.ndarray]:
        if self.delay is not None:
            time.sleep(self.delay())
        return self._scorer.retrieve(query_tokens, k)


@dataclass
class RetrievalResult:
    ids: np.ndarray
    scores: np.ndarray
    degraded: bool
    shards_answered: int
    latency_s: float


class RetrievalEngine:
    def __init__(self, shards: Sequence[BM25Index], *, k: int = 10,
                 deadline_s: float = 0.5, quorum: float = 0.75,
                 max_workers: int = 8,
                 delay: Callable[[int], Callable[[], float] | None] = None,
                 scorer: str = "scipy"):
        self.k = k
        self.deadline_s = deadline_s
        self.quorum = quorum
        self.scorer = scorer
        self._delay_factory = delay
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._build_runtimes(list(shards))

    def _build_runtimes(self, shards: list[BM25Index]) -> None:
        self.shards = shards
        self.runtimes = [
            ShardRuntime(s, delay=self._delay_factory(i)
                         if self._delay_factory else None,
                         scorer=self.scorer)
            for i, s in enumerate(shards)
        ]

    # -- control plane ------------------------------------------------------
    def rescale(self, n_shards: int) -> None:
        """Elastic re-shard (device pool grew or shrank)."""
        self._build_runtimes(reshard_index(self.shards, n_shards))

    # -- data plane ----------------------------------------------------------
    def retrieve(self, query_tokens: np.ndarray, *, k: int | None = None
                 ) -> RetrievalResult:
        k = k or self.k
        t0 = time.time()
        futures = {
            self._pool.submit(rt.topk, query_tokens, k): i
            for i, rt in enumerate(self.runtimes)
        }
        need = max(1, int(np.ceil(self.quorum * len(self.runtimes))))
        done: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        pending = set(futures)
        deadline = t0 + self.deadline_s
        while pending:
            timeout = deadline - time.time()
            if timeout <= 0 and len(done) >= need:
                break                     # quorum met, deadline passed
            finished, pending = wait(
                pending, timeout=max(timeout, 0.005),
                return_when=FIRST_COMPLETED)
            for f in finished:
                done[futures[f]] = f.result()
            if not finished and len(done) >= need:
                break
        for f in pending:                 # backfill continues off-path
            f.cancel()
        ids, scores = self._merge(done.values(), k)
        return RetrievalResult(
            ids=ids, scores=scores,
            degraded=len(done) < len(self.runtimes),
            shards_answered=len(done), latency_s=time.time() - t0)

    @staticmethod
    def _merge(parts, k: int) -> tuple[np.ndarray, np.ndarray]:
        # stage-2 of the paper's two-stage top-k, vectorized in
        # core.retrieval.merge_topk (concatenate + argpartition).
        return merge_topk(parts, k)
