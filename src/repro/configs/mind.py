"""MIND [arXiv:1904.08030]: multi-interest capsule routing retrieval.

embed_dim=64, 4 interest capsules, 3 dynamic-routing iterations,
label-aware attention. Item catalog 2^20 (retrieval_cand scores the full
catalog with the max-over-interests dot).
"""

from ..models.recsys import RecsysConfig, reduced
from .common import recsys_cells

CONFIG = RecsysConfig(
    name="mind", model="mind",
    vocab_sizes=(1_048_576,), embed_dim=64,
    n_interests=4, capsule_iters=3, seq_len=50,
)

SMOKE = reduced(CONFIG)

FAMILY = "recsys"


def cells():
    return recsys_cells("mind", CONFIG)
