"""Shared fixtures. NOTE: device count stays 1 here (the 512-device flag is
set ONLY inside launch/dryrun.py); multi-device tests spawn subprocesses or
use mesh-of-one."""

import os
import zlib

import numpy as np
import pytest

# ``hypothesis`` is an optional dev dependency (declared in pyproject.toml's
# ``test`` extra). When absent, property tests skip instead of breaking
# collection: import ``given``/``settings``/``st`` from here, not hypothesis.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (pip install '.[test]')")(f)

    def settings(*a, **k):
        return lambda f: f


def pytest_addoption(parser):
    parser.addoption(
        "--chaos", action="store_true", default=False,
        help="arm one deterministic guarded fault per test module "
             "(seed from $CHAOS_SEED; exact-recovery fault kinds only, so "
             "every test must STILL pass — that is the ladder's contract)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "no_chaos: exempt from --chaos fault arming (module arms its own "
        "faults, or asserts transfer/plan counters that a ladder hop "
        "legitimately changes)")


# The chaos pools hold ONLY faults the recovery machinery undoes exactly.
# "ladder" (default): residency / overflow / poisoned boards, healed by the
# retriever's degradation ladder. "io" ($CHAOS_POOL=io): on-disk snapshot
# corruption injected inside a load's guard scope, healed by the snapshot
# recovery ladder (dup replicas + layout rebuilds). "serve"
# ($CHAOS_POOL=serve): overload-lane faults — a wedged device launch
# (bounded stall: only latency without a watchdog, a typed ladder hop
# with one) and a former-stage crash (the supervisor fails in-flight
# futures typed and restarts the stage) — both exact recoveries.
# Excluded on purpose: query.* corruption (the sanitizer's repair CHANGES
# the correct answer), torn_write (fires during saves, which run
# unguarded), stale_version (a typed refusal, not a recovery) and
# queue.flood (a typed shed is caller-visible, like torn_write — tests
# not written for it would see AdmissionRejectedError) — those families
# are covered explicitly in tests/test_faults.py instead.
_CHAOS_POOLS = {
    "ladder": (
        ("residency.put_posting_arrays", "residency"),
        ("plan.fragments_device", "overflow"),
        ("kernel.resident_pruned", "nan_board"),
        ("kernel.resident_pruned", "inf_board"),
    ),
    "io": (
        ("snapshot.array", "bit_flip"),
        ("snapshot.array", "truncate"),
        ("snapshot.manifest", "manifest_corrupt"),
    ),
    "serve": (
        ("kernel.stall", "stall"),
        ("frontend.former", "thread_death"),
    ),
}
_CHAOS_POOL = _CHAOS_POOLS[os.environ.get("CHAOS_POOL", "ladder")]
_chaos_specs: dict = {}      # module name -> its one armed FaultSpec


@pytest.fixture(autouse=True)
def _chaos(request):
    """--chaos mode: one guarded, times=1 fault per test module.

    The spec is shared across the module's tests, so the fault fires at
    most once per module — in whichever test first walks a retriever
    ladder. Guarded specs cannot touch code outside a ladder scope, so
    index construction and pure-host tests are unaffected. Deterministic:
    the (site, kind) choice hashes ($CHAOS_SEED, module name).
    """
    if not request.config.getoption("--chaos") \
            or request.node.get_closest_marker("no_chaos"):
        yield
        return
    from repro.serve.faults import ACTIVE, FaultSpec
    mod = request.node.module.__name__
    spec = _chaos_specs.get(mod)
    if spec is None:
        seed = int(os.environ.get("CHAOS_SEED", "0"))
        pick = zlib.crc32(f"{seed}:{mod}".encode()) % len(_CHAOS_POOL)
        site, kind = _CHAOS_POOL[pick]
        spec = _chaos_specs[mod] = FaultSpec(
            site=site, kind=kind, times=1, seed=seed, guarded=True)
    ACTIVE.append(spec)
    try:
        yield
    finally:
        ACTIVE.remove(spec)


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    if not config.getoption("--chaos"):
        return
    seed = os.environ.get("CHAOS_SEED", "0")
    terminalreporter.section("chaos")
    terminalreporter.write_line(f"CHAOS_SEED={seed}")
    for mod, spec in sorted(_chaos_specs.items()):
        state = f"fired {spec.fired}x" if spec.fired else "never fired"
        terminalreporter.write_line(
            f"  {mod}: {spec.site}/{spec.kind} ({state})")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_corpus(rng, n_docs=60, n_vocab=50, max_len=30):
    return [rng.integers(0, n_vocab, size=rng.integers(1, max_len)
                         ).astype(np.int32) for _ in range(n_docs)]
