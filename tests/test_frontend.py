"""Async micro-batching front-end + the unified retrieval API surface.

Covers the PR-9 redesign contract:

* :class:`ServingFrontend` batches arrivals by the jit-cache shape keys
  and serves results BIT-IDENTICAL to direct ``retrieve_batch`` calls
  (per BM25 variant — degradation and batching change cost, never
  results);
* every retrieval entry point speaks :class:`RetrievalResult`, which
  unpacks as the legacy ``(ids, scores)`` tuple;
* every ``health()`` level speaks the schema-2 envelope;
* the deprecated forced-regime aliases still work but warn ONCE;
* SLO machinery: deadline misses raise (or count degraded), a full
  admission queue rejects with a typed error.
"""

import warnings

import numpy as np
import pytest

from repro.core import BM25Params, ScipyBM25, build_index
from repro.data.corpus import zipf_corpus, zipf_queries
from repro.serve import (HEALTH_SCHEMA, BlockedRetriever,
                         DeadlineExceededError, DeviceRetriever,
                         GatheredRetriever, PrunedRetriever, QueueOverflowError,
                         RetrievalEngine, RetrievalResult, ServingFrontend)
from repro.serve.retrieval_engine import _reset_alias_warnings

pytestmark = pytest.mark.no_chaos    # asserts exact counter values

N_VOCAB = 120
FIVE_VARIANTS = ("lucene", "robertson", "atire", "bm25l", "bm25+")
SMALL = dict(block_size=32, tile=64, q_max=8, frag=64)


@pytest.fixture(scope="module")
def corpus():
    return zipf_corpus(150, N_VOCAB, avg_len=25)


@pytest.fixture(scope="module")
def index(corpus):
    return build_index(corpus, N_VOCAB, params=BM25Params())


@pytest.fixture(scope="module")
def retriever(index):
    return DeviceRetriever(index, **SMALL)


# -- unified result type -------------------------------------------------

def test_result_tuple_unpack_compat(retriever):
    qs = zipf_queries(3, N_VOCAB)
    r = retriever.retrieve_batch(qs, 5)
    assert isinstance(r, RetrievalResult)
    ids, scores = r                                # legacy unpack order
    assert ids is r.ids and scores is r.scores
    assert r[0] is r.ids and r[1] is r.scores
    assert len(r) == 2
    assert tuple(r) == (r.ids, r.scores)
    # evidence fields ride along
    assert r.plan is not None and r.plan.regime in (
        "blocked", "gathered", "pruned")
    assert r.timings["total_s"] >= r.timings["execute_s"] >= 0
    assert r.degradations == [] and r.degraded is False


def test_result_single_query_row(retriever):
    q = zipf_queries(1, N_VOCAB)[0]
    r = retriever.retrieve(q, 5)
    ids, scores = r
    assert ids.shape == (5,) and scores.shape == (5,)
    rb = retriever.retrieve_batch([q], 5)
    np.testing.assert_array_equal(ids, rb.ids[0])
    np.testing.assert_array_equal(scores, rb.scores[0])


def test_engine_returns_unified_type(index):
    eng = RetrievalEngine([index], scorer="gathered",
                          scorer_opts=dict(SMALL), warmup=False)
    qs = zipf_queries(3, N_VOCAB)
    r = eng.retrieve_batch(qs, k=5)
    assert isinstance(r, RetrievalResult)
    ids, scores = r
    assert ids.shape == (3, 5)
    assert r.shards_answered == 1 and r.latency_s is not None
    r1 = eng.retrieve(qs[0], k=5)
    assert isinstance(r1, RetrievalResult)


def test_pack_then_execute_bit_identical(retriever):
    qs = zipf_queries(6, N_VOCAB)
    direct = retriever.retrieve_batch(qs, 7)
    packed = retriever.pack_batch(qs)
    resumed = retriever.retrieve_batch(None, 7, packed=packed)
    np.testing.assert_array_equal(direct.ids, resumed.ids)
    np.testing.assert_array_equal(direct.scores, resumed.scores)


# -- deprecated aliases --------------------------------------------------

@pytest.mark.parametrize("alias,regime", [
    (BlockedRetriever, "blocked"), (GatheredRetriever, "gathered"),
    (PrunedRetriever, "pruned")])
def test_alias_warns_once_and_forces_regime(index, alias, regime):
    _reset_alias_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r1 = alias(index, **SMALL)
        alias(index, **SMALL)                     # second: silent
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "DeviceRetriever" in str(dep[0].message)
    assert regime in str(dep[0].message)
    assert r1.regime == regime
    # alias output == keyword output (they are the same scorer)
    qs = zipf_queries(2, N_VOCAB)
    kw = DeviceRetriever(index, regime=regime, **SMALL)
    np.testing.assert_array_equal(r1.retrieve_batch(qs, 5).ids,
                                  kw.retrieve_batch(qs, 5).ids)


def test_engine_scorers_do_not_warn(index):
    _reset_alias_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        RetrievalEngine([index], scorer="pruned",
                        scorer_opts=dict(SMALL), warmup=False)
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]


# -- health schema -------------------------------------------------------

def test_health_schema_at_every_level(index, retriever):
    common = {"schema", "served", "degraded", "faults", "queries"}
    eng = RetrievalEngine([index], scorer="gathered",
                          scorer_opts=dict(SMALL), warmup=False)
    eng.retrieve_batch(zipf_queries(2, N_VOCAB), k=5)
    fe = ServingFrontend(retriever, k=5, max_batch=4,
                         batch_deadline_s=0.001)
    fe.submit(zipf_queries(1, N_VOCAB)[0]).result(timeout=30)
    fe.close()
    reports = {
        "retriever": retriever.health(),
        "shard": eng.runtimes[0].health(),
        "engine": eng.health(),
        "frontend": fe.health(),
    }
    for level, h in reports.items():
        missing = common - set(h)
        assert not missing, f"{level} missing {missing}"
        assert h["schema"] == HEALTH_SCHEMA
        assert isinstance(h["faults"], dict)
        assert isinstance(h["queries"], dict)
    # legacy spellings still present
    assert reports["retriever"]["batches_served"] == \
        reports["retriever"]["served"]
    assert reports["engine"]["responses"] == reports["engine"]["served"]
    assert reports["engine"]["shards"][0]["schema"] == HEALTH_SCHEMA
    assert reports["frontend"]["served"] == 1
    assert reports["frontend"]["retriever"]["schema"] == HEALTH_SCHEMA


# -- frontend: bit-identity ----------------------------------------------

@pytest.mark.parametrize("variant", FIVE_VARIANTS)
def test_frontend_bit_identical_to_direct(corpus, variant):
    """Every batch the frontend FORMS serves bit-identically to a direct
    ``retrieve_batch`` call on that same batch — micro-batching changes
    cost, never results (per BM25 variant)."""
    idx = build_index(corpus, N_VOCAB, params=BM25Params(method=variant))
    dr = DeviceRetriever(idx, **SMALL)
    qs = zipf_queries(8, N_VOCAB)
    with ServingFrontend(dr, k=5, max_batch=4, batch_deadline_s=0.005,
                         record_batches=True) as fe:
        futs = [fe.submit(q) for q in qs]
        rows = [f.result(timeout=60) for f in futs]
    assert fe.recorded                             # batches actually formed
    served = 0
    for batch_qs, kk, res in fe.recorded:
        replay = dr.retrieve_batch(batch_qs, kk)   # direct, same batch
        np.testing.assert_array_equal(res.ids, replay.ids)
        np.testing.assert_array_equal(res.scores, replay.scores)
        served += len(batch_qs)
    assert served == len(qs)
    # and every per-request row agrees with the numpy oracle
    sp = ScipyBM25(idx)
    for i, q in enumerate(qs):
        _, ref_v = sp.retrieve(q, 5)
        np.testing.assert_allclose(np.sort(rows[i].scores),
                                   np.sort(ref_v), atol=1e-3)


def test_frontend_forms_batches(retriever):
    """Concurrent same-shape arrivals share launches (micro-batching)."""
    qs = zipf_queries(12, N_VOCAB)
    with ServingFrontend(retriever, k=5, max_batch=4,
                         batch_deadline_s=0.05) as fe:
        futs = [fe.submit(q) for q in qs]
        for f in futs:
            f.result(timeout=60)
        h = fe.health()
    assert h["served"] == 12
    assert h["batches"] < 12                      # amortization happened
    assert h["flushes"]["size"] >= 1
    assert h["mean_batch"] > 1.0


def test_frontend_engine_target(index):
    """The single-stage path serves RetrievalEngine targets too."""
    eng = RetrievalEngine([index], scorer="gathered",
                          scorer_opts=dict(SMALL), warmup=False)
    q = zipf_queries(1, N_VOCAB)[0]
    with ServingFrontend(eng, k=5, max_batch=2,
                         batch_deadline_s=0.001) as fe:
        row = fe.submit(q).result(timeout=30)
    direct = eng.retrieve_batch([q], k=5)
    np.testing.assert_array_equal(row.ids, direct.ids[0])
    np.testing.assert_array_equal(row.scores, direct.scores[0])


def test_frontend_asubmit(retriever):
    import asyncio

    qs = zipf_queries(3, N_VOCAB)

    async def drive(fe):
        return await asyncio.gather(*(fe.asubmit(q) for q in qs))

    with ServingFrontend(retriever, k=5, max_batch=8,
                         batch_deadline_s=0.05) as fe:
        rows = asyncio.run(drive(fe))
    direct = retriever.retrieve_batch(qs, 5)       # same formed batch of 3
    for i, row in enumerate(rows):
        np.testing.assert_array_equal(row.ids, direct.ids[i])


# -- frontend: SLO + admission control -----------------------------------

def test_queue_overflow_typed_raise(retriever):
    fe = ServingFrontend(retriever, k=5, max_queue=2, autostart=False)
    fe._started = True                  # admit without draining (no threads)
    q = zipf_queries(1, N_VOCAB)[0]
    fe.submit(q)
    fe.submit(q)
    with pytest.raises(QueueOverflowError) as ei:
        fe.submit(q)
    assert ei.value.pending == 2
    assert isinstance(ei.value, RuntimeError)     # builtin-compat base
    assert fe.health()["rejected"] == 1


def test_deadline_miss_raises_typed(retriever):
    """on_miss="raise": a request that waited past its SLO fails typed."""
    fe = ServingFrontend(retriever, k=5, max_batch=8,
                         batch_deadline_s=0.05, request_timeout_s=1e-9,
                         on_miss="raise", autostart=False)
    fe._started = True
    q = zipf_queries(1, N_VOCAB)[0]
    fut = fe.submit(q)
    fe._started = False
    fe.start()                          # former drains the queued request
    with pytest.raises(DeadlineExceededError) as ei:
        fut.result(timeout=30)
    assert ei.value.waited_s is not None and ei.value.waited_s > 0
    assert isinstance(ei.value, TimeoutError)     # builtin-compat base
    fe.close()
    h = fe.health()
    assert h["deadline_missed"] == 1
    assert h["faults"].get("DeadlineExceededError") == 1


def test_deadline_miss_counts_degraded(retriever):
    """on_miss="degrade" (default): served exactly, counted degraded."""
    fe = ServingFrontend(retriever, k=5, max_batch=8,
                         batch_deadline_s=0.05, request_timeout_s=1e-9,
                         autostart=False)
    fe._started = True
    q = zipf_queries(1, N_VOCAB)[0]
    fut = fe.submit(q)
    fe._started = False
    fe.start()
    row = fut.result(timeout=30)
    fe.close()
    assert row.degraded                            # SLO miss flagged
    direct = retriever.retrieve_batch([q], 5)      # ... but still exact
    np.testing.assert_array_equal(row.ids, direct.ids[0])
    h = fe.health()
    assert h["deadline_missed"] == 1 and h["degraded"] == 1
    assert h["served"] == 1


def test_close_drains_pending(retriever):
    fe = ServingFrontend(retriever, k=5, max_batch=64,
                         batch_deadline_s=30.0)    # deadline never fires
    futs = [fe.submit(q) for q in zipf_queries(5, N_VOCAB)]
    fe.close()                                     # drain flush
    for f in futs:
        assert f.result(timeout=5).ids.shape == (5,)
    assert fe.health()["flushes"]["drain"] >= 1
    with pytest.raises(RuntimeError):
        fe.submit(zipf_queries(1, N_VOCAB)[0])     # closed: no admission
