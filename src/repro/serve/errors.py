"""Typed error taxonomy for the serving stack.

Every failure the serving API can surface derives from :class:`RetrievalError`
so callers catch ONE base class instead of fishing bare ``ValueError``s out
of the engine, the planners and the kernels. Each subclass also inherits the
builtin exception it historically shadowed (``ValueError`` for query/config
misuse, ``RuntimeError`` for runtime faults) so existing ``except ValueError``
call sites keep working through the migration.

The taxonomy maps one-to-one onto the graceful-degradation ladder in
``serve.retrieval_engine.DeviceRetriever.retrieve_batch`` (see ROADMAP
"Fault tolerance"): a typed failure in one regime triggers the hop to the
next — every hop is an exact regime, so degradation never changes results,
only cost.

* :class:`InvalidQueryError`     — malformed client input (out-of-range or
  negative token ids, non-integral dtypes, NaN) that ``on_invalid="raise"``
  surfaces instead of sanitizing.
* :class:`PlanOverflowError`     — an adaptive pow2 budget (posting bucket,
  fragment-count bucket) exhausted its cap; carries the attempted bucket
  sizes so the operator sees the regrowth trail.
* :class:`ResidencyError`        — device-resident state is missing or an
  upload failed (HBM pressure, a retriever built without the needed layout).
* :class:`ScoreIntegrityError`   — the returned ``[B, k]`` score board
  failed the cheap finite-check (NaN/Inf tiles from a bad kernel launch).
* :class:`RetrievalConfigError`  — incompatible constructor arguments
  (unknown regime/gather/plan modes and their invalid combinations).
* :class:`SnapshotIntegrityError` — an on-disk snapshot failed checksum /
  size / structure verification and the recovery ladder (duplicate copy →
  rebuild layout from surviving arrays → corpus rebuild) ran dry.
* :class:`SnapshotVersionError`  — a snapshot's format name, version, or
  checksum algorithm is not one this build can read; never silently
  reinterpreted as a different layout.
* :class:`DeadlineExceededError` — a queued request missed its serving
  deadline before its micro-batch launched (the front-end's SLO miss);
  carries how long the request waited so operators can see whether the
  queue or the device was the bottleneck.
* :class:`QueueOverflowError`    — the front-end's admission queue is
  full; the submission is REJECTED at the door (backpressure) instead of
  growing an unbounded queue whose tail latency lies to every client.
* :class:`AdmissionRejectedError` — the overload-protection gate (token
  bucket / CoDel queue-delay controller) shed the submission at the
  door; carries ``retry_after_s`` so well-behaved clients back off.
* :class:`ExecutionStalledError` — device execution of a formed batch
  exceeded the watchdog deadline; the (presumed hung) launch is
  abandoned and the typed error feeds the exact degradation ladder.
* :class:`StageFailedError`      — a serving pipeline stage (the batch
  former, a pack/execute worker) died or was shut down with requests
  still pending; every affected future fails with this instead of
  hanging its client.
* :class:`TruncationWarning`     — results are exact over a truncated
  posting set (budget overflow in the convenience API); a warning, not an
  error, because callers asked for a fixed budget.
"""

from __future__ import annotations


class RetrievalError(Exception):
    """Base class for every typed serving failure."""


class InvalidQueryError(RetrievalError, ValueError):
    """Client query batch is malformed (bad token ids, dtype, or shape)."""


class PlanOverflowError(RetrievalError, RuntimeError):
    """An adaptive pow2 budget exhausted its cap without fitting the batch.

    ``attempted`` records the bucket sizes tried (ascending), ``cap`` the
    final bucket — both appear in ``str(exc)`` for operators.
    """

    def __init__(self, message: str, *, attempted: list[int] | None = None,
                 cap: int | None = None):
        super().__init__(message)
        self.attempted = list(attempted or [])
        self.cap = cap


class ResidencyError(RetrievalError, RuntimeError, ValueError):
    """Device-resident index state is missing or failed to upload.

    Also inherits ``ValueError``: the raises it replaced (asking a
    retriever built without a layout to use it) historically surfaced as
    ``ValueError``, and existing callers catch that.
    """


class ScoreIntegrityError(RetrievalError, RuntimeError):
    """The top-k score board contains non-finite entries."""


class RetrievalConfigError(RetrievalError, ValueError):
    """Incompatible or unknown retriever construction arguments."""


class SnapshotIntegrityError(RetrievalError, RuntimeError):
    """An on-disk snapshot is corrupt beyond exact recovery.

    Raised when a manifest or array file fails checksum/size verification
    AND every recovery hop (duplicate copy, rebuild-from-surviving-layout,
    corpus rebuild) is unavailable. ``corrupt`` lists the offending
    manifest entries so operators see exactly which files to inspect.
    """

    def __init__(self, message: str, *, corrupt: list[str] | None = None):
        super().__init__(message)
        self.corrupt = list(corrupt or [])


class SnapshotVersionError(RetrievalError, ValueError):
    """A snapshot's format/version/checksum-algo is unknown to this build."""


class DeadlineExceededError(RetrievalError, TimeoutError):
    """A queued request missed its serving deadline before launch.

    Raised on (or set as the exception of) a front-end request future
    when the request's SLO budget (``ServingFrontend(request_timeout_s=
    ...)``) expired while it was still waiting in the batch former.
    ``waited_s`` records how long the request sat queued — also inherits
    the builtin ``TimeoutError`` so generic timeout handlers catch it.
    """

    def __init__(self, message: str, *, waited_s: float | None = None):
        super().__init__(message)
        self.waited_s = waited_s


class QueueOverflowError(RetrievalError, RuntimeError):
    """The serving front-end's admission queue is full (backpressure).

    Raised synchronously by ``ServingFrontend.submit`` — the request was
    never admitted, so the caller can shed load or retry elsewhere.
    ``pending`` carries the queue depth at rejection time.
    """

    def __init__(self, message: str, *, pending: int | None = None):
        super().__init__(message)
        self.pending = pending


class AdmissionRejectedError(RetrievalError, RuntimeError):
    """The overload-protection admission gate shed this submission.

    Raised synchronously by ``ServingFrontend.submit`` when the token
    bucket is dry or the CoDel-style queue-delay controller is shedding —
    the request was never admitted and consumed no device work.
    ``retry_after_s`` is the gate's backoff hint (seconds until a token
    accrues, or the controller's current shedding interval); ``pending``
    carries the queue depth the gate saw.
    """

    def __init__(self, message: str, *, retry_after_s: float | None = None,
                 pending: int | None = None):
        super().__init__(message)
        self.retry_after_s = retry_after_s
        self.pending = pending


class ExecutionStalledError(RetrievalError, TimeoutError):
    """Device execution exceeded the watchdog deadline (presumed hung).

    The watchdog abandons the stalled launch (its worker thread is
    replaced; a late result is discarded) and raises this typed error,
    which feeds the exact degradation ladder like any other rung fault —
    a stall trades latency and availability, never scores. ``waited_s``
    records how long the watchdog waited; ``hop`` names the ladder rung
    whose execution stalled.
    """

    def __init__(self, message: str, *, waited_s: float | None = None,
                 hop: str | None = None):
        super().__init__(message)
        self.waited_s = waited_s
        self.hop = hop


class StageFailedError(RetrievalError, RuntimeError):
    """A serving pipeline stage died (or closed) with requests pending.

    Set as the exception of every future the failed stage stranded: a
    batch-former crash beyond its restart budget, a request in flight
    when the former died, or a queued request aborted by
    ``ServingFrontend.close(drain=False)``. ``stage`` names the stage
    ("former", "close", ...) so operators can tell a crash from an
    abort.
    """

    def __init__(self, message: str, *, stage: str | None = None):
        super().__init__(message)
        self.stage = stage


class TruncationWarning(RuntimeWarning):
    """Scores were computed over a truncated posting set (budget overflow)."""


__all__ = [
    "RetrievalError", "InvalidQueryError", "PlanOverflowError",
    "ResidencyError", "ScoreIntegrityError", "RetrievalConfigError",
    "SnapshotIntegrityError", "SnapshotVersionError",
    "DeadlineExceededError", "QueueOverflowError",
    "AdmissionRejectedError", "ExecutionStalledError", "StageFailedError",
    "TruncationWarning",
]
