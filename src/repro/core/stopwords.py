"""Elastic's English stopword list.

The paper's tokenizer uses "Elastic's stopword list" — this is the standard
Lucene/Elasticsearch ``_english_`` analyzer stop set (33 words).
https://www.elastic.co/guide/en/elasticsearch/guide/current/stopwords.html
"""

from __future__ import annotations

ENGLISH_STOPWORDS: frozenset[str] = frozenset(
    {
        "a", "an", "and", "are", "as", "at", "be", "but", "by",
        "for", "if", "in", "into", "is", "it",
        "no", "not", "of", "on", "or", "such",
        "that", "the", "their", "then", "there", "these",
        "they", "this", "to", "was", "will", "with",
    }
)

STOPWORD_SETS: dict[str, frozenset[str]] = {
    "english": ENGLISH_STOPWORDS,
    "en": ENGLISH_STOPWORDS,
    "none": frozenset(),
}


def get_stopwords(name: str | None) -> frozenset[str]:
    """Resolve a stopword set by name. ``None`` / "none" disables stopwords."""
    if name is None:
        return frozenset()
    try:
        return STOPWORD_SETS[name.lower()]
    except KeyError:
        raise ValueError(
            f"unknown stopword set {name!r}; available: {sorted(STOPWORD_SETS)}"
        ) from None
