"""Batched retrieval serving with shard hedging, deadlines and elasticity.

The paper's §2 "Multi-threading" uses pooled executors for retrieval
speedup; at pod scale the same executor pattern becomes the scatter-gather
layer over document shards, and the operational concerns become:

* stragglers — the global merge proceeds once a QUORUM of shard top-k lists
  has arrived by the deadline; late shards are dropped from that response
  (recorded as ``degraded``) instead of stalling the tail latency. Because
  per-shard top-k is a superset property, a missed shard can only remove
  candidates it owns — results from responsive shards stay exact.
* elasticity — ``rescale(n_shards)`` re-buckets the postings (pure host
  re-slicing, ``core.index.reshard_index``) when the pool grows/shrinks;
  shards whose postings are byte-identical after the reshard KEEP their
  runtime (device arrays stay resident, no re-upload, no re-warmup —
  ``engine.last_build_stats`` reports the reuse count).

* device offload — each ``ShardRuntime`` scores either host-side
  (``scorer="scipy"``, the paper's CSC slice+sum) or through ONE device
  scorer, :class:`DeviceRetriever` (``scorer="auto"``), built on an
  HBM-resident ``sparse.block_csr.DeviceIndex``: the shifted CSC posting
  arrays AND the block-bucketed full-scan layout are uploaded once at
  build/rescale and live on device across calls. Per batch the planner
  (``core.retrieval.plan_retrieval``) compares the batch's Σ df — free,
  from the host descriptor table — against nnz and picks the regime:

    - **full-scan**  (O(nnz), ``bm25_block_score_topk``) when the batch is
      dense enough that every posting tile would be gathered anyway;
    - **gathered**   (O(Σ df), ``bm25_resident_score_topk``) everywhere
      else — run-fragment descriptors go to SMEM, posting tiles are DMA'd
      straight out of the resident index, and the steady-state path ships
      ZERO posting bytes host→device (a host-gather fallback with a
      hot-token LRU remains for CPU/interpret mode);
    - **pruned**     (O(postings that can still win),
      ``bm25_resident_score_topk_pruned``) when the resident block-max
      table estimates enough provably-losing blocks — the gathered
      machinery minus every fragment whose document block cannot beat
      the certified top-k threshold. Output stays bit-identical.

  ``scorer="blocked"`` / ``scorer="gathered"`` / ``scorer="pruned"``
  remain as forced-regime aliases of the same class.

* batching — ``retrieve_batch`` runs B queries through ONE kernel launch
  per shard (the batch dimension is free on the MXU), amortizing launch
  and membership-table cost across the batch; per-query ``retrieve``
  stays for latency-sensitive single queries.

``ShardRuntime`` is process-local here (threads simulate shard servers; a
``delay`` hook lets tests inject stragglers), but the engine logic —
quorum, deadline, merge, re-shard — is exactly the production control
plane.
"""

from __future__ import annotations

import threading
import time
import warnings
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Sequence

import numpy as np

from ..core.index import BM25Index, reshard_index
from ..core.reference import ScipyBM25
from ..core.retrieval import merge_topk
from .errors import (ExecutionStalledError, ResidencyError,
                     RetrievalConfigError, RetrievalError,
                     ScoreIntegrityError)
from .health import health_envelope, merge_fault_counts
from .overload import CircuitBreaker, RetryPolicy, WatchdogExecutor
from .results import PackedBatch, RetrievalResult


def _empty_batch(n_queries: int):
    ids = np.zeros((n_queries, 0), dtype=np.int64)
    scores = np.zeros((n_queries, 0), dtype=np.float32)
    return ids, scores


def _faults_module():
    """The fault harness, if (and only if) something already imported it."""
    import sys
    return sys.modules.get("repro.serve.faults")


class _DeviceRetrieverBase:
    """Shared host half of the device scorers (query packing + warmup).

    Subclasses set ``index``, ``n_docs``, ``q_max`` in ``__init__`` and
    implement ``retrieve_batch``; the packing helper and the single-query /
    warmup conveniences live here so the bucketing and no-truncation
    invariants have exactly ONE implementation.
    """

    def _pack_batch(self, query_tokens):
        """Batch -> padded query tables, every device dim pow2-bucketed.

        Three shape dimensions are bucketed so jit recompiles stay
        O(log demand) each, none silently truncating:

        * batch ``B`` — padded with empty queries (a ragged client batch
          must not trigger a fresh multi-second compile per distinct size);
        * per-query width — bucketed from the longest query (width ≥ query
          length ≥ its unique count, so ``pad_queries`` never truncates,
          unlike a fixed q_max that would quietly keep only the
          highest-count tokens of a long query);
        * unique-token table ``u_max`` — bucketed from the batch's actual
          distinct-token count.

        The token stream is sorted ONCE (``pad_queries``'s lexsort); the
        batch-unique table comes from its run set (``return_uniq``) and is
        reused for the pack table and the posting-run gather.

        Returns ``(b_true, uniq_batch, uniq_tab [u], weights [u, B],
        shift [B])`` — callers slice device outputs back to ``b_true``.
        """
        from ..core.scoring import bucket_pow2, pad_queries
        from ..sparse.block_csr import (pack_query_batch,
                                        query_nonoccurrence_shift)
        qs = [np.asarray(q).ravel() for q in query_tokens]
        b_true = len(qs)
        b_pad = bucket_pow2(max(b_true, 1), floor=8)
        qs += [np.zeros(0, np.int32)] * (b_pad - b_true)
        width = bucket_pow2(max((q.size for q in qs), default=1) or 1,
                            floor=self.q_max)
        toks, wts, uniq_batch = pad_queries(qs, width, return_uniq=True)
        u_max = bucket_pow2(max(uniq_batch.size, 1), floor=self.q_max)
        uniq_tab, weights = pack_query_batch(toks, wts, u_max=u_max,
                                             uniq=uniq_batch)
        shift = query_nonoccurrence_shift(self.index.nonoccurrence, toks,
                                          wts)
        return b_true, uniq_batch, uniq_tab, weights, shift

    def warmup(self, *, k: int) -> None:
        """Compile the floor-bucket retrieve path at engine build.

        The compiled-fn cache per (bucket..., k) is jax.jit's own
        static-arg/shape cache — the power-of-two bucketing in
        ``_pack_batch`` is what keys it to O(log demand) entries; this call
        pre-populates the floor buckets (B ≤ 8, width/u_max ≤ q_max floor)
        so typical first live queries never pay tracing+compilation; bigger
        batches pay one compile per pow2 bucket, then never again.
        """
        if self.n_docs == 0 or k <= 0:
            return
        q = np.zeros(1, dtype=np.int32)
        self.retrieve_batch([q], min(k, self.n_docs))

    def retrieve(self, query_tokens: np.ndarray, k: int
                 ) -> RetrievalResult:
        """One query -> :class:`RetrievalResult` with ``[k]`` boards.

        The single-query row of :meth:`retrieve_batch`; unpacks as the
        legacy ``(ids, scores)`` tuple.
        """
        r = self.retrieve_batch([np.asarray(query_tokens)], k)
        return RetrievalResult(
            ids=r.ids[0], scores=r.scores[0], plan=r.plan,
            degradations=r.degradations, timings=r.timings,
            degraded=r.degraded, latency_s=r.latency_s)


class DeviceRetriever(_DeviceRetrieverBase):
    """ONE device scorer, two regimes, zero per-batch posting copies.

    Builds an HBM-resident ``sparse.block_csr.DeviceIndex`` at construction
    (posting arrays uploaded ONCE — both the block-bucketed full-scan
    layout and the CSC arrays the resident gather kernel DMAs from) and
    plans every batch through ``core.retrieval.plan_retrieval``:

    * ``regime="auto"`` (default) — compare the batch's modeled costs:
      full-scan O(nnz), gathered O(crossover × Σ df), and — when the
      block-max table is resident — PRUNED, the gathered cost scaled by
      the estimated surviving-work fraction over ``PRUNE_DISCOUNT``. The
      decision and the pruning evidence (``survivor_frac``,
      ``frags_planned/pruned/skipped``) are recorded in ``self.last_plan``
      for observability.
    * ``regime="blocked"`` / ``"gathered"`` / ``"pruned"`` — force that
      regime (the planner still runs, so the evidence is logged); these
      back the :class:`BlockedRetriever` / :class:`GatheredRetriever` /
      :class:`PrunedRetriever` aliases.

    The pruned regime is the resident gather plus exact block-max
    pruning (see :meth:`_retrieve_pruned`): identical output bit-for-bit,
    strictly less work — fragments whose document block provably cannot
    place a document in any query's top-k are compacted out before launch
    and skipped in-kernel once the running threshold saturates further.

    The gathered regime has two executions:

    * ``gather="resident"`` — fragment descriptors go to SMEM and the
      scalar-prefetch kernel DMAs posting tiles straight out of the
      resident index (double-buffered: fragment f+1's copies overlap f's
      scatter; ``double_buffer=False`` keeps the sequential oracle).
      Where the fragment table is built is the ``plan`` axis:

      - ``plan="device"`` — the table is jit-built FROM the resident CSC
        arrays (``sparse.fragment_device``); the host never reads its CSC
        copy and per-batch host→device traffic is query tables only —
        zero posting AND zero descriptor bytes (tier-1 asserts both).
        ``host_arrays="drop"`` then releases the host posting copy
        entirely (O(V)/O(n_docs) metadata stays).
      - ``plan="host"`` — ``fragment_plan`` walks the host CSC copy and
        ships the O(Σ df/frag) descriptor table per batch (the PR-3
        behavior; still zero posting bytes).

      Default ``plan=None`` resolves to device on TPU, host elsewhere
      (interpret mode favors the cheaper host build); ``last_plan.plan``
      records the choice per batch.
    * ``gather="host"`` — the candidate-compacted host gather (fallback
      for CPU/interpret mode, where fragment-at-a-time DMA interpretation
      is slow); ships O(Σ df) postings per batch, with a hot-token LRU
      (:class:`~repro.sparse.block_csr.PostingRunCache`) so Zipf-head
      tokens are re-gathered once, not per batch.

    Default ``gather=None`` resolves to resident on TPU, host elsewhere.

    Budgets stay **adaptive**: fragment counts, posting tiles and chunk
    counts are sized from the batch's ACTUAL demand, pow2-bucketed
    (``bucket_pow2``) so recompiles stay O(log max-demand) and nothing is
    ever silently truncated (the device fragment builder turns its
    nf-bucket overflow flag into a larger-bucket retry). ``acc_block``
    (host-gather chunk height) stays SMALL — the one-hot scatter costs
    ``acc_block`` MACs/posting, so big candidate sets get MORE chunks,
    keeping work linear in Σ df.
    """

    def __init__(self, index: BM25Index, *, regime: str = "auto",
                 block_size: int = 512, tile: int = 512,
                 acc_block: int = 512, q_max: int = 32, frag: int = 512,
                 crossover: float | None = None, gather: str | None = None,
                 plan: str | None = None, double_buffer: bool = True,
                 host_arrays: str = "keep", run_cache: int = 256,
                 bmax_dtype: str = "auto", reorder: str = "none",
                 reuse_from=None,
                 device_index=None, on_fault: str = "degrade",
                 watchdog_s: float | None = None, retry_budget: int = 0,
                 retry_backoff_s: float = 0.005, retry_seed: int = 0,
                 breaker_threshold: int | None = 3,
                 breaker_window_s: float = 30.0,
                 breaker_cooldown_s: float = 5.0):
        from ..sparse.block_csr import DeviceIndex, PostingRunCache
        if regime not in ("auto", "blocked", "gathered", "pruned"):
            raise RetrievalConfigError(f"unknown regime {regime!r}")
        if on_fault not in ("degrade", "raise"):
            raise RetrievalConfigError(f"unknown on_fault mode {on_fault!r}")
        if watchdog_s is not None and watchdog_s <= 0:
            raise RetrievalConfigError("watchdog_s must be positive "
                                       "(or None to disable)")
        if retry_budget < 0:
            raise RetrievalConfigError("retry_budget must be >= 0")
        if breaker_threshold is not None and breaker_threshold < 1:
            raise RetrievalConfigError("breaker_threshold must be >= 1 "
                                       "(or None to disable breakers)")
        if device_index is not None:
            # ADOPT a pre-built DeviceIndex (snapshot cold-start:
            # ``DeviceIndex.load`` already uploaded the resident arrays —
            # no rebuild, no re-upload). Geometry comes from the adopted
            # index; regime / gather / plan resolve to the layouts the
            # snapshot actually holds.
            if index is None:
                index = device_index.host
            if index is None:
                raise RetrievalConfigError(
                    "device_index= adoption needs a host BM25Index (the "
                    "adopted DeviceIndex was built with host=None)")
            block_size = device_index.block_size
            frag = device_index.frag
            if regime == "auto" and device_index.blk_tok is None:
                regime = ("pruned" if device_index.bmax is not None
                          else "gathered")
            if regime == "auto" and device_index.csc_doc_ids is None:
                regime = "blocked"
            host_intact = (int(index.doc_ids.size) == int(index.indptr[-1]))
            if not host_intact:
                # the snapshot was loaded host_arrays="drop": every
                # host-side path (host gather / host planner / oracle) is
                # gone, so force the resident device plan
                gather, plan, host_arrays = "resident", "device", "keep"
        if gather is None:
            import jax
            # pruning is a resident-path concept (it gates fragment DMAs
            # against the resident block-max table), so a forced pruned
            # build resolves to the resident gather even off-TPU
            gather = ("resident" if regime == "pruned"
                      or jax.default_backend() == "tpu" else "host")
        if gather not in ("resident", "host"):
            raise RetrievalConfigError(f"unknown gather mode {gather!r}")
        if regime == "pruned" and gather != "resident":
            raise RetrievalConfigError(
                'regime="pruned" gates resident fragment DMAs against the '
                'block-max table — it requires gather="resident"')
        if plan is None:
            import jax
            plan = ("device" if gather == "resident"
                    and jax.default_backend() == "tpu" else "host")
        if plan not in ("host", "device"):
            raise RetrievalConfigError(f"unknown plan mode {plan!r}")
        if plan == "device" and gather != "resident":
            raise RetrievalConfigError(
                'plan="device" builds fragment tables from the resident '
                'CSC arrays — it requires gather="resident"')
        if host_arrays not in ("keep", "drop"):
            raise RetrievalConfigError(
                f"unknown host_arrays mode {host_arrays!r}")
        if host_arrays == "drop" and plan != "device":
            raise RetrievalConfigError(
                'host_arrays="drop" removes the arrays the host fragment '
                'planner reads — it requires plan="device"')
        self.index = index
        self.regime = regime
        self.gather_mode = gather
        self.plan_mode = plan
        self.double_buffer = double_buffer
        self.q_max = q_max                       # bucket floor, not a cap
        self.block_size = block_size
        self.tile = tile
        self.acc_block = acc_block               # host-gather chunk height
        self.crossover = crossover
        self.n_docs = int(index.doc_lens.size)
        self.run_cache = (PostingRunCache(run_cache)
                          if gather == "host" and run_cache > 0 else None)
        if device_index is not None:
            self.dindex = device_index
        else:
            with_csc = (regime in ("auto", "gathered", "pruned")
                        and gather == "resident")
            self.dindex = DeviceIndex.build(
                index, block_size=block_size, tile=tile, frag=frag,
                with_blocked=regime in ("auto", "blocked"),
                with_csc=with_csc,
                with_bmax=with_csc and regime in ("auto", "pruned"),
                bmax_dtype=bmax_dtype, reorder=reorder,
                host_arrays=host_arrays, reuse_from=reuse_from)
        if getattr(self.dindex, "perm", None) is not None \
                and self.dindex.host is not None:
            # doc-id reordering: serve in the PERMUTED id space end to
            # end — host fragment planning, the host-gather rung and the
            # oracle rung all read the permuted host copy, so EVERY
            # ladder hop yields permuted local ids and one host-side
            # gather at the merge maps winners back to client ids (the
            # survivor estimate in retrieve_batch thereby consumes the
            # permuted block-max table and matching fragment plans)
            self.index = self.dindex.host
        self._nf_state = {}                      # steady-state nf bucket
        self.on_fault = on_fault
        # overload protection (PR 10): watchdog-guarded execution, seeded
        # bounded retry on transient residency faults, and per-rung
        # circuit breakers giving the ladder memory across batches
        self.watchdog_s = watchdog_s
        self._watchdog = (WatchdogExecutor(watchdog_s,
                                           name="retriever-watchdog")
                          if watchdog_s is not None else None)
        self._retry = RetryPolicy(budget=retry_budget,
                                  base_s=retry_backoff_s, seed=retry_seed)
        self._breakers = ({hop: CircuitBreaker(
            threshold=breaker_threshold, window_s=breaker_window_s,
            cooldown_s=breaker_cooldown_s) for hop in self._LADDER}
            if breaker_threshold is not None else None)
        # observability: ladder + sanitizer counters feeding engine
        # health(). Mutations go through _health_lock — the frontend's
        # pack/execute stages run concurrently with direct callers, and
        # counts must sum exactly under that interleaving.
        self._health_lock = threading.RLock()
        self.fault_counters: dict[str, int] = {}
        self.query_counters: dict[str, int] = {}
        self.degradation_counts: dict[str, int] = {}
        self.batches_served = 0
        self.batches_degraded = 0
        self.retry_count = 0
        self.last_queries: list[np.ndarray] = []
        self._oracle = None                      # lazy ScipyBM25 (last rung)
        if (host_arrays == "drop"
                and getattr(self.dindex, "perm", None) is None):
            # serving now reads only metadata: release the O(nnz) host
            # posting copy (a private stripped view — the caller's index
            # object is untouched). Under reordering ``self.index`` is
            # already the builder's stripped PERMUTED metadata copy —
            # re-stripping from the client-order ctor index would hand
            # the merge the wrong doc_lens order.
            from dataclasses import replace
            self.index = replace(index, doc_ids=np.zeros(0, np.int32),
                                 scores=np.zeros(0, np.float32))
        self.last_plan = None

    def warmup(self, *, k: int) -> None:
        """Compile BOTH resident regimes' floor buckets at engine build."""
        if self.n_docs == 0 or k <= 0:
            return
        q = np.zeros(1, dtype=np.int32)
        kk = min(k, self.n_docs)
        if (self.regime in ("auto", "blocked")
                and self.dindex.blk_tok is not None):
            self.retrieve_batch([q], kk, regime="blocked")
        if (self.regime in ("auto", "gathered")
                and (self.gather_mode == "host"
                     or self.dindex.csc_doc_ids is not None)):
            self.retrieve_batch([q], kk, regime="gathered")
        if self.regime == "pruned":
            # auto engines compile the pruned kernels lazily on the first
            # batch the cost model routes there — warming all three per
            # shard would triple build latency for a regime many shards
            # never enter
            self.retrieve_batch([q], kk, regime="pruned")

    def health(self) -> dict:
        """Schema-2 health report (see ``repro.serve`` package docstring).

        ``served``/``degraded`` count BATCHES at this level; ``degraded``
        means the exact-fallback ladder hopped at least once. Legacy
        spellings (``batches_served``/``batches_degraded``) ride along as
        level extras, as do the overload-protection counters:
        ``breakers`` (per-rung state machine snapshots), ``retries``
        (seeded-backoff re-attempts that saved a ladder hop) and
        ``watchdog`` (armed deadline + stall count).
        """
        now = time.monotonic()
        with self._health_lock:
            breakers = ({hop: br.snapshot(now)
                         for hop, br in self._breakers.items()}
                        if self._breakers is not None else {})
            return health_envelope(
                served=self.batches_served,
                degraded=self.batches_degraded,
                faults=dict(self.fault_counters),
                queries=dict(self.query_counters),
                batches_served=self.batches_served,
                batches_degraded=self.batches_degraded,
                degradations=dict(self.degradation_counts),
                breakers=breakers,
                retries=self.retry_count,
                watchdog=({"timeout_s": self._watchdog.timeout_s,
                           "stalls": self._watchdog.stalls}
                          if self._watchdog is not None else {}),
                snapshot=dict(getattr(self.dindex, "snapshot_report",
                                      None) or {}),
            )

    def save(self, path, *, algo: str | None = None) -> dict:
        """Persist this retriever's resident index (see sparse.snapshot)."""
        return self.dindex.save(path, index=self.index, algo=algo)

    # -- the graceful-degradation ladder ---------------------------------
    #
    # Five rungs, all EXACT: pruned -> gathered-resident -> host-gather ->
    # blocked full-scan -> numpy ScipyBM25 oracle. A typed RetrievalError
    # in one rung triggers the hop to the next AVAILABLE rung (capability
    # depends on the layouts this retriever was built with); results never
    # change across hops — only the cost — so degradation preserves the
    # paper's exactness guarantee by construction. The trail is recorded
    # in ``last_plan.degradations`` and aggregated into the counters the
    # engine-level ``health()`` report exposes.

    _LADDER = ("pruned", "resident", "host", "blocked", "oracle")

    def _host_postings_intact(self) -> bool:
        """False once ``host_arrays="drop"`` released the host copy."""
        return int(self.index.doc_ids.size) == int(self.index.indptr[-1])

    def _hop_available(self, hop: str, kk: int) -> bool:
        """Can this rung run with the layouts this retriever holds?"""
        if hop == "pruned":
            return (self.gather_mode == "resident"
                    and self.dindex.bmax is not None
                    and self.dindex.csc_doc_ids is not None
                    and kk <= self.dindex.block_size)
        if hop == "resident":
            return self.dindex.csc_doc_ids is not None and (
                self.plan_mode == "device" or self._host_postings_intact())
        if hop in ("host", "oracle"):
            return self._host_postings_intact()
        if hop == "blocked":
            return self.dindex.blk_tok is not None
        return False

    # -- per-rung circuit breakers (overload protection, PR 10) -----------

    def _breaker_allow(self, hop: str) -> bool:
        """May the ladder run this rung now? (half-open claims its probe)."""
        if self._breakers is None:
            return True
        with self._health_lock:
            return self._breakers[hop].allow(time.monotonic())

    def _breaker_record(self, hop: str, *, ok: bool) -> None:
        if self._breakers is None:
            return
        with self._health_lock:
            br = self._breakers[hop]
            if ok:
                br.record_success(time.monotonic())
            else:
                br.record_fault(time.monotonic())

    def trip_breaker(self, hop: str, *,
                     cooldown_s: float | None = None) -> None:
        """Operator override: force a rung's breaker open for a cooldown.

        The ladder then skips ``hop`` (recording a ``BreakerOpen`` trail
        entry) and serves exactly from the remaining rungs until the
        cooldown's half-open probe closes the breaker again. Raises
        :class:`RetrievalConfigError` when breakers are disabled
        (``breaker_threshold=None``) or ``hop`` is not a ladder rung.
        """
        if self._breakers is None:
            raise RetrievalConfigError(
                "circuit breakers are disabled on this retriever "
                "(breaker_threshold=None)")
        if hop not in self._breakers:
            raise RetrievalConfigError(
                f"unknown ladder rung {hop!r}; available: "
                f"{list(self._LADDER)}")
        with self._health_lock:
            self._breakers[hop].force_open(time.monotonic(),
                                           cooldown_s=cooldown_s)

    def _run_hop(self, hop, qs, b, uniq_batch, uniq_tab, weights, shift,
                 kk, plan, prune_ub, *, strict, guard_cm):
        """One execution attempt of a rung: the ``kernel.stall`` fault
        site, then ``_exec_hop`` — under the watchdog deadline when armed.

        The watchdog runs the body on its supervised worker thread, so
        the ladder guard scope (thread-local) is re-entered ON that
        thread via ``ctx=``; a deadline miss abandons the stalled worker
        and surfaces as :class:`ExecutionStalledError` tagged with the
        rung. Strict calls bypass the watchdog: warmup's forced-regime
        calls pay one-off compiles that a serving-sized deadline would
        misread as stalls.
        """
        def body():
            _f = _faults_module()
            if _f is not None and _f.ACTIVE:
                _f.fire("kernel.stall")
            return self._exec_hop(hop, qs, b, uniq_batch, uniq_tab,
                                  weights, shift, kk, plan, prune_ub)

        if self._watchdog is not None and not strict:
            try:
                return self._watchdog.run(body, ctx=guard_cm)
            except ExecutionStalledError as e:
                e.hop = hop
                raise
        with guard_cm():
            return body()

    def pack_batch(self, query_tokens: Sequence[np.ndarray], *,
                   strict: bool | None = None) -> PackedBatch:
        """Host half of :meth:`retrieve_batch`: fault hook + sanitizer +
        pow2 pack, split out so a front-end can OVERLAP packing batch
        i+1 with device execution of batch i.

        Runs exactly the stages ``retrieve_batch`` runs before planning —
        the ``query.batch`` fault site, the shared sanitizer
        (``core.retrieval.validate_query_batch``, counting repairs into
        ``query_counters``), and ``_pack_batch``'s pow2 bucketing — so
        ``retrieve_batch(None, k, packed=pack_batch(qs))`` is
        bit-identical to ``retrieve_batch(qs, k)`` by construction.
        ``strict`` mirrors the retrieve-side strictness (default: the
        constructor's ``on_fault``); strict packs surface faults instead
        of entering the recoverable guard scope.
        """
        import contextlib

        from ..core.retrieval import validate_query_batch

        t0 = time.perf_counter()
        if strict is None:
            strict = self.on_fault == "raise"
        _f = _faults_module()
        # guarded faults target RECOVERABLE scopes only: a strict call
        # re-raises instead of degrading, so it never enters the guard —
        # chaos mode (guarded specs armed globally) cannot crash warmup's
        # forced-regime calls or an ``on_fault="raise"`` deployment. Test
        # strict surfacing with ``guarded=False`` specs.
        guard = (_f.guard if _f is not None and not strict
                 else contextlib.nullcontext)
        if _f is not None and _f.ACTIVE:
            with guard():
                query_tokens = _f.fire("query.batch", list(query_tokens),
                                       n_vocab=self.index.n_vocab)
        # sanitize into a LOCAL counter dict, merged under the health
        # lock: the frontend pack stage runs concurrently with direct
        # callers, and in-place mutation of the shared dict would drop
        # increments under that interleaving
        local_counts: dict[str, int] = {}
        qs = validate_query_batch(
            query_tokens, self.index.n_vocab,
            counters=local_counts,
            on_invalid="raise" if self.on_fault == "raise" else "sanitize")
        if local_counts:
            with self._health_lock:
                for key, v in local_counts.items():
                    self.query_counters[key] = \
                        self.query_counters.get(key, 0) + v
        if self.n_docs == 0:                     # empty shard post-rescale
            return PackedBatch(qs, len(qs), np.zeros(0, np.int32), None,
                               None, None,
                               pack_s=time.perf_counter() - t0)
        b, uniq_batch, uniq_tab, weights, shift = self._pack_batch(qs)
        return PackedBatch(qs, b, uniq_batch, uniq_tab, weights, shift,
                           pack_s=time.perf_counter() - t0)

    def retrieve_batch(self, query_tokens: Sequence[np.ndarray] | None,
                       k: int, *, regime: str | None = None,
                       packed: PackedBatch | None = None
                       ) -> RetrievalResult:
        """B queries -> :class:`RetrievalResult` with ``[B, k]`` boards,
        one launch per batch (unpacks as the legacy ``(ids, scores)``).

        ``regime`` overrides this call's plan (used by warmup and the
        benchmark sweep) and makes the call STRICT — a typed failure
        surfaces instead of degrading (a forced regime that cannot run is
        an operator error, not traffic to absorb). Normal traffic leaves
        it None: the cost model picks the entry rung and any typed
        failure walks the exact fallback ladder (see class docstring and
        ROADMAP "Fault tolerance"), recording each hop in the result's
        ``degradations`` (also ``last_plan.degradations``).
        ``on_fault="raise"`` (constructor) makes every call strict.
        Every returned board passes a cheap ``[B, k]`` finite-check; a
        NaN/Inf tile is a
        :class:`~repro.serve.errors.ScoreIntegrityError` — degraded
        around like any other fault.

        ``packed`` resumes from a prior :meth:`pack_batch` (the
        front-end's overlap path; ``query_tokens`` is then ignored and
        may be None) — the sanitizer and fault hook already ran at pack
        time, so results are bit-identical to the one-call path.
        """
        import contextlib

        from ..core.retrieval import plan_retrieval

        strict = regime is not None or self.on_fault == "raise"
        _f = _faults_module()
        # recoverable-scope guard for the EXECUTION stages (see
        # pack_batch for the strictness rationale)
        guard = (_f.guard if _f is not None and not strict
                 else contextlib.nullcontext)
        if packed is None:
            packed = self.pack_batch(query_tokens, strict=strict)
        t_start = time.perf_counter()            # exec clock excludes pack
        qs = packed.qs
        self.last_queries = qs
        if self.n_docs == 0 or k <= 0:           # empty shard post-rescale
            ids0, sc0 = _empty_batch(len(qs))
            return RetrievalResult(
                ids=ids0, scores=sc0,
                timings={"pack_s": packed.pack_s, "execute_s": 0.0,
                         "total_s": packed.pack_s},
                latency_s=packed.pack_s)
        b, uniq_batch, uniq_tab, weights, shift = (
            packed.b, packed.uniq_batch, packed.uniq_tab, packed.weights,
            packed.shift)
        kk = min(k, self.n_docs)
        # the pruned regime needs the block-max table and an accumulator
        # window matching its block grid (k can outgrow the block height)
        prune_ok = self._hop_available("pruned", kk)
        want = regime or self.regime
        survivor_frac, prune_ub = None, None
        # the host estimate feeds the auto cost model and (under host
        # planning) hands its bound matrix to the execution pass; a FORCED
        # pruned regime under device planning consumes neither — skip the
        # O(U·nb·B) host matmul on that hot path
        if prune_ok and (want == "auto"
                         or (want == "pruned" and self.plan_mode == "host")):
            from ..sparse.block_csr import estimate_prune_survivors
            survivor_frac, prune_ub = estimate_prune_survivors(
                self.dindex.bmax, uniq_tab, weights, k=kk, b_true=b)
        plan = plan_retrieval(self.dindex.sum_df(uniq_batch),
                              self.dindex.nnz, regime=want,
                              crossover=self.crossover, plan=self.plan_mode,
                              survivor_frac=survivor_frac)
        self.last_plan = plan
        if plan.regime == "pruned" and not prune_ok:
            if self.gather_mode != "resident":
                raise RetrievalConfigError('regime="pruned" requires '
                                           'gather="resident"')
            if self.dindex.csc_doc_ids is None or self.dindex.bmax is None:
                raise ResidencyError("pruned regime requested but this "
                                     "retriever was built without the "
                                     "resident CSC index + block-max "
                                     "table")
            # k outgrew the block-max grid (degenerate: the scoreboard
            # spans whole blocks, nothing can prune) — run the exact
            # unpruned resident path under the pruned label
            plan = plan_retrieval(plan.sum_df, plan.nnz, regime="gathered",
                                  crossover=self.crossover,
                                  plan=self.plan_mode)
            plan.regime, plan.forced = "pruned", True
            self.last_plan = plan
            entry = "resident"
        elif plan.regime == "pruned":
            entry = "pruned"
        elif plan.regime == "blocked":
            entry = "blocked"
        else:
            entry = "resident" if self.gather_mode == "resident" else "host"

        trail = plan.degradations
        hops = ((entry,) if strict
                else self._LADDER[self._LADDER.index(entry):])
        last_err = None
        with self._health_lock:
            self.batches_served += 1
        for hop in hops:
            if hop != entry and not self._hop_available(hop, kk):
                continue
            if not strict and not self._breaker_allow(hop):
                # the breaker remembers this rung's recent faults: skip
                # it WITHOUT execution (no fault-then-hop tax) and let
                # the next rung fill the trail entry's "to"
                trail.append({"from": hop, "to": None,
                              "error": "BreakerOpen",
                              "detail": f"circuit breaker open for rung "
                                        f"{hop!r} (skipped without "
                                        f"execution)"})
                continue
            if trail and trail[-1]["to"] is None:
                trail[-1]["to"] = hop
            # transient-fault retry: seeded exponential backoff with a
            # bounded budget before burning a ladder hop (strict calls
            # surface the first fault instead)
            delays = self._retry.delays() if not strict else []
            board = None
            while board is None:
                try:
                    ids, vals = self._run_hop(
                        hop, qs, b, uniq_batch, uniq_tab, weights, shift,
                        kk, plan, prune_ub, strict=strict, guard_cm=guard)
                    cand = np.asarray(vals)[:b].astype(np.float32,
                                                       copy=False)
                    # cheap integrity gate on the [B, k] board — NOT the
                    # full score matrix (which never materializes on
                    # these paths)
                    if not np.isfinite(cand).all():
                        raise ScoreIntegrityError(
                            f"non-finite entries in the [{b}, {kk}] "
                            f"score board returned by the {hop!r} hop")
                    board = cand
                except RetrievalError as e:
                    name = type(e).__name__
                    with self._health_lock:
                        self.fault_counters[name] = \
                            self.fault_counters.get(name, 0) + 1
                    if strict:
                        raise
                    if isinstance(e, ResidencyError) and delays:
                        with self._health_lock:
                            self.retry_count += 1
                        time.sleep(delays.pop(0))
                        continue
                    self._breaker_record(hop, ok=False)
                    trail.append({"from": hop, "to": None, "error": name,
                                  "detail": str(e)})
                    last_err = e
                    break
            if board is None:
                continue
            self._breaker_record(hop, ok=True)
            if trail:
                with self._health_lock:
                    self.batches_degraded += 1
                    for t in trail:
                        key = f"{t['from']}->{t['to']}"
                        self.degradation_counts[key] = \
                            self.degradation_counts.get(key, 0) + 1
            ids = np.asarray(ids)[:b].astype(np.int64)
            perm = getattr(self.dindex, "perm", None)
            if perm is not None:
                # doc-id reordering: every hop scored in the permuted id
                # space — ONE host-side gather on the [B, k] board maps
                # winners back to client ids (zero extra device bytes)
                from ..sparse.reorder import remap_board
                ids = remap_board(ids, board, perm)
            exec_s = time.perf_counter() - t_start
            return RetrievalResult(
                ids=ids + self.index.doc_offset, scores=board, plan=plan,
                degradations=list(trail), degraded=bool(trail),
                timings={"pack_s": packed.pack_s, "execute_s": exec_s,
                         "total_s": packed.pack_s + exec_s},
                latency_s=packed.pack_s + exec_s)
        raise RetrievalError(
            f"every ladder hop failed or is unavailable (entry "
            f"{entry!r}, degradations {trail!r})") from last_err

    def _exec_hop(self, hop, qs, b, uniq_batch, uniq_tab, weights, shift,
                  kk, plan, prune_ub):
        if hop == "pruned":
            return self._retrieve_pruned(uniq_batch, uniq_tab, weights,
                                         shift, kk, plan, b_true=b,
                                         ub=prune_ub)
        if hop == "resident":
            return self._exec_resident(uniq_batch, uniq_tab, weights,
                                       shift, kk, plan)
        if hop == "host":
            return self._exec_host(uniq_batch, uniq_tab, weights, shift,
                                   kk)
        if hop == "blocked":
            return self._exec_blocked(uniq_tab, weights, shift, kk)
        if hop == "oracle":
            return self._exec_oracle(qs, kk)
        raise AssertionError(f"unknown ladder hop {hop!r}")

    def _exec_blocked(self, uniq_tab, weights, shift, kk):
        import jax.numpy as jnp

        from ..kernels import ops
        if self.dindex.blk_tok is None:
            raise ResidencyError("blocked regime requested but this "
                                 "retriever was built gathered-only")
        return ops.bm25_retrieve_blocked(
            self.dindex.blk_tok, self.dindex.blk_loc, self.dindex.blk_sc,
            jnp.asarray(uniq_tab), jnp.asarray(weights),
            jnp.asarray(shift), block_size=self.dindex.block_size,
            n_docs=self.n_docs, k=kk, tile_p=self.dindex.tile_p)

    def _exec_resident(self, uniq_batch, uniq_tab, weights, shift, kk,
                       plan):
        import jax.numpy as jnp

        from ..core.retrieval import default_doc_ids
        from ..core.scoring import bucket_pow2
        from ..kernels import ops
        from ..sparse.block_csr import fragment_plan, put_descriptor_array
        if self.dindex.csc_doc_ids is None:
            raise ResidencyError("resident gather requested but this "
                                 "retriever was built blocked-only")
        # accumulator window grows only if k outruns it (the shard
        # scoreboard needs k ≤ block height); fragment count buckets
        # inside the planners
        rblock = bucket_pow2(kk, floor=self.block_size)
        if self.plan_mode == "device":
            # fragment table + default ids born ON device from the
            # resident CSC arrays — no host CSC read, no descriptor
            # upload (the tier-1 zero-descriptor-bytes invariant)
            from ..sparse.fragment_device import plan_fragments_device
            desc, dids, _nf = plan_fragments_device(
                self.dindex, uniq_tab, sum_df=plan.sum_df, k=kk,
                block_size=rblock, state=self._nf_state)
        else:
            if not self._host_postings_intact():
                raise ResidencyError('plan="host" fragment planning needs '
                                     'the host posting arrays')
            fp = fragment_plan(self.index, uniq_batch, block_size=rblock,
                               frag=self.dindex.frag)
            dids = jnp.asarray(default_doc_ids(fp.vis_blocks, kk,
                                               self.n_docs, rblock))
            desc = put_descriptor_array(fp.desc)
        return ops.bm25_retrieve_resident(
            desc, jnp.asarray(weights),
            self.dindex.csc_doc_ids, self.dindex.csc_scores,
            dids, jnp.asarray(shift), block_size=rblock,
            frag=self.dindex.frag, k=kk, n_docs=self.n_docs,
            double_buffer=self.double_buffer)

    def _exec_host(self, uniq_batch, uniq_tab, weights, shift, kk):
        import jax.numpy as jnp

        from ..core.scoring import bucket_pow2
        from ..kernels import ops
        from ..sparse.block_csr import (gather_posting_runs,
                                        put_posting_arrays)
        if not self._host_postings_intact():
            raise ResidencyError("host gather needs the host posting "
                                 'arrays, which host_arrays="drop" '
                                 "released")
        # host-gather: chunk height grows only if k outruns it; posting/
        # chunk dims bucket inside the gather. The uploads below are the
        # per-batch posting copies the resident path eliminates — routed
        # through the counting helper on purpose.
        acc_block = bucket_pow2(kk, floor=self.acc_block)
        gp = gather_posting_runs(self.index, uniq_batch,
                                 acc_block=acc_block, tile=self.tile,
                                 cache=self.run_cache)
        tok, slot, sc, cand = put_posting_arrays(
            gp.token_ids, gp.slot_ids, gp.scores, gp.candidates)
        return ops.bm25_retrieve_gathered(
            tok, slot, sc, jnp.asarray(uniq_tab), jnp.asarray(weights),
            cand, jnp.asarray(shift), acc_block=gp.acc_block, k=kk,
            n_docs=self.n_docs, tile_p=min(self.tile, gp.p_pad))

    def _exec_oracle(self, qs, kk):
        """Terminal rung: the paper-faithful numpy/scipy scorer.

        Host-side and slow, but it cannot fail for device reasons — the
        ladder's floor. Exact by definition: it IS the reference the
        device regimes are tested against. Ids come back shard-local
        (the caller adds ``doc_offset``, same as every other hop).
        """
        if not self._host_postings_intact():
            raise ResidencyError('oracle fallback needs the host posting '
                                 'arrays, which host_arrays="drop" '
                                 "released")
        from ..core.retrieval import topk_numpy
        if self._oracle is None:
            self._oracle = ScipyBM25(self.index)
        b = len(qs)
        ids = np.zeros((b, kk), np.int64)
        vals = np.zeros((b, kk), np.float32)
        for i, q in enumerate(qs):
            s = self._oracle.score(q)
            idx, v = topk_numpy(s[None], kk)
            ids[i], vals[i] = idx[0], v[0]
        return ids, vals

    def _retrieve_pruned(self, uniq_batch, uniq_tab, weights, shift, kk,
                         plan, *, b_true, ub=None):
        """Block-max pruned resident execution (exact; see ROADMAP).

        Three stages, under either planner:

        1. **Seed** — the full fragment table is compacted down to the few
           highest-upper-bound blocks and scored through the single-buffer
           resident kernel; the resulting scoreboard's k-th row is a REAL
           document's full score per query, i.e. a certified lower bound
           on each final k-th score (the threshold τ).
        2. **Compact** — fragments of blocks whose summed query-side upper
           bound beats τ for NO query are compacted out of the table
           before launch (the seed blocks always survive: each holds a
           document scoring ≥ its own bound's τ contribution), and the
           fragment bucket re-sizes so the kernel grid shrinks with the
           surviving work.
        3. **Skip** — the survivors run through the pruned kernel, whose
           per-fragment scoreboard test keeps cutting DMAs as the running
           threshold saturates past the seed estimate mid-launch.

        Under ``plan="host"`` the bound matmul/compaction run on numpy
        and the compacted table + bound rows ship as descriptors; under
        ``plan="device"`` everything is derived from the resident
        block-max table and CSC arrays — zero descriptor bytes, same as
        the unpruned device plan. Default-document ids always come from
        the UNPRUNED visited-block set: a pruned block's documents score
        below τ, not zero.
        """
        import jax.numpy as jnp

        from ..core.retrieval import default_doc_ids
        from ..core.scoring import bucket_pow2
        from ..kernels import ops
        from ..kernels.bm25_gather_score import bm25_resident_score_topk
        from ..sparse.block_csr import (block_upper_bounds, fragment_plan,
                                        prune_fragment_plan,
                                        put_descriptor_array,
                                        select_seed_blocks)
        bm = self.dindex.bmax
        rblock = self.dindex.block_size
        frag = self.dindex.frag
        w_dev = jnp.asarray(weights)
        csc_doc, csc_sc = self.dindex.csc_doc_ids, self.dindex.csc_scores
        if self.plan_mode == "device":
            from ..sparse.fragment_device import (block_bounds_device,
                                                  compact_fragment_table,
                                                  plan_fragments_device,
                                                  prune_fragment_mask,
                                                  seed_fragment_mask)
            desc_full, dids, _ = plan_fragments_device(
                self.dindex, uniq_tab, sum_df=plan.sum_df, k=kk,
                block_size=rblock, state=self._nf_state)
            nf_planned = int(np.asarray((desc_full[1] > 0).sum()))
            ub_dev = block_bounds_device(
                bm.device, bm.scale_dev,
                jnp.asarray(np.asarray(uniq_tab, np.int32)), w_dev,
                quantized=bm.quantized)
            # pow2 batch-padding columns are sliced off after retrieval —
            # their trivial thresholds must not veto pruning (real empty
            # queries keep theirs: their all-tied folds must replay
            # exactly)
            col = jnp.arange(ub_dev.shape[1], dtype=jnp.int32)
            ub_dev = jnp.where(col[None, :] < b_true, ub_dev, -jnp.inf)
            from ..sparse.block_csr import seed_block_budget
            seed_keep = seed_fragment_mask(desc_full, ub_dev,
                                           n_seed=seed_block_budget(kk))
            seed_desc, n_sk = compact_fragment_table(desc_full, seed_keep)
            sb = bucket_pow2(max(int(n_sk), 1), floor=8)
            sv, _ = bm25_resident_score_topk(
                seed_desc[:, :sb], w_dev, csc_doc, csc_sc,
                block_size=rblock, frag=frag, k=kk, n_docs=self.n_docs,
                double_buffer=False)
            tau = sv[kk - 1]
            keep = prune_fragment_mask(desc_full, ub_dev, tau)
            desc_c, n_kp = compact_fragment_table(desc_full, keep)
            nf_surv = int(n_kp)
            desc = desc_c[:, :bucket_pow2(max(nf_surv, 1), floor=8)]
            bounds = ub_dev[desc[3], :]
        else:
            fp = fragment_plan(self.index, uniq_batch, block_size=rblock,
                               frag=frag)
            nf_planned = fp.n_frags
            if ub is None:
                ub = block_upper_bounds(bm, uniq_tab, weights)
                ub[:, b_true:] = -np.inf      # see device branch comment
            dids = jnp.asarray(default_doc_ids(fp.vis_blocks, kk,
                                               self.n_docs, rblock))
            if fp.n_frags:
                seed_keep = select_seed_blocks(ub, fp.vis_blocks, k=kk,
                                               block_size=rblock)
                seed_fp = prune_fragment_plan(fp, seed_keep)
                sv, _ = bm25_resident_score_topk(
                    put_descriptor_array(seed_fp.desc), w_dev, csc_doc,
                    csc_sc, block_size=rblock, frag=frag, k=kk,
                    n_docs=self.n_docs, double_buffer=False)
                tau = np.asarray(sv)[kk - 1]                 # [B]
                pf = prune_fragment_plan(fp, (ub >= tau[None, :]).any(1))
            else:
                pf = fp
            nf_surv = pf.n_frags
            desc = put_descriptor_array(pf.desc)
            bounds = put_descriptor_array(ub[pf.desc[3]])
        ids, vals, skipped = ops.bm25_retrieve_resident_pruned(
            desc, w_dev, csc_doc, csc_sc, bounds, dids,
            jnp.asarray(shift), block_size=rblock, frag=frag, k=kk,
            n_docs=self.n_docs)
        plan.frags_planned = nf_planned
        plan.frags_pruned = nf_planned - nf_surv
        plan.frags_skipped = int(skipped)
        return ids, vals


# -- deprecated regime aliases -------------------------------------------
#
# The forced-regime subclasses predate ``DeviceRetriever(regime=...)``;
# they add nothing the keyword does not, so they are deprecation shims
# now. Each warns ONCE per process (a fleet constructing thousands of
# shard scorers should not drown its logs), tracked in ``_ALIAS_WARNED``;
# tests reset it via :func:`_reset_alias_warnings`.

_ALIAS_WARNED: set[str] = set()


def _reset_alias_warnings() -> None:
    """Re-arm the once-per-alias deprecation warnings (test hook)."""
    _ALIAS_WARNED.clear()


def _warn_alias(name: str, regime: str) -> None:
    if name in _ALIAS_WARNED:
        return
    _ALIAS_WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use DeviceRetriever(index, "
        f"regime={regime!r}) instead",
        DeprecationWarning, stacklevel=3)


class BlockedRetriever(DeviceRetriever):
    """Deprecated alias for ``DeviceRetriever(regime="blocked")``."""

    def __init__(self, index: BM25Index, *, block_size: int = 512,
                 tile: int = 512, q_max: int = 32, **kwargs):
        _warn_alias("BlockedRetriever", "blocked")
        super().__init__(index, regime="blocked", block_size=block_size,
                         tile=tile, q_max=q_max, **kwargs)


class GatheredRetriever(DeviceRetriever):
    """Deprecated alias for ``DeviceRetriever(regime="gathered")``."""

    def __init__(self, index: BM25Index, *, tile: int = 512,
                 acc_block: int = 512, q_max: int = 32, **kwargs):
        _warn_alias("GatheredRetriever", "gathered")
        super().__init__(index, regime="gathered", tile=tile,
                         acc_block=acc_block, q_max=q_max, **kwargs)


class PrunedRetriever(DeviceRetriever):
    """Deprecated alias for ``DeviceRetriever(regime="pruned")``."""

    def __init__(self, index: BM25Index, *, tile: int = 512,
                 q_max: int = 32, **kwargs):
        _warn_alias("PrunedRetriever", "pruned")
        super().__init__(index, regime="pruned", tile=tile, q_max=q_max,
                         **kwargs)


# partials, not the alias classes: engine-internal construction must not
# fire the deprecation warnings users are being migrated off of
_SCORERS = {"scipy": ScipyBM25, "auto": DeviceRetriever,
            "blocked": partial(DeviceRetriever, regime="blocked"),
            "gathered": partial(DeviceRetriever, regime="gathered"),
            "pruned": partial(DeviceRetriever, regime="pruned")}


@dataclass
class ShardRuntime:
    """One shard's scorer (thread-simulated shard server)."""

    index: BM25Index
    delay: Callable[[], float] | None = None     # test hook: seconds to sleep
    scorer: str = "scipy"          # "scipy"|"auto"|"blocked"|"gathered"
    scorer_opts: dict = field(default_factory=dict)  # device-scorer kwargs

    def __post_init__(self):
        if self.scorer not in _SCORERS:
            raise RetrievalConfigError(f"unknown scorer {self.scorer!r}; "
                                       f"available: {sorted(_SCORERS)}")
        self._scorer = _SCORERS[self.scorer](self.index, **self.scorer_opts)

    def health(self) -> dict:
        """Schema-2 health report for this shard (see ``repro.serve``
        package docstring). ``served``/``degraded`` count this shard's
        batches (the scipy reference scorer has no counters — zeros)."""
        sc = self._scorer
        return health_envelope(
            served=getattr(sc, "batches_served", 0),
            degraded=getattr(sc, "batches_degraded", 0),
            faults=dict(getattr(sc, "fault_counters", {})),
            queries=dict(getattr(sc, "query_counters", {})),
            scorer=self.scorer,
            batches_served=getattr(sc, "batches_served", 0),
            batches_degraded=getattr(sc, "batches_degraded", 0),
            degradations=dict(getattr(sc, "degradation_counts", {})),
            snapshot=dict(
                getattr(getattr(sc, "dindex", None), "snapshot_report",
                        None)
                or getattr(self.index, "snapshot_report", None) or {}),
        )

    def warmup(self, k: int) -> None:
        """Pre-compile the device scorer so query #1 skips compilation."""
        fn = getattr(self._scorer, "warmup", None)
        if fn is not None:
            fn(k=k)

    def topk(self, query_tokens: np.ndarray, k: int
             ) -> tuple[np.ndarray, np.ndarray]:
        if self.delay is not None:
            time.sleep(self.delay())
        return self._scorer.retrieve(query_tokens, k)

    def topk_batch(self, query_batch: Sequence[np.ndarray], k: int
                   ) -> tuple[np.ndarray, np.ndarray]:
        """[B queries] -> (ids [B, k'], scores [B, k']) for this shard."""
        if self.delay is not None:
            time.sleep(self.delay())
        fn = getattr(self._scorer, "retrieve_batch", None)
        if fn is not None:                       # one kernel launch for B
            return fn(query_batch, k)
        parts = [self._scorer.retrieve(q, k) for q in query_batch]
        kk = min((p[0].size for p in parts), default=0)
        ids = np.stack([p[0][:kk] for p in parts]) if parts else \
            np.zeros((0, 0), np.int64)
        sc = np.stack([p[1][:kk] for p in parts]) if parts else \
            np.zeros((0, 0), np.float32)
        return ids.astype(np.int64), sc.astype(np.float32)


def _same_shard(a: BM25Index, b: BM25Index) -> bool:
    """Byte-identical postings, doc range AND shift vector — safe to keep
    the resident device arrays of ``a``'s runtime for ``b``. ``doc_lens``
    must match too: a boundary moving through posting-less documents
    changes the shard's doc range without changing a single posting, and
    reusing the old runtime would then serve documents a neighbor shard
    now owns (duplicate results after the merge)."""
    return a is b or (
        int(a.doc_offset) == int(b.doc_offset)
        and np.array_equal(a.doc_lens, b.doc_lens)
        and np.array_equal(a.indptr, b.indptr)
        and np.array_equal(a.doc_ids, b.doc_ids)
        and np.array_equal(a.scores, b.scores)
        and np.array_equal(a.nonoccurrence, b.nonoccurrence))


class RetrievalEngine:
    def __init__(self, shards: Sequence[BM25Index], *, k: int = 10,
                 deadline_s: float = 0.5, quorum: float = 0.75,
                 max_workers: int = 8,
                 delay: Callable[[int], Callable[[], float] | None] = None,
                 scorer: str = "scipy", warmup: bool = True,
                 scorer_opts: dict | None = None,
                 device_indexes: Sequence | None = None):
        self.k = k
        self.deadline_s = deadline_s
        self.quorum = quorum
        self.scorer = scorer
        self.scorer_opts = dict(scorer_opts or {})
        self.warmup = warmup
        self._delay_factory = delay
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self.query_counters: dict[str, int] = {}
        self._responses = 0
        self._degraded_responses = 0
        # pre-built per-shard DeviceIndexes (snapshot cold-start via
        # ``RetrievalEngine.load``) — adopted by the FIRST build only;
        # rescale re-buckets postings, so loaded runtimes can't outlive it
        self._adopt = list(device_indexes or [])
        if self._adopt and len(self._adopt) != len(shards):
            raise RetrievalConfigError(
                f"device_indexes has {len(self._adopt)} entries for "
                f"{len(shards)} shards")
        self._build_runtimes(list(shards))

    def _build_runtimes(self, shards: list[BM25Index]) -> None:
        """(Re)build shard runtimes, REUSING any whose postings didn't move.

        Rescale re-uploads only the shards whose postings changed: a
        runtime whose index is byte-identical to a new shard keeps its
        device-resident arrays and compiled-fn cache (no re-upload, no
        re-warmup). ``last_build_stats`` records the split — a same-count
        rescale reuses everything, a boundary-moving one rebuilds only the
        moved shards.
        """
        from ..sparse.block_csr import DeviceIndex
        old = list(getattr(self, "runtimes", []))
        pool: dict[tuple, list[ShardRuntime]] = {}
        for rt in old:
            key = (int(rt.index.doc_offset), int(rt.index.doc_ids.size))
            pool.setdefault(key, []).append(rt)
        runtimes, reused, blockmax_reused = [], 0, 0
        for i, s in enumerate(shards):
            delay = self._delay_factory(i) if self._delay_factory else None
            cands = pool.get((int(s.doc_offset), int(s.doc_ids.size)), [])
            hit = next((rt for rt in cands if _same_shard(rt.index, s)),
                       None)
            if hit is not None:
                cands.remove(hit)
                hit.delay = delay
                runtimes.append(hit)
                reused += 1
                continue
            opts = self.scorer_opts
            if self.scorer != "scipy":
                # incremental re-blocking: a boundary that moved through
                # posting-LESS documents changes a shard's doc range but
                # not one posting byte — the runtime cannot be reused
                # wholesale (global ids shift), but its resident layouts
                # and block-max table can (they depend only on the local
                # postings), so the rebuild re-uploads nothing
                donor = next(
                    (rt for rt in old
                     if getattr(rt._scorer, "dindex", None) is not None
                     and DeviceIndex._postings_identical(s, rt.index)),
                    None)
                if donor is not None:
                    opts = {**opts, "reuse_from": donor._scorer.dindex}
                if i < len(self._adopt) and self._adopt[i] is not None:
                    opts = {**opts, "device_index": self._adopt[i]}
            rt = ShardRuntime(s, delay=delay, scorer=self.scorer,
                              scorer_opts=opts)
            di = getattr(rt._scorer, "dindex", None)
            if di is not None and di.reused and (
                    di.reused.get("bmax") or di.reused.get("blocked")):
                blockmax_reused += 1
            if self.warmup:
                # compile the device scorers at BUILD time (and after every
                # rescale) so the first live query never pays jit
                # compilation — on the floor buckets, which absorb typical
                # traffic.
                rt.warmup(self.k)
            runtimes.append(rt)
        self.shards = shards
        self.runtimes = runtimes
        self._adopt = []                  # adoption is first-build-only
        self.last_build_stats = {"reused": reused,
                                 "built": len(shards) - reused,
                                 "blockmax_reused": blockmax_reused}

    # -- control plane ------------------------------------------------------
    def rescale(self, n_shards: int) -> None:
        """Elastic re-shard (device pool grew or shrank)."""
        self._build_runtimes(reshard_index(self.shards, n_shards))

    ENGINE_FORMAT = "repro-bm25s-engine"
    ENGINE_VERSION = 1

    def save(self, path: str, *, algo: str | None = None) -> dict:
        """Snapshot every shard runtime + the engine config under ``path``.

        Layout: ``engine.json`` (config, written last — tmp + fsync +
        ``os.replace``) next to one ``shard-NNNN/`` snapshot root per
        runtime, each an atomic generation store (see ``sparse.snapshot``).
        Device runtimes persist their resident layouts
        (``save_device_index``: padded CSC + blocked + block-max, every
        file memmap-able); scipy runtimes persist the bare index
        (``save_index``). Re-saving into the same path adds a generation
        per shard and rewrites ``engine.json`` — a crash mid-save leaves
        every shard's previous generation committed.
        """
        import json
        import os

        from ..sparse import snapshot
        os.makedirs(path, exist_ok=True)
        for i, rt in enumerate(self.runtimes):
            sdir = os.path.join(path, f"shard-{i:04d}")
            di = getattr(rt._scorer, "dindex", None)
            if di is not None:
                snapshot.save_device_index(di, sdir,
                                           index=rt._scorer.index,
                                           algo=algo)
            else:
                snapshot.save_index(rt.index, sdir, algo=algo)
        body = {"format": self.ENGINE_FORMAT,
                "version": self.ENGINE_VERSION,
                "n_shards": len(self.runtimes), "k": self.k,
                "deadline_s": self.deadline_s, "quorum": self.quorum,
                "scorer": self.scorer}
        data = json.dumps(body, indent=1, sort_keys=True).encode("utf-8")
        tmp = os.path.join(path, "engine.json.tmp")
        with open(tmp, "wb") as fh:
            fh.write(data)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, os.path.join(path, "engine.json"))
        return body

    @classmethod
    def load(cls, path: str, *, mmap: bool = False,
             host_arrays: str = "keep", verify: bool = True, corpus=None,
             **kwargs) -> "RetrievalEngine":
        """Cold-start an engine from :meth:`save` — no shard rebuilds.

        Device shards come back through ``sparse.snapshot
        .load_device_index`` (checksummed read, memmap when ``mmap=True``,
        resident arrays uploaded straight from the files) and are ADOPTED
        by their runtimes via ``device_index=`` — ``DeviceIndex.build``
        never runs. Scipy shards come back through ``load_index``.
        ``corpus`` (the full tokenized corpus) arms the last recovery
        rung: each shard slices its own document range out of it.
        ``kwargs`` override the saved engine config
        (``RetrievalEngine.__init__`` keywords).
        """
        import json
        import os

        from ..sparse import snapshot
        with open(os.path.join(path, "engine.json"),
                  encoding="utf-8") as fh:
            cfg = json.load(fh)
        if cfg.get("format") != cls.ENGINE_FORMAT:
            from .errors import SnapshotVersionError
            raise SnapshotVersionError(
                f"{path}: not a {cls.ENGINE_FORMAT} store "
                f"(format={cfg.get('format')!r})")
        v = cfg.get("version")
        if not isinstance(v, int) or not 1 <= v <= cls.ENGINE_VERSION:
            from .errors import SnapshotVersionError
            raise SnapshotVersionError(
                f"{path}: engine store version {v!r} not supported")
        scorer = kwargs.pop("scorer", cfg["scorer"])
        opts = dict(k=cfg["k"], deadline_s=cfg["deadline_s"],
                    quorum=cfg["quorum"])
        opts.update(kwargs)
        shards, dis = [], []
        for i in range(int(cfg["n_shards"])):
            sdir = os.path.join(path, f"shard-{i:04d}")
            # corpus is the FULL corpus — each shard's loader slices its
            # own manifest-recorded doc range with global stats
            if scorer == "scipy":
                shards.append(snapshot.load_index(sdir, mmap=mmap,
                                                  verify=verify,
                                                  corpus=corpus))
            else:
                di = snapshot.load_device_index(sdir, mmap=mmap,
                                                host_arrays=host_arrays,
                                                verify=verify,
                                                corpus=corpus)
                host = di.host
                perm = getattr(di, "perm", None)
                if perm is not None and host is not None:
                    # engine shards stay in CLIENT doc order — rescale's
                    # reshard_index and the shard-reuse keys operate on
                    # global client ids; the adopted DeviceIndex keeps
                    # its permuted host for the retriever
                    from ..sparse.reorder import unpermute_index
                    host = unpermute_index(host, perm)
                shards.append(host)
                dis.append(di)
        return cls(shards, scorer=scorer,
                   device_indexes=dis if dis else None, **opts)

    def health(self) -> dict:
        """One operational snapshot of the engine's fault surface.

        Fields (see ROADMAP "Fault tolerance"):

        Schema-2 envelope (see ``repro.serve`` package docstring):
        ``served``/``degraded`` count scatter-gather rounds, and how many
        missed shards (quorum+deadline hedging); ``faults`` aggregates
        the per-shard typed-fault counts; ``queries`` are the
        engine-boundary sanitizer counters. Engine extras:

        * ``responses`` / ``degraded_responses`` — legacy spellings of
          ``served`` / ``degraded``;
        * ``build`` — the last ``_build_runtimes`` reuse split;
        * ``shards`` — per-shard :meth:`ShardRuntime.health`: ladder
          degradation counts keyed ``"from->to"``, typed-fault counts
          keyed by error class, and the shard's own sanitizer counters.
        """
        shard_reports = [rt.health() for rt in self.runtimes]
        return health_envelope(
            served=self._responses,
            degraded=self._degraded_responses,
            faults=merge_fault_counts(shard_reports),
            queries=self.query_counters,
            responses=self._responses,
            degraded_responses=self._degraded_responses,
            build=dict(self.last_build_stats),
            shards=shard_reports,
        )

    # -- data plane ----------------------------------------------------------
    def _scatter_gather(self, submit, merge, k: int):
        """Shared hedged scatter-gather: quorum + deadline + merge."""
        t0 = time.time()
        futures = {submit(rt): i for i, rt in enumerate(self.runtimes)}
        need = max(1, int(np.ceil(self.quorum * len(self.runtimes))))
        done: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        pending = set(futures)
        deadline = t0 + self.deadline_s
        while pending:
            timeout = deadline - time.time()
            if timeout <= 0 and len(done) >= need:
                break                     # quorum met, deadline passed
            finished, pending = wait(
                pending, timeout=max(timeout, 0.005),
                return_when=FIRST_COMPLETED)
            for f in finished:
                done[futures[f]] = f.result()
            if not finished and len(done) >= need:
                break
        for f in pending:                 # backfill continues off-path
            f.cancel()
        ids, scores = merge(done.values(), k)
        degraded = len(done) < len(self.runtimes)
        self._responses += 1
        self._degraded_responses += int(degraded)
        latency = time.time() - t0
        return RetrievalResult(
            ids=ids, scores=scores, degraded=degraded,
            shards_answered=len(done), latency_s=latency,
            timings={"total_s": latency})

    def _sanitize(self, query_batch):
        """Engine-boundary pass of the shared sanitizer — covers scipy
        runtimes (which have no device-scorer validation of their own)."""
        from ..core.retrieval import validate_query_batch
        n_vocab = self.shards[0].n_vocab if self.shards else 0
        return validate_query_batch(query_batch, n_vocab,
                                    counters=self.query_counters)

    def retrieve(self, query_tokens: np.ndarray, *, k: int | None = None
                 ) -> RetrievalResult:
        k = k or self.k
        query_tokens = self._sanitize([query_tokens])[0]
        return self._scatter_gather(
            lambda rt: self._pool.submit(rt.topk, query_tokens, k),
            self._merge, k)

    def retrieve_batch(self, query_batch: Sequence[np.ndarray], *,
                       k: int | None = None) -> RetrievalResult:
        """B queries in one hedged scatter-gather round.

        Each shard serves the whole batch in ONE device launch
        (``ShardRuntime.topk_batch``), so kernel-launch and query-table
        costs amortize over B; the merge is the batched stage-2
        (``core.retrieval.merge_topk_batch``). Returns a single
        :class:`RetrievalResult` with ``ids``/``scores`` of shape [B, k].
        """
        k = k or self.k
        query_batch = self._sanitize(query_batch)
        return self._scatter_gather(
            lambda rt: self._pool.submit(rt.topk_batch, query_batch, k),
            self._merge_batch, k)

    @staticmethod
    def _merge(parts, k: int) -> tuple[np.ndarray, np.ndarray]:
        # stage-2 of the paper's two-stage top-k, vectorized in
        # core.retrieval.merge_topk (concatenate + argpartition).
        return merge_topk(parts, k)

    @staticmethod
    def _merge_batch(parts, k: int) -> tuple[np.ndarray, np.ndarray]:
        from ..core.retrieval import merge_topk_batch
        return merge_topk_batch(parts, k)
