"""The ONE result type every retrieval entry point returns.

Before this module the serving surface spoke three dialects: the device
scorers returned bare ``(ids, scores)`` tuples, the engine returned its
own ad-hoc dataclass, and the evidence a batch was served on (the
planner's regime decision, the degradation trail, where the time went)
lived in side channels (``retriever.last_plan``) that a caller holding
only the return value could not reach. :class:`RetrievalResult` unifies
them:

* ``ids`` / ``scores`` — the ``[B, k]`` (batched) or ``[k]``
  (single-query) winner board, exactly what the bare tuples carried;
* ``plan`` — the :class:`~repro.core.retrieval.RetrievalPlan` this batch
  executed under (None for scorers that do not plan, e.g. scipy shards);
* ``degradations`` — the exact-fallback-ladder trail for THIS response
  (``[{"from", "to", "error", "detail"}, ...]``, empty on the healthy
  path; see ROADMAP "Fault tolerance");
* ``timings`` — seconds per serving stage, keyed by stage name
  (``"total_s"`` always present; the micro-batching frontend adds
  ``"queue_s"``/``"pack_s"``/``"execute_s"``);
* ``degraded`` / ``shards_answered`` / ``latency_s`` — the engine-level
  hedging fields the old engine dataclass carried (single-retriever
  results leave ``shards_answered`` None and set ``degraded`` iff the
  ladder hopped).

**Tuple-unpack compatibility**: the result iterates (and indexes) as the
legacy two-tuple, in the ORDER the old API returned —

    ids, scores = retriever.retrieve_batch(queries, k)

keeps working unchanged, as do ``result[0]``/``result[1]`` and
``merge_topk``-style ``for ids, scores in parts`` consumers. New code
should prefer the named fields.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class RetrievalResult:
    """Winner board + the evidence it was produced on (see module doc).

    Unpacks as the legacy ``(ids, scores)`` tuple for backward
    compatibility; every other field is keyword-accessible metadata.
    """

    ids: np.ndarray
    scores: np.ndarray
    plan: object | None = None
    degradations: list = field(default_factory=list)
    timings: dict = field(default_factory=dict)
    degraded: bool = False
    shards_answered: int | None = None
    latency_s: float | None = None

    def __iter__(self):
        """Legacy two-tuple protocol: ``ids, scores = result``."""
        yield self.ids
        yield self.scores

    def __len__(self) -> int:
        return 2

    def __getitem__(self, i):
        """Legacy indexing: ``result[0]`` is ids, ``result[1]`` scores."""
        return (self.ids, self.scores)[i]


@dataclass
class PackedBatch:
    """One batch's host-side pack, ready for device execution.

    The output of :meth:`DeviceRetriever.pack_batch` — the sanitized
    query list plus every pow2-bucketed device table ``_pack_batch``
    builds (see that docstring for the bucketing invariants). Splitting
    the pack off the launch is what lets the micro-batching frontend
    overlap host pack of batch i+1 with device execution of batch i
    (the double-buffer idiom one level above the kernel DMAs):
    ``retrieve_batch(..., packed=...)`` resumes exactly where
    ``pack_batch`` stopped, so pack-then-execute is bit-identical to the
    one-call path by construction.
    """

    qs: list                     # sanitized queries (validate_query_batch)
    b: int                       # true batch size (pre pow2 padding)
    uniq_batch: np.ndarray       # batch-unique token ids (sorted)
    uniq_tab: np.ndarray         # [u_max] padded unique-token table
    weights: np.ndarray          # [u_max, B_pad] per-query token weights
    shift: np.ndarray            # [B_pad] nonoccurrence shifts
    pack_s: float = 0.0          # host seconds spent packing


__all__ = ["RetrievalResult", "PackedBatch"]
