"""Synthetic retrieval corpora with planted relevance.

The BEIR datasets are not available offline, so benchmarks (Tables 1-3)
run on procedurally generated corpora whose *relevance structure is known
by construction*: documents are drawn from per-topic word distributions;
queries sample salient words of one topic; qrels = documents of that topic.
NDCG@10 and QPS are then measured exactly like the paper does per dataset.

Two generators:
  * ``SyntheticCorpus`` — text-level (real strings through the real
    tokenizer; exercises stopwords/stemming like Table 2);
  * ``zipf_corpus`` — id-level Zipfian postings for scale benchmarks
    (Table 1 throughput; millions of documents without string overhead).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

_SYLLABLES = ("ba be bi bo bu da de di do du fa fe fi fo fu ga ge gi go gu "
              "ka ke ki ko ku la le li lo lu ma me mi mo mu na ne ni no nu "
              "pa pe pi po pu ra re ri ro ru sa se si so su ta te ti to tu "
              "va ve vi vo vu za ze zi zo zu").split()


def _word(rng: np.random.Generator) -> str:
    n = rng.integers(2, 5)
    return "".join(rng.choice(_SYLLABLES) for _ in range(n))


@dataclass
class SyntheticCorpus:
    """Topic-model corpus: known relevance for NDCG, realistic Zipf tails."""

    n_docs: int = 2000
    n_topics: int = 20
    vocab_size: int = 2000
    doc_len: tuple[int, int] = (20, 120)
    query_len: tuple[int, int] = (2, 6)
    seed: int = 0
    documents: list[str] = field(default_factory=list)
    doc_topics: np.ndarray | None = None

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        words = np.array([_word(rng) for _ in range(self.vocab_size)])
        # Zipfian global frequencies + topic-salient word subsets
        zipf = 1.0 / np.arange(1, self.vocab_size + 1)
        self._topic_words = [
            rng.choice(self.vocab_size, size=60, replace=False)
            for _ in range(self.n_topics)
        ]
        self.doc_topics = rng.integers(0, self.n_topics, size=self.n_docs)
        docs = []
        for i in range(self.n_docs):
            t = self.doc_topics[i]
            length = int(rng.integers(*self.doc_len))
            n_topic = length // 3          # 1/3 topical, 2/3 background
            topical = rng.choice(self._topic_words[t], size=n_topic)
            backgr = rng.choice(self.vocab_size, size=length - n_topic,
                                p=zipf / zipf.sum())
            ids = np.concatenate([topical, backgr])
            rng.shuffle(ids)
            docs.append(" ".join(words[ids]))
        self.documents = docs
        self._words = words
        self._rng = rng

    def queries_with_qrels(self, n_queries: int
                           ) -> tuple[list[str], list[np.ndarray]]:
        """Queries targeting one topic each; qrels = that topic's docs."""
        qs, rels = [], []
        for _ in range(n_queries):
            t = int(self._rng.integers(0, self.n_topics))
            k = int(self._rng.integers(*self.query_len))
            ids = self._rng.choice(self._topic_words[t], size=k)
            qs.append(" ".join(self._words[ids]))
            rels.append(np.where(self.doc_topics == t)[0])
        return qs, rels


def zipf_corpus(n_docs: int, n_vocab: int, *, avg_len: int = 100,
                seed: int = 0, alpha: float = 1.07) -> list[np.ndarray]:
    """Id-level Zipf corpus for throughput benchmarks (no strings)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_vocab + 1, dtype=np.float64)
    p = ranks ** -alpha
    p /= p.sum()
    lens = np.maximum(1, rng.poisson(avg_len, size=n_docs))
    return [rng.choice(n_vocab, size=int(l), p=p).astype(np.int32)
            for l in lens]


def zipf_queries(n_queries: int, n_vocab: int, *, q_len: int = 5,
                 seed: int = 1, alpha: float = 1.07) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_vocab + 1, dtype=np.float64)
    p = ranks ** -alpha
    p /= p.sum()
    return [rng.choice(n_vocab, size=q_len, p=p).astype(np.int32)
            for _ in range(n_queries)]


def ndcg_at_k(ranked_ids: np.ndarray, relevant: np.ndarray, k: int = 10
              ) -> float:
    """Binary-relevance NDCG@k."""
    rel = np.isin(ranked_ids[:k], relevant).astype(np.float64)
    dcg = (rel / np.log2(np.arange(2, rel.size + 2))).sum()
    ideal = min(k, relevant.size)
    idcg = (1.0 / np.log2(np.arange(2, ideal + 2))).sum()
    return float(dcg / idcg) if idcg > 0 else 0.0
