import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

DOC = """Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract the roofline terms from the compiled artifact.

For each cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=...).lower(*abstract_args)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective parse (as_text)

Results append incrementally to a JSON file (benchmarks/out/dryrun.json by
default) so a long sweep survives interruption; EXPERIMENTS.md §Dry-run and
§Roofline are generated from it.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod both] [--out FILE]
"""

import argparse
import json
import re
import time
import traceback


# TPU v5e hardware model (per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s+(\w[\w\d\.\-]*)\s+"                      # result shape or tuple
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string like 'bf16[16,4096]' or a tuple."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective bytes from post-SPMD optimized HLO.

    Wire-cost model (per device): all-reduce ≈ 2× payload (ring
    reduce-scatter + all-gather), others ≈ 1× the op's result payload.
    ``-start``/``-done`` pairs are counted once (on the start).
    """
    per_op: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(
            r"=\s+(.+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all"
            r"|collective-permute)(-start|-done)?\(", line)
        if not m or m.group(3) == "-done":
            continue
        shape_str, op = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        wire = 2 * nbytes if op == "all-reduce" else nbytes
        d = per_op.setdefault(op, {"count": 0, "bytes": 0, "wire_bytes": 0})
        d["count"] += 1
        d["bytes"] += nbytes
        d["wire_bytes"] += wire
    total_wire = sum(d["wire_bytes"] for d in per_op.values())
    return {"per_op": per_op, "wire_bytes": total_wire}


def roofline(flops_global: float, bytes_global: float, coll_wire_dev: float,
             n_chips: int, model_flops: float) -> dict:
    """Three roofline terms (seconds) + bottleneck + useful-compute ratio.

    ``flops_global``/``bytes_global`` come from the loop-aware jaxpr walk
    (whole step, all devices); per-device = /n_chips under the cell's
    sharding. ``coll_wire_dev`` is per-device wire bytes from the
    loop-multiplied HLO parse.
    """
    flops_dev = flops_global / n_chips
    bytes_dev = bytes_global / n_chips
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_wire_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    return {
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_wire_bytes_per_device": coll_wire_dev,
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / flops_global
                               if flops_global else 0.0),
        "step_time_bound_s": max(terms.values()),
        "roofline_fraction": (
            (model_flops / (n_chips * PEAK_FLOPS_BF16)) /
            max(max(terms.values()), 1e-30)),
    }


def run_cell(cell, mesh, *, verbose: bool = True) -> dict:
    import jax

    from ..dist.sharding import activation_sharding
    from .costs import collective_bytes_multiplied, traced_cost

    t0 = time.time()
    if cell.remesh is not None:
        mesh = cell.remesh(mesh)
    fn, args = cell.build(mesh)
    in_shardings = cell.shardings(mesh, args)
    with mesh, activation_sharding(mesh):
        lowered = jax.jit(fn, in_shardings=in_shardings).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        try:
            mem = compiled.memory_analysis()
            mem_d = {
                "argument_size_b": getattr(mem, "argument_size_in_bytes", 0),
                "output_size_b": getattr(mem, "output_size_in_bytes", 0),
                "temp_size_b": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_size_b":
                    getattr(mem, "generated_code_size_in_bytes", 0),
            }
        except Exception:
            mem_d = {}
        cost_list = compiled.cost_analysis()
        xla_cost = cost_list if isinstance(cost_list, dict) else cost_list[0]
        text = compiled.as_text()
        # loop-aware global flops/bytes from the jaxpr (see costs.py)
        jc = traced_cost(fn, args, n_shards=mesh.size)
    coll = collective_bytes_multiplied(text)
    n_chips = mesh.size
    roof = roofline(jc["flops"], jc["bytes"], coll["wire_bytes"],
                    n_chips, cell.model_flops)
    rec = {
        "arch": cell.arch, "shape": cell.shape, "kind": cell.kind,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.shape),
        "axes": list(mesh.shape.keys()), "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_d, "collectives": coll["per_op"],
        "xla_cost_flops_bodies_once": float(xla_cost.get("flops", 0.0)),
        "xla_cost_bytes_bodies_once":
            float(xla_cost.get("bytes accessed", 0.0)),
        **roof,
        "note": cell.note, "ok": True,
    }
    if verbose:
        per_dev = (mem_d.get("argument_size_b", 0)
                   + mem_d.get("temp_size_b", 0)) / 2**30
        print(f"[dryrun] {cell.key:42s} mesh={rec['mesh']:9s} "
              f"bottleneck={rec['bottleneck']:10s} "
              f"t_bound={rec['step_time_bound_s']:.3e}s "
              f"mem/dev={per_dev:.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
    return rec


def load_results(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {}


def save_result(path: str, key: str, rec: dict) -> None:
    results = load_results(path)
    results[key] = rec
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1)
    os.replace(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="both")
    ap.add_argument("--out", default="benchmarks/out/dryrun.json")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--include-extra", action="store_true",
                    help="include the bm25s extra cells in --all")
    args = ap.parse_args()

    from ..configs import all_cells, get_cells
    from .mesh import make_production_mesh

    if args.all:
        cells = all_cells(include_extra=args.include_extra)
    elif args.arch:
        cells = get_cells(args.arch)
        if args.shape:
            cells = [c for c in cells if c.shape == args.shape]
    else:
        ap.error("--arch or --all required")

    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    done = load_results(args.out) if args.skip_done else {}
    failures = []
    for multi_pod in pods:
        mesh = make_production_mesh(multi_pod=multi_pod)
        tag = "2x16x16" if multi_pod else "16x16"
        for cell in cells:
            key = f"{cell.key}@{tag}"
            if key in done and done[key].get("ok"):
                print(f"[dryrun] skip {key} (done)", flush=True)
                continue
            try:
                rec = run_cell(cell, mesh)
            except Exception as e:  # record failures, keep sweeping
                rec = {"arch": cell.arch, "shape": cell.shape,
                       "mesh": tag, "ok": False, "error": repr(e),
                       "traceback": traceback.format_exc()[-2000:]}
                failures.append(key)
                print(f"[dryrun] FAIL {key}: {e!r}", flush=True)
            save_result(args.out, key, rec)
    print(f"[dryrun] complete; {len(failures)} failures: {failures}",
          flush=True)


if __name__ == "__main__":
    main()
