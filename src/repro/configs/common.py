"""Cell plumbing: every (architecture × input shape) becomes a ``Cell`` the
dry-run / benchmarks / tests can lower uniformly.

A Cell knows how to build its step function and abstract (ShapeDtypeStruct)
arguments lazily — nothing touches jax device state at import time — plus
how to produce ``in_shardings`` for a given mesh and a MODEL_FLOPS estimate
for the roofline's useful-compute ratio.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..dist.sharding import batch_pspec, data_axes, param_pspecs
from ..models import egnn, recsys, transformer
from ..train.optimizer import AdamW, cosine_schedule
from ..train.step import make_train_step


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


@dataclass
class Cell:
    arch: str
    shape: str
    kind: str                       # train | prefill | decode | serve | retrieval
    build: Callable                 # (mesh) -> (fn, args pytree of SDS)
    shardings: Callable             # (mesh, args) -> in_shardings pytree
    model_flops: float              # useful FLOPs per step (global, fwd[+bwd])
    note: str = ""
    remesh: Callable | None = None  # (mesh) -> mesh: logical re-mesh of the
                                    # SAME devices (perf variants only)

    @property
    def key(self) -> str:
        return f"{self.arch}/{self.shape}"


def remesh_dp_tp(dp: int, tp: int) -> Callable:
    """Re-map the production pod's devices onto a (data=dp, model=tp) mesh.

    Same 256/512 chips, different logical axis split — the §Perf lever for
    models whose TP collectives dominate (more DP, less TP). The "pod" axis
    is folded into data.
    """
    def fn(mesh: Mesh):
        from jax.sharding import Mesh as M

        from ..launch.mesh import _axis_types
        devs = np.asarray(mesh.devices).reshape(-1)
        assert devs.size == dp * tp, (devs.size, dp, tp)
        return M(devs.reshape(dp, tp), ("data", "model"), **_axis_types(2))
    return fn


def _shard_like(mesh: Mesh, args, batch_leading: set[int] = frozenset()):
    """Generic in_shardings: params/opt via rules, batch leaves on data axes."""
    def one(path_idx, a):
        return NamedSharding(mesh, batch_pspec(a.shape, mesh))
    return jax.tree.map(one, args)


def params_shardings(mesh: Mesh, params_shapes):
    specs = param_pspecs(params_shapes, mesh)
    return jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(mesh: Mesh, batch_shapes):
    return jax.tree.map(
        lambda a: NamedSharding(mesh, batch_pspec(a.shape, mesh)),
        batch_shapes)


def repl(mesh: Mesh, tree):
    return jax.tree.map(lambda a: NamedSharding(mesh, P()), tree)


# ==========================================================================
# LM family
# ==========================================================================

def lm_param_pspecs(cfg: transformer.LMConfig, params_shapes, mesh: Mesh,
                    *, serving: bool = False):
    """Role-aware parameter shardings (DESIGN.md §5).

    Megatron TP pairing: column-parallel (wq / w_gate / w_up: "model" on the
    output dim) with row-parallel (wo / w_down: "model" on the contraction
    dim), plus FSDP/ZeRO-style "data" sharding on the complementary dim —
    XLA all-gathers the weight once per layer inside the scan. K/V
    projections are replicated over "model" (GQA with TP > n_kv_heads) and
    data-sharded for ZeRO. Embedding rows over "model" serves both uses
    (token gather → tiny psum; tied unembedding → vocab-sharded logits).
    """
    model = mesh.shape.get("model", 1)
    data = mesh.shape.get("data", 1)

    # Serving keeps weights RESIDENT (model-sharded, replicated over data —
    # no per-step FSDP gathers) unless they don't fit ~8 GiB/chip in bf16,
    # in which case weight-gathered inference stays on (mixtral-8x22b).
    if serving and lm_total_params(cfg) * 2 / max(model, 1) <= 8 * 2 ** 30:
        data = 1

    def md(n):  # dim shardable over model?
        return "model" if model > 1 and n % model == 0 else None

    def dd(n):
        return "data" if data > 1 and n % data == 0 else None

    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    heads_ok = cfg.n_heads % model == 0

    def kv_in(n):
        # K/V projections: output replicated over "model" (GQA, TP > kv
        # heads), so shard the CONTRACTION dim over model (+data for ZeRO):
        # keeps dL/dW local instead of a per-layer all-reduce of the grads.
        if model > 1 and data > 1 and n % (model * data) == 0:
            return ("model", "data")
        return md(n) or dd(n)

    lay: dict = {
        "attn_norm": P(), "mlp_norm": P(),
        # column-parallel iff heads shardable; else replicate over model
        "wq": P(None, dd(d), md(cfg.n_heads * hd) if heads_ok else None),
        "wk": P(None, kv_in(d), None),
        "wv": P(None, kv_in(d), None),
        "wo": P(None, md(cfg.n_heads * hd) if heads_ok else None, dd(d)),
    }
    if cfg.qk_norm:
        lay["q_norm"] = P()
        lay["k_norm"] = P()
    if cfg.is_moe:
        lay["router"] = P()
        lay["w_gate"] = P(None, None, dd(d), md(f))
        lay["w_up"] = P(None, None, dd(d), md(f))
        lay["w_down"] = P(None, None, md(f), dd(d))
    else:
        lay["w_gate"] = P(None, dd(d), md(f))
        lay["w_up"] = P(None, dd(d), md(f))
        lay["w_down"] = P(None, md(f), dd(d))
    specs = {
        "embed": P(md(cfg.vocab_size), None),
        "layers": lay,
        "final_norm": P(),
    }
    if "lm_head" in params_shapes:
        specs["lm_head"] = P(None, md(cfg.vocab_size))
    return specs


def lm_param_shardings(cfg, params_shapes, mesh: Mesh, *,
                       serving: bool = False):
    specs = lm_param_pspecs(cfg, params_shapes, mesh, serving=serving)
    return jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                        is_leaf=lambda x: isinstance(x, P))


def lm_active_params(cfg: transformer.LMConfig) -> float:
    """Non-embedding, routing-active parameter count (6ND convention)."""
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    if cfg.is_moe:
        mlp = 3 * d * f * cfg.top_k + d * cfg.n_experts
    else:
        mlp = 3 * d * f
    return float(cfg.n_layers * (attn + mlp))


def lm_total_params(cfg: transformer.LMConfig) -> float:
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    mlp = 3 * d * f * (cfg.n_experts or 1)
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return float(cfg.n_layers * (attn + mlp) + emb)


def _lm_attn_flops(cfg, batch, s_q, s_kv) -> float:
    # qk^T and att@v per layer: 2 * 2 * Sq * Skv * H * hd (capped by window)
    per_layer = []
    for w in cfg.layer_windows():
        eff = min(s_kv, int(w)) if w > 0 else s_kv
        per_layer.append(4.0 * s_q * eff * cfg.n_heads * cfg.hd)
    return float(batch * sum(per_layer))


def lm_train_cell(arch: str, cfg: transformer.LMConfig, *,
                  global_batch: int, seq_len: int,
                  n_microbatches: int, remesh: Callable | None = None,
                  note: str = "") -> Cell:
    def build(mesh):
        opt = AdamW(lr=cosine_schedule(peak_lr=3e-4, warmup_steps=100,
                                       total_steps=10_000))
        step = make_train_step(functools.partial(transformer.loss_fn, cfg),
                               opt, n_microbatches=n_microbatches)
        params_s = jax.eval_shape(
            functools.partial(transformer.init_params, cfg=cfg),
            jax.random.PRNGKey(0))
        opt_s = jax.eval_shape(opt.init, params_s)
        batch_s = {"tokens": sds((global_batch, seq_len), jnp.int32),
                   "labels": sds((global_batch, seq_len), jnp.int32)}
        return step, (params_s, opt_s, batch_s)

    def shardings(mesh, args):
        params_s, opt_s, batch_s = args
        ps = lm_param_shardings(cfg, params_s, mesh)
        os_ = {"m": lm_param_shardings(cfg, opt_s["m"], mesh),
               "v": lm_param_shardings(cfg, opt_s["v"], mesh),
               "step": NamedSharding(mesh, P())}
        bs = batch_shardings(mesh, batch_s)
        return (ps, os_, bs)

    tokens = global_batch * seq_len
    flops = 6.0 * lm_active_params(cfg) * tokens \
        + 3.0 * _lm_attn_flops(cfg, global_batch, seq_len, seq_len)
    return Cell(arch, f"train_{seq_len // 1024}k", "train", build, shardings,
                flops, note=note, remesh=remesh)


def lm_prefill_cell(arch: str, cfg: transformer.LMConfig, *,
                    batch: int, seq_len: int, shape_name: str) -> Cell:
    def build(mesh):
        fn = functools.partial(transformer.prefill, cfg)
        params_s = jax.eval_shape(
            lambda k: jax.tree.map(
                lambda x: x.astype(jnp.bfloat16),
                transformer.init_params(k, cfg)),
            jax.random.PRNGKey(0))
        return fn, (params_s, sds((batch, seq_len), jnp.int32))

    def shardings(mesh, args):
        params_s, tok_s = args
        return (lm_param_shardings(cfg, params_s, mesh, serving=True),
                NamedSharding(mesh, batch_pspec(tok_s.shape, mesh)))

    flops = 2.0 * lm_active_params(cfg) * batch * seq_len \
        + _lm_attn_flops(cfg, batch, seq_len, seq_len) / 2.0  # causal half
    return Cell(arch, shape_name, "prefill", build, shardings, flops)


def lm_decode_cell(arch: str, cfg: transformer.LMConfig, *,
                   batch: int, seq_len: int, shape_name: str,
                   note: str = "") -> Cell:
    def build(mesh):
        fn = functools.partial(transformer.decode_step, cfg)
        params_s = jax.eval_shape(
            lambda k: jax.tree.map(
                lambda x: x.astype(jnp.bfloat16),
                transformer.init_params(k, cfg)),
            jax.random.PRNGKey(0))
        cache_s = jax.eval_shape(
            lambda: transformer.init_decode_cache(cfg, batch, seq_len,
                                                  dtype=jnp.bfloat16))
        return fn, (params_s, cache_s, sds((batch,), jnp.int32))

    def shardings(mesh, args):
        params_s, cache_s, tok_s = args
        dp = data_axes(mesh)
        n_dp = int(np.prod([mesh.shape[a] for a in dp]))

        model = mesh.shape.get("model", 1)
        data = mesh.shape.get("data", 1)

        def cache_shard(a):
            # [B, S, KV, hd] (values) / [B, S, KV] (int8 scales): batch over
            # the data axes when divisible, KV sequence dim over "model"
            # (decode attention psums its softmax stats — tiny — instead of
            # holding 16x the cache)
            if a.ndim < 3:
                return NamedSharding(mesh, P())
            tail = (None,) * (a.ndim - 2)
            s_len = a.shape[1]
            if batch % n_dp == 0 and batch >= n_dp:
                s_ax = "model" if model > 1 and s_len % model == 0 else None
                return NamedSharding(mesh, P(dp, s_ax, *tail))
            if s_len % (data * model) == 0:
                return NamedSharding(mesh, P(None, ("data", "model"), *tail))
            if s_len % data == 0:
                return NamedSharding(mesh, P(None, "data", *tail))
            return NamedSharding(mesh, P())

        cs = jax.tree.map(cache_shard, cache_s)
        cs["pos"] = NamedSharding(mesh, P())
        return (lm_param_shardings(cfg, params_s, mesh, serving=True), cs,
                NamedSharding(mesh, batch_pspec(tok_s.shape, mesh)))

    flops = 2.0 * lm_active_params(cfg) * batch \
        + _lm_attn_flops(cfg, batch, 1, seq_len)
    return Cell(arch, shape_name, "decode", build, shardings, flops,
                note=note)


LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def lm_cells(arch: str, cfg: transformer.LMConfig, *, n_microbatches: int,
             skip_long: bool = False) -> list[Cell]:
    cells = [
        lm_train_cell(arch, cfg, global_batch=256, seq_len=4096,
                      n_microbatches=n_microbatches),
        lm_prefill_cell(arch, cfg, batch=32, seq_len=32768,
                        shape_name="prefill_32k"),
        lm_decode_cell(arch, cfg, batch=128, seq_len=32768,
                       shape_name="decode_32k"),
    ]
    if not skip_long:
        cells.append(lm_decode_cell(arch, cfg, batch=1, seq_len=524288,
                                    shape_name="long_500k"))
    return cells


# ==========================================================================
# GNN family
# ==========================================================================

def gnn_train_cell(arch: str, cfg: egnn.EGNNConfig, shape_name: str, *,
                   n_nodes: int, n_edges: int, batch_labels: int | None = None,
                   n_graphs: int | None = None, note: str = "") -> Cell:
    n_edges_pad = int(-(-n_edges // 512) * 512)

    def build(mesh):
        opt = AdamW(lr=1e-3)
        base_step = make_train_step(functools.partial(egnn.loss_fn, cfg), opt)
        if cfg.readout == "graph":
            # n_graphs is static — close over it rather than passing a leaf
            def step(params, opt_state, batch):
                return base_step(params, opt_state,
                                 dict(batch, n_graphs=n_graphs))
        else:
            step = base_step
        params_s = jax.eval_shape(
            functools.partial(egnn.init_params, cfg=cfg),
            jax.random.PRNGKey(0))
        opt_s = jax.eval_shape(opt.init, params_s)
        batch_s = {
            "node_feat": sds((n_nodes, cfg.d_feat)),
            "coords": sds((n_nodes, cfg.coord_dim)),
            "edges": sds((n_edges_pad, 2), jnp.int32),
        }
        if cfg.readout == "graph":
            batch_s["graph_ids"] = sds((n_nodes,), jnp.int32)
            batch_s["targets"] = sds((n_graphs, cfg.n_out))
        else:
            batch_s["labels"] = sds((n_nodes,), jnp.int32)
        return step, (params_s, opt_s, batch_s)

    def shardings(mesh, args):
        params_s, opt_s, batch_s = args
        all_axes = tuple(mesh.shape.keys())

        def bshard(key, a):
            if key == "edges":
                return NamedSharding(mesh, P(all_axes, None))
            return NamedSharding(mesh, P())

        bs = {k: bshard(k, v) for k, v in batch_s.items()}
        return (repl(mesh, params_s), repl(mesh, opt_s), bs)

    d = cfg.d_hidden
    # messages: phi_e (2 layers d->d) per edge; phi_h per node; x3 for bwd
    flops = 3.0 * cfg.n_layers * (
        2.0 * n_edges * (2 * d + 1 + cfg.d_edge) * d + 2.0 * n_edges * d * d
        + 4.0 * n_nodes * d * d)
    return Cell(arch, shape_name, "train", build, shardings, flops, note)


# ==========================================================================
# RecSys family
# ==========================================================================

def _recsys_batch_sds(cfg: recsys.RecsysConfig, batch: int,
                      with_labels: bool) -> dict:
    if cfg.model in ("dlrm", "autoint"):
        b = {"sparse": sds((batch, cfg.n_sparse), jnp.int32)}
        if cfg.n_dense:
            b["dense"] = sds((batch, cfg.n_dense))
        if with_labels:
            b["labels"] = sds((batch,), jnp.int32)
    elif cfg.model == "sasrec":
        b = {"history": sds((batch, cfg.seq_len), jnp.int32),
             "pos_items": sds((batch, cfg.seq_len), jnp.int32),
             "neg_items": sds((batch, cfg.seq_len), jnp.int32)}
    else:  # mind
        b = {"history": sds((batch, cfg.seq_len), jnp.int32),
             "pos_items": sds((batch,), jnp.int32),
             "neg_items": sds((batch,), jnp.int32)}
    return b


def recsys_model_flops(cfg: recsys.RecsysConfig, batch: int) -> float:
    d = cfg.embed_dim
    if cfg.model == "dlrm":
        dims = (cfg.n_dense,) + cfg.bot_mlp
        mlp = sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
        tdims = (recsys._dlrm_top_in(cfg),) + cfg.top_mlp
        mlp += sum(2 * a * b for a, b in zip(tdims[:-1], tdims[1:]))
        inter = 2 * (cfg.n_sparse + 1) ** 2 * d
        return float(batch * (mlp + inter))
    if cfg.model == "autoint":
        f = cfg.n_sparse
        per_layer = 2 * f * (3 * d * cfg.d_attn + 2 * f * cfg.d_attn)
        return float(batch * cfg.n_attn_layers * per_layer)
    if cfg.model == "sasrec":
        l = cfg.seq_len
        per_blk = 2 * l * (4 * d * d) + 2 * l * l * d * 2
        return float(batch * cfg.n_blocks * per_blk)
    l = cfg.seq_len
    return float(batch * (2 * l * d * d
                          + cfg.capsule_iters * 4 * cfg.n_interests * l * d))


def recsys_train_cell(arch: str, cfg: recsys.RecsysConfig, *,
                      batch: int, n_microbatches: int = 1) -> Cell:
    def build(mesh):
        opt = AdamW(lr=1e-3)
        step = make_train_step(functools.partial(recsys.loss_fn, cfg), opt,
                               n_microbatches=n_microbatches)
        params_s = jax.eval_shape(
            functools.partial(recsys.init_params, cfg=cfg),
            jax.random.PRNGKey(0))
        opt_s = jax.eval_shape(opt.init, params_s)
        return step, (params_s, opt_s, _recsys_batch_sds(cfg, batch, True))

    def shardings(mesh, args):
        params_s, opt_s, batch_s = args
        ps = params_shardings(mesh, params_s)
        os_ = {"m": params_shardings(mesh, opt_s["m"]),
               "v": params_shardings(mesh, opt_s["v"]),
               "step": NamedSharding(mesh, P())}
        return (ps, os_, batch_shardings(mesh, batch_s))

    return Cell(arch, "train_batch", "train", build, shardings,
                3.0 * recsys_model_flops(cfg, batch))


def recsys_serve_cell(arch: str, cfg: recsys.RecsysConfig, *,
                      batch: int, shape_name: str) -> Cell:
    def build(mesh):
        fn = functools.partial(recsys.forward, cfg)
        params_s = jax.eval_shape(
            functools.partial(recsys.init_params, cfg=cfg),
            jax.random.PRNGKey(0))
        return fn, (params_s, _recsys_batch_sds(cfg, batch, False))

    def shardings(mesh, args):
        params_s, batch_s = args
        return (params_shardings(mesh, params_s),
                batch_shardings(mesh, batch_s))

    return Cell(arch, shape_name, "serve", build, shardings,
                recsys_model_flops(cfg, batch))


def recsys_retrieval_cell(arch: str, cfg: recsys.RecsysConfig, *,
                          n_candidates: int = 1_048_576, k: int = 100) -> Cell:
    """retrieval_cand: 1 query vs ~1M candidates + two-stage top-k.

    n_candidates is padded to 2^20 so candidate blocks divide the mesh.
    """
    from ..core.retrieval import blockwise_topk

    def build(mesh):
        def fn(params, batch, candidates):
            scores = recsys.retrieval_scores(cfg, params, batch, candidates)
            idx, vals = blockwise_topk(scores, k, block=4096)
            return idx, vals

        params_s = jax.eval_shape(
            functools.partial(recsys.init_params, cfg=cfg),
            jax.random.PRNGKey(0))
        return fn, (params_s, _recsys_batch_sds(cfg, 1, False),
                    sds((n_candidates,), jnp.int32))

    def shardings(mesh, args):
        params_s, batch_s, cand_s = args
        all_axes = tuple(mesh.shape.keys())
        return (params_shardings(mesh, params_s), repl(mesh, batch_s),
                NamedSharding(mesh, P(all_axes)))

    # CTR models run a full forward per candidate; seq models one dot
    if cfg.model in ("dlrm", "autoint"):
        flops = recsys_model_flops(cfg, n_candidates)
    else:
        flops = 2.0 * n_candidates * cfg.embed_dim * \
            (cfg.n_interests if cfg.model == "mind" else 1)
    return Cell(arch, "retrieval_cand", "retrieval", build, shardings, flops)


RECSYS_SHAPES = dict(train_batch=65_536, serve_p99=512, serve_bulk=262_144)


def recsys_cells(arch: str, cfg: recsys.RecsysConfig, *,
                 train_microbatches: int = 1) -> list[Cell]:
    return [
        recsys_train_cell(arch, cfg, batch=RECSYS_SHAPES["train_batch"],
                          n_microbatches=train_microbatches),
        recsys_serve_cell(arch, cfg, batch=RECSYS_SHAPES["serve_p99"],
                          shape_name="serve_p99"),
        recsys_serve_cell(arch, cfg, batch=RECSYS_SHAPES["serve_bulk"],
                          shape_name="serve_bulk"),
        recsys_retrieval_cell(arch, cfg),
    ]
