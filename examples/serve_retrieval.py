"""End-to-end serving driver (the paper's workload at system scale).

Builds a sharded eager index over a 100k-document Zipf corpus, serves
batched queries through the hedged scatter-gather engine, demonstrates
straggler mitigation and elastic re-sharding, and reports QPS/tail
latency.

    PYTHONPATH=src python examples/serve_retrieval.py
"""

import tempfile
import time

import numpy as np

from repro.core import BM25Params, build_sharded_indexes
from repro.data.corpus import zipf_corpus, zipf_queries
from repro.serve import RetrievalEngine

N_DOCS, N_VOCAB, N_SHARDS = 100_000, 60_000, 8

print(f"indexing {N_DOCS} docs into {N_SHARDS} shards...")
t0 = time.time()
corpus = zipf_corpus(N_DOCS, N_VOCAB, avg_len=80)
shards = build_sharded_indexes(corpus, N_VOCAB, N_SHARDS,
                               params=BM25Params(method="lucene"))
t_build = time.time() - t0
print(f"  built in {t_build:.1f}s "
      f"({sum(s.nnz for s in shards) / 1e6:.1f}M postings)")

engine = RetrievalEngine(shards, k=10, deadline_s=0.5, quorum=0.75)

# throughput: batched serving through the auto-planned device scorer — each
# shard plans full-scan vs gathered per batch from the batch's Σ df (see
# core.retrieval.plan_retrieval) and serves the whole batch in one kernel
# launch; the merge is the batched stage-2. deadline generous enough to
# absorb the one-off bucket compiles of the first batch.
auto = RetrievalEngine(shards, k=10, deadline_s=120.0, quorum=1.0,
                       scorer="auto")
queries = zipf_queries(200, N_VOCAB, q_len=5)
BATCH = 25
auto.retrieve_batch(queries[:BATCH])         # compile this batch's buckets
t0 = time.time()
lat = []
for lo in range(0, len(queries), BATCH):
    r = auto.retrieve_batch(queries[lo:lo + BATCH])
    lat.append(r.latency_s)
dt = time.time() - t0
lat = np.asarray(lat)
plans = {rt._scorer.last_plan.regime for rt in auto.runtimes}
print(f"served {len(queries)} queries in batches of {BATCH}: "
      f"{len(queries) / dt:.1f} QPS, "
      f"p50 batch latency {1e3 * np.percentile(lat, 50):.1f}ms "
      f"p99 {1e3 * np.percentile(lat, 99):.1f}ms "
      f"(planner chose: {sorted(plans)})")

print("\ninjecting a straggler shard (2s delay), deadline 100ms...")
slow = RetrievalEngine(
    shards, k=10, deadline_s=0.1, quorum=0.5,
    delay=lambda i: (lambda: 2.0) if i == 0 else None)
r = slow.retrieve(queries[0])
print(f"  degraded={r.degraded} shards={r.shards_answered}/{N_SHARDS} "
      f"latency={1e3 * r.latency_s:.0f}ms (no 2s stall)")

print("\nelastic rescale 8 -> 5 shards (pool shrank)...")
engine.rescale(5)
r = engine.retrieve(queries[0])
print(f"  ok, top score {r.scores[0]:.3f} from {r.shards_answered} shards")

print("\ncold start: snapshot the engine, reload without rebuilding...")
# engine.save persists every shard runtime's resident index through
# sparse.snapshot (atomic rename commit, per-array checksums); load
# memmaps the verified arrays and uploads them straight through
# put_posting_arrays — the tokenize/score/re-block pipeline above never
# runs again. The timings below are the whole restart story: a process
# that owns a snapshot directory is serving again in the load time, not
# the build time.
with tempfile.TemporaryDirectory() as snapdir:
    t0 = time.time()
    engine.save(snapdir)
    t_save = time.time() - t0
    t0 = time.time()
    reloaded = RetrievalEngine.load(snapdir, mmap=True, deadline_s=120.0)
    t_load = time.time() - t0
    r0, r1 = engine.retrieve(queries[0]), reloaded.retrieve(queries[0])
    np.testing.assert_array_equal(r0.scores, r1.scores)
    print(f"  save {t_save:.2f}s, cold-start load {t_load:.2f}s vs "
          f"{t_build:.1f}s rebuild ({t_build / max(t_load, 1e-9):.1f}x), "
          f"scores bit-identical: True")

print("\nquery-gathered device scorer, batched (one launch per shard)...")
# deadline generous enough to absorb the one-off bucket compile of the
# first big batch (a tight deadline would just degrade to quorum — the
# hedging working as designed, but not what this demo measures)
gathered = RetrievalEngine(shards, k=10, deadline_s=120.0,
                           scorer="gathered")
batch = queries[:32]
rb = gathered.retrieve_batch(batch)          # compiles this batch's bucket
t0 = time.time()
rb2 = gathered.retrieve_batch(batch)         # warm: the steady-state path
t_b = time.time() - t0
assert not rb.degraded and not rb2.degraded
np.testing.assert_allclose(rb2.scores, rb.scores, atol=1e-5)
print(f"  batch of {len(batch)}: {len(batch) / t_b:.1f} QPS warm, "
      f"ids {rb.ids.shape}, degraded={rb.degraded}")

print("\nasync micro-batching front-end (Poisson single-query stream)...")
# real traffic never hands us dense batches — ServingFrontend forms them:
# arrivals group by (pow2 width bucket, k) so a formed batch lands on an
# already-compiled jit cache key, a former thread flushes each bucket on
# size-or-deadline, and host pack of batch i+1 overlaps device execution
# of batch i. batch_deadline_s is the Pareto dial: the latency an early
# arrival pays waiting for batchmates, bought back as throughput.
from repro.core import build_index
from repro.serve import DeviceRetriever, ServingFrontend

# scale sized to THIS backend (CPU interpret mode, ~4ms/launch: see the
# BENCH_7 FULL comment in benchmarks/serving.py) so the stream actually
# overloads the one-launch-per-arrival server while batches keep up
# deadline 20ms: BENCH_7's Pareto at this rate — batches of ~20 are what
# hold 1000 qps on this backend (5ms forms ~6-query batches, just under
# the arrival rate, and the queue grows instead)
FE_DOCS, FE_VOCAB, N_REQ, RATE_QPS, DEADLINE_S = 2_000, 1_000, 150, 1_000.0, 0.020
fe_corpus = zipf_corpus(FE_DOCS, FE_VOCAB, avg_len=60)
dr = DeviceRetriever(build_index(fe_corpus, FE_VOCAB, params=BM25Params()))
stream = zipf_queries(N_REQ, FE_VOCAB, q_len=5)
for b in (1, 2, 4, 8, 16, 32):                 # compile the pow2 buckets
    for lo in range(0, N_REQ - b + 1, max(b * 4, 1)):
        dr.retrieve_batch(stream[lo:lo + b], 10)

rng = np.random.default_rng(0)
arrivals = np.cumsum(rng.exponential(1.0 / RATE_QPS, size=N_REQ))


def replay(deadline_s):
    with ServingFrontend(dr, k=10, max_batch=32,
                         batch_deadline_s=deadline_s) as fe:
        t0 = time.monotonic()
        futs = []
        for q, t_arr in zip(stream, arrivals):
            dt = t_arr - (time.monotonic() - t0)
            if dt > 0:
                time.sleep(dt)
            futs.append(fe.submit(q))
        rows = [f.result() for f in futs]      # each a RetrievalResult
        return rows, fe.health()


# pass 1 compiles whatever formed-batch jit buckets the size ladder
# above missed (batch composition picks the u_max / posting-budget
# buckets); pass 2 is the steady state a long-lived server lives in
replay(DEADLINE_S)
rows, health = replay(DEADLINE_S)
lat_ms = 1e3 * np.asarray([r.latency_s for r in rows])
ids0, scores0 = rows[0]                        # legacy tuple unpack works
direct = dr.retrieve(stream[0], 10)
# same answers as an un-batched call (bit-identity vs the SAME formed
# batch is the tier-1/BENCH_7 assertion; across different batch shapes
# f32 association differs in the last ulp, hence allclose here)
np.testing.assert_allclose(np.sort(scores0), np.sort(np.ravel(direct.scores)),
                           rtol=1e-5)
print(f"  {N_REQ} arrivals @ {RATE_QPS:.0f} qps, deadline "
      f"{1e3 * DEADLINE_S:.0f}ms: p50 {np.percentile(lat_ms, 50):.1f}ms "
      f"p99 {np.percentile(lat_ms, 99):.1f}ms, "
      f"{health['batches']} batches (mean {health['mean_batch']:.1f} "
      f"queries/launch), served={health['served']} "
      f"degraded={health['degraded']} [health schema "
      f"{health['schema']}]")

print("\noverload shedding (flood at 3x the admission gate's rate)...")
# a traffic spike nobody provisioned for: the admission gate sheds the
# excess AT THE DOOR with a typed AdmissionRejectedError (carrying a
# retry_after_s hint) BEFORE it costs any device work, so the requests
# it does admit keep a bounded p99 instead of everyone queueing into
# timeout territory. Every admitted answer stays bit-identical to a
# direct call — shedding trades availability, never scores.
from repro.serve import AdmissionRejectedError

FLOOD_RATE = 3.0 * RATE_QPS
flood_arrivals = np.cumsum(rng.exponential(1.0 / FLOOD_RATE, size=N_REQ))


def flood():
    with ServingFrontend(dr, k=10, max_batch=32,
                         batch_deadline_s=DEADLINE_S,
                         admission_rate_qps=RATE_QPS,      # what we can do
                         admission_burst=64,
                         codel_target_s=0.050) as fe:
        t0 = time.monotonic()
        futs, shed, hints = [], 0, []
        for q, t_arr in zip(stream, flood_arrivals):
            dt = t_arr - (time.monotonic() - t0)
            if dt > 0:
                time.sleep(dt)
            try:
                futs.append(fe.submit(q))
            except AdmissionRejectedError as e:  # typed, pre-device
                shed += 1
                hints.append(e.retry_after_s)
        return [f.result() for f in futs], shed, hints, fe.health()


# same two-pass idiom as replay() above: the flood's batch compositions
# hit jit buckets the smooth stream never formed, so pass 1 compiles
# them and pass 2 is the steady state the p99 claim is about
flood()
rows, shed, hints, health = flood()
lat_ms = 1e3 * np.asarray([r.latency_s for r in rows])
print(f"  {N_REQ} arrivals @ {FLOOD_RATE:.0f} qps against a "
      f"{RATE_QPS:.0f} qps gate: admitted {len(rows)}, shed {shed} "
      f"(typed, retry-after ~{1e3 * float(np.median(hints)):.1f}ms), "
      f"admitted p99 {np.percentile(lat_ms, 99):.1f}ms")
print(f"  health: shed={health['shed']} rejected={health['rejected']} "
      f"admission={health['admission']}")

print("\ncircuit breaker: force a rung open, serving stays exact...")
# operators (or K repeated typed faults inside a window) can take a
# ladder rung out of rotation; the ladder hops over it and keeps
# serving bit-identical results on the remaining rungs while health()
# reports the skip. Entry rung pinned here so the demo shows the hop.
dr_cb = DeviceRetriever(build_index(fe_corpus, FE_VOCAB,
                                    params=BM25Params()),
                        regime="gathered", gather="host")
r_ok = dr_cb.retrieve(stream[0], 10)
dr_cb.trip_breaker("host", cooldown_s=60.0)
r_skip = dr_cb.retrieve(stream[0], 10)
np.testing.assert_array_equal(np.asarray(r_skip.ids),
                              np.asarray(r_ok.ids))
# same winners, scores to f32 tolerance: the skipped-to rung sums
# postings in a different association order (last-ulp, like the
# cross-batch-shape comparison above)
np.testing.assert_allclose(np.asarray(r_skip.scores),
                           np.asarray(r_ok.scores), rtol=1e-5)
br = dr_cb.health()["breakers"]["host"]
print(f"  host rung open (state={br['state']}, skips={br['skips']}): "
      f"hop {r_skip.degradations[0]['from']}->"
      f"{r_skip.degradations[0]['to']} "
      f"[{r_skip.degradations[0]['error']}], same winners, scores "
      f"within f32 tolerance of the closed-breaker call: True")
