"""Top-k selection: paper's argpartition path, XLA path, two-stage merges."""

import numpy as np
from conftest import given, settings, st

import jax.numpy as jnp

from repro.core import blockwise_topk, topk_jax, topk_numpy


def test_numpy_vs_jax_topk(rng):
    x = rng.normal(size=(4, 1000)).astype(np.float32)
    ni, nv = topk_numpy(x, 10)
    ji, jv = topk_jax(jnp.asarray(x), 10)
    np.testing.assert_allclose(nv, np.asarray(jv), atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 31), k=st.integers(1, 64),
       logn=st.integers(7, 12))
def test_property_blockwise_equals_sort(seed, k, logn):
    """Two-stage top-k is lossless for any (n, block, k)."""
    rng = np.random.default_rng(seed)
    n = 2 ** logn
    block = 2 ** max(3, logn - 3)
    k = min(k, block)
    x = rng.normal(size=n).astype(np.float32)
    idx, vals = blockwise_topk(jnp.asarray(x), k, block=block)
    ref = np.sort(x)[::-1][:k]
    np.testing.assert_allclose(np.asarray(vals), ref, atol=1e-6)


def test_topk_numpy_sorted_descending(rng):
    x = rng.normal(size=500).astype(np.float32)
    idx, vals = topk_numpy(x[None], 20)
    assert (np.diff(vals[0]) <= 1e-7).all()
    np.testing.assert_allclose(x[idx[0]], vals[0])
