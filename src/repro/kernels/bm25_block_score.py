"""Pallas TPU kernel: batched BM25S scoring over block-bucketed postings.

This is the paper's hot loop ("slice query-token rows, sum over the token
dimension") re-architected for the TPU memory hierarchy (DESIGN.md §3):

* postings live in the static block-bucketed layout (block_csr.py) so every
  tile is a dense VMEM-resident rectangle;
* the per-posting "is this token in the query batch, at what weight?" lookup
  is a vectorized binary-search (comparison-count against the sorted
  unique-token table, O(P·U) VPU compares) followed by a row gather of the
  ``[U, B]`` weight table — NOT a one-hot matmul over U, which would cost
  P·U·B MACs and dominate the useful work at realistic U;
* the scatter ``acc[local_doc] += score·w`` is a second one-hot matmul
  (``one_hot(local_doc)ᵀ @ contrib``) — the classic TPU answer to random
  scatter, with the one-hot built in-register from ``broadcasted_iota``.

Grid: ``(n_blocks, nnz_pad // tile_p)``. The inner (posting-tile) dimension
revisits the same output block, accumulating; program 0 zero-initializes.
Arithmetic intensity grows with the query batch B, which is what turns the
paper's memory-bound slice-and-sum into a compute-bound GEMM (§Perf).

Two entry points share the scoring tile:

* ``bm25_block_score``       — dense ``[nb, block_size, B]`` scores. Oracle /
  debug path only; at realistic corpus sizes this round-trips the whole
  score matrix through HBM.
* ``bm25_block_score_topk``  — the FUSED retrieval path. The accumulator
  lives in VMEM scratch; the last posting tile of each doc-block reduces it
  to per-block top-k (``select_topk`` rounds of max/argmax/mask, the
  ``blockwise_topk`` reduction run column-wise) and only ``[nb, k, B]``
  ids+values ever reach HBM — ``block_size/k`` less traffic, and no second
  kernel launch to re-read the scores.

Retrieval regimes — this file is the FULL-SCAN one. Its grid walks every
posting tile in the shard per query batch: O(nnz) compares/scatters
regardless of the query, which buys perfect streaming locality and zero
per-query layout work. That trade only wins when the batch is dense enough
that Σ df(q) approaches nnz (every tile would be gathered anyway — e.g.
huge batches of head-token queries, or vocabularies so small every token
matches most docs). For everything else the QUERY-GATHERED regime
(``bm25_gather_score.py``) does O(Σ df(q)) work — it slices only the query
tokens' posting runs and scatters into a candidate-sized accumulator — and
its advantage over the full scan grows linearly with corpus size at fixed
query df. ``serve.retrieval_engine``'s ``DeviceRetriever`` keeps BOTH
layouts HBM-resident and picks per batch via the free nnz/Σdf cost model
(``core.retrieval.plan_retrieval``, ``scorer="auto"``; ``"blocked"`` /
``"gathered"`` force a regime).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .blockwise_topk import select_topk


def _score_tile(tok_ref, loc_ref, sc_ref, uniq_ref, w_ref, *,
                block_size: int) -> jax.Array:
    """One posting tile's ``[block_size, B]`` score contribution."""
    tok = tok_ref[0, :]                                   # [PT] int32
    loc = loc_ref[0, :]                                   # [PT] int32
    sc = sc_ref[0, :]                                     # [PT] f32
    uniq = uniq_ref[...]                                  # [U]  int32
    weights = w_ref[...]                                  # [U, B] f32

    # membership lookup: idx[p] = #\{u : uniq[u] <= tok[p]\} - 1 (uniq sorted);
    # a [PT, U] comparison-count on the VPU, then a row gather of weights.
    # Padding postings (tok = -1) count 0 -> idx -1 -> clamped + masked out;
    # padding table slots are INT32_MAX and never match.
    le = (uniq[None, :] <= tok[:, None]).astype(jnp.int32)       # [PT, U]
    idx = jnp.sum(le, axis=1) - 1                                # [PT]
    safe = jnp.maximum(idx, 0)
    w_rows = jnp.take(weights, safe, axis=0)                     # [PT, B]
    hit = (jnp.take(uniq, safe) == tok)[:, None]                 # exact match
    contrib = jnp.where(hit, w_rows, 0.0) * sc[:, None]          # [PT, B]

    # scatter -> one-hot matmul: oneh[d, p] = (loc[p] == d)
    d_iota = jax.lax.broadcasted_iota(jnp.int32, (block_size, loc.shape[0]), 0)
    oneh = (d_iota == loc[None, :]).astype(weights.dtype)        # [BS, PT]
    return oneh @ contrib                                        # [BS, B] MXU


def _kernel(tok_ref, loc_ref, sc_ref, uniq_ref, w_ref, out_ref, *,
            block_size: int):
    """Dense variant: one (doc-block, posting-tile) grid step."""
    pj = pl.program_id(1)

    @pl.when(pj == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, :, :] += _score_tile(tok_ref, loc_ref, sc_ref, uniq_ref,
                                    w_ref, block_size=block_size)


def _fused_kernel(tok_ref, loc_ref, sc_ref, uniq_ref, w_ref,
                  vals_ref, idx_ref, acc_ref, *,
                  block_size: int, k: int, n_docs: int):
    """Fused variant: accumulate in VMEM scratch, emit only top-k.

    The ``[block_size, B]`` accumulator never leaves VMEM; the final posting
    tile of each doc-block masks the tail-padding documents and runs k
    select-and-mask rounds column-wise (one winner per query per round).
    """
    # program ids are read at the top level: pl.program_id may not appear
    # inside a pl.when branch (interpret-mode lowering rejects it there).
    pi = pl.program_id(0)
    pj = pl.program_id(1)

    @pl.when(pj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _score_tile(tok_ref, loc_ref, sc_ref, uniq_ref, w_ref,
                                block_size=block_size)

    @pl.when(pj == pl.num_programs(1) - 1)
    def _reduce():
        acc = acc_ref[...]                                       # [BS, B]
        # docs past n_docs exist only as block padding; a padded doc's
        # accumulator is 0.0 which would outrank real negative scores
        # (robertson IDF can go negative), so mask before selecting.
        row = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 0)
        gdoc = pi * block_size + row
        acc = jnp.where(gdoc < n_docs, acc, jnp.finfo(acc.dtype).min)

        def emit(i, m, am):                                      # m, am: [B]
            b = m.shape[0]
            pl.store(vals_ref, (pl.ds(0, 1), pl.ds(i, 1), pl.ds(0, b)),
                     m[None, None, :])
            pl.store(idx_ref, (pl.ds(0, 1), pl.ds(i, 1), pl.ds(0, b)),
                     am[None, None, :])

        select_topk(acc, k, axis=0, emit=emit)


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "tile_p", "interpret"),
)
def bm25_block_score(token_ids: jax.Array, local_doc: jax.Array,
                     scores: jax.Array, uniq_tokens: jax.Array,
                     weights: jax.Array, *, block_size: int,
                     tile_p: int = 512, interpret: bool | None = None
                     ) -> jax.Array:
    """[nb, P] blocked postings x [U, B] query table -> [nb, block_size, B].

    Dense scores for oracle tests and full-score consumers; the retrieval
    path uses :func:`bm25_block_score_topk` instead.
    """
    nb, p = token_ids.shape
    u, b = weights.shape
    assert p % tile_p == 0, (p, tile_p)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid = (nb, p // tile_p)
    return pl.pallas_call(
        functools.partial(_kernel, block_size=block_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),       # token_ids
            pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),       # local_doc
            pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),       # scores
            pl.BlockSpec((u,), lambda i, j: (0,)),                # uniq table
            pl.BlockSpec((u, b), lambda i, j: (0, 0)),            # weights
        ],
        out_specs=pl.BlockSpec((1, block_size, b), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_size, b), weights.dtype),
        interpret=interpret,
        name="bm25_block_score",
    )(token_ids, local_doc, scores, uniq_tokens, weights)


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "k", "n_docs", "tile_p", "interpret"),
)
def bm25_block_score_topk(token_ids: jax.Array, local_doc: jax.Array,
                          scores: jax.Array, uniq_tokens: jax.Array,
                          weights: jax.Array, *, block_size: int, k: int,
                          n_docs: int, tile_p: int = 512,
                          interpret: bool | None = None
                          ) -> tuple[jax.Array, jax.Array]:
    """Fused score→top-k: blocked postings -> (values, local ids) [nb, k, B].

    HBM sees only the ``[nb, k, B]`` winners — the dense
    ``[nb, block_size, B]`` matrix stays in a VMEM scratch accumulator.
    Padded documents (global id ≥ ``n_docs``) are masked to -inf before
    selection, so they can only surface when a block holds fewer than ``k``
    real documents. Ids are block-local; the merge adds ``block·block_size``.
    """
    nb, p = token_ids.shape
    u, b = weights.shape
    assert p % tile_p == 0, (p, tile_p)
    assert k <= block_size, (k, block_size)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid = (nb, p // tile_p)
    return pl.pallas_call(
        functools.partial(_fused_kernel, block_size=block_size, k=k,
                          n_docs=n_docs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),       # token_ids
            pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),       # local_doc
            pl.BlockSpec((1, tile_p), lambda i, j: (i, j)),       # scores
            pl.BlockSpec((u,), lambda i, j: (0,)),                # uniq table
            pl.BlockSpec((u, b), lambda i, j: (0, 0)),            # weights
        ],
        out_specs=(
            pl.BlockSpec((1, k, b), lambda i, j: (i, 0, 0)),      # values
            pl.BlockSpec((1, k, b), lambda i, j: (i, 0, 0)),      # local ids
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nb, k, b), weights.dtype),
            jax.ShapeDtypeStruct((nb, k, b), jnp.int32),
        ),
        scratch_shapes=[pltpu.VMEM((block_size, b), weights.dtype)],
        interpret=interpret,
        name="bm25_block_score_topk",
    )(token_ids, local_doc, scores, uniq_tokens, weights)
