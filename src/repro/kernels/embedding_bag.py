"""Pallas TPU kernel: EmbeddingBag (DMA row gather + weighted reduce).

JAX has no ``nn.EmbeddingBag``; the jnp formulation (``sparse/embedding_bag``)
materializes a ``[B, F, D]`` gather before reducing. This kernel is the
TPU-native version: the table stays in HBM (``memory_space=ANY``), bag
indices are scalar-prefetched into SMEM so they can drive DMA descriptors,
and each bag's rows are streamed row-by-row into a VMEM scratch buffer and
accumulated in registers — the ``[B, F, D]`` intermediate never exists.

On real hardware the row DMAs of consecutive fanout slots overlap with the
accumulate of the previous row (double-buffered scratch); in interpret mode
the copies execute eagerly, which is what the CPU tests validate.

Grid: ``(B // tile_b,)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUMemorySpace -> MemorySpace
_ANY_SPACE = getattr(pltpu, "MemorySpace",
                     getattr(pltpu, "TPUMemorySpace", None)).ANY


def _kernel(idx_ref, w_ref, table_ref, out_ref, row0, row1, sem0, sem1, *,
            fanout: int, tile_b: int):
    pi = pl.program_id(0)

    def bag_body(bi, _):
        gb = pi * tile_b + bi                     # global bag id (SMEM index)

        def start_dma(f, slot_ref, sem):
            idx = idx_ref[gb, f]
            safe = jnp.maximum(idx, 0)
            return pltpu.make_async_copy(
                table_ref.at[pl.ds(safe, 1), :], slot_ref, sem)

        # double-buffered fanout loop: issue f+1's DMA before reducing f
        start_dma(0, row0, sem0).start()

        def fan_body(f, acc):
            cur_row, cur_sem = jax.lax.cond(
                f % 2 == 0, lambda: (0, 0), lambda: (1, 1))
            # issue the next row's copy into the other buffer
            @pl.when(f + 1 < fanout)
            def _prefetch():
                nxt = f + 1

                @pl.when(nxt % 2 == 0)
                def _():
                    start_dma(nxt, row0, sem0).start()

                @pl.when(nxt % 2 == 1)
                def _():
                    start_dma(nxt, row1, sem1).start()

            @pl.when(cur_row == 0)
            def _():
                pltpu.make_async_copy(table_ref, row0, sem0).wait()

            @pl.when(cur_row == 1)
            def _():
                pltpu.make_async_copy(table_ref, row1, sem1).wait()

            row = jnp.where(cur_row == 0, row0[0, :], row1[0, :])
            idx = idx_ref[gb, f]
            w = jnp.where(idx >= 0, w_ref[bi, f], 0.0)
            return acc + w * row

        acc = jax.lax.fori_loop(
            0, fanout, fan_body,
            jnp.zeros((out_ref.shape[1],), out_ref.dtype))
        out_ref[pl.ds(bi, 1), :] = acc[None, :]
        return 0

    jax.lax.fori_loop(0, tile_b, bag_body, 0)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def embedding_bag_kernel(table: jax.Array, indices: jax.Array,
                         weights: jax.Array, *, tile_b: int = 128,
                         interpret: bool | None = None) -> jax.Array:
    """[V, D] table + [B, F] indices (-1 pad) + [B, F] weights -> [B, D]."""
    v, d = table.shape
    b, f = indices.shape
    assert weights.shape == (b, f)
    assert b % tile_b == 0, (b, tile_b)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                    # indices -> SMEM
        grid=(b // tile_b,),
        in_specs=[
            pl.BlockSpec((tile_b, f), lambda i, idx: (i, 0)),     # weights
            pl.BlockSpec(memory_space=_ANY_SPACE),                # table/HBM
        ],
        out_specs=pl.BlockSpec((tile_b, d), lambda i, idx: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), table.dtype),
            pltpu.VMEM((1, d), table.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, fanout=f, tile_b=tile_b),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
        name="embedding_bag",
    )(indices, weights, table)
