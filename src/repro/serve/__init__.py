"""Serving stack: sharded retrieval engine with hedging, an async
micro-batching front-end, and the LM decode engine.

The retrieval surface speaks ONE result dialect and ONE health dialect:

**Results.** Every retrieval entry point — ``DeviceRetriever.retrieve`` /
``retrieve_batch``, ``RetrievalEngine.retrieve`` / ``retrieve_batch``,
and the futures ``ServingFrontend.submit`` resolves — returns a
:class:`~repro.serve.results.RetrievalResult` carrying the winner boards
plus the evidence they were produced on (plan, degradation trail,
stage timings). It unpacks as the legacy ``(ids, scores)`` tuple, so
pre-unification call sites keep working unchanged.

**Health — the schema-2 contract.** Every level's ``health()`` —
``DeviceRetriever``, ``ShardRuntime``, ``RetrievalEngine``,
``ServingFrontend`` — returns one envelope
(:func:`~repro.serve.health.health_envelope`) whose COMMON keys mean the
same thing everywhere:

* ``schema``  — the schema version int
  (:data:`~repro.serve.health.HEALTH_SCHEMA`, currently ``2``);
* ``served``  — responses this level completed: batches for a retriever
  or shard, scatter-gather rounds for the engine, client requests for
  the front-end;
* ``degraded`` — how many of those were served degraded: exact-ladder
  hops (retriever/shard), missed shards under quorum+deadline hedging
  (engine), deadline-missed-but-answered requests (front-end). Degraded
  responses are still EXACT — degradation changes cost, never results;
* ``faults``  — typed-fault counts keyed by ``RetrievalError`` subclass
  name, aggregated upward (the engine sums its shards');
* ``queries`` — shared-sanitizer repair counters
  (``core.retrieval.validate_query_batch`` keys, e.g.
  ``clamped_tokens`` / ``dropped_tokens``).

Level-specific extras (legacy spellings like ``batches_served`` /
``responses``, per-shard breakdowns, the front-end's queue/batch stats)
ride alongside the common keys; tooling written against schema 2 reads
only the common ones.
"""

from .errors import (DeadlineExceededError, InvalidQueryError,
                     PlanOverflowError, QueueOverflowError, ResidencyError,
                     RetrievalConfigError, RetrievalError,
                     ScoreIntegrityError, SnapshotIntegrityError,
                     SnapshotVersionError, TruncationWarning)
from .health import HEALTH_SCHEMA, health_envelope
from .results import PackedBatch, RetrievalResult
from .retrieval_engine import (BlockedRetriever, DeviceRetriever,
                               GatheredRetriever, PrunedRetriever,
                               RetrievalEngine, ShardRuntime)
from .frontend import ServingFrontend
from .decode_engine import DecodeEngine

__all__ = ["BlockedRetriever", "DeviceRetriever", "GatheredRetriever",
           "PrunedRetriever", "RetrievalEngine", "ShardRuntime",
           "ServingFrontend", "RetrievalResult", "PackedBatch",
           "HEALTH_SCHEMA", "health_envelope",
           "DecodeEngine", "RetrievalError", "InvalidQueryError",
           "PlanOverflowError", "ResidencyError", "ScoreIntegrityError",
           "RetrievalConfigError", "SnapshotIntegrityError",
           "SnapshotVersionError", "DeadlineExceededError",
           "QueueOverflowError", "TruncationWarning"]
