"""The versioned ``health()`` schema every serving layer shares.

Before schema 2 the serving stack had three divergent health dialects:
the device retriever reported ``batches_served``/``batches_degraded``,
the engine ``responses``/``degraded_responses``, and shard runtimes a
third mix — an operator aggregating across levels had to know which
spelling each level used. Schema 2 pins ONE envelope (see
:func:`health_envelope`); the full key contract is documented once, in
the ``repro.serve`` package docstring.

Every level keeps its legacy keys alongside the common ones (pre-schema
dashboards keep reading what they read), but the common keys are the
contract new tooling should target.
"""

from __future__ import annotations

#: Version stamped into every ``health()`` report as ``"schema"``.
#: Bump when a COMMON key changes meaning or disappears; adding
#: level-specific extras is not a schema change.
HEALTH_SCHEMA = 2


def health_envelope(*, served: int, degraded: int, faults: dict,
                    queries: dict, **extra) -> dict:
    """Build a schema-2 health report.

    Common keys, identical meaning at every level (retriever, shard,
    engine, frontend):

    * ``schema``  — :data:`HEALTH_SCHEMA` (int);
    * ``served``  — responses this level completed (batches for a
      retriever, scatter-gather rounds for the engine, requests for the
      frontend);
    * ``degraded`` — how many of those were served degraded (ladder
      hops, missed shards, or missed deadlines — each level's docstring
      says which);
    * ``faults``  — typed-fault counts keyed by error class name;
    * ``queries`` — sanitizer repair counters
      (``core.retrieval.validate_query_batch`` keys).

    ``extra`` keys are level-specific and appended verbatim (legacy
    spellings, per-shard breakdowns, frontend batching stats).
    """
    return {
        "schema": HEALTH_SCHEMA,
        "served": int(served),
        "degraded": int(degraded),
        "faults": dict(faults),
        "queries": dict(queries),
        **extra,
    }


def merge_fault_counts(reports) -> dict:
    """Sum ``faults`` dicts across child reports (engine aggregation)."""
    out: dict[str, int] = {}
    for rep in reports:
        for name, n in (rep.get("faults") or {}).items():
            out[name] = out.get(name, 0) + int(n)
    return out


__all__ = ["HEALTH_SCHEMA", "health_envelope", "merge_fault_counts"]
