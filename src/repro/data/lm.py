"""LM token batcher: deterministic synthetic next-token streams.

Sequences follow a planted bigram process (each token biases the next into
a small successor set) so a model that learns reduces loss well below the
uniform baseline — used by the train-loop convergence tests and the
``train_lm`` example.
"""

from __future__ import annotations

import numpy as np


def lm_batches(*, vocab_size: int, batch: int, seq_len: int, seed: int = 0,
               n_successors: int = 8):
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab_size, size=(vocab_size, n_successors))
    while True:
        toks = np.empty((batch, seq_len + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, vocab_size, size=batch)
        for t in range(seq_len):
            choice = rng.integers(0, n_successors, size=batch)
            nxt = succ[toks[:, t], choice]
            noise = rng.random(batch) < 0.1
            nxt = np.where(noise, rng.integers(0, vocab_size, size=batch),
                           nxt)
            toks[:, t + 1] = nxt
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
