"""RecSys: train SASRec on synthetic behaviour logs, then score the full
item catalog for one user with the two-stage top-k (the retrieval_cand
shape in miniature).

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.clicklogs import seq_rec_batches
from repro.kernels import ops
from repro.models import recsys
from repro.train import AdamW, init_train_state, make_train_step

cfg = recsys.RecsysConfig(name="sasrec-demo", model="sasrec",
                          vocab_sizes=(8192,), embed_dim=50,
                          n_blocks=2, n_heads=1, seq_len=20)
params = recsys.init_params(jax.random.PRNGKey(0), cfg)
opt = AdamW(lr=1e-3)
step = jax.jit(make_train_step(functools.partial(recsys.loss_fn, cfg), opt))
state = init_train_state(params, opt)

gen = seq_rec_batches(n_items=8192, seq_len=20, batch=64)
for i in range(60):
    batch = jax.tree.map(jnp.asarray, next(gen))
    params, state, m = step(params, state, batch)
    if i % 15 == 0 or i == 59:
        print(f"step {i:3d}  loss {float(m['loss']):.4f}")

# full-catalog retrieval for the first user, two-stage top-k kernel path
candidates = jnp.arange(1, 8193, dtype=jnp.int32)
scores = recsys.retrieval_scores(cfg, params,
                                 {"history": batch["history"][:1]},
                                 candidates)
vals, idx = ops.topk(scores[0], 10, block=1024)
print("top-10 items:", np.asarray(candidates)[np.asarray(idx)])
print("scores:      ", np.round(np.asarray(vals), 3))
