"""mixtral-8x7b [arXiv:2401.04088]: 8-expert top-2 MoE with SWA.

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=32000,
8 experts top-2. Sliding window 4096 (mistral lineage) ⇒ long_500k runs
window-capped. MoE uses the token-dispatch formulation with
group-local token dispatch (``moe_group_seq=4096``) bounding the [G, E, C, d_ff] expert activations.
"""

import jax.numpy as jnp

from ..models.transformer import LMConfig, reduced
from .common import lm_cells

CONFIG = LMConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    sliding_window=4096,
    n_experts=8, top_k=2, capacity_factor=1.25, moe_group_seq=4096,
    rope_theta=1_000_000.0,
    dtype=jnp.bfloat16,
)

SMOKE = reduced(CONFIG, moe_group_seq=16)

FAMILY = "lm"
N_MICROBATCHES = 8


def cells():
    return lm_cells("mixtral-8x7b", CONFIG, n_microbatches=N_MICROBATCHES)
