"""Serving: hedged sharded retrieval, elastic re-shard, decode engine."""


import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import BM25Params, build_sharded_indexes, topk_numpy, \
    dense_oracle_scores
from repro.data.corpus import zipf_corpus, zipf_queries
from repro.serve import DecodeEngine, RetrievalEngine


@pytest.fixture(scope="module")
def corpus_and_shards():
    corpus = zipf_corpus(300, 200, avg_len=30)
    shards = build_sharded_indexes(corpus, 200, 4, params=BM25Params())
    return corpus, shards


def test_engine_exact_vs_oracle(corpus_and_shards):
    corpus, shards = corpus_and_shards
    eng = RetrievalEngine(shards, k=10, deadline_s=5.0)
    for q in zipf_queries(5, 200):
        r = eng.retrieve(q)
        assert not r.degraded
        oracle = dense_oracle_scores(corpus, 200, q, BM25Params())
        _, ref_v = topk_numpy(oracle[None], 10)
        np.testing.assert_allclose(np.sort(r.scores), np.sort(ref_v[0]),
                                   atol=1e-3)


def test_straggler_hedging_meets_deadline(corpus_and_shards):
    _, shards = corpus_and_shards
    eng = RetrievalEngine(
        shards, k=5, deadline_s=0.2, quorum=0.5,
        delay=lambda i: (lambda: 2.0) if i == 0 else None)
    q = zipf_queries(1, 200)[0]
    r = eng.retrieve(q)
    assert r.degraded and r.shards_answered >= 2
    assert r.latency_s < 1.0                       # did not wait 2s straggler


def test_hedged_results_are_subset_exact(corpus_and_shards):
    """Answered shards' winners keep exact scores (superset property)."""
    corpus, shards = corpus_and_shards
    eng = RetrievalEngine(
        shards, k=5, deadline_s=0.2, quorum=0.5,
        delay=lambda i: (lambda: 2.0) if i == 0 else None)
    q = zipf_queries(1, 200)[0]
    r = eng.retrieve(q)
    oracle = dense_oracle_scores(corpus, 200, q, BM25Params())
    for i, s in zip(r.ids, r.scores):
        assert abs(oracle[i] - s) < 1e-3


def test_elastic_rescale_preserves_results(corpus_and_shards):
    corpus, shards = corpus_and_shards
    eng = RetrievalEngine(shards, k=8, deadline_s=5.0)
    q = zipf_queries(1, 200, seed=7)[0]
    before = eng.retrieve(q)
    eng.rescale(2)        # pool shrank 4 -> 2
    after = eng.retrieve(q)
    np.testing.assert_allclose(np.sort(before.scores),
                               np.sort(after.scores), atol=1e-3)
    eng.rescale(6)        # pool grew
    again = eng.retrieve(q)
    np.testing.assert_allclose(np.sort(before.scores),
                               np.sort(again.scores), atol=1e-3)


def test_decode_engine_continuous_batching():
    from repro.models.transformer import LMConfig, init_params
    cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                   d_ff=64, vocab_size=61, head_dim=8, sliding_window=16,
                   seq_chunk=8, loss_chunk=8, dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, n_slots=2, max_seq=32)
    rids = [eng.submit([1 + i, 2 + i], max_new=3 + i) for i in range(5)]
    out = eng.run_until_done()
    assert set(out) == set(rids)
    for i, rid in enumerate(rids):
        assert len(out[rid]) == 3 + i


def test_decode_engine_matches_lockstep():
    """Single request through the ragged engine == greedy lockstep decode."""
    from repro.models import transformer
    from repro.models.transformer import LMConfig, init_params
    cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                   d_ff=64, vocab_size=61, head_dim=8, seq_chunk=8,
                   loss_chunk=8, dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(1), cfg)
    prompt = [5, 9, 11]
    eng = DecodeEngine(cfg, params, n_slots=1, max_seq=32)
    rid = eng.submit(prompt, max_new=5)
    got = eng.run_until_done()[rid]
    # lockstep reference
    cache = transformer.init_decode_cache(cfg, 1, 32)
    cache["pos"] = jnp.asarray(0, jnp.int32)
    toks = list(prompt)
    ref = []
    for t in range(len(prompt) + 4):
        cur = jnp.asarray([toks[t]], jnp.int32)
        logits, cache = transformer.decode_step(cfg, params, cache, cur)
        if t >= len(prompt) - 1:
            nxt = int(jnp.argmax(logits[0]))
            ref.append(nxt)
            if t + 1 >= len(toks):
                toks.append(nxt)
    assert got == ref


def test_engine_save_load_serves_exact(corpus_and_shards, tmp_path):
    """Cold start from disk serves the same answers as the built engine.

    NOT marked no_chaos: the snapshot load below walks the verified-read
    guard scope, so --chaos with $CHAOS_POOL=io arms an on-disk corruption
    here — and the recovery ladder must hand back the exact same engine.
    """
    corpus, shards = corpus_and_shards
    eng = RetrievalEngine(shards, k=8, deadline_s=5.0)
    qs = zipf_queries(4, 200)
    r0 = eng.retrieve_batch(qs)
    eng.save(str(tmp_path / "engine"))
    eng2 = RetrievalEngine.load(str(tmp_path / "engine"), mmap=True,
                                deadline_s=5.0)
    r1 = eng2.retrieve_batch(qs)
    np.testing.assert_array_equal(r0.ids, r1.ids)
    np.testing.assert_array_equal(r0.scores, r1.scores)
