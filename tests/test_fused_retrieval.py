"""Fused score→top-k retrieval pipeline vs the exactness oracles.

The fused kernel emits per-block ``[nb, k, B]`` winners straight from its
VMEM accumulator — these tests pin the whole pipeline (block layout → fused
kernel → global merge) against ``topk_numpy`` over dense oracle scores, on
every BM25 variant (including the shifted ones, whose §2.1 nonoccurrence
offset must survive the fusion exactly). Also covers the vectorized host
indexing path against a straightforward per-document/per-block loop
re-implementation, and the posting-budget overflow flag.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import given, make_corpus, settings, st
from repro.core import (BM25Params, DeviceIndex, build_index,
                        build_sharded_indexes, dense_oracle_scores,
                        merge_topk, pad_queries, reshard_index, score_batch,
                        suggest_p_max, topk_numpy)
from repro.core.index import CorpusStats, _corpus_coo
from repro.kernels import ops, ref
from repro.kernels.bm25_block_score import bm25_block_score_topk
from repro.sparse.block_csr import (block_postings_from_coo,
                                    block_postings_from_index,
                                    pack_query_batch,
                                    query_nonoccurrence_shift)

ALL_VARIANTS = ["robertson", "atire", "lucene", "bm25l", "bm25+"]


def _fused_retrieve(corpus, n_vocab, queries, method, k, *,
                    block_size=32, tile=64, q_max=8):
    """Full fused pipeline: index → block → fused kernel → merge."""
    p = BM25Params(method=method)
    idx = build_index(corpus, n_vocab, params=p)
    bp = block_postings_from_index(idx, block_size=block_size, tile=tile)
    toks, wts = pad_queries(queries, q_max)
    uniq, weights = pack_query_batch(toks, wts, u_max=4 * q_max)
    shift = query_nonoccurrence_shift(idx.nonoccurrence, toks, wts)
    ids, vals = ops.bm25_retrieve_blocked(
        jnp.asarray(bp.token_ids), jnp.asarray(bp.local_doc),
        jnp.asarray(bp.scores), jnp.asarray(uniq), jnp.asarray(weights),
        jnp.asarray(shift), block_size=bp.block_size,
        n_docs=len(corpus), k=k, tile_p=min(tile, bp.nnz_pad))
    return np.asarray(ids), np.asarray(vals), p


# -- tentpole: fused kernel + merge == topk_numpy oracle --------------------

@pytest.mark.parametrize("method", ALL_VARIANTS)
def test_fused_matches_oracle_all_variants(method, rng):
    corpus = make_corpus(rng, n_docs=90, n_vocab=64, max_len=20)
    queries = [rng.integers(0, 64, size=rng.integers(1, 6)).astype(np.int32)
               for _ in range(4)]
    ids, vals, p = _fused_retrieve(corpus, 64, queries, method, k=7)
    for i, q in enumerate(queries):
        oracle = dense_oracle_scores(corpus, 64, q, p)
        _, ref_v = topk_numpy(oracle[None], 7)
        np.testing.assert_allclose(vals[i], ref_v[0], atol=1e-4)
        # returned ids carry their exact oracle scores (not just same values)
        np.testing.assert_allclose(oracle[ids[i]], vals[i], atol=1e-4)


def test_fused_kernel_emits_topk_not_dense(rng):
    """Kernel output is [nb, k, B] and matches the per-block top-k oracle."""
    corpus = make_corpus(rng, n_docs=70, n_vocab=50)
    idx = build_index(corpus, 50, params=BM25Params(method="lucene"))
    bp = block_postings_from_index(idx, block_size=16, tile=64)
    queries = [rng.integers(0, 50, size=4).astype(np.int32)
               for _ in range(3)]
    toks, wts = pad_queries(queries, 8)
    uniq, weights = pack_query_batch(toks, wts, u_max=16)
    args = (jnp.asarray(bp.token_ids), jnp.asarray(bp.local_doc),
            jnp.asarray(bp.scores), jnp.asarray(uniq), jnp.asarray(weights))
    k = 5
    vals, loc = bm25_block_score_topk(
        *args, block_size=16, k=k, n_docs=70, tile_p=64)
    assert vals.shape == (bp.n_blocks, k, 3)
    assert loc.shape == (bp.n_blocks, k, 3)
    rv, ri = ref.bm25_block_topk_ref(*args, block_size=16, k=k, n_docs=70)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), atol=1e-5)
    # padded docs of the last block (70..79) may only appear with -inf value
    last = np.asarray(loc)[-1] + (bp.n_blocks - 1) * 16
    pad_hits = np.asarray(vals)[-1][last >= 70]
    assert (pad_hits <= np.finfo(np.float32).min / 2).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 31), k=st.integers(1, 12),
       variant=st.sampled_from(ALL_VARIANTS))
def test_property_fused_equals_topk_numpy(seed, k, variant):
    """Random corpora/queries/k/variant: fused pipeline == argpartition
    oracle, including the shifted variants' score offset."""
    rng = np.random.default_rng(seed)
    v = int(rng.integers(20, 80))
    corpus = [rng.integers(0, v, size=rng.integers(1, 25)).astype(np.int32)
              for _ in range(int(rng.integers(20, 120)))]
    k = min(k, len(corpus))
    queries = [rng.integers(0, v, size=rng.integers(1, 7)).astype(np.int32)
               for _ in range(3)]
    ids, vals, p = _fused_retrieve(corpus, v, queries, variant, k=k)
    for i, q in enumerate(queries):
        oracle = dense_oracle_scores(corpus, v, q, p)
        _, ref_v = topk_numpy(oracle[None], k)
        np.testing.assert_allclose(vals[i], ref_v[0], atol=1e-4)
        np.testing.assert_allclose(oracle[ids[i]], vals[i], atol=1e-4)


# -- vectorized host indexing == loop semantics -----------------------------

def _corpus_coo_loop(doc_tokens):
    """The seed's per-document loop, kept as the semantics oracle."""
    tok_c, doc_c, tf_c = [], [], []
    doc_lens = np.zeros(len(doc_tokens), dtype=np.int32)
    for d, toks in enumerate(doc_tokens):
        doc_lens[d] = toks.size
        if toks.size == 0:
            continue
        uniq, counts = np.unique(toks, return_counts=True)
        tok_c.append(uniq.astype(np.int64))
        doc_c.append(np.full(uniq.size, d, dtype=np.int64))
        tf_c.append(counts.astype(np.float64))
    if not tok_c:
        z = np.zeros(0, np.int64)
        return z, z.copy(), np.zeros(0, np.float64), doc_lens
    return (np.concatenate(tok_c), np.concatenate(doc_c),
            np.concatenate(tf_c), doc_lens)


def test_vectorized_corpus_coo_matches_loop(rng):
    corpus = make_corpus(rng, n_docs=120, n_vocab=40)
    corpus[7] = np.zeros(0, np.int32)            # empty doc edge case
    tok, doc, tf, lens = _corpus_coo(corpus, 40)
    lt, ld, ltf, ll = _corpus_coo_loop(corpus)
    order = np.lexsort((lt, ld))                 # vectorized is (doc, tok)
    np.testing.assert_array_equal(tok, lt[order])
    np.testing.assert_array_equal(doc, ld[order])
    np.testing.assert_array_equal(tf, ltf[order])
    np.testing.assert_array_equal(lens, ll)


def test_vectorized_corpus_stats(rng):
    corpus = make_corpus(rng, n_docs=100, n_vocab=30)
    stats = CorpusStats.from_corpus(corpus, 30)
    df = np.zeros(30, np.int64)
    total = 0
    for t in corpus:
        total += t.size
        if t.size:
            df[np.unique(t)] += 1
    np.testing.assert_array_equal(stats.df, df)
    assert stats.l_avg == pytest.approx(total / len(corpus))


def test_vectorized_block_postings_matches_loop(rng):
    nnz = 500
    tok = rng.integers(0, 90, size=nnz).astype(np.int64)
    doc = rng.integers(0, 150, size=nnz).astype(np.int64)
    sc = rng.normal(size=nnz).astype(np.float32)
    bp = block_postings_from_coo(tok, doc, sc, n_docs=150, n_vocab=90,
                                 block_size=32, tile=16)
    # loop oracle
    n_blocks = -(-150 // 32)
    assert bp.n_blocks == n_blocks
    for i in range(n_blocks):
        sel = (doc // 32) == i
        t, d, s = tok[sel], doc[sel] - i * 32, sc[sel]
        o = np.argsort(t, kind="stable")
        t, d, s = t[o], d[o], s[o]
        np.testing.assert_array_equal(bp.token_ids[i, : t.size], t)
        np.testing.assert_array_equal(bp.local_doc[i, : t.size], d)
        np.testing.assert_array_equal(bp.scores[i, : t.size], s)
        assert (bp.token_ids[i, t.size:] == -1).all()
        assert (bp.scores[i, t.size:] == 0.0).all()


def test_reshard_searchsorted_matches_direct_build(rng):
    corpus = make_corpus(rng, n_docs=83, n_vocab=40)
    p = BM25Params(method="bm25+")
    shards = build_sharded_indexes(corpus, 40, 5, params=p)
    for n_new in (1, 2, 3, 7):
        direct = build_sharded_indexes(corpus, 40, n_new, params=p)
        resharded = reshard_index(shards, n_new)
        assert len(resharded) == n_new
        for a, b in zip(resharded, direct):
            np.testing.assert_array_equal(a.indptr, b.indptr)
            np.testing.assert_array_equal(a.doc_ids, b.doc_ids)
            np.testing.assert_allclose(a.scores, b.scores, atol=1e-6)
            np.testing.assert_array_equal(a.doc_lens, b.doc_lens)
            assert a.doc_offset == b.doc_offset and a.n_docs == b.n_docs


# -- satellite regressions ---------------------------------------------------

def test_score_batch_overflow_flag_detects_truncation(rng):
    """An undersized posting budget must be detectable, not silent."""
    corpus = make_corpus(rng, n_docs=80, n_vocab=10)   # tiny vocab: huge df
    idx = build_index(corpus, 10, params=BM25Params())
    di = DeviceIndex.from_host(idx)
    queries = [np.arange(8, dtype=np.int32)]
    toks, wts = pad_queries(queries, 8)
    need = suggest_p_max(idx, 8)
    ok_scores, ok_flag = score_batch(di, toks, wts, p_max=need,
                                     return_overflow=True)
    bad_scores, bad_flag = score_batch(di, toks, wts, p_max=32,
                                       return_overflow=True)
    assert not bool(np.asarray(ok_flag)[0])
    assert bool(np.asarray(bad_flag)[0])
    # and the truncation it flags is real score corruption
    assert not np.allclose(np.asarray(ok_scores), np.asarray(bad_scores))
    # default call keeps the legacy single-output shape
    legacy = score_batch(di, toks, wts, p_max=need)
    np.testing.assert_allclose(np.asarray(legacy), np.asarray(ok_scores))


def test_sharded_retrieve_overflow_flag(rng):
    """The SPMD retrieval path exposes budget truncation like score_batch."""
    from repro.core.retrieval import make_sharded_retrieve, stack_shard_arrays
    from repro.launch.mesh import make_test_mesh
    corpus = make_corpus(rng, n_docs=60, n_vocab=10)   # tiny vocab: huge df
    shards = build_sharded_indexes(corpus, 10, 1, params=BM25Params())
    mesh = make_test_mesh(1)
    axes = tuple(mesh.shape.keys())
    arrs, ndoc = stack_shard_arrays(shards, mesh, axes)
    toks, wts = pad_queries([np.arange(8, dtype=np.int32)], 8)
    need = max(suggest_p_max(s, 8) for s in shards)
    r_over = make_sharded_retrieve(mesh, axes, p_max=16, k=3,
                                   n_docs_per_shard=ndoc,
                                   return_overflow=True)
    _, _, over = r_over(arrs, toks, wts)
    assert bool(np.asarray(over)[0])
    r_fit = make_sharded_retrieve(mesh, axes, p_max=need, k=3,
                                  n_docs_per_shard=ndoc,
                                  return_overflow=True)
    ids, vals, over = r_fit(arrs, toks, wts)
    assert not bool(np.asarray(over)[0])
    # default stays a 2-tuple (existing callers unchanged)
    r_default = make_sharded_retrieve(mesh, axes, p_max=need, k=3,
                                      n_docs_per_shard=ndoc)
    ids2, vals2 = r_default(arrs, toks, wts)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(vals2))


def test_merge_topk_matches_heap_semantics(rng):
    parts = []
    pool_ids = rng.choice(10_000, size=60, replace=False)
    pool_sc = rng.normal(size=60).astype(np.float32)
    for c in np.array_split(np.arange(60), 4):
        parts.append((pool_ids[c], pool_sc[c]))
    ids, scores = merge_topk(parts, 10)
    order = np.argsort(-pool_sc, kind="stable")[:10]
    np.testing.assert_allclose(scores, pool_sc[order], atol=1e-7)
    np.testing.assert_array_equal(ids, pool_ids[order])
    assert (np.diff(scores) <= 1e-7).all()
    # degenerate: empty parts, k > candidates, and k=0 (regression: the
    # [-0:] slice must not return every candidate like the old heap didn't)
    ids0, sc0 = merge_topk([], 5)
    assert ids0.size == 0 and sc0.size == 0
    ids1, sc1 = merge_topk([(pool_ids[:3], pool_sc[:3])], 99)
    assert ids1.size == 3
    idsz, scz = merge_topk([(pool_ids[:3], pool_sc[:3])], 0)
    assert idsz.size == 0 and scz.size == 0


def test_is_shifted_cached(rng):
    corpus = make_corpus(rng, n_docs=30, n_vocab=20)
    idx = build_index(corpus, 20, params=BM25Params(method="bm25l"))
    assert idx.is_shifted
    assert "is_shifted" in idx.__dict__          # cached after first access
    idx2 = build_index(corpus, 20, params=BM25Params(method="lucene"))
    assert not idx2.is_shifted


def test_corpus_coo_rejects_out_of_range_tokens(rng):
    corpus = make_corpus(rng, n_docs=10, n_vocab=20)
    corpus[3] = np.array([5, 25], dtype=np.int32)   # 25 >= n_vocab=20
    with pytest.raises(ValueError, match="token ids"):
        _corpus_coo(corpus, 20)
    corpus[3] = np.array([5, -2], dtype=np.int32)
    with pytest.raises(ValueError, match="token ids"):
        _corpus_coo(corpus, 20)


def test_blocked_scorer_long_query_not_truncated(rng):
    """Queries with more unique tokens than the q_max floor stay exact."""
    from repro.serve import DeviceRetriever
    from repro.core import ScipyBM25
    corpus = make_corpus(rng, n_docs=100, n_vocab=120, max_len=40)
    idx = build_index(corpus, 120, params=BM25Params())
    br = DeviceRetriever(idx, regime="blocked", block_size=32, tile=64, q_max=8)
    q = rng.choice(120, size=40, replace=False).astype(np.int32)  # 40 > 8
    ids, vals = br.retrieve(q, k=5)
    ref_ids, ref_vals = ScipyBM25(idx).retrieve(q, 5)
    np.testing.assert_allclose(np.sort(vals), np.sort(ref_vals), atol=1e-4)


def test_blocked_engine_survives_rescale_to_empty_shards(rng):
    """rescale() can create zero-doc shards; the blocked scorer must not
    crash on them (regression: ZeroDivisionError in pallas k=0 block)."""
    from repro.serve import RetrievalEngine
    corpus = make_corpus(rng, n_docs=3, n_vocab=20)
    shards = build_sharded_indexes(corpus, 20, 2, params=BM25Params())
    eng = RetrievalEngine(shards, k=2, deadline_s=10.0, scorer="blocked")
    eng.rescale(5)                               # 3 docs over 5 shards
    q = rng.integers(0, 20, size=3).astype(np.int32)
    r = eng.retrieve(q)
    oracle = dense_oracle_scores(corpus, 20, q, BM25Params())
    _, ref_v = topk_numpy(oracle[None], 2)
    np.testing.assert_allclose(np.sort(r.scores), np.sort(ref_v[0]),
                               atol=1e-3)


def test_engine_blocked_scorer_exact(rng):
    from repro.serve import RetrievalEngine
    corpus = make_corpus(rng, n_docs=120, n_vocab=60)
    p = BM25Params(method="bm25l")
    shards = build_sharded_indexes(corpus, 60, 3, params=p)
    eng = RetrievalEngine(shards, k=9, deadline_s=30.0, scorer="blocked")
    for _ in range(3):
        q = rng.integers(0, 60, size=5).astype(np.int32)
        r = eng.retrieve(q)
        oracle = dense_oracle_scores(corpus, 60, q, p)
        _, ref_v = topk_numpy(oracle[None], 9)
        np.testing.assert_allclose(np.sort(r.scores), np.sort(ref_v[0]),
                                   atol=1e-3)
        for i, s in zip(r.ids, r.scores):
            assert abs(oracle[i] - s) < 1e-3
    eng.rescale(2)                               # rescale keeps the scorer
    assert all(rt.scorer == "blocked" for rt in eng.runtimes)
    r2 = eng.retrieve(q)
    np.testing.assert_allclose(np.sort(r2.scores), np.sort(ref_v[0]),
                               atol=1e-3)
