"""End-to-end serving driver (the paper's workload at system scale).

Builds a sharded eager index over a 100k-document Zipf corpus, serves
batched queries through the hedged scatter-gather engine, demonstrates
straggler mitigation and elastic re-sharding, and reports QPS/tail
latency.

    PYTHONPATH=src python examples/serve_retrieval.py
"""

import tempfile
import time

import numpy as np

from repro.core import BM25Params, build_sharded_indexes
from repro.data.corpus import zipf_corpus, zipf_queries
from repro.serve import RetrievalEngine

N_DOCS, N_VOCAB, N_SHARDS = 100_000, 60_000, 8

print(f"indexing {N_DOCS} docs into {N_SHARDS} shards...")
t0 = time.time()
corpus = zipf_corpus(N_DOCS, N_VOCAB, avg_len=80)
shards = build_sharded_indexes(corpus, N_VOCAB, N_SHARDS,
                               params=BM25Params(method="lucene"))
t_build = time.time() - t0
print(f"  built in {t_build:.1f}s "
      f"({sum(s.nnz for s in shards) / 1e6:.1f}M postings)")

engine = RetrievalEngine(shards, k=10, deadline_s=0.5, quorum=0.75)

# throughput: batched serving through the auto-planned device scorer — each
# shard plans full-scan vs gathered per batch from the batch's Σ df (see
# core.retrieval.plan_retrieval) and serves the whole batch in one kernel
# launch; the merge is the batched stage-2. deadline generous enough to
# absorb the one-off bucket compiles of the first batch.
auto = RetrievalEngine(shards, k=10, deadline_s=120.0, quorum=1.0,
                       scorer="auto")
queries = zipf_queries(200, N_VOCAB, q_len=5)
BATCH = 25
auto.retrieve_batch(queries[:BATCH])         # compile this batch's buckets
t0 = time.time()
lat = []
for lo in range(0, len(queries), BATCH):
    r = auto.retrieve_batch(queries[lo:lo + BATCH])
    lat.append(r.latency_s)
dt = time.time() - t0
lat = np.asarray(lat)
plans = {rt._scorer.last_plan.regime for rt in auto.runtimes}
print(f"served {len(queries)} queries in batches of {BATCH}: "
      f"{len(queries) / dt:.1f} QPS, "
      f"p50 batch latency {1e3 * np.percentile(lat, 50):.1f}ms "
      f"p99 {1e3 * np.percentile(lat, 99):.1f}ms "
      f"(planner chose: {sorted(plans)})")

print("\ninjecting a straggler shard (2s delay), deadline 100ms...")
slow = RetrievalEngine(
    shards, k=10, deadline_s=0.1, quorum=0.5,
    delay=lambda i: (lambda: 2.0) if i == 0 else None)
r = slow.retrieve(queries[0])
print(f"  degraded={r.degraded} shards={r.shards_answered}/{N_SHARDS} "
      f"latency={1e3 * r.latency_s:.0f}ms (no 2s stall)")

print("\nelastic rescale 8 -> 5 shards (pool shrank)...")
engine.rescale(5)
r = engine.retrieve(queries[0])
print(f"  ok, top score {r.scores[0]:.3f} from {r.shards_answered} shards")

print("\ncold start: snapshot the engine, reload without rebuilding...")
# engine.save persists every shard runtime's resident index through
# sparse.snapshot (atomic rename commit, per-array checksums); load
# memmaps the verified arrays and uploads them straight through
# put_posting_arrays — the tokenize/score/re-block pipeline above never
# runs again. The timings below are the whole restart story: a process
# that owns a snapshot directory is serving again in the load time, not
# the build time.
with tempfile.TemporaryDirectory() as snapdir:
    t0 = time.time()
    engine.save(snapdir)
    t_save = time.time() - t0
    t0 = time.time()
    reloaded = RetrievalEngine.load(snapdir, mmap=True, deadline_s=120.0)
    t_load = time.time() - t0
    r0, r1 = engine.retrieve(queries[0]), reloaded.retrieve(queries[0])
    np.testing.assert_array_equal(r0.scores, r1.scores)
    print(f"  save {t_save:.2f}s, cold-start load {t_load:.2f}s vs "
          f"{t_build:.1f}s rebuild ({t_build / max(t_load, 1e-9):.1f}x), "
          f"scores bit-identical: True")

print("\nquery-gathered device scorer, batched (one launch per shard)...")
# deadline generous enough to absorb the one-off bucket compile of the
# first big batch (a tight deadline would just degrade to quorum — the
# hedging working as designed, but not what this demo measures)
gathered = RetrievalEngine(shards, k=10, deadline_s=120.0,
                           scorer="gathered")
batch = queries[:32]
rb = gathered.retrieve_batch(batch)          # compiles this batch's bucket
t0 = time.time()
rb2 = gathered.retrieve_batch(batch)         # warm: the steady-state path
t_b = time.time() - t0
assert not rb.degraded and not rb2.degraded
np.testing.assert_allclose(rb2.scores, rb.scores, atol=1e-5)
print(f"  batch of {len(batch)}: {len(batch) / t_b:.1f} QPS warm, "
      f"ids {rb.ids.shape}, degraded={rb.degraded}")
