"""jit'd public wrappers around the Pallas kernels.

These are the entry points the rest of the framework calls; each picks the
kernel path on TPU and interpret mode elsewhere, and composes the kernel
with the surrounding host/JAX logic (layout reshapes, nonoccurrence shift,
global top-k merge).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.retrieval import splice_default_docs
from .blockwise_topk import blockwise_topk_kernel
from .bm25_block_score import bm25_block_score, bm25_block_score_topk
from .bm25_gather_score import bm25_gather_score_topk, \
    bm25_resident_score_topk, bm25_resident_score_topk_pruned
from .block_segment_sum import block_segment_sum
from .embedding_bag import embedding_bag_kernel


def bm25_score_blocked(token_ids: jax.Array, local_doc: jax.Array,
                       scores: jax.Array, uniq_tokens: jax.Array,
                       weights: jax.Array, nonocc_shift: jax.Array, *,
                       block_size: int, n_docs: int,
                       tile_p: int = 512) -> jax.Array:
    """Batched BM25 scores [B, n_docs] from block-bucketed postings.

    ``nonocc_shift`` is the per-query ``Σᵢ wᵢ·S⁰(qᵢ)`` constant ([B]) — zero
    for the sparse variants, the §2.1 shift for BM25L/BM25+/TFldp.

    Materializes the full dense score matrix — oracle / full-score consumers
    only. Retrieval goes through :func:`bm25_retrieve_blocked`, which never
    writes the dense matrix to HBM.
    """
    out = bm25_block_score(token_ids, local_doc, scores, uniq_tokens,
                           weights, block_size=block_size, tile_p=tile_p)
    nb, bs, b = out.shape
    flat = jnp.transpose(out, (2, 0, 1)).reshape(b, nb * bs)[:, :n_docs]
    return flat + nonocc_shift[:, None]


@functools.partial(
    jax.jit, static_argnames=("block_size", "n_docs", "k", "tile_p"))
def bm25_retrieve_blocked(token_ids: jax.Array, local_doc: jax.Array,
                          scores: jax.Array, uniq_tokens: jax.Array,
                          weights: jax.Array, nonocc_shift: jax.Array, *,
                          block_size: int, n_docs: int, k: int,
                          tile_p: int = 512
                          ) -> tuple[jax.Array, jax.Array]:
    """Fused end-to-end retrieval: blocked postings -> (ids, scores) [B, k].

    Stage 1 is the fused score→top-k kernel (``[nb, k, B]`` winners straight
    out of VMEM, the dense ``[nb, block_size, B]`` matrix never reaches
    HBM). Stage 2 is the tiny global merge over ``nb·k`` candidates per
    query — lossless because every global winner wins its own block.
    The §2.1 nonoccurrence shift is a per-query constant, so it is
    rank-invariant and added after the merge; returned scores are exact.
    """
    kb = min(k, block_size, n_docs)
    vals, loc = bm25_block_score_topk(
        token_ids, local_doc, scores, uniq_tokens, weights,
        block_size=block_size, k=kb, n_docs=n_docs, tile_p=tile_p)
    nb, _, b = vals.shape
    gids = loc + (jnp.arange(nb, dtype=jnp.int32) * block_size)[:, None, None]
    flat_v = jnp.transpose(vals, (2, 0, 1)).reshape(b, nb * kb)
    flat_i = jnp.transpose(gids, (2, 0, 1)).reshape(b, nb * kb)
    mvals, midx = jax.lax.top_k(flat_v, min(k, n_docs, nb * kb))
    ids = jnp.take_along_axis(flat_i, midx, axis=-1)
    return ids, mvals + nonocc_shift[:, None]


@functools.partial(
    jax.jit, static_argnames=("acc_block", "k", "n_docs", "tile_p",
                              "two_level"))
def bm25_retrieve_gathered(token_ids: jax.Array, slot_ids: jax.Array,
                           scores: jax.Array, uniq_tokens: jax.Array,
                           weights: jax.Array, candidates: jax.Array,
                           nonocc_shift: jax.Array, *, acc_block: int,
                           k: int, n_docs: int, tile_p: int = 512,
                           two_level: bool = True
                           ) -> tuple[jax.Array, jax.Array]:
    """Query-gathered end-to-end retrieval: O(Σ df) postings -> [B, k].

    Stage 1 is the gathered fused kernel. With ``two_level=True`` (default)
    the chunk→shard winner merge happens INSIDE the launch (running
    ``[k, B]`` scoreboard in VMEM) and only ``[k, B]`` shard winners reach
    HBM; ``two_level=False`` keeps the per-chunk ``[nc, k, B]`` output and
    merges here — ``nc``× more winner traffic, retained as the oracle for
    the two-level reduction's exactness tests. Stage 2 splices in
    **default documents**: a document outside the candidate set
    contributes no posting, so its exact score is the per-query
    nonoccurrence shift (= raw 0 before the shift). Those defaults matter
    whenever a matched doc scores *below* zero (robertson IDF) or fewer
    than ``k`` docs match — the full-scan kernel got this for free by
    touching every doc; here the j-th-missing-id trick recovers it in
    O(k log C) without ever scanning ``n_docs``. The §2.1 shift is added
    after the merge (rank-invariant per query), so returned scores are
    exact, not rank-equivalent.
    """
    kk = min(k, n_docs)
    kb = min(kk, acc_block)
    if two_level and kb < kk:
        # the in-launch fold keeps only kb winners; ranks kb+1..kk would be
        # silently lost. The chunked path supplies nc·kb candidates (every
        # chunk holds ≤ acc_block ≤ kk candidates, so per-chunk top-kb IS
        # the chunk's full candidate set) — exact, so fall back to it.
        two_level = False
    if two_level:
        vals, gids = bm25_gather_score_topk(
            token_ids, slot_ids, scores, uniq_tokens, weights, candidates,
            acc_block=acc_block, k=kb, tile_p=tile_p, two_level=True)
        flat_v = vals.T                                     # [B, kb]
        flat_i = gids.T
    else:
        vals, gids = bm25_gather_score_topk(
            token_ids, slot_ids, scores, uniq_tokens, weights, candidates,
            acc_block=acc_block, k=kb, tile_p=tile_p)
        nc, _, b = vals.shape
        flat_v = jnp.transpose(vals, (2, 0, 1)).reshape(b, nc * kb)
        flat_i = jnp.transpose(gids, (2, 0, 1)).reshape(b, nc * kb)
    ids, mvals = splice_default_docs(flat_v, flat_i,
                                     candidates.reshape(-1), kk, n_docs)
    return ids, mvals + nonocc_shift[:, None]


@functools.partial(
    jax.jit, static_argnames=("block_size", "frag", "k", "n_docs",
                              "double_buffer"))
def bm25_retrieve_resident(desc: jax.Array, weights: jax.Array,
                           doc_ids_res: jax.Array, scores_res: jax.Array,
                           def_ids: jax.Array, nonocc_shift: jax.Array, *,
                           block_size: int, frag: int, k: int, n_docs: int,
                           double_buffer: bool = True
                           ) -> tuple[jax.Array, jax.Array]:
    """Device-resident retrieval: fragment descriptors -> (ids, scores) [B, k].

    The zero-posting-copy steady-state path: ``doc_ids_res``/``scores_res``
    are the HBM-resident CSC arrays of a ``sparse.block_csr.DeviceIndex``
    (uploaded once at engine build/rescale); the per-batch operands are the
    ``[6, nf]`` fragment table (host-built, or already device-resident
    from ``sparse.fragment_device`` — then NOTHING here crosses
    host→device but the query tables), the ``[U, B]`` query-weight table,
    ``k`` default doc ids from unvisited blocks
    (``core.retrieval.default_doc_ids`` or the device builder's), and the
    ``[B]`` §2.1 shift — all O(U + k + B), none of it postings. The kernel
    already returns merged shard winners (two-level reduce), so the only
    post-processing is the default-document splice (docs in unvisited
    blocks score raw 0, which matters for negative-IDF variants and
    undersized candidate sets) and the rank-invariant shift add.
    ``double_buffer`` selects the overlapped-DMA kernel schedule (output
    is bit-identical either way).
    """
    kk = min(k, n_docs)
    vals, gids = bm25_resident_score_topk(
        desc, weights, doc_ids_res, scores_res, block_size=block_size,
        frag=frag, k=kk, n_docs=n_docs, double_buffer=double_buffer)
    # the ONE splice definition (core.retrieval), fed the precomputed
    # unvisited-block default ids instead of the j-th-missing search
    ids, mvals = splice_default_docs(vals.T, gids.T, None, kk, n_docs,
                                     default_ids=def_ids)
    return ids, mvals + nonocc_shift[:, None]


@functools.partial(
    jax.jit, static_argnames=("block_size", "frag", "k", "n_docs"))
def _bm25_retrieve_resident_pruned_jit(desc: jax.Array, weights: jax.Array,
                                       doc_ids_res: jax.Array,
                                       scores_res: jax.Array,
                                       bounds: jax.Array,
                                       def_ids: jax.Array,
                                       nonocc_shift: jax.Array, *,
                                       block_size: int, frag: int, k: int,
                                       n_docs: int
                                       ) -> tuple[jax.Array, jax.Array,
                                                  jax.Array]:
    """Pruned-regime resident retrieval: (ids, scores, skipped) per batch.

    :func:`bm25_retrieve_resident` with the block-max skip: ``desc`` is the
    threshold-COMPACTED fragment table (losing blocks already pruned by
    the planner pass), ``bounds`` the surviving fragments' per-query block
    upper bounds driving the in-kernel skip of fragments that only become
    losers once the scoreboard saturates mid-launch. ``def_ids`` MUST come
    from the UNPRUNED visited-block set: a pruned block's documents score
    below the threshold, not zero, so they are neither candidates nor
    default documents. The third output is the in-kernel skip count.
    Output (ids, scores) are bit-identical to the single-buffer unpruned
    path on the same batch — pruning removes provably-losing work only.
    """
    kk = min(k, n_docs)
    vals, gids, skipped = bm25_resident_score_topk_pruned(
        desc, weights, bounds, doc_ids_res, scores_res,
        block_size=block_size, frag=frag, k=kk, n_docs=n_docs)
    ids, mvals = splice_default_docs(vals.T, gids.T, None, kk, n_docs,
                                     default_ids=def_ids)
    return ids, mvals + nonocc_shift[:, None], skipped[0, 0]


def bm25_retrieve_resident_pruned(*args, **kwargs):
    """Host wrapper of :func:`_bm25_retrieve_resident_pruned_jit`.

    Fault-injection site ``kernel.resident_pruned`` (repro.serve.faults):
    an armed ``nan_board``/``inf_board`` fault poisons the returned
    ``[B, k]`` score board — exactly the non-finite tile a broken kernel
    launch would produce, caught downstream by the retriever's cheap
    finite-check on the board (never the full score matrix). The hook
    lives here, outside the jitted body, so the corruption is a host-side
    transform and the compiled kernel stays byte-identical.
    """
    ids, mvals, skipped = _bm25_retrieve_resident_pruned_jit(
        *args, **kwargs)
    import sys
    _f = sys.modules.get("repro.serve.faults")
    if _f is not None and _f.ACTIVE:
        mvals = _f.fire("kernel.resident_pruned", mvals)
    return ids, mvals, skipped


def segment_sum_blocked(values: jax.Array, segment_ids: jax.Array, *,
                        num_segments: int, tile_p: int = 512) -> jax.Array:
    """Blocked scatter-add: [nb, P, D] + [nb, P] -> [nb, num_segments, D]."""
    return block_segment_sum(values, segment_ids,
                             num_segments=num_segments, tile_p=tile_p)


def embedding_bag(table: jax.Array, indices: jax.Array,
                  weights: jax.Array | None = None, *,
                  tile_b: int = 128) -> jax.Array:
    """Kernel-backed EmbeddingBag; pads B up to a tile multiple if needed."""
    b, f = indices.shape
    if weights is None:
        weights = jnp.ones((b, f), table.dtype)
    pad = (-b) % tile_b
    if pad:
        indices = jnp.concatenate(
            [indices, jnp.full((pad, f), -1, indices.dtype)])
        weights = jnp.concatenate([weights, jnp.zeros((pad, f), weights.dtype)])
    out = embedding_bag_kernel(table, indices, weights, tile_b=tile_b)
    return out[:b]


def topk(x: jax.Array, k: int, *, block: int = 4096
         ) -> tuple[jax.Array, jax.Array]:
    """Two-stage top-k over the last axis: per-block kernel + global merge.

    Accepts [n] or [B, n]; returns (values, indices) sorted descending.
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    bsz, n = x.shape
    if n % block or n <= block:
        vals, idx = jax.lax.top_k(x, k)                 # fallback: tiny inputs
    else:
        nb = n // block
        kb = min(k, block)
        xb = x.reshape(bsz * nb, block)
        bvals, bidx = blockwise_topk_kernel(xb, k=kb)
        bvals = bvals.reshape(bsz, nb * kb)
        gidx = (bidx.reshape(bsz, nb, kb)
                + (jnp.arange(nb, dtype=jnp.int32) * block)[None, :, None]
                ).reshape(bsz, nb * kb)
        vals, merge_idx = jax.lax.top_k(bvals, k)       # tiny global merge
        idx = jnp.take_along_axis(gidx, merge_idx, axis=-1)
    if squeeze:
        return vals[0], idx[0]
    return vals, idx
