"""AdamW + LR schedules + global-norm clipping, built from scratch in JAX.

Optimizer state is a params-shaped pytree; under the framework's FSDP-style
parameter sharding the moments inherit the same ``PartitionSpec``s, which is
exactly ZeRO-1: every chip owns 1/N of the optimizer state and the update is
computed shard-locally (XLA keeps the elementwise update unpartitioned —
no collectives in the optimizer itself).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


def cosine_schedule(*, peak_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * (step + 1.0) / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) /
                     max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                         (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float | None = 1.0

    def init(self, params) -> dict:
        zeros = lambda: jax.tree.map(  # noqa: E731
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": zeros(), "v": zeros(),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state: dict, params) -> tuple:
        """Returns (new_params, new_state, metrics)."""
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        else:
            gnorm = global_norm(grads)
        step = state["step"] + 1
        lr = self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p - lr * delta.astype(p.dtype)).astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, \
            {"grad_norm": gnorm, "lr": lr}
