"""Pallas TPU kernel: per-block top-k by iterative max (select-and-mask).

The paper's top-k is average-O(n) selection (np.argpartition / XLA top_k).
The distributed generalization is lossless two-stage selection: every global
winner is a winner of its own block, so per-block top-k + a tiny global
merge equals a full sort's top-k. This kernel is the per-block stage; the
merge is ~``nb·k`` elements and runs as a plain ``lax.top_k`` (ops.py).

Each grid step owns one block and performs k rounds of
(max, argmax, mask-out) — k·O(block) work, all VPU-friendly 2D reductions.
For the k ≪ block regime this matches the paper's O(n) average contract.

``select_topk`` is the reusable reduction core: the fused score→top-k
kernel (``bm25_block_score.bm25_block_score_topk``) runs the same k rounds
column-wise over its VMEM accumulator, which is how the dense score matrix
never reaches HBM.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401


def select_topk(acc: jax.Array, k: int, *, axis: int,
                emit: Callable[[jax.Array, jax.Array, jax.Array], None]
                ) -> None:
    """k rounds of (max, argmax, mask-out) along ``axis`` of ``acc``.

    ``emit(i, vals, idxs)`` is called once per round with the round index
    and the selected values/indices (``acc``'s shape minus ``axis``); it is
    expected to store into output refs. VPU-only: reductions + a compare
    mask, no sorts.
    """
    neg = jnp.finfo(acc.dtype).min
    iota = jax.lax.broadcasted_iota(jnp.int32, acc.shape, axis)

    def body(i, cur):
        m = jnp.max(cur, axis=axis)
        am = jnp.argmax(cur, axis=axis).astype(jnp.int32)
        emit(i, m, am)
        return jnp.where(iota == jnp.expand_dims(am, axis), neg, cur)

    jax.lax.fori_loop(0, k, body, acc)


def _kernel(x_ref, vals_ref, idx_ref, *, k: int):
    def emit(i, m, am):
        pl.store(vals_ref, (pl.ds(0, 1), pl.ds(i, 1)), m[:, None])
        pl.store(idx_ref, (pl.ds(0, 1), pl.ds(i, 1)), am[:, None])

    select_topk(x_ref[...], k, axis=1, emit=emit)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def blockwise_topk_kernel(x: jax.Array, *, k: int,
                          interpret: bool | None = None
                          ) -> tuple[jax.Array, jax.Array]:
    """[nb, block] -> (values [nb, k], local indices [nb, k]), descending."""
    nb, blk = x.shape
    assert k <= blk, (k, blk)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    vals, idx = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nb, k), x.dtype),
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
        ),
        interpret=interpret,
        name="blockwise_topk",
    )(x)
    return vals, idx
