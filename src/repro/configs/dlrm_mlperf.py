"""DLRM MLPerf benchmark config [arXiv:1906.00091] — Criteo 1TB.

13 dense features -> bottom MLP 512-256-128; 26 categorical features with
the MLPerf vocabulary sizes below (≈188M rows total, dim 128 ≈ 96 GB fp32
of embedding state — the huge-embedding roofline cell); dot interaction;
top MLP 1024-1024-512-256-1. Tables are stored concatenated and row/dim
sharded over ("data", "model").
"""

from ..models.recsys import RecsysConfig, reduced
from .common import recsys_cells

# MLPerf DLRM (Criteo Terabyte) per-table row counts
MLPERF_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)

CONFIG = RecsysConfig(
    name="dlrm-mlperf", model="dlrm",
    vocab_sizes=MLPERF_VOCABS, embed_dim=128, n_dense=13,
    bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1),
)

SMOKE = reduced(CONFIG)

FAMILY = "recsys"


def cells():
    return recsys_cells("dlrm-mlperf", CONFIG, train_microbatches=1)
