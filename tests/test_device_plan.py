"""Device-side batch planning + double-buffered fragment DMA (PR 4).

Pins the fully-device-resident serving contract:

* **sparse** — the jit-compiled fragment builder
  (``sparse.fragment_device``) emits a table BYTE-IDENTICAL to the host
  ``fragment_plan`` across head/tail/dense df profiles, empty queries and
  df-0 tokens, and turns nf-bucket overflow into a larger-bucket retry
  (never truncation); its device default-doc ids match
  ``core.retrieval.default_doc_ids``.
* **kernel** — the double-buffered DMA schedule is bit-identical to the
  single-buffer oracle on all five BM25 variants (same scatter/fold
  helpers, different copy schedule only).
* **serve** — with ``plan="device"`` the steady-state batch ships ZERO
  posting and ZERO descriptor bytes host→device (the PR's acceptance
  invariant); ``host_arrays="drop"`` serves exactly without any host CSC
  posting copy; ``last_plan`` records the plan mode.
* **core** — the planner's crossover discounts the now-free device
  descriptor build.
"""

import numpy as np
import pytest

from conftest import given, make_corpus, settings, st
from repro.core import (BM25Params, ScipyBM25, build_index,
                        build_sharded_indexes, default_doc_ids,
                        dense_oracle_scores, plan_retrieval, topk_numpy)
from repro.core.retrieval import DEFAULT_CROSSOVER, DEVICE_PLAN_DISCOUNT
from repro.serve import DeviceRetriever, RetrievalEngine
from repro.sparse.block_csr import (TRANSFERS, DeviceIndex, bucket_pow2,
                                    fragment_plan, reset_transfer_stats)
from repro.sparse.fragment_device import (build_fragment_table,
                                          plan_fragments_device)

# transfer/plan counters asserted here change legitimately when a
# chaos fault forces a ladder hop (e.g. an extra host-gather upload)
pytestmark = pytest.mark.no_chaos

ALL_VARIANTS = ["robertson", "atire", "lucene", "bm25l", "bm25+"]

SMALL = dict(block_size=16, tile=16, acc_block=16, frag=8, q_max=8)

BIG = np.iinfo(np.int32).max


def _pad_uniq(uniq: np.ndarray, floor: int = 8) -> np.ndarray:
    """uniq tokens -> the padded sentinel table ``pack_query_batch`` uses."""
    u_max = bucket_pow2(max(uniq.size, 1), floor=floor)
    tab = np.full(u_max, BIG, dtype=np.int32)
    tab[: uniq.size] = uniq
    return tab


def _profile_uniq(rng, profile: str, n_vocab: int) -> np.ndarray:
    if profile == "head":
        pool = np.arange(0, max(4, n_vocab // 8))
    elif profile == "dense":
        return np.arange(n_vocab, dtype=np.int64)
    else:
        pool = np.arange(n_vocab // 2, n_vocab)
    return np.unique(rng.choice(pool, size=6)).astype(np.int64)


# -- tentpole: device fragment builder == host fragment_plan ------------------

@pytest.mark.parametrize("profile", ["head", "tail", "dense"])
def test_device_plan_matches_host_byte_for_byte(profile, rng):
    corpus = make_corpus(rng, n_docs=120, n_vocab=48, max_len=25)
    idx = build_index(corpus, 48, params=BM25Params())
    di = DeviceIndex.build(idx, block_size=16, tile=16, frag=8,
                           with_blocked=False)
    uniq = _profile_uniq(rng, profile, 48)
    fp = fragment_plan(idx, uniq, block_size=16, frag=8)
    sum_df = int(np.diff(idx.indptr)[uniq].sum())
    desc, dids, nf_used = plan_fragments_device(
        di, _pad_uniq(uniq), sum_df=sum_df, k=5, block_size=16,
        nf_bucket=fp.nf_pad)
    assert nf_used == fp.nf_pad
    np.testing.assert_array_equal(np.asarray(desc), fp.desc)
    np.testing.assert_array_equal(
        np.asarray(dids),
        default_doc_ids(fp.vis_blocks, 5, int(idx.doc_lens.size), 16))


def test_device_plan_empty_query_and_df0_tokens(rng):
    corpus = make_corpus(rng, n_docs=40, n_vocab=64, max_len=10)
    idx = build_index(corpus, 64, params=BM25Params())
    di = DeviceIndex.build(idx, block_size=16, tile=16, frag=8,
                           with_blocked=False)
    df = np.diff(idx.indptr)
    cases = [np.zeros(0, np.int64)]
    if (df == 0).any():                           # df-0 tokens: no fragments
        cases.append(np.flatnonzero(df == 0)[:3].astype(np.int64))
    for uniq in cases:
        fp = fragment_plan(idx, uniq, block_size=16, frag=8)
        sum_df = int(df[uniq].sum())
        desc, dids, _ = plan_fragments_device(
            di, _pad_uniq(uniq), sum_df=sum_df, k=4, block_size=16,
            nf_bucket=fp.nf_pad)
        assert fp.n_frags == 0
        np.testing.assert_array_equal(np.asarray(desc), fp.desc)
        np.testing.assert_array_equal(
            np.asarray(dids),
            default_doc_ids(fp.vis_blocks, 4, int(idx.doc_lens.size), 16))


def test_device_plan_overflow_flag_and_retry(rng):
    """A too-small nf bucket must RAISE the flag, and the wrapper must
    retry to a bucket that reproduces the host table exactly — overflow is
    a retry signal, never silent truncation."""
    corpus = make_corpus(rng, n_docs=120, n_vocab=32, max_len=25)
    idx = build_index(corpus, 32, params=BM25Params())
    di = DeviceIndex.build(idx, block_size=16, tile=16, frag=8,
                           with_blocked=False)
    uniq = np.arange(32, dtype=np.int64)          # dense: many fragments
    sum_df = int(np.diff(idx.indptr).sum())
    fp = fragment_plan(idx, uniq, block_size=16, frag=8)
    assert fp.n_frags > 8                         # 8 really is too small
    import jax.numpy as jnp
    _, _, nf, over = build_fragment_table(
        jnp.asarray(_pad_uniq(uniq)), di.csc_indptr, di.csc_doc_ids,
        block_size=16, frag=8, nf_pad=8,
        p_bucket=bucket_pow2(sum_df, floor=8), k=5,
        n_docs=int(idx.doc_lens.size))
    assert bool(over) and int(nf) == fp.n_frags
    desc, _, nf_used = plan_fragments_device(
        di, _pad_uniq(uniq), sum_df=sum_df, k=5, block_size=16,
        nf_bucket=8)                              # starts too small
    assert nf_used >= bucket_pow2(fp.n_frags, floor=8)
    ref = fragment_plan(idx, uniq, block_size=16, frag=8,
                        nf_bucket=nf_used)
    np.testing.assert_array_equal(np.asarray(desc), ref.desc)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 31), block_size=st.sampled_from([8, 16, 32]),
       frag=st.sampled_from([4, 8, 16]))
def test_property_device_plan_equals_host(seed, block_size, frag):
    rng = np.random.default_rng(seed)
    v = int(rng.integers(10, 60))
    corpus = [rng.integers(0, v, size=rng.integers(1, 20)).astype(np.int32)
              for _ in range(int(rng.integers(10, 150)))]
    idx = build_index(corpus, v, params=BM25Params())
    di = DeviceIndex.build(idx, block_size=block_size, tile=16, frag=frag,
                           with_blocked=False)
    uniq = np.unique(
        rng.integers(0, v, size=rng.integers(1, 12))).astype(np.int64)
    fp = fragment_plan(idx, uniq, block_size=block_size, frag=frag)
    sum_df = int(np.diff(idx.indptr)[uniq].sum())
    k = int(rng.integers(1, 8))
    desc, dids, _ = plan_fragments_device(
        di, _pad_uniq(uniq), sum_df=sum_df, k=k, block_size=block_size,
        nf_bucket=fp.nf_pad)
    np.testing.assert_array_equal(np.asarray(desc), fp.desc)
    np.testing.assert_array_equal(
        np.asarray(dids),
        default_doc_ids(fp.vis_blocks, k, int(idx.doc_lens.size),
                        block_size))


def test_device_plan_wrapper_estimates_without_nf_bucket(rng):
    """The estimate/state path (no explicit nf_bucket) must still cover
    the real fragment count and remember the bucket across batches."""
    corpus = make_corpus(rng, n_docs=100, n_vocab=32, max_len=25)
    idx = build_index(corpus, 32, params=BM25Params())
    di = DeviceIndex.build(idx, block_size=16, tile=16, frag=8,
                           with_blocked=False)
    uniq = np.arange(32, dtype=np.int64)
    sum_df = int(np.diff(idx.indptr).sum())
    state = {}
    desc, _, nf_used = plan_fragments_device(
        di, _pad_uniq(uniq), sum_df=sum_df, k=5, block_size=16, state=state)
    fp = fragment_plan(idx, uniq, block_size=16, frag=8, nf_bucket=nf_used)
    np.testing.assert_array_equal(np.asarray(desc), fp.desc)
    assert state["nf"] == nf_used                 # steady-state memory


def test_device_plan_requires_resident_csc(rng):
    corpus = make_corpus(rng, n_docs=30, n_vocab=16)
    idx = build_index(corpus, 16, params=BM25Params())
    di = DeviceIndex.build(idx, with_csc=False)
    with pytest.raises(ValueError, match="resident CSC"):
        plan_fragments_device(di, _pad_uniq(np.array([1])), sum_df=3, k=2)


# -- tentpole: double-buffered DMA schedule == single-buffer oracle -----------

@pytest.mark.parametrize("method", ALL_VARIANTS)
def test_double_buffer_bit_identical_all_variants(method, rng):
    """Same scatter/fold math, different copy schedule — outputs must be
    BIT-identical, not just close (the acceptance criterion)."""
    corpus = make_corpus(rng, n_docs=90, n_vocab=64, max_len=20)
    idx = build_index(corpus, 64, params=BM25Params(method=method))
    kw = dict(regime="gathered", gather="resident", plan="device", **SMALL)
    db = DeviceRetriever(idx, **kw)
    sb = DeviceRetriever(idx, double_buffer=False, **kw)
    assert db.double_buffer and not sb.double_buffer
    queries = [rng.integers(0, 64, size=rng.integers(1, 6)).astype(np.int32)
               for _ in range(4)]
    for k in (1, 7):
        ids_db, vals_db = db.retrieve_batch(queries, k)
        ids_sb, vals_sb = sb.retrieve_batch(queries, k)
        np.testing.assert_array_equal(ids_db, ids_sb)
        np.testing.assert_array_equal(vals_db, vals_sb)   # bitwise
    # and both are exact against the oracle
    sc = ScipyBM25(idx)
    for i, q in enumerate(queries):
        oracle = sc.score(q)
        _, ref_v = topk_numpy(oracle[None], 7)
        np.testing.assert_allclose(vals_db[i], ref_v[0], atol=1e-4)
        np.testing.assert_allclose(oracle[ids_db[i]], vals_db[i], atol=1e-4)


def test_double_buffer_kernel_direct_single_fragment(rng):
    """Degenerate grids (1 fragment; all-padding table) through the raw
    kernel — the warm-up/prefetch/wait schedule must stay balanced."""
    import jax.numpy as jnp

    from repro.kernels.bm25_gather_score import bm25_resident_score_topk
    corpus = make_corpus(rng, n_docs=20, n_vocab=8, max_len=6)
    idx = build_index(corpus, 8, params=BM25Params())
    di = DeviceIndex.build(idx, block_size=32, tile=16, frag=8,
                           with_blocked=False)
    weights = jnp.zeros((8, 4), jnp.float32).at[0, :].set(1.0)
    for desc_np in (
        fragment_plan(idx, np.array([0]), block_size=32, frag=8).desc,
        np.zeros((6, 8), np.int32),               # nothing valid at all
    ):
        outs = [bm25_resident_score_topk(
            jnp.asarray(desc_np), weights, di.csc_doc_ids, di.csc_scores,
            block_size=32, frag=8, k=3, n_docs=int(idx.doc_lens.size),
            double_buffer=flag) for flag in (True, False)]
        np.testing.assert_array_equal(np.asarray(outs[0][0]),
                                      np.asarray(outs[1][0]))
        np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                      np.asarray(outs[1][1]))


# -- acceptance: zero posting AND descriptor bytes with plan="device" ---------

def test_device_plan_ships_zero_posting_and_descriptor_bytes(rng):
    """THE acceptance invariant: with plan="device" the steady-state batch
    ships NOTHING through the counted posting/descriptor channels — the
    fragment table is born on device. plan="host" on the same index still
    ships descriptors (the contrast that proves the counter works)."""
    corpus = make_corpus(rng, n_docs=120, n_vocab=60)
    idx = build_index(corpus, 60, params=BM25Params(method="lucene"))
    dr = DeviceRetriever(idx, regime="auto", gather="resident",
                         plan="device", **SMALL)
    dr.warmup(k=5)
    qs = [rng.integers(0, 60, size=4).astype(np.int32) for _ in range(5)]
    dr.retrieve_batch(qs, 5)                      # settle the nf bucket
    reset_transfer_stats()
    for regime in (None, "blocked", "gathered"):
        for _ in range(2):
            dr.retrieve_batch(qs, 5, regime=regime)
    assert TRANSFERS.posting_uploads == 0, vars(TRANSFERS)
    assert TRANSFERS.posting_bytes == 0
    assert TRANSFERS.descriptor_uploads == 0, vars(TRANSFERS)
    assert TRANSFERS.descriptor_bytes == 0
    assert dr.last_plan.plan == "device"
    # contrast: host planning ships the descriptor table every batch
    hp = DeviceRetriever(idx, regime="gathered", gather="resident",
                         plan="host", **SMALL)
    hp.retrieve_batch(qs, 5)
    reset_transfer_stats()
    hp.retrieve_batch(qs, 5)
    assert TRANSFERS.posting_bytes == 0           # postings stay zero
    assert TRANSFERS.descriptor_bytes > 0         # but descriptors flowed
    assert hp.last_plan.plan == "host"


def test_host_arrays_drop_serves_exact_without_host_csc(rng):
    """host_arrays="drop" releases the O(nnz) host posting copy; serving
    must stay exact end-to-end from the resident arrays alone."""
    corpus = make_corpus(rng, n_docs=100, n_vocab=50)
    idx = build_index(corpus, 50, params=BM25Params(method="robertson"))
    dr = DeviceRetriever(idx, regime="gathered", gather="resident",
                         plan="device", host_arrays="drop", **SMALL)
    assert dr.dindex.host is None
    assert dr.index.doc_ids.size == 0 and dr.index.scores.size == 0
    assert idx.doc_ids.size > 0                   # caller's copy untouched
    sc = ScipyBM25(idx)
    queries = [rng.integers(0, 50, size=rng.integers(1, 5)).astype(np.int32)
               for _ in range(3)]
    ids, vals = dr.retrieve_batch(queries, 6)
    for i, q in enumerate(queries):
        oracle = sc.score(q)
        _, ref_v = topk_numpy(oracle[None], 6)
        np.testing.assert_allclose(vals[i], ref_v[0], atol=1e-4)
        np.testing.assert_allclose(oracle[ids[i]], vals[i], atol=1e-4)


def test_drop_mode_guards():
    rng = np.random.default_rng(0)
    corpus = make_corpus(rng, n_docs=20, n_vocab=10)
    idx = build_index(corpus, 10, params=BM25Params())
    with pytest.raises(ValueError, match="device"):
        DeviceRetriever(idx, regime="gathered", gather="resident",
                        plan="host", host_arrays="drop", **SMALL)
    with pytest.raises(ValueError, match="resident"):
        DeviceRetriever(idx, regime="gathered", gather="host",
                        plan="device", **SMALL)
    with pytest.raises(ValueError, match="host_arrays"):
        DeviceIndex.build(idx, host_arrays="free")


# -- core: planner discounts the free device descriptor build -----------------

def test_planner_device_plan_discount():
    """A work ratio between the discounted and full crossover gathers
    under device planning but full-scans under host planning; explicit
    crossovers are honored verbatim either way."""
    ratio = (DEFAULT_CROSSOVER * DEVICE_PLAN_DISCOUNT
             + DEFAULT_CROSSOVER) / 2.0
    nnz, sum_df = int(ratio * 1000), 1000
    host = plan_retrieval(sum_df, nnz, plan="host")
    dev = plan_retrieval(sum_df, nnz, plan="device")
    assert host.regime == "blocked" and host.plan == "host"
    assert dev.regime == "gathered" and dev.plan == "device"
    assert dev.crossover == pytest.approx(
        DEFAULT_CROSSOVER * DEVICE_PLAN_DISCOUNT)
    pinned = plan_retrieval(sum_df, nnz, plan="device", crossover=5.0)
    assert pinned.crossover == 5.0 and pinned.regime == "blocked"
    with pytest.raises(ValueError, match="plan mode"):
        plan_retrieval(1, 1, plan="tpu")


# -- serve: engine end-to-end with device planning ----------------------------

def test_engine_device_plan_exact_and_rescale(rng):
    corpus = make_corpus(rng, n_docs=90, n_vocab=40)
    p = BM25Params(method="bm25l")
    shards = build_sharded_indexes(corpus, 40, 3, params=p)
    eng = RetrievalEngine(shards, k=7, deadline_s=30.0, scorer="auto",
                          scorer_opts=dict(gather="resident",
                                           plan="device", **SMALL))
    qs = [rng.integers(0, 40, size=5).astype(np.int32) for _ in range(4)]
    rb = eng.retrieve_batch(qs)
    assert rb.ids.shape == (4, 7) and not rb.degraded
    for i, q in enumerate(qs):
        oracle = dense_oracle_scores(corpus, 40, q, p)
        _, ref_v = topk_numpy(oracle[None], 7)
        np.testing.assert_allclose(rb.scores[i], ref_v[0], atol=1e-3)
    eng.rescale(2)                                # boundaries move
    rb2 = eng.retrieve_batch(qs)
    for i, q in enumerate(qs):
        oracle = dense_oracle_scores(corpus, 40, q, p)
        _, ref_v = topk_numpy(oracle[None], 7)
        np.testing.assert_allclose(rb2.scores[i], ref_v[0], atol=1e-3)
