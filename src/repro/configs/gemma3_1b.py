"""gemma3-1b [hf:google/gemma-3-1b-pt]: 5:1 local:global attention.

26L, d_model=1152, 4 heads (GQA kv=1), head_dim=256, d_ff=6912,
vocab=262144. Every 6th layer is global (full attention, rope theta 1e6);
the rest slide over a 512-token window (theta 10k). Gemma conventions:
(1+w) RMSNorm, embeddings scaled by sqrt(d_model), tied unembedding.
long_500k runs: only the 4-5 global layers keep full-length KV (kv=1).
"""

import jax.numpy as jnp

from ..models.transformer import LMConfig, reduced
from .common import lm_cells

CONFIG = LMConfig(
    name="gemma3-1b",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    sliding_window=512, global_every=6,
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    embed_scale=True, rmsnorm_plus_one=True, tie_embeddings=True,
    dtype=jnp.bfloat16,
)

SMOKE = reduced(CONFIG, global_every=3, n_layers=3)

FAMILY = "lm"
N_MICROBATCHES = 2


def cells():
    return lm_cells("gemma3-1b", CONFIG, n_microbatches=N_MICROBATCHES)
