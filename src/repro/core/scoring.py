"""Device-side BM25S query scoring in JAX.

This is the paper-faithful eager path, adapted to XLA's static-shape world:

    slice the query tokens' postings  →  sum across the token dimension

becomes

    ragged-gather flatten (static postings budget)  →  segment_sum

A query is a padded ``(tokens[Q_max], weights[Q_max])`` pair; ``weights``
carries the per-unique-token occurrence count (summing a token's postings
``w`` times ≡ the paper's per-occurrence summation) and 0 marks padding.
The gather budget ``P_max`` bounds ``Σᵢ df(qᵢ)`` per query and is a static
compile-time constant (configs size it from corpus statistics; the
retriever logs and truncates pathological queries).

The shifted variants' query constant ``Σᵢ wᵢ·S⁰(qᵢ)`` (§2.1) is added here,
so returned scores are *exact*, not rank-equivalent.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# re-exported here (budget logic's public home); defined next to the other
# static-shape/bucketing machinery in the sparse layout module
from ..sparse.block_csr import bucket_pow2  # noqa: F401
from .index import BM25Index


@jax.tree_util.register_pytree_node_class
@dataclass
class DeviceIndex:
    """BM25Index arrays on device (one shard's postings)."""

    indptr: jax.Array       # [V+1] int32
    doc_ids: jax.Array      # [nnz] int32
    scores: jax.Array       # [nnz] float32
    nonoccurrence: jax.Array  # [V] float32
    n_docs: int             # static
    doc_offset: int = 0     # static

    def tree_flatten(self):
        return (
            (self.indptr, self.doc_ids, self.scores, self.nonoccurrence),
            (self.n_docs, self.doc_offset),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        indptr, doc_ids, scores, nonocc = children
        return cls(indptr, doc_ids, scores, nonocc, *aux)

    @staticmethod
    def from_host(index: BM25Index) -> "DeviceIndex":
        return DeviceIndex(
            indptr=jnp.asarray(index.indptr, dtype=jnp.int32),
            doc_ids=jnp.asarray(index.doc_ids, dtype=jnp.int32),
            scores=jnp.asarray(index.scores, dtype=jnp.float32),
            nonoccurrence=jnp.asarray(index.nonoccurrence, dtype=jnp.float32),
            n_docs=int(index.doc_lens.size),
            doc_offset=int(index.doc_offset),
        )


def pad_queries(query_tokens: list[np.ndarray], q_max: int, *,
                return_uniq: bool = False):
    """Unique-ify + pad a batch of tokenized queries.

    Returns ``tokens [B, q_max] int32`` (pad = -1) and
    ``weights [B, q_max] float32`` (occurrence counts; 0 = pad). Queries with
    more than ``q_max`` unique tokens keep the highest-count tokens.

    Vectorized: ONE flattened ``lexsort`` over the whole batch replaces the
    per-query ``np.unique`` loop (this sits on the serving hot path of every
    scorer). Unique (query, token) pairs are the runs of the sorted flat
    stream; within-query ranks come from run bookkeeping, never a Python
    loop. Semantics match the loop exactly, including the truncation order
    (count-descending, token-ascending ties) for queries over ``q_max``.

    ``return_uniq=True`` appends the batch's sorted unique tokens as a
    third output, derived from the (much smaller) run set instead of
    re-sorting the raw stream — the device scorers need exactly this table
    and would otherwise pay a second full sort per batch. Note it covers
    ALL input tokens, including any a truncated query dropped.
    """
    b = len(query_tokens)
    toks = np.full((b, q_max), -1, dtype=np.int32)
    wts = np.zeros((b, q_max), dtype=np.float32)
    no_uniq = np.zeros(0, dtype=np.int64)
    if b == 0:
        return (toks, wts, no_uniq) if return_uniq else (toks, wts)
    lens = np.fromiter((q.size for q in query_tokens), dtype=np.int64,
                       count=b)
    if lens.sum() == 0:
        return (toks, wts, no_uniq) if return_uniq else (toks, wts)
    flat = np.concatenate(query_tokens).astype(np.int64, copy=False)
    qi = np.repeat(np.arange(b, dtype=np.int64), lens)
    keep = flat >= 0
    flat, qi = flat[keep], qi[keep]
    if flat.size == 0:
        return (toks, wts, no_uniq) if return_uniq else (toks, wts)
    order = np.lexsort((flat, qi))
    flat, qi = flat[order], qi[order]
    # runs of equal (query, token) = the per-query unique tokens + counts
    new = np.empty(flat.size, dtype=bool)
    new[0] = True
    new[1:] = (flat[1:] != flat[:-1]) | (qi[1:] != qi[:-1])
    run = np.flatnonzero(new)
    counts = np.diff(np.append(run, flat.size))
    u_tok, u_qi = flat[run], qi[run]
    # within-query rank in ascending-token order
    grp_new = np.empty(u_qi.size, dtype=bool)
    grp_new[0] = True
    grp_new[1:] = u_qi[1:] != u_qi[:-1]
    grp_start = np.flatnonzero(grp_new)
    grp_sizes = np.diff(np.append(grp_start, u_qi.size))
    col_asc = np.arange(u_qi.size) - np.repeat(grp_start, grp_sizes)
    # within-query rank in (count-desc, token-asc) order — the loop's
    # ``argsort(-counts, kind="stable")`` truncation policy
    order2 = np.lexsort((col_asc, -counts, u_qi))
    rank_desc = np.empty(u_qi.size, dtype=np.int64)
    rank_desc[order2] = np.arange(u_qi.size) - np.repeat(grp_start, grp_sizes)
    over = np.repeat(grp_sizes > q_max, grp_sizes)
    col = np.where(over, rank_desc, col_asc)
    sel = col < q_max
    toks[u_qi[sel], col[sel]] = u_tok[sel].astype(np.int32)
    wts[u_qi[sel], col[sel]] = counts[sel].astype(np.float32)
    if return_uniq:
        return toks, wts, np.unique(u_tok)
    return toks, wts


def _flatten_postings(indptr: jax.Array, q_tokens: jax.Array,
                      q_weights: jax.Array, p_max: int):
    """Ragged-gather bookkeeping: map flat slot j -> (query token i, posting).

    Returns (positions [p_max], weight-per-slot [p_max], valid mask [p_max],
    total postings requested). When ``total > p_max`` the trailing
    ``total - p_max`` postings DO NOT FIT and are silently dropped by the
    static budget — callers must surface ``total > p_max`` as an overflow
    flag (see :func:`score_query` / :func:`score_batch`), otherwise the
    truncation is undetectable score corruption.
    """
    valid_q = q_tokens >= 0
    safe_q = jnp.where(valid_q, q_tokens, 0)
    starts = indptr[safe_q]
    lens = jnp.where(valid_q, indptr[safe_q + 1] - starts, 0)
    cum = jnp.cumsum(lens)                      # inclusive
    total = cum[-1]
    j = jnp.arange(p_max, dtype=jnp.int32)
    # token index owning flat slot j (first i with cum[i] > j)
    i = jnp.searchsorted(cum, j, side="right").astype(jnp.int32)
    i = jnp.minimum(i, q_tokens.shape[0] - 1)
    offset_excl = cum[i] - lens[i]
    pos = starts[i] + (j - offset_excl)
    ok = j < total
    return jnp.where(ok, pos, 0), jnp.where(ok, q_weights[i], 0.0), ok, total


def score_query(index: DeviceIndex, q_tokens: jax.Array, q_weights: jax.Array,
                *, p_max: int) -> tuple[jax.Array, jax.Array]:
    """Exact BM25 scores of one query against this shard's documents.

    The eager path: gather the precomputed postings scores, segment-sum per
    document, add the §2.1 nonoccurrence shift. Returns ``(scores [n_docs],
    overflow [] bool)`` — overflow is True iff ``Σᵢ df(qᵢ) > p_max``, i.e.
    the static budget truncated postings and the scores are lower bounds.
    """
    pos, w, ok, total = _flatten_postings(index.indptr, q_tokens, q_weights,
                                          p_max)
    g_scores = index.scores[pos] * w
    g_docs = jnp.where(ok, index.doc_ids[pos], index.n_docs)
    dense = jax.ops.segment_sum(
        g_scores, g_docs, num_segments=index.n_docs + 1
    )[: index.n_docs]
    valid_q = q_tokens >= 0
    shift = jnp.sum(
        jnp.where(valid_q, index.nonoccurrence[jnp.where(valid_q, q_tokens, 0)], 0.0)
        * q_weights
    )
    return dense + shift, total > p_max


@partial(jax.jit, static_argnames=("p_max", "return_overflow"))
def score_batch(index: DeviceIndex, q_tokens: jax.Array, q_weights: jax.Array,
                *, p_max: int, return_overflow: bool = False):
    """Batched exact scoring: ``[B, Q_max] -> [B, n_docs]``.

    With ``return_overflow=True`` also returns a ``[B]`` bool flag marking
    queries whose posting demand exceeded the static ``p_max`` budget (their
    scores silently miss the dropped postings — re-run with a larger budget
    or log the degradation; see ``BM25Retriever.retrieve``).
    """
    scores, overflow = jax.vmap(
        lambda t, w: score_query(index, t, w, p_max=p_max))(
        q_tokens, q_weights
    )
    if return_overflow:
        return scores, overflow
    return scores


def query_posting_budget(index: BM25Index, q_tokens: np.ndarray) -> int:
    """Host helper: exact Σ df(qᵢ) for a padded query batch (budget sizing)."""
    df = np.diff(index.indptr)
    safe = np.where(q_tokens >= 0, q_tokens, 0)
    return int((np.where(q_tokens >= 0, df[safe], 0)).sum(axis=-1).max())


def batch_posting_budget(index: BM25Index, q_tokens: np.ndarray) -> int:
    """Exact Σ df over the BATCH's unique tokens — the gathered path's work.

    The gather materializes each unique token's posting run once for the
    whole batch, so its budget is Σ df(unique(batch)), not the per-query
    maximum :func:`query_posting_budget` sizes.
    """
    uniq = np.unique(q_tokens[q_tokens >= 0])
    df = np.diff(index.indptr)
    return int(df[uniq].sum()) if uniq.size else 0


def suggest_p_max(index: BM25Index, q_max: int, *, quantile: float = 1.0,
                  tile: int = 1024) -> int:
    """Static budget heuristic: q_max × weighted-quantile(df), tile-rounded.

    The quantile is **df-weighted**: realistic query tokens are drawn
    roughly ∝ df (head tokens dominate traffic), so the budget question is
    "how big is the posting run of the q-quantile *query token*", not of
    the q-quantile *distinct vocabulary entry*. An unweighted quantile over
    distinct tokens wildly undersizes on Zipfian vocabularies where the
    tail is millions of df=1 tokens but queries hit the head. At
    ``quantile=1.0`` both definitions degenerate to ``max(df)`` (the
    default stays a safe upper bound).
    """
    df = np.diff(index.indptr)
    df = df[df > 0]
    if df.size:
        sdf = np.sort(df)
        cum = np.cumsum(sdf, dtype=np.float64)
        i = int(np.searchsorted(cum, quantile * cum[-1], side="left"))
        per_tok = float(sdf[min(i, sdf.size - 1)])
    else:
        per_tok = 1.0
    budget = int(q_max * per_tok)
    return max(tile, ((budget + tile - 1) // tile) * tile)
