"""Table 2 — NDCG@10 under the tokenizer ablation (stopwords × stemmer) —
plus the tokenization-throughput benchmark for the vectorized corpus pass.

The paper's finding: the Snowball stemmer modestly improves NDCG on
average, stopwords have a small effect. The synthetic corpus plants
relevance by topic (data/corpus.py) and inflects topical words so that
stemming actually matters (queries use different surface forms than
documents).

``run_throughput`` times ``Tokenizer.tokenize_corpus`` (one flattened
``np.unique`` pass, per-unique-surface-form stemming/vocab lookups, one
array gather back to per-document ids) against the sequential per-token
loop it replaced (``_tokenize_corpus_loop``, kept as the oracle) and
reports the speedup — outputs are asserted identical before timing.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import BM25Retriever
from repro.core.tokenizer import Tokenizer
from repro.data.corpus import SyntheticCorpus, ndcg_at_k

_SUFFIXES = ["", "s", "ed", "ing", "ly"]


def _inflect(text: str, rng: np.random.Generator) -> str:
    return " ".join(w + rng.choice(_SUFFIXES) for w in text.split())


def run(n_docs: int = 800, n_queries: int = 60, k: int = 10) -> list[dict]:
    base = SyntheticCorpus(n_docs=n_docs, n_topics=16, vocab_size=900,
                           seed=3)
    rng = np.random.default_rng(7)
    docs = [_inflect(d, rng) for d in base.documents]
    queries, qrels = base.queries_with_qrels(n_queries)
    queries = [_inflect(q, rng) for q in queries]
    # mix stopwords into queries so the stopword axis is exercised
    queries = [f"the {q} of a" for q in queries]

    rows = []
    for stop in ("english", None):
        for stem in ("snowball", None):
            r = BM25Retriever(method="lucene", k1=1.5, b=0.75,
                              stopwords=stop, stemmer=stem).index(docs)
            ids, _ = r.retrieve(queries, k=k)
            ids = np.asarray(ids)
            ndcg = float(np.mean([
                ndcg_at_k(ids[i], qrels[i], k) for i in range(len(queries))
            ]))
            rows.append({"stopwords": stop or "none",
                         "stemmer": stem or "none",
                         "ndcg@10": round(ndcg, 4)})
    return rows


def run_throughput(n_docs: int = 3000, repeats: int = 3) -> dict:
    """Vectorized vs per-token-loop corpus tokenization (same output)."""
    base = SyntheticCorpus(n_docs=n_docs, n_topics=32, vocab_size=2000,
                           seed=11)
    rng = np.random.default_rng(13)
    docs = [_inflect(d, rng) for d in base.documents]

    fast = Tokenizer().tokenize_corpus(docs)
    slow = Tokenizer()._tokenize_corpus_loop(docs)
    for a, b in zip(fast, slow):
        np.testing.assert_array_equal(a, b)       # identical before timing

    def best_of(fn):
        t = np.inf
        for _ in range(repeats):
            tok = Tokenizer()
            t0 = time.perf_counter()
            fn(tok)
            t = min(t, time.perf_counter() - t0)
        return t

    t_loop = best_of(lambda tok: tok._tokenize_corpus_loop(docs))
    t_vec = best_of(lambda tok: tok.tokenize_corpus(docs))
    n_tokens = int(sum(len(d.split()) for d in docs))
    return {
        "n_docs": n_docs, "n_tokens": n_tokens,
        "loop_s": round(t_loop, 4), "vectorized_s": round(t_vec, 4),
        "speedup": round(t_loop / max(t_vec, 1e-9), 2),
        "vectorized_tokens_per_s": int(n_tokens / max(t_vec, 1e-9)),
    }


if __name__ == "__main__":
    for r in run():
        print(r)
    print(run_throughput())
