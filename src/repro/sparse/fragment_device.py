"""Device-side fragment planning: the batch's DMA-unit table built on TPU.

``sparse.block_csr.fragment_plan`` compiles a query batch into the resident
kernel's ``[6, nf_pad]`` descriptor table by walking the HOST CSC copy —
an O(Σ df) host read per batch, plus a per-batch descriptor upload. This
module is the device port: the SAME table is computed by a jit-compiled
builder straight from the HBM-resident CSC ``indptr``/``doc_ids`` arrays
(:class:`~repro.sparse.block_csr.DeviceIndex`), so steady-state serving
reads no host posting array at all and ships ZERO descriptor bytes
host→device per batch (the table is born on device).

The algorithm mirrors :func:`~repro.sparse.block_csr.fragment_plan`
byte-for-byte (tests assert equality of the emitted tables):

1. posting-run descriptors ``(start, len)`` from the resident ``indptr``
   for the batch's padded unique-token table (sentinel ``INT32_MAX`` rows
   contribute length 0);
2. the flat posting stream is reconstructed positionally over a static
   ``p_bucket`` budget (``searchsorted`` over the run-length cumsum — the
   same trick as ``core.retrieval._device_gathered_topk``), and split into
   *segments* wherever the owning run or the document block of
   ``doc_ids[pos]`` changes;
3. segments are split into ≤``frag``-sized *fragments* (a cumulative-max
   recovers each position's segment start, so fragment boundaries fall at
   ``frag`` multiples inside every segment), compacted into a static
   ``nf_pad`` table, and stably sorted by document block — identical
   ordering to the host plan because a stable block-sort commutes with
   per-segment fragmenting;
4. the visited-block set (first-fragment-per-block flags after the sort)
   feeds a device port of :func:`~repro.core.retrieval.default_doc_ids`,
   so the default-document splice needs no host plan either.

Static shapes: ``p_bucket`` is pow2-bucketed from the batch's Σ df (free,
host ``df`` metadata — O(V), kept even when the host posting arrays are
dropped); ``nf_pad`` is pow2-bucketed with an OVERFLOW flag — every
fragment carries ≥1 posting, so ``nf ≤ Σ df`` and the retry loop in
:func:`plan_fragments_device` always terminates at the Σ df bucket.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .block_csr import _BOUND_ABS, _BOUND_SLACK, bucket_pow2

_I32_BIG = np.iinfo(np.int32).max


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "frag", "nf_pad", "p_bucket", "k",
                     "n_docs"),
)
def build_fragment_table(uniq: jax.Array, indptr: jax.Array,
                         doc_ids_res: jax.Array, *, block_size: int,
                         frag: int, nf_pad: int, p_bucket: int, k: int,
                         n_docs: int
                         ) -> tuple[jax.Array, jax.Array, jax.Array,
                                    jax.Array]:
    """Padded unique tokens × resident CSC -> fragment table, on device.

    ``uniq`` is the ``[U]`` int32 sorted unique-token table padded with
    ``INT32_MAX`` (``pack_query_batch``'s layout — descriptor ``uniq``
    rows index THIS table, matching the kernel's weight rows);
    ``indptr``/``doc_ids_res`` are the resident ``[V+1]`` / ``[1,
    nnz_pad]`` arrays. ``p_bucket`` must cover the batch's Σ df (the
    caller sizes it from host metadata, so it cannot overflow).

    Returns ``(desc [6, nf_pad] i32, def_ids [k] i32, nf [] i32,
    overflow [] bool)``. ``desc`` matches the host
    ``fragment_plan(...).desc`` byte-for-byte whenever ``overflow`` is
    False; ``def_ids`` matches ``default_doc_ids`` on the host plan's
    visited blocks. On overflow (``nf > nf_pad``) the table is garbage —
    callers must retry at a larger bucket.
    """
    u = uniq.shape[0]
    iota_p = jnp.arange(p_bucket, dtype=jnp.int32)
    iota_f = jnp.arange(nf_pad, dtype=jnp.int32)

    # 1. run descriptors from the resident indptr (sentinel rows: len 0)
    valid_u = uniq < _I32_BIG
    safe_u = jnp.where(valid_u, uniq, 0)
    starts = indptr[safe_u]
    lens = jnp.where(valid_u, indptr[safe_u + 1] - starts, 0)

    # 2. flat stream positions + (owner run, doc block) per position
    cum = jnp.cumsum(lens)
    total = cum[u - 1]
    owner = jnp.searchsorted(cum, iota_p, side="right").astype(jnp.int32)
    owner = jnp.minimum(owner, u - 1)
    pos = starts[owner] + (iota_p - (cum[owner] - lens[owner]))
    ok = iota_p < total
    blk = jnp.where(ok, doc_ids_res[0, jnp.where(ok, pos, 0)] // block_size,
                    _I32_BIG)

    # segment boundaries: owner or block changes (flat order, like host)
    prev_owner = jnp.concatenate(
        [jnp.full((1,), -1, jnp.int32), owner[:-1]])
    prev_blk = jnp.concatenate([jnp.full((1,), -1, jnp.int32), blk[:-1]])
    new_seg = ok & ((iota_p == 0) | (owner != prev_owner)
                    | (blk != prev_blk))

    # 3. fragment boundaries: segment starts + frag multiples within one
    seg_start = jax.lax.cummax(jnp.where(new_seg, iota_p, -1))
    new_frag = ok & (new_seg | ((iota_p - seg_start) % frag == 0))
    frank = jnp.cumsum(new_frag.astype(jnp.int32)) - 1
    nf = jnp.sum(new_frag.astype(jnp.int32))
    nf_c = jnp.minimum(nf, nf_pad)
    overflow = nf > nf_pad

    # compact fragment-start flat positions into [nf_pad] (rank scatter;
    # non-boundary positions collide harmlessly on the dropped extra slot)
    slot = jnp.where(new_frag & (frank < nf_pad), frank, nf_pad)
    fs = jnp.full((nf_pad + 1,), p_bucket, jnp.int32).at[slot].min(iota_p)
    fs = fs[:nf_pad]
    freal = iota_f < nf_c
    safe_fs = jnp.where(freal, fs, 0)
    nxt = jnp.where(iota_f + 1 < nf_c,
                    jnp.concatenate([fs[1:],
                                     jnp.full((1,), p_bucket, jnp.int32)]),
                    total)
    f_start = pos[safe_fs]
    f_valid = jnp.where(freal, nxt - fs, 0)
    f_uniq = owner[safe_fs]
    f_blk = jnp.where(freal, blk[safe_fs], _I32_BIG)

    # stable block-sort of flat-order fragments == host's segment sort
    order = jnp.argsort(f_blk)
    o_start, o_valid, o_uniq, o_blk, o_real = (
        f_start[order], f_valid[order], f_uniq[order], f_blk[order],
        freal[order])
    prev_o = jnp.concatenate([jnp.full((1,), -1, jnp.int32), o_blk[:-1]])
    next_o = jnp.concatenate([o_blk[1:], jnp.full((1,), -1, jnp.int32)])
    o_first = o_real & (o_blk != prev_o)
    o_last = o_real & (o_blk != next_o)
    desc = jnp.stack([
        jnp.where(o_real, o_start, 0),
        o_valid,
        jnp.where(o_real, o_uniq, 0),
        jnp.where(o_real, o_blk, 0),
        o_first.astype(jnp.int32),
        o_last.astype(jnp.int32),
    ]).astype(jnp.int32)

    # 4. default doc ids from unvisited blocks (device default_doc_ids):
    # o_first flags are exactly the sorted visited-block set
    n_blocks = max(1, -(-n_docs // block_size))
    nv = jnp.sum(o_first.astype(jnp.int32))
    vrank = jnp.cumsum(o_first.astype(jnp.int32)) - 1
    vslot = jnp.where(o_first & (vrank < nf_pad), vrank, nf_pad)
    vis = jnp.full((nf_pad + 1,), _I32_BIG, jnp.int32).at[vslot].min(o_blk)
    vis = vis[:nf_pad]
    # j-th missing block via the miss-count trick (vis sorted ascending)
    miss_before = jnp.where(iota_f < nv, vis - iota_f, n_blocks + 1)
    m = max(1, min(k, n_blocks))
    jj = jnp.arange(m, dtype=jnp.int32)
    unvis = jj + jnp.searchsorted(miss_before, jj + 1).astype(jnp.int32)
    uvalid = unvis < n_blocks
    lo = jnp.where(uvalid, unvis * block_size, 0)
    cnt = jnp.where(uvalid, jnp.minimum(lo + block_size, n_docs) - lo, 0)
    ccum = jnp.cumsum(cnt)
    tt = jnp.arange(k, dtype=jnp.int32)
    bidx = jnp.minimum(
        jnp.searchsorted(ccum, tt, side="right").astype(jnp.int32), m - 1)
    flat = lo[bidx] + (tt - (ccum[bidx] - cnt[bidx]))
    def_ids = jnp.where(tt < ccum[m - 1], flat, n_docs).astype(jnp.int32)

    return desc, def_ids, nf, overflow


def plan_fragments_device(dindex, uniq_tab, *, sum_df: int, k: int,
                          block_size: int | None = None,
                          nf_bucket: int | None = None,
                          state: dict | None = None):
    """Build a batch's fragment table ON DEVICE, retrying on nf overflow.

    The device counterpart of calling ``fragment_plan`` +
    ``default_doc_ids`` + ``put_descriptor_array``: nothing O(Σ df) is
    read on host and nothing at all is uploaded (the unique-token table is
    query data the batch ships anyway). ``sum_df`` comes free from the
    host ``df`` metadata and sizes the flat-stream budget, so the posting
    dimension can never overflow; the fragment-count bucket starts at an
    estimate (``Σ df/frag`` full fragments + one per live run) — or
    ``nf_bucket``/the last successful bucket in ``state`` — and doubles on
    the overflow flag up to the Σ df bucket, which always fits because
    every fragment carries at least one posting.

    Returns ``(desc [6, nf_pad] i32 device, def_ids [k] i32 device,
    nf_bucket_used)``.
    """
    if dindex.csc_indptr is None or dindex.csc_doc_ids is None:
        from repro.serve.errors import ResidencyError
        raise ResidencyError("device fragment planning needs a resident "
                             "CSC index (DeviceIndex built with "
                             "with_csc=True)")
    # fault-injection site ``plan.fragments_device`` (repro.serve.faults):
    # an armed overflow fault simulates nf-bucket regrowth exhaustion
    import sys
    _f = sys.modules.get("repro.serve.faults")
    if _f is not None and _f.ACTIVE:
        _f.fire("plan.fragments_device")
    block_size = block_size or dindex.block_size
    frag = dindex.frag
    uniq_dev = jnp.asarray(np.asarray(uniq_tab, dtype=np.int32))
    u = int(uniq_dev.shape[0])
    p_bucket = bucket_pow2(max(sum_df, 1), floor=8)
    cap = p_bucket                       # nf ≤ Σ df ≤ p_bucket, always fits
    if nf_bucket is not None:
        nf_pad = min(bucket_pow2(nf_bucket, floor=8), cap)
    else:
        est = 2 * (sum_df // frag) + u + 8
        nf_pad = min(bucket_pow2(est, floor=8), cap)
        if state is not None:
            nf_pad = min(max(nf_pad, state.get("nf", 8)), cap)
    while True:
        desc, def_ids, _nf, over = build_fragment_table(
            uniq_dev, dindex.csc_indptr, dindex.csc_doc_ids,
            block_size=block_size, frag=frag, nf_pad=nf_pad,
            p_bucket=p_bucket, k=k, n_docs=dindex.n_docs)
        if nf_pad >= cap or not bool(over):
            break
        nf_pad = min(nf_pad * 2, cap)    # overflow -> retry, never truncate
    if state is not None:
        state["nf"] = nf_pad
    return desc, def_ids, nf_pad


# -- device half of the pruned regime ----------------------------------------
#
# The threshold-aware pruning pass mirrors the host one
# (``block_csr.block_upper_bounds`` / ``prune_fragment_plan`` /
# ``select_seed_blocks``) but reads only the HBM-resident block-max table
# and the device-built fragment table — under ``plan="device"`` the pruned
# regime therefore ships ZERO descriptor bytes host→device per batch, same
# invariant as the unpruned device plan (the compacted table and the bound
# rows are born on device).


@functools.partial(jax.jit, static_argnames=("quantized",))
def block_bounds_device(table: jax.Array, scale: jax.Array, uniq: jax.Array,
                        weights: jax.Array, *, quantized: bool) -> jax.Array:
    """Device port of ``block_csr.block_upper_bounds``: ``[nb_pad, B]``.

    ``table`` is the resident ``[V, nb_pad]`` block-max array (u8 codes
    when ``quantized`` — dequantized here against the ``[V]`` per-token
    ``scale`` vector, ceil-quantization keeps the bound conservative);
    ``uniq``/``weights`` are the batch's packed query operands (sentinel
    rows carry zero weight). Slack-inflated in lockstep with the host
    version so both planners prune identically-safely.
    """
    safe = jnp.clip(uniq.astype(jnp.int32), 0, table.shape[0] - 1)
    rows = table[safe].astype(jnp.float32)               # [U, nb_pad]
    if quantized:
        rows = rows * scale[safe][:, None]
    ub = rows.T @ weights                                # [nb_pad, B]
    return ub * (1.0 + _BOUND_SLACK) + _BOUND_ABS


@jax.jit
def compact_fragment_table(desc: jax.Array, keep: jax.Array
                           ) -> tuple[jax.Array, jax.Array]:
    """Stable-partition a ``[6, nf_pad]`` table to the kept columns.

    Surviving fragments keep their relative order (a stable argsort on the
    drop flag), so the block grouping and first/last accumulator flags
    stay valid as long as ``keep`` is block-uniform — which the threshold
    test guarantees (it depends only on the fragment's block). Dropped
    columns become all-zero padding at the tail. Returns ``(compacted
    [6, nf_pad], n_kept [])``; the caller slices the static width down to
    the survivor bucket (pure device slicing, nothing uploaded).
    """
    order = jnp.argsort(jnp.logical_not(keep), stable=True)
    return (jnp.where(keep[order][None, :], desc[:, order], 0),
            jnp.sum(keep.astype(jnp.int32)))


@functools.partial(jax.jit, static_argnames=("n_seed",))
def seed_fragment_mask(desc: jax.Array, ub: jax.Array, *, n_seed: int
                       ) -> jax.Array:
    """Fragments of each query's ``n_seed`` highest-bound visited blocks.

    The threshold-seeding choice (device port of
    ``block_csr.select_seed_blocks``): PER QUERY, scoring the
    highest-upper-bound blocks first yields a tight per-query threshold;
    the per-query picks are unioned (a shared pick would let one query's
    hot blocks crowd out the rest). Ties at a query's ``n_seed``-th bound
    admit extra blocks — more seed work, never less correctness. Returns
    a block-uniform boolean mask over columns.
    """
    blk = desc[3]
    real = desc[1] > 0
    neg = jnp.finfo(ub.dtype).min
    # per-(block, query) bound restricted to blocks the batch visits
    blk_score = jnp.full(ub.shape, neg, ub.dtype).at[blk].max(
        jnp.where(real[:, None], ub[blk], neg))          # [nb_pad, B]
    kth = jax.lax.top_k(blk_score.T,
                        min(n_seed, ub.shape[0]))[0][:, -1]   # [B]
    kth = jnp.maximum(kth, neg / 2)      # no-visited/padding query: none
    # the zero-bound floor keeps an all-tied trivial column (a real empty
    # query: every block bounds at the additive slack) from seeding the
    # whole table — a zero-bound block cannot tighten any threshold
    live = blk_score[blk] > 2.0 * _BOUND_ABS
    return real & jnp.any((blk_score[blk] >= kth[None, :]) & live, axis=1)


@jax.jit
def prune_fragment_mask(desc: jax.Array, ub: jax.Array, tau: jax.Array
                        ) -> jax.Array:
    """Survivors of the threshold test: blocks some query can still win.

    ``tau`` is the ``[B]`` per-query threshold (a real document's full
    kernel-computed score per query — the seed scoreboard's k-th row — so
    a certified lower bound on each final k-th score; -inf rows disable
    pruning for that query). A fragment survives iff ANY query's bound
    reaches its threshold; the test reads only the fragment's block, so
    the mask is block-uniform and compaction preserves accumulator flags.
    """
    return (desc[1] > 0) & jnp.any(ub[desc[3]] >= tau[None, :], axis=1)
