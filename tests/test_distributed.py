"""Distribution-layer tests on 8 fake CPU devices (subprocess so the main
test process keeps 1 device)."""

import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    # pin the cpu backend BEFORE importing jax: the stripped subprocess env
    # drops the parent's JAX_PLATFORMS, and letting jax probe for TPU
    # hardware stalls startup by minutes on CPU-only hosts
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import (BM25Params, build_sharded_indexes, pad_queries,
                            suggest_p_max, dense_oracle_scores, topk_numpy)
    from repro.core.retrieval import make_sharded_retrieve, stack_shard_arrays
    from repro.launch.mesh import make_mesh_from, make_test_mesh

    out = {}

    # --- elastic mesh builder
    mesh = make_mesh_from(jax.devices())
    out["mesh_shape"] = dict(mesh.shape)
    mesh6 = make_mesh_from(jax.devices()[:6])      # non-power-of-two pool
    out["mesh6_shape"] = dict(mesh6.shape)

    # --- sharded retrieval == oracle on 8 devices
    rng = np.random.default_rng(0)
    V, C = 80, 64
    corpus = [rng.integers(0, V, size=rng.integers(1, 30)).astype(np.int32)
              for _ in range(C)]
    queries = [rng.integers(0, V, size=rng.integers(1, 8)).astype(np.int32)
               for _ in range(4)]
    p = BM25Params(method="bm25+")
    shards = build_sharded_indexes(corpus, V, 8, params=p)
    m8 = make_mesh_from(jax.devices())
    axes = tuple(m8.shape.keys())
    arrs, ndoc = stack_shard_arrays(shards, m8, axes)
    toks, wts = pad_queries(queries, 8)
    pm = max(suggest_p_max(s, 8) for s in shards)
    retrieve = make_sharded_retrieve(m8, axes, p_max=pm, k=5,
                                     n_docs_per_shard=ndoc)
    gidx, gvals = retrieve(arrs, toks, wts)
    ok = True
    for i, q in enumerate(queries):
        oracle = dense_oracle_scores(corpus, V, q, p)
        _, ref_v = topk_numpy(oracle[None], 5)
        ok &= bool(np.allclose(np.sort(np.asarray(gvals)[i]),
                               np.sort(ref_v[0]), atol=1e-3))
    out["sharded_retrieval_exact"] = ok

    # --- LM train step lowers + runs on a 2x4 mesh with real values
    from repro.configs import get_smoke
    from repro.configs.common import lm_param_shardings, batch_shardings
    from repro.dist.sharding import activation_sharding
    from repro.models import transformer
    from repro.train import AdamW, init_train_state, make_train_step
    import functools
    cfg = get_smoke("qwen3-8b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=1e-3)
    step = make_train_step(functools.partial(transformer.loss_fn, cfg), opt,
                           n_microbatches=2)
    state = init_train_state(params, opt)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(8, 16)),
                       jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    params_shapes = jax.eval_shape(lambda: params)
    with m8, activation_sharding(m8):
        ps = lm_param_shardings(cfg, params_shapes, m8)
        os_ = {"m": lm_param_shardings(cfg, state["m"], m8),
               "v": lm_param_shardings(cfg, state["v"], m8),
               "step": NamedSharding(m8, P())}
        bs = batch_shardings(m8, batch)
        jstep = jax.jit(step, in_shardings=(ps, os_, bs))
        p2, s2, metrics = jstep(params, state, batch)
        out["lm_step_loss"] = float(metrics["loss"])
    # same step on 1 device for numerical comparison
    p1, s1, m1 = jax.jit(step)(params, state, batch)
    out["loss_matches_single_device"] = bool(
        abs(float(m1["loss"]) - out["lm_step_loss"]) < 1e-2)

    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def dist_results():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=600, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_elastic_mesh_shapes(dist_results):
    assert dist_results["mesh_shape"] == {"data": 1, "model": 8} or \
        dist_results["mesh_shape"]["data"] * \
        dist_results["mesh_shape"]["model"] == 8
    assert dist_results["mesh6_shape"]["data"] * \
        dist_results["mesh6_shape"]["model"] in (4, 6)


def test_sharded_retrieval_exact_8dev(dist_results):
    assert dist_results["sharded_retrieval_exact"]


def test_lm_train_step_runs_sharded(dist_results):
    assert dist_results["lm_step_loss"] > 0
    assert dist_results["loss_matches_single_device"]
