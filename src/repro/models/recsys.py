"""RecSys architectures: DLRM, AutoInt, SASRec, MIND.

All four share the sparse embedding substrate (DESIGN.md §2): huge
row-sharded tables + gather (+ segment-reduce for multi-hot bags) — the
same eager-scoring primitive as BM25S. The embedding lookup is the hot
path; tables are stored concatenated (``[Σ vocab_f, D]`` + per-field row
offsets) so the whole state is a single shardable array and one gather.

``retrieval_scores`` (the ``retrieval_cand`` shape) scores one user against
10⁶ candidates as a batched dot against the item table — never a loop —
and feeds the two-stage top-k kernel.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .common import normal_init, split_keys


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    model: str                       # dlrm | autoint | sasrec | mind
    vocab_sizes: tuple[int, ...]     # per sparse field (item vocab for seq models)
    embed_dim: int
    n_dense: int = 0
    bot_mlp: tuple[int, ...] = ()
    top_mlp: tuple[int, ...] = ()
    n_attn_layers: int = 3           # autoint
    n_heads: int = 2
    d_attn: int = 32
    n_blocks: int = 2                # sasrec
    seq_len: int = 50
    n_interests: int = 4             # mind
    capsule_iters: int = 3
    dtype: Any = jnp.float32

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_rows(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def padded_rows(self) -> int:
        """Concatenated-table rows padded so the (data, model) row/dim
        sharding always divides (4096 | rows)."""
        return -(-self.total_rows // 4096) * 4096

    def field_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]
                              ).astype(np.int32)


def _mlp_init(key, dims):
    ks = split_keys(key, len(dims) - 1)
    return [{"w": normal_init(k, (a, b), 1.0 / np.sqrt(a)),
             "b": jnp.zeros((b,))}
            for k, (a, b) in zip(ks, zip(dims[:-1], dims[1:]))]


def _mlp(params, x, act=jax.nn.relu, last_act=False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or last_act:
            x = act(x)
    return x


def lookup_fields(table: jax.Array, offsets: jax.Array, idx: jax.Array
                  ) -> jax.Array:
    """[B, F] per-field ids -> [B, F, D] rows of the concatenated table."""
    return jnp.take(table, idx + offsets[None, :], axis=0)


# ==========================================================================
# DLRM (arXiv:1906.00091, MLPerf config)
# ==========================================================================

def dlrm_init(key, cfg: RecsysConfig) -> dict:
    ks = iter(split_keys(key, 4))
    return {
        "table": normal_init(next(ks), (cfg.padded_rows, cfg.embed_dim),
                             1.0 / np.sqrt(cfg.embed_dim)),
        "bot": _mlp_init(next(ks), (cfg.n_dense,) + cfg.bot_mlp),
        "top": _mlp_init(next(ks), (_dlrm_top_in(cfg),) + cfg.top_mlp),
    }


def _dlrm_top_in(cfg: RecsysConfig) -> int:
    f = cfg.n_sparse + 1                     # embeddings + bottom-MLP output
    return cfg.embed_dim + f * (f - 1) // 2  # dense feature + pairwise dots


def dlrm_forward(cfg: RecsysConfig, params: dict, batch: dict) -> jax.Array:
    offsets = jnp.asarray(cfg.field_offsets())
    dense = batch["dense"].astype(cfg.dtype)            # [B, 13]
    emb = lookup_fields(params["table"], offsets, batch["sparse"])  # [B,26,D]
    bot = _mlp(params["bot"], dense, last_act=True)     # [B, D]
    z = jnp.concatenate([bot[:, None, :], emb], axis=1)  # [B, 27, D]
    inter = jnp.einsum("bfd,bgd->bfg", z, z)             # [B, 27, 27]
    f = z.shape[1]
    iu, ju = np.triu_indices(f, k=1)
    pairs = inter[:, iu, ju]                             # [B, 351]
    top_in = jnp.concatenate([bot, pairs], axis=-1)
    return _mlp(params["top"], top_in)[:, 0]             # logits [B]


# ==========================================================================
# AutoInt (arXiv:1810.11921)
# ==========================================================================

def autoint_init(key, cfg: RecsysConfig) -> dict:
    d, da, h = cfg.embed_dim, cfg.d_attn, cfg.n_heads
    ks = iter(split_keys(key, 3 + 4 * cfg.n_attn_layers))
    layers = []
    d_in = d
    for _ in range(cfg.n_attn_layers):
        layers.append({
            "wq": normal_init(next(ks), (d_in, da), 1.0 / np.sqrt(d_in)),
            "wk": normal_init(next(ks), (d_in, da), 1.0 / np.sqrt(d_in)),
            "wv": normal_init(next(ks), (d_in, da), 1.0 / np.sqrt(d_in)),
            "wres": normal_init(next(ks), (d_in, da), 1.0 / np.sqrt(d_in)),
        })
        d_in = da
    return {
        "table": normal_init(next(ks), (cfg.padded_rows, d), 1.0 / np.sqrt(d)),
        "layers": layers,
        "out": _mlp_init(next(ks), (cfg.n_sparse * d_in, 1)),
    }


def autoint_forward(cfg: RecsysConfig, params: dict, batch: dict) -> jax.Array:
    offsets = jnp.asarray(cfg.field_offsets())
    x = lookup_fields(params["table"], offsets, batch["sparse"])  # [B,F,D]
    h = cfg.n_heads
    for lp in params["layers"]:
        q = (x @ lp["wq"])
        k = (x @ lp["wk"])
        v = (x @ lp["wv"])
        dh = q.shape[-1] // h
        def split(t):
            return t.reshape(*t.shape[:-1], h, dh)
        att = jnp.einsum("bfhd,bghd->bhfg", split(q), split(k)) / np.sqrt(dh)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhfg,bghd->bfhd", att, split(v))
        o = o.reshape(*x.shape[:-1], h * dh)
        x = jax.nn.relu(o + x @ lp["wres"])
    flat = x.reshape(x.shape[0], -1)
    return _mlp(params["out"], flat)[:, 0]


# ==========================================================================
# SASRec (arXiv:1808.09781)
# ==========================================================================

def sasrec_init(key, cfg: RecsysConfig) -> dict:
    d = cfg.embed_dim
    v = cfg.vocab_sizes[0]
    ks = iter(split_keys(key, 3 + 6 * cfg.n_blocks))
    blocks = []
    for _ in range(cfg.n_blocks):
        blocks.append({
            "ln1": jnp.ones((d,)), "ln2": jnp.ones((d,)),
            "wq": normal_init(next(ks), (d, d), 1.0 / np.sqrt(d)),
            "wk": normal_init(next(ks), (d, d), 1.0 / np.sqrt(d)),
            "wv": normal_init(next(ks), (d, d), 1.0 / np.sqrt(d)),
            "ffn1": _mlp_init(next(ks), (d, d))[0],
            "ffn2": _mlp_init(next(ks), (d, d))[0],
        })
    return {
        "item_emb": normal_init(next(ks), (-(-(v + 1) // 4096) * 4096, d),
                                1.0 / np.sqrt(d)),
        "pos_emb": normal_init(next(ks), (cfg.seq_len, d), 0.02),
        "blocks": blocks,
        "ln_f": jnp.ones((d,)),
    }


def _layernorm(x, w, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w


def sasrec_hidden(cfg: RecsysConfig, params: dict, history: jax.Array
                  ) -> jax.Array:
    """history [B, L] item ids (0 = pad) -> hidden states [B, L, D]."""
    b, l = history.shape
    x = jnp.take(params["item_emb"], history, axis=0)
    x = x + params["pos_emb"][None, :l]
    mask = (history > 0).astype(cfg.dtype)
    x = x * mask[..., None]
    causal = np.tril(np.ones((l, l), bool))
    for blk in params["blocks"]:
        h = _layernorm(x, blk["ln1"])
        q, k, v = h @ blk["wq"], h @ blk["wk"], h @ blk["wv"]
        att = jnp.einsum("bqd,bkd->bqk", q, k) / np.sqrt(q.shape[-1])
        att = jnp.where(causal[None], att, -1e30)
        att = jnp.where(mask[:, None, :] > 0, att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        x = x + jnp.einsum("bqk,bkd->bqd", att, v)
        h = _layernorm(x, blk["ln2"])
        x = x + (jax.nn.relu(h @ blk["ffn1"]["w"] + blk["ffn1"]["b"])
                 @ blk["ffn2"]["w"] + blk["ffn2"]["b"])
        x = x * mask[..., None]
    return _layernorm(x, params["ln_f"])


def sasrec_forward(cfg: RecsysConfig, params: dict, batch: dict) -> jax.Array:
    """Next-item logit for (pos_items, neg_items): returns [B, L, 2] logits."""
    h = sasrec_hidden(cfg, params, batch["history"])       # [B, L, D]
    pos = jnp.take(params["item_emb"], batch["pos_items"], axis=0)
    neg = jnp.take(params["item_emb"], batch["neg_items"], axis=0)
    return jnp.stack([jnp.sum(h * pos, -1), jnp.sum(h * neg, -1)], axis=-1)


# ==========================================================================
# MIND (arXiv:1904.08030)
# ==========================================================================

def mind_init(key, cfg: RecsysConfig) -> dict:
    d = cfg.embed_dim
    v = cfg.vocab_sizes[0]
    ks = iter(split_keys(key, 3))
    return {
        "item_emb": normal_init(next(ks), (-(-(v + 1) // 4096) * 4096, d),
                                1.0 / np.sqrt(d)),
        "bilinear": normal_init(next(ks), (d, d), 1.0 / np.sqrt(d)),
        # fixed (non-trained in paper) routing-logit init, one per interest
        "b_init": normal_init(next(ks), (cfg.n_interests, cfg.seq_len), 1.0),
    }


def _squash(x, axis=-1, eps=1e-9):
    n2 = jnp.sum(x * x, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + eps)


def mind_interests(cfg: RecsysConfig, params: dict, history: jax.Array
                   ) -> jax.Array:
    """Dynamic routing: history [B, L] -> interest capsules [B, K, D]."""
    e = jnp.take(params["item_emb"], history, axis=0)        # [B, L, D]
    mask = (history > 0).astype(cfg.dtype)                   # [B, L]
    u_hat = e @ params["bilinear"]                           # [B, L, D]
    b = jnp.broadcast_to(params["b_init"][None],
                         (history.shape[0],) + params["b_init"].shape)
    v = None
    for it in range(cfg.capsule_iters):
        w = jax.nn.softmax(b, axis=1)                        # over K
        w = w * mask[:, None, :]
        z = jnp.einsum("bkl,bld->bkd", w, u_hat)
        v = _squash(z)
        if it < cfg.capsule_iters - 1:
            # stop-gradient per the paper's routing (coefficients not trained)
            b = b + jnp.einsum("bkd,bld->bkl", jax.lax.stop_gradient(v), u_hat)
    return v


def mind_forward(cfg: RecsysConfig, params: dict, batch: dict) -> jax.Array:
    """Label-aware attention score for pos/neg targets: [B, 2] logits."""
    v = mind_interests(cfg, params, batch["history"])        # [B, K, D]

    def score(items):
        e_t = jnp.take(params["item_emb"], items, axis=0)    # [B, D]
        att = jax.nn.softmax(jnp.einsum("bkd,bd->bk", v, e_t) ** 2, axis=-1)
        u = jnp.einsum("bk,bkd->bd", att, v)
        return jnp.sum(u * e_t, axis=-1)

    return jnp.stack([score(batch["pos_items"]),
                      score(batch["neg_items"])], axis=-1)


# ==========================================================================
# shared losses / serving / retrieval
# ==========================================================================

_FORWARD = {"dlrm": dlrm_forward, "autoint": autoint_forward,
            "sasrec": sasrec_forward, "mind": mind_forward}
_INIT = {"dlrm": dlrm_init, "autoint": autoint_init,
         "sasrec": sasrec_init, "mind": mind_init}


def init_params(key, cfg: RecsysConfig) -> dict:
    return _INIT[cfg.model](key, cfg)


def forward(cfg: RecsysConfig, params: dict, batch: dict) -> jax.Array:
    return _FORWARD[cfg.model](cfg, params, batch)


def loss_fn(cfg: RecsysConfig, params: dict, batch: dict
            ) -> tuple[jax.Array, dict]:
    logits = forward(cfg, params, batch)
    if cfg.model in ("dlrm", "autoint"):                     # CTR: BCE w/ labels
        labels = batch["labels"].astype(jnp.float32)
        loss = jnp.mean(_bce(logits.astype(jnp.float32), labels))
    else:                                                    # pos/neg pairs
        lg = logits.astype(jnp.float32)
        pos, neg = lg[..., 0], lg[..., 1]
        mask = (batch["pos_items"] > 0).astype(jnp.float32)
        loss = ((_bce(pos, jnp.ones_like(pos)) +
                 _bce(neg, jnp.zeros_like(neg))) * mask).sum() \
            / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss}


def _bce(logits, labels):
    return jnp.maximum(logits, 0) - logits * labels + \
        jnp.log1p(jnp.exp(-jnp.abs(logits)))


def retrieval_scores(cfg: RecsysConfig, params: dict, batch: dict,
                     candidates: jax.Array) -> jax.Array:
    """Score a query-user against [Nc] candidate items (batched dot)."""
    if cfg.model == "sasrec":
        h = sasrec_hidden(cfg, params, batch["history"])[:, -1]   # [B, D]
        cand = jnp.take(params["item_emb"], candidates, axis=0)   # [Nc, D]
        return h @ cand.T                                         # [B, Nc]
    if cfg.model == "mind":
        v = mind_interests(cfg, params, batch["history"])         # [B, K, D]
        cand = jnp.take(params["item_emb"], candidates, axis=0)
        return jnp.einsum("bkd,nd->bkn", v, cand).max(axis=1)     # max-interest
    # CTR models: candidate id occupies the item field (field 0 by convention)
    b = batch["sparse"].shape[0]
    nc = candidates.shape[0]
    sparse = jnp.broadcast_to(batch["sparse"][:, None, :],
                              (b, nc, cfg.n_sparse)).reshape(b * nc, -1)
    sparse = sparse.at[:, 0].set(jnp.tile(candidates, b))
    rep = {"sparse": sparse}
    if cfg.n_dense:
        rep["dense"] = jnp.broadcast_to(
            batch["dense"][:, None, :],
            (b, nc, cfg.n_dense)).reshape(b * nc, -1)
    return forward(cfg, params, rep).reshape(b, nc)


def reduced(cfg: RecsysConfig, **overrides) -> RecsysConfig:
    small = dict(
        vocab_sizes=tuple(min(v, 1000) for v in cfg.vocab_sizes),
        seq_len=min(cfg.seq_len, 10),
    )
    if cfg.bot_mlp:
        small["bot_mlp"] = (32, cfg.embed_dim)
    if cfg.top_mlp:
        small["top_mlp"] = (32, 1)
    small.update(overrides)
    return replace(cfg, **small)
