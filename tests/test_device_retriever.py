"""Device-resident index + cost-model planner (the zero-copy serving path).

Pins the PR-3 contract at every layer:

* **sparse** — ``DeviceIndex`` uploads posting arrays once (counted by the
  ``TRANSFERS`` instrumentation); ``fragment_plan`` compiles a batch into
  block-grouped run fragments that exactly cover Σ df; the descriptor-only
  mode of ``gather_posting_runs`` never copies postings; the hot-token LRU
  makes the host fallback byte-identical while re-gathering hot runs once.
* **kernel** — the scalar-prefetch resident kernel and the two-level
  (chunk→shard) reduction are exact against the ``ScipyBM25`` oracle on
  all five variants, including robertson's negative IDF where default
  (never-touched) documents must outrank matched ones.
* **core** — ``plan_retrieval`` picks full-scan for head-heavy batches on
  tiny vocabularies (Σ df ≈ nnz), gathered for tail batches on large
  corpora, honors forced regimes, and is monotone in the work ratio.
* **serve** — one ``DeviceRetriever`` behind ``scorer="auto"``; steady-state
  ``retrieve_batch`` on a resident index ships ZERO posting bytes
  host→device; ``rescale`` reuses runtimes for shards whose postings did
  not move (no re-upload).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import given, make_corpus, settings, st
from repro.core import (BM25Params, ScipyBM25, build_index,
                        build_sharded_indexes, default_doc_ids,
                        dense_oracle_scores, pad_queries, plan_retrieval,
                        topk_numpy)
from repro.core.retrieval import DEFAULT_CROSSOVER
from repro.serve import DeviceRetriever, RetrievalEngine
from repro.sparse.block_csr import (TRANSFERS, DeviceIndex, PostingRunCache,
                                    fragment_plan, gather_posting_runs,
                                    reset_transfer_stats)

# transfer/plan counters asserted here change legitimately when a
# chaos fault forces a ladder hop (e.g. an extra host-gather upload)
pytestmark = pytest.mark.no_chaos

ALL_VARIANTS = ["robertson", "atire", "lucene", "bm25l", "bm25+"]

SMALL = dict(block_size=16, tile=16, acc_block=16, frag=8, q_max=8)


# -- tentpole: scalar-prefetch resident path == ScipyBM25 oracle -------------

@pytest.mark.parametrize("method", ALL_VARIANTS)
def test_resident_matches_oracle_all_variants(method, rng):
    corpus = make_corpus(rng, n_docs=90, n_vocab=64, max_len=20)
    idx = build_index(corpus, 64, params=BM25Params(method=method))
    dr = DeviceRetriever(idx, regime="gathered", gather="resident", **SMALL)
    queries = [rng.integers(0, 64, size=rng.integers(1, 6)).astype(np.int32)
               for _ in range(4)]
    ids, vals = dr.retrieve_batch(queries, 7)
    assert dr.last_plan.sum_df < idx.nnz  # really did less than a full scan
    sc = ScipyBM25(idx)
    for i, q in enumerate(queries):
        oracle = sc.score(q)
        _, ref_v = topk_numpy(oracle[None], 7)
        np.testing.assert_allclose(vals[i], ref_v[0], atol=1e-4)
        # returned ids carry their exact oracle scores
        np.testing.assert_allclose(oracle[ids[i]], vals[i], atol=1e-4)


def test_resident_defaults_beat_negative_scores():
    """robertson head tokens score NEGATIVE; docs in blocks the batch never
    visits score exactly 0 and must win — the resident path recovers them
    via the unvisited-block default splice."""
    rng = np.random.default_rng(7)
    corpus = [rng.integers(0, 6, size=rng.integers(3, 10)).astype(np.int32)
              for _ in range(40)]
    idx = build_index(corpus, 6, params=BM25Params(method="robertson"))
    dr = DeviceRetriever(idx, regime="gathered", gather="resident", **SMALL)
    q = np.array([0, 1], dtype=np.int32)          # head tokens, negative IDF
    ids, vals = dr.retrieve_batch([q], 10)
    oracle = ScipyBM25(idx).score(q)
    _, ref_v = topk_numpy(oracle[None], 10)
    np.testing.assert_allclose(vals[0], ref_v[0], atol=1e-5)
    np.testing.assert_allclose(oracle[ids[0]], vals[0], atol=1e-5)
    assert (vals[0] == 0.0).any()                 # defaults actually won


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 31), k=st.integers(1, 12),
       variant=st.sampled_from(ALL_VARIANTS))
def test_property_resident_equals_topk_numpy(seed, k, variant):
    rng = np.random.default_rng(seed)
    v = int(rng.integers(20, 80))
    corpus = [rng.integers(0, v, size=rng.integers(1, 25)).astype(np.int32)
              for _ in range(int(rng.integers(20, 120)))]
    k = min(k, len(corpus))
    idx = build_index(corpus, v, params=BM25Params(method=variant))
    dr = DeviceRetriever(idx, regime="gathered", gather="resident", **SMALL)
    queries = [rng.integers(0, v, size=rng.integers(1, 7)).astype(np.int32)
               for _ in range(3)]
    ids, vals = dr.retrieve_batch(queries, k)
    sc = ScipyBM25(idx)
    for i, q in enumerate(queries):
        oracle = sc.score(q)
        _, ref_v = topk_numpy(oracle[None], k)
        np.testing.assert_allclose(vals[i], ref_v[0], atol=1e-4)
        np.testing.assert_allclose(oracle[ids[i]], vals[i], atol=1e-4)


def test_resident_degenerate_queries(rng):
    """Empty queries and df=0 tokens produce NO fragments — results must
    still be the exact all-defaults top-k."""
    corpus = make_corpus(rng, n_docs=30, n_vocab=50)
    for method in ("lucene", "bm25l"):            # sparse + shifted
        idx = build_index(corpus, 50, params=BM25Params(method=method))
        dr = DeviceRetriever(idx, regime="gathered", gather="resident",
                             **SMALL)
        sc = ScipyBM25(idx)
        for q in (np.zeros(0, dtype=np.int32),
                  np.array([48, 49], dtype=np.int32)):  # likely-sparse tail
            ids, vals = dr.retrieve_batch([q], 5)
            oracle = sc.score(q)
            _, ref_v = topk_numpy(oracle[None], 5)
            np.testing.assert_allclose(vals[0], ref_v[0], atol=1e-5)
            np.testing.assert_allclose(oracle[ids[0]], vals[0], atol=1e-5)


# -- tentpole: zero per-batch posting copies on a resident index -------------

def test_steady_state_ships_zero_posting_bytes(rng):
    """THE acceptance invariant: after build, batched serving on a resident
    index performs no host→device posting-array transfer — only O(U)
    descriptor/query traffic. The host-gather fallback, by contrast, ships
    postings every batch (that contrast is what the counters prove)."""
    corpus = make_corpus(rng, n_docs=120, n_vocab=60)
    idx = build_index(corpus, 60, params=BM25Params(method="lucene"))
    reset_transfer_stats()
    dr = DeviceRetriever(idx, regime="auto", gather="resident", **SMALL)
    build_uploads = TRANSFERS.posting_uploads
    assert build_uploads > 0                      # the one-time residency
    dr.warmup(k=5)                                # compile both regimes
    reset_transfer_stats()
    qs = [rng.integers(0, 60, size=4).astype(np.int32) for _ in range(5)]
    for regime in (None, "blocked", "gathered"):  # auto + both forced
        for _ in range(2):
            dr.retrieve_batch(qs, 5, regime=regime)
    assert TRANSFERS.posting_uploads == 0, vars(TRANSFERS)
    assert TRANSFERS.posting_bytes == 0
    assert TRANSFERS.descriptor_uploads > 0       # descriptors DID flow
    # contrast: the host-gather fallback pays O(Σ df) uploads per batch
    host = DeviceRetriever(idx, regime="gathered", gather="host", **SMALL)
    reset_transfer_stats()
    host.retrieve_batch(qs, 5)
    assert TRANSFERS.posting_uploads > 0
    assert TRANSFERS.posting_bytes > 0


def test_fragment_plan_covers_sum_df_and_groups_blocks(rng):
    corpus = make_corpus(rng, n_docs=100, n_vocab=40, max_len=25)
    idx = build_index(corpus, 40, params=BM25Params())
    uniq = np.unique(rng.integers(0, 40, size=6)).astype(np.int64)
    fp = fragment_plan(idx, uniq, block_size=16, frag=8)
    df = np.diff(idx.indptr)
    assert fp.sum_df == int(df[uniq].sum())
    d = fp.desc
    n = fp.n_frags
    # fragments exactly cover Σ df, padding slots carry zero valid
    assert int(d[1, :n].sum()) == fp.sum_df
    assert (d[1, n:] == 0).all()
    # every fragment's postings really belong to (token, block)
    for j in range(n):
        start, valid, u, blk = d[0, j], d[1, j], d[2, j], d[3, j]
        lo, hi = idx.indptr[uniq[u]], idx.indptr[uniq[u] + 1]
        assert lo <= start and start + valid <= hi
        docs = idx.doc_ids[start:start + valid]
        assert (docs // 16 == blk).all()
    # block-grouped: first/last flags delimit maximal constant-block spans
    blocks = d[3, :n]
    assert (np.flatnonzero(d[4, :n] == 1)
            == np.flatnonzero(np.r_[True, blocks[1:] != blocks[:-1]])).all()
    np.testing.assert_array_equal(fp.vis_blocks, np.unique(blocks))
    # descriptor-only gather emits the same traversal plan, no copies
    rd = gather_posting_runs(idx, uniq, descriptors_only=True)
    assert rd.sum_df == fp.sum_df
    np.testing.assert_array_equal(rd.lens, df[uniq])


def test_default_doc_ids_skips_visited_blocks():
    dids = default_doc_ids(np.array([0, 2]), k=5, n_docs=50, block_size=16)
    # blocks 1 and 3 are unvisited -> ids 16.. then 48..
    np.testing.assert_array_equal(dids, [16, 17, 18, 19, 20])
    dids = default_doc_ids(np.array([0, 1, 2]), k=5, n_docs=50,
                           block_size=16)
    np.testing.assert_array_equal(dids, [48, 49, 50, 50, 50])  # padded
    assert (default_doc_ids(np.arange(4), 3, 50, 16) == 50).all()


# -- cost-model planner -------------------------------------------------------

def test_planner_head_heavy_tiny_vocab_full_scans(rng):
    """Tiny vocabulary + head-heavy batch: Σ df ≈ nnz, the gather would
    touch every tile anyway — the planner must pick the full scan."""
    corpus = [rng.integers(0, 8, size=rng.integers(5, 15)).astype(np.int32)
              for _ in range(80)]
    idx = build_index(corpus, 8, params=BM25Params())
    dr = DeviceRetriever(idx, regime="auto", gather="resident", **SMALL)
    qs = [np.arange(8, dtype=np.int32) for _ in range(4)]   # all tokens
    ids, vals = dr.retrieve_batch(qs, 5)
    assert dr.last_plan.regime == "blocked"
    assert dr.last_plan.work_ratio < DEFAULT_CROSSOVER
    oracle = ScipyBM25(idx).score(qs[0])
    np.testing.assert_allclose(oracle[ids[0]], vals[0], atol=1e-4)


def test_planner_tail_batch_large_corpus_gathers(rng):
    """Large vocabulary + tail tokens: Σ df ≪ nnz — must gather."""
    corpus = make_corpus(rng, n_docs=200, n_vocab=500, max_len=30)
    idx = build_index(corpus, 500, params=BM25Params())
    dr = DeviceRetriever(idx, regime="auto", gather="resident", **SMALL)
    q = np.unique(rng.integers(400, 500, size=3)).astype(np.int32)
    ids, vals = dr.retrieve_batch([q], 5)
    assert dr.last_plan.regime == "gathered"
    assert dr.last_plan.work_ratio >= DEFAULT_CROSSOVER
    oracle = ScipyBM25(idx).score(q)
    np.testing.assert_allclose(oracle[ids[0]], vals[0], atol=1e-4)


def test_planner_forced_aliases_honored(rng):
    """blocked/gathered scorers force their regime regardless of the work
    ratio; the plan still records the evidence and the forced flag."""
    corpus = make_corpus(rng, n_docs=60, n_vocab=200)
    idx = build_index(corpus, 200, params=BM25Params())
    q = [np.array([5], dtype=np.int32)]           # tail-ish: auto => gathered
    br = DeviceRetriever(idx, regime="blocked", block_size=16, tile=16, q_max=8)
    br.retrieve_batch(q, 3)
    assert br.last_plan.regime == "blocked" and br.last_plan.forced
    gr = DeviceRetriever(idx, regime="gathered", tile=16, acc_block=16, q_max=8)
    gr.retrieve_batch(q, 3)
    assert gr.last_plan.regime == "gathered" and gr.last_plan.forced
    # both give the same exact answer
    np.testing.assert_allclose(br.retrieve(q[0], 3)[1],
                               gr.retrieve(q[0], 3)[1], atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(sum_df=st.integers(0, 10 ** 6), nnz=st.integers(1, 10 ** 7),
       crossover=st.floats(0.5, 16.0))
def test_property_planner_monotone_and_total(sum_df, nnz, crossover):
    """The decision is total, respects the crossover threshold, and is
    monotone: shrinking Σ df (cheaper gather) never flips gathered→blocked."""
    p = plan_retrieval(sum_df, nnz, crossover=crossover)
    assert p.regime in ("blocked", "gathered") and not p.forced
    if sum_df and p.work_ratio >= crossover:
        assert p.regime == "gathered"
    smaller = plan_retrieval(sum_df // 2, nnz, crossover=crossover)
    if p.regime == "gathered":
        assert smaller.regime == "gathered"
    assert plan_retrieval(sum_df, nnz, regime="blocked",
                          crossover=crossover).forced


# -- satellite: two-level (chunk -> shard) reduce -----------------------------

def test_two_level_reduce_matches_two_step_merge(rng):
    """two_level=True winners == host merge of the per-chunk winners, on a
    layout with many chunks (the traffic the reduction eliminates)."""
    from repro.kernels.bm25_gather_score import bm25_gather_score_topk
    from repro.sparse.block_csr import pack_query_batch
    corpus = make_corpus(rng, n_docs=150, n_vocab=30, max_len=25)
    idx = build_index(corpus, 30, params=BM25Params(method="robertson"))
    queries = [rng.integers(0, 30, size=5).astype(np.int32)
               for _ in range(3)]
    toks, wts, uniq = pad_queries(queries, 8, return_uniq=True)
    gp = gather_posting_runs(idx, uniq, acc_block=16, tile=16)
    assert gp.n_chunks > 1                        # the reduce has work to do
    uniq_tab, weights = pack_query_batch(toks, wts, u_max=32, uniq=uniq)
    args = (jnp.asarray(gp.token_ids), jnp.asarray(gp.slot_ids),
            jnp.asarray(gp.scores), jnp.asarray(uniq_tab),
            jnp.asarray(weights), jnp.asarray(gp.candidates))
    for k in (1, 4, 9):
        v2, i2 = bm25_gather_score_topk(*args, acc_block=16, k=k, tile_p=16,
                                        two_level=True)
        v1, i1 = bm25_gather_score_topk(*args, acc_block=16, k=k, tile_p=16)
        assert v2.shape == (k, v1.shape[2])       # [k, B], not [nc, k, B]
        nc, _, b = v1.shape
        fv = np.transpose(np.asarray(v1), (2, 0, 1)).reshape(b, nc * k)
        fi = np.transpose(np.asarray(i1), (2, 0, 1)).reshape(b, nc * k)
        order = np.argsort(-fv, kind="stable", axis=1)[:, :k]
        np.testing.assert_allclose(np.asarray(v2).T,
                                   np.take_along_axis(fv, order, 1),
                                   atol=1e-5)
        # ids agree wherever values are finite (ties may reorder ids)
        finite = np.asarray(v2).T > np.finfo(np.float32).min / 2
        got_sets = [set(np.asarray(i2).T[r][finite[r]])
                    for r in range(b)]
        ref_ids = np.take_along_axis(fi, order, 1)
        for r in range(b):
            ref_vals = np.take_along_axis(fv, order, 1)[r]
            scores_of = {i: v for i, v in zip(fi[r], fv[r])}
            for gid in got_sets[r]:
                assert any(abs(scores_of.get(gid, np.inf) - rv) < 1e-4
                           for rv in ref_vals)
        del ref_ids


def test_two_level_falls_back_when_k_exceeds_acc_block(rng):
    """Regression: with k > acc_block the in-launch fold can only keep
    acc_block winners — ranks acc_block+1..k would silently become default
    docs. The ops wrapper must fall back to the exact chunked merge."""
    from repro.kernels import ops
    from repro.sparse.block_csr import (pack_query_batch,
                                        query_nonoccurrence_shift)
    corpus = make_corpus(rng, n_docs=200, n_vocab=40, max_len=20)
    idx = build_index(corpus, 40, params=BM25Params(method="lucene"))
    queries = [rng.integers(0, 40, size=5).astype(np.int32)
               for _ in range(2)]
    toks, wts, uniq = pad_queries(queries, 8, return_uniq=True)
    gp = gather_posting_runs(idx, uniq, acc_block=16, tile=16)
    uniq_tab, weights = pack_query_batch(toks, wts, u_max=32, uniq=uniq)
    shift = query_nonoccurrence_shift(idx.nonoccurrence, toks, wts)
    k = 40                                        # > acc_block = 16
    ids, vals = ops.bm25_retrieve_gathered(
        jnp.asarray(gp.token_ids), jnp.asarray(gp.slot_ids),
        jnp.asarray(gp.scores), jnp.asarray(uniq_tab),
        jnp.asarray(weights), jnp.asarray(gp.candidates),
        jnp.asarray(shift), acc_block=16, k=k,
        n_docs=int(idx.doc_lens.size), tile_p=16)
    sc = ScipyBM25(idx)
    for i, q in enumerate(queries):
        oracle = sc.score(q)
        _, ref_v = topk_numpy(oracle[None], k)
        np.testing.assert_allclose(np.asarray(vals)[i], ref_v[0], atol=1e-4)
        np.testing.assert_allclose(oracle[np.asarray(ids)[i]],
                                   np.asarray(vals)[i], atol=1e-4)


def test_rescale_boundary_through_empty_docs_not_reused(rng):
    """Regression: a reshard boundary moving through posting-LESS documents
    changes a shard's doc range without changing any posting byte. Reusing
    the old runtime would leave the same global docs owned by TWO shards
    (duplicate merged results). doc_lens must participate in the match."""
    corpus = [rng.integers(0, 12, size=5).astype(np.int32) for _ in range(10)]
    corpus[3] = np.zeros(0, np.int32)             # empty docs at the
    corpus[4] = np.zeros(0, np.int32)             # 2-way shard boundary
    p = BM25Params(method="robertson")            # empty docs score 0: top
    shards = build_sharded_indexes(corpus, 12, 2, params=p)
    eng = RetrievalEngine(shards, k=6, deadline_s=30.0, scorer="auto",
                          scorer_opts=dict(gather="resident", **SMALL))
    eng.rescale(3)                                # bounds move through 3-4
    q = np.array([0, 1], dtype=np.int32)
    r = eng.retrieve(q)
    assert len(set(r.ids.tolist())) == r.ids.size, r.ids   # no duplicates
    oracle = dense_oracle_scores(corpus, 12, q, p)
    _, ref_v = topk_numpy(oracle[None], 6)
    np.testing.assert_allclose(np.sort(r.scores), np.sort(ref_v[0]),
                               atol=1e-4)
    np.testing.assert_allclose(oracle[r.ids], r.scores, atol=1e-4)


# -- satellite: hot-token LRU for the host-gather fallback --------------------

def test_run_cache_identical_results_and_hits(rng):
    corpus = make_corpus(rng, n_docs=80, n_vocab=40)
    idx = build_index(corpus, 40, params=BM25Params())
    uniq = np.unique(rng.integers(0, 40, size=8)).astype(np.int64)
    cold = gather_posting_runs(idx, uniq, acc_block=16, tile=16)
    cache = PostingRunCache(capacity=64)
    g1 = gather_posting_runs(idx, uniq, acc_block=16, tile=16, cache=cache)
    assert cache.misses == uniq.size and cache.hits == 0
    g2 = gather_posting_runs(idx, uniq, acc_block=16, tile=16, cache=cache)
    assert cache.hits == uniq.size                # second batch: all hot
    for g in (g1, g2):
        np.testing.assert_array_equal(g.token_ids, cold.token_ids)
        np.testing.assert_array_equal(g.slot_ids, cold.slot_ids)
        np.testing.assert_array_equal(g.scores, cold.scores)
        np.testing.assert_array_equal(g.candidates, cold.candidates)


def test_run_cache_lru_eviction():
    cache = PostingRunCache(capacity=2)
    for t in (1, 2, 3):
        cache.put(t, np.array([t]), np.array([float(t)]))
    assert len(cache) == 2
    assert cache.get(1) is None                   # evicted (oldest)
    assert cache.get(3) is not None
    cache.get(2)                                  # touch 2 -> 3 is now LRU
    cache.put(4, np.array([4]), np.array([4.0]))
    assert cache.get(3) is None and cache.get(2) is not None


def test_host_retriever_uses_cache_across_batches(rng):
    corpus = make_corpus(rng, n_docs=60, n_vocab=30)
    idx = build_index(corpus, 30, params=BM25Params(method="bm25+"))
    dr = DeviceRetriever(idx, regime="gathered", gather="host",
                         run_cache=32, **SMALL)
    sc = ScipyBM25(idx)
    q = rng.integers(0, 30, size=5).astype(np.int32)
    for _ in range(3):                            # same hot tokens repeat
        ids, vals = dr.retrieve_batch([q], 6)
        oracle = sc.score(q)
        np.testing.assert_allclose(oracle[ids[0]], vals[0], atol=1e-4)
    assert dr.run_cache.hits > 0


# -- serve: one retriever via scorer="auto", elastic reuse --------------------

def test_engine_auto_scorer_exact_batch(rng):
    corpus = make_corpus(rng, n_docs=120, n_vocab=60)
    p = BM25Params(method="bm25l")
    shards = build_sharded_indexes(corpus, 60, 3, params=p)
    eng = RetrievalEngine(shards, k=9, deadline_s=30.0, scorer="auto",
                          scorer_opts=dict(gather="resident", **SMALL))
    qs = [rng.integers(0, 60, size=5).astype(np.int32) for _ in range(4)]
    rb = eng.retrieve_batch(qs)
    assert rb.ids.shape == (4, 9) and not rb.degraded
    for i, q in enumerate(qs):
        oracle = dense_oracle_scores(corpus, 60, q, p)
        _, ref_v = topk_numpy(oracle[None], 9)
        np.testing.assert_allclose(rb.scores[i], ref_v[0], atol=1e-3)
        for d, s in zip(rb.ids[i], rb.scores[i]):
            assert abs(oracle[d] - s) < 1e-3
        r1 = eng.retrieve(q)
        np.testing.assert_allclose(r1.scores, rb.scores[i], atol=1e-5)


def test_rescale_reuses_unchanged_shards(rng):
    """Same-count rescale keeps every runtime (zero new posting uploads);
    a boundary-moving rescale rebuilds only what moved."""
    corpus = make_corpus(rng, n_docs=60, n_vocab=30)
    shards = build_sharded_indexes(corpus, 30, 4, params=BM25Params())
    eng = RetrievalEngine(shards, k=3, deadline_s=30.0, scorer="auto",
                          scorer_opts=dict(gather="resident", **SMALL))
    assert eng.last_build_stats == {"reused": 0, "built": 4,
                                    "blockmax_reused": 0}
    reset_transfer_stats()
    eng.rescale(4)                                # boundaries unchanged
    assert eng.last_build_stats == {"reused": 4, "built": 0,
                                    "blockmax_reused": 0}
    assert TRANSFERS.posting_uploads == 0         # nothing re-uploaded
    eng.rescale(2)                                # boundaries move
    assert eng.last_build_stats["built"] > 0
    q = rng.integers(0, 30, size=4).astype(np.int32)
    r = eng.retrieve(q)
    oracle = dense_oracle_scores(corpus, 30, q, BM25Params())
    _, ref_v = topk_numpy(oracle[None], 3)
    np.testing.assert_allclose(np.sort(r.scores), np.sort(ref_v[0]),
                               atol=1e-3)


def test_auto_engine_survives_rescale_to_empty_shards(rng):
    corpus = make_corpus(rng, n_docs=3, n_vocab=20)
    shards = build_sharded_indexes(corpus, 20, 2, params=BM25Params())
    eng = RetrievalEngine(shards, k=2, deadline_s=10.0, scorer="auto",
                          scorer_opts=dict(gather="resident", **SMALL))
    eng.rescale(5)                                # 3 docs over 5 shards
    q = rng.integers(0, 20, size=3).astype(np.int32)
    r = eng.retrieve(q)
    oracle = dense_oracle_scores(corpus, 20, q, BM25Params())
    _, ref_v = topk_numpy(oracle[None], 2)
    np.testing.assert_allclose(np.sort(r.scores), np.sort(ref_v[0]),
                               atol=1e-3)


def test_device_index_memory_flags(rng):
    """Forced-regime builds skip the layout they will never touch."""
    corpus = make_corpus(rng, n_docs=40, n_vocab=20)
    idx = build_index(corpus, 20, params=BM25Params())
    gathered_only = DeviceIndex.build(idx, with_blocked=False, frag=8)
    assert gathered_only.blk_tok is None
    assert gathered_only.csc_doc_ids is not None
    blocked_only = DeviceIndex.build(idx, with_csc=False)
    assert blocked_only.csc_doc_ids is None and blocked_only.blk_tok \
        is not None
    dr = DeviceRetriever(idx, regime="gathered", gather="resident", **SMALL)
    with pytest.raises(ValueError, match="gathered-only"):
        dr.retrieve_batch([np.array([1], np.int32)], 2, regime="blocked")
