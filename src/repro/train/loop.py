"""Fault-tolerant training loop.

Design points for 1000+ nodes (validated in tests at small scale):

* fixed-shape steps — XLA collectives can never deadlock on data-dependent
  shapes; a straggling host delays but never wedges the step;
* periodic checkpoints with atomic manifests (checkpoint.py) +
  ``auto-resume``: the loop entry point looks for the latest COMPLETE
  checkpoint and continues from there, so preemption between (or during)
  steps loses at most ``ckpt_every`` steps;
* step-level retry: a transient step failure (simulated in tests via an
  injected fault hook) is retried from the last known-good state rather
  than crashing the job;
* metrics emitted per step through a callback (production would export to
  a metrics service; tests assert on them).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterator

import jax

from .checkpoint import (latest_complete_step, load_checkpoint,
                         save_checkpoint)


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    max_step_retries: int = 2
    log_every: int = 10
    metrics_cb: Callable[[int, dict], None] | None = None
    fault_hook: Callable[[int], None] | None = None   # tests inject faults


def run_training(train_step, state: tuple, batches: Iterator[dict],
                 cfg: LoopConfig) -> tuple:
    """Run (params, opt_state) through the loop with resume + retry.

    ``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``
    must be jit-compiled by the caller. Returns the final state.
    """
    params, opt_state = state
    start = 0
    if cfg.ckpt_dir:
        latest = latest_complete_step(cfg.ckpt_dir)
        if latest is not None:
            params, opt_state = load_checkpoint(
                cfg.ckpt_dir, latest, (params, opt_state))
            start = latest
    step = start
    while step < cfg.total_steps:
        batch = next(batches)
        for attempt in range(cfg.max_step_retries + 1):
            try:
                if cfg.fault_hook is not None:
                    cfg.fault_hook(step)
                new_params, new_opt, metrics = train_step(
                    params, opt_state, batch)
                # materialize before committing (surfaces async failures)
                jax.block_until_ready(metrics["loss"])
                params, opt_state = new_params, new_opt
                break
            except Exception:
                if attempt >= cfg.max_step_retries:
                    raise
                # retry from last good state (params/opt unchanged)
                continue
        step += 1
        if cfg.metrics_cb and (step % cfg.log_every == 0
                               or step == cfg.total_steps):
            cfg.metrics_cb(step, {k: float(v) for k, v in metrics.items()})
        if cfg.ckpt_dir and step % cfg.ckpt_every == 0:
            save_checkpoint(cfg.ckpt_dir, step, (params, opt_state))
    if cfg.ckpt_dir and step > start and step % cfg.ckpt_every != 0:
        save_checkpoint(cfg.ckpt_dir, step, (params, opt_state))
    return params, opt_state
