"""AutoInt [arXiv:1810.11921]: self-attentive feature interaction.

39 sparse fields (Criteo: 13 bucketized numeric + 26 categorical),
embed_dim=16, 3 attention layers, 2 heads, d_attn=32. Field vocabularies
below total ≈1M features (the paper's Criteo feature count).
"""

from ..models.recsys import RecsysConfig, reduced
from .common import recsys_cells

# 13 bucketized numeric fields + 26 categorical (sums to ~998k features)
AUTOINT_VOCABS = tuple([64] * 13) + (
    1461, 584, 1_000_000 - 13 * 64 - 1461 - 584 - 305 - 24 - 12518 - 634
    - 4 - 42647 - 5161 - 3176 - 27 - 11746 - 155 - 4 - 977 - 15 - 286181
    - 105 - 142573 - 300_000 - 12337 - 11 - 5641 - 34,
    305, 24, 12518, 634, 4, 42647, 5161, 3176, 27, 11746, 155, 4, 977, 15,
    286181, 105, 142573, 300_000, 12337, 11, 5641, 34,
)

CONFIG = RecsysConfig(
    name="autoint", model="autoint",
    vocab_sizes=AUTOINT_VOCABS, embed_dim=16,
    n_attn_layers=3, n_heads=2, d_attn=32,
)

SMOKE = reduced(CONFIG)

FAMILY = "recsys"


def cells():
    return recsys_cells("autoint", CONFIG)
