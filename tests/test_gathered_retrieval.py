"""Query-driven gathered retrieval (the O(Σ df) device path) vs oracles.

The gathered pipeline — posting-run descriptors from the CSC ``indptr``,
vectorized run gather, candidate compaction, the ``bm25_gather_score_topk``
kernel with its candidate-sized VMEM accumulator, default-document splice —
must return the SAME top-k (ids carrying their exact oracle scores) as the
``topk_numpy``-over-``ScipyBM25`` reference on every BM25 variant,
including the shifted ones (whose §2.1 nonoccurrence offset makes
non-candidate documents score nonzero) and robertson (whose negative IDF
makes matched docs rank BELOW unmatched ones — the splice's hard case).

Also pins: the adaptive-budget retry of the sharded device variant, the
vectorized ``pad_queries`` against the seed's per-query loop, the
df-weighted ``suggest_p_max``, and degenerate/empty-shard edge cases.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import given, make_corpus, settings, st
from repro.core import (BM25Params, ScipyBM25, batch_posting_budget,
                        bucket_pow2, build_index, build_sharded_indexes,
                        dense_oracle_scores, merge_topk_batch, pad_queries,
                        sharded_retrieve_adaptive, suggest_p_max, topk_numpy)
from repro.kernels import ops, ref
from repro.serve import DeviceRetriever
from repro.kernels.bm25_gather_score import bm25_gather_score_topk
from repro.sparse.block_csr import (gather_posting_runs, pack_query_batch,
                                    posting_runs, query_nonoccurrence_shift)

ALL_VARIANTS = ["robertson", "atire", "lucene", "bm25l", "bm25+"]


def _gathered_retrieve(idx, queries, k, *, acc_block=32, tile=16, q_max=8):
    """Host gather → kernel → merge+splice, returning [B, k] ids/scores."""
    toks, wts = pad_queries(queries, q_max)
    uniq_batch = np.unique(toks[toks >= 0])
    gp = gather_posting_runs(idx, uniq_batch, acc_block=acc_block, tile=tile)
    uniq_tab, weights = pack_query_batch(toks, wts, u_max=4 * q_max)
    shift = query_nonoccurrence_shift(idx.nonoccurrence, toks, wts)
    n_docs = int(idx.doc_lens.size)
    ids, vals = ops.bm25_retrieve_gathered(
        jnp.asarray(gp.token_ids), jnp.asarray(gp.slot_ids),
        jnp.asarray(gp.scores), jnp.asarray(uniq_tab), jnp.asarray(weights),
        jnp.asarray(gp.candidates), jnp.asarray(shift),
        acc_block=gp.acc_block, k=min(k, n_docs), n_docs=n_docs,
        tile_p=min(tile, gp.p_pad))
    return np.asarray(ids), np.asarray(vals), gp


# -- tentpole: gathered pipeline == ScipyBM25 / topk_numpy oracle -----------

@pytest.mark.parametrize("method", ALL_VARIANTS)
def test_gathered_matches_oracle_all_variants(method, rng):
    corpus = make_corpus(rng, n_docs=90, n_vocab=64, max_len=20)
    idx = build_index(corpus, 64, params=BM25Params(method=method))
    queries = [rng.integers(0, 64, size=rng.integers(1, 6)).astype(np.int32)
               for _ in range(4)]
    ids, vals, gp = _gathered_retrieve(idx, queries, k=7)
    assert gp.sum_df < idx.nnz            # really did less than a full scan
    sc = ScipyBM25(idx)
    for i, q in enumerate(queries):
        oracle = sc.score(q)
        _, ref_v = topk_numpy(oracle[None], 7)
        np.testing.assert_allclose(vals[i], ref_v[0], atol=1e-4)
        # returned ids carry their exact oracle scores (not just same values)
        np.testing.assert_allclose(oracle[ids[i]], vals[i], atol=1e-4)


def test_gathered_kernel_matches_ref_and_emits_global_ids(rng):
    """Kernel == jnp oracle; winners carry GLOBAL doc ids (no offset math)."""
    corpus = make_corpus(rng, n_docs=70, n_vocab=50)
    idx = build_index(corpus, 50, params=BM25Params(method="lucene"))
    queries = [rng.integers(0, 50, size=4).astype(np.int32)
               for _ in range(3)]
    toks, wts = pad_queries(queries, 8)
    uniq_batch = np.unique(toks[toks >= 0])
    gp = gather_posting_runs(idx, uniq_batch, acc_block=16, tile=16)
    uniq_tab, weights = pack_query_batch(toks, wts, u_max=16)
    args = (jnp.asarray(gp.token_ids), jnp.asarray(gp.slot_ids),
            jnp.asarray(gp.scores), jnp.asarray(uniq_tab),
            jnp.asarray(weights), jnp.asarray(gp.candidates))
    k = 5
    vals, gids = bm25_gather_score_topk(*args, acc_block=16, k=k,
                                        tile_p=16)
    assert vals.shape == (gp.n_chunks, k, 3)
    rv, ri = ref.bm25_gather_topk_ref(*args, acc_block=16, k=k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), atol=1e-5)
    # every emitted finite winner is a real candidate document id
    finite = np.asarray(vals) > np.finfo(np.float32).min / 2
    emitted = np.asarray(gids)[finite]
    assert np.isin(emitted, gp.candidates[gp.candidates >= 0]).all()
    # padding-slot winners (only when a chunk holds < k candidates) are -1
    assert (np.asarray(gids)[~finite] == -1).all()


def test_gathered_defaults_beat_negative_scores(rng):
    """robertson: matched docs can score NEGATIVE; the exact top-k must
    then prefer unmatched (default) docs at score 0 — the full-scan path
    gets this free, the gathered path must splice them in."""
    rng = np.random.default_rng(7)
    # tiny vocab => huge df => robertson IDF goes negative for head tokens
    corpus = [rng.integers(0, 6, size=rng.integers(3, 10)).astype(np.int32)
              for _ in range(40)]
    idx = build_index(corpus, 6, params=BM25Params(method="robertson"))
    q = np.array([0, 1], dtype=np.int32)          # head tokens, negative IDF
    ids, vals, _ = _gathered_retrieve(idx, [q], k=10)
    oracle = ScipyBM25(idx).score(q)
    _, ref_v = topk_numpy(oracle[None], 10)
    np.testing.assert_allclose(vals[0], ref_v[0], atol=1e-5)
    np.testing.assert_allclose(oracle[ids[0]], vals[0], atol=1e-5)
    assert (vals[0] == 0.0).any()                 # defaults actually won


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 31), k=st.integers(1, 12),
       variant=st.sampled_from(ALL_VARIANTS))
def test_property_gathered_equals_topk_numpy(seed, k, variant):
    """Random corpora/queries/k/variant: gathered pipeline == argpartition
    oracle, incl. shifted nonoccurrence offsets and chunked candidates."""
    rng = np.random.default_rng(seed)
    v = int(rng.integers(20, 80))
    corpus = [rng.integers(0, v, size=rng.integers(1, 25)).astype(np.int32)
              for _ in range(int(rng.integers(20, 120)))]
    k = min(k, len(corpus))
    idx = build_index(corpus, v, params=BM25Params(method=variant))
    queries = [rng.integers(0, v, size=rng.integers(1, 7)).astype(np.int32)
               for _ in range(3)]
    ids, vals, _ = _gathered_retrieve(idx, queries, k=k)
    sc = ScipyBM25(idx)
    for i, q in enumerate(queries):
        oracle = sc.score(q)
        _, ref_v = topk_numpy(oracle[None], k)
        np.testing.assert_allclose(vals[i], ref_v[0], atol=1e-4)
        np.testing.assert_allclose(oracle[ids[i]], vals[i], atol=1e-4)


# -- run descriptors and adaptive buckets -----------------------------------

def test_posting_runs_and_batch_budget(rng):
    corpus = make_corpus(rng, n_docs=60, n_vocab=30)
    idx = build_index(corpus, 30, params=BM25Params())
    uniq = np.array([3, 7, 20], dtype=np.int64)
    starts, lens = posting_runs(idx.indptr, uniq)
    df = np.diff(idx.indptr)
    np.testing.assert_array_equal(lens, df[uniq])
    np.testing.assert_array_equal(starts, idx.indptr[uniq])
    toks = np.array([[3, 7, -1], [7, 20, -1]], dtype=np.int32)
    assert batch_posting_budget(idx, toks) == int(df[[3, 7, 20]].sum())


def test_gather_work_is_sum_df_not_nnz(rng):
    """The gathered layout's posting count is Σ df(q), NOT nnz."""
    corpus = make_corpus(rng, n_docs=100, n_vocab=200, max_len=25)
    idx = build_index(corpus, 200, params=BM25Params())
    uniq = np.unique(rng.integers(0, 200, size=3)).astype(np.int64)
    gp = gather_posting_runs(idx, uniq, acc_block=64, tile=16)
    df = np.diff(idx.indptr)
    assert gp.sum_df == int(df[uniq].sum())
    assert int((gp.token_ids >= 0).sum()) == gp.sum_df
    assert gp.work_ratio(idx.nnz) == idx.nnz / max(gp.sum_df, 1)
    # candidate table is the sorted union of the gathered runs' doc ids
    expect = np.unique(np.concatenate(
        [idx.doc_ids[idx.indptr[t]:idx.indptr[t + 1]] for t in uniq]))
    got = gp.candidates[gp.candidates >= 0]
    np.testing.assert_array_equal(np.sort(got), expect)


def test_adaptive_budget_retry_no_silent_truncation(rng):
    """Sharded device variant: an undersized bucket RETRIES larger instead
    of silently truncating — final scores are exact."""
    from repro.core.retrieval import stack_shard_arrays
    from repro.launch.mesh import make_test_mesh
    corpus = make_corpus(rng, n_docs=60, n_vocab=10)   # tiny vocab: huge df
    p = BM25Params(method="lucene")
    shards = build_sharded_indexes(corpus, 10, 1, params=p)
    mesh = make_test_mesh(1)
    axes = tuple(mesh.shape.keys())
    arrs, ndoc = stack_shard_arrays(shards, mesh, axes)
    queries = [np.arange(8, dtype=np.int32)]
    toks, wts = pad_queries(queries, 8)
    assert batch_posting_budget(shards[0], toks) > 16   # floor WILL overflow
    retrieve = sharded_retrieve_adaptive(mesh, axes, k=5,
                                         n_docs_per_shard=ndoc, p_floor=16)
    ids, vals, p_used = retrieve(arrs, toks, wts)
    assert p_used > 16                                  # retried upward
    oracle = dense_oracle_scores(corpus, 10, queries[0], p)
    _, ref_v = topk_numpy(oracle[None], 5)
    np.testing.assert_allclose(np.asarray(vals)[0], ref_v[0], atol=1e-3)
    np.testing.assert_allclose(oracle[np.asarray(ids)[0]],
                               np.asarray(vals)[0], atol=1e-3)


def test_sharded_gathered_matches_full_scan_variant(rng):
    """gathered=True and the classic per-query segment-sum variant agree."""
    from repro.core.retrieval import make_sharded_retrieve, \
        stack_shard_arrays
    from repro.launch.mesh import make_test_mesh
    corpus = make_corpus(rng, n_docs=80, n_vocab=40)
    shards = build_sharded_indexes(corpus, 40, 1,
                                   params=BM25Params(method="bm25+"))
    mesh = make_test_mesh(1)
    axes = tuple(mesh.shape.keys())
    arrs, ndoc = stack_shard_arrays(shards, mesh, axes)
    queries = [rng.integers(0, 40, size=5).astype(np.int32)
               for _ in range(3)]
    toks, wts = pad_queries(queries, 8)
    classic = make_sharded_retrieve(mesh, axes, p_max=1024, k=6,
                                    n_docs_per_shard=ndoc)
    gathered = make_sharded_retrieve(mesh, axes, p_max=1024, k=6,
                                     n_docs_per_shard=ndoc, gathered=True)
    ci, cv = classic(arrs, toks, wts)
    gi, gv = gathered(arrs, toks, wts)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(cv), atol=1e-4)


def test_uneven_shards_emit_no_phantom_docs():
    """Stacking pads smaller shards up to ndoc_pad; a padded doc must never
    surface as a (duplicate or out-of-range) result id. Regression: with
    shards of sizes [3, 4] and k = n_docs both sharded variants used to
    return one shard's padding slot (scoring the bare nonoccurrence shift)
    instead of the last real document. Needs 2 fake devices → subprocess
    (the main test process must stay single-device, see conftest)."""
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["JAX_PLATFORMS"] = "cpu"   # fake devices need the cpu
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import numpy as np, jax
        from repro.core import (BM25Params, build_sharded_indexes,
                                pad_queries, dense_oracle_scores, topk_numpy)
        from repro.core.retrieval import (make_sharded_retrieve,
                                          stack_shard_arrays)
        from repro.launch.mesh import make_mesh_from
        rng = np.random.default_rng(0)
        corpus = [rng.integers(0, 12, size=rng.integers(1, 8)
                               ).astype(np.int32) for _ in range(7)]
        p = BM25Params(method="bm25l")
        shards = build_sharded_indexes(corpus, 12, 2, params=p)  # [3, 4]
        assert {s.doc_lens.size for s in shards} == {3, 4}
        mesh = make_mesh_from(jax.devices())
        axes = tuple(mesh.shape.keys())
        arrs, ndoc = stack_shard_arrays(shards, mesh, axes)
        assert ndoc == 4
        toks, wts = pad_queries([np.array([0], np.int32)], 4)
        oracle = dense_oracle_scores(corpus, 12, np.array([0]), p)
        _, ref_v = topk_numpy(oracle[None], 7)
        for gathered in (False, True):
            fn = make_sharded_retrieve(mesh, axes, p_max=64, k=7,
                                       n_docs_per_shard=ndoc,
                                       gathered=gathered)
            ids, vals = fn(arrs, toks, wts)
            ids, vals = np.asarray(ids)[0], np.asarray(vals)[0]
            assert len(set(ids.tolist())) == 7, (gathered, ids)
            assert (ids < 7).all(), (gathered, ids)
            np.testing.assert_allclose(vals, ref_v[0], atol=1e-4)
            np.testing.assert_allclose(oracle[ids], vals, atol=1e-4)
        print("PHANTOM-OK")
    """)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=300, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PHANTOM-OK" in proc.stdout


def test_bucket_pow2_bounds_recompiles():
    assert bucket_pow2(1) == 512
    assert bucket_pow2(512) == 512
    assert bucket_pow2(513) == 1024
    assert bucket_pow2(5000, floor=64) == 8192
    assert bucket_pow2(10 ** 6, cap=8192) == 8192    # capped, caller chunks
    # distinct buckets over a huge demand range stay logarithmic
    buckets = {bucket_pow2(n) for n in range(1, 100_000, 97)}
    assert len(buckets) < 10


# -- degenerate and empty cases ---------------------------------------------

def test_gathered_degenerate_queries(rng):
    """Empty / all-pad / no-matching-postings queries return exact default
    top-k (every doc scores the nonoccurrence shift)."""
    corpus = make_corpus(rng, n_docs=30, n_vocab=50)
    for method in ("lucene", "bm25l"):               # sparse + shifted
        idx = build_index(corpus, 50, params=BM25Params(method=method))
        sc = ScipyBM25(idx)
        empty = np.zeros(0, dtype=np.int32)
        ids, vals, gp = _gathered_retrieve(idx, [empty], k=5)
        assert gp.n_candidates == 0 and gp.sum_df == 0
        oracle = sc.score(empty)
        np.testing.assert_allclose(oracle[ids[0]], vals[0], atol=1e-5)
        _, ref_v = topk_numpy(oracle[None], 5)
        np.testing.assert_allclose(vals[0], ref_v[0], atol=1e-5)


def test_gathered_query_token_without_postings(rng):
    """Tokens with df=0 (never indexed) gather nothing but stay exact."""
    corpus = [np.array([0, 1, 2], np.int32), np.array([1, 2], np.int32)]
    idx = build_index(corpus, 10, params=BM25Params(method="bm25+"))
    q = np.array([7, 8], dtype=np.int32)             # df=0 tokens only
    ids, vals, gp = _gathered_retrieve(idx, [q], k=2)
    assert gp.sum_df == 0
    oracle = ScipyBM25(idx).score(q)
    np.testing.assert_allclose(oracle[ids[0]], vals[0], atol=1e-5)


def test_gathered_engine_survives_rescale_to_empty_shards(rng):
    """rescale() can create zero-doc shards; the gathered scorer must not
    crash on them (mirror of the blocked-scorer regression test)."""
    from repro.serve import RetrievalEngine
    corpus = make_corpus(rng, n_docs=3, n_vocab=20)
    shards = build_sharded_indexes(corpus, 20, 2, params=BM25Params())
    eng = RetrievalEngine(shards, k=2, deadline_s=10.0, scorer="gathered")
    eng.rescale(5)                               # 3 docs over 5 shards
    q = rng.integers(0, 20, size=3).astype(np.int32)
    r = eng.retrieve(q)
    oracle = dense_oracle_scores(corpus, 20, q, BM25Params())
    _, ref_v = topk_numpy(oracle[None], 2)
    np.testing.assert_allclose(np.sort(r.scores), np.sort(ref_v[0]),
                               atol=1e-3)


def test_engine_gathered_batch_exact_and_single_agree(rng):
    from repro.serve import RetrievalEngine
    corpus = make_corpus(rng, n_docs=120, n_vocab=60)
    p = BM25Params(method="bm25l")
    shards = build_sharded_indexes(corpus, 60, 3, params=p)
    eng = RetrievalEngine(shards, k=9, deadline_s=30.0, scorer="gathered")
    qs = [rng.integers(0, 60, size=5).astype(np.int32) for _ in range(4)]
    rb = eng.retrieve_batch(qs)
    assert rb.ids.shape == (4, 9) and not rb.degraded
    for i, q in enumerate(qs):
        oracle = dense_oracle_scores(corpus, 60, q, p)
        _, ref_v = topk_numpy(oracle[None], 9)
        np.testing.assert_allclose(rb.scores[i], ref_v[0], atol=1e-3)
        for d, s in zip(rb.ids[i], rb.scores[i]):
            assert abs(oracle[d] - s) < 1e-3
        r1 = eng.retrieve(q)
        np.testing.assert_allclose(r1.scores, rb.scores[i], atol=1e-5)


def test_merge_topk_batch_matches_per_query_merge(rng):
    from repro.core import merge_topk
    b, s_parts = 5, 3
    parts = [(rng.integers(0, 10_000, size=(b, 4)).astype(np.int64),
              rng.normal(size=(b, 4)).astype(np.float32))
             for _ in range(s_parts)]
    ids, sc = merge_topk_batch(parts, 6)
    assert ids.shape == (b, 6)
    for i in range(b):
        per_q = [(p[0][i], p[1][i]) for p in parts]
        ri, rs = merge_topk(per_q, 6)
        np.testing.assert_allclose(sc[i], rs, atol=1e-7)
    # degenerate: empty parts and k=0
    i0, s0 = merge_topk_batch([], 5)
    assert i0.shape[1] == 0
    iz, sz = merge_topk_batch(parts, 0)
    assert iz.shape == (b, 0)


# -- satellite: vectorized pad_queries == the seed's loop --------------------

def _pad_queries_loop(query_tokens, q_max):
    """The seed's per-query np.unique loop, kept as the semantics oracle."""
    b = len(query_tokens)
    toks = np.full((b, q_max), -1, dtype=np.int32)
    wts = np.zeros((b, q_max), dtype=np.float32)
    for i, q in enumerate(query_tokens):
        q = q[q >= 0]
        uniq, counts = np.unique(q, return_counts=True)
        if uniq.size > q_max:
            keep = np.argsort(-counts, kind="stable")[:q_max]
            uniq, counts = uniq[keep], counts[keep]
        toks[i, : uniq.size] = uniq
        wts[i, : uniq.size] = counts
    return toks, wts


def test_vectorized_pad_queries_matches_loop(rng):
    for _ in range(30):
        b = int(rng.integers(0, 7))
        qs = [rng.integers(-2, 25, size=rng.integers(0, 20)).astype(np.int32)
              for _ in range(b)]
        q_max = int(rng.integers(1, 9))
        t1, w1 = pad_queries(qs, q_max)
        t2, w2 = _pad_queries_loop(qs, q_max)
        np.testing.assert_array_equal(t1, t2)
        np.testing.assert_array_equal(w1, w2)
    # edge: empty batch, empty queries, all-padding queries
    t, w = pad_queries([], 4)
    assert t.shape == (0, 4)
    t, w = pad_queries([np.zeros(0, np.int32),
                        np.array([-1, -1], np.int32)], 4)
    assert (t == -1).all() and (w == 0).all()


def test_pad_queries_return_uniq_matches_full_sort(rng):
    """return_uniq derives the batch-unique table from the run set — must
    equal a plain np.unique over all valid tokens (incl. empty queries)."""
    qs = [rng.integers(-2, 30, size=rng.integers(0, 15)).astype(np.int32)
          for _ in range(5)]
    toks, wts, uniq = pad_queries(qs, 16, return_uniq=True)
    flat = np.concatenate(qs)
    np.testing.assert_array_equal(uniq, np.unique(flat[flat >= 0]))
    t2, w2 = pad_queries(qs, 16)
    np.testing.assert_array_equal(toks, t2)
    _, _, u0 = pad_queries([], 4, return_uniq=True)
    assert u0.size == 0


def test_retriever_ragged_batch_sizes_exact(rng):
    """The batch dim is pow2-bucketed (padded with empty queries) — ragged
    batch sizes must still return [b_true, k] exact results."""
    corpus = make_corpus(rng, n_docs=60, n_vocab=40)
    idx = build_index(corpus, 40, params=BM25Params(method="bm25+"))
    gr = DeviceRetriever(idx, regime="gathered", tile=64, acc_block=32)
    sc = ScipyBM25(idx)
    for b in (1, 3, 9):                          # crosses the B=8 floor
        qs = [rng.integers(0, 40, size=4).astype(np.int32)
              for _ in range(b)]
        ids, vals = gr.retrieve_batch(qs, 5)
        assert ids.shape == (b, 5)
        for i, q in enumerate(qs):
            oracle = sc.score(q)
            _, ref_v = topk_numpy(oracle[None], 5)
            np.testing.assert_allclose(vals[i], ref_v[0], atol=1e-4)
            np.testing.assert_allclose(oracle[ids[i]], vals[i], atol=1e-4)


def test_pad_queries_truncation_keeps_highest_count(rng):
    q = np.array([5, 5, 5, 2, 2, 9, 1], dtype=np.int32)
    toks, wts = pad_queries([q], 2)
    assert toks[0, 0] == 5 and wts[0, 0] == 3
    assert toks[0, 1] == 2 and wts[0, 1] == 2


# -- satellite: df-weighted suggest_p_max -----------------------------------

def test_suggest_p_max_df_weighted_on_zipf():
    """On a Zipfian df profile the weighted quantile sizes for the HEAD
    (where query traffic lands), the unweighted one for the tail."""
    from repro.core.index import BM25Index
    from repro.core.variants import BM25Params as P

    df = np.r_[np.full(10, 10_000), np.ones(10_000)].astype(np.int64)
    indptr = np.zeros(df.size + 1, dtype=np.int64)
    np.cumsum(df, out=indptr[1:])
    nnz = int(indptr[-1])
    idx = BM25Index(
        indptr=indptr, doc_ids=np.zeros(nnz, np.int32),
        scores=np.zeros(nnz, np.float32),
        nonoccurrence=np.zeros(df.size, np.float32),
        doc_lens=np.ones(100, np.int32), n_docs=100, n_vocab=df.size,
        l_avg=1.0, variant="lucene", params=P())
    # unweighted median over distinct tokens would say df≈1; df-weighted
    # median sees half the posting mass in the head => budget ~ head df
    assert suggest_p_max(idx, 8, quantile=0.5, tile=1) >= 8 * 10_000 // 2
    # quantile=1.0 stays the safe max-df bound (old behavior preserved)
    assert suggest_p_max(idx, 8, quantile=1.0, tile=1) == 8 * 10_000


def test_suggest_p_max_covers_realistic_zipf_traffic():
    from repro.data.corpus import zipf_corpus, zipf_queries
    corpus = zipf_corpus(400, 300, avg_len=40)
    idx = build_index(corpus, 300, params=BM25Params())
    toks, _ = pad_queries(zipf_queries(32, 300, q_len=5), 8)
    need = max(batch_posting_budget(idx, toks[i:i + 1])
               for i in range(toks.shape[0]))
    assert suggest_p_max(idx, 8, quantile=0.95, tile=64) >= need
