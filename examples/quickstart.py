"""Quickstart: index a corpus, retrieve with exact BM25 scores.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import BM25Retriever

corpus = [
    "a cat is a feline and likes to purr",
    "a dog is the human's best friend and loves to play",
    "a bird is a beautiful animal that can fly",
    "a fish is a creature that lives in water and swims",
    "sparse lexical search remains fast and robust",
    "eager scoring moves all BM25 math to indexing time",
]

retriever = BM25Retriever(method="lucene", k1=1.5, b=0.75).index(corpus)

queries = ["does the fish purr like a cat?",
           "how fast is sparse eager search"]
ids, scores = retriever.retrieve(queries, k=3)
for q, row_ids, row_scores in zip(queries, np.asarray(ids),
                                  np.asarray(scores)):
    print(f"\nquery: {q}")
    for i, s in zip(row_ids, row_scores):
        print(f"  {s:6.3f}  {corpus[i]}")

# variants: the same API covers all five Kamphuis et al. scoring methods
for method in ("robertson", "atire", "bm25l", "bm25+", "tfldp"):
    r = BM25Retriever(method=method).index(corpus)
    ids, scores = r.retrieve(["eager sparse scoring"], k=1)
    print(f"{method:10s} top doc: {int(np.asarray(ids)[0, 0])} "
          f"score {float(np.asarray(scores)[0, 0]):.3f}")
